//! Unified-job-layer bench: E15 (two concurrent jobs under
//! capacity-share queues at 1/2/4/8 nodes, per-queue throughput and
//! grant-wait latency) and E16 (fair-share preemption on/off — reclaim
//! latency for a late below-share tenant and the work wasted, with
//! checkpoint/resume absorbing the requeues).
mod common;
fn main() {
    common::run(&["e15", "e16"]);
}
