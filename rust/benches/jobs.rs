//! Unified-job-layer bench: E15 (two concurrent jobs — a scenario
//! campaign and a fleet-compaction drain — under capacity-share queues
//! at 1/2/4/8 nodes, reporting per-queue throughput and grant-wait
//! latency).
mod common;
fn main() {
    common::run(&["e15"]);
}
