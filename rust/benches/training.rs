//! Training-service benches: E7 (unified vs staged pipeline, Fig 7),
//! E8 (parameter server tiered vs DFS, §4.2), E9 (train-step devices +
//! Fig 9 GPU scaling).
mod common;
fn main() {
    common::run(&["e7", "e8", "e9"]);
}
