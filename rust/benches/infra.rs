//! Infrastructure benches: E1 (SQL DCE vs MapReduce, §2.1), E2 (tiered
//! store vs DFS, §2.2), E4 (container overhead, §2.3), E12 (reliability
//! soak, §2.1), E17 (sharded-store fast path vs single-lock baseline).
mod common;
fn main() {
    common::run(&["e1", "e2", "e4", "e12", "e17"]);
}
