//! Heterogeneous-computing bench: E3 (CNN inference GPU/FPGA/CPU with
//! energy, §2.3 — measured host rows + paper-hardware roofline rows).
mod common;
fn main() {
    common::run(&["e3"]);
}
