//! Fleet-ingest bench: E14 (sustained ingest throughput at 1/2/4/8 log
//! partitions, with and without concurrent compaction contention).
mod common;
fn main() {
    common::run(&["e14"]);
}
