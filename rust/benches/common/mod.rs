//! Shared bench runner: every `cargo bench` target replays a subset of
//! the paper-experiment harness (platform::experiments) and prints the
//! paper-style tables. Set `ADCLOUD_BENCH_QUICK=1` for CI-sized runs.

use adcloud::platform::experiments;

pub fn run(ids: &[&str]) {
    let quick = std::env::var("ADCLOUD_BENCH_QUICK").is_ok();
    println!(
        "adcloud bench — {} experiment(s), {} mode\n",
        ids.len(),
        if quick { "quick" } else { "full" }
    );
    let mut failures = 0;
    for id in ids {
        let start = std::time::Instant::now();
        match experiments::run_experiment(id, quick) {
            Ok(table) => {
                println!("{}", table.render());
                println!("  (bench wall time: {:?})\n", start.elapsed());
            }
            Err(e) => {
                eprintln!("{id} FAILED: {e:#}\n");
                failures += 1;
            }
        }
    }
    if failures > 0 {
        std::process::exit(1);
    }
}
