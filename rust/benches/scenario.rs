//! Scenario-engine bench: E13 (campaign throughput, scenarios/sec at
//! 1/2/4/8 simulated nodes, calibrated by a real campaign run).
mod common;
fn main() {
    common::run(&["e13"]);
}
