//! Simulation-service benches: E5 (Fig 6 core scaling, calibrated
//! virtual time) and E6 (replay 1->8 node scaling, §3.3).
mod common;
fn main() {
    common::run(&["e5", "e6"]);
}
