//! Map-generation benches: E10 (fused vs staged pipeline, §5.2) and
//! E11 (ICP device comparison, §5.2).
mod common;
fn main() {
    common::run(&["e10", "e11"]);
}
