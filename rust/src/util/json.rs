//! Minimal JSON parser/emitter (no external deps in this offline build).
//!
//! Covers everything the platform needs: the artifact manifest written by
//! `python/compile/aot.py`, platform config files, and experiment-report
//! emission. Full escape handling, `\uXXXX` (incl. surrogate pairs),
//! arbitrary nesting; numbers are f64 (the manifest only holds small
//! integers).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap for deterministic emission order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------ parsing
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ---------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected unsigned integer, got {f}");
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    // --------------------------------------------------------- builders
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---------------------------------------------------------- emission
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON at byte {}", self.i))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{08}'),
                        b'f' => out.push('\u{0c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint {cp:#x}"))?,
                            );
                        }
                        other => bail!("bad escape '\\{}'", other as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string at byte {}", self.i - 1),
                c => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        self.i = start + len;
                        out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        self.i += 4;
        u32::from_str_radix(s, 16).map_err(|e| anyhow!("bad \\u escape: {e}"))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":{"d":false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parses_raw_utf8() {
        let v = Json::parse(r#"{"k":"héllo 世界"}"#).unwrap();
        assert_eq!(v.get("k").unwrap().as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"x"],"b":true,"n":null,"nested":{"deep":[[]]}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
        // pretty round-trips too
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"unterminated", "[] []"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn integer_emission_is_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn accessors_type_check() {
        let v = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64().unwrap(), 3);
        assert!(v.get("s").unwrap().as_u64().is_err());
        assert!(v.req("missing").is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
    }
}
