//! Small shared utilities: deterministic RNG, byte formatting, timing,
//! and the in-tree JSON codec.

pub mod json;

use std::time::Duration;

/// Deterministic xoshiro256** RNG. Every workload generator in the repo is
/// seeded through this so experiments are reproducible bit-for-bit.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.next_u64() % n
    }

    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independent child stream (for per-partition RNGs).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

/// Human-readable duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 3600.0 {
        format!("{:.1} h", s / 3600.0)
    } else if s >= 60.0 {
        format!("{:.1} min", s / 60.0)
    } else if s >= 1.0 {
        format!("{:.2} s", s)
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// f32 slice -> little-endian bytes (shuffle/cache codecs, BinPipe frames).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Little-endian bytes -> f32 vec. Length must be a multiple of 4.
pub fn bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn rng_below_bounds() {
        let mut r = Rng::new(9);
        for n in [1u64, 2, 7, 100] {
            for _ in 0..100 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn rng_split_streams_differ() {
        let mut r = Rng::new(1);
        let mut a = r.split(0);
        let mut b = r.split(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn f32_roundtrip() {
        let xs = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)), xs);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
