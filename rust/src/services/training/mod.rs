//! Offline model training service (paper section 4): synthetic labelled
//! corpus, storage-backed parameter server, synchronous data-parallel
//! trainer over the accelerator queues, and the unified-vs-staged
//! pipeline comparison.

pub mod data;
pub mod param_server;
pub mod pipeline;
pub mod trainer;

pub use data::{gen_dataset, label_histogram, pack_batch, shard, Example};
pub use param_server::{average_grads, MomentumSgd, ParamServer, ParamStore};
pub use pipeline::{run_staged, run_unified, PipelineReport};
pub use trainer::{DistTrainer, TrainReport, BATCH};
