//! Synthetic labelled image corpus + sharding (the training service's
//! stand-in for the paper's proprietary perception datasets).
//!
//! Ten classes, each a distinct oriented-grating texture plus noise —
//! learnable by the small perception CNN within a few hundred steps, so
//! the end-to-end example shows a genuinely falling loss curve.

use crate::dce::DceContext;
use crate::util::Rng;
use anyhow::Result;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const NUM_CLASSES: usize = 10;

/// One labelled example.
#[derive(Debug, Clone)]
pub struct Example {
    /// (32,32,3) NHWC pixels.
    pub pixels: Vec<f32>,
    pub label: i32,
}

/// Generate one example of `class`.
pub fn gen_example(class: usize, rng: &mut Rng) -> Example {
    let theta = class as f32 * std::f32::consts::PI / NUM_CLASSES as f32;
    let freq = 0.25 + 0.06 * (class % 5) as f32;
    let (s, c) = theta.sin_cos();
    let phase = rng.next_f32() * std::f32::consts::TAU;
    let mut pixels = vec![0f32; IMG * IMG * CHANNELS];
    for y in 0..IMG {
        for x in 0..IMG {
            let u = c * x as f32 + s * y as f32;
            let v = -s * x as f32 + c * y as f32;
            let base = (freq * u + phase).sin();
            let alt = (0.5 * freq * v).cos();
            for ch in 0..CHANNELS {
                let mix = match ch {
                    0 => base,
                    1 => 0.5 * (base + alt),
                    _ => alt,
                };
                pixels[(y * IMG + x) * CHANNELS + ch] = mix + rng.normal_f32(0.0, 0.25);
            }
        }
    }
    Example { pixels, label: class as i32 }
}

/// A balanced, shuffled dataset.
pub fn gen_dataset(n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed);
    let mut out: Vec<Example> = (0..n).map(|i| gen_example(i % NUM_CLASSES, &mut rng)).collect();
    rng.shuffle(&mut out);
    out
}

/// Split a dataset into per-worker shards (data parallelism).
pub fn shard(data: Vec<Example>, shards: usize) -> Vec<Vec<Example>> {
    let shards = shards.max(1);
    let mut out: Vec<Vec<Example>> = (0..shards).map(|_| Vec::new()).collect();
    for (i, ex) in data.into_iter().enumerate() {
        out[i % shards].push(ex);
    }
    out
}

/// Class histogram of a dataset as a DCE shuffle job: `(label, 1)`
/// pairs through `reduce_by_key` — the shuffle-heavy slice of the
/// training pipeline's input-stats pass, and E22's training-side
/// end-to-end arm. Returns `(label, count)` sorted by label.
pub fn label_histogram(
    ctx: &DceContext,
    data: &[Example],
    parts: usize,
) -> Result<Vec<(i32, u64)>> {
    let pairs: Vec<(i32, u64)> = data.iter().map(|ex| (ex.label, 1u64)).collect();
    ctx.parallelize(pairs, parts)
        .reduce_by_key(|a, b| a + b, parts)
        .collect_sorted_by_key()
}

/// Pack `batch` examples (wrapping) starting at `offset` into NHWC f32 +
/// i32 labels, as the train-step artifact expects.
pub fn pack_batch(shard: &[Example], offset: usize, batch: usize) -> (Vec<f32>, Vec<i32>) {
    let mut xs = Vec::with_capacity(batch * IMG * IMG * CHANNELS);
    let mut ys = Vec::with_capacity(batch);
    for i in 0..batch {
        let ex = &shard[(offset + i) % shard.len()];
        xs.extend_from_slice(&ex.pixels);
        ys.push(ex.label);
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_is_balanced_and_deterministic() {
        let d1 = gen_dataset(100, 5);
        let d2 = gen_dataset(100, 5);
        assert_eq!(d1.len(), 100);
        for (a, b) in d1.iter().zip(d2.iter()) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.pixels, b.pixels);
        }
        let mut counts = [0usize; NUM_CLASSES];
        for ex in &d1 {
            counts[ex.label as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean absolute difference between class textures must exceed
        // noise-level — otherwise the CNN can't learn anything.
        let mut rng = Rng::new(1);
        let a = gen_example(0, &mut rng);
        let b = gen_example(5, &mut rng);
        let diff: f32 = a
            .pixels
            .iter()
            .zip(b.pixels.iter())
            .map(|(x, y)| (x - y).abs())
            .sum::<f32>()
            / a.pixels.len() as f32;
        assert!(diff > 0.3, "class textures too similar: {diff}");
    }

    #[test]
    fn sharding_partitions_everything() {
        let d = gen_dataset(103, 2);
        let shards = shard(d, 4);
        assert_eq!(shards.len(), 4);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 103);
        let max = shards.iter().map(|s| s.len()).max().unwrap();
        let min = shards.iter().map(|s| s.len()).min().unwrap();
        assert!(max - min <= 1, "unbalanced shards");
    }

    #[test]
    fn label_histogram_counts_every_class() {
        let ctx = DceContext::local().unwrap();
        let d = gen_dataset(100, 5);
        let h = label_histogram(&ctx, &d, 4).unwrap();
        assert_eq!(h.len(), NUM_CLASSES);
        assert!(h.iter().enumerate().all(|(i, &(l, c))| l == i as i32 && c == 10), "{h:?}");
    }

    #[test]
    fn pack_batch_shapes_and_wrapping() {
        let d = gen_dataset(10, 3);
        let (xs, ys) = pack_batch(&d, 8, 16);
        assert_eq!(xs.len(), 16 * IMG * IMG * CHANNELS);
        assert_eq!(ys.len(), 16);
        // Wrapped: example 8+2 == example 0 again at position 2.
        assert_eq!(ys[2], d[0].label);
    }
}
