//! Distributed data-parallel trainer (paper section 4, Figures 8-9).
//!
//! The paper's architecture: a driver manages Spark executors, each
//! hosting a Paddle trainer instance; per iteration every node computes
//! gradients on its shard, the parameter server aggregates and
//! broadcasts. Here each worker owns one shard and one accelerator
//! queue; per round workers pull the current parameters from the
//! [`ParamServer`], run the AOT train-step artifact (fwd+bwd) on their
//! batch, and the driver averages gradients, applies momentum SGD and
//! pushes the next version.

use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::data::{pack_batch, Example};
use super::param_server::{average_grads, MomentumSgd, ParamServer};
use crate::dce::ExecutorPool;
use crate::hetero::cpu_impls::PARAM_SHAPES;
use crate::hetero::Dispatcher;
use crate::resource::DeviceKind;
use crate::runtime::Tensor;

pub const BATCH: usize = 16;

/// One round's outcome.
#[derive(Debug, Clone)]
pub struct RoundStats {
    pub round: usize,
    pub mean_loss: f32,
    pub elapsed: Duration,
}

/// Full training run report.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub rounds: Vec<RoundStats>,
    pub total: Duration,
    pub workers: usize,
    pub device: DeviceKind,
    /// examples/second across the whole run.
    pub throughput: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f32 {
        self.rounds.first().map(|r| r.mean_loss).unwrap_or(f32::NAN)
    }
    pub fn last_loss(&self) -> f32 {
        self.rounds.last().map(|r| r.mean_loss).unwrap_or(f32::NAN)
    }
}

/// Build train-step artifact inputs from params + a packed batch.
fn train_inputs(params: &[Vec<f32>], xs: Vec<f32>, ys: Vec<i32>) -> Result<Vec<Tensor>> {
    let mut ins = Vec::with_capacity(8);
    for (p, (_, shape)) in params.iter().zip(PARAM_SHAPES.iter()) {
        ins.push(Tensor::from_f32(p.clone(), shape)?);
    }
    ins.push(Tensor::from_f32(xs, &[BATCH, 32, 32, 3])?);
    ins.push(Tensor::from_i32(ys, &[BATCH])?);
    Ok(ins)
}

/// Parse (loss, grads) from the artifact's output tuple.
fn parse_step_output(out: Vec<Tensor>) -> Result<(f32, Vec<Vec<f32>>)> {
    anyhow::ensure!(out.len() == 1 + PARAM_SHAPES.len(), "train step returned {}", out.len());
    let loss = out[0].scalar_value()?;
    let grads = out[1..]
        .iter()
        .map(|t| t.as_f32().map(|s| s.to_vec()))
        .collect::<Result<Vec<_>>>()?;
    Ok((loss, grads))
}

/// The distributed trainer.
pub struct DistTrainer {
    pub dispatcher: Dispatcher,
    pub device: DeviceKind,
    pub shards: Vec<Arc<Vec<Example>>>,
    pool: ExecutorPool,
}

impl DistTrainer {
    pub fn new(
        dispatcher: Dispatcher,
        device: DeviceKind,
        shards: Vec<Vec<Example>>,
    ) -> Self {
        let workers = shards.len().max(1);
        Self {
            dispatcher,
            device,
            shards: shards.into_iter().map(Arc::new).collect(),
            pool: ExecutorPool::new(workers),
        }
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Run synchronous data-parallel training for `rounds` iterations.
    pub fn train(
        &self,
        ps: &ParamServer,
        init: Vec<Vec<f32>>,
        rounds: usize,
        lr: f32,
    ) -> Result<TrainReport> {
        let mut params = init;
        let mut opt = MomentumSgd::new(lr, 0.9);
        ps.push(0, &params)?;
        let mut stats = Vec::with_capacity(rounds);
        let run_start = Instant::now();
        for round in 0..rounds {
            let round_start = Instant::now();
            // Fan out: every worker pulls the current version from the
            // parameter server and runs one train step on its shard.
            let tasks: Vec<Arc<dyn Fn(usize) -> Result<(f32, Vec<Vec<f32>>)> + Send + Sync>> =
                (0..self.workers())
                    .map(|w| {
                        let shard = self.shards[w].clone();
                        let dispatcher = self.dispatcher.clone();
                        let device = self.device;
                        let ps_params = ps.pull(round as u64);
                        let f: Arc<dyn Fn(usize) -> Result<(f32, Vec<Vec<f32>>)> + Send + Sync> =
                            Arc::new(move |_| {
                                let params =
                                    ps_params.as_ref().map_err(|e| anyhow::anyhow!("{e}"))?;
                                let (xs, ys) = pack_batch(&shard, round * BATCH, BATCH);
                                let ins = train_inputs(params, xs, ys)?;
                                let out = dispatcher.run_on(device, "cnn_train_b16", &ins)?;
                                parse_step_output(out)
                            });
                        f
                    })
                    .collect();
            let results = self.pool.run_tasks(tasks, 1)?;
            let mean_loss =
                results.iter().map(|(l, _)| l).sum::<f32>() / results.len().max(1) as f32;
            let grads = average_grads(results.into_iter().map(|(_, g)| g).collect());
            opt.apply(&mut params, &grads);
            ps.push(round as u64 + 1, &params)?;
            stats.push(RoundStats { round, mean_loss, elapsed: round_start.elapsed() });
        }
        let total = run_start.elapsed();
        let examples = rounds * self.workers() * BATCH;
        Ok(TrainReport {
            rounds: stats,
            total,
            workers: self.workers(),
            device: self.device,
            throughput: examples as f64 / total.as_secs_f64().max(1e-9),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::hetero::cpu_impls::init_params;
    use crate::hetero::{register_default_kernels, KernelRegistry};
    use crate::metrics::MetricsRegistry;
    use crate::runtime::shared_runtime;
    use crate::services::training::data::gen_dataset;
    use crate::storage::TieredStore;
    use crate::util::Rng;

    fn have_artifacts() -> bool {
        let ok = crate::artifacts_dir().join("manifest.json").is_file();
        if !ok {
            eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
        }
        ok
    }

    fn dispatcher() -> Dispatcher {
        let reg = KernelRegistry::new();
        if have_artifacts() {
            register_default_kernels(&reg, &shared_runtime().unwrap());
        }
        Dispatcher::new(reg, MetricsRegistry::new())
    }

    #[test]
    fn distributed_training_reduces_loss() {
        if !have_artifacts() {
            return;
        }
        let data = gen_dataset(256, 9);
        let shards = super::super::data::shard(data, 2);
        let trainer = DistTrainer::new(dispatcher(), DeviceKind::Gpu, shards);
        let store = TieredStore::test_store(&PlatformConfig::test().storage);
        let ps = ParamServer::tiered(store, "t");
        let report = trainer
            .train(&ps, init_params(&mut Rng::new(0)), 15, 0.05)
            .unwrap();
        assert_eq!(report.rounds.len(), 15);
        assert!(
            report.last_loss() < report.first_loss() * 0.9,
            "loss {} -> {}",
            report.first_loss(),
            report.last_loss()
        );
        assert!(report.throughput > 0.0);
        // The final version on the PS matches what training produced.
        assert!(ps.pull(15).is_ok());
    }

    #[test]
    fn single_worker_matches_multi_worker_first_step() {
        if !have_artifacts() {
            return;
        }
        // With identical shards and the same init, round-0 mean loss of a
        // 2-worker run equals the single-worker loss (synchronous SGD).
        let data = gen_dataset(64, 4);
        let t1 = DistTrainer::new(dispatcher(), DeviceKind::Gpu, vec![data.clone()]);
        let t2 = DistTrainer::new(dispatcher(), DeviceKind::Gpu, vec![data.clone(), data]);
        let store = TieredStore::test_store(&PlatformConfig::test().storage);
        let init = init_params(&mut Rng::new(3));
        let r1 = t1
            .train(&ParamServer::tiered(store.clone(), "a"), init.clone(), 1, 0.01)
            .unwrap();
        let r2 = t2
            .train(&ParamServer::tiered(store, "b"), init, 1, 0.01)
            .unwrap();
        assert!((r1.first_loss() - r2.first_loss()).abs() < 1e-4);
    }
}
