//! Unified vs staged training pipeline (paper section 4.1, Figure 7).
//!
//! "If we treated each stage as standalone, this would involve intensive
//! I/O to the underlying storage ... by using Spark as the unified
//! framework we can buffer the intermediate data in memory ... This
//! approach allowed us to effectively double, on average, the throughput."
//!
//! Both paths run the same three logical stages — ETL (decode+normalise),
//! feature prep (augmentation), training — over the same data. The
//! *unified* path keeps intermediates as cached RDD partitions; the
//! *staged* path materialises every boundary through the DFS device,
//! exactly like the left side of Figure 7.

use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::data::{gen_dataset, shard, Example, IMG};
use super::param_server::ParamServer;
use super::trainer::DistTrainer;
use crate::dce::DceContext;
use crate::hetero::cpu_impls::init_params;
use crate::hetero::Dispatcher;
use crate::platform::job::{run_stage, JobHandle, JobSpec};
use crate::platform::opts::JobOpts;
use crate::resource::{DeviceKind, ResourceManager, ResourceVec};
use crate::storage::DfsStore;
use crate::util::Rng;

/// Result of one pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub mode: &'static str,
    pub examples: usize,
    pub rounds: usize,
    pub elapsed: Duration,
    pub throughput_eps: f64,
    pub final_loss: f32,
}

const EXAMPLE_BYTES: u64 = (IMG * IMG * 3 * 4) as u64;

/// Stage 1 — ETL: decode + per-channel normalisation.
fn etl(mut ex: Example) -> Example {
    let mut mean = [0f32; 3];
    for (i, p) in ex.pixels.iter().enumerate() {
        mean[i % 3] += p;
    }
    let n = (ex.pixels.len() / 3) as f32;
    for m in mean.iter_mut() {
        *m /= n;
    }
    for (i, p) in ex.pixels.iter_mut().enumerate() {
        *p -= mean[i % 3];
    }
    ex
}

/// Stage 2 — feature prep: deterministic horizontal flip augmentation.
fn augment(idx: usize, mut ex: Example) -> Example {
    if idx % 2 == 1 {
        for y in 0..IMG {
            for x in 0..IMG / 2 {
                for c in 0..3 {
                    let a = (y * IMG + x) * 3 + c;
                    let b = (y * IMG + (IMG - 1 - x)) * 3 + c;
                    ex.pixels.swap(a, b);
                }
            }
        }
    }
    ex
}

/// Unified pipeline: ONE job on the unified job layer, intermediates
/// kept in memory between stages. The grant is held for the whole
/// pipeline; ETL + feature prep shard across it, training consumes the
/// prepared shards directly (no storage hop).
pub fn run_unified(
    ctx: &DceContext,
    rm: &Arc<ResourceManager>,
    dispatcher: &Dispatcher,
    device: DeviceKind,
    ps: &ParamServer,
    n_examples: usize,
    rounds: usize,
    opts: &JobOpts,
    seed: u64,
) -> Result<PipelineReport> {
    let start = Instant::now();
    let workers = opts.workers.max(1);
    let raw = gen_dataset(n_examples, seed);
    // The grant is elastic: fewer containers than `workers` means a
    // shard can own up to the whole dataset, so size each container's
    // limit for that worst case.
    let job = JobHandle::submit(
        rm,
        opts.spec().resources(ResourceVec::cores(
            1,
            (2 * EXAMPLE_BYTES * n_examples as u64).max(32 << 20),
        )),
    )?;
    // Stages 1+2 shard across the grant, each shard charged against its
    // container's memory limit; intermediates never leave memory.
    let per_shard = n_examples.div_ceil(job.shards()).max(1);
    let prepared = job.run_sharded(ctx, raw, move |sctx, items: Vec<Example>| {
        // ETL + augmentation are pure functions of the shard's input,
        // so preemption needs no checkpoint here: yield before doing
        // the work and the requeued shard recomputes it exactly. Round
        // state in stage 3 is already durable in the param server.
        sctx.check_preempted()?;
        sctx.run(|cctx| -> Result<Vec<Example>> {
            let est = EXAMPLE_BYTES * items.len() as u64;
            cctx.alloc_mem(est)?;
            // Global example indices (partitions are contiguous chunks
            // of `per_shard`), so the deterministic flip augmentation
            // matches the staged pipeline whatever the grant size.
            let base = sctx.shard * per_shard;
            let out = items
                .into_iter()
                .map(etl)
                .enumerate()
                .map(|(i, e)| augment(base + i, e))
                .collect();
            cctx.free_mem(est);
            Ok(out)
        })?
    })?;
    // Stage 3: training consumes the prepared shards directly, still
    // inside the job's grant.
    let shards = shard(prepared, workers);
    let trainer = DistTrainer::new(dispatcher.clone(), device, shards);
    let report = trainer.train(ps, init_params(&mut Rng::new(seed)), rounds, 0.05)?;
    let _ = job.finish();
    let elapsed = start.elapsed();
    Ok(PipelineReport {
        mode: "unified",
        examples: n_examples,
        rounds,
        elapsed,
        throughput_eps: n_examples as f64 / elapsed.as_secs_f64().max(1e-9),
        final_loss: report.last_loss(),
    })
}

/// Staged pipeline: ETL job → DFS → feature job → DFS → training job.
/// Each stage is its own application-master submission (the
/// pre-unification shape — one grant per stage, paid in churn) and
/// every boundary pays the remote-storage device.
pub fn run_staged(
    dfs: &Arc<DfsStore>,
    rm: &Arc<ResourceManager>,
    dispatcher: &Dispatcher,
    device: DeviceKind,
    ps: &ParamServer,
    n_examples: usize,
    rounds: usize,
    opts: &JobOpts,
    seed: u64,
) -> Result<PipelineReport> {
    let start = Instant::now();
    let workers = opts.workers.max(1);
    let mem = (2 * EXAMPLE_BYTES * n_examples as u64).max(32 << 20);
    let stage_spec = |stage: &str| {
        JobSpec::new(format!("{}-{stage}", opts.app))
            .queue(opts.queue.as_str())
            .grant_timeout(opts.grant_timeout)
            .resources(ResourceVec::cores(1, mem))
    };
    let raw = gen_dataset(n_examples, seed);
    // Stage 1: ETL — raw data lands on DFS (as it would from ingest),
    // is read back, transformed, and written out again.
    let etled = run_stage(rm, stage_spec("etl"), |_cctx| {
        for (i, _chunk) in raw.chunks(64.max(raw.len() / workers)).enumerate() {
            dfs.write(&format!("staged/raw-{i:05}"), &vec![0u8; (EXAMPLE_BYTES as usize) * 64])?;
        }
        dfs.device().charge(EXAMPLE_BYTES * n_examples as u64); // read all raw
        let etled: Vec<Example> = raw.into_iter().map(etl).collect();
        dfs.device().charge(EXAMPLE_BYTES * n_examples as u64); // write intermediates
        dfs.write("staged/etl-manifest", b"etl done")?;
        Ok(etled)
    })?;
    // Stage 2: feature prep — read intermediates, transform, write back.
    let prepared = run_stage(rm, stage_spec("feature"), |_cctx| {
        dfs.device().charge(EXAMPLE_BYTES * n_examples as u64);
        let prepared: Vec<Example> =
            etled.into_iter().enumerate().map(|(i, e)| augment(i, e)).collect();
        dfs.device().charge(EXAMPLE_BYTES * n_examples as u64);
        dfs.write("staged/feat-manifest", b"feat done")?;
        Ok(prepared)
    })?;
    // Stage 3: training — read prepared data from DFS into shards.
    let report = run_stage(rm, stage_spec("train"), |_cctx| {
        dfs.device().charge(EXAMPLE_BYTES * n_examples as u64);
        let shards = shard(prepared, workers);
        let trainer = DistTrainer::new(dispatcher.clone(), device, shards);
        trainer.train(ps, init_params(&mut Rng::new(seed)), rounds, 0.05)
    })?;
    let elapsed = start.elapsed();
    Ok(PipelineReport {
        mode: "staged",
        examples: n_examples,
        rounds,
        elapsed,
        throughput_eps: n_examples as f64 / elapsed.as_secs_f64().max(1e-9),
        final_loss: report.last_loss(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::hetero::{register_default_kernels, KernelRegistry};
    use crate::metrics::MetricsRegistry;
    use crate::runtime::shared_runtime;
    use crate::storage::TieredStore;

    fn have_artifacts() -> bool {
        let ok = crate::artifacts_dir().join("manifest.json").is_file();
        if !ok {
            eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
        }
        ok
    }

    #[test]
    fn etl_zero_means_channels() {
        let ex = gen_dataset(1, 1).remove(0);
        let e = etl(ex);
        let mut mean = [0f64; 3];
        for (i, p) in e.pixels.iter().enumerate() {
            mean[i % 3] += *p as f64;
        }
        for m in mean {
            assert!((m / (e.pixels.len() / 3) as f64).abs() < 1e-4);
        }
    }

    #[test]
    fn augment_flip_is_involution() {
        let ex = gen_dataset(1, 2).remove(0);
        let once = augment(1, ex.clone());
        let twice = augment(1, once.clone());
        assert_ne!(once.pixels, ex.pixels);
        assert_eq!(twice.pixels, ex.pixels);
        // Even indices untouched.
        assert_eq!(augment(0, ex.clone()).pixels, ex.pixels);
    }

    #[test]
    fn unified_and_staged_converge_similarly() {
        if !have_artifacts() {
            return;
        }
        let ctx = DceContext::local().unwrap();
        let rm = crate::resource::ResourceManager::new(
            &PlatformConfig::test().cluster,
            MetricsRegistry::new(),
        );
        let reg = KernelRegistry::new();
        register_default_kernels(&reg, &shared_runtime().unwrap());
        let d = Dispatcher::new(reg, MetricsRegistry::new());
        let store = TieredStore::test_store(&PlatformConfig::test().storage);
        let ps_u = ParamServer::tiered(store.clone(), "u");
        let before = ctx.dfs().device().ops_total();
        let uo = JobOpts::new("training-unified").workers(2);
        let u = run_unified(&ctx, &rm, &d, DeviceKind::Gpu, &ps_u, 64, 4, &uo, 7).unwrap();
        assert_eq!(ctx.dfs().device().ops_total(), before, "unified must not touch DFS");
        let ps_s = ParamServer::tiered(store, "s");
        let so = JobOpts::new("training-staged").workers(2);
        let s = run_staged(ctx.dfs(), &rm, &d, DeviceKind::Gpu, &ps_s, 64, 4, &so, 7).unwrap();
        assert!(ctx.dfs().device().ops_total() > before, "staged must hit DFS");
        assert_eq!(rm.live_containers(), 0, "both pipelines must return their grants");
        // Identical data + init => identical final loss.
        assert!((u.final_loss - s.final_loss).abs() < 1e-4, "{} vs {}", u.final_loss, s.final_loss);
    }
}
