//! Storage-backed parameter server (paper section 4.2).
//!
//! "We utilized Alluxio as our parameter server ... we have observed an
//! I/O performance gain factor of more than 5X by utilizing Alluxio as
//! parameter servers [compared to HDFS]." The server stores versioned
//! parameter tensors as blocks behind the [`ParamStore`] trait; the two
//! implementations ride the tiered store (memory-speed, the paper's
//! Alluxio) and the DFS baseline (disk+network, the paper's HDFS), so
//! experiment E8 is a like-for-like swap of the storage engine.

use anyhow::{bail, Result};
use std::sync::Arc;

use crate::hetero::cpu_impls::PARAM_SHAPES;
use crate::storage::{DfsStore, TieredStore};
use crate::util::{bytes_to_f32s, f32s_to_bytes};

/// Versioned parameter blocks.
pub trait ParamStore: Send + Sync {
    fn write_block(&self, key: &str, bytes: Vec<u8>) -> Result<()>;
    fn read_block(&self, key: &str) -> Result<Vec<u8>>;
}

impl ParamStore for TieredStore {
    fn write_block(&self, key: &str, bytes: Vec<u8>) -> Result<()> {
        // Pinned: evicting live parameters would be silly.
        self.put_opts(key, bytes, true, true)
    }
    fn read_block(&self, key: &str) -> Result<Vec<u8>> {
        Ok(self.get(key)?.as_ref().clone())
    }
}

impl ParamStore for DfsStore {
    fn write_block(&self, key: &str, bytes: Vec<u8>) -> Result<()> {
        self.write(key, &bytes)
    }
    fn read_block(&self, key: &str) -> Result<Vec<u8>> {
        self.read(key)
    }
}

/// The parameter server: versioned push/pull of the model's six tensors.
pub struct ParamServer {
    store: Arc<dyn ParamStore>,
    prefix: String,
}

impl ParamServer {
    pub fn new(store: Arc<dyn ParamStore>, prefix: &str) -> Self {
        Self { store, prefix: prefix.to_string() }
    }

    pub fn tiered(store: Arc<TieredStore>, prefix: &str) -> Self {
        Self::new(store, prefix)
    }

    pub fn dfs(store: Arc<DfsStore>, prefix: &str) -> Self {
        Self::new(store, prefix)
    }

    fn key(&self, version: u64, name: &str) -> String {
        format!("{}/v{:06}/{}", self.prefix, version, name)
    }

    /// Publish a parameter set as `version`.
    pub fn push(&self, version: u64, params: &[Vec<f32>]) -> Result<()> {
        if params.len() != PARAM_SHAPES.len() {
            bail!("expected {} tensors, got {}", PARAM_SHAPES.len(), params.len());
        }
        for (p, (name, shape)) in params.iter().zip(PARAM_SHAPES.iter()) {
            let n: usize = shape.iter().product();
            if p.len() != n {
                bail!("tensor {name}: {} values for shape {shape:?}", p.len());
            }
            self.store.write_block(&self.key(version, name), f32s_to_bytes(p))?;
        }
        Ok(())
    }

    /// Fetch the full parameter set of `version`.
    pub fn pull(&self, version: u64) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(PARAM_SHAPES.len());
        for (name, shape) in PARAM_SHAPES.iter() {
            let bytes = self.store.read_block(&self.key(version, name))?;
            let vals = bytes_to_f32s(&bytes);
            let n: usize = shape.iter().product();
            if vals.len() != n {
                bail!("tensor {name} v{version}: got {} values, want {n}", vals.len());
            }
            out.push(vals);
        }
        Ok(out)
    }
}

/// SGD with momentum applied driver-side after gradient aggregation.
pub struct MomentumSgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<Vec<f32>>,
}

impl MomentumSgd {
    pub fn new(lr: f32, momentum: f32) -> Self {
        Self {
            lr,
            momentum,
            velocity: PARAM_SHAPES
                .iter()
                .map(|(_, s)| vec![0f32; s.iter().product()])
                .collect(),
        }
    }

    /// params <- params - lr * (momentum * v + g)
    pub fn apply(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        for ((p, g), v) in params.iter_mut().zip(grads.iter()).zip(self.velocity.iter_mut()) {
            for i in 0..p.len() {
                v[i] = self.momentum * v[i] + g[i];
                p[i] -= self.lr * v[i];
            }
        }
    }
}

/// Average a set of per-worker gradients in place.
pub fn average_grads(mut all: Vec<Vec<Vec<f32>>>) -> Vec<Vec<f32>> {
    let n = all.len().max(1) as f32;
    let mut acc = all.remove(0);
    for worker in all {
        for (a, g) in acc.iter_mut().zip(worker.iter()) {
            for (x, y) in a.iter_mut().zip(g.iter()) {
                *x += *y;
            }
        }
    }
    for a in acc.iter_mut() {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::hetero::cpu_impls::init_params;
    use crate::util::Rng;

    fn params() -> Vec<Vec<f32>> {
        init_params(&mut Rng::new(1))
    }

    #[test]
    fn push_pull_roundtrip_tiered() {
        let store = TieredStore::test_store(&PlatformConfig::test().storage);
        let ps = ParamServer::tiered(store, "params");
        let p = params();
        ps.push(3, &p).unwrap();
        assert_eq!(ps.pull(3).unwrap(), p);
        assert!(ps.pull(4).is_err());
    }

    #[test]
    fn push_pull_roundtrip_dfs() {
        let cfg = crate::config::TierConfig {
            capacity_bytes: u64::MAX,
            bandwidth_bps: 1e9,
            latency_us: 0,
        };
        let dfs = DfsStore::new(cfg, false, crate::metrics::MetricsRegistry::new()).unwrap();
        let ps = ParamServer::dfs(dfs, "params");
        let p = params();
        ps.push(0, &p).unwrap();
        assert_eq!(ps.pull(0).unwrap(), p);
    }

    #[test]
    fn shape_validation() {
        let store = TieredStore::test_store(&PlatformConfig::test().storage);
        let ps = ParamServer::tiered(store, "p");
        let mut p = params();
        p[0].pop();
        assert!(ps.push(0, &p).is_err());
        assert!(ps.push(0, &p[..3].to_vec()).is_err());
    }

    #[test]
    fn average_grads_is_mean() {
        let g1 = vec![vec![1.0f32, 2.0], vec![0.0]];
        let g2 = vec![vec![3.0f32, 6.0], vec![2.0]];
        let avg = average_grads(vec![g1, g2]);
        assert_eq!(avg, vec![vec![2.0, 4.0], vec![1.0]]);
    }

    #[test]
    fn momentum_sgd_descends_quadratic() {
        // Minimise f(p) = 0.5 * p^2 on the first parameter entry.
        let mut p = params();
        p[0][0] = 10.0;
        let mut opt = MomentumSgd::new(0.1, 0.9);
        for _ in 0..100 {
            let mut grads: Vec<Vec<f32>> = p
                .iter()
                .map(|t| vec![0f32; t.len()])
                .collect();
            grads[0][0] = p[0][0];
            opt.apply(&mut p, &grads);
        }
        assert!(p[0][0].abs() < 0.5, "did not converge: {}", p[0][0]);
    }
}
