//! Mini SQL workload (experiment E1, paper section 2.1).
//!
//! The paper ran "a high number of production SQL queries" on MapReduce
//! and Spark with the same resources and saw 5X average, with one daily
//! query going from >1,000 s to 150 s. This module is that workload in
//! miniature: a vehicle-telemetry star schema, three representative
//! query shapes (filter+aggregate, join+group, and the multi-stage
//! "daily report"), each expressible on the DCE (pipelined, cached) and
//! on the MapReduce baseline (one disk-staged job per stage).

use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

use crate::dce::{DceContext, Rdd};
use crate::mapreduce::MapReduceEngine;
use crate::util::Rng;

/// One telemetry record emitted by a vehicle.
#[derive(Debug, Clone, PartialEq)]
pub struct Telemetry {
    pub vehicle: u32,
    pub ts: u64,
    pub speed_kmh: f32,
    pub sensor_bytes: u32,
    pub zone: u8,
}

/// Vehicle registry row (the dimension table).
#[derive(Debug, Clone, PartialEq)]
pub struct Vehicle {
    pub id: u32,
    pub fleet: u8,
    pub model_year: u16,
}

/// Deterministic workload generator.
pub fn generate_telemetry(n: usize, vehicles: u32, seed: u64) -> Vec<Telemetry> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| Telemetry {
            vehicle: rng.below(vehicles as u64) as u32,
            ts: i as u64,
            speed_kmh: (rng.range_f64(0.0, 120.0)) as f32,
            sensor_bytes: rng.below(2_000_000) as u32,
            zone: rng.below(16) as u8,
        })
        .collect()
}

pub fn generate_vehicles(vehicles: u32, seed: u64) -> Vec<Vehicle> {
    let mut rng = Rng::new(seed ^ 0x5EED_CAB5);
    (0..vehicles)
        .map(|id| Vehicle {
            id,
            fleet: rng.below(4) as u8,
            model_year: 2012 + rng.below(6) as u16,
        })
        .collect()
}

/// Query result row: key -> aggregate.
pub type AggRows = Vec<(u32, f64)>;

fn sorted(mut rows: AggRows) -> AggRows {
    rows.sort_by(|a, b| a.0.cmp(&b.0));
    rows
}

// ---------------------------------------------------------------------------
// Q1: SELECT vehicle, AVG(speed) WHERE zone < 8 GROUP BY vehicle
// ---------------------------------------------------------------------------

pub fn q1_dce(data: &Rdd<Telemetry>, parts: usize) -> Result<AggRows> {
    let pairs = data
        .filter(|t| t.zone < 8)
        .map(|t| (t.vehicle, (t.speed_kmh as f64, 1u64)))
        .reduce_by_key(|a, b| (a.0 + b.0, a.1 + b.1), parts)
        .map(|(k, (sum, n))| (k, sum / n as f64));
    Ok(sorted(pairs.collect()?))
}

pub fn q1_mr(
    engine: &MapReduceEngine,
    input: &crate::mapreduce::MrFile<Telemetry>,
    reducers: usize,
) -> Result<AggRows> {
    let out = engine.run(
        input,
        |t: &Telemetry| {
            if t.zone < 8 {
                vec![(t.vehicle, (t.speed_kmh as f64, 1u64))]
            } else {
                vec![]
            }
        },
        |k: &u32, vs: Vec<(f64, u64)>| {
            let (s, n) = vs.iter().fold((0.0, 0u64), |acc, v| (acc.0 + v.0, acc.1 + v.1));
            vec![(*k, s / n as f64)]
        },
        reducers,
    )?;
    Ok(sorted(out.collect()))
}

// ---------------------------------------------------------------------------
// Q2: join telemetry with the registry, aggregate bytes per fleet
// ---------------------------------------------------------------------------

pub fn q2_dce(data: &Rdd<Telemetry>, registry: &Rdd<Vehicle>, parts: usize) -> Result<AggRows> {
    let t = data.map(|t| (t.vehicle, t.sensor_bytes as u64));
    let r = registry.map(|v| (v.id, v.fleet));
    let rows = t
        .join(&r, parts)
        .map(|(_, (bytes, fleet))| (fleet as u32, bytes as f64))
        .reduce_by_key(|a, b| a + b, parts);
    Ok(sorted(rows.collect()?))
}

pub fn q2_mr(
    engine: &MapReduceEngine,
    telemetry: &crate::mapreduce::MrFile<Telemetry>,
    registry: &[Vehicle],
    reducers: usize,
) -> Result<AggRows> {
    // MR join: broadcast the dimension table into the mapper (map-side
    // hash join, standard Hadoop practice) — still a full extra
    // stage for the final aggregation.
    let dim: Arc<HashMap<u32, u8>> =
        Arc::new(registry.iter().map(|v| (v.id, v.fleet)).collect());
    let stage1 = engine.run(
        telemetry,
        {
            let dim = dim.clone();
            move |t: &Telemetry| match dim.get(&t.vehicle) {
                Some(&fleet) => vec![((fleet as u32), t.sensor_bytes as u64)],
                None => vec![],
            }
        },
        |k: &u32, vs: Vec<u64>| vec![(*k, vs.into_iter().sum::<u64>())],
        reducers,
    )?;
    // Second job: final per-fleet rollup (numeric cast), rereads DFS.
    let stage2 = engine.run(
        &stage1,
        |&(k, b): &(u32, u64)| vec![(k, b)],
        |k: &u32, vs: Vec<u64>| vec![(*k, vs.into_iter().sum::<u64>() as f64)],
        reducers,
    )?;
    Ok(sorted(stage2.collect()))
}

// ---------------------------------------------------------------------------
// Q3: the "daily report" — a multi-stage query: clean → per-vehicle daily
// stats → per-zone rollup → top zones. On the DCE the cleaned input is
// cached once; the MR baseline pays a full job (disk in, disk out) per
// stage. This is the 1,000 s → 150 s query shape.
// ---------------------------------------------------------------------------

pub fn q3_dce(data: &Rdd<Telemetry>, parts: usize) -> Result<AggRows> {
    let clean = data.filter(|t| t.speed_kmh > 1.0).cache();
    // stage A: per-vehicle mean speed
    let per_vehicle = clean
        .map(|t| (t.vehicle, (t.speed_kmh as f64, 1u64)))
        .reduce_by_key(|a, b| (a.0 + b.0, a.1 + b.1), parts)
        .map(|(v, (s, n))| (v, s / n as f64));
    // stage B: per-zone traffic volume over the same cached input
    let per_zone = clean
        .map(|t| (t.zone as u32, t.sensor_bytes as f64))
        .reduce_by_key(|a, b| a + b, parts);
    // stage C: join-free rollup: zones weighted by fleet mean speeds
    let mean_speed: f64 = {
        let rows = per_vehicle.collect()?;
        if rows.is_empty() {
            0.0
        } else {
            rows.iter().map(|(_, s)| s).sum::<f64>() / rows.len() as f64
        }
    };
    let rows = per_zone.map(move |(z, b)| (z, b / 1e6 + mean_speed));
    Ok(sorted(rows.collect()?))
}

pub fn q3_mr(
    engine: &MapReduceEngine,
    input: &crate::mapreduce::MrFile<Telemetry>,
    reducers: usize,
) -> Result<AggRows> {
    // stage 0: clean (identity map-reduce materialising the filter)
    let clean = engine.run(
        input,
        |t: &Telemetry| {
            if t.speed_kmh > 1.0 {
                vec![(t.vehicle, t.clone())]
            } else {
                vec![]
            }
        },
        |_k: &u32, vs: Vec<Telemetry>| vs,
        reducers,
    )?;
    // stage A: per-vehicle mean speed
    let per_vehicle = engine.run(
        &clean,
        |t: &Telemetry| vec![(t.vehicle, (t.speed_kmh as f64, 1u64))],
        |k: &u32, vs: Vec<(f64, u64)>| {
            let (s, n) = vs.iter().fold((0.0, 0u64), |a, v| (a.0 + v.0, a.1 + v.1));
            vec![(*k, s / n as f64)]
        },
        reducers,
    )?;
    // stage B: per-zone volume (rereads the cleaned data from DFS)
    let per_zone = engine.run(
        &clean,
        |t: &Telemetry| vec![(t.zone as u32, t.sensor_bytes as f64)],
        |k: &u32, vs: Vec<f64>| vec![(*k, vs.into_iter().sum::<f64>())],
        reducers,
    )?;
    // stage C: rollup
    let rows_v = per_vehicle.collect();
    let mean_speed: f64 = if rows_v.is_empty() {
        0.0
    } else {
        rows_v.iter().map(|(_, s)| s).sum::<f64>() / rows_v.len() as f64
    };
    let rollup = engine.run(
        &per_zone,
        move |&(z, b): &(u32, f64)| vec![(z, b / 1e6 + mean_speed)],
        |k: &u32, vs: Vec<f64>| vec![(*k, vs.into_iter().sum::<f64>())],
        reducers,
    )?;
    Ok(sorted(rollup.collect()))
}

/// Convenience: load telemetry into a DCE RDD.
pub fn telemetry_rdd(ctx: &DceContext, data: Vec<Telemetry>, parts: usize) -> Rdd<Telemetry> {
    ctx.parallelize(data, parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TierConfig;
    use crate::metrics::MetricsRegistry;
    use crate::storage::DfsStore;

    fn setup() -> (DceContext, MapReduceEngine, Vec<Telemetry>, Vec<Vehicle>) {
        let ctx = DceContext::local().unwrap();
        let cfg = TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e9, latency_us: 0 };
        let dfs = DfsStore::new(cfg, false, MetricsRegistry::new()).unwrap();
        let engine = MapReduceEngine::new(4, dfs, MetricsRegistry::new());
        let data = generate_telemetry(2000, 20, 1);
        let reg = generate_vehicles(20, 1);
        (ctx, engine, data, reg)
    }

    #[test]
    fn q1_dce_equals_mr() {
        let (ctx, engine, data, _) = setup();
        let rdd = telemetry_rdd(&ctx, data.clone(), 4);
        let a = q1_dce(&rdd, 3).unwrap();
        let input = engine.write_file(data, 4).unwrap();
        let b = q1_mr(&engine, &input, 3).unwrap();
        assert_eq!(a.len(), b.len());
        for ((ka, va), (kb, vb)) in a.iter().zip(b.iter()) {
            assert_eq!(ka, kb);
            assert!((va - vb).abs() < 1e-9);
        }
    }

    #[test]
    fn q2_dce_equals_mr() {
        let (ctx, engine, data, reg) = setup();
        let t = telemetry_rdd(&ctx, data.clone(), 4);
        let r = ctx.parallelize(reg.clone(), 2);
        let a = q2_dce(&t, &r, 3).unwrap();
        let input = engine.write_file(data, 4).unwrap();
        let b = q2_mr(&engine, &input, &reg, 3).unwrap();
        assert_eq!(a.len(), b.len());
        for ((ka, va), (kb, vb)) in a.iter().zip(b.iter()) {
            assert_eq!(ka, kb);
            assert!((va - vb).abs() < 1.0, "{va} vs {vb}");
        }
    }

    #[test]
    fn q3_dce_equals_mr() {
        let (ctx, engine, data, _) = setup();
        let rdd = telemetry_rdd(&ctx, data.clone(), 4);
        let a = q3_dce(&rdd, 3).unwrap();
        let input = engine.write_file(data, 4).unwrap();
        let b = q3_mr(&engine, &input, 3).unwrap();
        assert_eq!(a.len(), b.len());
        for ((ka, va), (kb, vb)) in a.iter().zip(b.iter()) {
            assert_eq!(ka, kb);
            assert!((va - vb).abs() < 1e-6 * (1.0 + va.abs()), "{va} vs {vb}");
        }
    }

    #[test]
    fn generator_is_deterministic() {
        assert_eq!(generate_telemetry(100, 5, 9), generate_telemetry(100, 5, 9));
        assert_ne!(generate_telemetry(100, 5, 9), generate_telemetry(100, 5, 10));
    }

    #[test]
    fn mr_baseline_touches_dfs_more_than_dce() {
        let (ctx, engine, data, _) = setup();
        // DCE path: no DFS ops at all.
        let rdd = telemetry_rdd(&ctx, data.clone(), 4);
        let dfs_before = ctx.dfs().device().ops_total();
        q3_dce(&rdd, 3).unwrap();
        assert_eq!(ctx.dfs().device().ops_total(), dfs_before);
        // MR path: many DFS ops.
        let input = engine.write_file(data, 4).unwrap();
        let before = engine.dfs().device().ops_total();
        q3_mr(&engine, &input, 3).unwrap();
        assert!(engine.dfs().device().ops_total() > before + 20);
    }
}
