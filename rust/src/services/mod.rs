//! The cloud services running on the unified infrastructure (paper
//! sections 3-5): distributed simulation replay, offline model
//! training, HD map generation — plus the SQL workload used for the
//! engine comparison of section 2.1.

pub mod mapgen;
pub mod simulation;
pub mod sql;
pub mod training;
