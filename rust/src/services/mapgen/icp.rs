//! ICP point-cloud alignment (paper section 5.2's 30X hot spot).
//!
//! Each iteration's data pass (correspondence search + cross-covariance)
//! is the AOT Pallas kernel dispatched through the hetero layer on the
//! chosen device class; the 3x3 Kabsch solve closing the iteration runs
//! here. The same code path with `DeviceKind::Cpu` runs the naive scalar
//! implementation — that pairing is experiment E11.

use anyhow::{bail, Result};

use crate::hetero::Dispatcher;
use crate::pointcloud::{kabsch_rotation, m_apply, v_sub, Se3};
use crate::resource::DeviceKind;
use crate::runtime::Tensor;
use crate::util::Rng;

/// Fixed sizes the AOT artifacts were lowered for.
pub const ICP_SIZES: [usize; 2] = [1024, 4096];

/// Resample a packed cloud to exactly `n` points (stride subsample or
/// repeat-pad), as the fixed-shape artifact requires.
pub fn resample(cloud: &[f32], n: usize, seed: u64) -> Vec<f32> {
    let m = cloud.len() / 3;
    if m == 0 {
        return vec![0.0; n * 3];
    }
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n * 3);
    if m >= n {
        // Uniform stride with random phase.
        let stride = m as f64 / n as f64;
        let phase = rng.next_f64();
        for i in 0..n {
            let idx = (((i as f64 + phase) * stride) as usize).min(m - 1);
            out.extend_from_slice(&cloud[idx * 3..idx * 3 + 3]);
        }
    } else {
        for i in 0..n {
            let idx = i % m;
            out.extend_from_slice(&cloud[idx * 3..idx * 3 + 3]);
        }
    }
    out
}

/// Result of an alignment.
#[derive(Debug, Clone)]
pub struct IcpResult {
    pub transform: Se3,
    pub final_err: f32,
    pub iterations: usize,
}

/// Align `src` onto `dst` (both packed (N,3)) with up to `max_iters`
/// iterations on `device`. `size` must be one of [`ICP_SIZES`].
pub fn icp_align(
    dispatcher: &Dispatcher,
    device: DeviceKind,
    src: &[f32],
    dst: &[f32],
    size: usize,
    max_iters: usize,
) -> Result<IcpResult> {
    if !ICP_SIZES.contains(&size) {
        bail!("no ICP artifact for size {size} (have {ICP_SIZES:?})");
    }
    let kernel = format!("icp_step_{size}");
    let src_s = resample(src, size, 17);
    let dst_s = resample(dst, size, 23);
    let dst_t = Tensor::from_f32(dst_s, &[size, 3])?;
    let mut total = Se3::identity();
    let mut cur = src_s;
    let mut final_err = f32::INFINITY;
    let mut iterations = 0;
    for it in 0..max_iters {
        let src_t = Tensor::from_f32(cur.clone(), &[size, 3])?;
        let out = dispatcher.run_on(device, &kernel, &[src_t, dst_t.clone()])?;
        let h_flat = out[0].as_f32()?;
        let cs = out[1].as_f32()?;
        let cd = out[2].as_f32()?;
        let err = out[3].scalar_value()?;
        let h = [
            [h_flat[0], h_flat[1], h_flat[2]],
            [h_flat[3], h_flat[4], h_flat[5]],
            [h_flat[6], h_flat[7], h_flat[8]],
        ];
        let r = kabsch_rotation(&h);
        let t = v_sub([cd[0], cd[1], cd[2]], m_apply(&r, [cs[0], cs[1], cs[2]]));
        let step = Se3::new(r, t);
        cur = step.apply_cloud(&cur);
        total = step.compose(&total);
        iterations = it + 1;
        let improved = final_err - err;
        final_err = err;
        if err < 1e-4 || improved.abs() < 1e-6 {
            break;
        }
    }
    Ok(IcpResult { transform: total, final_err, iterations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{register_default_kernels, KernelRegistry};
    use crate::metrics::MetricsRegistry;
    use crate::pointcloud::rot_z;
    use crate::runtime::shared_runtime;

    fn have_artifacts() -> bool {
        let ok = crate::artifacts_dir().join("manifest.json").is_file();
        if !ok {
            eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
        }
        ok
    }

    fn dispatcher() -> Dispatcher {
        let reg = KernelRegistry::new();
        if have_artifacts() {
            register_default_kernels(&reg, &shared_runtime().unwrap());
        }
        Dispatcher::new(reg, MetricsRegistry::new())
    }

    fn structured_cloud(n: usize, seed: u64) -> Vec<f32> {
        // Ring + verticals: enough structure for unambiguous alignment.
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(n * 3);
        for i in 0..n {
            let theta = (i as f64 / n as f64) * std::f64::consts::TAU;
            let r = 8.0 + 2.0 * (4.0 * theta).sin();
            out.push((r * theta.cos()) as f32 + rng.normal_f32(0.0, 0.01));
            out.push((r * theta.sin()) as f32 + rng.normal_f32(0.0, 0.01));
            out.push(((i % 13) as f32) * 0.15);
        }
        out
    }

    #[test]
    fn resample_sizes() {
        let c = structured_cloud(100, 1);
        assert_eq!(resample(&c, 64, 0).len(), 64 * 3);
        assert_eq!(resample(&c, 256, 0).len(), 256 * 3);
        assert_eq!(resample(&[], 16, 0), vec![0.0; 48]);
    }

    #[test]
    fn icp_recovers_small_transform_cpu() {
        // CPU path works without artifacts.
        let d = dispatcher();
        if !have_artifacts() {
            return; // registry empty without the manifest
        }
        let src = structured_cloud(1024, 2);
        let true_tf = Se3::new(rot_z(0.06), [0.3, -0.2, 0.05]);
        let dst = true_tf.apply_cloud(&src);
        let result =
            icp_align(&d, DeviceKind::Cpu, &src, &dst, 1024, 12).unwrap();
        assert!(result.final_err < 0.05, "err {}", result.final_err);
        // Recovered transform maps src ≈ dst.
        let mapped = result.transform.apply(
            [src[0], src[1], src[2]],
        );
        let want = true_tf.apply([src[0], src[1], src[2]]);
        for k in 0..3 {
            assert!((mapped[k] - want[k]).abs() < 0.15, "{mapped:?} vs {want:?}");
        }
    }

    #[test]
    fn icp_gpu_matches_cpu() {
        if !have_artifacts() {
            return;
        }
        let d = dispatcher();
        let src = structured_cloud(1024, 3);
        let true_tf = Se3::new(rot_z(-0.04), [0.2, 0.1, 0.0]);
        let dst = true_tf.apply_cloud(&src);
        let gpu = icp_align(&d, DeviceKind::Gpu, &src, &dst, 1024, 10).unwrap();
        let cpu = icp_align(&d, DeviceKind::Cpu, &src, &dst, 1024, 10).unwrap();
        assert!((gpu.final_err - cpu.final_err).abs() < 1e-3);
        for i in 0..3 {
            assert!((gpu.transform.t[i] - cpu.transform.t[i]).abs() < 1e-2);
        }
    }

    #[test]
    fn icp_identity_converges_immediately() {
        if !have_artifacts() {
            return;
        }
        let d = dispatcher();
        let src = structured_cloud(1024, 4);
        let r = icp_align(&d, DeviceKind::Gpu, &src, &src, 1024, 8).unwrap();
        assert!(r.final_err < 1e-3);
        assert!(r.iterations <= 2);
    }

    #[test]
    fn icp_rejects_bad_size() {
        let d = dispatcher();
        assert!(icp_align(&d, DeviceKind::Cpu, &[], &[], 999, 1).is_err());
    }
}
