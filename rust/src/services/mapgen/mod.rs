//! HD map generation service (paper section 5): synthetic world/drive
//! generation, SLAM pose recovery, accelerated ICP alignment, the 5 cm
//! grid map, semantic layers, and the fused-vs-staged pipeline.

pub mod gridmap;
pub mod icp;
pub mod pipeline;
pub mod semantic;
pub mod slam;
pub mod trace;

pub use gridmap::{tile_histogram, Cell, GridMap};
pub use icp::{icp_align, resample, IcpResult};
pub use pipeline::{run_fused, run_staged, MapgenReport};
pub use semantic::{derive_lanes, extract_signs, HdMap, LaneSample, SignLabel};
pub use slam::{dead_reckon, propagate, slam_trajectory, SlamConfig, SlamResult};
pub use trace::{gen_drive, gen_world, gen_world_with_density, DriveLog, World};
