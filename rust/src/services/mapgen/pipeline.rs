//! The HD-map production pipeline (paper section 5.2, Figure 10):
//! raw log reading → SLAM (pose recovery) → point-cloud assembly with
//! ICP alignment → 2-D reflectance grid → semantic labelling.
//!
//! Two execution modes reproduce the paper's 5X claim: **fused** links
//! all stages in one job with intermediates in memory; **staged** runs
//! one job per stage with every intermediate materialised through the
//! DFS device ("we do not have to store the intermediate data in hard
//! disk" — the staged mode is exactly that counterfactual).

use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::gridmap::GridMap;
use super::semantic::{derive_lanes, extract_signs, HdMap};
use super::slam::{slam_trajectory, SlamConfig};
use super::trace::{DriveLog, LANE_HALF_WIDTH};
use crate::hetero::Dispatcher;
use crate::platform::job::{run_stage, JobHandle, JobSpec};
use crate::platform::opts::JobOpts;
use crate::resource::{ResourceManager, ResourceVec};
use crate::storage::DfsStore;

/// Pipeline outcome + quality metrics.
pub struct MapgenReport {
    pub mode: &'static str,
    pub elapsed: Duration,
    pub slam_err_m: f32,
    pub occupied_cells: usize,
    pub signs: usize,
    pub lanes: usize,
    pub map: HdMap,
}

fn assemble_cloud(poses: &[crate::pointcloud::Se3], log: &DriveLog) -> Vec<f32> {
    let mut cloud = Vec::new();
    for (pose, scan) in poses.iter().zip(log.scans.iter()) {
        cloud.extend(pose.apply_cloud(scan));
    }
    cloud
}

/// Fused pipeline: ONE job on the unified job layer, all five stages
/// in a single granted container, intermediates in memory. The
/// assembled cloud (≈ scan bytes) is charged against the container's
/// memory limit. The stage chain is deterministic and runs through the
/// per-container runner, so it is preemptible as a unit: a flagged
/// container is yielded and the requeued replacement reruns the chain.
pub fn run_fused(
    dispatcher: &Dispatcher,
    rm: &Arc<ResourceManager>,
    log: &DriveLog,
    config: &SlamConfig,
    opts: &JobOpts,
    grid_res_m: f32,
) -> Result<MapgenReport> {
    let start = Instant::now();
    let scan_bytes: u64 = log.scans.iter().map(|s| (s.len() * 4) as u64).sum();
    let job = JobHandle::submit(
        rm,
        opts.spec()
            .containers(1, 1)
            .resources(ResourceVec::cores(1, (4 * scan_bytes).max(32 << 20))),
    )?;
    let reports = job.run_per_container(|sctx| {
        sctx.check_preempted()?;
        sctx.run(|cctx| {
            cctx.alloc_mem(scan_bytes)?;
            let result = (|| -> Result<MapgenReport> {
                // Stage 1+2: SLAM pose recovery (ICP-refined).
                let slam = slam_trajectory(dispatcher, log, config)?;
                // Stage 3: point-cloud assembly.
                let cloud = assemble_cloud(&slam.poses, log);
                // Stage 4: grid map.
                let mut grid = GridMap::covering(&cloud, grid_res_m);
                grid.add_points(&cloud);
                // Stage 5: semantics.
                let lanes = derive_lanes(&slam.poses, LANE_HALF_WIDTH);
                let signs = extract_signs(&cloud);
                let map = HdMap { grid, lanes, signs };
                Ok(MapgenReport {
                    mode: "fused",
                    elapsed: start.elapsed(),
                    slam_err_m: slam.mean_err_m,
                    occupied_cells: map.grid.occupied_cells(),
                    signs: map.signs.len(),
                    lanes: map.lanes.len(),
                    map,
                })
            })();
            cctx.free_mem(scan_bytes);
            result
        })?
    });
    let _ = job.finish();
    let mut reports = reports?;
    anyhow::ensure!(!reports.is_empty(), "mapgen job produced no report");
    Ok(reports.remove(0))
}

/// Staged pipeline: identical stages, but each one is its own
/// application-master submission (one job per stage, the
/// pre-unification shape) and every boundary round-trips the DFS
/// device.
pub fn run_staged(
    dispatcher: &Dispatcher,
    rm: &Arc<ResourceManager>,
    dfs: &Arc<DfsStore>,
    log: &DriveLog,
    config: &SlamConfig,
    opts: &JobOpts,
    grid_res_m: f32,
) -> Result<MapgenReport> {
    let start = Instant::now();
    let scan_bytes: u64 = log.scans.iter().map(|s| (s.len() * 4) as u64).sum();
    let mem = (4 * scan_bytes).max(32 << 20);
    let spec = |stage: &str| {
        JobSpec::new(format!("{}-{stage}", opts.app))
            .queue(opts.queue.as_str())
            .grant_timeout(opts.grant_timeout)
            .resources(ResourceVec::cores(1, mem))
    };
    // Stage 1+2: SLAM job — raw logs from DFS in, poses written out.
    let slam = run_stage(rm, spec("slam"), |_cctx| {
        dfs.write("mapgen/raw-log", &vec![0u8; (scan_bytes / 64).max(1) as usize])?;
        dfs.device().charge(scan_bytes);
        let slam = slam_trajectory(dispatcher, log, config)?;
        let pose_bytes = (slam.poses.len() * 48) as u64;
        dfs.device().charge(pose_bytes);
        dfs.write("mapgen/poses", &vec![0u8; pose_bytes as usize])?;
        Ok(slam)
    })?;
    let pose_bytes = (slam.poses.len() * 48) as u64;
    // Stage 3: assembly job rereads logs + poses, writes the cloud.
    let cloud = run_stage(rm, spec("assemble"), |_cctx| {
        dfs.device().charge(scan_bytes + pose_bytes);
        let cloud = assemble_cloud(&slam.poses, log);
        dfs.device().charge((cloud.len() * 4) as u64);
        dfs.write("mapgen/cloud-manifest", b"cloud")?;
        Ok(cloud)
    })?;
    let cloud_bytes = (cloud.len() * 4) as u64;
    // Stage 4: grid job rereads the cloud, writes the grid.
    let grid = run_stage(rm, spec("grid"), |_cctx| {
        dfs.device().charge(cloud_bytes);
        let mut grid = GridMap::covering(&cloud, grid_res_m);
        grid.add_points(&cloud);
        dfs.device().charge(grid.size_bytes() as u64);
        dfs.write("mapgen/grid-manifest", b"grid")?;
        Ok(grid)
    })?;
    // Stage 5: labelling job rereads grid + cloud + poses.
    run_stage(rm, spec("label"), |_cctx| {
        dfs.device().charge(cloud_bytes + grid.size_bytes() as u64 + pose_bytes);
        let lanes = derive_lanes(&slam.poses, LANE_HALF_WIDTH);
        let signs = extract_signs(&cloud);
        let map = HdMap { grid, lanes, signs };
        Ok(MapgenReport {
            mode: "staged",
            elapsed: start.elapsed(),
            slam_err_m: slam.mean_err_m,
            occupied_cells: map.grid.occupied_cells(),
            signs: map.signs.len(),
            lanes: map.lanes.len(),
            map,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, TierConfig};
    use crate::hetero::{register_default_kernels, KernelRegistry};
    use crate::metrics::MetricsRegistry;
    use crate::resource::DeviceKind;
    use crate::runtime::shared_runtime;
    use crate::services::mapgen::trace::{gen_drive, gen_world};

    fn test_rm() -> Arc<ResourceManager> {
        ResourceManager::new(&PlatformConfig::test().cluster, MetricsRegistry::new())
    }

    fn have_artifacts() -> bool {
        let ok = crate::artifacts_dir().join("manifest.json").is_file();
        if !ok {
            eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
        }
        ok
    }

    #[test]
    fn fused_pipeline_produces_usable_map() {
        if !have_artifacts() {
            return;
        }
        let reg = KernelRegistry::new();
        register_default_kernels(&reg, &shared_runtime().unwrap());
        let d = Dispatcher::new(reg, MetricsRegistry::new());
        let world = gen_world(20);
        let log = gen_drive(&world, 100, 20);
        let cfg = SlamConfig { device: DeviceKind::Gpu, ..Default::default() };
        let rm = test_rm();
        let report = run_fused(&d, &rm, &log, &cfg, &JobOpts::new("mapgen-fused"), 0.1).unwrap();
        assert_eq!(rm.live_containers(), 0, "mapgen grant must be returned");
        // GPS sigma is 0.4 m with outage sectors; ~1-1.5 m mean error is
        // the expected envelope (dead reckoning alone drifts to 10+ m).
        assert!(report.slam_err_m < 2.0, "slam err {}", report.slam_err_m);
        assert!(report.occupied_cells > 1000, "{} cells", report.occupied_cells);
        assert!(report.signs >= 1, "no signs labelled");
        assert_eq!(report.lanes, 100);
        // The produced map localises the vehicle.
        let p = log.poses_gt[50];
        let (refined, score) = report.map.localize(&log.scans[50], &p);
        assert!(score > 0.15, "match score {score}");
        let _ = refined;
    }

    #[test]
    fn staged_hits_dfs_fused_does_not() {
        if !have_artifacts() {
            return;
        }
        let reg = KernelRegistry::new();
        register_default_kernels(&reg, &shared_runtime().unwrap());
        let d = Dispatcher::new(reg, MetricsRegistry::new());
        let world = gen_world(21);
        let log = gen_drive(&world, 60, 21);
        let cfg = SlamConfig { device: DeviceKind::Gpu, icp_every: 20, ..Default::default() };
        let tier = TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e9, latency_us: 0 };
        let dfs = DfsStore::new(tier, false, MetricsRegistry::new()).unwrap();
        let rm = test_rm();
        let fused = run_fused(&d, &rm, &log, &cfg, &JobOpts::new("mapgen-fused"), 0.1).unwrap();
        let before = dfs.device().bytes_total();
        let staged =
            run_staged(&d, &rm, &dfs, &log, &cfg, &JobOpts::new("mapgen-staged"), 0.1).unwrap();
        assert!(
            dfs.device().bytes_total() > before + 1_000_000,
            "staged must move MBs through DFS"
        );
        // Same outputs either way.
        assert_eq!(fused.occupied_cells, staged.occupied_cells);
        assert_eq!(fused.signs, staged.signs);
        assert!((fused.slam_err_m - staged.slam_err_m).abs() < 1e-5);
    }
}
