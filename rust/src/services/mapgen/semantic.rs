//! Semantic layers of the HD map (paper section 5.1, Figure 11's upper
//! layers): reference line + lane boundaries derived from the refined
//! trajectory, and traffic-sign labels extracted from tall, thin
//! landmark clusters near the road.

use crate::pointcloud::{KdTree, Se3};

/// One lane-boundary polyline point pair (left, right).
#[derive(Debug, Clone, Copy)]
pub struct LaneSample {
    pub reference: [f32; 2],
    pub left: [f32; 2],
    pub right: [f32; 2],
}

/// A labelled traffic sign.
#[derive(Debug, Clone)]
pub struct SignLabel {
    pub pos: [f32; 3],
    pub kind: &'static str,
}

/// The layered HD map: grid + semantics.
pub struct HdMap {
    pub grid: super::gridmap::GridMap,
    pub lanes: Vec<LaneSample>,
    pub signs: Vec<SignLabel>,
}

/// Derive lane geometry from the refined trajectory: the reference line
/// is the driven path; boundaries are lateral offsets along the heading
/// normal.
pub fn derive_lanes(poses: &[Se3], half_width_m: f32) -> Vec<LaneSample> {
    poses
        .iter()
        .map(|p| {
            // Heading = rotated +x; normal = rotated +y.
            let n = crate::pointcloud::m_apply(&p.r, [0.0, 1.0, 0.0]);
            LaneSample {
                reference: [p.t[0], p.t[1]],
                left: [p.t[0] + half_width_m * n[0], p.t[1] + half_width_m * n[1]],
                right: [p.t[0] - half_width_m * n[0], p.t[1] - half_width_m * n[1]],
            }
        })
        .collect()
}

/// Extract sign poles from the accumulated world cloud: 1 m columns of
/// points that are tall (z span > 2.2 m, above wall clutter) and thin
/// (lateral standard deviation < 0.3 m). Single pass: per-column
/// moments, then a variance-based thinness test — O(points + columns).
pub fn extract_signs(world_points: &[f32]) -> Vec<SignLabel> {
    use std::collections::HashMap;
    #[derive(Default)]
    struct Col {
        n: u64,
        sx: f64,
        sy: f64,
        sxx: f64,
        syy: f64,
        zmin: f32,
        zmax: f32,
    }
    let mut cols: HashMap<(i32, i32), Col> = HashMap::new();
    for p in world_points.chunks_exact(3) {
        let key = (p[0].floor() as i32, p[1].floor() as i32);
        let e = cols.entry(key).or_insert_with(|| Col {
            zmin: f32::MAX,
            zmax: f32::MIN,
            ..Default::default()
        });
        e.n += 1;
        e.sx += p[0] as f64;
        e.sy += p[1] as f64;
        e.sxx += (p[0] as f64) * (p[0] as f64);
        e.syy += (p[1] as f64) * (p[1] as f64);
        e.zmin = e.zmin.min(p[2]);
        e.zmax = e.zmax.max(p[2]);
    }
    let mut signs = Vec::new();
    for c in cols.values() {
        if c.n >= 8 && c.zmax - c.zmin > 2.2 {
            let n = c.n as f64;
            let var = (c.sxx / n - (c.sx / n).powi(2)) + (c.syy / n - (c.sy / n).powi(2));
            if var.max(0.0).sqrt() < 0.3 {
                signs.push(SignLabel {
                    pos: [(c.sx / n) as f32, (c.sy / n) as f32, c.zmax],
                    kind: "speed_limit",
                });
            }
        }
    }
    signs.sort_by(|a, b| a.pos[0].partial_cmp(&b.pos[0]).unwrap());
    signs
}

impl HdMap {
    /// Is a world position within the mapped lane?
    pub fn on_lane(&self, x: f32, y: f32) -> bool {
        // Nearest reference sample, then lateral distance test.
        let mut best = f32::MAX;
        for s in &self.lanes {
            let d = (s.reference[0] - x).powi(2) + (s.reference[1] - y).powi(2);
            if d < best {
                best = d;
            }
        }
        best.sqrt() <= super::trace::LANE_HALF_WIDTH
    }

    /// Nearest sign to a position (for speed-limit lookahead).
    pub fn nearest_sign(&self, x: f32, y: f32) -> Option<(&SignLabel, f32)> {
        self.signs
            .iter()
            .map(|s| {
                let d = ((s.pos[0] - x).powi(2) + (s.pos[1] - y).powi(2)).sqrt();
                (s, d)
            })
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// Localise a scan: best match score over yaw/x/y perturbations of
    /// the initial estimate (the paper's "compare in real time the new
    /// LiDAR scans against the grid map with initial position estimates
    /// provided by GPS and/or IMU").
    pub fn localize(&self, scan_local: &[f32], initial: &Se3) -> (Se3, f32) {
        let mut best = (*initial, f32::MIN);
        for dyaw in [-0.02f32, 0.0, 0.02] {
            for dx in [-0.2f32, 0.0, 0.2] {
                for dy in [-0.2f32, 0.0, 0.2] {
                    let cand = Se3::new(
                        crate::pointcloud::m_mul(&crate::pointcloud::rot_z(dyaw), &initial.r),
                        [initial.t[0] + dx, initial.t[1] + dy, initial.t[2]],
                    );
                    let world = cand.apply_cloud(scan_local);
                    let score = self.grid.match_score(&world);
                    if score > best.1 {
                        best = (cand, score);
                    }
                }
            }
        }
        best
    }
}

/// Spatial index over sign positions (used by planning-style queries).
pub fn sign_index(signs: &[SignLabel]) -> KdTree {
    let pts: Vec<f32> = signs.iter().flat_map(|s| s.pos.to_vec()).collect();
    KdTree::build(&pts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pointcloud::rot_z;
    use crate::services::mapgen::trace::{gen_drive, gen_world};

    #[test]
    fn lanes_offset_laterally() {
        let poses = vec![Se3::identity(), Se3::new(rot_z(0.0), [1.0, 0.0, 0.0])];
        let lanes = derive_lanes(&poses, 1.75);
        assert_eq!(lanes.len(), 2);
        // Heading +x => normal +y: left is +y, right is -y.
        assert!((lanes[0].left[1] - 1.75).abs() < 1e-6);
        assert!((lanes[0].right[1] + 1.75).abs() < 1e-6);
    }

    #[test]
    fn signs_found_in_synthetic_world() {
        let w = gen_world(9);
        let signs = extract_signs(&w.landmarks);
        assert!(!signs.is_empty(), "no signs found");
        assert!(signs.len() <= 10, "too many: {}", signs.len());
        // Every extracted sign is near a true pole.
        for s in &signs {
            let near = w
                .poles
                .iter()
                .any(|p| ((p[0] - s.pos[0]).powi(2) + (p[1] - s.pos[1]).powi(2)).sqrt() < 1.5);
            assert!(near, "phantom sign at {:?}", s.pos);
        }
    }

    #[test]
    fn hdmap_queries_work() {
        let world = gen_world(10);
        let log = gen_drive(&world, 60, 10);
        // Build a map from ground truth directly (pipeline tested elsewhere).
        let mut cloud = Vec::new();
        for (pose, scan) in log.poses_gt.iter().zip(log.scans.iter()) {
            cloud.extend(pose.apply_cloud(scan));
        }
        let mut grid = super::super::gridmap::GridMap::covering(&cloud, 0.1);
        grid.add_points(&cloud);
        let map = HdMap {
            grid,
            lanes: derive_lanes(&log.poses_gt, 1.75),
            signs: extract_signs(&cloud),
        };
        // On-lane at a trajectory point, off-lane at the world origin.
        let p = log.poses_gt[10].t;
        assert!(map.on_lane(p[0], p[1]));
        assert!(!map.on_lane(0.0, 0.0));
        // Localisation sharpens a perturbed initial pose.
        let truth = log.poses_gt[20];
        let perturbed = Se3::new(truth.r, [truth.t[0] + 0.2, truth.t[1] - 0.2, truth.t[2]]);
        let (refined, score) = map.localize(&log.scans[20], &perturbed);
        assert!(score > 0.2, "score {score}");
        let err_before = crate::pointcloud::v_norm(crate::pointcloud::v_sub(perturbed.t, truth.t));
        let err_after = crate::pointcloud::v_norm(crate::pointcloud::v_sub(refined.t, truth.t));
        assert!(err_after <= err_before + 1e-4, "{err_after} > {err_before}");
    }

    #[test]
    fn sign_index_nearest() {
        let signs = vec![
            SignLabel { pos: [0.0, 0.0, 2.5], kind: "speed_limit" },
            SignLabel { pos: [10.0, 0.0, 2.5], kind: "speed_limit" },
        ];
        let idx = sign_index(&signs);
        let (i, _) = idx.nearest([9.0, 0.5, 2.0]).unwrap();
        assert_eq!(i, 1);
    }
}
