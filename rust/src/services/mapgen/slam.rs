//! SLAM stage of HD map generation (paper section 5.2, Figure 12).
//!
//! "First, the wheel odometry data and the IMU data can be used to
//! perform propagation ... Then the GPS data and the LiDAR data can be
//! used to correct the propagation results in order to minimize errors."
//!
//! Propagation: integrate odometry deltas in SE(2)-on-SE(3). GPS
//! correction: covariance-weighted blend of the predicted position
//! toward the fix. LiDAR correction: scan-to-map ICP through the
//! accelerated kernel (see [`super::icp`]).

use anyhow::Result;

use super::icp::{icp_align, IcpResult};
use super::trace::DriveLog;
use crate::hetero::Dispatcher;
use crate::pointcloud::{rot_z, Se3};
use crate::resource::DeviceKind;
use crate::services::simulation::sensors::{GpsFix, OdomDelta};

/// Integrate one odometry delta: rotate, then move along heading.
pub fn propagate(pose: &Se3, odom: &OdomDelta) -> Se3 {
    let r_new = crate::pointcloud::m_mul(&rot_z(odom.d_theta_rad), &pose.r);
    let fwd = crate::pointcloud::m_apply(&r_new, [odom.d_forward_m, 0.0, 0.0]);
    Se3::new(r_new, crate::pointcloud::v_add(pose.t, fwd))
}

/// Blend position toward a GPS fix with gain proportional to trust.
pub fn correct_gps(pose: &Se3, fix: &GpsFix, process_sigma_m: f32) -> Se3 {
    // Scalar Kalman-style gain on x/y.
    let k = process_sigma_m * process_sigma_m
        / (process_sigma_m * process_sigma_m + fix.sigma_m * fix.sigma_m);
    let mut t = pose.t;
    t[0] += k * (fix.x_m - t[0]);
    t[1] += k * (fix.y_m - t[1]);
    Se3::new(pose.r, t)
}

/// Pure dead reckoning over the whole log.
pub fn dead_reckon(start: Se3, odoms: &[OdomDelta]) -> Vec<Se3> {
    let mut out = Vec::with_capacity(odoms.len());
    let mut pose = start;
    for o in odoms {
        out.push(pose);
        pose = propagate(&pose, o);
    }
    out
}

/// SLAM configuration.
#[derive(Debug, Clone)]
pub struct SlamConfig {
    /// Growth of position uncertainty per step (drives the GPS gain).
    pub process_sigma_m: f32,
    /// Run scan-to-map ICP every `icp_every` steps (0 = never).
    pub icp_every: usize,
    /// Which device class runs the ICP kernel.
    pub device: DeviceKind,
    pub icp_size: usize,
    pub icp_iters: usize,
}

impl Default for SlamConfig {
    fn default() -> Self {
        Self {
            process_sigma_m: 0.3,
            icp_every: 10,
            device: DeviceKind::Gpu,
            icp_size: 1024,
            icp_iters: 5,
        }
    }
}

/// Output trajectory + quality metrics.
#[derive(Debug, Clone)]
pub struct SlamResult {
    pub poses: Vec<Se3>,
    /// Mean translation error vs ground truth (only computable on
    /// synthetic logs).
    pub mean_err_m: f32,
    pub icp_runs: usize,
}

/// Full SLAM pass: propagate → GPS-correct → periodic scan-to-keyframe
/// ICP refinement.
pub fn slam_trajectory(
    dispatcher: &Dispatcher,
    log: &DriveLog,
    config: &SlamConfig,
) -> Result<SlamResult> {
    let mut poses = Vec::with_capacity(log.odom.len());
    let mut pose = log.poses_gt.first().copied().unwrap_or_else(Se3::identity);
    let mut icp_runs = 0usize;
    let mut last_key: Option<(Se3, &Vec<f32>)> = None;
    for (i, odom) in log.odom.iter().enumerate() {
        if i > 0 {
            pose = propagate(&pose, odom);
        }
        if let Some(Some(fix)) = log.gps.get(i) {
            pose = correct_gps(&pose, fix, config.process_sigma_m);
        }
        // Scan-to-keyframe ICP: align this scan against the previous
        // keyframe scan placed in the world by its refined pose.
        if config.icp_every > 0 && i % config.icp_every == 0 {
            if let (Some((key_pose, key_scan)), Some(scan)) = (last_key.as_ref(), log.scans.get(i))
            {
                let world_key = key_pose.apply_cloud(key_scan);
                let world_cur = pose.apply_cloud(scan);
                let IcpResult { transform, .. } = icp_align(
                    dispatcher,
                    config.device,
                    &world_cur,
                    &world_key,
                    config.icp_size,
                    config.icp_iters,
                )?;
                // Gate: a sane scan-to-keyframe correction is small. Large
                // transforms mean ICP slid along the (near-symmetric) wall
                // geometry — discard those rather than inject them.
                let t_norm = crate::pointcloud::v_norm(transform.t);
                let yaw = transform.r[1][0].atan2(transform.r[0][0]).abs();
                if t_norm < 1.0 && yaw < 0.05 {
                    // Damped application: trust ICP for half the correction
                    // (translation only; yaw is better constrained by odom).
                    let half = Se3::new(
                        crate::pointcloud::MAT3_ID,
                        crate::pointcloud::v_scale(transform.t, 0.5),
                    );
                    pose = half.compose(&pose);
                }
                icp_runs += 1;
            }
            if let Some(scan) = log.scans.get(i) {
                last_key = Some((pose, scan));
            }
        }
        poses.push(pose);
    }
    let mean_err_m = mean_err(&poses, &log.poses_gt);
    Ok(SlamResult { poses, mean_err_m, icp_runs })
}

/// Mean translation error between two trajectories.
pub fn mean_err(got: &[Se3], want: &[Se3]) -> f32 {
    if got.is_empty() || want.is_empty() {
        return f32::NAN;
    }
    let n = got.len().min(want.len());
    let mut sum = 0f32;
    for i in 0..n {
        let d = crate::pointcloud::v_sub(got[i].t, want[i].t);
        sum += crate::pointcloud::v_norm(d);
    }
    sum / n as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{register_default_kernels, KernelRegistry};
    use crate::metrics::MetricsRegistry;
    use crate::runtime::shared_runtime;
    use crate::services::mapgen::trace::{gen_drive, gen_world};

    fn have_artifacts() -> bool {
        let ok = crate::artifacts_dir().join("manifest.json").is_file();
        if !ok {
            eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
        }
        ok
    }

    fn dispatcher() -> Dispatcher {
        let reg = KernelRegistry::new();
        if have_artifacts() {
            register_default_kernels(&reg, &shared_runtime().unwrap());
        }
        Dispatcher::new(reg, MetricsRegistry::new())
    }

    #[test]
    fn propagate_moves_forward() {
        let p = Se3::identity();
        let o = OdomDelta { ts_ns: 0, d_forward_m: 2.0, d_theta_rad: 0.0 };
        let q = propagate(&p, &o);
        assert!((q.t[0] - 2.0).abs() < 1e-6);
        assert!((q.t[1]).abs() < 1e-6);
    }

    #[test]
    fn gps_correction_pulls_toward_fix() {
        let p = Se3::new(crate::pointcloud::MAT3_ID, [10.0, 0.0, 0.0]);
        let fix = GpsFix { ts_ns: 0, x_m: 0.0, y_m: 0.0, sigma_m: 0.1 };
        let q = correct_gps(&p, &fix, 1.0);
        assert!(q.t[0] < 1.0, "barely corrected: {}", q.t[0]);
        // Low-trust fix barely moves the pose.
        let fix2 = GpsFix { ts_ns: 0, x_m: 0.0, y_m: 0.0, sigma_m: 100.0 };
        let q2 = correct_gps(&p, &fix2, 1.0);
        assert!(q2.t[0] > 9.9);
    }

    #[test]
    fn dead_reckoning_drifts_and_gps_fixes_it() {
        let world = gen_world(7);
        let log = gen_drive(&world, 150, 7);
        let dr = dead_reckon(log.poses_gt[0], &log.odom);
        let dr_err = mean_err(&dr, &log.poses_gt);
        assert!(dr_err > 0.3, "odometry should drift: {dr_err}");
        // GPS-corrected (no ICP) must beat dead reckoning.
        let d = dispatcher();
        let cfg = SlamConfig { icp_every: 0, ..Default::default() };
        let slam = slam_trajectory(&d, &log, &cfg).unwrap();
        assert!(
            slam.mean_err_m < dr_err * 0.7,
            "gps {} vs dr {dr_err}",
            slam.mean_err_m
        );
        assert_eq!(slam.icp_runs, 0);
    }

    #[test]
    fn icp_refinement_does_not_hurt() {
        if !have_artifacts() {
            return;
        }
        let world = gen_world(8);
        let log = gen_drive(&world, 120, 8);
        let d = dispatcher();
        let gps_only = slam_trajectory(
            &d,
            &log,
            &SlamConfig { icp_every: 0, ..Default::default() },
        )
        .unwrap();
        let with_icp = slam_trajectory(&d, &log, &SlamConfig::default()).unwrap();
        assert!(with_icp.icp_runs > 5);
        assert!(
            with_icp.mean_err_m < gps_only.mean_err_m * 1.25,
            "icp {} vs gps {}",
            with_icp.mean_err_m,
            gps_only.mean_err_m
        );
    }
}
