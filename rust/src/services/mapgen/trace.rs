//! Synthetic world + drive-log generator (the proprietary-fleet-data
//! substitution for HD map generation, paper section 5).
//!
//! A ring road through a world of wall segments and sign poles; the
//! vehicle drives the ring while logging noisy odometry, sparse noisy
//! GPS, and LiDAR scans of the nearby landmarks (expressed in the
//! vehicle frame) — the exact input mix of Figure 12 (wheel odometry,
//! IMU, GPS, LiDAR).

use crate::pointcloud::{rot_z, Se3};
use crate::services::simulation::sensors::{GpsFix, OdomDelta};
use crate::util::Rng;

/// Static world: packed (N,3) landmark points.
#[derive(Debug, Clone)]
pub struct World {
    pub landmarks: Vec<f32>,
    /// Sign-pole positions (subset of landmarks, one entry per pole).
    pub poles: Vec<[f32; 3]>,
}

pub const ROAD_RADIUS: f32 = 30.0;
pub const LANE_HALF_WIDTH: f32 = 1.75;

/// Build the ring-road world: wall points on two concentric circles plus
/// a handful of sign poles just off the outer edge.
pub fn gen_world(seed: u64) -> World {
    gen_world_with_density(seed, 1)
}

/// `density` multiplies the wall-point count: production LiDAR sweeps
/// carry 10-100x more returns than the functional tests need, and the
/// pipeline benches (E10) use that fidelity to reproduce the paper's
/// data-volume-dominated stage boundaries.
pub fn gen_world_with_density(seed: u64, density: usize) -> World {
    let mut rng = Rng::new(seed);
    let mut landmarks = Vec::new();
    let mut poles = Vec::new();
    // Walls: points along inner/outer circles with vertical spread.
    for ring in [ROAD_RADIUS - 6.0, ROAD_RADIUS + 6.0] {
        let n = 1400 * density.max(1);
        for i in 0..n {
            let theta = (i as f64 / n as f64) * std::f64::consts::TAU;
            let r = ring + rng.normal_f32(0.0, 0.08);
            let x = r * (theta.cos() as f32);
            let y = r * (theta.sin() as f32);
            let z = rng.next_f32() * 2.0;
            landmarks.extend_from_slice(&[x, y, z]);
        }
    }
    // Sign poles: tall thin clusters.
    for k in 0..8 {
        let theta = k as f64 * std::f64::consts::TAU / 8.0 + 0.2;
        let r = ROAD_RADIUS + 4.5;
        let base = [r * theta.cos() as f32, r * theta.sin() as f32, 0.0];
        poles.push([base[0], base[1], 2.5]);
        for j in 0..12 {
            landmarks.extend_from_slice(&[
                base[0] + rng.normal_f32(0.0, 0.02),
                base[1] + rng.normal_f32(0.0, 0.02),
                j as f32 * 0.25,
            ]);
        }
    }
    World { landmarks, poles }
}

/// Everything the vehicle logged during one drive.
#[derive(Debug, Clone)]
pub struct DriveLog {
    /// Ground-truth poses (held out for evaluation only).
    pub poses_gt: Vec<Se3>,
    pub odom: Vec<OdomDelta>,
    /// One entry per step; `None` during GPS outages.
    pub gps: Vec<Option<GpsFix>>,
    /// Vehicle-frame LiDAR scans, packed (N,3).
    pub scans: Vec<Vec<f32>>,
}

/// Drive `steps` steps around the ring, logging sensors.
pub fn gen_drive(world: &World, steps: usize, seed: u64) -> DriveLog {
    let mut rng = Rng::new(seed ^ 0xD21E);
    let speed = 2.0f32; // metres per step (arc length)
    let dtheta_gt = speed / ROAD_RADIUS;
    let mut poses_gt = Vec::with_capacity(steps);
    let mut odom = Vec::with_capacity(steps);
    let mut gps = Vec::with_capacity(steps);
    let mut scans = Vec::with_capacity(steps);
    // Exact parametric ground truth: angle k*dθ on the ring, heading
    // tangential. (Integrating chords would spiral outward.)
    let gt_pose = |k: usize| -> Se3 {
        let th = k as f32 * dtheta_gt;
        Se3::new(
            rot_z(th + std::f32::consts::FRAC_PI_2),
            [ROAD_RADIUS * th.cos(), ROAD_RADIUS * th.sin(), 0.0],
        )
    };
    // Chord length between consecutive ground-truth poses (what wheel
    // odometry actually measures).
    let chord = 2.0 * ROAD_RADIUS * (dtheta_gt / 2.0).sin();
    for step in 0..steps {
        let pose = gt_pose(step);
        poses_gt.push(pose);
        // Odometry: forward + yaw with noise and a small bias (drift!).
        odom.push(OdomDelta {
            ts_ns: step as u64,
            d_forward_m: chord * (1.0 + rng.normal_f32(0.0, 0.01)) + 0.005,
            d_theta_rad: dtheta_gt * (1.0 + rng.normal_f32(0.0, 0.02)) + 0.0004,
        });
        // GPS: every 5th step, unless in the outage sector.
        let in_outage = (step / 25) % 4 == 3;
        gps.push(if step % 5 == 0 && !in_outage {
            Some(GpsFix {
                ts_ns: step as u64,
                x_m: pose.t[0] + rng.normal_f32(0.0, 0.4),
                y_m: pose.t[1] + rng.normal_f32(0.0, 0.4),
                sigma_m: 0.4,
            })
        } else {
            None
        });
        // LiDAR: world landmarks within range, in the vehicle frame.
        let inv = pose.inverse();
        let mut scan = Vec::new();
        for p in world.landmarks.chunks_exact(3) {
            let dx = p[0] - pose.t[0];
            let dy = p[1] - pose.t[1];
            if dx * dx + dy * dy < 20.0 * 20.0 {
                let local = inv.apply([p[0], p[1], p[2]]);
                scan.push(local[0] + rng.normal_f32(0.0, 0.02));
                scan.push(local[1] + rng.normal_f32(0.0, 0.02));
                scan.push(local[2] + rng.normal_f32(0.0, 0.02));
            }
        }
        scans.push(scan);
    }
    let _ = speed;
    DriveLog { poses_gt, odom, gps, scans }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_is_deterministic_and_sized() {
        let w1 = gen_world(3);
        let w2 = gen_world(3);
        assert_eq!(w1.landmarks, w2.landmarks);
        assert_eq!(w1.poles.len(), 8);
        assert!(w1.landmarks.len() / 3 > 2500);
    }

    #[test]
    fn drive_stays_on_ring() {
        let w = gen_world(4);
        let log = gen_drive(&w, 60, 4);
        assert_eq!(log.poses_gt.len(), 60);
        for pose in &log.poses_gt {
            let r = (pose.t[0] * pose.t[0] + pose.t[1] * pose.t[1]).sqrt();
            assert!((r - ROAD_RADIUS).abs() < 1.0, "r={r}");
        }
    }

    #[test]
    fn scans_are_nonempty_and_local() {
        let w = gen_world(5);
        let log = gen_drive(&w, 20, 5);
        for scan in &log.scans {
            assert!(scan.len() / 3 > 50, "sparse scan: {}", scan.len() / 3);
            // Local frame: everything within sensor range.
            for p in scan.chunks_exact(3) {
                let r = (p[0] * p[0] + p[1] * p[1]).sqrt();
                assert!(r < 21.0, "point at {r}");
            }
        }
    }

    #[test]
    fn gps_has_fixes_and_outages() {
        let w = gen_world(6);
        let log = gen_drive(&w, 200, 6);
        let fixes = log.gps.iter().flatten().count();
        assert!(fixes > 10, "{fixes} fixes");
        assert!(fixes < 40, "{fixes} — outages missing");
        // Fix accuracy plausible.
        for (i, g) in log.gps.iter().enumerate() {
            if let Some(fix) = g {
                let gt = log.poses_gt[i].t;
                let err = ((fix.x_m - gt[0]).powi(2) + (fix.y_m - gt[1]).powi(2)).sqrt();
                assert!(err < 2.5, "gps err {err}");
            }
        }
    }
}
