//! Synthetic sensor suite (the proprietary-road-data substitution).
//!
//! The paper's replay simulations consume "raw or filtered readings from
//! various sensors" from real road tests; we generate deterministic
//! synthetic equivalents that preserve the record structure and rates:
//! camera frames (64x64 grayscale with planted obstacle edges + noise),
//! LiDAR sweeps, IMU/odometry deltas and (sparse, noisy) GPS fixes.
//! Camera frames carry their ground-truth obstacle count so replayed
//! detection algorithms can be scored (the "qualification test").

use crate::util::Rng;

pub const FRAME_W: usize = 64;
pub const FRAME_H: usize = 64;

/// One camera frame with planted ground truth.
#[derive(Debug, Clone)]
pub struct CameraFrame {
    pub ts_ns: u64,
    /// Row-major grayscale in [0,1].
    pub pixels: Vec<f32>,
    /// Number of planted obstacles (ground truth for scoring).
    pub truth_obstacles: u32,
}

/// Serialise: ts | truth | pixels (LE f32). The binary record the
/// BinPipeRDD pipeline moves around.
impl CameraFrame {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.pixels.len() * 4);
        out.extend_from_slice(&self.ts_ns.to_le_bytes());
        out.extend_from_slice(&self.truth_obstacles.to_le_bytes());
        out.extend_from_slice(&crate::util::f32s_to_bytes(&self.pixels));
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        if bytes.len() < 12 {
            anyhow::bail!("camera frame record too short: {}", bytes.len());
        }
        let ts_ns = u64::from_le_bytes(bytes[..8].try_into().unwrap());
        let truth_obstacles = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        let pixels = crate::util::bytes_to_f32s(&bytes[12..]);
        if pixels.len() != FRAME_W * FRAME_H {
            anyhow::bail!("camera frame has {} pixels", pixels.len());
        }
        Ok(Self { ts_ns, pixels, truth_obstacles })
    }
}

/// Generate a frame: flat-ish road texture plus `truth` bright
/// rectangular "obstacles" with crisp edges, plus sensor noise.
pub fn gen_camera_frame(ts_ns: u64, rng: &mut Rng) -> CameraFrame {
    let truth = rng.below(4) as u32; // 0..=3 obstacles
    let mut pixels = vec![0f32; FRAME_W * FRAME_H];
    // Base road texture: slow horizontal ramp + mild noise.
    for y in 0..FRAME_H {
        for x in 0..FRAME_W {
            pixels[y * FRAME_W + x] =
                0.35 + 0.1 * (x as f32 / FRAME_W as f32) + rng.normal_f32(0.0, 0.015);
        }
    }
    // Planted obstacles: bright boxes, at least 8x8 so the 8x8 feature
    // cells see a strong gradient. One box per (shuffled) quadrant with a
    // 4px margin, so distinct obstacles never merge into one blob.
    let mut quadrants = [(0usize, 0usize), (32, 0), (0, 32), (32, 32)];
    rng.shuffle(&mut quadrants);
    for &(qx, qy) in quadrants.iter().take(truth as usize) {
        let w = 8 + rng.below(5) as usize; // 8..=12
        let h = 8 + rng.below(5) as usize;
        let x0 = qx + 4 + rng.below((32 - w - 8) as u64 + 1) as usize;
        let y0 = qy + 4 + rng.below((32 - h - 8) as u64 + 1) as usize;
        let level = 0.85 + rng.normal_f32(0.0, 0.05);
        for y in y0..y0 + h {
            for x in x0..x0 + w {
                pixels[y * FRAME_W + x] = level;
            }
        }
    }
    for p in pixels.iter_mut() {
        *p = p.clamp(0.0, 1.0);
    }
    CameraFrame { ts_ns, pixels, truth_obstacles: truth }
}

/// One LiDAR sweep: packed (N,3) points.
#[derive(Debug, Clone)]
pub struct LidarScan {
    pub ts_ns: u64,
    pub points: Vec<f32>,
}

pub fn gen_lidar_scan(ts_ns: u64, n_points: usize, rng: &mut Rng) -> LidarScan {
    // A ring of returns (walls) + ground plane clutter.
    let mut points = Vec::with_capacity(n_points * 3);
    for i in 0..n_points {
        let theta = (i as f64 / n_points as f64) * std::f64::consts::TAU;
        let r = 8.0 + 4.0 * (3.0 * theta).sin() + rng.normal() * 0.05;
        points.push((r * theta.cos()) as f32);
        points.push((r * theta.sin()) as f32);
        points.push(rng.normal_f32(0.2, 0.3).max(0.0));
    }
    LidarScan { ts_ns, points }
}

/// IMU/odometry delta between consecutive poses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OdomDelta {
    pub ts_ns: u64,
    pub d_forward_m: f32,
    pub d_theta_rad: f32,
}

/// GPS fix (sparse; `None` models outages).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpsFix {
    pub ts_ns: u64,
    pub x_m: f32,
    pub y_m: f32,
    pub sigma_m: f32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn camera_frame_roundtrips() {
        let mut rng = Rng::new(1);
        let f = gen_camera_frame(12345, &mut rng);
        let back = CameraFrame::from_bytes(&f.to_bytes()).unwrap();
        assert_eq!(back.ts_ns, 12345);
        assert_eq!(back.truth_obstacles, f.truth_obstacles);
        assert_eq!(back.pixels, f.pixels);
    }

    #[test]
    fn frame_pixels_in_range() {
        let mut rng = Rng::new(2);
        for ts in 0..20 {
            let f = gen_camera_frame(ts, &mut rng);
            assert!(f.pixels.iter().all(|p| (0.0..=1.0).contains(p)));
            assert!(f.truth_obstacles <= 3);
        }
    }

    #[test]
    fn obstacles_create_contrast() {
        let mut rng = Rng::new(3);
        // Find a frame with obstacles; its max-min contrast must be big.
        loop {
            let f = gen_camera_frame(0, &mut rng);
            if f.truth_obstacles > 0 {
                let max = f.pixels.iter().cloned().fold(0f32, f32::max);
                let min = f.pixels.iter().cloned().fold(1f32, f32::min);
                assert!(max - min > 0.3, "contrast {}", max - min);
                break;
            }
        }
    }

    #[test]
    fn lidar_scan_shape_and_determinism() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let s1 = gen_lidar_scan(0, 360, &mut a);
        let s2 = gen_lidar_scan(0, 360, &mut b);
        assert_eq!(s1.points, s2.points);
        assert_eq!(s1.points.len(), 360 * 3);
        // Points are within plausible range.
        for p in s1.points.chunks_exact(3) {
            let r = (p[0] * p[0] + p[1] * p[1]).sqrt();
            assert!(r > 2.0 && r < 15.0, "r={r}");
        }
    }

    #[test]
    fn corrupt_frame_rejected() {
        assert!(CameraFrame::from_bytes(&[1, 2, 3]).is_err());
        let mut rng = Rng::new(4);
        let mut bytes = gen_camera_frame(0, &mut rng).to_bytes();
        bytes.truncate(bytes.len() - 4);
        assert!(CameraFrame::from_bytes(&bytes).is_err());
    }
}
