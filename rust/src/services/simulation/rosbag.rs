//! Bag files: timestamped, topic-tagged binary message logs (the ROS
//! bag analog the replay service consumes).
//!
//! Format (little-endian):
//! `"ADBG" | u32 msg_count | { u32 topic_len | topic | u64 ts_ns |
//!  u32 payload_len | payload }*`
//!
//! Bags are real files; the replay service shards a directory of bag
//! chunks across the compute engine.

use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

pub const BAG_MAGIC: &[u8; 4] = b"ADBG";

/// One recorded message.
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub topic: String,
    pub ts_ns: u64,
    pub payload: Vec<u8>,
}

/// Serialise messages into one bag blob.
pub fn encode_bag(messages: &[Message]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(BAG_MAGIC);
    out.extend_from_slice(&(messages.len() as u32).to_le_bytes());
    for m in messages {
        out.extend_from_slice(&(m.topic.len() as u32).to_le_bytes());
        out.extend_from_slice(m.topic.as_bytes());
        out.extend_from_slice(&m.ts_ns.to_le_bytes());
        out.extend_from_slice(&(m.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&m.payload);
    }
    out
}

/// Parse a bag blob.
pub fn decode_bag(bytes: &[u8]) -> Result<Vec<Message>> {
    if bytes.len() < 8 || &bytes[..4] != BAG_MAGIC {
        bail!("not a bag: {} bytes", bytes.len());
    }
    let count = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    // A message needs at least 16 bytes (empty topic + empty payload);
    // reject impossible counts *before* allocating, so a truncated or
    // bit-flipped header is an error, not an OOM abort.
    if count > (bytes.len() - 8) / 16 {
        bail!("bag header claims {count} messages in {} bytes", bytes.len());
    }
    let mut off = 8usize;
    let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
        if *off + n > bytes.len() {
            bail!("bag truncated at byte {off}");
        }
        let s = &bytes[*off..*off + n];
        *off += n;
        Ok(s)
    };
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let tl = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let topic = String::from_utf8(take(&mut off, tl)?.to_vec()).context("bad topic utf8")?;
        let ts_ns = u64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap());
        let pl = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let payload = take(&mut off, pl)?.to_vec();
        out.push(Message { topic, ts_ns, payload });
    }
    if off != bytes.len() {
        bail!("bag has {} trailing bytes", bytes.len() - off);
    }
    Ok(out)
}

/// Incremental bag writer over a real file.
pub struct BagWriter {
    path: PathBuf,
    messages: Vec<Message>,
}

impl BagWriter {
    pub fn create(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), messages: Vec::new() }
    }

    pub fn write(&mut self, msg: Message) {
        self.messages.push(msg);
    }

    /// Flush all messages to disk.
    pub fn finish(self) -> Result<PathBuf> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(&self.path)
            .with_context(|| format!("creating bag {:?}", self.path))?;
        f.write_all(&encode_bag(&self.messages))?;
        Ok(self.path)
    }
}

/// Read a bag file.
pub fn read_bag(path: impl AsRef<Path>) -> Result<Vec<Message>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading bag {:?}", path.as_ref()))?;
    decode_bag(&bytes)
}

/// Filter a decoded bag by topic.
pub fn by_topic<'a>(messages: &'a [Message], topic: &str) -> Vec<&'a Message> {
    messages.iter().filter(|m| m.topic == topic).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Message> {
        vec![
            Message { topic: "/camera/front".into(), ts_ns: 1, payload: vec![1, 2, 3] },
            Message { topic: "/lidar/top".into(), ts_ns: 2, payload: vec![0u8; 1000] },
            Message { topic: "/camera/front".into(), ts_ns: 3, payload: vec![] },
        ]
    }

    #[test]
    fn encode_decode_roundtrip() {
        let msgs = sample();
        assert_eq!(decode_bag(&encode_bag(&msgs)).unwrap(), msgs);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("adbag-{}", std::process::id()));
        let mut w = BagWriter::create(dir.join("t.bag"));
        for m in sample() {
            w.write(m);
        }
        let path = w.finish().unwrap();
        let back = read_bag(&path).unwrap();
        assert_eq!(back, sample());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn topic_filter() {
        let msgs = sample();
        assert_eq!(by_topic(&msgs, "/camera/front").len(), 2);
        assert_eq!(by_topic(&msgs, "/lidar/top").len(), 1);
        assert_eq!(by_topic(&msgs, "/nope").len(), 0);
    }

    #[test]
    fn corruption_detected() {
        let msgs = sample();
        let mut bytes = encode_bag(&msgs);
        bytes[0] = b'X';
        assert!(decode_bag(&bytes).is_err());
        let mut bytes2 = encode_bag(&msgs);
        bytes2.truncate(bytes2.len() - 2);
        assert!(decode_bag(&bytes2).is_err());
        let mut bytes3 = encode_bag(&msgs);
        bytes3.push(7);
        assert!(decode_bag(&bytes3).is_err());
    }

    #[test]
    fn empty_bag_roundtrips() {
        // Zero messages is a valid bag, in memory and on disk.
        let bytes = encode_bag(&[]);
        assert_eq!(decode_bag(&bytes).unwrap(), Vec::<Message>::new());
        let dir = std::env::temp_dir().join(format!("adbag-empty-{}", std::process::id()));
        let path = BagWriter::create(dir.join("empty.bag")).finish().unwrap();
        assert_eq!(read_bag(&path).unwrap(), Vec::<Message>::new());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn truncated_file_is_an_error_not_a_panic() {
        let dir = std::env::temp_dir().join(format!("adbag-trunc-{}", std::process::id()));
        let mut w = BagWriter::create(dir.join("t.bag"));
        for m in sample() {
            w.write(m);
        }
        let path = w.finish().unwrap();
        let full = std::fs::read(&path).unwrap();
        // Every strict prefix of a non-empty bag must decode to an error.
        for cut in [0, 3, 7, 8, full.len() / 2, full.len() - 1] {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(read_bag(&path).is_err(), "prefix of {cut} bytes must fail");
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn absurd_message_count_rejected_without_allocation() {
        // Magic + count=u32::MAX and no message bytes: must error out
        // before reserving capacity for 4 billion messages.
        let mut bytes = BAG_MAGIC.to_vec();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_bag(&bytes).is_err());
        bytes.extend_from_slice(&[0u8; 64]);
        assert!(decode_bag(&bytes).is_err());
    }

    #[test]
    fn writer_read_write_roundtrip_large() {
        // A denser round trip: many messages with mixed payload sizes.
        let dir = std::env::temp_dir().join(format!("adbag-large-{}", std::process::id()));
        let mut w = BagWriter::create(dir.join("big.bag"));
        let msgs: Vec<Message> = (0..200)
            .map(|i| Message {
                topic: if i % 3 == 0 { "/camera/front".into() } else { "/lidar/top".into() },
                ts_ns: i as u64 * 100_000_000,
                payload: vec![(i % 256) as u8; (i * 7) % 513],
            })
            .collect();
        for m in &msgs {
            w.write(m.clone());
        }
        let path = w.finish().unwrap();
        assert_eq!(read_bag(&path).unwrap(), msgs);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn binary_payloads_any_value() {
        let msgs = vec![Message {
            topic: "t".into(),
            ts_ns: 0,
            payload: (0..=255u8).collect(),
        }];
        assert_eq!(decode_bag(&encode_bag(&msgs)).unwrap(), msgs);
    }
}
