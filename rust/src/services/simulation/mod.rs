//! Distributed simulation service (paper section 3): bag recording,
//! synthetic sensors, and the distributed replay of an algorithm under
//! test — in-process via the hetero dispatcher or over real Unix pipes
//! via BinPipeRDD.

pub mod replay;
pub mod rosbag;
pub mod sensors;

pub use replay::{
    count_obstacles_from_features, detect_batch, pipe_worker_detect, record_drive, replay,
    replay_piped, ReplayReport, CAMERA_TOPIC, LIDAR_TOPIC,
};
pub use rosbag::{by_topic, decode_bag, encode_bag, read_bag, BagWriter, Message};
pub use sensors::{gen_camera_frame, gen_lidar_scan, CameraFrame, GpsFix, LidarScan, OdomDelta};
