//! Distributed replay simulation (paper section 3, Figure 4).
//!
//! "Deploy the new algorithm on many compute nodes, feed each node with
//! different chunks of data, and, at the end, aggregate the test
//! results." Bag chunks become RDD partitions; the algorithm under test
//! (an obstacle detector over camera frames) runs per partition — either
//! in-process through the hetero dispatcher (feature kernel on the
//! GPU-class device) or in a separate "node" process over a real Linux
//! pipe (BinPipeRDD) — and the per-frame verdicts are aggregated into a
//! qualification report against the planted ground truth.

use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::rosbag::{read_bag, BagWriter, Message};
use super::sensors::{gen_camera_frame, gen_lidar_scan, CameraFrame, FRAME_H, FRAME_W};
use crate::dce::{BinaryRddExt, DceContext};
use crate::hetero::Dispatcher;
use crate::resource::DeviceKind;
use crate::runtime::Tensor;
use crate::util::Rng;

pub const CAMERA_TOPIC: &str = "/camera/front";
pub const LIDAR_TOPIC: &str = "/lidar/top";

/// Record a synthetic drive into `num_bags` bag files.
pub fn record_drive(
    dir: impl Into<PathBuf>,
    num_bags: usize,
    frames_per_bag: usize,
    seed: u64,
) -> Result<Vec<PathBuf>> {
    let dir = dir.into();
    let mut rng = Rng::new(seed);
    let mut paths = Vec::new();
    let mut ts = 0u64;
    for b in 0..num_bags {
        let mut w = BagWriter::create(dir.join(format!("chunk-{b:04}.bag")));
        for _ in 0..frames_per_bag {
            let frame = gen_camera_frame(ts, &mut rng);
            w.write(Message {
                topic: CAMERA_TOPIC.into(),
                ts_ns: ts,
                payload: frame.to_bytes(),
            });
            // Interleave a LiDAR sweep every 4 frames, as on a real bus.
            if ts % 4 == 0 {
                let scan = gen_lidar_scan(ts, 180, &mut rng);
                w.write(Message {
                    topic: LIDAR_TOPIC.into(),
                    ts_ns: ts,
                    payload: crate::util::f32s_to_bytes(&scan.points),
                });
            }
            ts += 100_000_000; // 10 Hz
        }
        paths.push(w.finish()?);
    }
    Ok(paths)
}

/// The algorithm under test: count obstacles in a frame from its 8x8-cell
/// gradient features (cells with a strong max-gradient are "active"; each
/// 4-connected active blob is one obstacle).
pub fn count_obstacles_from_features(features: &[f32], cells_h: usize, cells_w: usize) -> u32 {
    let active: Vec<bool> = (0..cells_h * cells_w)
        .map(|c| features[c * 4 + 3] > 0.15) // max gradient magnitude
        .collect();
    // BFS blob count.
    let mut seen = vec![false; active.len()];
    let mut blobs = 0u32;
    for start in 0..active.len() {
        if !active[start] || seen[start] {
            continue;
        }
        blobs += 1;
        let mut stack = vec![start];
        seen[start] = true;
        while let Some(c) = stack.pop() {
            let (cy, cx) = (c / cells_w, c % cells_w);
            let mut push = |y: isize, x: isize| {
                if y >= 0 && x >= 0 && (y as usize) < cells_h && (x as usize) < cells_w {
                    let n = y as usize * cells_w + x as usize;
                    if active[n] && !seen[n] {
                        seen[n] = true;
                        stack.push(n);
                    }
                }
            };
            push(cy as isize - 1, cx as isize);
            push(cy as isize + 1, cx as isize);
            push(cy as isize, cx as isize - 1);
            push(cy as isize, cx as isize + 1);
        }
    }
    blobs
}

/// Detect obstacles in a batch of frames via the hetero dispatcher
/// (feature kernel on the chosen device, batches of 8 padded as needed).
pub fn detect_batch(
    dispatcher: &Dispatcher,
    device: DeviceKind,
    frames: &[CameraFrame],
) -> Result<Vec<u32>> {
    let mut out = Vec::with_capacity(frames.len());
    for chunk in frames.chunks(8) {
        let b = chunk.len();
        let (name, batch) = if b == 8 { ("feature_b8", 8) } else { ("feature_b1", 1) };
        if batch == 8 {
            let mut pixels = Vec::with_capacity(8 * FRAME_W * FRAME_H);
            for f in chunk {
                pixels.extend_from_slice(&f.pixels);
            }
            let t = Tensor::from_f32(pixels, &[8, FRAME_H, FRAME_W])?;
            let feats = dispatcher.run_on(device, name, &[t])?;
            let data = feats[0].as_f32()?;
            let per = 8 * 8 * 4;
            for i in 0..8 {
                out.push(count_obstacles_from_features(&data[i * per..(i + 1) * per], 8, 8));
            }
        } else {
            for f in chunk {
                let t = Tensor::from_f32(f.pixels.clone(), &[1, FRAME_H, FRAME_W])?;
                let feats = dispatcher.run_on(device, name, &[t])?;
                out.push(count_obstacles_from_features(feats[0].as_f32()?, 8, 8));
            }
        }
    }
    Ok(out)
}

/// Outcome of a replay qualification run.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub frames: usize,
    pub exact_matches: usize,
    pub accuracy: f64,
    pub elapsed: Duration,
    pub device: DeviceKind,
}

/// Distributed replay: bag chunks → partitions → per-partition detection
/// through the dispatcher → aggregated accuracy.
pub fn replay(
    ctx: &DceContext,
    dispatcher: &Dispatcher,
    bags: &[PathBuf],
    device: DeviceKind,
) -> Result<ReplayReport> {
    let start = Instant::now();
    let dispatcher = dispatcher.clone();
    let rdd = ctx.parallelize(bags.to_vec(), bags.len().max(1));
    let counts = rdd
        .map_partitions(move |_, paths: Vec<PathBuf>| {
            let mut exact = 0usize;
            let mut total = 0usize;
            for path in paths {
                let msgs = read_bag(&path).with_context(|| format!("replaying {path:?}"))?;
                let frames: Vec<CameraFrame> = msgs
                    .iter()
                    .filter(|m| m.topic == CAMERA_TOPIC)
                    .map(|m| CameraFrame::from_bytes(&m.payload))
                    .collect::<Result<_>>()?;
                let detected = detect_batch(&dispatcher, device, &frames)?;
                total += frames.len();
                exact += frames
                    .iter()
                    .zip(detected)
                    .filter(|(f, d)| *d == f.truth_obstacles)
                    .count();
            }
            Ok(vec![(exact, total)])
        })
        .reduce(|a, b| (a.0 + b.0, a.1 + b.1))?
        .unwrap_or((0, 0));
    Ok(ReplayReport {
        frames: counts.1,
        exact_matches: counts.0,
        accuracy: if counts.1 == 0 { 0.0 } else { counts.0 as f64 / counts.1 as f64 },
        elapsed: start.elapsed(),
        device,
    })
}

/// Pipe-based replay: frames flow to an external worker process over a
/// real Unix pipe (BinPipeRDD), mirroring the paper's Spark↔ROS bridge.
/// The worker must speak the BinPipe framing and emit one 4-byte LE
/// count per input frame (see `pipe_worker_detect` / `adcloud pipe-worker`).
pub fn replay_piped(
    ctx: &DceContext,
    bags: &[PathBuf],
    worker_cmd: Vec<String>,
) -> Result<ReplayReport> {
    let start = Instant::now();
    let rdd = ctx.parallelize(bags.to_vec(), bags.len().max(1));
    // Partition of frame records (with truth stripped into a side list).
    let frames = rdd.map_partitions(|_, paths: Vec<PathBuf>| {
        let mut records = Vec::new();
        for path in paths {
            for m in read_bag(&path)? {
                if m.topic == CAMERA_TOPIC {
                    records.push(m.payload);
                }
            }
        }
        Ok(records)
    });
    let truths = frames.map(|rec| {
        CameraFrame::from_bytes(&rec).map(|f| f.truth_obstacles).unwrap_or(u32::MAX)
    });
    let detected = frames.pipe_through(worker_cmd).map(|rec: Vec<u8>| {
        if rec.len() == 4 {
            u32::from_le_bytes(rec.try_into().unwrap())
        } else {
            u32::MAX
        }
    });
    let t = truths.collect()?;
    let d = detected.collect()?;
    anyhow::ensure!(
        t.len() == d.len(),
        "worker returned {} records for {} frames",
        d.len(),
        t.len()
    );
    let exact = t.iter().zip(d.iter()).filter(|(a, b)| a == b).count();
    Ok(ReplayReport {
        frames: t.len(),
        exact_matches: exact,
        accuracy: if t.is_empty() { 0.0 } else { exact as f64 / t.len() as f64 },
        elapsed: start.elapsed(),
        device: DeviceKind::Cpu,
    })
}

/// The child-process side of the pipe bridge: decode frames from the
/// framed stdin stream, run CPU detection, write 4-byte counts back.
/// Wired to `adcloud pipe-worker detect`.
pub fn pipe_worker_detect() -> Result<()> {
    let records = crate::dce::binpipe::read_stream(&mut std::io::stdin().lock())?;
    let mut out = Vec::with_capacity(records.len());
    for rec in records {
        let frame = CameraFrame::from_bytes(&rec)?;
        let feats = crate::hetero::cpu_impls::feature_extract(&frame.pixels, 1, FRAME_H, FRAME_W);
        let n = count_obstacles_from_features(&feats, 8, 8);
        out.push(n.to_le_bytes().to_vec());
    }
    crate::dce::binpipe::write_stream(&mut std::io::stdout().lock(), &out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::{register_default_kernels, KernelRegistry};
    use crate::metrics::MetricsRegistry;
    use crate::runtime::shared_runtime;

    fn have_artifacts() -> bool {
        let ok = crate::artifacts_dir().join("manifest.json").is_file();
        if !ok {
            eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
        }
        ok
    }

    fn dispatcher() -> Dispatcher {
        let reg = KernelRegistry::new();
        if have_artifacts() {
            register_default_kernels(&reg, &shared_runtime().unwrap());
        }
        Dispatcher::new(reg, MetricsRegistry::new())
    }

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("adreplay-{tag}-{}", std::process::id()))
    }

    #[test]
    fn blob_counter_counts_separated_blobs() {
        // Two separated active cells on an 8x8 grid.
        let mut feats = vec![0f32; 8 * 8 * 4];
        feats[(0 * 8 + 0) * 4 + 3] = 1.0;
        feats[(5 * 8 + 5) * 4 + 3] = 1.0;
        feats[(5 * 8 + 6) * 4 + 3] = 1.0; // adjacent to previous: same blob
        assert_eq!(count_obstacles_from_features(&feats, 8, 8), 2);
        assert_eq!(count_obstacles_from_features(&vec![0f32; 8 * 8 * 4], 8, 8), 0);
    }

    #[test]
    fn record_drive_writes_bags() {
        let dir = temp_dir("rec");
        let bags = record_drive(&dir, 3, 5, 7).unwrap();
        assert_eq!(bags.len(), 3);
        let msgs = read_bag(&bags[0]).unwrap();
        let cams = msgs.iter().filter(|m| m.topic == CAMERA_TOPIC).count();
        let lidars = msgs.iter().filter(|m| m.topic == LIDAR_TOPIC).count();
        assert_eq!(cams, 5);
        assert!(lidars >= 1);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn cpu_detector_beats_chance_on_planted_truth() {
        // Pure-CPU path (works without artifacts).
        let mut rng = Rng::new(42);
        let mut exact = 0;
        let n = 40;
        for i in 0..n {
            let f = gen_camera_frame(i, &mut rng);
            let feats =
                crate::hetero::cpu_impls::feature_extract(&f.pixels, 1, FRAME_H, FRAME_W);
            if count_obstacles_from_features(&feats, 8, 8) == f.truth_obstacles {
                exact += 1;
            }
        }
        let acc = exact as f64 / n as f64;
        assert!(acc > 0.6, "detector accuracy {acc}");
    }

    #[test]
    fn distributed_replay_gpu_report() {
        if !have_artifacts() {
            return;
        }
        let dir = temp_dir("gpu");
        let bags = record_drive(&dir, 4, 8, 11).unwrap();
        let ctx = DceContext::local().unwrap();
        let d = dispatcher();
        let report = replay(&ctx, &d, &bags, DeviceKind::Gpu).unwrap();
        assert_eq!(report.frames, 32);
        assert!(report.accuracy > 0.6, "accuracy {}", report.accuracy);
        // GPU and CPU agree on verdicts.
        let report_cpu = replay(&ctx, &d, &bags, DeviceKind::Cpu).unwrap();
        assert_eq!(report.exact_matches, report_cpu.exact_matches);
        let _ = std::fs::remove_dir_all(dir);
    }
}
