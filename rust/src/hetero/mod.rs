//! Heterogeneous computing layer (paper section 2.3).
//!
//! Named kernels with per-device-class implementations: naive scalar
//! CPU (the baseline the paper's speedups are measured against),
//! GPU-class via AOT-compiled XLA artifacts on PJRT, and FPGA-class via
//! the same artifacts under a throughput/power model. The [`Dispatcher`]
//! is the RDD→JNI→OpenCL seam of Figure 3.

pub mod accel;
pub mod cpu_impls;
pub mod dispatch;
pub mod energy;
pub mod registry;
pub mod roofline;

pub use accel::{register_default_kernels, FpgaKernel, PjrtKernel};
pub use dispatch::Dispatcher;
pub use energy::EnergyMeter;
pub use registry::{FnKernel, KernelImpl, KernelRegistry};
pub use roofline::{KernelCost, RooflineDevice};
