//! Accelerator-backed kernel implementations.
//!
//! GPU-class: the AOT-compiled XLA artifact executed on a PJRT
//! device-server thread (the paper's JNI→OpenCL→GPU path becomes
//! Rust→PJRT→XLA). FPGA-class: the same artifact under a calibrated
//! performance model — a factor slower than the GPU-class device but an
//! order of magnitude lower power (see DESIGN.md's substitution ledger;
//! the FPGA experiments in the paper are about the energy axis).

use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

use super::cpu_impls;
use super::registry::{FnKernel, KernelImpl, KernelRegistry};
use crate::resource::DeviceKind;
use crate::runtime::{Tensor, XlaRuntime};
use crate::storage::device::precise_wait;

/// GPU-class kernel: executes an AOT artifact via PJRT.
pub struct PjrtKernel {
    pub runtime: XlaRuntime,
    pub artifact: String,
    /// Which device-server queue to submit to.
    pub device: Option<usize>,
}

impl KernelImpl for PjrtKernel {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match self.device {
            Some(d) => self.runtime.execute_on(d, &self.artifact, inputs.to_vec()),
            None => self.runtime.execute(&self.artifact, inputs.to_vec()),
        }
    }
}

/// FPGA-class kernel: same artifact, modelled slowdown vs the GPU class.
///
/// Calibration: the paper positions FPGA as slower-but-efficient for
/// vector workloads; we model `slowdown`x the measured GPU-class latency
/// (default 2.5x), at 1/10th the board power (see DeviceKind).
pub struct FpgaKernel {
    pub inner: PjrtKernel,
    pub slowdown: f64,
}

impl KernelImpl for FpgaKernel {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let start = Instant::now();
        let out = self.inner.run(inputs)?;
        let real = start.elapsed();
        let modelled = real.mul_f64(self.slowdown);
        precise_wait(modelled.saturating_sub(real));
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Naive-CPU adapters matching each artifact's tensor signature
// ---------------------------------------------------------------------------

fn params_from(inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
    inputs[..6]
        .iter()
        .map(|t| t.as_f32().map(|s| s.to_vec()))
        .collect()
}

fn cpu_infer(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    if inputs.len() != 7 {
        bail!("cnn_infer expects 6 params + x");
    }
    let params = params_from(inputs)?;
    let x = inputs[6].as_f32()?;
    let bsz = inputs[6].shape[0];
    let logits = cpu_impls::cnn_infer(&params, x, bsz)?;
    Ok(vec![Tensor::from_f32(logits, &[bsz, cpu_impls::NUM_CLASSES])?])
}

fn cpu_train(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    if inputs.len() != 8 {
        bail!("cnn_train expects 6 params + x + y");
    }
    let params = params_from(inputs)?;
    let x = inputs[6].as_f32()?;
    let y = inputs[7].as_i32()?;
    let bsz = inputs[6].shape[0];
    let (loss, grads) = cpu_impls::cnn_train_step(&params, x, y, bsz)?;
    let mut out = vec![Tensor::scalar_f32(loss)];
    for (g, (_, shape)) in grads.into_iter().zip(cpu_impls::PARAM_SHAPES.iter()) {
        out.push(Tensor::from_f32(g, shape)?);
    }
    Ok(out)
}

fn cpu_icp(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    if inputs.len() != 2 {
        bail!("icp_step expects src + dst");
    }
    let src = inputs[0].as_f32()?;
    let dst = inputs[1].as_f32()?;
    let (h, cs, cd, err) = cpu_impls::icp_step(src, dst);
    Ok(vec![
        Tensor::from_f32(h.to_vec(), &[3, 3])?,
        Tensor::from_f32(cs.to_vec(), &[3])?,
        Tensor::from_f32(cd.to_vec(), &[3])?,
        Tensor::scalar_f32(err),
    ])
}

fn cpu_feature(inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    if inputs.len() != 1 {
        bail!("feature expects one batch tensor");
    }
    let x = inputs[0].as_f32()?;
    let (b, h, w) = (inputs[0].shape[0], inputs[0].shape[1], inputs[0].shape[2]);
    let f = cpu_impls::feature_extract(x, b, h, w);
    Ok(vec![Tensor::from_f32(f, &[b, h / 8, w / 8, 4])?])
}

/// Register every artifact in the manifest with GPU (PJRT), FPGA
/// (modelled) and naive-CPU implementations.
pub fn register_default_kernels(reg: &KernelRegistry, runtime: &XlaRuntime) {
    let names: Vec<String> = runtime
        .manifest()
        .names()
        .into_iter()
        .map(str::to_string)
        .collect();
    for name in names {
        reg.register(
            &name,
            DeviceKind::Gpu,
            Arc::new(PjrtKernel { runtime: runtime.clone(), artifact: name.clone(), device: None }),
        );
        reg.register(
            &name,
            DeviceKind::Fpga,
            Arc::new(FpgaKernel {
                inner: PjrtKernel {
                    runtime: runtime.clone(),
                    artifact: name.clone(),
                    device: None,
                },
                slowdown: 2.5,
            }),
        );
        let cpu: Option<Arc<dyn KernelImpl>> = if name.starts_with("cnn_infer") {
            Some(Arc::new(FnKernel(cpu_infer)))
        } else if name.starts_with("cnn_train") {
            Some(Arc::new(FnKernel(cpu_train)))
        } else if name.starts_with("icp_step") {
            Some(Arc::new(FnKernel(cpu_icp)))
        } else if name.starts_with("feature") {
            Some(Arc::new(FnKernel(cpu_feature)))
        } else {
            None
        };
        if let Some(imp) = cpu {
            reg.register(&name, DeviceKind::Cpu, imp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::shared_runtime;

    fn have_artifacts() -> bool {
        let ok = crate::artifacts_dir().join("manifest.json").is_file();
        if !ok {
            eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
        }
        ok
    }

    fn registry() -> Option<KernelRegistry> {
        if !have_artifacts() {
            return None;
        }
        let rt = shared_runtime().unwrap();
        let reg = KernelRegistry::new();
        register_default_kernels(&reg, &rt);
        Some(reg)
    }

    /// Cross-layer validation: naive Rust CPU vs the XLA artifact.
    #[test]
    fn cpu_matches_gpu_on_icp() {
        let Some(reg) = registry() else { return };
        let mut rng = crate::util::Rng::new(7);
        let pts: Vec<f32> = (0..1024 * 3).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let qts: Vec<f32> = (0..1024 * 3).map(|_| rng.normal_f32(0.1, 2.0)).collect();
        let ins = vec![
            Tensor::from_f32(pts, &[1024, 3]).unwrap(),
            Tensor::from_f32(qts, &[1024, 3]).unwrap(),
        ];
        let gpu = reg.get("icp_step_1024", DeviceKind::Gpu).unwrap().run(&ins).unwrap();
        let cpu = reg.get("icp_step_1024", DeviceKind::Cpu).unwrap().run(&ins).unwrap();
        for (a, b) in gpu.iter().zip(cpu.iter()) {
            let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            for (x, y) in av.iter().zip(bv.iter()) {
                assert!((x - y).abs() < 1e-2 * (1.0 + x.abs()), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn cpu_matches_gpu_on_feature() {
        let Some(reg) = registry() else { return };
        let mut rng = crate::util::Rng::new(8);
        let img: Vec<f32> = (0..8 * 64 * 64).map(|_| rng.next_f32()).collect();
        let ins = vec![Tensor::from_f32(img, &[8, 64, 64]).unwrap()];
        let gpu = reg.get("feature_b8", DeviceKind::Gpu).unwrap().run(&ins).unwrap();
        let cpu = reg.get("feature_b8", DeviceKind::Cpu).unwrap().run(&ins).unwrap();
        let (g, c) = (gpu[0].as_f32().unwrap(), cpu[0].as_f32().unwrap());
        for (x, y) in g.iter().zip(c.iter()) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn cpu_matches_gpu_on_train_step() {
        let Some(reg) = registry() else { return };
        let mut rng = crate::util::Rng::new(9);
        let params = cpu_impls::init_params(&mut rng);
        let mut ins: Vec<Tensor> = params
            .iter()
            .zip(cpu_impls::PARAM_SHAPES.iter())
            .map(|(p, (_, s))| Tensor::from_f32(p.clone(), s).unwrap())
            .collect();
        let x: Vec<f32> = (0..16 * 32 * 32 * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..16).map(|i| (i % 10) as i32).collect();
        ins.push(Tensor::from_f32(x, &[16, 32, 32, 3]).unwrap());
        ins.push(Tensor::from_i32(y, &[16]).unwrap());
        let gpu = reg.get("cnn_train_b16", DeviceKind::Gpu).unwrap().run(&ins).unwrap();
        let cpu = reg.get("cnn_train_b16", DeviceKind::Cpu).unwrap().run(&ins).unwrap();
        assert_eq!(gpu.len(), 7);
        let (gl, cl) = (gpu[0].scalar_value().unwrap(), cpu[0].scalar_value().unwrap());
        assert!((gl - cl).abs() < 1e-3 * (1.0 + gl.abs()), "loss {gl} vs {cl}");
        for (gt, ct) in gpu[1..].iter().zip(cpu[1..].iter()) {
            let (g, c) = (gt.as_f32().unwrap(), ct.as_f32().unwrap());
            for (x, y) in g.iter().zip(c.iter()) {
                assert!((x - y).abs() < 5e-3 * (1.0 + x.abs()), "grad {x} vs {y}");
            }
        }
    }

    #[test]
    fn fpga_slower_than_gpu_same_result() {
        let Some(reg) = registry() else { return };
        let img = vec![0.25f32; 64 * 64];
        let ins = vec![Tensor::from_f32(img, &[1, 64, 64]).unwrap()];
        // Warm every round-robin device queue (compile once per device).
        let gpu_k = reg.get("feature_b1", DeviceKind::Gpu).unwrap();
        let fpga_k = reg.get("feature_b1", DeviceKind::Fpga).unwrap();
        for _ in 0..4 {
            let _ = gpu_k.run(&ins).unwrap();
            let _ = fpga_k.run(&ins).unwrap();
        }
        // Compare best-of-3 so scheduler noise can't flip the order.
        let best = |k: &Arc<dyn KernelImpl>| {
            (0..3)
                .map(|_| {
                    let t = Instant::now();
                    let out = k.run(&ins).unwrap();
                    (t.elapsed(), out)
                })
                .min_by_key(|(d, _)| *d)
                .unwrap()
        };
        let (gpu_t, g) = best(&gpu_k);
        let (fpga_t, f) = best(&fpga_k);
        assert_eq!(g[0], f[0]);
        assert!(
            fpga_t.as_secs_f64() >= gpu_t.as_secs_f64() * 1.5,
            "fpga {fpga_t:?} should be ~2.5x gpu {gpu_t:?}"
        );
    }
}
