//! Naive scalar CPU kernels — the paper's "generic CPU" baseline side.
//!
//! Section 2.3 compares OpenCL GPU kernels against plain CPU execution
//! (10–20X on CNN), section 4.3 reports 15X on training, section 5.2
//! 30X on ICP. These functions are that CPU side: correct, idiomatic,
//! deliberately *scalar* Rust (no blocking/vectorisation — that is what
//! the XLA-compiled artifacts bring), mirroring the JVM-side compute the
//! paper's accelerators displaced.
//!
//! They double as an independent second implementation of every L1/L2
//! graph: unit tests cross-check them against the PJRT artifacts, which
//! validates the whole Python→HLO→Rust chain numerically.

use anyhow::{bail, Result};

// ---------------------------------------------------------------------------
// CNN building blocks (NHWC, f32)
// ---------------------------------------------------------------------------

/// SAME conv2d: x (B,H,W,Cin) * w (KH,KW,Cin,Cout) -> (B,H,W,Cout).
pub fn conv2d(x: &[f32], xs: [usize; 4], w: &[f32], ws: [usize; 4]) -> Vec<f32> {
    let [b, h, wd, cin] = xs;
    let [kh, kw, cin2, cout] = ws;
    assert_eq!(cin, cin2, "channel mismatch");
    let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
    let mut out = vec![0f32; b * h * wd * cout];
    for bi in 0..b {
        for i in 0..h {
            for j in 0..wd {
                for u in 0..kh {
                    let si = i as isize + u as isize - ph as isize;
                    if si < 0 || si >= h as isize {
                        continue;
                    }
                    for v in 0..kw {
                        let sj = j as isize + v as isize - pw as isize;
                        if sj < 0 || sj >= wd as isize {
                            continue;
                        }
                        let xbase = ((bi * h + si as usize) * wd + sj as usize) * cin;
                        let wbase = (u * kw + v) * cin * cout;
                        let obase = ((bi * h + i) * wd + j) * cout;
                        for c in 0..cin {
                            let xv = x[xbase + c];
                            let wrow = wbase + c * cout;
                            for o in 0..cout {
                                out[obase + o] += xv * w[wrow + o];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Gradients of SAME conv2d w.r.t. input and weights.
pub fn conv2d_backward(
    x: &[f32],
    xs: [usize; 4],
    w: &[f32],
    ws: [usize; 4],
    g: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let [b, h, wd, cin] = xs;
    let [kh, kw, _, cout] = ws;
    let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
    let mut dx = vec![0f32; x.len()];
    let mut dw = vec![0f32; w.len()];
    for bi in 0..b {
        for i in 0..h {
            for j in 0..wd {
                let gbase = ((bi * h + i) * wd + j) * cout;
                for u in 0..kh {
                    let si = i as isize + u as isize - ph as isize;
                    if si < 0 || si >= h as isize {
                        continue;
                    }
                    for v in 0..kw {
                        let sj = j as isize + v as isize - pw as isize;
                        if sj < 0 || sj >= wd as isize {
                            continue;
                        }
                        let xbase = ((bi * h + si as usize) * wd + sj as usize) * cin;
                        let wbase = (u * kw + v) * cin * cout;
                        for c in 0..cin {
                            let xv = x[xbase + c];
                            let wrow = wbase + c * cout;
                            let mut acc = 0f32;
                            for o in 0..cout {
                                let gv = g[gbase + o];
                                dw[wrow + o] += xv * gv;
                                acc += w[wrow + o] * gv;
                            }
                            dx[xbase + c] += acc;
                        }
                    }
                }
            }
        }
    }
    (dx, dw)
}

/// 2x2 max pooling; returns (pooled, argmax index per output element).
pub fn maxpool2(x: &[f32], xs: [usize; 4]) -> (Vec<f32>, Vec<usize>) {
    let [b, h, w, c] = xs;
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; b * oh * ow * c];
    let mut arg = vec![0usize; b * oh * ow * c];
    for bi in 0..b {
        for i in 0..oh {
            for j in 0..ow {
                for ci in 0..c {
                    let oidx = ((bi * oh + i) * ow + j) * c + ci;
                    for di in 0..2 {
                        for dj in 0..2 {
                            let xi = ((bi * h + 2 * i + di) * w + 2 * j + dj) * c + ci;
                            if x[xi] > out[oidx] {
                                out[oidx] = x[xi];
                                arg[oidx] = xi;
                            }
                        }
                    }
                }
            }
        }
    }
    (out, arg)
}

/// Scatter pooled gradients back through the recorded argmaxes.
pub fn maxpool2_backward(g: &[f32], arg: &[usize], input_len: usize) -> Vec<f32> {
    let mut dx = vec![0f32; input_len];
    for (gi, &ai) in g.iter().zip(arg.iter()) {
        dx[ai] += gi;
    }
    dx
}

/// In-place ReLU; returns the activation mask.
pub fn relu(x: &mut [f32]) -> Vec<bool> {
    x.iter_mut()
        .map(|v| {
            let on = *v > 0.0;
            if !on {
                *v = 0.0;
            }
            on
        })
        .collect()
}

/// Dense layer y = x @ w + b; x (B,I), w (I,O), b (O).
pub fn dense(x: &[f32], bsz: usize, inp: usize, w: &[f32], out_dim: usize, b: &[f32]) -> Vec<f32> {
    let mut y = vec![0f32; bsz * out_dim];
    for bi in 0..bsz {
        for i in 0..inp {
            let xv = x[bi * inp + i];
            if xv == 0.0 {
                continue;
            }
            let wrow = i * out_dim;
            let yrow = bi * out_dim;
            for o in 0..out_dim {
                y[yrow + o] += xv * w[wrow + o];
            }
        }
        for o in 0..out_dim {
            y[bi * out_dim + o] += b[o];
        }
    }
    y
}

// ---------------------------------------------------------------------------
// The perception CNN (matches python/compile/model.py PARAM_SPECS exactly)
// ---------------------------------------------------------------------------

pub const IMG: usize = 32;
pub const NUM_CLASSES: usize = 10;

/// (name, shape) — must stay in lock-step with model.PARAM_SPECS.
pub const PARAM_SHAPES: [(&str, &[usize]); 6] = [
    ("c1w", &[3, 3, 3, 8]),
    ("c1b", &[8]),
    ("c2w", &[3, 3, 8, 16]),
    ("c2b", &[16]),
    ("dw", &[1024, NUM_CLASSES]),
    ("db", &[NUM_CLASSES]),
];

/// He-style init matching the Python initialiser's structure (zero biases,
/// scaled-normal weights) — exact values differ (different RNG), which is
/// fine: training starts from *an* init, not *the* init.
pub fn init_params(rng: &mut crate::util::Rng) -> Vec<Vec<f32>> {
    PARAM_SHAPES
        .iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            if name.ends_with('b') {
                vec![0f32; n]
            } else {
                let fan_in: usize = shape[..shape.len() - 1].iter().product();
                let scale = (2.0 / fan_in as f64).sqrt() as f32;
                (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
            }
        })
        .collect()
}

struct ForwardCache {
    a1: Vec<f32>,          // post-relu conv1 (B,32,32,8)
    mask1: Vec<bool>,
    p1: Vec<f32>,          // pooled (B,16,16,8)
    arg1: Vec<usize>,
    a2: Vec<f32>,          // post-relu conv2 (B,16,16,16)
    mask2: Vec<bool>,
    p2: Vec<f32>,          // pooled (B,8,8,16) == flat (B,1024)
    arg2: Vec<usize>,
    logits: Vec<f32>,
}

fn forward(params: &[Vec<f32>], x: &[f32], bsz: usize) -> ForwardCache {
    let (c1w, c1b, c2w, c2b, dw, db) =
        (&params[0], &params[1], &params[2], &params[3], &params[4], &params[5]);
    let mut a1 = conv2d(x, [bsz, IMG, IMG, 3], c1w, [3, 3, 3, 8]);
    for (i, v) in a1.iter_mut().enumerate() {
        *v += c1b[i % 8];
    }
    let mask1 = relu(&mut a1);
    let (p1, arg1) = maxpool2(&a1, [bsz, IMG, IMG, 8]);

    let mut a2 = conv2d(&p1, [bsz, 16, 16, 8], c2w, [3, 3, 8, 16]);
    for (i, v) in a2.iter_mut().enumerate() {
        *v += c2b[i % 16];
    }
    let mask2 = relu(&mut a2);
    let (p2, arg2) = maxpool2(&a2, [bsz, 16, 16, 16]);

    let logits = dense(&p2, bsz, 1024, dw, NUM_CLASSES, db);
    ForwardCache { a1, mask1, p1, arg1, a2, mask2, p2, arg2, logits }
}

/// Inference: logits for a batch of (B,32,32,3) images.
pub fn cnn_infer(params: &[Vec<f32>], x: &[f32], bsz: usize) -> Result<Vec<f32>> {
    if x.len() != bsz * IMG * IMG * 3 {
        bail!("bad input len {} for batch {bsz}", x.len());
    }
    Ok(forward(params, x, bsz).logits)
}

/// Full train step: mean softmax cross-entropy loss + gradients for all
/// six parameter tensors (same outputs as the `cnn_train_b16` artifact).
pub fn cnn_train_step(
    params: &[Vec<f32>],
    x: &[f32],
    y: &[i32],
    bsz: usize,
) -> Result<(f32, Vec<Vec<f32>>)> {
    if y.len() != bsz {
        bail!("bad label len {}", y.len());
    }
    let cache = forward(params, x, bsz);
    let (c1w, _c1b, c2w, _c2b, dw, _db) =
        (&params[0], &params[1], &params[2], &params[3], &params[4], &params[5]);

    // Softmax CE loss + dlogits.
    let mut loss = 0f64;
    let mut dlogits = vec![0f32; bsz * NUM_CLASSES];
    for bi in 0..bsz {
        let row = &cache.logits[bi * NUM_CLASSES..(bi + 1) * NUM_CLASSES];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = row.iter().map(|v| (v - m).exp()).sum();
        let logz = m + sum.ln();
        let yi = y[bi] as usize;
        loss += (logz - row[yi]) as f64;
        for o in 0..NUM_CLASSES {
            let p = (row[o] - logz).exp();
            dlogits[bi * NUM_CLASSES + o] =
                (p - if o == yi { 1.0 } else { 0.0 }) / bsz as f32;
        }
    }
    let loss = (loss / bsz as f64) as f32;

    // Dense backward.
    let mut g_dw = vec![0f32; dw.len()];
    let mut g_db = vec![0f32; NUM_CLASSES];
    let mut dp2 = vec![0f32; bsz * 1024];
    for bi in 0..bsz {
        for o in 0..NUM_CLASSES {
            let gv = dlogits[bi * NUM_CLASSES + o];
            g_db[o] += gv;
            if gv == 0.0 {
                continue;
            }
            for i in 0..1024 {
                g_dw[i * NUM_CLASSES + o] += cache.p2[bi * 1024 + i] * gv;
                dp2[bi * 1024 + i] += dw[i * NUM_CLASSES + o] * gv;
            }
        }
    }

    // Pool2 + relu2 backward.
    let mut da2 = maxpool2_backward(&dp2, &cache.arg2, cache.a2.len());
    for (v, &on) in da2.iter_mut().zip(cache.mask2.iter()) {
        if !on {
            *v = 0.0;
        }
    }
    // Bias2 grad = sum over spatial+batch of da2 per channel.
    let mut g_c2b = vec![0f32; 16];
    for (i, v) in da2.iter().enumerate() {
        g_c2b[i % 16] += v;
    }
    // Conv2 backward.
    let (dp1, g_c2w) = conv2d_backward(&cache.p1, [bsz, 16, 16, 8], c2w, [3, 3, 8, 16], &da2);

    // Pool1 + relu1 backward.
    let mut da1 = maxpool2_backward(&dp1, &cache.arg1, cache.a1.len());
    for (v, &on) in da1.iter_mut().zip(cache.mask1.iter()) {
        if !on {
            *v = 0.0;
        }
    }
    let mut g_c1b = vec![0f32; 8];
    for (i, v) in da1.iter().enumerate() {
        g_c1b[i % 8] += v;
    }
    let (_dx, g_c1w) = conv2d_backward(x, [bsz, IMG, IMG, 3], c1w, [3, 3, 3, 8], &da1);

    Ok((loss, vec![g_c1w, g_c1b, g_c2w, g_c2b, g_dw, g_db]))
}

// ---------------------------------------------------------------------------
// ICP correspondence + step statistics (brute force scalar)
// ---------------------------------------------------------------------------

/// For each src point, its nearest dst point and squared distance.
pub fn icp_correspondences(src: &[f32], dst: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let n = src.len() / 3;
    let m = dst.len() / 3;
    let mut nearest = vec![0f32; n * 3];
    let mut d2 = vec![0f32; n];
    for i in 0..n {
        let (sx, sy, sz) = (src[3 * i], src[3 * i + 1], src[3 * i + 2]);
        let mut best = f32::INFINITY;
        let mut bj = 0;
        for j in 0..m {
            let dx = sx - dst[3 * j];
            let dy = sy - dst[3 * j + 1];
            let dz = sz - dst[3 * j + 2];
            let d = dx * dx + dy * dy + dz * dz;
            if d < best {
                best = d;
                bj = j;
            }
        }
        nearest[3 * i..3 * i + 3].copy_from_slice(&dst[3 * bj..3 * bj + 3]);
        d2[i] = best;
    }
    (nearest, d2)
}

/// One ICP data pass: (cross_cov 3x3 row-major, src centroid, nn centroid,
/// mean squared error) — identical contract to the `icp_step_*` artifacts.
pub fn icp_step(src: &[f32], dst: &[f32]) -> ([f32; 9], [f32; 3], [f32; 3], f32) {
    let n = src.len() / 3;
    let (nearest, d2) = icp_correspondences(src, dst);
    let mut cs = [0f32; 3];
    let mut cd = [0f32; 3];
    for i in 0..n {
        for k in 0..3 {
            cs[k] += src[3 * i + k];
            cd[k] += nearest[3 * i + k];
        }
    }
    for k in 0..3 {
        cs[k] /= n as f32;
        cd[k] /= n as f32;
    }
    let mut h = [0f32; 9];
    for i in 0..n {
        for r in 0..3 {
            let sv = src[3 * i + r] - cs[r];
            for c in 0..3 {
                h[3 * r + c] += sv * (nearest[3 * i + c] - cd[c]);
            }
        }
    }
    let err = d2.iter().sum::<f32>() / n as f32;
    (h, cs, cd, err)
}

// ---------------------------------------------------------------------------
// Image feature extraction (the Fig 6 workload)
// ---------------------------------------------------------------------------

/// Gradient-energy descriptors for (B,H,W) grayscale; H, W % 8 == 0.
/// Output (B, H/8, W/8, 4): mean|gx|, mean|gy|, mean mag, max mag.
pub fn feature_extract(x: &[f32], b: usize, h: usize, w: usize) -> Vec<f32> {
    let (ch, cw) = (h / 8, w / 8);
    let mut out = vec![0f32; b * ch * cw * 4];
    let at = |bi: usize, i: isize, j: isize| -> f32 {
        // edge-padded access
        let ii = i.clamp(0, h as isize - 1) as usize;
        let jj = j.clamp(0, w as isize - 1) as usize;
        x[(bi * h + ii) * w + jj]
    };
    for bi in 0..b {
        for ci in 0..ch {
            for cj in 0..cw {
                let (mut sgx, mut sgy, mut smag, mut mmag) = (0f32, 0f32, 0f32, 0f32);
                for di in 0..8 {
                    for dj in 0..8 {
                        let i = (ci * 8 + di) as isize;
                        let j = (cj * 8 + dj) as isize;
                        let gx = (at(bi, i, j + 1) - at(bi, i, j - 1)) * 0.5;
                        let gy = (at(bi, i + 1, j) - at(bi, i - 1, j)) * 0.5;
                        let mag = (gx * gx + gy * gy).sqrt();
                        sgx += gx.abs();
                        sgy += gy.abs();
                        smag += mag;
                        mmag = mmag.max(mag);
                    }
                }
                let o = ((bi * ch + ci) * cw + cj) * 4;
                out[o] = sgx / 64.0;
                out[o + 1] = sgy / 64.0;
                out[o + 2] = smag / 64.0;
                out[o + 3] = mmag;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn conv2d_identity_1x1() {
        let mut rng = Rng::new(1);
        let x = randv(&mut rng, 2 * 4 * 4 * 3);
        let mut eye = vec![0f32; 3 * 3];
        for i in 0..3 {
            eye[i * 3 + i] = 1.0;
        }
        let y = conv2d(&x, [2, 4, 4, 3], &eye, [1, 1, 3, 3]);
        assert_eq!(y, x);
    }

    #[test]
    fn conv2d_counts_border_correctly() {
        // All-ones 3x3 kernel over all-ones image counts the in-bounds
        // neighbourhood: 4 in corners, 6 on edges, 9 inside.
        let x = vec![1f32; 4 * 4];
        let w = vec![1f32; 9];
        let y = conv2d(&x, [1, 4, 4, 1], &w, [3, 3, 1, 1]);
        assert_eq!(y[0], 4.0);
        assert_eq!(y[1], 6.0);
        assert_eq!(y[5], 9.0);
    }

    #[test]
    fn maxpool_roundtrip_gradient() {
        let x = vec![1., 5., 2., 0., 3., 1., 7., 2., 4., 4., 4., 4., 0., 1., 2., 9.];
        let (p, arg) = maxpool2(&x, [1, 4, 4, 1]);
        assert_eq!(p, vec![5., 7., 4., 9.]);
        let dx = maxpool2_backward(&[1., 1., 1., 1.], &arg, 16);
        assert_eq!(dx.iter().sum::<f32>(), 4.0);
        assert_eq!(dx[1], 1.0); // the 5
    }

    #[test]
    fn train_step_gradcheck_dense_bias() {
        // Finite-difference check of a few coordinates.
        let mut rng = Rng::new(2);
        let mut params = init_params(&mut rng);
        let bsz = 2;
        let x = randv(&mut rng, bsz * IMG * IMG * 3);
        let y = vec![3i32, 7];
        let (_, grads) = cnn_train_step(&params, &x, &y, bsz).unwrap();
        let eps = 1e-2f32;
        for (pi, ci) in [(5usize, 3usize), (5, 7), (1, 0), (3, 5)] {
            let orig = params[pi][ci];
            params[pi][ci] = orig + eps;
            let (lp, _) = cnn_train_step(&params, &x, &y, bsz).unwrap();
            params[pi][ci] = orig - eps;
            let (lm, _) = cnn_train_step(&params, &x, &y, bsz).unwrap();
            params[pi][ci] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let an = grads[pi][ci];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                "param {pi}[{ci}]: fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn sgd_on_cpu_reduces_loss() {
        let mut rng = Rng::new(3);
        let mut params = init_params(&mut rng);
        let bsz = 4;
        let x = randv(&mut rng, bsz * IMG * IMG * 3);
        let y = vec![0i32, 1, 2, 3];
        let (first, _) = cnn_train_step(&params, &x, &y, bsz).unwrap();
        for _ in 0..8 {
            let (_, grads) = cnn_train_step(&params, &x, &y, bsz).unwrap();
            for (p, g) in params.iter_mut().zip(grads.iter()) {
                for (pv, gv) in p.iter_mut().zip(gv_iter(g)) {
                    *pv -= 0.1 * gv;
                }
            }
        }
        let (last, _) = cnn_train_step(&params, &x, &y, bsz).unwrap();
        assert!(last < first, "loss {first} -> {last}");
    }

    fn gv_iter(g: &[f32]) -> impl Iterator<Item = f32> + '_ {
        g.iter().copied()
    }

    #[test]
    fn icp_identical_clouds() {
        let mut rng = Rng::new(4);
        let pts = randv(&mut rng, 64 * 3);
        let (h, cs, cd, err) = icp_step(&pts, &pts);
        assert!(err < 1e-10);
        assert_eq!(cs, cd);
        // H is the covariance of the cloud with itself: symmetric PSD.
        for r in 0..3 {
            for c in 0..3 {
                assert!((h[3 * r + c] - h[3 * c + r]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn icp_translation_shows_in_centroids() {
        let mut rng = Rng::new(5);
        let src = randv(&mut rng, 256 * 3);
        let t = [0.02f32, -0.01, 0.015];
        let dst: Vec<f32> = src
            .iter()
            .enumerate()
            .map(|(i, v)| v + t[i % 3])
            .collect();
        let (_, cs, cd, _) = icp_step(&src, &dst);
        for k in 0..3 {
            assert!((cd[k] - cs[k] - t[k]).abs() < 5e-3);
        }
    }

    #[test]
    fn feature_constant_image_is_zero() {
        let x = vec![0.3f32; 2 * 16 * 16];
        let f = feature_extract(&x, 2, 16, 16);
        assert!(f.iter().all(|v| v.abs() < 1e-7));
    }

    #[test]
    fn feature_detects_vertical_edge() {
        let mut x = vec![0f32; 16 * 16];
        for i in 0..16 {
            for j in 8..16 {
                x[i * 16 + j] = 1.0;
            }
        }
        let f = feature_extract(&x, 1, 16, 16);
        // mean|gx| over some cell must be positive, all |gy| zero.
        assert!(f.iter().step_by(4).any(|v| *v > 0.0));
        assert!(f.iter().skip(1).step_by(4).all(|v| v.abs() < 1e-7));
    }
}
