//! Kernel registry: named kernels with one implementation per device
//! class — the platform's analog of the paper's OpenCL kernel catalog
//! ("functions executed on an OpenCL device are called kernels").

use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::resource::DeviceKind;
use crate::runtime::Tensor;

/// A device-specific kernel implementation.
pub trait KernelImpl: Send + Sync {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>>;
}

/// Closure adapter (used for the naive CPU implementations).
pub struct FnKernel<F>(pub F);

impl<F> KernelImpl for FnKernel<F>
where
    F: Fn(&[Tensor]) -> Result<Vec<Tensor>> + Send + Sync,
{
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        (self.0)(inputs)
    }
}

/// name -> device class -> implementation.
#[derive(Default, Clone)]
pub struct KernelRegistry {
    inner: Arc<RwLock<HashMap<String, HashMap<DeviceKind, Arc<dyn KernelImpl>>>>>,
}

impl KernelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&self, name: &str, kind: DeviceKind, imp: Arc<dyn KernelImpl>) {
        self.inner
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .insert(kind, imp);
    }

    pub fn get(&self, name: &str, kind: DeviceKind) -> Result<Arc<dyn KernelImpl>> {
        self.inner
            .read()
            .unwrap()
            .get(name)
            .and_then(|m| m.get(&kind))
            .cloned()
            .ok_or_else(|| anyhow!("no {kind} implementation for kernel '{name}'"))
    }

    /// Device classes implementing `name`, in preference order GPU>FPGA>CPU.
    pub fn devices_for(&self, name: &str) -> Vec<DeviceKind> {
        let map = self.inner.read().unwrap();
        let mut v: Vec<DeviceKind> = map
            .get(name)
            .map(|m| m.keys().copied().collect())
            .unwrap_or_default();
        v.sort_by_key(|k| match k {
            DeviceKind::Gpu => 0,
            DeviceKind::Fpga => 1,
            DeviceKind::Cpu => 2,
        });
        v
    }

    pub fn kernel_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo() -> Arc<dyn KernelImpl> {
        Arc::new(FnKernel(|ins: &[Tensor]| Ok(ins.to_vec())))
    }

    #[test]
    fn register_and_lookup() {
        let reg = KernelRegistry::new();
        reg.register("k", DeviceKind::Cpu, echo());
        let imp = reg.get("k", DeviceKind::Cpu).unwrap();
        let out = imp.run(&[Tensor::scalar_f32(1.0)]).unwrap();
        assert_eq!(out.len(), 1);
        assert!(reg.get("k", DeviceKind::Gpu).is_err());
        assert!(reg.get("nope", DeviceKind::Cpu).is_err());
    }

    #[test]
    fn devices_for_prefers_gpu() {
        let reg = KernelRegistry::new();
        reg.register("k", DeviceKind::Cpu, echo());
        reg.register("k", DeviceKind::Gpu, echo());
        reg.register("k", DeviceKind::Fpga, echo());
        assert_eq!(
            reg.devices_for("k"),
            vec![DeviceKind::Gpu, DeviceKind::Fpga, DeviceKind::Cpu]
        );
        assert!(reg.devices_for("missing").is_empty());
    }
}
