//! Energy accounting for heterogeneous dispatch.
//!
//! The paper's FPGA story is *efficiency*: "FPGA is a low-power solution
//! for vector computation". We account energy = board power × busy time
//! per device class, which lets benches report joules/inference alongside
//! latency — the axis on which the modelled FPGA wins even while slower
//! than the GPU-class device.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::resource::DeviceKind;

/// Accumulated busy-time and energy per device class.
#[derive(Debug, Default)]
pub struct EnergyMeter {
    busy_us: [AtomicU64; 3],
    ops: [AtomicU64; 3],
}

fn slot(kind: DeviceKind) -> usize {
    match kind {
        DeviceKind::Cpu => 0,
        DeviceKind::Gpu => 1,
        DeviceKind::Fpga => 2,
    }
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, kind: DeviceKind, busy: Duration) {
        self.busy_us[slot(kind)].fetch_add(busy.as_micros() as u64, Ordering::Relaxed);
        self.ops[slot(kind)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn busy(&self, kind: DeviceKind) -> Duration {
        Duration::from_micros(self.busy_us[slot(kind)].load(Ordering::Relaxed))
    }

    pub fn ops(&self, kind: DeviceKind) -> u64 {
        self.ops[slot(kind)].load(Ordering::Relaxed)
    }

    /// Joules consumed by a device class so far.
    pub fn joules(&self, kind: DeviceKind) -> f64 {
        self.busy(kind).as_secs_f64() * kind.power_watts()
    }

    /// Joules per op (NaN if no ops recorded).
    pub fn joules_per_op(&self, kind: DeviceKind) -> f64 {
        self.joules(kind) / self.ops(kind) as f64
    }

    pub fn reset(&self) {
        for i in 0..3 {
            self.busy_us[i].store(0, Ordering::Relaxed);
            self.ops[i].store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_per_class() {
        let m = EnergyMeter::new();
        m.record(DeviceKind::Gpu, Duration::from_millis(100));
        m.record(DeviceKind::Fpga, Duration::from_millis(300));
        assert_eq!(m.ops(DeviceKind::Gpu), 1);
        assert_eq!(m.busy(DeviceKind::Fpga), Duration::from_millis(300));
        assert_eq!(m.ops(DeviceKind::Cpu), 0);
    }

    #[test]
    fn fpga_wins_on_energy_despite_longer_time() {
        let m = EnergyMeter::new();
        // FPGA 3x slower but 10x lower power -> ~3.3x less energy.
        m.record(DeviceKind::Gpu, Duration::from_millis(100));
        m.record(DeviceKind::Fpga, Duration::from_millis(300));
        assert!(m.joules(DeviceKind::Fpga) < m.joules(DeviceKind::Gpu));
    }

    #[test]
    fn reset_zeroes() {
        let m = EnergyMeter::new();
        m.record(DeviceKind::Cpu, Duration::from_secs(1));
        m.reset();
        assert_eq!(m.ops(DeviceKind::Cpu), 0);
        assert_eq!(m.joules(DeviceKind::Cpu), 0.0);
    }
}
