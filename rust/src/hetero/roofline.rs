//! Roofline device models for the paper's hardware classes.
//!
//! This reproduction runs on a single CPU core, so the paper's
//! GPU-vs-CPU factors (10–20x CNN, 15x training, 30x ICP) — which are
//! *hardware parallelism* — cannot appear in host wall-clock. Per the
//! substitution rule, the hardware is modelled analytically: each kernel
//! gets a (flops, bytes) cost from its shapes, and a device class turns
//! that into time via `launch + max(flops/F, bytes/B)` with sustained
//! rates for the paper's 2016-era parts:
//!
//! * CPU class: dual-socket Xeon E5 v3 (~600 GFLOP/s peak fp32).
//!   Sustained efficiency is workload-dependent: dense conv ~25%
//!   (im2col + vendor BLAS), nearest-neighbour search ~10% (KD-tree /
//!   compare-select chains vectorise poorly).
//! * GPU class: Tesla M40 (6.8 TFLOP/s fp32, 288 GB/s), cuDNN-style
//!   sustained 25% compute / 60% bandwidth, 20 us launch.
//!
//! Benches report these *modelled* rows clearly labelled, next to the
//! real measured host rows; EXPERIMENTS.md discusses both.

use std::time::Duration;

/// A device class with sustained roofline rates.
#[derive(Debug, Clone)]
pub struct RooflineDevice {
    pub name: &'static str,
    /// Sustained FLOP/s for dense (regular) kernels.
    pub flops_dense: f64,
    /// Sustained FLOP/s for irregular (search/reduce) kernels.
    pub flops_irregular: f64,
    /// Sustained memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Per-kernel launch/dispatch overhead.
    pub launch: Duration,
}

impl RooflineDevice {
    /// Dual-socket Xeon E5-2680v3-class server (the paper's CPU side).
    pub fn server_cpu() -> Self {
        Self {
            name: "xeon-e5-class cpu (modelled)",
            flops_dense: 600e9 * 0.25,
            flops_irregular: 600e9 * 0.10,
            mem_bw: 68e9 * 0.60,
            launch: Duration::from_micros(2),
        }
    }

    /// Tesla M40-class accelerator (the paper's GPU side).
    pub fn m40_gpu() -> Self {
        Self {
            name: "m40-class gpu (modelled)",
            flops_dense: 6.8e12 * 0.25,
            flops_irregular: 6.8e12 * 0.25, // brute-force maps to dense work
            mem_bw: 288e9 * 0.60,
            launch: Duration::from_micros(20),
        }
    }

    /// Mid-size FPGA card: lower clock but deep pipelines; wins on
    /// energy, not latency (25 W board).
    pub fn fpga_card() -> Self {
        Self {
            name: "fpga-class card (modelled)",
            flops_dense: 1.0e12 * 0.50,
            flops_irregular: 1.0e12 * 0.50,
            mem_bw: 34e9 * 0.80,
            launch: Duration::from_micros(50),
        }
    }

    /// Modelled execution time of a kernel invocation.
    pub fn time(&self, cost: &KernelCost) -> Duration {
        let f = if cost.irregular { self.flops_irregular } else { self.flops_dense };
        let compute = cost.flops / f;
        let memory = cost.bytes / self.mem_bw;
        self.launch + Duration::from_secs_f64(compute.max(memory))
    }
}

/// Analytic cost of one kernel invocation.
#[derive(Debug, Clone, Copy)]
pub struct KernelCost {
    pub flops: f64,
    pub bytes: f64,
    /// Irregular (search/pointer-chasing) on CPUs.
    pub irregular: bool,
}

/// SAME conv2d cost: 2*B*H*W*KH*KW*Cin*Cout FLOPs.
pub fn conv2d_cost(b: usize, h: usize, w: usize, k: usize, cin: usize, cout: usize) -> KernelCost {
    let flops = 2.0 * (b * h * w * k * k * cin * cout) as f64;
    let bytes = 4.0 * (b * h * w * cin + k * k * cin * cout + b * h * w * cout) as f64;
    KernelCost { flops, bytes, irregular: false }
}

/// The perception CNN inference cost (conv1 + conv2 + dense).
pub fn cnn_infer_cost(batch: usize) -> KernelCost {
    let c1 = conv2d_cost(batch, 32, 32, 3, 3, 8);
    let c2 = conv2d_cost(batch, 16, 16, 3, 8, 16);
    let dense = 2.0 * (batch * 1024 * 10) as f64;
    KernelCost {
        flops: c1.flops + c2.flops + dense,
        bytes: c1.bytes + c2.bytes + 4.0 * (batch * 1024) as f64,
        irregular: false,
    }
}

/// Train step ≈ 3x inference (fwd + dgrad + wgrad).
pub fn cnn_train_cost(batch: usize) -> KernelCost {
    let inf = cnn_infer_cost(batch);
    KernelCost { flops: 3.0 * inf.flops, bytes: 3.0 * inf.bytes, irregular: false }
}

/// One ICP iteration on N src / M dst points: distance matrix + min
/// reduce + nearest selection. Irregular on CPU (NN search), dense
/// brute-force on accelerators.
pub fn icp_iter_cost(n: usize, m: usize, on_cpu: bool) -> KernelCost {
    let nm = (n * m) as f64;
    // cross matmul (2*3) + norm/broadcast (~3) + min reduce (1) + mask
    // select matmul (2*3).
    let flops = nm * 12.0;
    // With cache/SMEM tiling the (N,M) tile is heavily reused; effective
    // HBM traffic is ~0.2 passes over the matrix.
    let bytes = nm * 4.0 * 0.2;
    KernelCost { flops, bytes, irregular: on_cpu }
}

/// Feature extraction cost per batch of (H,W) images.
pub fn feature_cost(b: usize, h: usize, w: usize) -> KernelCost {
    let px = (b * h * w) as f64;
    KernelCost { flops: px * 14.0, bytes: px * 4.0 * 2.0, irregular: false }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_beats_cpu_in_paper_band_on_cnn() {
        let cpu = RooflineDevice::server_cpu();
        let gpu = RooflineDevice::m40_gpu();
        // Paper-scale CNN: AlexNet-class, ~0.7 GFLOP/image, batch 128.
        let cost = KernelCost { flops: 0.7e9 * 128.0, bytes: 128.0 * 5e6, irregular: false };
        let ratio = cpu.time(&cost).as_secs_f64() / gpu.time(&cost).as_secs_f64();
        assert!(
            (8.0..25.0).contains(&ratio),
            "CNN modelled speedup {ratio} outside the paper's 10-20x band"
        );
    }

    #[test]
    fn gpu_beats_cpu_about_30x_on_icp() {
        let cpu = RooflineDevice::server_cpu();
        let gpu = RooflineDevice::m40_gpu();
        let c_cpu = icp_iter_cost(100_000, 100_000, true);
        let c_gpu = icp_iter_cost(100_000, 100_000, false);
        let ratio = cpu.time(&c_cpu).as_secs_f64() / gpu.time(&c_gpu).as_secs_f64();
        assert!((15.0..60.0).contains(&ratio), "ICP modelled speedup {ratio} not ~30x");
    }

    #[test]
    fn fpga_wins_energy_not_latency() {
        let gpu = RooflineDevice::m40_gpu();
        let fpga = RooflineDevice::fpga_card();
        let cost = cnn_infer_cost(32);
        let t_gpu = gpu.time(&cost);
        let t_fpga = fpga.time(&cost);
        assert!(t_fpga >= t_gpu);
        // Energy: 250 W vs 25 W boards.
        let e_gpu = 250.0 * t_gpu.as_secs_f64();
        let e_fpga = 25.0 * t_fpga.as_secs_f64();
        assert!(e_fpga < e_gpu, "fpga should win energy: {e_fpga} vs {e_gpu}");
    }

    #[test]
    fn launch_floor_applies() {
        let gpu = RooflineDevice::m40_gpu();
        let tiny = KernelCost { flops: 1.0, bytes: 4.0, irregular: false };
        assert!(gpu.time(&tiny) >= Duration::from_micros(20));
    }

    #[test]
    fn cost_helpers_scale_linearly() {
        let a = cnn_infer_cost(8);
        let b = cnn_infer_cost(16);
        assert!((b.flops / a.flops - 2.0).abs() < 0.01);
        let f1 = feature_cost(1, 64, 64);
        let f8 = feature_cost(8, 64, 64);
        assert!((f8.flops / f1.flops - 8.0).abs() < 1e-9);
    }
}
