//! Heterogeneous dispatch: route a kernel invocation to a device class,
//! time it, meter its energy — the seam the paper built with
//! RDD→JNI→OpenCL (section 2.3: "how to seamlessly dispatch a workload
//! to a computing substrate").

use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

use super::energy::EnergyMeter;
use super::registry::KernelRegistry;
use crate::metrics::MetricsRegistry;
use crate::resource::DeviceKind;
use crate::runtime::Tensor;

/// Shared dispatcher handle.
#[derive(Clone)]
pub struct Dispatcher {
    registry: KernelRegistry,
    energy: Arc<EnergyMeter>,
    metrics: MetricsRegistry,
}

impl Dispatcher {
    pub fn new(registry: KernelRegistry, metrics: MetricsRegistry) -> Self {
        Self { registry, energy: Arc::new(EnergyMeter::new()), metrics }
    }

    pub fn registry(&self) -> &KernelRegistry {
        &self.registry
    }

    pub fn energy(&self) -> &EnergyMeter {
        &self.energy
    }

    /// Run `name` on a specific device class.
    pub fn run_on(&self, kind: DeviceKind, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let imp = self.registry.get(name, kind)?;
        let start = Instant::now();
        let out = imp.run(inputs)?;
        let elapsed = start.elapsed();
        self.energy.record(kind, elapsed);
        self.metrics
            .histogram(&format!("hetero.{}.{}", kind.name(), name))
            .record(elapsed);
        Ok(out)
    }

    /// Run on the best available device class, restricted to `allowed`
    /// (empty = anything). Falls through the preference order on missing
    /// implementations and returns which class actually ran.
    pub fn run_best(
        &self,
        name: &str,
        inputs: &[Tensor],
        allowed: &[DeviceKind],
    ) -> Result<(DeviceKind, Vec<Tensor>)> {
        let mut last_err = None;
        for kind in self.registry.devices_for(name) {
            if !allowed.is_empty() && !allowed.contains(&kind) {
                continue;
            }
            match self.run_on(kind, name, inputs) {
                Ok(out) => return Ok((kind, out)),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("no implementation for kernel '{name}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetero::registry::FnKernel;

    fn dispatcher() -> Dispatcher {
        let reg = KernelRegistry::new();
        reg.register(
            "double",
            DeviceKind::Cpu,
            Arc::new(FnKernel(|ins: &[Tensor]| {
                let v = ins[0].as_f32()?;
                Tensor::from_f32(v.iter().map(|x| x * 2.0).collect(), &ins[0].shape)
                    .map(|t| vec![t])
            })),
        );
        Dispatcher::new(reg, MetricsRegistry::new())
    }

    #[test]
    fn run_on_times_and_meters() {
        let d = dispatcher();
        let out = d
            .run_on(DeviceKind::Cpu, "double", &[Tensor::from_f32(vec![1.0, 2.0], &[2]).unwrap()])
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 4.0]);
        assert_eq!(d.energy().ops(DeviceKind::Cpu), 1);
    }

    #[test]
    fn run_best_falls_back_to_cpu() {
        let d = dispatcher();
        let (kind, _) = d
            .run_best("double", &[Tensor::from_f32(vec![1.0], &[1]).unwrap()], &[])
            .unwrap();
        assert_eq!(kind, DeviceKind::Cpu);
    }

    #[test]
    fn run_best_respects_allowed() {
        let d = dispatcher();
        let r = d.run_best(
            "double",
            &[Tensor::from_f32(vec![1.0], &[1]).unwrap()],
            &[DeviceKind::Gpu],
        );
        assert!(r.is_err(), "only CPU impl exists but GPU demanded");
    }

    #[test]
    fn unknown_kernel_errors() {
        let d = dispatcher();
        assert!(d.run_on(DeviceKind::Cpu, "ghost", &[]).is_err());
        assert!(d.run_best("ghost", &[], &[]).is_err());
    }
}
