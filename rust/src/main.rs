//! `adcloud` — the platform launcher.
//!
//! Subcommands:
//!   info                         platform + artifact summary
//!   quickstart                   tiny end-to-end demo job
//!   simulate  [--bags N] [--frames M] [--piped]
//!   campaign  [--seed S] [--scenarios N] [--nodes K] [--frames F]
//!   ingest    [--vehicles N] [--ticks T] [--partitions P] [--workers W]
//!             [--campaign]   fleet ingest -> compaction -> scenario mining
//!   jobs      [--nodes N] [--scenarios S] [--vehicles V] [--ticks T]
//!             [--preempt]  two concurrent jobs (campaign + compaction)
//!             on capacity-share queues through the unified job layer;
//!             --preempt opens elastic 100% ceilings over the 50%
//!             guarantees, lets the campaign balloon over-share, and
//!             has the late compaction job reclaim its share through
//!             fair-share preemption + checkpointed shard requeue
//!             [--ckpt-gc-secs S]  after the jobs finish, sweep ckpt/*
//!             blobs older than S seconds (orphans from failed,
//!             never-resubmitted jobs) and report the reclaimed count
//!             [--sample-ms MS]  run the telemetry plane (sampler +
//!             SLO watchdogs + flight recorder) over the job layer
//!             [--serve ADDR]  with --sample-ms: serve /metrics
//!             (Prometheus text) and /healthz (watchdog rollup) over
//!             HTTP during the run, e.g. --serve 127.0.0.1:9100
//!             [--force-postmortem PATH]  with --sample-ms: write a
//!             flight-recorder bundle to PATH before exiting
//!   serve     [--nodes N] [--workers W] [--requests R] [--load F]
//!             [--service-us US] [--deadline-us US] [--local-us US]
//!             the latency-SLO serving plane: deadline-aware offload
//!             requests over the unified job layer on the interactive
//!             priority queue (EDF dispatch + speculative local-model
//!             fallback); --load is a fraction of worker capacity
//!             [--quick]  run the CI self-test instead
//!             [--sample-ms MS]  telemetry plane with the serve SLO
//!             rules (interactive grant-wait p99, rising-latency
//!             slope) stacked on the builtin watchdog set
//!   train     [--examples N] [--rounds R] [--workers W]
//!   mapgen    [--steps N]
//!   sql       [--rows N]
//!   repro-tables [e1..e21|all] [--quick]
//!             [--vehicles N]  e20 only: sweep the fleet up to N
//!             vehicles instead of the default (1M, or 50k --quick)
//!   top       [--once] [--duration-secs S] [--refresh-ms MS]
//!             refreshing text dashboard (sampler series + SLO rules)
//!             over a self-contained demo workload
//!   postmortem <bundle.json>     pretty-print a flight-recorder bundle
//!   bench-diff [files...] [--baseline-dir D] [--update]
//!             compare fresh BENCH_*.json throughput against the
//!             checked-in baselines; >10% regression fails the command
//!   trace <trace.json>           pretty-print a recorded trace as a span tree
//!   pipe-worker <logic>          BinPipe child process (detect)
//!   metrics                      dump the metrics registry after a demo job
//!
//! Subcommands that submit through the unified job layer (`campaign`,
//! `ingest`, `mapgen`) share the same submission flags with identical
//! meaning: `--app NAME` (application name), `--queue Q` (capacity
//! queue), `--no-checkpoint` (skip shard checkpointing).
//!
//! Every subcommand also accepts `--baseline`: force the pre-fast-path
//! storage plane (single-lock block map, O(n) eviction scans) for A/B
//! runs against experiment E17's sharded default, plus the pre-E22
//! single-lock shuffle manager (per-op metric lookups, no manager-side
//! combine, no placement hints); for `ingest` it also
//! selects the pre-batching gateway (per-vehicle stepping, one
//! admission decision and one log append per upload) against the
//! event-driven batched default; for `serve` it selects FIFO dispatch
//! with speculation off (experiment E21's baseline arm) — and
//! `--trace <out.json>`: enable the causal tracer for the run and write
//! every recorded span as Chrome trace-event JSON (loadable in
//! Perfetto / chrome://tracing, or pretty-printed by `adcloud trace`).
//!
//! Arg parsing is hand-rolled (offline build: no clap in the vendored
//! crate set).

use adcloud::platform::{experiments, Platform};
use adcloud::resource::DeviceKind;
use adcloud::scenario;
use adcloud::services::{mapgen, simulation, sql, training};
use adcloud::Result;
use std::collections::HashMap;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, name: &str, default: T) -> T {
    flags
        .get(name)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// The shared job-submission flags, meaning the same thing on every
/// subcommand that submits through the unified job layer: `--app NAME`
/// (application name), `--queue Q` (capacity queue), `--no-checkpoint`
/// (skip shard checkpointing).
fn job_opts_from(
    flags: &HashMap<String, String>,
    default_app: &str,
    workers: usize,
) -> adcloud::platform::JobOpts {
    let app = flags.get("app").map(String::as_str).unwrap_or(default_app);
    let mut opts = adcloud::platform::JobOpts::new(app).workers(workers);
    if let Some(q) = flags.get("queue") {
        opts.queue = q.clone();
    }
    opts.checkpoint = !flags.contains_key("no-checkpoint");
    opts
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("adcloud error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: Vec<String>) -> Result<()> {
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("info");
    // `--trace <out.json>`: record every span of this run and dump it
    // as Chrome trace-event JSON on exit (success or failure — a trace
    // of a failed run is the one you want most).
    let trace_out = flags.get("trace").cloned();
    if trace_out.is_some() {
        adcloud::trace::tracer().enable();
    }
    let result = dispatch(cmd, &pos, &flags);
    if let Some(path) = trace_out {
        let spans = adcloud::trace::tracer().take_all();
        match adcloud::trace::export::write_chrome_trace(&path, &spans) {
            Ok(()) => eprintln!("trace: {} span(s) written to {path}", spans.len()),
            Err(e) => eprintln!("trace write failed: {e:#}"),
        }
    }
    result
}

fn dispatch(cmd: &str, pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    match cmd {
        "info" => {
            let p = Platform::boot(config_from(flags))?;
            println!("{}", p.describe());
            if let Some(rt) = &p.runtime {
                println!("artifacts dir: {:?}", adcloud::artifacts_dir());
                for name in rt.manifest().names() {
                    println!("  artifact: {name}");
                }
            }
            Ok(())
        }
        "quickstart" => quickstart(flags),
        "simulate" => simulate(flags),
        "campaign" => campaign(flags),
        "ingest" => run_ingest(flags),
        "jobs" => run_jobs(flags),
        "serve" => run_serve(flags),
        "train" => train(flags),
        "mapgen" => run_mapgen(flags),
        "sql" => run_sql(flags),
        "repro-tables" => repro_tables(&pos[1..], flags),
        "top" => run_top(flags),
        "postmortem" => {
            let path = pos.get(1).map(String::as_str).ok_or_else(|| {
                anyhow::anyhow!("usage: adcloud postmortem <postmortem-bundle.json>")
            })?;
            let bundle = adcloud::obs::recorder::load(path)?;
            print!("{}", adcloud::obs::recorder::render(&bundle)?);
            Ok(())
        }
        "bench-diff" => bench_diff(&pos[1..], flags),
        "trace" => {
            let path = pos.get(1).map(String::as_str);
            let path =
                path.ok_or_else(|| anyhow::anyhow!("usage: adcloud trace <trace.json>"))?;
            let spans = adcloud::trace::export::load_chrome_trace(path)?;
            print!("{}", adcloud::trace::export::render_tree(&spans));
            Ok(())
        }
        "pipe-worker" => pipe_worker(pos.get(1).map(String::as_str)),
        "metrics" => {
            let p = Platform::boot(config_from(flags))?;
            let _ = p.ctx.range(10_000, 8).map(|x| x * 2).count()?;
            // A wide stage, so the shuffle plane (the single-lock arm
            // under --baseline) shows up in the report too.
            let _ = p
                .ctx
                .range(10_000, 8)
                .map(|x| (x % 64, 1u64))
                .reduce_by_key(|a, b| a + b, 8)
                .collect()?;
            println!("{}", p.metrics.report());
            println!("{}", p.ctx.metrics().report());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            eprintln!(
                "commands: info quickstart simulate campaign ingest jobs serve train mapgen \
                 sql repro-tables top postmortem bench-diff trace pipe-worker metrics"
            );
            std::process::exit(2);
        }
    }
}

fn config_from(flags: &HashMap<String, String>) -> adcloud::config::PlatformConfig {
    let mut loaded = None;
    if let Some(path) = flags.get("config") {
        match adcloud::config::PlatformConfig::load(path) {
            Ok(c) => loaded = Some(c),
            Err(e) => {
                eprintln!("config load failed ({e:#}); using defaults");
            }
        }
    }
    let mut cfg = loaded.unwrap_or_else(|| {
        if flags.contains_key("bench") {
            adcloud::config::PlatformConfig::bench()
        } else {
            adcloud::config::PlatformConfig::default()
        }
    });
    if flags.contains_key("baseline") {
        // The E17 A/B knob: old single-lock storage path.
        cfg.storage.scan_evict = true;
        cfg.storage.shards = 1;
        // The E22 A/B knob: old single-lock shuffle manager (per-op
        // metric lookups, no manager-side combine, no placement hints).
        cfg.engine.shuffle_single_lock = true;
    }
    cfg
}

fn quickstart(flags: &HashMap<String, String>) -> Result<()> {
    let p = Platform::boot(config_from(flags))?;
    println!("{}", p.describe());
    // A tiny unified job: telemetry stats on the compute engine.
    let data = sql::generate_telemetry(10_000, 50, 1);
    let rdd = p.ctx.parallelize(data, 8);
    let rows = sql::q1_dce(&rdd, 4)?;
    println!("q1: {} vehicles aggregated; first row: {:?}", rows.len(), rows.first());
    // One accelerator call if artifacts exist.
    if p.has_accelerators() {
        let x = adcloud::runtime::Tensor::from_f32(vec![0.5; 64 * 64], &[1, 64, 64])?;
        let (kind, out) = p.dispatcher.run_best("feature_b1", &[x], &[])?;
        println!("feature kernel ran on {kind}: output shape {:?}", out[0].shape);
    }
    println!("quickstart OK");
    Ok(())
}

fn simulate(flags: &HashMap<String, String>) -> Result<()> {
    let p = Platform::boot(config_from(flags))?;
    let bags_n = flag(flags, "bags", 8usize);
    let frames = flag(flags, "frames", 32usize);
    let dir = std::env::temp_dir().join(format!("adcloud-sim-{}", std::process::id()));
    println!("recording {bags_n} bags x {frames} frames to {dir:?}");
    let bags = simulation::record_drive(&dir, bags_n, frames, p.config.seed)?;
    let report = if flags.contains_key("piped") {
        let exe = std::env::current_exe()?;
        println!("replaying through pipe workers ({exe:?} pipe-worker detect)");
        simulation::replay_piped(
            &p.ctx,
            &bags,
            vec![exe.to_string_lossy().into_owned(), "pipe-worker".into(), "detect".into()],
        )?
    } else {
        simulation::replay(&p.ctx, &p.dispatcher, &bags, DeviceKind::Gpu)?
    };
    println!(
        "replayed {} frames on {}: accuracy {:.1}% in {}",
        report.frames,
        report.device,
        report.accuracy * 100.0,
        adcloud::util::fmt_duration(report.elapsed)
    );
    let _ = std::fs::remove_dir_all(dir);
    Ok(())
}

fn campaign(flags: &HashMap<String, String>) -> Result<()> {
    let p = Platform::boot(config_from(flags))?;
    let seed = flag(flags, "seed", 7u64);
    let scenarios = flag(flags, "scenarios", 32usize);
    let nodes = flag(flags, "nodes", 4usize);
    let frames = flag(flags, "frames", 32u32);
    let specs = scenario::generate_campaign_sized(seed, scenarios, frames);
    let distinct: std::collections::HashSet<u64> =
        specs.iter().map(|s| s.content_hash()).collect();
    println!(
        "campaign seed {seed}: {} scenarios generated ({} distinct spec hashes), spec digest {:016x}",
        specs.len(),
        distinct.len(),
        scenario::campaign_digest(&specs)
    );
    let mut cfg = scenario::CampaignConfig::new(format!("campaign-{seed}"), nodes);
    cfg.opts = job_opts_from(flags, &format!("campaign-{seed}"), nodes);
    let report = scenario::run_campaign(&p.ctx, &p.resources, &specs, &cfg)?;
    println!("{}", report.render());
    Ok(())
}

fn run_ingest(flags: &HashMap<String, String>) -> Result<()> {
    use adcloud::ingest;
    let p = Platform::boot(config_from(flags))?;
    let vehicles = flag(flags, "vehicles", 16u32);
    let ticks = flag(flags, "ticks", 200usize);
    let partitions = flag(flags, "partitions", 4usize);
    let workers = flag(flags, "workers", 2usize);
    println!("{}", p.describe());
    println!("ingesting {vehicles} vehicles x {ticks} ticks into {partitions} partition(s)");

    let log = ingest::PartitionedLog::temp(
        "cli",
        ingest::LogConfig { partitions, ..Default::default() },
    )?;
    let gw = ingest::IngestGateway::new(
        log.clone(),
        ingest::GatewayConfig::default(),
        p.metrics.clone(),
    );
    let mut fleet_cfg = ingest::FleetConfig::new(vehicles, ticks, p.config.seed);
    fleet_cfg.corrupt_rate = 0.02;
    fleet_cfg.baseline = flags.contains_key("baseline");
    let fleet = ingest::simulate_fleet(&gw, &fleet_cfg)?;
    println!("{}", fleet.render());

    let mut ccfg = ingest::CompactorConfig::new("cli-ingest", workers);
    ccfg.opts = job_opts_from(flags, "cli-ingest", workers);
    let compaction = ingest::compact(&log, p.ctx.store(), &p.resources, &ccfg)?;
    println!("{}", compaction.render());

    let mined = ingest::mine(
        &p.ctx,
        &p.resources,
        p.ctx.store(),
        &compaction.blocks,
        &ingest::MinerConfig::default(),
    )?;
    print!("{}", mined.render());

    if flags.contains_key("campaign") && !mined.specs.is_empty() {
        let cfg = scenario::CampaignConfig::new("ingest-mined", workers);
        let report = scenario::run_campaign(&p.ctx, &p.resources, &mined.specs, &cfg)?;
        println!("{}", report.render());
    }
    println!("ingest done");
    Ok(())
}

/// Two tenants, one cluster: a scenario campaign (queue `sim`) and a
/// fleet-compaction drain (queue `fleet`) run concurrently through the
/// unified job layer against a 50/50 capacity split, then the job-layer
/// metrics (grant waits, shard retries, container-seconds) are printed.
/// With `--preempt`, both queues get elastic 100% ceilings, preemption
/// is enabled, and the compaction job arrives late — so the over-share
/// campaign is visibly preempted, checkpointed, and requeued.
fn run_jobs(flags: &HashMap<String, String>) -> Result<()> {
    use adcloud::ingest;
    let mut cfg = config_from(flags);
    cfg.cluster.nodes = flag(flags, "nodes", cfg.cluster.nodes);
    let scenarios = flag(flags, "scenarios", 16usize);
    let vehicles = flag(flags, "vehicles", 8u32);
    let ticks = flag(flags, "ticks", 200usize);
    let preempt = flags.contains_key("preempt");
    let metrics = adcloud::metrics::MetricsRegistry::new();
    let rm = if preempt {
        adcloud::resource::ResourceManager::with_elastic_queues(
            &cfg.cluster,
            vec![("sim".into(), 0.5, 1.0), ("fleet".into(), 0.5, 1.0)],
            metrics.clone(),
        )
    } else {
        adcloud::resource::ResourceManager::with_queues(
            &cfg.cluster,
            vec![("sim".into(), 0.5), ("fleet".into(), 0.5)],
            metrics.clone(),
        )
    };
    rm.set_preemption(preempt);
    let ctx = adcloud::dce::DceContext::new(cfg.clone())?;
    // --sample-ms: run the telemetry plane (sampler + SLO watchdogs +
    // flight recorder) over the job layer for the duration of the run.
    let obs = flags.get("sample-ms").and_then(|v| v.parse::<u64>().ok()).map(|ms| {
        let o = adcloud::obs::Observability::start(
            metrics.clone(),
            adcloud::obs::ObsConfig {
                sampler: adcloud::obs::SamplerConfig {
                    period: std::time::Duration::from_millis(ms.max(1)),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let steals_ctx = ctx.clone();
        o.add_probe("dce.executor.steals", adcloud::obs::ProbeKind::Counter, move || {
            steals_ctx.executor_steals() as f64
        });
        o.add_probe("trace.ring_dropped", adcloud::obs::ProbeKind::Counter, || {
            adcloud::trace::tracer().dropped_events() as f64
        });
        adcloud::obs::install(&o);
        o
    });
    let server = match (&obs, flags.get("serve")) {
        (Some(o), Some(addr)) => {
            let s = adcloud::runtime::ObsServer::serve(addr, o.clone())?;
            println!("obs: serving /metrics and /healthz on http://{}", s.addr());
            Some(s)
        }
        (None, Some(_)) => {
            eprintln!("--serve requires --sample-ms; not starting the HTTP endpoint");
            None
        }
        _ => None,
    };
    println!(
        "unified job layer: {} nodes x {} cores; queues sim/fleet guaranteed 0.5 each, \
         ceilings {}, preemption {}",
        cfg.cluster.nodes,
        cfg.cluster.cores_per_node,
        if preempt { "1.0 (elastic)" } else { "0.5 (hard)" },
        if preempt { "on" } else { "off" },
    );

    // Fleet side: simulated vehicles upload through the gateway into
    // the partitioned log the compaction job will drain.
    let log = ingest::PartitionedLog::temp(
        "jobs-cli",
        ingest::LogConfig { partitions: cfg.cluster.nodes.max(2), ..Default::default() },
    )?;
    let gw = ingest::IngestGateway::new(
        log.clone(),
        ingest::GatewayConfig::default(),
        metrics.clone(),
    );
    let fleet = ingest::simulate_fleet(&gw, &ingest::FleetConfig::new(vehicles, ticks, cfg.seed))?;
    println!("{}", fleet.render());

    // Sim side: a procedurally generated campaign. Under --preempt it
    // asks for the whole cluster so it visibly balloons over-share.
    let specs = scenario::generate_campaign_sized(cfg.seed, scenarios, 16);
    let campaign_nodes = if preempt {
        cfg.cluster.total_cores()
    } else {
        cfg.cluster.nodes
    };
    let mut ccfg = scenario::CampaignConfig::new("jobs-campaign", campaign_nodes);
    ccfg.opts.queue = "sim".into();
    let mut kcfg = ingest::CompactorConfig::new("jobs-compact", cfg.cluster.nodes);
    kcfg.opts.queue = "fleet".into();

    let stagger = if preempt {
        std::time::Duration::from_millis(30)
    } else {
        std::time::Duration::ZERO
    };
    let run =
        experiments::run_tenant_pair(&ctx, &rm, &specs, &ccfg, &log, ctx.store(), &kcfg, stagger)?;
    println!("{}", run.campaign.render());
    println!("{}", run.compaction.render());
    println!(
        "both tenants done in {} (campaign {}, compaction {})",
        adcloud::util::fmt_duration(run.makespan),
        adcloud::util::fmt_duration(run.campaign_elapsed),
        adcloud::util::fmt_duration(run.compaction_elapsed),
    );
    if preempt {
        println!(
            "preemption: {} container(s) flagged, {} shard requeue(s), 0 scenarios re-scored \
             (checkpoint/resume)",
            metrics.counter("resource.preemptions").get(),
            metrics.counter("platform.job.preemptions").get(),
        );
    }
    if let Some(secs) = flags.get("ckpt-gc-secs").and_then(|v| v.parse::<u64>().ok()) {
        // Both jobs succeeded and cleared their own checkpoints; what
        // the sweep reclaims is orphans from failed, never-resubmitted
        // jobs (here: anything a previous crashed run left behind).
        let reclaimed = adcloud::platform::ShardCheckpoint::sweep(
            ctx.store(),
            std::time::Duration::from_secs(secs),
        )?;
        println!("checkpoint GC: reclaimed {reclaimed} orphaned blob(s) older than {secs}s");
    }
    if let Some(server) = &server {
        // Self-scrape once so a plain CLI run demonstrates both
        // endpoints without needing curl in the loop.
        for path in ["/metrics", "/healthz"] {
            match scrape(&server.addr(), path) {
                Ok(body) => {
                    let head: Vec<&str> = body.lines().take(6).collect();
                    println!("GET {path} ->\n{}", head.join("\n"));
                }
                Err(e) => eprintln!("self-scrape of {path} failed: {e:#}"),
            }
        }
    }
    drop(server);
    if let Some(o) = &obs {
        if let Some(path) = flags.get("force-postmortem") {
            o.write_bundle("forced by --force-postmortem", path)?;
            println!("flight-recorder bundle written to {path}");
        }
        let health = o.health_json();
        println!(
            "obs: health {}, {} post-mortem bundle(s) captured",
            health.req("status")?.as_str()?,
            o.bundles_captured(),
        );
        adcloud::obs::uninstall();
        o.stop();
    }
    println!("job-layer metrics:\n{}", metrics.report());
    Ok(())
}

/// `adcloud serve` — the latency-SLO serving plane: deadline-carrying
/// offload requests admitted (or rejected on arrival), dispatched EDF
/// from the `interactive` priority queue via the unified job layer,
/// with speculative local-model fallback when slack runs out.
/// `--quick` runs the CI self-test; `--baseline` is E21's FIFO /
/// no-speculation arm.
fn run_serve(flags: &HashMap<String, String>) -> Result<()> {
    use adcloud::serve::{self, ServeConfig, ServePlane};
    if flags.contains_key("quick") {
        println!("{}", serve::self_test()?);
        return Ok(());
    }
    let mut cfg = ServeConfig {
        nodes: flag(flags, "nodes", 2usize),
        workers_per_node: flag(flags, "workers", 2usize),
        requests: flag(flags, "requests", 2_000usize),
        mean_service_us: flag(flags, "service-us", 400u64),
        deadline_us: flag(flags, "deadline-us", 2_400u64),
        local_service_us: flag(flags, "local-us", 80u64),
        seed: flag(flags, "seed", 7u64),
        ..ServeConfig::default()
    }
    .at_load(flag(flags, "load", 0.8f64));
    if flags.contains_key("baseline") {
        cfg = cfg.baseline();
    }
    let cluster = adcloud::config::ClusterConfig {
        nodes: cfg.nodes,
        cores_per_node: cfg.workers_per_node,
        gpus_per_node: 0,
        fpgas_per_node: 0,
        mem_per_node: 256 << 20,
    };
    let metrics = adcloud::metrics::MetricsRegistry::new();
    let rm = adcloud::resource::ResourceManager::with_priority_queues(
        &cluster,
        vec![("batch".into(), 0.5, 1.0, 0), ("interactive".into(), 0.5, 1.0, 1)],
        metrics.clone(),
    );
    // --sample-ms: telemetry plane with the serve SLO rules (tight
    // interactive grant-wait p99, rising-latency slope, absolute
    // latency p99) stacked on the builtin watchdog set.
    let obs = flags.get("sample-ms").and_then(|v| v.parse::<u64>().ok()).map(|ms| {
        let sustain = std::time::Duration::from_millis(500);
        let mut rules = adcloud::obs::builtin_rules(sustain);
        rules.extend(adcloud::obs::serve_rules(sustain));
        let o = adcloud::obs::Observability::start(
            metrics.clone(),
            adcloud::obs::ObsConfig {
                sampler: adcloud::obs::SamplerConfig {
                    period: std::time::Duration::from_millis(ms.max(1)),
                    ..Default::default()
                },
                rules,
                ..Default::default()
            },
        );
        adcloud::obs::install(&o);
        o
    });
    println!(
        "serving plane: {} nodes x {} workers, {} requests at {:.0} rps (capacity {:.0} \
         rps), deadline {} us, policy {:?}, speculation {}",
        cfg.nodes,
        cfg.workers_per_node,
        cfg.requests,
        cfg.offered_rps,
        cfg.capacity_rps(),
        cfg.deadline_us,
        cfg.policy,
        if cfg.speculation { "on" } else { "off" },
    );
    let report = ServePlane::run_on(&rm, &cfg)?;
    anyhow::ensure!(rm.live_containers() == 0, "serving plane leaked containers");
    println!("{}", report.render());
    if let Some(o) = &obs {
        let health = o.health_json();
        println!("obs: health {}", health.req("status")?.as_str()?);
        adcloud::obs::uninstall();
        o.stop();
    }
    Ok(())
}

/// One-shot HTTP GET against the in-process `ObsServer`.
fn scrape(addr: &std::net::SocketAddr, path: &str) -> Result<String> {
    use std::io::{Read, Write};
    let mut conn = std::net::TcpStream::connect(addr)?;
    write!(conn, "GET {path} HTTP/1.0\r\n\r\n")?;
    conn.flush()?;
    let mut buf = String::new();
    conn.read_to_string(&mut buf)?;
    Ok(buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
}

/// `adcloud top` — refreshing text dashboard over a demo workload.
fn run_top(flags: &HashMap<String, String>) -> Result<()> {
    use std::io::Write as _;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let once = flags.contains_key("once");
    let duration = std::time::Duration::from_secs(flag(flags, "duration-secs", 15u64));
    let refresh = std::time::Duration::from_millis(flag(flags, "refresh-ms", 500u64).max(50));
    let ctx = adcloud::dce::DceContext::new(config_from(flags))?;
    let obs = adcloud::obs::Observability::start(
        ctx.metrics().clone(),
        adcloud::obs::ObsConfig {
            sampler: adcloud::obs::SamplerConfig {
                period: std::time::Duration::from_millis(100),
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let steals_ctx = ctx.clone();
    obs.add_probe("dce.executor.steals", adcloud::obs::ProbeKind::Counter, move || {
        steals_ctx.executor_steals() as f64
    });
    obs.add_probe("trace.ring_dropped", adcloud::obs::ProbeKind::Counter, || {
        adcloud::trace::tracer().dropped_events() as f64
    });
    // A background demo workload so the dashboard has moving series:
    // small DCE jobs plus store churn.
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let ctx = ctx.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let _ = ctx.range(10_000, 16).map(|x| x.wrapping_mul(3)).count();
                let _ = ctx.store().put(&format!("top/{}", i % 256), vec![7u8; 32 << 10]);
                i += 1;
            }
        })
    };
    let t0 = std::time::Instant::now();
    loop {
        std::thread::sleep(refresh);
        let frame = obs.dashboard();
        if once {
            println!("{frame}");
            break;
        }
        // ANSI clear-screen + cursor-home, then the frame.
        print!("\x1b[2J\x1b[H{frame}");
        std::io::stdout().flush()?;
        if t0.elapsed() >= duration {
            println!();
            break;
        }
    }
    stop.store(true, Ordering::Relaxed);
    worker.join().expect("top demo workload thread panicked");
    obs.stop();
    Ok(())
}

/// `adcloud bench-diff` — compare fresh BENCH_*.json files against the
/// checked-in baselines; any throughput series more than 10% below its
/// baseline fails the command.
fn bench_diff(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    use adcloud::util::json::Json;
    let dir = flags
        .get("baseline-dir")
        .cloned()
        .unwrap_or_else(|| "bench/baseline".to_string());
    let update = flags.contains_key("update");
    let files: Vec<String> = if pos.is_empty() {
        vec![
            "BENCH_E14.json".into(),
            "BENCH_E17.json".into(),
            "BENCH_E18.json".into(),
            "BENCH_E19.json".into(),
            "BENCH_E21.json".into(),
            "BENCH_E22.json".into(),
        ]
    } else {
        pos.to_vec()
    };
    let mut regressions: Vec<String> = Vec::new();
    let mut compared = 0usize;
    for file in &files {
        let name = std::path::Path::new(file)
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| file.clone());
        let base_path = format!("{dir}/{name}");
        if !std::path::Path::new(file).is_file() {
            println!("bench-diff: {file} not found (run its experiment first); skipping");
            continue;
        }
        if update {
            std::fs::create_dir_all(&dir)?;
            std::fs::copy(file, &base_path)?;
            println!("bench-diff: baseline {base_path} updated from {file}");
            continue;
        }
        if !std::path::Path::new(&base_path).is_file() {
            println!("bench-diff: no baseline at {base_path}; skipping {file}");
            continue;
        }
        let base = Json::parse(&std::fs::read_to_string(&base_path)?)?;
        let fresh = Json::parse(&std::fs::read_to_string(file)?)?;
        let mut pairs: Vec<(String, f64, f64)> = Vec::new();
        walk_bench(&base, &fresh, &name, &mut pairs);
        if pairs.is_empty() {
            println!("bench-diff: no comparable *per_sec series in {file}");
        }
        for (series, b, f) in pairs {
            compared += 1;
            let delta_pct = (f / b.max(1e-9) - 1.0) * 100.0;
            let flagged = f < b * 0.9;
            println!(
                "  {} {series}: baseline {b:.0}/s, fresh {f:.0}/s ({delta_pct:+.1}%)",
                if flagged { "REGRESSION" } else { "ok " },
            );
            if flagged {
                regressions.push(series);
            }
        }
    }
    if update {
        return Ok(());
    }
    anyhow::ensure!(
        regressions.is_empty(),
        "bench-diff: {} series regressed >10%: {}",
        regressions.len(),
        regressions.join(", "),
    );
    println!("bench-diff: {compared} throughput series compared, none regressed >10%");
    Ok(())
}

/// Walk two bench JSON trees in lockstep, collecting every numeric key
/// whose name contains `per_sec` and exists in both.
fn walk_bench(
    base: &adcloud::util::json::Json,
    fresh: &adcloud::util::json::Json,
    at: &str,
    out: &mut Vec<(String, f64, f64)>,
) {
    use adcloud::util::json::Json;
    match (base, fresh) {
        (Json::Obj(bm), Json::Obj(fm)) => {
            for (k, bv) in bm {
                let Some(fv) = fm.get(k) else { continue };
                let here = format!("{at}.{k}");
                if k.contains("per_sec") {
                    if let (Ok(b), Ok(f)) = (bv.as_f64(), fv.as_f64()) {
                        out.push((here, b, f));
                        continue;
                    }
                }
                walk_bench(bv, fv, &here, out);
            }
        }
        (Json::Arr(ba), Json::Arr(fa)) => {
            for (i, (bv, fv)) in ba.iter().zip(fa.iter()).enumerate() {
                walk_bench(bv, fv, &format!("{at}[{i}]"), out);
            }
        }
        _ => {}
    }
}

fn train(flags: &HashMap<String, String>) -> Result<()> {
    let p = Platform::boot(config_from(flags))?;
    anyhow::ensure!(p.has_accelerators(), "train requires artifacts (make artifacts)");
    let examples = flag(flags, "examples", 1024usize);
    let rounds = flag(flags, "rounds", 50usize);
    let workers = flag(flags, "workers", 4usize);
    let data = training::gen_dataset(examples, p.config.seed);
    let shards = training::shard(data, workers);
    let trainer = training::DistTrainer::new(p.dispatcher.clone(), DeviceKind::Gpu, shards);
    let ps = training::ParamServer::tiered(p.ctx.store().clone(), "cli-train");
    let mut rng = adcloud::util::Rng::new(p.config.seed);
    let init = adcloud::hetero::cpu_impls::init_params(&mut rng);
    println!("training {examples} examples, {rounds} rounds on {workers} workers...");
    let report = trainer.train(&ps, init, rounds, 0.05)?;
    for r in report.rounds.iter().step_by((rounds / 10).max(1)) {
        println!("  round {:>4}  loss {:.4}", r.round, r.mean_loss);
    }
    println!(
        "loss {:.4} -> {:.4}; {:.0} examples/s",
        report.first_loss(),
        report.last_loss(),
        report.throughput
    );
    Ok(())
}

fn run_mapgen(flags: &HashMap<String, String>) -> Result<()> {
    let p = Platform::boot(config_from(flags))?;
    anyhow::ensure!(p.has_accelerators(), "mapgen requires artifacts (make artifacts)");
    let steps = flag(flags, "steps", 200usize);
    let world = mapgen::gen_world(p.config.seed);
    let log = mapgen::gen_drive(&world, steps, p.config.seed);
    let cfg = mapgen::SlamConfig::default();
    let opts = job_opts_from(flags, "mapgen-fused", 1);
    let report = mapgen::run_fused(&p.dispatcher, &p.resources, &log, &cfg, &opts, 0.1)?;
    println!(
        "map built from {steps} steps in {}: {} occupied cells, {} signs, slam err {:.2} m",
        adcloud::util::fmt_duration(report.elapsed),
        report.occupied_cells,
        report.signs,
        report.slam_err_m
    );
    Ok(())
}

fn run_sql(flags: &HashMap<String, String>) -> Result<()> {
    let p = Platform::boot(config_from(flags))?;
    let rows = flag(flags, "rows", 50_000usize);
    let data = sql::generate_telemetry(rows, 100, p.config.seed);
    let rdd = p.ctx.parallelize(data, 8).cache();
    let t = std::time::Instant::now();
    let q1 = sql::q1_dce(&rdd, 8)?;
    let q3 = sql::q3_dce(&rdd, 8)?;
    println!(
        "q1 -> {} rows, q3 -> {} rows in {}",
        q1.len(),
        q3.len(),
        adcloud::util::fmt_duration(t.elapsed())
    );
    Ok(())
}

fn repro_tables(ids: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let quick = flags.contains_key("quick");
    let ids: Vec<String> = if ids.is_empty() || ids.iter().any(|i| i == "all") {
        experiments::ALL_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        ids.to_vec()
    };
    let vehicles = flags.get("vehicles").and_then(|v| v.parse::<u32>().ok());
    let mut failed = Vec::new();
    for id in ids {
        let run = match (id.as_str(), vehicles) {
            ("e20", Some(v)) => experiments::e20_fleet_sized(v, quick),
            _ => experiments::run_experiment(&id, quick),
        };
        match run {
            Ok(table) => println!("{}", table.render()),
            Err(e) => {
                eprintln!("{id} failed: {e:#}");
                failed.push(id);
            }
        }
    }
    // A failing experiment fails the command, so CI smoke runs gate on
    // the tables actually reproducing.
    anyhow::ensure!(failed.is_empty(), "experiment(s) failed: {}", failed.join(", "));
    Ok(())
}

fn pipe_worker(logic: Option<&str>) -> Result<()> {
    match logic {
        Some("detect") => simulation::pipe_worker_detect(),
        other => anyhow::bail!("unknown pipe-worker logic {other:?} (have: detect)"),
    }
}
