//! Critical-path attribution over a finished job's span DAG.
//!
//! Walks a root span's children as time intervals and charges every
//! microsecond of `[root.start, root.end]` to exactly one category:
//! at each instant the deepest overlapping descendant (ties broken
//! toward the one reaching furthest) owns the time; gaps no child
//! covers are charged to the enclosing span's own category. The
//! attribution therefore *partitions* the makespan — category totals
//! sum to the root duration by construction, which is what lets E18
//! assert the sum lands within 1% of the measured job makespan.

use std::collections::HashMap;

use super::{Category, SpanEvent};
use crate::util::json::Json;

/// Per-category makespan attribution for one trace.
#[derive(Debug, Clone, Default)]
pub struct CriticalPath {
    /// Root span duration (equals the sum of `by_category`).
    pub total_us: u64,
    pub by_category: [u64; Category::COUNT],
}

impl CriticalPath {
    pub fn category_us(&self, cat: Category) -> u64 {
        self.by_category[cat.idx()]
    }

    /// Fraction of the makespan charged to `cat`, in [0, 1].
    pub fn category_frac(&self, cat: Category) -> f64 {
        if self.total_us == 0 {
            return 0.0;
        }
        self.category_us(cat) as f64 / self.total_us as f64
    }

    pub fn sum_us(&self) -> u64 {
        self.by_category.iter().sum()
    }

    /// One-line human rendering, dominant categories first; zero
    /// categories are elided.
    pub fn render(&self) -> String {
        let mut parts: Vec<(Category, u64)> = Category::ALL
            .iter()
            .map(|&c| (c, self.category_us(c)))
            .filter(|&(_, us)| us > 0)
            .collect();
        parts.sort_by_key(|&(_, us)| std::cmp::Reverse(us));
        let body = parts
            .iter()
            .map(|&(c, us)| format!("{} {:.1}%", c.label(), 100.0 * self.category_frac(c)))
            .collect::<Vec<_>>()
            .join(", ");
        let total = std::time::Duration::from_micros(self.total_us);
        format!("critical path ({}): {}", crate::util::fmt_duration(total), body)
    }

    pub fn to_json(&self) -> Json {
        let mut cats = Vec::new();
        for c in Category::ALL {
            cats.push((c.label(), Json::num(self.category_us(c) as f64)));
        }
        Json::obj(vec![
            ("total_us", Json::num(self.total_us as f64)),
            ("by_category_us", Json::obj(cats)),
        ])
    }

    /// Merge another trace's attribution into this one (E18 reports
    /// one aggregate row over several concurrent jobs).
    pub fn merge(&mut self, other: &CriticalPath) {
        self.total_us += other.total_us;
        for i in 0..Category::COUNT {
            self.by_category[i] += other.by_category[i];
        }
    }
}

/// Attribute the trace that `root_span_id` heads. Returns `None` when
/// the root span is missing from `spans`.
pub fn analyze(spans: &[SpanEvent], root_span_id: u64) -> Option<CriticalPath> {
    let root_idx = spans.iter().position(|e| e.span_id == root_span_id)?;
    let mut children: HashMap<u64, Vec<usize>> = HashMap::new();
    for (i, e) in spans.iter().enumerate() {
        if e.span_id != root_span_id {
            children.entry(e.parent_id).or_default().push(i);
        }
    }
    let root = &spans[root_idx];
    let mut cp = CriticalPath { total_us: root.duration_us(), ..Default::default() };
    attribute(spans, &children, root_idx, root.start_us, root.end_us, &mut cp.by_category);
    Some(cp)
}

/// Attribute every root (parent-less) span of `trace_id`, merged.
pub fn analyze_trace(spans: &[SpanEvent], trace_id: u64) -> CriticalPath {
    let mut cp = CriticalPath::default();
    for e in spans {
        if e.trace_id == trace_id && e.parent_id == 0 {
            if let Some(one) = analyze(spans, e.span_id) {
                cp.merge(&one);
            }
        }
    }
    cp
}

/// Interval sweep over `[lo, hi)` of span `idx`: recurse into the
/// overlapping child that reaches furthest; charge uncovered gaps to
/// the span's own category. Every microsecond of `[lo, hi)` is
/// charged exactly once, so the recursion partitions the interval.
fn attribute(
    spans: &[SpanEvent],
    children: &HashMap<u64, Vec<usize>>,
    idx: usize,
    lo: u64,
    hi: u64,
    acc: &mut [u64; Category::COUNT],
) {
    let kids: &[usize] = children
        .get(&spans[idx].span_id)
        .map(|v| v.as_slice())
        .unwrap_or(&[]);
    let mut t = lo;
    while t < hi {
        let mut best: Option<usize> = None;
        let mut next_start = hi;
        for &k in kids {
            let s = &spans[k];
            if s.start_us <= t && s.end_us > t {
                if best.map_or(true, |b| spans[b].end_us < s.end_us) {
                    best = Some(k);
                }
            } else if s.start_us > t && s.start_us < next_start {
                next_start = s.start_us;
            }
        }
        match best {
            Some(k) => {
                let end = spans[k].end_us.min(hi);
                attribute(spans, children, k, t, end, acc);
                t = end;
            }
            None => {
                acc[spans[idx].cat.idx()] += next_start - t;
                t = next_start;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        id: u64,
        parent: u64,
        name: &'static str,
        cat: Category,
        start: u64,
        end: u64,
    ) -> SpanEvent {
        SpanEvent {
            trace_id: 1,
            span_id: id,
            parent_id: parent,
            name,
            cat,
            start_us: start,
            end_us: end,
            tid: 0,
            args: [("", 0); 3],
            nargs: 0,
        }
    }

    #[test]
    fn gaps_go_to_the_parent_category() {
        // job [0, 100): grant-wait child [10, 30), compute child
        // [30, 80); the [0,10) and [80,100) gaps are the job's own.
        let spans = vec![
            ev(1, 0, "job", Category::Other, 0, 100),
            ev(2, 1, "grant", Category::GrantWait, 10, 30),
            ev(3, 1, "work", Category::Compute, 30, 80),
        ];
        let cp = analyze(&spans, 1).unwrap();
        assert_eq!(cp.total_us, 100);
        assert_eq!(cp.category_us(Category::GrantWait), 20);
        assert_eq!(cp.category_us(Category::Compute), 50);
        assert_eq!(cp.category_us(Category::Other), 30);
        assert_eq!(cp.sum_us(), cp.total_us);
    }

    #[test]
    fn overlapping_children_pick_the_furthest_reaching() {
        // Two concurrent shards [0,60) and [20,100) under a job of
        // [0,100): the sweep follows shard A to 60 then shard B to
        // 100 — full coverage, no double counting.
        let spans = vec![
            ev(1, 0, "job", Category::Other, 0, 100),
            ev(2, 1, "shard-a", Category::Compute, 0, 60),
            ev(3, 1, "shard-b", Category::Compute, 20, 100),
            // store I/O inside shard B while it owns [60, 100).
            ev(4, 3, "put", Category::StoreIo, 70, 90),
        ];
        let cp = analyze(&spans, 1).unwrap();
        assert_eq!(cp.total_us, 100);
        assert_eq!(cp.sum_us(), 100);
        assert_eq!(cp.category_us(Category::StoreIo), 20);
        assert_eq!(cp.category_us(Category::Compute), 80);
    }

    #[test]
    fn nested_attribution_partitions_the_makespan() {
        let spans = vec![
            ev(1, 0, "job", Category::Other, 0, 1000),
            ev(2, 1, "grant", Category::GrantWait, 0, 200),
            ev(3, 1, "shard", Category::Compute, 200, 950),
            ev(4, 3, "requeue", Category::PreemptRequeue, 300, 400),
            ev(5, 3, "ckpt", Category::CheckpointReplay, 400, 450),
            ev(6, 3, "log", Category::LogIo, 450, 500),
            ev(7, 3, "shuffle", Category::Shuffle, 600, 900),
        ];
        let cp = analyze(&spans, 1).unwrap();
        assert_eq!(cp.sum_us(), cp.total_us);
        assert_eq!(cp.category_us(Category::GrantWait), 200);
        assert_eq!(cp.category_us(Category::PreemptRequeue), 100);
        assert_eq!(cp.category_us(Category::CheckpointReplay), 50);
        assert_eq!(cp.category_us(Category::LogIo), 50);
        assert_eq!(cp.category_us(Category::Shuffle), 300);
        // shard's own slices: [200,300) + [500,600) + [900,950).
        assert_eq!(cp.category_us(Category::Compute), 250);
        // job's own slice: [950, 1000).
        assert_eq!(cp.category_us(Category::Other), 50);
    }

    #[test]
    fn children_poking_outside_the_parent_are_clamped() {
        let spans = vec![
            ev(1, 0, "job", Category::Other, 100, 200),
            ev(2, 1, "early", Category::Compute, 50, 150),
            ev(3, 1, "late", Category::StoreIo, 150, 400),
        ];
        let cp = analyze(&spans, 1).unwrap();
        assert_eq!(cp.total_us, 100);
        assert_eq!(cp.sum_us(), 100);
        assert_eq!(cp.category_us(Category::Compute), 50);
        assert_eq!(cp.category_us(Category::StoreIo), 50);
    }

    #[test]
    fn render_orders_dominant_categories_first() {
        let spans = vec![
            ev(1, 0, "job", Category::Other, 0, 100),
            ev(2, 1, "w", Category::GrantWait, 0, 80),
            ev(3, 1, "c", Category::Compute, 80, 90),
        ];
        let cp = analyze(&spans, 1).unwrap();
        let r = cp.render();
        let gw = r.find("grant-wait").unwrap();
        let comp = r.find("compute").unwrap();
        assert!(gw < comp, "dominant category first: {r}");
        assert!(r.contains("grant-wait 80.0%"), "{r}");
    }

    #[test]
    fn json_carries_all_categories() {
        let spans = vec![ev(1, 0, "job", Category::Other, 0, 10)];
        let cp = analyze(&spans, 1).unwrap();
        let j = cp.to_json();
        assert_eq!(j.req("total_us").unwrap().as_u64().unwrap(), 10);
        let cats = j.req("by_category_us").unwrap().as_obj().unwrap();
        assert_eq!(cats.len(), Category::COUNT);
        assert_eq!(cats["other"].as_u64().unwrap(), 10);
    }

    #[test]
    fn analyze_trace_merges_concurrent_roots() {
        let mut spans = vec![
            ev(1, 0, "job-a", Category::Other, 0, 100),
            ev(2, 1, "w", Category::Compute, 0, 100),
        ];
        let mut b = ev(3, 0, "job-b", Category::Other, 0, 50);
        b.trace_id = 1;
        spans.push(b);
        let cp = analyze_trace(&spans, 1);
        assert_eq!(cp.total_us, 150);
        assert_eq!(cp.category_us(Category::Compute), 100);
        assert_eq!(cp.category_us(Category::Other), 50);
    }
}
