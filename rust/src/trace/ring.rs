//! Bounded lock-free SPSC ring buffer for completed span events.
//!
//! One ring per recording thread: the owning thread is the only
//! producer (span guards record on drop), the collector is the only
//! consumer (drains are serialized by the tracer's registry lock).
//! The producer path is wait-free — one sequence load, one slot write,
//! two relaxed stores — so tracing never blocks a worker. When the
//! ring is full the event is dropped and counted rather than stalling
//! the hot path.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, Ordering};

use super::SpanEvent;

/// Slots per ring. Power of two; at ~150 B per event this is ~600 KiB
/// per recording thread, reclaimed when the thread exits.
pub const RING_CAP: usize = 4096;

struct Slot {
    /// Vyukov sequence: `pos` when empty and writable, `pos + 1` when
    /// full and readable, `pos + cap` after the consumer frees it.
    seq: AtomicU64,
    val: UnsafeCell<MaybeUninit<SpanEvent>>,
}

/// Single-producer single-consumer bounded queue of span events.
pub struct Ring {
    slots: Box<[Slot]>,
    mask: u64,
    /// Next slot the producer writes. Only the owning thread stores.
    tail: AtomicU64,
    /// Next slot the consumer reads. Only the collector stores.
    head: AtomicU64,
    dropped: AtomicU64,
}

// SAFETY: slot payloads are published/claimed through the per-slot
// `seq` acquire/release pair, so the producer and consumer never
// touch the same `UnsafeCell` concurrently.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    pub fn new() -> Self {
        let slots: Vec<Slot> = (0..RING_CAP)
            .map(|i| Slot {
                seq: AtomicU64::new(i as u64),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        Self {
            slots: slots.into_boxed_slice(),
            mask: RING_CAP as u64 - 1,
            tail: AtomicU64::new(0),
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Producer side: record one completed span. Returns false (and
    /// counts a drop) if the consumer has fallen `RING_CAP` behind.
    pub fn push(&self, ev: SpanEvent) -> bool {
        let tail = self.tail.load(Ordering::Relaxed);
        let slot = &self.slots[(tail & self.mask) as usize];
        if slot.seq.load(Ordering::Acquire) != tail {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // SAFETY: seq == tail means this slot is empty and reserved
        // for this producer position; the consumer won't read it until
        // the release store below publishes it.
        unsafe { (*slot.val.get()).write(ev) };
        slot.seq.store(tail + 1, Ordering::Release);
        self.tail.store(tail + 1, Ordering::Relaxed);
        true
    }

    /// Consumer side: move every published event into `out`.
    pub fn drain(&self, out: &mut Vec<SpanEvent>) {
        loop {
            let head = self.head.load(Ordering::Relaxed);
            let slot = &self.slots[(head & self.mask) as usize];
            if slot.seq.load(Ordering::Acquire) != head + 1 {
                return;
            }
            // SAFETY: seq == head + 1 means the producer published
            // this slot and won't rewrite it until we bump seq past
            // the next lap below.
            let ev = unsafe { (*slot.val.get()).assume_init_read() };
            slot.seq.store(head + self.mask + 1, Ordering::Release);
            self.head.store(head + 1, Ordering::Relaxed);
            out.push(ev);
        }
    }

    /// Events discarded because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Relaxed) == self.tail.load(Ordering::Relaxed)
    }
}

impl Default for Ring {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Category;

    fn ev(id: u64) -> SpanEvent {
        SpanEvent {
            trace_id: 1,
            span_id: id,
            parent_id: 0,
            name: "t",
            cat: Category::Other,
            start_us: id,
            end_us: id + 1,
            tid: 0,
            args: [("", 0); 3],
            nargs: 0,
        }
    }

    #[test]
    fn ring_roundtrips_in_order() {
        let r = Ring::new();
        for i in 0..100 {
            assert!(r.push(ev(i)));
        }
        let mut out = Vec::new();
        r.drain(&mut out);
        assert_eq!(out.len(), 100);
        assert!(out.iter().enumerate().all(|(i, e)| e.span_id == i as u64));
        assert!(r.is_empty());
    }

    #[test]
    fn ring_drops_when_full_and_recovers_after_drain() {
        let r = Ring::new();
        for i in 0..RING_CAP as u64 {
            assert!(r.push(ev(i)));
        }
        assert!(!r.push(ev(9999)));
        assert_eq!(r.dropped(), 1);
        let mut out = Vec::new();
        r.drain(&mut out);
        assert_eq!(out.len(), RING_CAP);
        assert!(r.push(ev(10000)));
        out.clear();
        r.drain(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].span_id, 10000);
    }

    #[test]
    fn ring_wraps_across_many_laps() {
        let r = Ring::new();
        let mut out = Vec::new();
        for lap in 0..5u64 {
            for i in 0..RING_CAP as u64 {
                assert!(r.push(ev(lap * RING_CAP as u64 + i)));
            }
            r.drain(&mut out);
        }
        assert_eq!(out.len(), 5 * RING_CAP);
        assert!(out.iter().enumerate().all(|(i, e)| e.span_id == i as u64));
    }

    #[test]
    fn concurrent_producer_consumer_loses_nothing_when_paced() {
        let r = std::sync::Arc::new(Ring::new());
        let n = 20_000u64;
        let rc = r.clone();
        let consumer = std::thread::spawn(move || {
            let mut out = Vec::new();
            while out.len() < n as usize {
                rc.drain(&mut out);
                std::thread::yield_now();
            }
            out
        });
        for i in 0..n {
            while !r.push(ev(i)) {
                std::thread::yield_now();
            }
        }
        let out = consumer.join().unwrap();
        assert_eq!(out.len(), n as usize);
        assert!(out.iter().enumerate().all(|(i, e)| e.span_id == i as u64));
    }
}
