//! Chrome-trace-event export and re-import.
//!
//! [`write_chrome_trace`] emits the `traceEvents` JSON understood by
//! Perfetto and `chrome://tracing` (complete `"ph": "X"` events, one
//! per span). The span/trace/parent ids ride along in each event's
//! `args`, so [`load_chrome_trace`] can parse a file back into
//! [`LoadedSpan`]s and [`render_tree`] can pretty-print the causal
//! span tree — that is what the `trace` CLI subcommand does.

use anyhow::{Context, Result};

use super::SpanEvent;
use crate::util::json::Json;

/// Serialize spans into a Chrome trace-event document.
pub fn to_chrome_json(spans: &[SpanEvent]) -> Json {
    let events = spans
        .iter()
        .map(|e| {
            let mut args = vec![
                ("trace_id", Json::num(e.trace_id as f64)),
                ("span_id", Json::num(e.span_id as f64)),
                ("parent_id", Json::num(e.parent_id as f64)),
            ];
            for (k, v) in e.args() {
                args.push((k, Json::num(*v as f64)));
            }
            Json::obj(vec![
                ("name", Json::str(e.name)),
                ("cat", Json::str(e.cat.label())),
                ("ph", Json::str("X")),
                ("ts", Json::num(e.start_us as f64)),
                ("dur", Json::num(e.duration_us() as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(e.tid as f64)),
                ("args", Json::obj(args)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Write spans to `path` as Chrome trace-event JSON.
pub fn write_chrome_trace(path: &str, spans: &[SpanEvent]) -> Result<()> {
    std::fs::write(path, to_chrome_json(spans).to_string_pretty())
        .with_context(|| format!("writing trace to {path}"))
}

/// One span parsed back from an exported trace file.
#[derive(Debug, Clone)]
pub struct LoadedSpan {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent_id: u64,
    pub name: String,
    pub cat: String,
    pub start_us: u64,
    pub dur_us: u64,
    pub tid: u64,
    /// Annotations other than the id triple.
    pub kv: Vec<(String, u64)>,
}

/// Parse a Chrome trace-event file written by [`write_chrome_trace`].
pub fn load_chrome_trace(path: &str) -> Result<Vec<LoadedSpan>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace file {path}"))?;
    let doc = Json::parse(&text).with_context(|| format!("parsing trace file {path}"))?;
    let mut out = Vec::new();
    for ev in doc.req("traceEvents")?.as_arr()? {
        if ev.get("ph").and_then(|p| p.as_str().ok()) != Some("X") {
            continue;
        }
        let args = ev.req("args")?;
        let mut kv = Vec::new();
        for (k, v) in args.as_obj()? {
            if matches!(k.as_str(), "trace_id" | "span_id" | "parent_id") {
                continue;
            }
            if let Ok(n) = v.as_u64() {
                kv.push((k.clone(), n));
            }
        }
        out.push(LoadedSpan {
            trace_id: args.req("trace_id")?.as_u64()?,
            span_id: args.req("span_id")?.as_u64()?,
            parent_id: args.req("parent_id")?.as_u64()?,
            name: ev.req("name")?.as_str()?.to_string(),
            cat: ev.req("cat")?.as_str()?.to_string(),
            start_us: ev.req("ts")?.as_u64()?,
            dur_us: ev.req("dur")?.as_u64()?,
            tid: ev.req("tid")?.as_u64()?,
            kv,
        });
    }
    Ok(out)
}

/// Pretty-print loaded spans as indented per-trace span trees,
/// children sorted by start time.
pub fn render_tree(spans: &[LoadedSpan]) -> String {
    let mut order: Vec<usize> = (0..spans.len()).collect();
    order.sort_by_key(|&i| (spans[i].trace_id, spans[i].start_us, spans[i].span_id));
    let ids: std::collections::HashSet<u64> = spans.iter().map(|s| s.span_id).collect();
    let mut children: std::collections::HashMap<u64, Vec<usize>> =
        std::collections::HashMap::new();
    let mut roots: Vec<usize> = Vec::new();
    for &i in &order {
        let s = &spans[i];
        // A span whose parent is missing from the file (e.g. the file
        // was exported mid-run) renders as a root rather than vanish.
        if s.parent_id != 0 && ids.contains(&s.parent_id) {
            children.entry(s.parent_id).or_default().push(i);
        } else {
            roots.push(i);
        }
    }
    let mut out = String::new();
    let mut last_trace = None;
    for &r in &roots {
        if last_trace != Some(spans[r].trace_id) {
            last_trace = Some(spans[r].trace_id);
            out.push_str(&format!("trace {}\n", spans[r].trace_id));
        }
        render_node(spans, &children, r, 1, &mut out);
    }
    out
}

fn render_node(
    spans: &[LoadedSpan],
    children: &std::collections::HashMap<u64, Vec<usize>>,
    idx: usize,
    depth: usize,
    out: &mut String,
) {
    let s = &spans[idx];
    let kv = s
        .kv
        .iter()
        .map(|(k, v)| format!(" {k}={v}"))
        .collect::<String>();
    out.push_str(&format!(
        "{}{} [{}] {} @{}us{}\n",
        "  ".repeat(depth),
        s.name,
        s.cat,
        crate::util::fmt_duration(std::time::Duration::from_micros(s.dur_us)),
        s.start_us,
        kv,
    ));
    if let Some(kids) = children.get(&s.span_id) {
        for &k in kids {
            render_node(spans, children, k, depth + 1, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Category, SpanEvent};

    fn ev(trace: u64, id: u64, parent: u64, name: &'static str, start: u64) -> SpanEvent {
        SpanEvent {
            trace_id: trace,
            span_id: id,
            parent_id: parent,
            name,
            cat: Category::Compute,
            start_us: start,
            end_us: start + 100,
            tid: 1,
            args: [("shard", 2), ("", 0), ("", 0)],
            nargs: 1,
        }
    }

    #[test]
    fn chrome_export_roundtrips_through_a_file() {
        let dir = std::env::temp_dir().join("adcloud-trace-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.json");
        let path = path.to_str().unwrap();
        let spans =
            vec![ev(5, 1, 0, "job", 0), ev(5, 2, 1, "shard", 10), ev(5, 3, 2, "task", 20)];
        write_chrome_trace(path, &spans).unwrap();
        let loaded = load_chrome_trace(path).unwrap();
        assert_eq!(loaded.len(), 3);
        let shard = loaded.iter().find(|s| s.name == "shard").unwrap();
        assert_eq!(shard.trace_id, 5);
        assert_eq!(shard.parent_id, 1);
        assert_eq!(shard.dur_us, 100);
        assert_eq!(shard.kv, vec![("shard".to_string(), 2)]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn tree_renders_nested_and_orphaned_spans() {
        let spans = vec![
            ev(5, 1, 0, "job", 0),
            ev(5, 2, 1, "shard", 10),
            ev(5, 3, 99, "lost", 20),
        ];
        let loaded: Vec<LoadedSpan> = spans
            .iter()
            .map(|e| LoadedSpan {
                trace_id: e.trace_id,
                span_id: e.span_id,
                parent_id: e.parent_id,
                name: e.name.to_string(),
                cat: e.cat.label().to_string(),
                start_us: e.start_us,
                dur_us: e.duration_us(),
                tid: e.tid,
                kv: vec![],
            })
            .collect();
        let tree = render_tree(&loaded);
        assert!(tree.contains("trace 5"));
        assert!(tree.contains("  job [compute]"));
        assert!(tree.contains("    shard [compute]"));
        // span 3's parent is missing: still rendered, as a root.
        assert!(tree.contains("  lost [compute]"));
    }
}
