//! Causal tracing across the job, compute, and storage planes.
//!
//! Every unit of platform work — gang grant waits, shard attempts,
//! DCE tasks, store puts/gets/evictions, log appends, compaction
//! block lands — can open a [`SpanGuard`]. Spans carry
//! `(trace_id, span_id, parent_id, name, kv-annotations)`; parent
//! links are threaded two ways:
//!
//! - **explicitly**, as a [`SpanCtx`] carried by the context structs
//!   that already cross thread boundaries (`JobHandle` → `ShardCtx` /
//!   `ContainerCtx` → DCE tasks), and
//! - **implicitly**, through a per-thread current-span stack that
//!   guards push on creation and pop on drop, so leaf libraries (the
//!   tiered store, the partitioned log) parent their spans without
//!   new function parameters.
//!
//! Completed spans are recorded — on guard *drop*, so a panicking
//! shard still closes its spans during unwind — into per-thread
//! lock-free rings ([`ring::Ring`]) that [`Tracer::collect`] drains.
//! When the tracer is disabled (the default) opening a span is one
//! relaxed atomic load and no allocation; E18 enforces <5% overhead
//! on the E17 store benchmark even with tracing *on*.
//!
//! Downstream consumers: [`export`] writes Chrome-trace-event JSON
//! (Perfetto / `chrome://tracing` loadable, `--trace <out.json>` on
//! every CLI subcommand), [`critical_path`] attributes a finished
//! job's makespan to wait/compute/I-O categories.

pub mod critical_path;
pub mod export;
pub mod ring;

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Span identity propagated across threads: which trace, which span.
/// `Copy` so context structs can carry it for free; the all-zero
/// [`SpanCtx::NONE`] means "not tracing".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SpanCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

impl SpanCtx {
    pub const NONE: SpanCtx = SpanCtx { trace_id: 0, span_id: 0 };

    pub fn is_none(&self) -> bool {
        self.trace_id == 0
    }
}

/// Where a span's time is charged by the critical-path analyzer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Category {
    GrantWait = 0,
    PreemptRequeue = 1,
    CheckpointReplay = 2,
    Compute = 3,
    Shuffle = 4,
    StoreIo = 5,
    LogIo = 6,
    Other = 7,
}

impl Category {
    pub const COUNT: usize = 8;
    pub const ALL: [Category; Category::COUNT] = [
        Category::GrantWait,
        Category::PreemptRequeue,
        Category::CheckpointReplay,
        Category::Compute,
        Category::Shuffle,
        Category::StoreIo,
        Category::LogIo,
        Category::Other,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Category::GrantWait => "grant-wait",
            Category::PreemptRequeue => "preempt-requeue",
            Category::CheckpointReplay => "checkpoint-replay",
            Category::Compute => "compute",
            Category::Shuffle => "shuffle",
            Category::StoreIo => "store-io",
            Category::LogIo => "log-io",
            Category::Other => "other",
        }
    }

    pub fn idx(self) -> usize {
        self as usize
    }
}

/// Maximum numeric annotations per span. Fixed so events stay `Copy`
/// and ring slots stay allocation-free.
pub const MAX_ARGS: usize = 3;

/// One completed span as recorded into a ring. Names are `&'static
/// str` by design: dynamic data goes in the numeric `args`, keeping
/// the hot path free of formatting and heap traffic.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent_id: u64,
    pub name: &'static str,
    pub cat: Category,
    pub start_us: u64,
    pub end_us: u64,
    pub tid: u64,
    pub args: [(&'static str, u64); MAX_ARGS],
    pub nargs: u8,
}

impl SpanEvent {
    pub fn duration_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }

    pub fn args(&self) -> &[(&'static str, u64)] {
        &self.args[..self.nargs as usize]
    }
}

thread_local! {
    /// Innermost open span on this thread (implicit parent).
    static CURRENT: Cell<SpanCtx> = const { Cell::new(SpanCtx::NONE) };
    /// This thread's ring + collector-visible id, created on first
    /// record so untraced threads never allocate one.
    static LOCAL: (Arc<ring::Ring>, u64) = {
        let r = Arc::new(ring::Ring::new());
        let tid = tracer().register(r.clone());
        (r, tid)
    };
}

/// Process-wide tracer: the enable flag, id allocator, ring registry,
/// and the archive that `collect()` drains rings into.
pub struct Tracer {
    enabled: AtomicBool,
    next_id: AtomicU64,
    /// Guards created minus events recorded — a nonzero steady-state
    /// value means some code path leaked an open span.
    open: AtomicU64,
    epoch: OnceLock<Instant>,
    rings: Mutex<Vec<Arc<ring::Ring>>>,
    archive: Mutex<Vec<SpanEvent>>,
}

static TRACER: OnceLock<Tracer> = OnceLock::new();

/// The process-wide tracer.
pub fn tracer() -> &'static Tracer {
    TRACER.get_or_init(|| Tracer {
        enabled: AtomicBool::new(false),
        next_id: AtomicU64::new(1),
        open: AtomicU64::new(0),
        epoch: OnceLock::new(),
        rings: Mutex::new(Vec::new()),
        archive: Mutex::new(Vec::new()),
    })
}

impl Tracer {
    pub fn enable(&self) {
        self.epoch.get_or_init(Instant::now);
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    /// The only check on the disabled hot path: one relaxed load.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Microseconds since the tracer was first enabled.
    pub fn now_us(&self) -> u64 {
        self.epoch.get_or_init(Instant::now).elapsed().as_micros() as u64
    }

    fn next_span_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn register(&self, r: Arc<ring::Ring>) -> u64 {
        let mut rings = self.rings.lock().unwrap();
        rings.push(r);
        rings.len() as u64
    }

    /// Guards currently open (created but not yet recorded).
    pub fn open_spans(&self) -> u64 {
        self.open.load(Ordering::Relaxed)
    }

    /// Events lost to full rings since startup.
    pub fn dropped_events(&self) -> u64 {
        self.rings
            .lock()
            .unwrap()
            .iter()
            .map(|r| r.dropped())
            .sum()
    }

    /// Drain every thread's ring into the archive. Rings whose owning
    /// thread has exited (we hold the only reference) are dropped
    /// once empty, so short-lived executor threads don't pile up.
    pub fn collect(&self) {
        let mut rings = self.rings.lock().unwrap();
        let mut archive = self.archive.lock().unwrap();
        for r in rings.iter() {
            r.drain(&mut archive);
        }
        rings.retain(|r| Arc::strong_count(r) > 1 || !r.is_empty());
    }

    /// Collect, then return every archived span of one trace.
    pub fn spans_for(&self, trace_id: u64) -> Vec<SpanEvent> {
        self.collect();
        self.archive
            .lock()
            .unwrap()
            .iter()
            .filter(|e| e.trace_id == trace_id)
            .copied()
            .collect()
    }

    /// Collect, then copy out the newest `n` archived spans *without*
    /// draining — the flight recorder snapshots recent history while
    /// leaving `--trace` export and `spans_for` consumers intact.
    pub fn recent(&self, n: usize) -> Vec<SpanEvent> {
        self.collect();
        let archive = self.archive.lock().unwrap();
        let start = archive.len().saturating_sub(n);
        archive[start..].to_vec()
    }

    /// Collect, then drain and return the whole archive.
    pub fn take_all(&self) -> Vec<SpanEvent> {
        self.collect();
        std::mem::take(&mut *self.archive.lock().unwrap())
    }

    /// Drop all archived + in-flight recorded spans (tests, E18 reuse
    /// between sweep points). Open guards are unaffected.
    pub fn clear(&self) {
        self.collect();
        self.archive.lock().unwrap().clear();
    }
}

/// Innermost open span on the calling thread, [`SpanCtx::NONE`] when
/// untraced. Leaf libraries use this as the implicit parent; context
/// structs capture it when handing work to another thread.
pub fn current() -> SpanCtx {
    CURRENT.with(|c| c.get())
}

/// Open a span parented on the calling thread's current span (a new
/// root when there is none). Inert and allocation-free when the
/// tracer is disabled.
#[inline]
pub fn span(name: &'static str, cat: Category) -> SpanGuard {
    span_in(name, cat, SpanCtx::NONE)
}

/// Open a span under an explicit parent carried across threads. A
/// `NONE` parent falls back to the thread-current span, then to a new
/// trace root.
#[inline]
pub fn span_in(name: &'static str, cat: Category, parent: SpanCtx) -> SpanGuard {
    let t = tracer();
    if !t.enabled() {
        return SpanGuard::inert();
    }
    let parent = if parent.is_none() { current() } else { parent };
    let span_id = t.next_span_id();
    let ctx = SpanCtx {
        trace_id: if parent.is_none() { span_id } else { parent.trace_id },
        span_id,
    };
    let prev = CURRENT.with(|c| c.replace(ctx));
    t.open.fetch_add(1, Ordering::Relaxed);
    SpanGuard {
        ctx,
        parent_id: parent.span_id,
        prev,
        name,
        cat,
        start_us: t.now_us(),
        args: [("", 0); MAX_ARGS],
        nargs: 0,
        live: true,
    }
}

/// RAII handle for an open span. Records the completed [`SpanEvent`]
/// on drop — including drops that happen while unwinding a panic —
/// and restores the thread's previous current span.
pub struct SpanGuard {
    ctx: SpanCtx,
    parent_id: u64,
    prev: SpanCtx,
    name: &'static str,
    cat: Category,
    start_us: u64,
    args: [(&'static str, u64); MAX_ARGS],
    nargs: u8,
    live: bool,
}

impl SpanGuard {
    fn inert() -> Self {
        SpanGuard {
            ctx: SpanCtx::NONE,
            parent_id: 0,
            prev: SpanCtx::NONE,
            name: "",
            cat: Category::Other,
            start_us: 0,
            args: [("", 0); MAX_ARGS],
            nargs: 0,
            live: false,
        }
    }

    /// This span's identity, for handing to child work on other
    /// threads. `NONE` when the guard is inert.
    pub fn ctx(&self) -> SpanCtx {
        self.ctx
    }

    /// Attach a numeric annotation (first [`MAX_ARGS`] stick).
    pub fn arg(&mut self, name: &'static str, value: u64) -> &mut Self {
        if self.live && (self.nargs as usize) < MAX_ARGS {
            self.args[self.nargs as usize] = (name, value);
            self.nargs += 1;
        }
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let t = tracer();
        let ev = SpanEvent {
            trace_id: self.ctx.trace_id,
            span_id: self.ctx.span_id,
            parent_id: self.parent_id,
            name: self.name,
            cat: self.cat,
            start_us: self.start_us,
            end_us: t.now_us(),
            tid: 0,
            args: self.args,
            nargs: self.nargs,
        };
        // Restore the implicit stack even if the thread_local is
        // mid-teardown; losing the pop is better than panicking in a
        // destructor.
        let _ = CURRENT.try_with(|c| c.set(self.prev));
        let _ = LOCAL.try_with(|(ring, tid)| {
            ring.push(SpanEvent { tid: *tid, ..ev });
        });
        t.open.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Test support: every test that enables the global tracer must hold
/// this lock, or concurrently running tests observe each other's
/// spans and enable/disable flips.
pub mod testing {
    use std::sync::{Mutex, MutexGuard, OnceLock};

    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();

    pub fn serial() -> MutexGuard<'static, ()> {
        let m = LOCK.get_or_init(|| Mutex::new(()));
        m.lock().unwrap_or_else(|p| p.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_spans_are_inert() {
        let _g = testing::serial();
        tracer().disable();
        let mut s = span("noop", Category::Compute);
        s.arg("k", 1);
        assert!(s.ctx().is_none());
        drop(s);
        assert_eq!(current(), SpanCtx::NONE);
    }

    #[test]
    fn disabled_span_open_is_cheap() {
        let _g = testing::serial();
        tracer().disable();
        let start = Instant::now();
        for _ in 0..100_000 {
            let _s = span("bench", Category::StoreIo);
        }
        // ~500 ns/op budget: two orders of magnitude above the real
        // cost of one relaxed load, far below any lock or allocation.
        assert!(
            start.elapsed() < std::time::Duration::from_millis(50),
            "100k disabled span opens took {:?}",
            start.elapsed()
        );
    }

    #[test]
    fn spans_nest_and_record_parent_links() {
        let _g = testing::serial();
        tracer().enable();
        tracer().clear();
        let root_ctx;
        let child_ctx;
        {
            let root = span("root", Category::Compute);
            root_ctx = root.ctx();
            assert_eq!(current(), root_ctx);
            {
                let mut child = span("child", Category::StoreIo);
                child.arg("bytes", 4096);
                child_ctx = child.ctx();
                assert_eq!(child_ctx.trace_id, root_ctx.trace_id);
                assert_eq!(current(), child_ctx);
            }
            assert_eq!(current(), root_ctx);
        }
        assert_eq!(current(), SpanCtx::NONE);
        let spans = tracer().spans_for(root_ctx.trace_id);
        assert_eq!(spans.len(), 2);
        let child = spans.iter().find(|e| e.name == "child").unwrap();
        let root = spans.iter().find(|e| e.name == "root").unwrap();
        assert_eq!(child.parent_id, root.span_id);
        assert_eq!(root.parent_id, 0);
        assert_eq!(child.args(), &[("bytes", 4096)]);
        assert!(root.end_us >= child.end_us);
        tracer().disable();
    }

    #[test]
    fn explicit_parent_crosses_threads() {
        let _g = testing::serial();
        tracer().enable();
        tracer().clear();
        let root = span("xroot", Category::Compute);
        let ctx = root.ctx();
        std::thread::scope(|s| {
            s.spawn(move || {
                let child = span_in("xchild", Category::Compute, ctx);
                assert_eq!(child.ctx().trace_id, ctx.trace_id);
            });
        });
        drop(root);
        let spans = tracer().spans_for(ctx.trace_id);
        assert_eq!(spans.len(), 2);
        let child = spans.iter().find(|e| e.name == "xchild").unwrap();
        assert_eq!(child.parent_id, ctx.span_id);
        tracer().disable();
    }

    #[test]
    fn panicking_scope_still_records_its_span() {
        let _g = testing::serial();
        tracer().enable();
        tracer().clear();
        let root = span("panic-root", Category::Compute);
        let ctx = root.ctx();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _inner = span("panic-inner", Category::Compute);
            panic!("boom");
        }));
        assert!(r.is_err());
        assert_eq!(current(), ctx, "unwind must restore the parent span");
        drop(root);
        let spans = tracer().spans_for(ctx.trace_id);
        assert!(spans.iter().any(|e| e.name == "panic-inner"));
        tracer().disable();
    }
}
