//! Procedural scenario generation.
//!
//! Two deterministic sources of diversity, both driven by the in-tree
//! [`Rng`] so a campaign seed reproduces byte-identical specs:
//!
//! * **parameter-grid sweeps** — the cartesian product of weather x
//!   actor count x sensor-noise level over a shared base route
//!   ([`generate_grid`]);
//! * **seeded mutation operators** — perturb an existing scenario into
//!   a named variant family ([`mutate`]): weather shift, actor add /
//!   remove, noise escalation, route jitter, fault injection.
//!
//! [`generate_campaign`] combines the two (roughly 3:1 grid:mutant) and
//! guarantees every returned spec has a distinct
//! [`ScenarioSpec::content_hash`].

use std::collections::HashSet;

use super::spec::{round3, ActorKind, ActorSpec, FaultSpec, RouteSpec, ScenarioSpec, Weather};
use crate::util::Rng;

/// Sensor-noise sigma sweep points (low / med / high buckets).
pub const NOISE_LEVELS: [f64; 3] = [0.01, 0.04, 0.09];

/// Names of the mutation operators (also the `mut-*` family suffixes).
pub const MUTATIONS: [&str; 6] =
    ["weather", "add-actor", "drop-actor", "noise", "route", "faults"];

fn seed32(rng: &mut Rng) -> u64 {
    rng.below(1 << 32)
}

/// A plausible drive route: a handful of forward-progress waypoints.
pub fn base_route(rng: &mut Rng) -> RouteSpec {
    let n = 4 + rng.below(4) as usize;
    let mut waypoints = Vec::with_capacity(n);
    let (mut x, mut y) = (0.0f64, 0.0f64);
    for _ in 0..n {
        x += round3(rng.range_f64(20.0, 80.0));
        y += round3(rng.range_f64(-30.0, 30.0));
        waypoints.push((round3(x), round3(y)));
    }
    RouteSpec { waypoints, speed_mps: round3(rng.range_f64(8.0, 22.0)) }
}

/// One actor in a given quadrant with the 4 px-margin placement
/// discipline (keeps blobs separable for the ground-truth counter).
fn gen_actor(quadrant: u8, frames: u32, rng: &mut Rng) -> ActorSpec {
    let kind = ActorKind::ALL[rng.below(ActorKind::ALL.len() as u64) as usize];
    let w = 8 + rng.below(5) as u8;
    let h = 8 + rng.below(5) as u8;
    let dx = rng.below(25 - w as u64) as u8;
    let dy = rng.below(25 - h as u64) as u8;
    let appear = rng.below((frames as u64 / 2).max(1)) as u32;
    // `vanish` may exceed `frames` — the actor then stays to the end.
    let vanish = appear + 1 + rng.below(frames.max(1) as u64 * 2) as u32;
    ActorSpec { kind, quadrant, dx, dy, w, h, appear, vanish }
}

/// Full parameter-grid sweep over a shared base route. The weather axis
/// cycles fastest so a truncated prefix still covers all four regimes.
pub fn generate_grid(seed: u64, frames: u32) -> Vec<ScenarioSpec> {
    let mut rng = Rng::new(seed);
    let route = base_route(&mut rng);
    let mut out = Vec::new();
    let mut idx = 0usize;
    for actors_n in 1..=4usize {
        for &noise in &NOISE_LEVELS {
            for weather in Weather::ALL {
                let mut arng = rng.split(idx as u64);
                let mut quadrants = [0u8, 1, 2, 3];
                arng.shuffle(&mut quadrants);
                let actors = quadrants[..actors_n]
                    .iter()
                    .map(|&q| gen_actor(q, frames, &mut arng))
                    .collect();
                out.push(ScenarioSpec {
                    id: format!("grid-{idx:04}"),
                    family: format!("grid-{}", weather.name()),
                    seed: seed32(&mut arng),
                    frames,
                    weather,
                    pixel_noise: noise,
                    route: route.clone(),
                    actors,
                    faults: FaultSpec::none(),
                });
                idx += 1;
            }
        }
    }
    out
}

/// Apply one seeded mutation operator, producing a `mut-*` family
/// variant. Always reseeds the sensor-noise stream, so even a
/// structurally-identical mutant records a different drive.
pub fn mutate(base: &ScenarioSpec, id: usize, rng: &mut Rng) -> ScenarioSpec {
    let op = MUTATIONS[rng.below(MUTATIONS.len() as u64) as usize];
    let mut s = base.clone();
    s.id = format!("mut-{id:04}");
    s.family = format!("mut-{op}");
    s.seed = seed32(rng);
    match op {
        "weather" => {
            let i = Weather::ALL.iter().position(|w| *w == s.weather).unwrap_or(0);
            s.weather = Weather::ALL[(i + 1 + rng.below(3) as usize) % Weather::ALL.len()];
        }
        "add-actor" => {
            let used: HashSet<u8> = s.actors.iter().map(|a| a.quadrant).collect();
            if let Some(q) = (0u8..4).find(|q| !used.contains(q)) {
                s.actors.push(gen_actor(q, s.frames, rng));
            }
        }
        "drop-actor" => {
            if s.actors.len() >= 2 {
                let i = rng.below(s.actors.len() as u64) as usize;
                s.actors.remove(i);
            }
        }
        "noise" => {
            s.pixel_noise = round3((s.pixel_noise * 1.6 + 0.005).min(0.15));
        }
        "route" => {
            for wp in s.route.waypoints.iter_mut() {
                wp.0 = round3(wp.0 + rng.range_f64(-2.0, 2.0));
                wp.1 = round3(wp.1 + rng.range_f64(-2.0, 2.0));
            }
        }
        "faults" => {
            s.faults.drop_rate = round3((s.faults.drop_rate + 0.08).min(0.4));
            s.faults.corrupt_rate = round3((s.faults.corrupt_rate + 0.05).min(0.3));
        }
        _ => unreachable!("mutation table covers all ops"),
    }
    s
}

/// Generate `n` scenarios with distinct content hashes: a grid-sweep
/// prefix (~3/4 of the budget) plus mutation families grown from it.
pub fn generate_campaign(seed: u64, n: usize) -> Vec<ScenarioSpec> {
    generate_campaign_sized(seed, n, 32)
}

/// [`generate_campaign`] with an explicit per-scenario frame count.
pub fn generate_campaign_sized(seed: u64, n: usize, frames: u32) -> Vec<ScenarioSpec> {
    let grid = generate_grid(seed, frames);
    let grid_target = if n <= 4 { n } else { (n * 3 / 4).min(grid.len()) };
    let mut seen: HashSet<u64> = HashSet::new();
    let mut out: Vec<ScenarioSpec> = Vec::with_capacity(n);
    for s in grid {
        if out.len() >= grid_target {
            break;
        }
        if seen.insert(s.content_hash()) {
            out.push(s);
        }
    }
    let mut rng = Rng::new(seed ^ 0x00C0_FFEE);
    let mut id = 0usize;
    // The reseed inside `mutate` makes hash collisions vanishingly
    // rare; the attempt cap is a defensive bound, not an expected exit.
    let mut attempts = 0usize;
    while out.len() < n && attempts < n * 50 + 100 {
        attempts += 1;
        let base = out[rng.below(out.len() as u64) as usize].clone();
        let m = mutate(&base, id, &mut rng);
        if seen.insert(m.content_hash()) {
            out.push(m);
            id += 1;
        }
    }
    out
}

/// Digest over every spec's canonical JSON — two campaigns with equal
/// digests generated byte-identical spec sets (the reproducibility
/// check `adcloud campaign` prints).
pub fn campaign_digest(specs: &[ScenarioSpec]) -> u64 {
    let mut joined = String::new();
    for s in specs {
        joined.push_str(&s.canonical_json());
        joined.push('\n');
    }
    super::spec::fnv1a64(joined.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_every_axis_value() {
        let grid = generate_grid(7, 32);
        assert_eq!(grid.len(), 4 * 3 * 4);
        for weather in Weather::ALL {
            assert!(grid.iter().any(|s| s.weather == weather), "{weather:?} missing");
        }
        for n in 1..=4usize {
            assert!(grid.iter().any(|s| s.actors.len() == n), "{n} actors missing");
        }
        for &noise in &NOISE_LEVELS {
            assert!(grid.iter().any(|s| s.pixel_noise == noise));
        }
        // Weather cycles fastest: a 4-prefix already covers all regimes.
        let prefix: HashSet<Weather> = grid[..4].iter().map(|s| s.weather).collect();
        assert_eq!(prefix.len(), 4);
    }

    #[test]
    fn campaign_is_deterministic_and_distinct() {
        let a = generate_campaign(7, 32);
        let b = generate_campaign(7, 32);
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.canonical_json(), y.canonical_json());
        }
        assert_eq!(campaign_digest(&a), campaign_digest(&b));
        let hashes: HashSet<u64> = a.iter().map(|s| s.content_hash()).collect();
        assert_eq!(hashes.len(), 32, "content hashes must be distinct");
        assert_ne!(campaign_digest(&a), campaign_digest(&generate_campaign(8, 32)));
    }

    #[test]
    fn campaign_mixes_grid_and_mutant_families() {
        let specs = generate_campaign(7, 32);
        let grid = specs.iter().filter(|s| s.family.starts_with("grid-")).count();
        let mutants = specs.iter().filter(|s| s.family.starts_with("mut-")).count();
        assert_eq!(grid + mutants, 32);
        assert!(grid >= 20, "grid share too small: {grid}");
        assert!(mutants >= 4, "mutant share too small: {mutants}");
    }

    #[test]
    fn oversubscribed_campaign_still_distinct() {
        // More scenarios than the raw grid: mutation must fill the gap.
        let specs = generate_campaign(3, 80);
        assert_eq!(specs.len(), 80);
        let hashes: HashSet<u64> = specs.iter().map(|s| s.content_hash()).collect();
        assert_eq!(hashes.len(), 80);
    }

    #[test]
    fn mutations_stay_in_bounds() {
        let mut rng = Rng::new(11);
        let mut spec = generate_grid(11, 16).remove(0);
        for i in 0..200 {
            spec = mutate(&spec, i, &mut rng);
            assert!(spec.actors.len() <= 4);
            assert!(!spec.actors.is_empty());
            assert!(spec.pixel_noise <= 0.15);
            assert!(spec.faults.drop_rate <= 0.4);
            assert!(spec.faults.corrupt_rate <= 0.3);
            for a in &spec.actors {
                assert!(a.quadrant < 4);
                assert!(a.dx as usize + a.w as usize <= 24, "{a:?} leaves margin");
                assert!(a.dy as usize + a.h as usize <= 24, "{a:?} leaves margin");
            }
            // Quadrants stay exclusive — blobs must not merge.
            let quads: HashSet<u8> = spec.actors.iter().map(|a| a.quadrant).collect();
            assert_eq!(quads.len(), spec.actors.len());
        }
    }

    #[test]
    fn generated_specs_roundtrip_json() {
        use crate::util::json::Json;
        for s in generate_campaign(5, 40) {
            let back =
                ScenarioSpec::from_json(&Json::parse(&s.canonical_json()).unwrap()).unwrap();
            assert_eq!(back, s);
        }
    }
}
