//! Campaign qualification reports: per-scenario verdicts aggregated
//! into parameter-space coverage and per-family failure rates — the
//! artifact a fleet-qualification run hands to the release gate.

use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use super::spec::Weather;
use crate::util::json::Json;

/// Outcome of one scenario's replay through the detector under test.
#[derive(Debug, Clone)]
pub struct ScenarioVerdict {
    pub id: String,
    pub family: String,
    pub content_hash: u64,
    pub weather: Weather,
    /// Actor count (parameter-space axis).
    pub actors: usize,
    /// Noise axis bucket ("low" / "med" / "high").
    pub noise_bucket: &'static str,
    /// Camera frames that reached the bag (post fault injection).
    pub frames: usize,
    /// Frames where the detector matched the planted truth exactly.
    pub exact: usize,
    /// Frames whose payload was corrupt (counted as misses).
    pub faults: usize,
    pub accuracy: f64,
    pub passed: bool,
}

const NOISE_BUCKETS: [&str; 3] = ["low", "med", "high"];

impl ScenarioVerdict {
    /// Deterministic binary encoding — the blob a campaign commits per
    /// scenario into its [`crate::platform::ShardCheckpoint`], so a
    /// preempted or resubmitted campaign resumes byte-identically.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.id.len() as u16).to_le_bytes());
        out.extend_from_slice(self.id.as_bytes());
        out.extend_from_slice(&(self.family.len() as u16).to_le_bytes());
        out.extend_from_slice(self.family.as_bytes());
        out.extend_from_slice(&self.content_hash.to_le_bytes());
        let weather = Weather::ALL.iter().position(|w| *w == self.weather).unwrap() as u8;
        out.push(weather);
        out.extend_from_slice(&(self.actors as u32).to_le_bytes());
        let noise = NOISE_BUCKETS.iter().position(|b| *b == self.noise_bucket).unwrap() as u8;
        out.push(noise);
        out.extend_from_slice(&(self.frames as u32).to_le_bytes());
        out.extend_from_slice(&(self.exact as u32).to_le_bytes());
        out.extend_from_slice(&(self.faults as u32).to_le_bytes());
        out.extend_from_slice(&self.accuracy.to_le_bytes());
        out.push(self.passed as u8);
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            if *off + n > bytes.len() {
                bail!("verdict blob truncated at byte {off}");
            }
            let s = &bytes[*off..*off + n];
            *off += n;
            Ok(s)
        };
        let take_str = |off: &mut usize| -> Result<String> {
            let n = u16::from_le_bytes(take(off, 2)?.try_into().unwrap()) as usize;
            Ok(String::from_utf8(take(off, n)?.to_vec())?)
        };
        let id = take_str(&mut off)?;
        let family = take_str(&mut off)?;
        let content_hash = u64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap());
        let weather = match Weather::ALL.get(take(&mut off, 1)?[0] as usize) {
            Some(w) => *w,
            None => bail!("verdict blob has invalid weather index"),
        };
        let actors = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let noise_bucket = match NOISE_BUCKETS.get(take(&mut off, 1)?[0] as usize) {
            Some(b) => *b,
            None => bail!("verdict blob has invalid noise bucket"),
        };
        let frames = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let exact = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let faults = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let accuracy = f64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap());
        let passed = take(&mut off, 1)?[0] != 0;
        if off != bytes.len() {
            bail!("verdict blob has {} trailing bytes", bytes.len() - off);
        }
        Ok(Self {
            id,
            family,
            content_hash,
            weather,
            actors,
            noise_bucket,
            frames,
            exact,
            faults,
            accuracy,
            passed,
        })
    }
}

/// Pass/fail statistics for one scenario family.
#[derive(Debug, Clone, Default)]
pub struct FamilyStats {
    pub total: usize,
    pub passed: usize,
    pub mean_accuracy: f64,
}

impl FamilyStats {
    pub fn failure_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            (self.total - self.passed) as f64 / self.total as f64
        }
    }
}

/// How much of the scenario parameter space the campaign exercised.
#[derive(Debug, Clone)]
pub struct Coverage {
    /// Weather regimes seen, out of [`Weather::ALL`].
    pub weather_covered: usize,
    pub weather_total: usize,
    /// Actor counts seen, out of 0..=4.
    pub actor_counts_covered: usize,
    pub actor_counts_total: usize,
    /// Noise buckets seen, out of low/med/high.
    pub noise_buckets_covered: usize,
    pub noise_buckets_total: usize,
    /// Distinct (weather, actor count, noise bucket) grid cells seen.
    pub cells_covered: usize,
    pub cells_total: usize,
}

impl Coverage {
    pub fn cell_fraction(&self) -> f64 {
        if self.cells_total == 0 {
            0.0
        } else {
            self.cells_covered as f64 / self.cells_total as f64
        }
    }
}

/// The aggregated campaign outcome.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub scenarios: usize,
    pub distinct_hashes: usize,
    /// Containers the campaign actually ran on.
    pub shards: usize,
    pub frames: usize,
    pub faults: usize,
    pub passed: usize,
    pub elapsed: Duration,
    pub coverage: Coverage,
    /// Family name -> stats, sorted for deterministic rendering.
    pub families: BTreeMap<String, FamilyStats>,
    pub verdicts: Vec<ScenarioVerdict>,
}

impl CampaignReport {
    pub fn failed(&self) -> usize {
        self.scenarios - self.passed
    }

    pub fn scenarios_per_sec(&self) -> f64 {
        self.scenarios as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Paper-style text rendering for the CLI and benches.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "== campaign qualification report ({} scenarios, {} shards)\n",
            self.scenarios, self.shards
        ));
        out.push_str(&format!(
            "  scenarios: {} passed / {} failed ({} distinct spec hashes)\n",
            self.passed,
            self.failed(),
            self.distinct_hashes
        ));
        out.push_str(&format!(
            "  frames:    {} replayed, {} corrupt-frame faults survived\n",
            self.frames, self.faults
        ));
        out.push_str(&format!(
            "  wall time: {} ({:.1} scenarios/s)\n",
            crate::util::fmt_duration(self.elapsed),
            self.scenarios_per_sec()
        ));
        let c = &self.coverage;
        out.push_str(&format!(
            "  coverage:  weather {}/{}, actor-counts {}/{}, noise {}/{}, grid cells {}/{} ({:.0}%)\n",
            c.weather_covered,
            c.weather_total,
            c.actor_counts_covered,
            c.actor_counts_total,
            c.noise_buckets_covered,
            c.noise_buckets_total,
            c.cells_covered,
            c.cells_total,
            c.cell_fraction() * 100.0
        ));
        out.push_str("  family                failure-rate  mean-acc  scenarios\n");
        for (name, f) in &self.families {
            out.push_str(&format!(
                "    {:<20}  {:>10.0}%  {:>8.3}  {:>4}/{}\n",
                name,
                f.failure_rate() * 100.0,
                f.mean_accuracy,
                f.passed,
                f.total
            ));
        }
        out
    }

    /// JSON emission (for archiving a campaign's outcome).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenarios", Json::num(self.scenarios as f64)),
            ("distinct_hashes", Json::num(self.distinct_hashes as f64)),
            ("shards", Json::num(self.shards as f64)),
            ("frames", Json::num(self.frames as f64)),
            ("faults", Json::num(self.faults as f64)),
            ("passed", Json::num(self.passed as f64)),
            ("elapsed_ms", Json::num(self.elapsed.as_secs_f64() * 1e3)),
            ("coverage_cells", Json::num(self.coverage.cells_covered as f64)),
            ("coverage_cells_total", Json::num(self.coverage.cells_total as f64)),
            (
                "families",
                Json::Obj(
                    self.families
                        .iter()
                        .map(|(k, f)| {
                            (
                                k.clone(),
                                Json::obj(vec![
                                    ("total", Json::num(f.total as f64)),
                                    ("passed", Json::num(f.passed as f64)),
                                    ("failure_rate", Json::num(f.failure_rate())),
                                    ("mean_accuracy", Json::num(f.mean_accuracy)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Fold per-scenario verdicts into the campaign report.
pub fn aggregate(
    verdicts: Vec<ScenarioVerdict>,
    shards: usize,
    elapsed: Duration,
) -> CampaignReport {
    let mut families: BTreeMap<String, (usize, usize, f64)> = BTreeMap::new();
    let mut hashes = BTreeSet::new();
    let mut weather = BTreeSet::new();
    let mut actor_counts = BTreeSet::new();
    let mut noise_buckets = BTreeSet::new();
    let mut cells = BTreeSet::new();
    let (mut frames, mut faults, mut passed) = (0usize, 0usize, 0usize);
    for v in &verdicts {
        let e = families.entry(v.family.clone()).or_insert((0, 0, 0.0));
        e.0 += 1;
        if v.passed {
            e.1 += 1;
            passed += 1;
        }
        e.2 += v.accuracy;
        hashes.insert(v.content_hash);
        weather.insert(v.weather);
        actor_counts.insert(v.actors.min(4));
        noise_buckets.insert(v.noise_bucket);
        cells.insert((v.weather, v.actors.min(4), v.noise_bucket));
        frames += v.frames;
        faults += v.faults;
    }
    let families = families
        .into_iter()
        .map(|(k, (total, passed, acc_sum))| {
            (
                k,
                FamilyStats {
                    total,
                    passed,
                    mean_accuracy: if total == 0 { 0.0 } else { acc_sum / total as f64 },
                },
            )
        })
        .collect();
    CampaignReport {
        scenarios: verdicts.len(),
        distinct_hashes: hashes.len(),
        shards,
        frames,
        faults,
        passed,
        elapsed,
        coverage: Coverage {
            weather_covered: weather.len(),
            weather_total: Weather::ALL.len(),
            actor_counts_covered: actor_counts.len(),
            actor_counts_total: 5,
            noise_buckets_covered: noise_buckets.len(),
            noise_buckets_total: 3,
            cells_covered: cells.len(),
            cells_total: Weather::ALL.len() * 5 * 3,
        },
        families,
        verdicts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn verdict(family: &str, weather: Weather, actors: usize, passed: bool) -> ScenarioVerdict {
        ScenarioVerdict {
            id: format!("{family}-x"),
            family: family.to_string(),
            content_hash: crate::scenario::spec::fnv1a64(
                format!("{family}{weather:?}{actors}{passed}").as_bytes(),
            ),
            weather,
            actors,
            noise_bucket: "low",
            frames: 10,
            exact: if passed { 9 } else { 2 },
            faults: 1,
            accuracy: if passed { 0.9 } else { 0.2 },
            passed,
        }
    }

    #[test]
    fn aggregate_counts_families_and_coverage() {
        let r = aggregate(
            vec![
                verdict("grid-clear", Weather::Clear, 1, true),
                verdict("grid-clear", Weather::Clear, 2, true),
                verdict("grid-fog", Weather::Fog, 1, false),
                verdict("mut-noise", Weather::Rain, 3, false),
            ],
            2,
            Duration::from_secs(1),
        );
        assert_eq!(r.scenarios, 4);
        assert_eq!(r.passed, 2);
        assert_eq!(r.failed(), 2);
        assert_eq!(r.distinct_hashes, 4);
        assert_eq!(r.frames, 40);
        assert_eq!(r.faults, 4);
        assert_eq!(r.coverage.weather_covered, 3);
        assert_eq!(r.coverage.actor_counts_covered, 3);
        assert_eq!(r.coverage.noise_buckets_covered, 1);
        assert_eq!(r.coverage.cells_covered, 4);
        assert_eq!(r.coverage.cells_total, 60);
        let fog = &r.families["grid-fog"];
        assert_eq!(fog.total, 1);
        assert!((fog.failure_rate() - 1.0).abs() < 1e-9);
        let clear = &r.families["grid-clear"];
        assert!((clear.failure_rate() - 0.0).abs() < 1e-9);
        assert!((clear.mean_accuracy - 0.9).abs() < 1e-9);
        assert!((r.scenarios_per_sec() - 4.0).abs() < 0.1);
    }

    #[test]
    fn render_and_json_are_complete() {
        let r = aggregate(
            vec![verdict("grid-clear", Weather::Clear, 1, true)],
            1,
            Duration::from_millis(100),
        );
        let text = r.render();
        assert!(text.contains("grid-clear"));
        assert!(text.contains("coverage"));
        assert!(text.contains("failure-rate"));
        let j = r.to_json();
        assert_eq!(j.get("scenarios").unwrap().as_u64().unwrap(), 1);
        assert!(j.get("families").unwrap().get("grid-clear").is_some());
        // JSON emission parses back.
        assert!(crate::util::json::Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn verdict_binary_roundtrip() {
        let v = verdict("grid-night", Weather::Night, 3, true);
        let b = v.to_bytes();
        let back = ScenarioVerdict::from_bytes(&b).unwrap();
        assert_eq!(back.to_bytes(), b, "re-encoding must be byte-identical");
        assert_eq!(back.id, v.id);
        assert_eq!(back.family, v.family);
        assert_eq!(back.content_hash, v.content_hash);
        assert_eq!(back.noise_bucket, v.noise_bucket);
        assert_eq!(back.accuracy, v.accuracy);
        assert_eq!(back.passed, v.passed);
        assert!(ScenarioVerdict::from_bytes(&b[..b.len() - 1]).is_err(), "truncation rejected");
    }

    #[test]
    fn empty_campaign_report_is_sane() {
        let r = aggregate(Vec::new(), 1, Duration::from_secs(1));
        assert_eq!(r.scenarios, 0);
        assert_eq!(r.coverage.cells_covered, 0);
        assert!(r.render().contains("0 scenarios"));
    }
}
