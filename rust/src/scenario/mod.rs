//! Scenario engine: procedural scenario generation and distributed
//! test campaigns.
//!
//! The paper's simulation service replays *recorded* road data; this
//! subsystem opens the scenario-diversity axis on top of it. A
//! campaign is: declarative [`spec::ScenarioSpec`]s (route, actors,
//! weather/noise, fault injection) → deterministic procedural
//! generation ([`generate`]: parameter-grid sweeps + seeded mutation
//! operators) → distributed execution ([`campaign`]: specs sharded as
//! DCE partitions inside YARN-analog containers, each materialized to
//! real bag chunks and replayed through the detector under test) →
//! a qualification report ([`report`]: parameter-space coverage and
//! per-family failure rates).
//!
//! Everything is seed-deterministic: the same campaign seed reproduces
//! byte-identical canonical-JSON specs (and therefore identical bags),
//! which `adcloud campaign` surfaces as a printed digest.

pub mod campaign;
pub mod generate;
pub mod report;
pub mod spec;

pub use campaign::{
    materialize_scenario, render_frame, run_campaign, score_scenario, CampaignConfig,
};
pub use generate::{
    base_route, campaign_digest, generate_campaign, generate_campaign_sized, generate_grid,
    mutate, MUTATIONS, NOISE_LEVELS,
};
pub use report::{aggregate, CampaignReport, Coverage, FamilyStats, ScenarioVerdict};
pub use spec::{
    fnv1a64, ActorKind, ActorSpec, FaultSpec, RouteSpec, ScenarioSpec, Weather,
};
