//! Campaign execution: materialize scenarios into bag chunks, shard
//! them across the compute engine, run the detector under test per
//! partition, and aggregate verdicts.
//!
//! This is the paper's distributed-simulation service grown into a
//! qualification pipeline: the YARN-analog resource manager grants one
//! container per simulated node, each DCE partition renders its
//! scenarios to real bag files (through the same rosbag codec the
//! replay service uses), replays them through the obstacle detector,
//! and the driver aggregates a [`CampaignReport`].

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use super::report::{self, CampaignReport, ScenarioVerdict};
use super::spec::ScenarioSpec;
use crate::dce::DceContext;
use crate::platform::checkpoint::ShardCheckpoint;
use crate::platform::job::JobHandle;
use crate::platform::opts::JobOpts;
use crate::resource::{ResourceManager, ResourceVec};
use crate::services::simulation::{
    count_obstacles_from_features, gen_lidar_scan, read_bag, BagWriter, CameraFrame, Message,
    CAMERA_TOPIC, LIDAR_TOPIC,
};
use crate::services::simulation::sensors::{FRAME_H, FRAME_W};
use crate::trace;
use crate::util::Rng;

/// Knobs for one campaign run. The shared submission fields (app name,
/// queue, worker ceiling, checkpointing — where `opts.checkpoint`
/// commits each verdict into a [`ShardCheckpoint`] keyed by the
/// scenario's content hash and clears it on success) live in
/// [`JobOpts`]; only the campaign-domain knobs are declared here.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Shared job-submission options.
    pub opts: JobOpts,
    /// A scenario qualifies when frame accuracy reaches this bar.
    pub pass_accuracy: f64,
    /// Scratch directory for materialized bag chunks.
    pub work_dir: PathBuf,
}

impl CampaignConfig {
    pub fn new(app: impl Into<String>, nodes: usize) -> Self {
        let app = app.into();
        Self {
            work_dir: std::env::temp_dir()
                .join(format!("adcloud-campaign-{}-{}", app, std::process::id())),
            opts: JobOpts::new(app).workers(nodes),
            pass_accuracy: 0.6,
        }
    }
}

/// Render one camera frame from the spec: weather-scaled road texture,
/// actor boxes with per-kind contrast, additive sensor noise. The frame
/// carries its ground truth so replay can score the detector.
pub fn render_frame(spec: &ScenarioSpec, frame: u32, rng: &mut Rng) -> CameraFrame {
    let (brightness, fade, weather_noise) = spec.weather.params();
    let sigma = spec.pixel_noise as f32 + weather_noise;
    let mut pixels = vec![0f32; FRAME_W * FRAME_H];
    for y in 0..FRAME_H {
        for x in 0..FRAME_W {
            let base = 0.35 + 0.1 * (x as f32 / FRAME_W as f32);
            pixels[y * FRAME_W + x] = base * brightness + rng.normal_f32(0.0, sigma);
        }
    }
    let mut truth = 0u32;
    for a in &spec.actors {
        if !a.visible_at(frame) {
            continue;
        }
        truth += 1;
        let (qx, qy) = match a.quadrant {
            0 => (0usize, 0usize),
            1 => (32, 0),
            2 => (0, 32),
            _ => (32, 32),
        };
        let x0 = qx + 4 + a.dx as usize;
        let y0 = qy + 4 + a.dy as usize;
        let level = (a.kind.level() - fade) * brightness + rng.normal_f32(0.0, 0.01);
        for y in y0..(y0 + a.h as usize).min(FRAME_H) {
            for x in x0..(x0 + a.w as usize).min(FRAME_W) {
                pixels[y * FRAME_W + x] = level;
            }
        }
    }
    for p in pixels.iter_mut() {
        *p = p.clamp(0.0, 1.0);
    }
    CameraFrame { ts_ns: frame as u64 * 100_000_000, pixels, truth_obstacles: truth }
}

/// Frames per bag chunk (scenarios shard into multiple DCE-sized files,
/// mirroring `record_drive`'s chunked layout).
const FRAMES_PER_CHUNK: u32 = 16;

/// Record a scenario into bag chunks under `dir`, applying the spec's
/// fault injection: dropped frames never reach the bag, corrupted
/// frames are written with a mangled payload the replay side must
/// survive.
pub fn materialize_scenario(spec: &ScenarioSpec, dir: &Path) -> Result<Vec<PathBuf>> {
    let mut rng = Rng::new(spec.seed);
    let chunks = spec.frames.div_ceil(FRAMES_PER_CHUNK).max(1);
    let mut paths = Vec::with_capacity(chunks as usize);
    let mut t = 0u32;
    for c in 0..chunks {
        let mut w = BagWriter::create(dir.join(format!("chunk-{c:04}.bag")));
        while t < spec.frames && t < (c + 1) * FRAMES_PER_CHUNK {
            let frame = render_frame(spec, t, &mut rng);
            let dropped = rng.next_f64() < spec.faults.drop_rate;
            let corrupted = rng.next_f64() < spec.faults.corrupt_rate;
            if !dropped {
                let mut payload = frame.to_bytes();
                if corrupted {
                    // Truncate mid-header: decodes as a bag message but
                    // fails CameraFrame::from_bytes.
                    payload.truncate(10);
                }
                w.write(Message { topic: CAMERA_TOPIC.into(), ts_ns: frame.ts_ns, payload });
                if t % 4 == 0 {
                    let scan = gen_lidar_scan(frame.ts_ns, 90, &mut rng);
                    w.write(Message {
                        topic: LIDAR_TOPIC.into(),
                        ts_ns: frame.ts_ns,
                        payload: crate::util::f32s_to_bytes(&scan.points),
                    });
                }
            }
            t += 1;
        }
        paths.push(w.finish()?);
    }
    Ok(paths)
}

/// Replay a scenario's bags through the CPU detector under test and
/// score it against the planted truth. Corrupt frames count as faults
/// *and* as misses — a detector pipeline that crashes on bad input
/// fails qualification, it doesn't skip the frame.
pub fn score_scenario(
    spec: &ScenarioSpec,
    bags: &[PathBuf],
    pass_accuracy: f64,
) -> Result<ScenarioVerdict> {
    let mut frames = 0usize;
    let mut exact = 0usize;
    let mut faults = 0usize;
    for path in bags {
        let msgs = read_bag(path).with_context(|| format!("replaying scenario {}", spec.id))?;
        for m in &msgs {
            if m.topic != CAMERA_TOPIC {
                continue;
            }
            frames += 1;
            match CameraFrame::from_bytes(&m.payload) {
                Ok(f) => {
                    let feats =
                        crate::hetero::cpu_impls::feature_extract(&f.pixels, 1, FRAME_H, FRAME_W);
                    if count_obstacles_from_features(&feats, 8, 8) == f.truth_obstacles {
                        exact += 1;
                    }
                }
                Err(_) => faults += 1,
            }
        }
    }
    let accuracy = if frames == 0 { 0.0 } else { exact as f64 / frames as f64 };
    Ok(ScenarioVerdict {
        id: spec.id.clone(),
        family: spec.family.clone(),
        content_hash: spec.content_hash(),
        weather: spec.weather,
        actors: spec.actors.len(),
        noise_bucket: spec.noise_bucket(),
        frames,
        exact,
        faults,
        accuracy,
        passed: accuracy >= pass_accuracy,
    })
}

/// Checkpoint item key for one scenario: content hash plus the scoring
/// bar, so a resubmission with a different `pass_accuracy` can never
/// reuse verdicts judged under the old threshold.
fn ckpt_item(spec: &ScenarioSpec, pass_accuracy: f64) -> String {
    format!("{:016x}-{:016x}", spec.content_hash(), pass_accuracy.to_bits())
}

/// Run a full campaign as one job on the unified job layer: acquire an
/// elastic container grant (one per requested node, degrading
/// gracefully on a small cluster), shard the scenario list across the
/// DCE, materialize + score each scenario inside its container's
/// accounting, and aggregate the verdicts into a qualification report.
/// The grant is an RAII guard: containers return to the pool on every
/// exit path, including shard errors and panics.
///
/// With `checkpoint` enabled (the default), every verdict is committed
/// to a [`ShardCheckpoint`] as it lands and each shard yields at
/// scenario boundaries when its container is flagged for preemption —
/// the requeued (or resubmitted) shard reloads completed verdicts
/// instead of re-scoring them, so preemption costs at most the
/// in-flight scenario and a resubmitted campaign reruns nothing.
pub fn run_campaign(
    ctx: &DceContext,
    rm: &Arc<ResourceManager>,
    specs: &[ScenarioSpec],
    cfg: &CampaignConfig,
) -> Result<CampaignReport> {
    anyhow::ensure!(!specs.is_empty(), "campaign has no scenarios");
    let start = Instant::now();
    // Size the grant for the largest scenario's frame buffers (with
    // headroom for the encoded bag), floored at 32 MiB.
    let max_frames = specs.iter().map(|s| s.frames as u64).max().unwrap_or(0);
    let mem = (2 * max_frames * (FRAME_W * FRAME_H * 4) as u64).max(32 << 20);
    let job = JobHandle::submit(rm, cfg.opts.spec().resources(ResourceVec::cores(1, mem)))
        .with_context(|| format!("submitting campaign job '{}'", cfg.opts.app))?;
    let shards = job.shards();
    // One resolution for the whole campaign; the scoring loop touches
    // these per scenario on every shard.
    let m = crate::metrics::CampaignMetrics::new(ctx.metrics());
    m.campaigns.inc();

    let work_dir = cfg.work_dir.clone();
    let pass_accuracy = cfg.pass_accuracy;
    let ckpt = cfg.opts.checkpoint.then(|| ShardCheckpoint::new(ctx.store(), &cfg.opts.app));
    let shard_ckpt = ckpt.clone();
    let metrics = m.clone();
    let result = job.run_sharded(ctx, specs.to_vec(), move |sctx, specs: Vec<ScenarioSpec>| {
        let mut out = Vec::with_capacity(specs.len());
        for spec in specs {
            let item = ckpt_item(&spec, pass_accuracy);
            // Resume path: a verdict committed before a preemption or
            // by a prior submission is reloaded, never re-scored. A
            // blob that fails to decode must not poison the job — fall
            // through and re-score instead.
            let committed = {
                let mut csp =
                    trace::span("ckpt.lookup", trace::Category::CheckpointReplay);
                csp.arg("shard", sctx.shard as u64);
                shard_ckpt.as_ref().and_then(|c| c.lookup(&item))
            };
            if let Some(bytes) = committed {
                if let Ok(v) = ScenarioVerdict::from_bytes(&bytes) {
                    out.push(v);
                    metrics.ckpt_hits.inc();
                    continue;
                }
                metrics.ckpt_corrupt.inc();
            }
            // Yield at a scenario boundary when asked to: everything
            // scored so far is already committed, so the requeued
            // shard loses no work.
            sctx.check_preempted()?;
            let dir = work_dir.join(&spec.id);
            let verdict = sctx.run(|cctx| -> Result<ScenarioVerdict> {
                // Charge the frame buffers against the container's
                // memory limit, cgroup-style.
                let est = spec.frames as u64 * (FRAME_W * FRAME_H * 4) as u64;
                cctx.alloc_mem(est)?;
                let result = (|| {
                    let bags = materialize_scenario(&spec, &dir)?;
                    score_scenario(&spec, &bags, pass_accuracy)
                })();
                cctx.free_mem(est);
                let _ = std::fs::remove_dir_all(&dir);
                result
            })??;
            metrics.scored.inc();
            if let Some(c) = &shard_ckpt {
                let mut csp =
                    trace::span("ckpt.commit", trace::Category::CheckpointReplay);
                csp.arg("shard", sctx.shard as u64);
                c.commit(&item, verdict.to_bytes())?;
            }
            out.push(verdict);
        }
        Ok(out)
    });

    // finish() returns the grant whether or not the job succeeded — a
    // failed campaign must not permanently deduct cluster capacity.
    let _ = job.finish();
    let _ = std::fs::remove_dir_all(&cfg.work_dir);
    let verdicts = result?;
    if let Some(c) = &ckpt {
        // Success: later campaigns under this app name start fresh. A
        // FAILED campaign keeps its checkpoint, which is the point —
        // resubmission resumes from the completed scenarios.
        c.clear(specs.iter().map(|s| ckpt_item(s, cfg.pass_accuracy)));
    }
    m.scenarios_run.add(verdicts.len() as u64);
    Ok(report::aggregate(verdicts, shards, start.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::metrics::MetricsRegistry;
    use crate::scenario::generate::{generate_campaign_sized, generate_grid};
    use crate::scenario::spec::{FaultSpec, Weather};

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("adscen-{tag}-{}", std::process::id()))
    }

    #[test]
    fn render_matches_spec_truth_and_range() {
        let spec = generate_grid(3, 16).remove(5);
        let mut rng = Rng::new(spec.seed);
        for t in 0..spec.frames {
            let f = render_frame(&spec, t, &mut rng);
            assert_eq!(f.truth_obstacles, spec.truth_at(t));
            assert!(f.pixels.iter().all(|p| (0.0..=1.0).contains(p)));
        }
    }

    #[test]
    fn materialize_is_seed_deterministic() {
        let spec = generate_grid(9, 24).remove(0);
        let (d1, d2) = (temp_dir("det1"), temp_dir("det2"));
        let b1 = materialize_scenario(&spec, &d1).unwrap();
        let b2 = materialize_scenario(&spec, &d2).unwrap();
        assert_eq!(b1.len(), b2.len());
        for (a, b) in b1.iter().zip(&b2) {
            assert_eq!(std::fs::read(a).unwrap(), std::fs::read(b).unwrap());
        }
        let _ = std::fs::remove_dir_all(d1);
        let _ = std::fs::remove_dir_all(d2);
    }

    #[test]
    fn clear_scenario_qualifies() {
        // Clear weather, low noise, no faults: the detector must pass.
        let spec = generate_grid(7, 16)
            .into_iter()
            .find(|s| s.weather == Weather::Clear && s.pixel_noise < 0.03)
            .unwrap();
        let dir = temp_dir("clear");
        let bags = materialize_scenario(&spec, &dir).unwrap();
        let v = score_scenario(&spec, &bags, 0.6).unwrap();
        assert_eq!(v.frames, 16);
        assert_eq!(v.faults, 0);
        assert!(v.passed, "clear-weather accuracy {}", v.accuracy);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn fault_injection_drops_and_corrupts() {
        let mut spec = generate_grid(13, 32).remove(0);
        spec.faults = FaultSpec { drop_rate: 0.3, corrupt_rate: 0.3 };
        let dir = temp_dir("faults");
        let bags = materialize_scenario(&spec, &dir).unwrap();
        let v = score_scenario(&spec, &bags, 0.6).unwrap();
        assert!(v.frames < 32, "some frames must be dropped, got {}", v.frames);
        assert!(v.faults > 0, "some frames must be corrupt");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn campaign_end_to_end_on_local_cluster() {
        let cfg = PlatformConfig::test();
        let ctx = DceContext::new(cfg.clone()).unwrap();
        let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
        let specs = generate_campaign_sized(7, 8, 8);
        let ccfg = CampaignConfig::new("campaign-ut", 2);
        let report = run_campaign(&ctx, &rm, &specs, &ccfg).unwrap();
        assert_eq!(report.scenarios, 8);
        assert_eq!(report.distinct_hashes, 8);
        assert_eq!(report.shards, 2);
        assert!(report.passed >= 1, "at least the clear scenarios must pass");
        assert!(rm.live_containers() == 0, "containers must be released");
        // Work dir cleaned up.
        assert!(!ccfg.work_dir.exists());
        // The app was unregistered: the same config is reusable.
        let again = run_campaign(&ctx, &rm, &specs, &ccfg).unwrap();
        assert_eq!(again.scenarios, 8);
    }

    #[test]
    fn checkpointed_campaign_resumes_without_rescoring() {
        let cfg = PlatformConfig::test();
        let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
        let specs = generate_campaign_sized(11, 6, 8);
        // Baseline: an uninterrupted run.
        let ctx1 = DceContext::new(cfg.clone()).unwrap();
        let base_cfg = CampaignConfig::new("ckpt-base", 2);
        let base = run_campaign(&ctx1, &rm, &specs, &base_cfg).unwrap();
        assert_eq!(ctx1.metrics().counter("scenario.scored").get(), 6);
        // Interrupted submission: half the verdicts already sit in the
        // app's checkpoint (exactly what a preempted shard leaves
        // behind), plus one corrupt blob that must be ignored, not
        // poison the job. The resubmitted campaign scores only what is
        // genuinely missing.
        let ctx2 = DceContext::new(cfg.clone()).unwrap();
        let resume_cfg = CampaignConfig::new("ckpt-resume", 2);
        let bar = resume_cfg.pass_accuracy;
        let ckpt = ShardCheckpoint::new(ctx2.store(), "ckpt-resume");
        for (s, v) in specs.iter().zip(&base.verdicts).take(3) {
            ckpt.commit(&ckpt_item(s, bar), v.to_bytes()).unwrap();
        }
        ckpt.commit(&ckpt_item(&specs[3], bar), b"not a verdict".to_vec()).unwrap();
        let resumed = run_campaign(&ctx2, &rm, &specs, &resume_cfg).unwrap();
        assert_eq!(ctx2.metrics().counter("scenario.scored").get(), 3, "3 already done");
        assert_eq!(ctx2.metrics().counter("scenario.ckpt_hits").get(), 3);
        assert_eq!(ctx2.metrics().counter("scenario.ckpt_corrupt").get(), 1);
        // Byte-identical final output, resumed or not.
        let bytes = |r: &crate::scenario::CampaignReport| -> Vec<u8> {
            r.verdicts.iter().flat_map(|v| v.to_bytes()).collect()
        };
        assert_eq!(bytes(&base), bytes(&resumed));
        // Success clears the checkpoint for the next submission.
        for s in &specs {
            assert!(!ckpt.contains(&ckpt_item(s, bar)));
        }
    }

    #[test]
    fn campaign_degrades_to_available_capacity() {
        let cfg = PlatformConfig::test(); // 2 nodes x 2 cores
        let ctx = DceContext::new(cfg.clone()).unwrap();
        let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
        let specs = generate_campaign_sized(5, 4, 8);
        // Ask for more shards than the cluster has cores.
        let ccfg = CampaignConfig::new("campaign-degrade", 64);
        let report = run_campaign(&ctx, &rm, &specs, &ccfg).unwrap();
        assert_eq!(report.scenarios, 4);
        assert!(report.shards <= cfg.cluster.total_cores());
        assert!(report.shards >= 1);
    }
}
