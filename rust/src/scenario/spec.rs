//! Declarative scenario specifications.
//!
//! A [`ScenarioSpec`] fully determines one simulated test drive: route
//! geometry, actor placements (the planted obstacles the detector under
//! test must find), weather and sensor-noise parameters, and
//! fault-injection rates for the recording path. Specs round-trip
//! through [`crate::util::json`] — the canonical JSON emission is
//! byte-deterministic (BTreeMap key order, shortest-round-trip float
//! formatting), so a spec's [`ScenarioSpec::content_hash`] identifies
//! its test content and `generate` can guarantee campaign diversity.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// FNV-1a over a byte string — the stable spec/digest hash (no external
/// hashing crates in the offline build; DefaultHasher is not guaranteed
/// stable across releases).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Round to 3 decimals so generated parameters emit as short, exact
/// JSON numbers (f64 Display is shortest-round-trip, so re-parsing is
/// byte-identical).
pub fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

/// Weather regimes and their sensor-degradation models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Weather {
    Clear,
    Rain,
    Fog,
    Night,
}

impl Weather {
    pub const ALL: [Weather; 4] = [Weather::Clear, Weather::Rain, Weather::Fog, Weather::Night];

    pub fn name(&self) -> &'static str {
        match self {
            Weather::Clear => "clear",
            Weather::Rain => "rain",
            Weather::Fog => "fog",
            Weather::Night => "night",
        }
    }

    pub fn from_name(name: &str) -> Result<Weather> {
        Weather::ALL
            .into_iter()
            .find(|w| w.name() == name)
            .ok_or_else(|| anyhow::anyhow!("unknown weather '{name}'"))
    }

    /// `(brightness, obstacle_fade, extra_noise)` applied to rendered
    /// frames. Fog washes out obstacle contrast, night dims the whole
    /// frame, rain adds sensor noise — each pushes the gradient-feature
    /// detector toward a different failure mode.
    pub fn params(&self) -> (f32, f32, f32) {
        match self {
            Weather::Clear => (1.0, 0.0, 0.0),
            Weather::Rain => (0.9, 0.05, 0.02),
            Weather::Fog => (0.95, 0.22, 0.01),
            Weather::Night => (0.65, 0.05, 0.03),
        }
    }
}

/// What kind of obstacle an actor renders as (drives its contrast).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ActorKind {
    Vehicle,
    Pedestrian,
    Cyclist,
    Debris,
}

impl ActorKind {
    pub const ALL: [ActorKind; 4] =
        [ActorKind::Vehicle, ActorKind::Pedestrian, ActorKind::Cyclist, ActorKind::Debris];

    pub fn name(&self) -> &'static str {
        match self {
            ActorKind::Vehicle => "vehicle",
            ActorKind::Pedestrian => "pedestrian",
            ActorKind::Cyclist => "cyclist",
            ActorKind::Debris => "debris",
        }
    }

    pub fn from_name(name: &str) -> Result<ActorKind> {
        ActorKind::ALL
            .into_iter()
            .find(|k| k.name() == name)
            .ok_or_else(|| anyhow::anyhow!("unknown actor kind '{name}'"))
    }

    /// Rendered brightness level (before weather fade). Debris is the
    /// lowest-contrast class and the first to vanish under fog. The
    /// spread is deliberately narrow: in clear weather every kind sits
    /// safely above the detector's gradient threshold, so failures come
    /// from the weather/noise axes rather than kind lottery.
    pub fn level(&self) -> f32 {
        match self {
            ActorKind::Vehicle => 0.85,
            ActorKind::Cyclist => 0.83,
            ActorKind::Pedestrian => 0.81,
            ActorKind::Debris => 0.79,
        }
    }
}

/// Route geometry the simulated drive follows.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteSpec {
    /// Polyline waypoints in metres (map frame).
    pub waypoints: Vec<(f64, f64)>,
    pub speed_mps: f64,
}

impl RouteSpec {
    pub fn length_m(&self) -> f64 {
        self.waypoints
            .windows(2)
            .map(|w| {
                let (dx, dy) = (w[1].0 - w[0].0, w[1].1 - w[0].1);
                (dx * dx + dy * dy).sqrt()
            })
            .sum()
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "waypoints",
                Json::arr(
                    self.waypoints
                        .iter()
                        .map(|(x, y)| Json::arr(vec![Json::num(*x), Json::num(*y)]))
                        .collect(),
                ),
            ),
            ("speed_mps", Json::num(self.speed_mps)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let mut waypoints = Vec::new();
        for p in j.req("waypoints")?.as_arr()? {
            let xy = p.as_arr()?;
            if xy.len() != 2 {
                bail!("waypoint must be [x, y], got {} values", xy.len());
            }
            waypoints.push((xy[0].as_f64()?, xy[1].as_f64()?));
        }
        Ok(Self { waypoints, speed_mps: j.req("speed_mps")?.as_f64()? })
    }
}

/// One planted obstacle: a bright box in a 32x32 quadrant of the 64x64
/// frame, visible over `[appear, vanish)` frames. Placement keeps a 4 px
/// quadrant margin (same discipline as `sensors::gen_camera_frame`) so
/// distinct actors stay separable blobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActorSpec {
    pub kind: ActorKind,
    /// Frame quadrant 0..4 (row-major: TL, TR, BL, BR).
    pub quadrant: u8,
    /// Offset from the quadrant's 4 px margin.
    pub dx: u8,
    pub dy: u8,
    /// Box size in pixels, 8..=12 (one 8x8 feature cell minimum).
    pub w: u8,
    pub h: u8,
    /// First frame the actor is visible.
    pub appear: u32,
    /// First frame the actor is gone (exclusive).
    pub vanish: u32,
}

impl ActorSpec {
    pub fn visible_at(&self, frame: u32) -> bool {
        frame >= self.appear && frame < self.vanish
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind.name())),
            ("quadrant", Json::num(self.quadrant as f64)),
            ("dx", Json::num(self.dx as f64)),
            ("dy", Json::num(self.dy as f64)),
            ("w", Json::num(self.w as f64)),
            ("h", Json::num(self.h as f64)),
            ("appear", Json::num(self.appear as f64)),
            ("vanish", Json::num(self.vanish as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        // Bounds-check on the raw u64s: an `as` cast would silently
        // truncate an oversized hand-authored value.
        let field = |name: &str, max: u64| -> Result<u64> {
            let v = j.req(name)?.as_u64()?;
            if v > max {
                bail!("actor {name}={v} exceeds {max}");
            }
            Ok(v)
        };
        let a = Self {
            kind: ActorKind::from_name(j.req("kind")?.as_str()?)?,
            quadrant: field("quadrant", 3)? as u8,
            dx: field("dx", 24)? as u8,
            dy: field("dy", 24)? as u8,
            w: field("w", 12)? as u8,
            h: field("h", 12)? as u8,
            appear: field("appear", u32::MAX as u64)? as u32,
            vanish: field("vanish", u32::MAX as u64)? as u32,
        };
        if !(8..=12).contains(&a.w) || !(8..=12).contains(&a.h) {
            bail!("actor size {}x{} outside 8..=12", a.w, a.h);
        }
        // The placement invariant the generator maintains: the box must
        // fit the quadrant's 24 px budget or neighboring actors' blobs
        // would merge and corrupt the ground truth.
        if a.dx + a.w > 24 || a.dy + a.h > 24 {
            bail!("actor at ({},{}) size {}x{} overflows its quadrant", a.dx, a.dy, a.w, a.h);
        }
        if a.vanish <= a.appear {
            bail!("actor vanish {} must exceed appear {}", a.vanish, a.appear);
        }
        Ok(a)
    }
}

/// Recording-path fault injection: frames silently dropped by the
/// "sensor bus", and frames whose payload is corrupted in the bag.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub drop_rate: f64,
    pub corrupt_rate: f64,
}

impl FaultSpec {
    pub fn none() -> Self {
        Self { drop_rate: 0.0, corrupt_rate: 0.0 }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("drop_rate", Json::num(self.drop_rate)),
            ("corrupt_rate", Json::num(self.corrupt_rate)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            drop_rate: j.req("drop_rate")?.as_f64()?,
            corrupt_rate: j.req("corrupt_rate")?.as_f64()?,
        })
    }
}

/// One complete, reproducible test scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Unique within a campaign (e.g. `grid-0007`, `mut-0002`).
    pub id: String,
    /// Grouping key for failure-rate aggregation (e.g. `grid-fog`).
    pub family: String,
    /// Per-scenario sensor-noise seed. Kept < 2^32 so the JSON f64
    /// representation is exact.
    pub seed: u64,
    /// Camera frames recorded (10 Hz).
    pub frames: u32,
    pub weather: Weather,
    /// Base pixel-noise sigma (weather adds on top).
    pub pixel_noise: f64,
    pub route: RouteSpec,
    pub actors: Vec<ActorSpec>,
    pub faults: FaultSpec,
}

impl ScenarioSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("family", Json::str(self.family.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("frames", Json::num(self.frames as f64)),
            ("weather", Json::str(self.weather.name())),
            ("pixel_noise", Json::num(self.pixel_noise)),
            ("route", self.route.to_json()),
            ("actors", Json::arr(self.actors.iter().map(|a| a.to_json()).collect())),
            ("faults", self.faults.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let s = Self {
            id: j.req("id")?.as_str()?.to_string(),
            family: j.req("family")?.as_str()?.to_string(),
            seed: j.req("seed")?.as_u64()?,
            frames: j.req("frames")?.as_u64()? as u32,
            weather: Weather::from_name(j.req("weather")?.as_str()?)?,
            pixel_noise: j.req("pixel_noise")?.as_f64()?,
            route: RouteSpec::from_json(j.req("route")?)?,
            actors: j
                .req("actors")?
                .as_arr()?
                .iter()
                .map(ActorSpec::from_json)
                .collect::<Result<_>>()?,
            faults: FaultSpec::from_json(j.req("faults")?)?,
        };
        if s.seed > u32::MAX as u64 {
            bail!("scenario seed {} exceeds the exact-f64 range", s.seed);
        }
        // Quadrant exclusivity: two actors in one quadrant render as a
        // single blob while the ground truth counts two, so the spec
        // would be unsatisfiable by any detector.
        let mut quads = [false; 4];
        for a in &s.actors {
            if std::mem::replace(&mut quads[a.quadrant as usize], true) {
                bail!("two actors share quadrant {}", a.quadrant);
            }
        }
        Ok(s)
    }

    /// Byte-deterministic JSON emission (sorted keys, compact).
    pub fn canonical_json(&self) -> String {
        self.to_json().to_string()
    }

    /// Hash of the scenario's *test content* — everything except its
    /// campaign-local `id`/`family` labels. Two scenarios with equal
    /// content hashes would record byte-identical bags.
    pub fn content_hash(&self) -> u64 {
        let mut j = self.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("id");
            m.remove("family");
        }
        fnv1a64(j.to_string().as_bytes())
    }

    /// Ground-truth obstacle count at a frame index.
    pub fn truth_at(&self, frame: u32) -> u32 {
        self.actors.iter().filter(|a| a.visible_at(frame)).count() as u32
    }

    /// Coverage bucket for the noise axis (low/med/high).
    pub fn noise_bucket(&self) -> &'static str {
        if self.pixel_noise < 0.03 {
            "low"
        } else if self.pixel_noise < 0.07 {
            "med"
        } else {
            "high"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_spec() -> ScenarioSpec {
        ScenarioSpec {
            id: "grid-0000".into(),
            family: "grid-clear".into(),
            seed: 1234,
            frames: 16,
            weather: Weather::Clear,
            pixel_noise: 0.01,
            route: RouteSpec {
                waypoints: vec![(0.0, 0.0), (42.5, 10.25), (80.125, -5.0)],
                speed_mps: 12.5,
            },
            actors: vec![
                ActorSpec {
                    kind: ActorKind::Vehicle,
                    quadrant: 0,
                    dx: 3,
                    dy: 5,
                    w: 10,
                    h: 9,
                    appear: 0,
                    vanish: 16,
                },
                ActorSpec {
                    kind: ActorKind::Debris,
                    quadrant: 3,
                    dx: 0,
                    dy: 0,
                    w: 8,
                    h: 8,
                    appear: 4,
                    vanish: 12,
                },
            ],
            faults: FaultSpec { drop_rate: 0.05, corrupt_rate: 0.1 },
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let s = sample_spec();
        let text = s.canonical_json();
        let back = ScenarioSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        // Emission is byte-stable across the round trip too.
        assert_eq!(back.canonical_json(), text);
    }

    #[test]
    fn content_hash_ignores_labels_only() {
        let s = sample_spec();
        let mut relabeled = s.clone();
        relabeled.id = "other".into();
        relabeled.family = "elsewhere".into();
        assert_eq!(s.content_hash(), relabeled.content_hash());
        let mut changed = s.clone();
        changed.pixel_noise = 0.09;
        assert_ne!(s.content_hash(), changed.content_hash());
        let mut reseeded = s;
        reseeded.seed += 1;
        assert_ne!(reseeded.content_hash(), relabeled.content_hash());
    }

    #[test]
    fn truth_tracks_actor_windows() {
        let s = sample_spec();
        assert_eq!(s.truth_at(0), 1); // debris not yet visible
        assert_eq!(s.truth_at(5), 2);
        assert_eq!(s.truth_at(12), 1); // debris gone
    }

    #[test]
    fn invalid_specs_rejected() {
        let s = sample_spec();
        let mut j = s.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("seed".into(), Json::num((u32::MAX as f64) * 8.0));
        }
        assert!(ScenarioSpec::from_json(&j).is_err(), "oversized seed must fail");
        let mut bad_actor = s.clone();
        bad_actor.actors[0].quadrant = 9;
        let text = bad_actor.canonical_json();
        assert!(ScenarioSpec::from_json(&Json::parse(&text).unwrap()).is_err());
        assert!(Weather::from_name("hail").is_err());
        assert!(ActorKind::from_name("ufo").is_err());
    }

    #[test]
    fn fnv_is_stable_and_spreads() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
    }

    #[test]
    fn weather_params_degrade_contrast() {
        let (b_clear, f_clear, _) = Weather::Clear.params();
        let (_, f_fog, _) = Weather::Fog.params();
        let (b_night, _, _) = Weather::Night.params();
        assert_eq!((b_clear, f_clear), (1.0, 0.0));
        assert!(f_fog > 0.1, "fog must fade obstacles");
        assert!(b_night < 0.8, "night must dim the frame");
    }

    #[test]
    fn route_length_sums_segments() {
        let r = RouteSpec { waypoints: vec![(0.0, 0.0), (3.0, 4.0), (3.0, 14.0)], speed_mps: 10.0 };
        assert!((r.length_m() - 15.0).abs() < 1e-9);
    }
}
