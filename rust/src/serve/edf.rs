//! The pure serving-plane state machine: deadline-aware admission,
//! EDF ordering, and the speculative-fallback decision.
//!
//! Everything here is virtual-time (microseconds since plane start) and
//! allocation-only — no clocks, no threads, no I/O — so the same code
//! drives the real [`super::ServePlane`] under a mutex *and* the
//! deterministic single-threaded [`super::simulate`] used by the
//! regression tests and experiment E21.
//!
//! The admission rule is reject-on-arrival (paper §3: a late perception
//! result is worthless to the vehicle, which falls back to its on-board
//! model — better to say no immediately than to burn a cloud slot on a
//! response that cannot arrive in time):
//!
//! ```text
//! estimated_wait = busy_us + backlog_us / workers
//! admit  iff  estimated_wait + service_estimate <= deadline - now
//! ```
//!
//! Admitted requests are dispatched earliest-deadline-first. At
//! dispatch, if the remaining slack no longer covers the p99 service
//! estimate (plus 25% headroom), the request is *speculatively* served
//! by the cheap local model instead — a degraded-quality completion,
//! not an SLO miss.

use std::collections::VecDeque;

/// How the ready queue orders dispatches. `Fifo` is the `--baseline`
/// arm of experiment E21.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Edf,
    Fifo,
}

/// One vehicle offload request, times in µs since plane start.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: u64,
    pub arrival_us: u64,
    /// Absolute deadline: the response is useless after this instant.
    pub deadline_us: u64,
    /// True remote service cost. The plane never reads this before
    /// execution — admission works off the estimator only.
    pub work_us: u64,
}

/// Outcome of the reject-on-arrival admission check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    Admit,
    Reject { est_wait_us: u64 },
}

/// Windowed service-time estimator: a 512-sample ring over observed
/// remote service times, with a configured prior before any samples
/// land so cold-start admission is not vacuously permissive.
#[derive(Clone, Debug)]
pub struct ServiceEstimator {
    samples: Vec<u64>,
    next: usize,
    prior_us: u64,
}

const ESTIMATOR_WINDOW: usize = 512;

impl ServiceEstimator {
    pub fn new(prior_us: u64) -> Self {
        Self { samples: Vec::new(), next: 0, prior_us: prior_us.max(1) }
    }

    pub fn record(&mut self, service_us: u64) {
        if self.samples.len() < ESTIMATOR_WINDOW {
            self.samples.push(service_us);
        } else {
            self.samples[self.next] = service_us;
        }
        self.next = (self.next + 1) % ESTIMATOR_WINDOW;
    }

    /// Expected service time — the admission check's cost term.
    pub fn mean_us(&self) -> u64 {
        if self.samples.is_empty() {
            return self.prior_us;
        }
        let sum: u64 = self.samples.iter().sum();
        (sum / self.samples.len() as u64).max(1)
    }

    /// Tail service time — the speculation check's cost term. With few
    /// samples this is close to the observed max, which errs toward
    /// falling back (degraded answer) rather than missing the deadline.
    pub fn p99_us(&self) -> u64 {
        if self.samples.is_empty() {
            // Prior tail: assume the tail is ~2.5x the prior mean.
            return self.prior_us.saturating_mul(5) / 2;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        // Rank rounds *up* so small windows report their max — erring
        // toward a degraded answer rather than a deadline miss.
        sorted[((sorted.len() - 1) * 99 + 99) / 100]
    }
}

struct Queued {
    req: Request,
    /// The mean estimate charged to `backlog_us` at admission; the pop
    /// refunds exactly this amount so the backlog never drifts.
    est_us: u64,
}

/// The admission + ready queue. Owns the backlog accounting and the
/// service estimator; callers provide "now" and pop results back in.
pub struct AdmissionQueue {
    policy: Policy,
    workers: usize,
    queue: VecDeque<Queued>,
    /// Sum of the mean-estimate cost of every queued request.
    backlog_us: u64,
    est: ServiceEstimator,
}

impl AdmissionQueue {
    pub fn new(policy: Policy, workers: usize, prior_service_us: u64) -> Self {
        Self {
            policy,
            workers: workers.max(1),
            queue: VecDeque::new(),
            backlog_us: 0,
            est: ServiceEstimator::new(prior_service_us),
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Feed an observed remote service time back into the estimator.
    pub fn record_service(&mut self, service_us: u64) {
        self.est.record(service_us);
    }

    pub fn estimator(&self) -> &ServiceEstimator {
        &self.est
    }

    /// Queue-delay estimate for a request arriving now: `busy_us` is
    /// the wait until the first worker frees (0 when any is idle), and
    /// the backlog ahead of it drains across all workers.
    pub fn estimated_wait_us(&self, busy_us: u64) -> u64 {
        busy_us + self.backlog_us / self.workers as u64
    }

    /// Reject-on-arrival admission: admit iff the queue-delay estimate
    /// plus the expected service time fits inside the deadline slack.
    pub fn offer(&mut self, req: Request, now_us: u64, busy_us: u64) -> Decision {
        let wait = self.estimated_wait_us(busy_us);
        let svc = self.est.mean_us();
        let slack = req.deadline_us.saturating_sub(now_us);
        if wait + svc > slack {
            return Decision::Reject { est_wait_us: wait };
        }
        self.backlog_us += svc;
        self.queue.push_back(Queued { req, est_us: svc });
        Decision::Admit
    }

    /// Dispatch the next request: earliest absolute deadline under
    /// `Edf`, arrival order under `Fifo`.
    pub fn pop(&mut self) -> Option<Request> {
        let idx = match self.policy {
            Policy::Fifo => 0,
            Policy::Edf => {
                let mut best = 0;
                for (i, q) in self.queue.iter().enumerate() {
                    if q.req.deadline_us < self.queue[best].req.deadline_us {
                        best = i;
                    }
                }
                best
            }
        };
        let q = self.queue.remove(idx)?;
        self.backlog_us = self.backlog_us.saturating_sub(q.est_us);
        Some(q.req)
    }

    /// Speculation check at dispatch time: if the remaining slack no
    /// longer covers the p99 service estimate (plus 25% headroom for
    /// estimator lag), serve the cheap local model instead of risking
    /// an SLO miss on the remote path.
    pub fn should_fallback(&self, req: &Request, now_us: u64) -> bool {
        let remaining = req.deadline_us.saturating_sub(now_us);
        let p99 = self.est.p99_us();
        remaining < p99 + p99 / 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival_us: u64, deadline_us: u64, work_us: u64) -> Request {
        Request { id, arrival_us, deadline_us, work_us }
    }

    #[test]
    fn admission_rejects_exactly_when_queue_estimate_exceeds_slack() {
        // 1 worker, mean-service prior 1000us, every deadline 3500us of
        // slack: wait(k admitted) = k*1000, admit needs k*1000 + 1000
        // <= 3500, so requests 0..=2 admit and request 3 bounces.
        let mut q = AdmissionQueue::new(Policy::Edf, 1, 1000);
        for k in 0..3 {
            assert_eq!(q.offer(req(k, 0, 3500, 1000), 0, 0), Decision::Admit, "req {k}");
        }
        assert_eq!(q.offer(req(3, 0, 3500, 1000), 0, 0), Decision::Reject { est_wait_us: 3000 });
        // A later-deadline request still fits behind the same backlog.
        assert_eq!(q.offer(req(4, 0, 9000, 1000), 0, 0), Decision::Admit);
        // Worker-busy time counts against the slack too.
        let mut fresh = AdmissionQueue::new(Policy::Edf, 1, 1000);
        assert_eq!(
            fresh.offer(req(5, 0, 3500, 1000), 0, 3000),
            Decision::Reject { est_wait_us: 3000 }
        );
    }

    #[test]
    fn edf_pops_earliest_deadline_fifo_pops_arrival_order() {
        let mk = |policy| {
            let mut q = AdmissionQueue::new(policy, 4, 100);
            q.offer(req(0, 0, 90_000, 100), 0, 0);
            q.offer(req(1, 1, 10_000, 100), 1, 0);
            q.offer(req(2, 2, 50_000, 100), 2, 0);
            q
        };
        let mut edf = mk(Policy::Edf);
        let order: Vec<u64> = std::iter::from_fn(|| edf.pop().map(|r| r.id)).collect();
        assert_eq!(order, vec![1, 2, 0]);
        let mut fifo = mk(Policy::Fifo);
        let order: Vec<u64> = std::iter::from_fn(|| fifo.pop().map(|r| r.id)).collect();
        assert_eq!(order, vec![0, 1, 2]);
        assert!(edf.is_empty() && fifo.is_empty());
    }

    #[test]
    fn backlog_refund_matches_charge_across_estimator_drift() {
        // The estimator mean moves between admit and pop; the refund
        // must use the charged amount, not the current mean, or the
        // backlog drifts and admission silently tightens/loosens.
        let mut q = AdmissionQueue::new(Policy::Edf, 1, 1000);
        q.offer(req(0, 0, 100_000, 1000), 0, 0);
        for _ in 0..32 {
            q.record_service(4000); // mean jumps to 4000
        }
        q.offer(req(1, 0, 100_000, 1000), 0, 0);
        assert_eq!(q.estimated_wait_us(0), 5000);
        q.pop();
        q.pop();
        assert_eq!(q.estimated_wait_us(0), 0, "backlog must return to zero");
    }

    #[test]
    fn fallback_fires_iff_slack_is_below_the_p99_estimate() {
        let mut q = AdmissionQueue::new(Policy::Edf, 1, 1000);
        for _ in 0..99 {
            q.record_service(1000);
        }
        q.record_service(5000); // p99 = 5000
        assert_eq!(q.estimator().p99_us(), 5000);
        let r = req(0, 0, 10_000, 1000);
        // 10_000 of slack covers 5000 * 1.25: remote path is safe.
        assert!(!q.should_fallback(&r, 0));
        // 3000 of slack left: the tail no longer fits, go local.
        assert!(q.should_fallback(&r, 7000));
    }

    #[test]
    fn estimator_prior_applies_until_samples_land() {
        let mut e = ServiceEstimator::new(2000);
        assert_eq!(e.mean_us(), 2000);
        assert_eq!(e.p99_us(), 5000);
        e.record(400);
        assert_eq!(e.mean_us(), 400);
        assert_eq!(e.p99_us(), 400);
    }
}
