//! The latency-SLO serving plane (paper §3): vehicles offload
//! perception/planning inference to the cloud with *hard deadlines*,
//! and the plane either answers in time or gets out of the way.
//!
//! Three mechanisms, all driven by the pure state machine in [`edf`]:
//!
//! 1. **Reject-on-arrival admission** — a request whose queue-delay
//!    estimate already exceeds its deadline slack is bounced
//!    immediately, so the vehicle falls back to its on-board model at
//!    arrival time instead of after a wasted round trip.
//! 2. **EDF dispatch** — admitted requests run earliest-deadline-first
//!    inside an `interactive` capacity queue that sits *above* the
//!    batch/campaign queues in the resource manager's priority order.
//! 3. **Speculative fallback** — if, by dispatch time, the remaining
//!    slack no longer covers the p99 service estimate, the request is
//!    served by the cheap local model: a degraded-quality completion,
//!    not an SLO miss.
//!
//! The plane exists twice on purpose: [`simulate`] is a
//! single-threaded virtual-time run (deterministic — the regression
//! tests and experiment E21's sweep curves use it), and [`ServePlane`]
//! is the real thing — worker shards obtained through the unified job
//! layer (`JobOpts` → `JobHandle::run_per_container`) on the
//! `interactive` queue, a producer thread pacing arrivals in
//! wall-clock microseconds, and `serve.*` metrics feeding the obs
//! sampler (`serve.latency.p50/.p99/.p999`) and the serve watchdog
//! rules.

pub mod edf;

pub use edf::{AdmissionQueue, Decision, Policy, Request, ServiceEstimator};

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::config::ClusterConfig;
use crate::metrics::{MetricsRegistry, ServeMetrics};
use crate::platform::{JobHandle, JobOpts};
use crate::resource::{ResourceManager, ResourceVec};
use crate::util::Rng;

/// Knobs for one serving run — shared by [`simulate`], [`ServePlane`],
/// and experiment E21.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub nodes: usize,
    pub workers_per_node: usize,
    pub policy: Policy,
    /// Speculative local-model fallback at dispatch. Off in the
    /// `--baseline` arm.
    pub speculation: bool,
    pub requests: usize,
    /// Offered load, requests/second of virtual (or wall) time.
    pub offered_rps: f64,
    /// Relative deadline attached to every request.
    pub deadline_us: u64,
    /// Mean remote service cost; per-request cost is lognormal around
    /// it, clamped to [mean/4, 4*mean] so no single request is
    /// infeasible within the deadline.
    pub mean_service_us: u64,
    /// Cost of the degraded on-vehicle fallback model.
    pub local_service_us: u64,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        // 8 workers x 2 ms mean service = 4000 rps capacity; offered
        // defaults to 80% of it. Deadline = 6x mean service.
        Self {
            nodes: 2,
            workers_per_node: 4,
            policy: Policy::Edf,
            speculation: true,
            requests: 20_000,
            offered_rps: 3200.0,
            deadline_us: 12_000,
            mean_service_us: 2000,
            local_service_us: 300,
            seed: 7,
        }
    }
}

impl ServeConfig {
    pub fn workers(&self) -> usize {
        (self.nodes * self.workers_per_node).max(1)
    }

    /// Ideal throughput if every worker served mean-cost requests
    /// back to back — the knee of the latency cliff sits near load 1.0.
    pub fn capacity_rps(&self) -> f64 {
        self.workers() as f64 * 1e6 / self.mean_service_us as f64
    }

    /// Set offered load as a multiple of capacity (1.0 = the knee).
    pub fn at_load(mut self, multiple: f64) -> Self {
        self.offered_rps = multiple * self.capacity_rps();
        self
    }

    /// The E21 `--baseline` arm: FIFO dispatch, no speculation.
    pub fn baseline(mut self) -> Self {
        self.policy = Policy::Fifo;
        self.speculation = false;
        self
    }

    pub fn quick(mut self) -> Self {
        self.requests = 4000;
        self
    }
}

/// Outcome tallies for one serving run. `offered = admitted + rejected`
/// and `admitted = completed + missed + fallbacks` always hold.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub offered: u64,
    pub admitted: u64,
    pub rejected: u64,
    /// Remote completions that made their deadline.
    pub completed: u64,
    /// Remote completions that landed late: the SLO misses.
    pub missed: u64,
    /// Speculative local-model completions (degraded, not missed).
    pub fallbacks: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub makespan_us: u64,
    /// IDs served by the fallback model (completion order in the
    /// simulator, sorted on the real plane) — the determinism
    /// regression compares these across same-seed runs.
    pub degraded_ids: Vec<u64>,
}

impl ServeReport {
    /// In-deadline remote completions per second of makespan — the
    /// number E21 benchmarks (`serve_goodput_per_sec`).
    pub fn goodput_per_sec(&self) -> f64 {
        if self.makespan_us == 0 {
            return 0.0;
        }
        self.completed as f64 * 1e6 / self.makespan_us as f64
    }

    pub fn miss_pct(&self) -> f64 {
        let admitted = self.admitted.max(1);
        self.missed as f64 * 100.0 / admitted as f64
    }

    pub fn fallback_pct(&self) -> f64 {
        let admitted = self.admitted.max(1);
        self.fallbacks as f64 * 100.0 / admitted as f64
    }

    pub fn render(&self) -> String {
        format!(
            "offered {} | admitted {} | rejected {}\n\
             completed {} | missed {} ({:.2}%) | fallbacks {} ({:.2}%)\n\
             latency p50 {}us p99 {}us p999 {}us | goodput {:.1}/s",
            self.offered,
            self.admitted,
            self.rejected,
            self.completed,
            self.missed,
            self.miss_pct(),
            self.fallbacks,
            self.fallback_pct(),
            self.p50_us,
            self.p99_us,
            self.p999_us,
            self.goodput_per_sec()
        )
    }
}

/// Deterministic synthetic workload: Poisson arrivals at
/// `offered_rps`, lognormal service costs around `mean_service_us`
/// (clamped to [mean/4, 4*mean]), a fixed relative deadline. The same
/// seed yields the same trace in the simulator and the real plane.
pub fn gen_requests(cfg: &ServeConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let rps = cfg.offered_rps.max(1.0);
    let mean = cfg.mean_service_us.max(1);
    let mut t = 0u64;
    let mut out = Vec::with_capacity(cfg.requests);
    for id in 0..cfg.requests as u64 {
        let u = rng.next_f64();
        t += ((-(1.0 - u).ln() * 1e6 / rps).ceil() as u64).max(1);
        let factor = (rng.normal_f32(0.0, 0.4) as f64).exp();
        let work = ((mean as f64 * factor) as u64).clamp(mean / 4, mean * 4);
        out.push(Request {
            id,
            arrival_us: t,
            deadline_us: t + cfg.deadline_us,
            work_us: work,
        });
    }
    out
}

struct SimTally {
    completed: u64,
    missed: u64,
    fallbacks: u64,
    latencies: Vec<u64>,
    degraded_ids: Vec<u64>,
    makespan_us: u64,
}

/// Dispatch queued requests onto the earliest-free worker until no
/// worker frees before `until` (or the queue drains).
fn sim_drain(
    cfg: &ServeConfig,
    q: &mut AdmissionQueue,
    worker_free: &mut [u64],
    until: u64,
    tally: &mut SimTally,
) {
    while !q.is_empty() {
        let (wi, wfree) = worker_free
            .iter()
            .copied()
            .enumerate()
            .min_by_key(|&(_, f)| f)
            .expect("at least one worker");
        if wfree >= until {
            return;
        }
        let req = q.pop().expect("queue checked non-empty");
        let start = wfree.max(req.arrival_us);
        if cfg.speculation && q.should_fallback(&req, start) {
            // Local model: does not consume the worker slot.
            let done = start + cfg.local_service_us;
            tally.fallbacks += 1;
            tally.degraded_ids.push(req.id);
            tally.latencies.push(done - req.arrival_us);
            tally.makespan_us = tally.makespan_us.max(done);
            continue;
        }
        let done = start + req.work_us;
        worker_free[wi] = done;
        q.record_service(req.work_us);
        tally.latencies.push(done - req.arrival_us);
        if done > req.deadline_us {
            tally.missed += 1;
        } else {
            tally.completed += 1;
        }
        tally.makespan_us = tally.makespan_us.max(done);
    }
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// Single-threaded virtual-time run of the whole plane: same admission
/// / EDF / speculation machine as [`ServePlane`], zero wall-clock in
/// the loop, so identical seeds give identical reports. E21's sweep
/// curves and the determinism regressions run through here.
pub fn simulate(cfg: &ServeConfig) -> ServeReport {
    let workers = cfg.workers();
    let reqs = gen_requests(cfg);
    let mut q = AdmissionQueue::new(cfg.policy, workers, cfg.mean_service_us);
    let mut worker_free = vec![0u64; workers];
    let mut tally = SimTally {
        completed: 0,
        missed: 0,
        fallbacks: 0,
        latencies: Vec::with_capacity(reqs.len()),
        degraded_ids: Vec::new(),
        makespan_us: 0,
    };
    let mut admitted = 0u64;
    let mut rejected = 0u64;
    for r in &reqs {
        sim_drain(cfg, &mut q, &mut worker_free, r.arrival_us, &mut tally);
        let earliest_free = worker_free.iter().copied().min().unwrap_or(0);
        let busy_us = earliest_free.saturating_sub(r.arrival_us);
        match q.offer(*r, r.arrival_us, busy_us) {
            Decision::Admit => admitted += 1,
            Decision::Reject { .. } => rejected += 1,
        }
    }
    sim_drain(cfg, &mut q, &mut worker_free, u64::MAX, &mut tally);
    tally.latencies.sort_unstable();
    ServeReport {
        offered: reqs.len() as u64,
        admitted,
        rejected,
        completed: tally.completed,
        missed: tally.missed,
        fallbacks: tally.fallbacks,
        p50_us: percentile(&tally.latencies, 0.50),
        p99_us: percentile(&tally.latencies, 0.99),
        p999_us: percentile(&tally.latencies, 0.999),
        makespan_us: tally.makespan_us,
        degraded_ids: tally.degraded_ids,
    }
}

/// Shared frontend state: the pure queue under a mutex, a condvar to
/// wake idle workers, and a done flag the producer raises after the
/// last arrival.
struct Frontend {
    lane: Mutex<Lane>,
    cv: Condvar,
}

struct Lane {
    q: AdmissionQueue,
    done: bool,
}

fn us_since(t0: Instant) -> u64 {
    t0.elapsed().as_micros() as u64
}

/// Busy-wait until `end` — sleeps are far too coarse for microsecond
/// service times and would fake SLO misses.
fn spin_until(t0: Instant, target_us: u64) {
    while us_since(t0) < target_us {
        std::hint::spin_loop();
    }
}

/// The real serving plane: worker shards are job-layer containers on
/// the `interactive` priority queue, arrivals are paced on the wall
/// clock, and every decision lands in `serve.*` metrics.
pub struct ServePlane;

impl ServePlane {
    /// Boot a dedicated resource manager (batch + interactive queues,
    /// interactive on top) and run the plane. Fails if any container
    /// leaks past job finish.
    pub fn run(cfg: &ServeConfig) -> Result<ServeReport> {
        let cluster = ClusterConfig {
            nodes: cfg.nodes,
            cores_per_node: cfg.workers_per_node,
            gpus_per_node: 0,
            fpgas_per_node: 0,
            mem_per_node: 256 << 20,
        };
        let metrics = MetricsRegistry::new();
        let rm = ResourceManager::with_priority_queues(
            &cluster,
            vec![("batch".into(), 0.5, 1.0, 0), ("interactive".into(), 0.5, 1.0, 1)],
            metrics,
        );
        let report = Self::run_on(&rm, cfg)?;
        ensure!(rm.live_containers() == 0, "serving plane leaked containers");
        Ok(report)
    }

    /// Run against an existing resource manager (the `interactive`
    /// queue must exist). The submission goes through the same unified
    /// job API as every batch workload — serving is just a job whose
    /// shards never want to exit.
    pub fn run_on(rm: &Arc<ResourceManager>, cfg: &ServeConfig) -> Result<ServeReport> {
        let workers = cfg.workers();
        let sm = ServeMetrics::new(rm.metrics());
        let opts = JobOpts::new("serve-frontend").queue("interactive").workers(workers);
        let spec = opts
            .spec()
            .containers(workers, workers)
            .resources(ResourceVec::cores(1, 16 << 20));
        let handle = JobHandle::submit(rm, spec)?;

        let frontend = Arc::new(Frontend {
            lane: Mutex::new(Lane {
                q: AdmissionQueue::new(cfg.policy, workers, cfg.mean_service_us),
                done: false,
            }),
            cv: Condvar::new(),
        });
        let degraded = Arc::new(Mutex::new(Vec::new()));
        let t0 = Instant::now();

        // The vehicle fleet: one producer pacing Poisson arrivals and
        // making the admission decision at each one.
        let producer = {
            let frontend = Arc::clone(&frontend);
            let sm = sm.clone();
            let reqs = gen_requests(cfg);
            std::thread::spawn(move || {
                for r in reqs {
                    spin_until(t0, r.arrival_us);
                    let mut lane = frontend.lane.lock().unwrap();
                    sm.requests.inc();
                    // No worker-free view from here; the backlog term
                    // alone drives the wait estimate on the real path.
                    match lane.q.offer(r, us_since(t0), 0) {
                        Decision::Admit => {
                            sm.admitted.inc();
                            sm.queue_depth.set(lane.q.len() as u64);
                            drop(lane);
                            frontend.cv.notify_one();
                        }
                        Decision::Reject { .. } => sm.rejected.inc(),
                    }
                }
                let mut lane = frontend.lane.lock().unwrap();
                lane.done = true;
                drop(lane);
                frontend.cv.notify_all();
            })
        };

        let served = handle.run_per_container(|_ctx| {
            let mut handled = 0u64;
            loop {
                let next = {
                    let mut lane = frontend.lane.lock().unwrap();
                    loop {
                        if let Some(req) = lane.q.pop() {
                            sm.queue_depth.set(lane.q.len() as u64);
                            let now = us_since(t0);
                            let fb = cfg.speculation && lane.q.should_fallback(&req, now);
                            break Some((req, fb));
                        }
                        if lane.done {
                            break None;
                        }
                        lane = frontend.cv.wait(lane).unwrap();
                    }
                };
                let Some((req, fallback)) = next else {
                    return Ok(handled);
                };
                if fallback {
                    spin_until(t0, us_since(t0) + cfg.local_service_us);
                    sm.fallbacks.inc();
                    degraded.lock().unwrap().push(req.id);
                } else {
                    spin_until(t0, us_since(t0) + req.work_us);
                    frontend.lane.lock().unwrap().q.record_service(req.work_us);
                    if us_since(t0) > req.deadline_us {
                        sm.deadline_misses.inc();
                    } else {
                        sm.completed.inc();
                    }
                }
                sm.latency.record(Duration::from_micros(us_since(t0) - req.arrival_us));
                handled += 1;
            }
        })?;
        let makespan_us = us_since(t0);
        producer.join().expect("producer thread panicked");
        let stats = handle.finish();
        let handled: u64 = served.iter().sum();
        ensure!(
            handled == sm.admitted.get(),
            "workers handled {handled} of {} admitted requests",
            sm.admitted.get()
        );
        debug_assert_eq!(stats.app, "serve-frontend");

        let mut degraded_ids = std::mem::take(&mut *degraded.lock().unwrap());
        degraded_ids.sort_unstable();
        Ok(ServeReport {
            offered: sm.requests.get(),
            admitted: sm.admitted.get(),
            rejected: sm.rejected.get(),
            completed: sm.completed.get(),
            missed: sm.deadline_misses.get(),
            fallbacks: sm.fallbacks.get(),
            p50_us: sm.latency.quantile(0.50).as_micros() as u64,
            p99_us: sm.latency.quantile(0.99).as_micros() as u64,
            p999_us: sm.latency.quantile(0.999).as_micros() as u64,
            makespan_us,
            degraded_ids,
        })
    }
}

/// `adcloud serve --quick`: the CI smoke path. Checks simulator
/// determinism, the EDF-vs-FIFO ordering win, the below-knee SLO, and
/// one small real run end to end.
pub fn self_test() -> Result<String> {
    let base = ServeConfig::default().quick();
    let mut out = String::new();

    let a = simulate(&base.clone().at_load(2.0));
    let b = simulate(&base.clone().at_load(2.0));
    ensure!(
        a.degraded_ids == b.degraded_ids && a.completed == b.completed && a.missed == b.missed,
        "same seed must produce the same degraded set and tallies"
    );
    out.push_str(&format!(
        "determinism: ok ({} fallbacks reproduced)\n",
        a.fallbacks
    ));

    let low = simulate(&base.clone().at_load(0.4));
    ensure!(
        low.missed == 0 && low.fallbacks == 0 && low.p99_us <= base.deadline_us,
        "below the knee every deadline must be met remotely: {}",
        low.render()
    );
    out.push_str(&format!("below knee: ok (p99 {}us <= {}us)\n", low.p99_us, base.deadline_us));

    let edf = simulate(&base.clone().at_load(1.5));
    let fifo = simulate(&base.clone().at_load(1.5).baseline());
    ensure!(
        edf.miss_pct() < 1.0 && edf.missed <= fifo.missed,
        "EDF+speculation must hold the miss rate past the knee: edf {} vs fifo {}",
        edf.render(),
        fifo.render()
    );
    out.push_str(&format!(
        "past knee: ok (edf miss {:.2}% vs baseline {:.2}%)\n",
        edf.miss_pct(),
        fifo.miss_pct()
    ));

    let real_cfg = ServeConfig {
        nodes: 1,
        workers_per_node: 2,
        requests: 200,
        mean_service_us: 400,
        deadline_us: 2400,
        local_service_us: 80,
        ..ServeConfig::default()
    }
    .at_load(0.8);
    let real = ServePlane::run(&real_cfg)?;
    ensure!(
        real.admitted + real.rejected == real.offered
            && real.completed + real.missed + real.fallbacks == real.admitted,
        "real-plane accounting must balance: {}",
        real.render()
    );
    out.push_str(&format!("real plane: ok ({})", real.render().replace('\n', " | ")));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ServeConfig {
        ServeConfig::default().quick()
    }

    #[test]
    fn below_knee_meets_every_deadline_without_fallbacks() {
        let cfg = base().at_load(0.4);
        let r = simulate(&cfg);
        assert_eq!(r.rejected, 0, "{}", r.render());
        assert_eq!(r.missed, 0, "{}", r.render());
        assert_eq!(r.fallbacks, 0, "{}", r.render());
        assert!(r.p99_us <= cfg.deadline_us, "{}", r.render());
    }

    #[test]
    fn past_knee_speculation_holds_miss_rate_under_one_percent() {
        let r = simulate(&base().at_load(2.5));
        assert!(r.rejected > 0, "overload must trip admission: {}", r.render());
        assert!(r.miss_pct() < 1.0, "{}", r.render());
        // Degraded completions are the price; they must be the
        // recorded outcome, not hidden misses.
        assert_eq!(r.admitted, r.completed + r.missed + r.fallbacks);
    }

    #[test]
    fn speculative_fallback_set_is_deterministic() {
        let a = simulate(&base().at_load(2.0));
        let b = simulate(&base().at_load(2.0));
        assert!(a.fallbacks > 0, "load 2.0 must exercise speculation: {}", a.render());
        assert_eq!(a.degraded_ids, b.degraded_ids, "same seed, same degraded set");
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.missed, b.missed);
        assert_eq!(a.p999_us, b.p999_us);
    }

    #[test]
    fn fifo_baseline_misses_more_than_edf_with_speculation() {
        let edf = simulate(&base().at_load(1.5));
        let fifo = simulate(&base().at_load(1.5).baseline());
        assert!(edf.miss_pct() < 1.0, "edf: {}", edf.render());
        assert!(fifo.missed >= edf.missed, "edf {} vs fifo {}", edf.render(), fifo.render());
        assert!(fifo.missed > 0, "the baseline arm must show the cliff: {}", fifo.render());
    }

    #[test]
    fn edf_reordering_never_starves_an_admitted_request() {
        // Jackson's-rule check, hand-built: 1 worker, exact estimates,
        // six simultaneous arrivals whose deadlines are feasible in
        // *some* order. EDF must meet every one — including the widest
        // deadline, which it serves last.
        let mut q = AdmissionQueue::new(Policy::Edf, 1, 10_000);
        let deadlines = [70_000u64, 30_000, 110_000, 50_000, 130_000, 90_000];
        for (id, d) in deadlines.iter().enumerate() {
            let r = Request {
                id: id as u64,
                arrival_us: 0,
                deadline_us: *d,
                work_us: 10_000,
            };
            assert_eq!(q.offer(r, 0, 0), Decision::Admit, "request {id} is feasible");
        }
        let mut now = 0u64;
        let mut popped = Vec::new();
        while let Some(r) = q.pop() {
            now += r.work_us;
            let d = r.deadline_us;
            assert!(now <= d, "request {} done {now} > deadline {d}", r.id);
            popped.push(r.deadline_us);
        }
        let mut sorted = deadlines.to_vec();
        sorted.sort_unstable();
        assert_eq!(popped, sorted, "EDF serves in deadline order");
    }

    #[test]
    fn real_plane_balances_accounting_and_releases_containers() {
        let cfg = ServeConfig {
            nodes: 1,
            workers_per_node: 2,
            requests: 120,
            mean_service_us: 300,
            deadline_us: 1800,
            local_service_us: 60,
            ..ServeConfig::default()
        }
        .at_load(0.7);
        // run() fails if any container outlives the job.
        let r = ServePlane::run(&cfg).unwrap();
        assert_eq!(r.offered, 120);
        assert_eq!(r.admitted + r.rejected, r.offered);
        assert_eq!(r.completed + r.missed + r.fallbacks, r.admitted);
        assert!(r.makespan_us > 0);
    }
}
