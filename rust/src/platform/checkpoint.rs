//! Generic per-shard job checkpointing over the tiered store.
//!
//! The compactor has always had durable progress: it commits a log
//! offset after every block it lands, so a crashed or requeued worker
//! resumes instead of re-reading. [`ShardCheckpoint`] generalizes that
//! commit-offset pattern for every workload on the unified job layer:
//! a job commits one opaque blob per completed *work item* (keyed by a
//! stable item identity, e.g. a scenario's content hash), a preempted
//! or resubmitted job looks items up before redoing them, and a
//! successful job clears its keys.
//!
//! Checkpoints are ordinary [`TieredStore`] blocks (`ckpt/<job>/<item>`),
//! so they ride the same machinery as everything else: they land in
//! MEM, persist asynchronously to the under-store, and survive
//! eviction. Keying by item identity — not shard index — means a
//! resubmitted job may shard differently (smaller cluster, different
//! grant) and still skip every completed item.

use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

use crate::storage::TieredStore;

/// Every checkpoint blob lives under this store-key prefix.
pub const CKPT_PREFIX: &str = "ckpt/";

/// Durable per-item progress for one job (see module docs).
#[derive(Clone)]
pub struct ShardCheckpoint {
    store: Arc<TieredStore>,
    job: String,
    ttl: Option<Duration>,
}

impl ShardCheckpoint {
    pub fn new(store: &Arc<TieredStore>, job: &str) -> Self {
        Self { store: store.clone(), job: job.to_string(), ttl: None }
    }

    /// Like [`Self::new`], but every commit carries a retention TTL: the
    /// store's deadline index reaps expired blobs in O(expired) via
    /// [`TieredStore::expire_ttl`], so steady-state GC never scans the
    /// `ckpt/*` keyspace. [`Self::sweep`] stays as the fallback for
    /// blobs written by pre-TTL jobs (it also reaps TTL'd blobs, since
    /// they are ordinary store keys — the two paths are equivalent; see
    /// the `ttl_gc_matches_sweep_on_the_same_workload` test).
    pub fn with_ttl(store: &Arc<TieredStore>, job: &str, retention: Duration) -> Self {
        Self { store: store.clone(), job: job.to_string(), ttl: Some(retention) }
    }

    pub fn job(&self) -> &str {
        &self.job
    }

    fn key(&self, item: &str) -> String {
        format!("ckpt/{}/{item}", self.job)
    }

    /// Durably record a completed item's result. Call after the item's
    /// work is done and before yielding to a preemption signal.
    pub fn commit(&self, item: &str, bytes: Vec<u8>) -> Result<()> {
        match self.ttl {
            Some(retention) => self.store.put_ttl(&self.key(item), bytes, retention)?,
            None => self.store.put(&self.key(item), bytes)?,
        }
        self.store.counters().ckpt_commits.inc();
        Ok(())
    }

    /// A committed item's result, if any — the resume path.
    pub fn lookup(&self, item: &str) -> Option<Vec<u8>> {
        let key = self.key(item);
        if !self.store.contains(&key) {
            return None;
        }
        let bytes = self.store.get(&key).ok()?;
        self.store.counters().ckpt_hits.inc();
        Some(bytes.as_ref().clone())
    }

    pub fn contains(&self, item: &str) -> bool {
        self.store.contains(&self.key(item))
    }

    /// Drop the checkpoint after a successful run so a later job under
    /// the same name starts fresh. Callers pass the item universe (the
    /// keys are item-derived, so the job's input list enumerates them).
    pub fn clear<I, S>(&self, items: I)
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        for item in items {
            let _ = self.store.delete(&self.key(item.as_ref()));
        }
    }

    /// Garbage-collect orphaned checkpoints: delete every `ckpt/*` blob
    /// (across ALL jobs) whose durable copy is older than `retention`.
    /// Successful jobs clear their own keys; blobs that outlive the
    /// window belong to jobs that failed and were never resubmitted,
    /// and would otherwise occupy tier + under-store capacity forever.
    /// Returns the number of blobs reclaimed.
    ///
    /// Pending persists are flushed first so age is read from the
    /// durable copy; a blob with no readable timestamp is treated as
    /// fresh (never reclaimed by guesswork).
    pub fn sweep(store: &Arc<TieredStore>, retention: Duration) -> Result<u64> {
        store.flush();
        let mut reclaimed = 0u64;
        for key in store.keys_with_prefix(CKPT_PREFIX) {
            let old_enough = store
                .under()
                .age_of(&key)
                .map_or(false, |age| age >= retention);
            if old_enough {
                store.delete(&key)?;
                reclaimed += 1;
            }
        }
        store.counters().ckpt_swept.add(reclaimed);
        Ok(reclaimed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, StorageConfig, TierConfig};

    fn store() -> Arc<TieredStore> {
        TieredStore::test_store(&PlatformConfig::test().storage)
    }

    #[test]
    fn commit_lookup_clear_roundtrip() {
        let s = store();
        let ckpt = ShardCheckpoint::new(&s, "job-a");
        assert!(ckpt.lookup("item-1").is_none());
        ckpt.commit("item-1", b"verdict".to_vec()).unwrap();
        assert!(ckpt.contains("item-1"));
        assert_eq!(ckpt.lookup("item-1").unwrap(), b"verdict");
        ckpt.clear(["item-1", "item-2"]);
        assert!(!ckpt.contains("item-1"));
        assert!(ckpt.lookup("item-1").is_none());
    }

    #[test]
    fn checkpoints_are_namespaced_per_job() {
        let s = store();
        let a = ShardCheckpoint::new(&s, "job-a");
        let b = ShardCheckpoint::new(&s, "job-b");
        a.commit("item", b"from-a".to_vec()).unwrap();
        assert!(b.lookup("item").is_none(), "jobs must not see each other's progress");
        assert_eq!(a.lookup("item").unwrap(), b"from-a");
    }

    #[test]
    fn sweep_reclaims_orphans_and_spares_fresh_blobs() {
        let s = store();
        // A job that failed and was never resubmitted: its blobs are
        // orphans nothing will ever clear.
        let dead = ShardCheckpoint::new(&s, "never-resubmitted");
        for i in 0..5 {
            dead.commit(&format!("item-{i}"), vec![i as u8; 64]).unwrap();
        }
        // Unrelated non-checkpoint data must never be swept.
        s.put("ingest/p00/b0000000000", vec![7u8; 64]).unwrap();
        // Everything is younger than an hour: a sane retention window
        // reclaims nothing.
        assert_eq!(
            ShardCheckpoint::sweep(&s, Duration::from_secs(3600)).unwrap(),
            0,
            "fresh blobs must survive a long retention window"
        );
        assert!(dead.contains("item-0"));
        // Zero retention says "anything already durable is reclaimable":
        // all five orphans go, the ingest block stays.
        assert_eq!(ShardCheckpoint::sweep(&s, Duration::ZERO).unwrap(), 5);
        for i in 0..5 {
            assert!(!dead.contains(&format!("item-{i}")), "orphan item-{i} not reclaimed");
        }
        assert!(s.contains("ingest/p00/b0000000000"), "non-ckpt data must be untouched");
        assert_eq!(s.metrics().counter("platform.ckpt.swept").get(), 5);
        // A later job under the same name starts clean.
        let again = ShardCheckpoint::new(&s, "never-resubmitted");
        assert!(again.lookup("item-0").is_none());
    }

    #[test]
    fn ttl_gc_matches_sweep_on_the_same_workload() {
        // Same synthetic workload on two stores; one GC'd by the scan
        // sweep, one by the TTL deadline index. The surviving key sets
        // must be identical — the TTL path is a pure perf substitution.
        let workload = |s: &Arc<TieredStore>, ttl: Option<Duration>| {
            let dead = match ttl {
                Some(t) => ShardCheckpoint::with_ttl(s, "orphaned", t),
                None => ShardCheckpoint::new(s, "orphaned"),
            };
            for i in 0..6 {
                dead.commit(&format!("item-{i}"), vec![i as u8; 32]).unwrap();
            }
            // A job that finished cleanly clears its own keys before GC.
            let done = match ttl {
                Some(t) => ShardCheckpoint::with_ttl(s, "finished", t),
                None => ShardCheckpoint::new(s, "finished"),
            };
            done.commit("only", vec![9u8; 32]).unwrap();
            done.clear(["only"]);
            // Non-checkpoint data: neither GC path may touch it.
            s.put("ingest/p01/b0000000001", vec![7u8; 32]).unwrap();
            s.flush();
        };
        let keys = |s: &Arc<TieredStore>| {
            let mut all: Vec<String> = s.keys_with_prefix("");
            all.sort();
            all
        };

        let swept = store();
        workload(&swept, None);
        assert_eq!(ShardCheckpoint::sweep(&swept, Duration::ZERO).unwrap(), 6);

        let ttld = store();
        workload(&ttld, Some(Duration::ZERO));
        assert_eq!(ttld.expire_ttl().unwrap(), 6, "clear() must have cancelled 'only'");
        assert_eq!(ttld.metrics().counter("storage.tiered.ttl_expired").get(), 6);

        assert_eq!(keys(&swept), keys(&ttld), "sweep and TTL GC must agree");
        assert!(ttld.contains("ingest/p01/b0000000001"));
        assert!(ttld.keys_with_prefix(CKPT_PREFIX).is_empty());
        // Steady state: nothing pending, a second expire is a no-op that
        // never scans.
        assert_eq!(ttld.ttl_pending(), 0);
        assert_eq!(ttld.expire_ttl().unwrap(), 0);
    }

    #[test]
    fn checkpoint_survives_eviction_through_the_under_store() {
        // Tiny tiers: later commits push earlier ones out of the whole
        // stack; the async persist keeps them durable, exactly like any
        // other tiered block.
        let cfg = StorageConfig {
            mem: TierConfig { capacity_bytes: 128, bandwidth_bps: 1e12, latency_us: 0 },
            ssd: TierConfig { capacity_bytes: 128, bandwidth_bps: 1e12, latency_us: 0 },
            hdd: TierConfig { capacity_bytes: 128, bandwidth_bps: 1e12, latency_us: 0 },
            dfs: TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e12, latency_us: 0 },
            ..StorageConfig::default()
        };
        let s = TieredStore::test_store(&cfg);
        let ckpt = ShardCheckpoint::new(&s, "evicted");
        for i in 0..8 {
            ckpt.commit(&format!("item-{i}"), vec![i as u8; 100]).unwrap();
        }
        s.flush();
        for i in 0..8 {
            assert_eq!(
                ckpt.lookup(&format!("item-{i}")).unwrap(),
                vec![i as u8; 100],
                "item-{i} must survive eviction"
            );
        }
    }
}
