//! Generic per-shard job checkpointing over the tiered store.
//!
//! The compactor has always had durable progress: it commits a log
//! offset after every block it lands, so a crashed or requeued worker
//! resumes instead of re-reading. [`ShardCheckpoint`] generalizes that
//! commit-offset pattern for every workload on the unified job layer:
//! a job commits one opaque blob per completed *work item* (keyed by a
//! stable item identity, e.g. a scenario's content hash), a preempted
//! or resubmitted job looks items up before redoing them, and a
//! successful job clears its keys.
//!
//! Checkpoints are ordinary [`TieredStore`] blocks (`ckpt/<job>/<item>`),
//! so they ride the same machinery as everything else: they land in
//! MEM, persist asynchronously to the under-store, and survive
//! eviction. Keying by item identity — not shard index — means a
//! resubmitted job may shard differently (smaller cluster, different
//! grant) and still skip every completed item.

use anyhow::Result;
use std::sync::Arc;

use crate::storage::TieredStore;

/// Durable per-item progress for one job (see module docs).
#[derive(Clone)]
pub struct ShardCheckpoint {
    store: Arc<TieredStore>,
    job: String,
}

impl ShardCheckpoint {
    pub fn new(store: &Arc<TieredStore>, job: &str) -> Self {
        Self { store: store.clone(), job: job.to_string() }
    }

    pub fn job(&self) -> &str {
        &self.job
    }

    fn key(&self, item: &str) -> String {
        format!("ckpt/{}/{item}", self.job)
    }

    /// Durably record a completed item's result. Call after the item's
    /// work is done and before yielding to a preemption signal.
    pub fn commit(&self, item: &str, bytes: Vec<u8>) -> Result<()> {
        self.store.put(&self.key(item), bytes)?;
        self.store.metrics().counter("platform.ckpt.commits").inc();
        Ok(())
    }

    /// A committed item's result, if any — the resume path.
    pub fn lookup(&self, item: &str) -> Option<Vec<u8>> {
        let key = self.key(item);
        if !self.store.contains(&key) {
            return None;
        }
        let bytes = self.store.get(&key).ok()?;
        self.store.metrics().counter("platform.ckpt.hits").inc();
        Some(bytes.as_ref().clone())
    }

    pub fn contains(&self, item: &str) -> bool {
        self.store.contains(&self.key(item))
    }

    /// Drop the checkpoint after a successful run so a later job under
    /// the same name starts fresh. Callers pass the item universe (the
    /// keys are item-derived, so the job's input list enumerates them).
    pub fn clear<I, S>(&self, items: I)
    where
        I: IntoIterator<Item = S>,
        S: AsRef<str>,
    {
        for item in items {
            let _ = self.store.delete(&self.key(item.as_ref()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, StorageConfig, TierConfig};

    fn store() -> Arc<TieredStore> {
        TieredStore::test_store(&PlatformConfig::test().storage)
    }

    #[test]
    fn commit_lookup_clear_roundtrip() {
        let s = store();
        let ckpt = ShardCheckpoint::new(&s, "job-a");
        assert!(ckpt.lookup("item-1").is_none());
        ckpt.commit("item-1", b"verdict".to_vec()).unwrap();
        assert!(ckpt.contains("item-1"));
        assert_eq!(ckpt.lookup("item-1").unwrap(), b"verdict");
        ckpt.clear(["item-1", "item-2"]);
        assert!(!ckpt.contains("item-1"));
        assert!(ckpt.lookup("item-1").is_none());
    }

    #[test]
    fn checkpoints_are_namespaced_per_job() {
        let s = store();
        let a = ShardCheckpoint::new(&s, "job-a");
        let b = ShardCheckpoint::new(&s, "job-b");
        a.commit("item", b"from-a".to_vec()).unwrap();
        assert!(b.lookup("item").is_none(), "jobs must not see each other's progress");
        assert_eq!(a.lookup("item").unwrap(), b"from-a");
    }

    #[test]
    fn checkpoint_survives_eviction_through_the_under_store() {
        // Tiny tiers: later commits push earlier ones out of the whole
        // stack; the async persist keeps them durable, exactly like any
        // other tiered block.
        let cfg = StorageConfig {
            mem: TierConfig { capacity_bytes: 128, bandwidth_bps: 1e12, latency_us: 0 },
            ssd: TierConfig { capacity_bytes: 128, bandwidth_bps: 1e12, latency_us: 0 },
            hdd: TierConfig { capacity_bytes: 128, bandwidth_bps: 1e12, latency_us: 0 },
            dfs: TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e12, latency_us: 0 },
            model_devices: false,
        };
        let s = TieredStore::test_store(&cfg);
        let ckpt = ShardCheckpoint::new(&s, "evicted");
        for i in 0..8 {
            ckpt.commit(&format!("item-{i}"), vec![i as u8; 100]).unwrap();
        }
        s.flush();
        for i in 0..8 {
            assert_eq!(
                ckpt.lookup(&format!("item-{i}")).unwrap(),
                vec![i as u8; 100],
                "item-{i} must survive eviction"
            );
        }
    }
}
