//! Shared job-submission options.
//!
//! Before this module, every workload carried its own copy of the same
//! submission fields — `CampaignConfig.nodes`, `CompactorConfig.workers`,
//! `MinerConfig.workers`, a bare `workers: usize` on the training
//! entry points — with drifting names and defaults. [`JobOpts`] is the
//! one shared builder: app name, capacity queue, worker ceiling,
//! checkpointing, and grant timeout, with a uniform `Default` and a
//! JSON codec that still accepts the pre-redesign field spellings
//! (`nodes` for `workers`, missing keys fall back to the defaults), so
//! configs saved by older builds keep loading.
//!
//! Workload configs embed it (`CampaignConfig.opts`, …); entry points
//! without a config struct take it directly. [`JobOpts::spec`] turns it
//! into the base [`JobSpec`] every submission starts from.

use anyhow::Result;
use std::time::Duration;

use super::job::JobSpec;
use crate::util::json::Json;

/// The submission fields every workload shares. Domain knobs (batch
/// sizes, detection thresholds, …) stay in the workload's own config.
#[derive(Debug, Clone, PartialEq)]
pub struct JobOpts {
    /// Application name registered with the resource manager.
    pub app: String,
    /// Capacity-share queue the job is charged against.
    pub queue: String,
    /// Requested worker-container ceiling (one shard per container;
    /// degrades gracefully on a smaller cluster).
    pub workers: usize,
    /// Whether the workload commits progress to a `ShardCheckpoint`
    /// (ignored by workloads that have nothing to checkpoint).
    pub checkpoint: bool,
    /// How long submission may block waiting for the grant floor.
    pub grant_timeout: Duration,
}

impl Default for JobOpts {
    fn default() -> Self {
        Self {
            app: "job".into(),
            queue: "default".into(),
            workers: 1,
            checkpoint: true,
            grant_timeout: Duration::from_secs(10),
        }
    }
}

impl JobOpts {
    pub fn new(app: impl Into<String>) -> Self {
        Self { app: app.into(), ..Default::default() }
    }

    pub fn queue(mut self, queue: impl Into<String>) -> Self {
        self.queue = queue.into();
        self
    }

    /// Worker ceiling, floored at 1.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn checkpoint(mut self, checkpoint: bool) -> Self {
        self.checkpoint = checkpoint;
        self
    }

    pub fn grant_timeout(mut self, timeout: Duration) -> Self {
        self.grant_timeout = timeout;
        self
    }

    /// The base [`JobSpec`] for these options: elastic `1..=workers`
    /// containers on `queue`. Callers chain `.resources(..)` (and
    /// tighten `.containers(..)` when the work list is shorter than the
    /// worker ceiling).
    pub fn spec(&self) -> JobSpec {
        JobSpec::new(self.app.as_str())
            .queue(self.queue.as_str())
            .containers(1, self.workers)
            .grant_timeout(self.grant_timeout)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", Json::str(&self.app)),
            ("queue", Json::str(&self.queue)),
            ("workers", Json::num(self.workers as f64)),
            ("checkpoint", Json::Bool(self.checkpoint)),
            ("grant_timeout_ms", Json::num(self.grant_timeout.as_millis() as f64)),
        ])
    }

    /// Parse from JSON, tolerating the pre-redesign spellings: `nodes`
    /// aliases `workers` (the old `CampaignConfig` name), `name`
    /// aliases `app`, every key is optional (defaults apply), and
    /// unrecognised workload-domain keys are ignored — so the JSON
    /// shape of any legacy workload config parses directly.
    pub fn from_json(j: &Json) -> Result<Self> {
        let d = Self::default();
        let str_of = |keys: &[&str], dflt: &str| -> Result<String> {
            for k in keys {
                if let Some(v) = j.get(k) {
                    return Ok(v.as_str()?.to_string());
                }
            }
            Ok(dflt.to_string())
        };
        let workers = match j.get("workers").or_else(|| j.get("nodes")) {
            Some(v) => v.as_usize()?,
            None => d.workers,
        };
        let checkpoint = match j.get("checkpoint") {
            Some(v) => v.as_bool()?,
            None => d.checkpoint,
        };
        let grant_timeout = match j.get("grant_timeout_ms") {
            Some(v) => Duration::from_millis(v.as_u64()?),
            None => d.grant_timeout,
        };
        Ok(Self {
            app: str_of(&["app", "name"], &d.app)?,
            queue: str_of(&["queue"], &d.queue)?,
            workers: workers.max(1),
            checkpoint,
            grant_timeout,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_defaults() {
        let o = JobOpts::new("camp").queue("sim").workers(4).checkpoint(false);
        assert_eq!(o.app, "camp");
        assert_eq!(o.queue, "sim");
        assert_eq!(o.workers, 4);
        assert!(!o.checkpoint);
        assert_eq!(o.grant_timeout, Duration::from_secs(10));
        // Floors.
        assert_eq!(JobOpts::default().workers(0).workers, 1);
    }

    #[test]
    fn spec_carries_every_shared_field() {
        let o = JobOpts::new("x")
            .queue("interactive")
            .workers(8)
            .grant_timeout(Duration::from_secs(2));
        let s = o.spec();
        assert_eq!(s.app, "x");
        assert_eq!(s.queue, "interactive");
        assert_eq!((s.min_containers, s.max_containers), (1, 8));
        assert_eq!(s.grant_timeout, Duration::from_secs(2));
    }

    #[test]
    fn json_roundtrip() {
        let o = JobOpts::new("rt").queue("q").workers(3).grant_timeout(Duration::from_millis(750));
        let back = JobOpts::from_json(&Json::parse(&o.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn legacy_config_shapes_parse() {
        // The JSON shape of each pre-redesign workload config must
        // parse: shared fields extracted, domain fields ignored.
        let campaign = r#"{"app":"camp","queue":"sim","nodes":4,
            "pass_accuracy":0.6,"work_dir":"/tmp/x","checkpoint":true}"#;
        let o = JobOpts::from_json(&Json::parse(campaign).unwrap()).unwrap();
        assert_eq!((o.app.as_str(), o.queue.as_str(), o.workers), ("camp", "sim", 4));
        assert!(o.checkpoint);

        let compactor = r#"{"app":"cp","queue":"fleet","workers":2,
            "batch_records":256,"block_prefix":"ingest"}"#;
        let o = JobOpts::from_json(&Json::parse(compactor).unwrap()).unwrap();
        assert_eq!((o.app.as_str(), o.queue.as_str(), o.workers), ("cp", "fleet", 2));

        let miner = r#"{"app":"scenario-miner","queue":"default","workers":4,
            "hard_brake_mps2":-6.0,"dropout_ms":500,"checkpoint":false}"#;
        let o = JobOpts::from_json(&Json::parse(miner).unwrap()).unwrap();
        assert_eq!(o.app, "scenario-miner");
        assert!(!o.checkpoint);

        let training = r#"{"name":"training-unified","workers":2}"#;
        let o = JobOpts::from_json(&Json::parse(training).unwrap()).unwrap();
        assert_eq!((o.app.as_str(), o.workers), ("training-unified", 2));

        // Empty object: pure defaults.
        let o = JobOpts::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(o, JobOpts::default());
    }
}
