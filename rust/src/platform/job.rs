//! The unified job layer: an Application-Master analog over the
//! YARN-analog resource manager and the DCE executor pool.
//!
//! Every platform workload — scenario campaigns, fleet compaction,
//! scenario mining, training pipelines, HD-map generation — schedules
//! through the same two types instead of hand-rolling container
//! choreography:
//!
//! * [`JobSpec`] declares what the job needs: app name, capacity queue,
//!   an elastic container range (`min..=max`), a per-container
//!   [`ResourceVec`] (cores, memory, GPU/FPGA slots), a shard retry
//!   budget, and how long to block when the cluster is briefly full.
//! * [`JobHandle`] owns the full lifecycle: it registers the app,
//!   acquires an elastic [`Grant`] (greedy up to `max`, blocking
//!   escalation to the `min` floor), shards work lists across the grant
//!   via the DCE executor pool, converts shard panics into job errors,
//!   and — because the grant and app lease are RAII guards — releases
//!   every container on every exit path, including `?` and unwinding.
//!
//! Per-job metrics land in the resource manager's [`MetricsRegistry`]:
//! `platform.job.grant_wait` (histogram), `platform.job.shard_retries`,
//! `platform.job.shard_panics`, `platform.job.container_ms`, and
//! `platform.job.jobs` (counters). [`JobHandle::finish`] returns the
//! same numbers per job as a [`JobStats`].

use anyhow::{anyhow, Context, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::dce::{Data, DceContext};
use crate::metrics::MetricsRegistry;
use crate::resource::{
    AppLease, ContainerCtx, ContainerRef, Grant, ResourceManager, ResourceVec,
};

/// Declarative description of a job's resource needs.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Application name registered with the resource manager (freed for
    /// resubmission when the job finishes or fails).
    pub app: String,
    /// Capacity-share queue the app is charged against.
    pub queue: String,
    /// Grant floor: block (up to `grant_timeout`) until at least this
    /// many containers are held.
    pub min_containers: usize,
    /// Grant ceiling: take up to this many containers when free.
    pub max_containers: usize,
    /// Resources per container.
    pub resources: ResourceVec,
    /// Extra attempts per shard before the job fails.
    pub max_shard_retries: usize,
    /// How long `submit` may block waiting for the grant floor.
    pub grant_timeout: Duration,
}

impl JobSpec {
    pub fn new(app: impl Into<String>) -> Self {
        Self {
            app: app.into(),
            queue: "default".into(),
            min_containers: 1,
            max_containers: 1,
            resources: ResourceVec::cores(1, 32 << 20),
            max_shard_retries: 1,
            grant_timeout: Duration::from_secs(10),
        }
    }

    pub fn queue(mut self, queue: impl Into<String>) -> Self {
        self.queue = queue.into();
        self
    }

    /// Elastic container range (both floored at 1; `max >= min`).
    pub fn containers(mut self, min: usize, max: usize) -> Self {
        self.min_containers = min.max(1);
        self.max_containers = max.max(self.min_containers);
        self
    }

    pub fn resources(mut self, resources: ResourceVec) -> Self {
        self.resources = resources;
        self
    }

    pub fn retries(mut self, max_shard_retries: usize) -> Self {
        self.max_shard_retries = max_shard_retries;
        self
    }

    pub fn grant_timeout(mut self, timeout: Duration) -> Self {
        self.grant_timeout = timeout;
        self
    }
}

/// What a finished job cost.
#[derive(Debug, Clone)]
pub struct JobStats {
    pub app: String,
    pub queue: String,
    /// Containers actually granted (elastic: `min..=max` of the spec).
    pub containers: usize,
    /// How long `submit` blocked acquiring the grant.
    pub grant_wait: Duration,
    pub shard_retries: u64,
    /// Containers held x wall time, in seconds.
    pub container_seconds: f64,
    pub elapsed: Duration,
}

impl JobStats {
    pub fn render(&self) -> String {
        format!(
            "job '{}' on queue '{}': {} container(s), grant wait {}, {} shard retr{}, \
             {:.2} container-seconds in {}",
            self.app,
            self.queue,
            self.containers,
            crate::util::fmt_duration(self.grant_wait),
            self.shard_retries,
            if self.shard_retries == 1 { "y" } else { "ies" },
            self.container_seconds,
            crate::util::fmt_duration(self.elapsed),
        )
    }
}

/// Context handed to a shard closure: which shard this is and the
/// container whose accounting it runs under.
pub struct ShardCtx {
    pub shard: usize,
    pub shards: usize,
    /// 0 on the first try, incremented per job-layer retry.
    pub attempt: usize,
    container: ContainerRef,
}

impl ShardCtx {
    pub fn container(&self) -> &ContainerRef {
        &self.container
    }

    /// Run a closure inside this shard's container (memory limits,
    /// cgroup-style accounting).
    pub fn run<T>(&self, f: impl FnOnce(&ContainerCtx) -> T) -> Result<T> {
        self.container.run(f)
    }
}

/// A live job: app registered, grant held. Dropping the handle (on any
/// path) releases the containers and unregisters the app, in that
/// order — the field order below is load-bearing.
pub struct JobHandle {
    grant: Grant,
    #[allow(dead_code)] // held for its Drop side effect
    app: AppLease,
    spec: JobSpec,
    metrics: MetricsRegistry,
    retries: Arc<AtomicU64>,
    started: Instant,
}

impl JobHandle {
    /// Register the app and acquire its elastic grant: everything free
    /// right now up to `max_containers`, then blocking escalation until
    /// the `min_containers` floor is met or `grant_timeout` expires.
    pub fn submit(rm: &Arc<ResourceManager>, spec: JobSpec) -> Result<JobHandle> {
        let metrics = rm.metrics().clone();
        let app = AppLease::submit(rm, &spec.app, &spec.queue)?;
        let grant = Grant::acquire(
            rm,
            &spec.app,
            spec.resources,
            spec.min_containers,
            spec.max_containers,
            spec.grant_timeout,
        )
        .with_context(|| format!("acquiring grant for job '{}'", spec.app))?;
        metrics.histogram("platform.job.grant_wait").record(grant.wait());
        metrics.counter("platform.job.jobs").inc();
        Ok(JobHandle {
            grant,
            app,
            spec,
            metrics,
            retries: Arc::new(AtomicU64::new(0)),
            started: Instant::now(),
        })
    }

    /// Containers actually granted — also the shard count.
    pub fn shards(&self) -> usize {
        self.grant.len()
    }

    pub fn containers(&self) -> &[ContainerRef] {
        self.grant.containers()
    }

    pub fn grant_wait(&self) -> Duration {
        self.grant.wait()
    }

    /// Shard `items` across the grant via the DCE executor pool: one
    /// partition per container, each shard closure retried within the
    /// job's budget, panics converted into job errors. Output order
    /// follows input order.
    pub fn run_sharded<T: Data, U: Data>(
        &self,
        ctx: &DceContext,
        items: Vec<T>,
        f: impl Fn(&ShardCtx, Vec<T>) -> Result<Vec<U>> + Send + Sync + 'static,
    ) -> Result<Vec<U>> {
        let conts: Vec<ContainerRef> = self.grant.containers().to_vec();
        let shards = conts.len();
        let budget = self.spec.max_shard_retries;
        let retries = self.retries.clone();
        let metrics = self.metrics.clone();
        ctx.parallelize(items, shards)
            .map_partitions(move |part, items: Vec<T>| {
                let container = &conts[part % conts.len()];
                // Clone the shard's input only while a retry could still
                // follow; the final permitted attempt takes it by move.
                let items = std::sync::Mutex::new(Some(items));
                run_attempts(part, shards, container, budget, &retries, &metrics, |sctx| {
                    let input = if sctx.attempt >= budget {
                        items.lock().unwrap().take().expect("final attempt input")
                    } else {
                        items.lock().unwrap().as_ref().expect("attempt input").clone()
                    };
                    f(sctx, input)
                })
            })
            .collect()
    }

    /// One closure per granted container on dedicated threads — for
    /// workloads that poll or stream rather than consume a fixed list
    /// (e.g. the compactor draining its share of log partitions). Same
    /// retry budget and panic containment as [`Self::run_sharded`].
    pub fn run_per_container<U: Send>(
        &self,
        f: impl Fn(&ShardCtx) -> Result<U> + Send + Sync,
    ) -> Result<Vec<U>> {
        let conts = self.grant.containers();
        let shards = conts.len();
        let budget = self.spec.max_shard_retries;
        let results: Vec<std::thread::Result<Result<U>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..shards)
                .map(|w| {
                    let f = &f;
                    let container = &conts[w];
                    let retries = &self.retries;
                    let metrics = &self.metrics;
                    s.spawn(move || {
                        run_attempts(w, shards, container, budget, retries, metrics, f)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        let mut out = Vec::with_capacity(shards);
        let mut first_err: Option<anyhow::Error> = None;
        for r in results {
            match r {
                Ok(Ok(v)) => out.push(v),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(payload) => {
                    first_err.get_or_insert(anyhow!(
                        "job worker panicked: {}",
                        panic_msg(payload.as_ref())
                    ));
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    /// Run one closure inside the first granted container — the shape
    /// of a sequential single-container stage.
    pub fn run_single<T>(&self, f: impl FnOnce(&ContainerCtx) -> Result<T>) -> Result<T> {
        let c = self
            .grant
            .containers()
            .first()
            .ok_or_else(|| anyhow!("job '{}' holds no containers", self.spec.app))?;
        c.run(f)?
    }

    /// Finish the job: record container-seconds, return the stats, and
    /// release the grant + app registration (RAII).
    pub fn finish(self) -> JobStats {
        let elapsed = self.started.elapsed();
        let containers = self.grant.len();
        let container_seconds = elapsed.as_secs_f64() * containers as f64;
        self.metrics
            .counter("platform.job.container_ms")
            .add((container_seconds * 1000.0) as u64);
        JobStats {
            app: self.spec.app.clone(),
            queue: self.spec.queue.clone(),
            containers,
            grant_wait: self.grant.wait(),
            shard_retries: self.retries.load(Ordering::Relaxed),
            container_seconds,
            elapsed,
        }
    }
}

/// Submit + run one closure in one container + finish: the shape of a
/// pre-unification per-stage job (the staged pipeline baselines submit
/// one of these per stage, paying the grant churn the unified path
/// avoids).
pub fn run_stage<T>(
    rm: &Arc<ResourceManager>,
    spec: JobSpec,
    f: impl FnOnce(&ContainerCtx) -> Result<T>,
) -> Result<T> {
    let job = JobHandle::submit(rm, spec)?;
    let out = job.run_single(f);
    let _ = job.finish();
    out
}

/// Retry loop shared by the sharded and per-container runners: panics
/// are caught and converted to errors so the RAII guards — not luck —
/// decide when containers go back to the pool.
fn run_attempts<U>(
    shard: usize,
    shards: usize,
    container: &ContainerRef,
    budget: usize,
    retries: &AtomicU64,
    metrics: &MetricsRegistry,
    attempt_fn: impl Fn(&ShardCtx) -> Result<U>,
) -> Result<U> {
    let mut last: Option<anyhow::Error> = None;
    for attempt in 0..=budget {
        if attempt > 0 {
            retries.fetch_add(1, Ordering::Relaxed);
            metrics.counter("platform.job.shard_retries").inc();
        }
        let sctx = ShardCtx { shard, shards, attempt, container: container.clone() };
        match catch_unwind(AssertUnwindSafe(|| attempt_fn(&sctx))) {
            Ok(Ok(v)) => return Ok(v),
            Ok(Err(e)) => last = Some(e),
            Err(payload) => {
                metrics.counter("platform.job.shard_panics").inc();
                last = Some(anyhow!("shard {shard} panicked: {}", panic_msg(payload.as_ref())));
            }
        }
    }
    let e = last.expect("at least one attempt ran");
    Err(e.context(format!("shard {shard} failed after {} attempt(s)", budget + 1)))
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn rm() -> Arc<ResourceManager> {
        ResourceManager::new(&PlatformConfig::test().cluster, MetricsRegistry::new())
    }

    #[test]
    fn spec_builder_clamps_ranges() {
        let s = JobSpec::new("j").containers(0, 0);
        assert_eq!((s.min_containers, s.max_containers), (1, 1));
        let s = JobSpec::new("j").containers(3, 2);
        assert_eq!((s.min_containers, s.max_containers), (3, 3));
    }

    #[test]
    fn sharded_job_runs_and_releases() {
        let rm = rm();
        let ctx = DceContext::local().unwrap();
        let job = JobHandle::submit(&rm, JobSpec::new("j").containers(1, 3)).unwrap();
        assert!(job.shards() >= 1);
        let out = job
            .run_sharded(&ctx, (0..50u64).collect(), |sctx, items: Vec<u64>| {
                assert!(sctx.shard < sctx.shards);
                sctx.run(|_| items.into_iter().map(|x| x + 1).collect())
            })
            .unwrap();
        assert_eq!(out, (1..=50).collect::<Vec<u64>>());
        let stats = job.finish();
        assert_eq!(stats.shard_retries, 0);
        assert!(stats.containers >= 1);
        assert_eq!(rm.live_containers(), 0);
    }

    #[test]
    fn duplicate_submit_fails_until_finished() {
        let rm = rm();
        let job = JobHandle::submit(&rm, JobSpec::new("dup")).unwrap();
        assert!(JobHandle::submit(&rm, JobSpec::new("dup")).is_err());
        let _ = job.finish();
        let again = JobHandle::submit(&rm, JobSpec::new("dup")).unwrap();
        let _ = again.finish();
    }

    #[test]
    fn shard_retry_budget_is_counted() {
        let rm = rm();
        let ctx = DceContext::local().unwrap();
        let job =
            JobHandle::submit(&rm, JobSpec::new("flaky").containers(1, 1).retries(2)).unwrap();
        let calls = Arc::new(AtomicU64::new(0));
        let c2 = calls.clone();
        let out = job
            .run_sharded(&ctx, vec![7u32], move |_sctx, items: Vec<u32>| {
                if c2.fetch_add(1, Ordering::SeqCst) < 2 {
                    anyhow::bail!("transient");
                }
                Ok(items)
            })
            .unwrap();
        assert_eq!(out, vec![7]);
        let stats = job.finish();
        assert_eq!(stats.shard_retries, 2);
        assert_eq!(rm.live_containers(), 0);
    }

    #[test]
    fn run_single_uses_the_first_container() {
        let rm = rm();
        let job = JobHandle::submit(&rm, JobSpec::new("single")).unwrap();
        let v = job.run_single(|cctx| {
            cctx.alloc_mem(1024)?;
            cctx.free_mem(1024);
            Ok(99)
        });
        assert_eq!(v.unwrap(), 99);
        let _ = job.finish();
        assert_eq!(rm.live_containers(), 0);
    }

    #[test]
    fn run_stage_is_a_self_contained_job() {
        let rm = rm();
        let out = run_stage(&rm, JobSpec::new("stage"), |_c| Ok(5u32)).unwrap();
        assert_eq!(out, 5);
        assert_eq!(rm.live_containers(), 0);
        assert_eq!(rm.metrics().counter("platform.job.jobs").get(), 1);
    }
}
