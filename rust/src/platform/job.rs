//! The unified job layer: an Application-Master analog over the
//! YARN-analog resource manager and the DCE executor pool.
//!
//! Every platform workload — scenario campaigns, fleet compaction,
//! scenario mining, training pipelines, HD-map generation — schedules
//! through the same two types instead of hand-rolling container
//! choreography:
//!
//! * [`JobSpec`] declares what the job needs: app name, capacity queue,
//!   an elastic container range (`min..=max`), a per-container
//!   [`ResourceVec`] (cores, memory, GPU/FPGA slots), a shard retry
//!   budget, and how long to block when the cluster is briefly full.
//! * [`JobHandle`] owns the full lifecycle: it registers the app,
//!   acquires a gang-atomic elastic [`Grant`] (the `min` floor is
//!   reserved all-or-nothing, extras up to `max` are taken greedily),
//!   shards work lists across the grant via the DCE executor pool,
//!   converts shard panics into job errors, and — because the grant
//!   and app lease are RAII guards — releases every container on every
//!   exit path, including `?` and unwinding.
//!
//! **Preemption.** When the resource manager flags a shard's container
//! (fair-share reclaim for a queue below its guarantee), the failure is
//! NOT charged against the shard's retry budget: the job layer releases
//! the flagged container to the reclaiming queue, blocks for a
//! replacement, and requeues the shard. Workloads cooperate by calling
//! [`ShardCtx::check_preempted`] between work items — after committing
//! a [`super::ShardCheckpoint`] — so a requeued shard resumes from
//! completed work instead of redoing it.
//!
//! Per-job metrics land in the resource manager's [`MetricsRegistry`]:
//! `platform.job.grant_wait` and `platform.job.preempt_requeue_wait`
//! (histograms), `platform.job.shard_retries`, `platform.job.preemptions`,
//! `platform.job.shard_panics`, `platform.job.container_ms`, and
//! `platform.job.jobs` (counters). [`JobHandle::finish`] returns the
//! same numbers per job as a [`JobStats`].

use anyhow::{anyhow, Context, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dce::{Data, DceContext};
use crate::metrics::JobMetrics;
use crate::resource::{
    AppLease, ContainerCtx, ContainerRef, Grant, ResourceManager, ResourceVec,
};
use crate::trace::{self, critical_path::CriticalPath, SpanCtx};

/// A shard may be preempted repeatedly while a sibling queue churns;
/// past this many requeues the job layer treats the signal as livelock
/// and fails the shard instead of cycling forever.
const MAX_PREEMPT_REQUEUES: usize = 32;

/// Declarative description of a job's resource needs.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Application name registered with the resource manager (freed for
    /// resubmission when the job finishes or fails).
    pub app: String,
    /// Capacity-share queue the app is charged against.
    pub queue: String,
    /// Grant floor: block (up to `grant_timeout`) until this many
    /// containers can be reserved gang-atomically.
    pub min_containers: usize,
    /// Grant ceiling: take up to this many containers when free.
    pub max_containers: usize,
    /// Resources per container.
    pub resources: ResourceVec,
    /// Extra attempts per shard before the job fails (preemption
    /// requeues are never charged against this budget).
    pub max_shard_retries: usize,
    /// How long `submit` may block waiting for the grant floor (also
    /// the budget for reacquiring a preempted shard's replacement).
    pub grant_timeout: Duration,
}

impl JobSpec {
    pub fn new(app: impl Into<String>) -> Self {
        Self {
            app: app.into(),
            queue: "default".into(),
            min_containers: 1,
            max_containers: 1,
            resources: ResourceVec::cores(1, 32 << 20),
            max_shard_retries: 1,
            grant_timeout: Duration::from_secs(10),
        }
    }

    pub fn queue(mut self, queue: impl Into<String>) -> Self {
        self.queue = queue.into();
        self
    }

    /// Elastic container range (both floored at 1; `max >= min`).
    pub fn containers(mut self, min: usize, max: usize) -> Self {
        self.min_containers = min.max(1);
        self.max_containers = max.max(self.min_containers);
        self
    }

    pub fn resources(mut self, resources: ResourceVec) -> Self {
        self.resources = resources;
        self
    }

    pub fn retries(mut self, max_shard_retries: usize) -> Self {
        self.max_shard_retries = max_shard_retries;
        self
    }

    pub fn grant_timeout(mut self, timeout: Duration) -> Self {
        self.grant_timeout = timeout;
        self
    }
}

/// What a finished job cost.
#[derive(Debug, Clone)]
pub struct JobStats {
    pub app: String,
    pub queue: String,
    /// Containers actually granted (elastic: `min..=max` of the spec).
    pub containers: usize,
    /// How long `submit` blocked acquiring the grant.
    pub grant_wait: Duration,
    pub shard_retries: u64,
    /// Times a shard yielded its container to a reclaiming queue and
    /// was requeued on a replacement.
    pub preemptions: u64,
    /// Containers held x wall time, in seconds.
    pub container_seconds: f64,
    pub elapsed: Duration,
    /// Per-category makespan attribution from the job's span DAG.
    /// `None` unless the global tracer was enabled while the job ran.
    pub critical_path: Option<CriticalPath>,
}

impl JobStats {
    pub fn render(&self) -> String {
        let mut s = format!(
            "job '{}' on queue '{}': {} container(s), grant wait {}, {} shard retr{}, \
             {} preemption(s), {:.2} container-seconds in {}",
            self.app,
            self.queue,
            self.containers,
            crate::util::fmt_duration(self.grant_wait),
            self.shard_retries,
            if self.shard_retries == 1 { "y" } else { "ies" },
            self.preemptions,
            self.container_seconds,
            crate::util::fmt_duration(self.elapsed),
        );
        if let Some(cp) = &self.critical_path {
            if cp.total_us > 0 {
                s.push_str("\n  ");
                s.push_str(&cp.render());
            }
        }
        s
    }
}

/// Context handed to a shard closure: which shard this is and the
/// container whose accounting it runs under.
pub struct ShardCtx {
    pub shard: usize,
    pub shards: usize,
    /// 0 on the first try, incremented per job-layer retry (preemption
    /// requeues do NOT increment it).
    pub attempt: usize,
    container: ContainerRef,
    /// Trace context of this attempt's `job.shard` span.
    trace: SpanCtx,
}

impl ShardCtx {
    pub fn container(&self) -> &ContainerRef {
        &self.container
    }

    /// Trace parent for spans the shard closure opens on *other*
    /// threads (same-thread spans nest under the attempt implicitly).
    pub fn trace(&self) -> SpanCtx {
        self.trace
    }

    /// Run a closure inside this shard's container (memory limits,
    /// cgroup-style accounting).
    pub fn run<T>(&self, f: impl FnOnce(&ContainerCtx) -> T) -> Result<T> {
        self.container.run(f)
    }

    /// Whether the resource manager has asked this shard's container to
    /// yield to a reclaiming queue. Poll between work items.
    pub fn preempt_requested(&self) -> bool {
        self.container.preempt_requested()
    }

    /// Yield point: errors when the container has been flagged for
    /// preemption. Call between work items, after committing progress
    /// to a shard checkpoint — the job layer recognises the flagged
    /// container, releases it, and requeues this shard on a
    /// replacement without charging the retry budget.
    pub fn check_preempted(&self) -> Result<()> {
        if self.container.preempt_requested() {
            anyhow::bail!(
                "shard {} preempted (container {} asked to yield)",
                self.shard,
                self.container.id
            );
        }
        Ok(())
    }
}

/// A live job: app registered, grant held. Dropping the handle (on any
/// path) releases the containers and unregisters the app, in that
/// order — the field order below is load-bearing.
pub struct JobHandle {
    grant: Grant,
    #[allow(dead_code)] // held for its Drop side effect
    app: AppLease,
    rm: Arc<ResourceManager>,
    spec: JobSpec,
    metrics: JobMetrics,
    retries: Arc<AtomicU64>,
    preemptions: Arc<AtomicU64>,
    started: Instant,
    /// Root `job` span, open from submit to finish. Declared last so
    /// it closes after the grant and lease have released; a handle
    /// must finish on the thread that submitted it (it always does —
    /// each tenant drives its job from its own thread).
    span: trace::SpanGuard,
}

impl JobHandle {
    /// Register the app and acquire its elastic grant: the
    /// `min_containers` floor is reserved gang-atomically (blocking up
    /// to `grant_timeout`; nothing is held while waiting), then extras
    /// up to `max_containers` are taken greedily.
    pub fn submit(rm: &Arc<ResourceManager>, spec: JobSpec) -> Result<JobHandle> {
        // Root of the job's trace: admission, every shard attempt, and
        // requeue nests under it (explicitly via `SpanCtx`, or
        // implicitly for spans opened on the submitting thread).
        let span = trace::span("job", trace::Category::Other);
        // One registry resolution per job; shard attempts and requeues
        // then touch plain atomics.
        let metrics = JobMetrics::new(rm.metrics());
        let app = AppLease::submit(rm, &spec.app, &spec.queue)?;
        let grant = Grant::acquire_in(
            rm,
            &spec.app,
            spec.resources,
            spec.min_containers,
            spec.max_containers,
            spec.grant_timeout,
            span.ctx(),
        )
        .with_context(|| format!("acquiring grant for job '{}'", spec.app))
        .map_err(|e| {
            crate::obs::job_failed(&spec.app, &e);
            e
        })?;
        metrics.grant_wait.record(grant.wait());
        metrics.jobs.inc();
        Ok(JobHandle {
            grant,
            app,
            rm: rm.clone(),
            spec,
            metrics,
            retries: Arc::new(AtomicU64::new(0)),
            preemptions: Arc::new(AtomicU64::new(0)),
            started: Instant::now(),
            span,
        })
    }

    /// Trace context of the job's root span ([`SpanCtx::NONE`] when
    /// the tracer is disabled).
    pub fn trace(&self) -> SpanCtx {
        self.span.ctx()
    }

    /// Containers actually granted — also the shard count.
    pub fn shards(&self) -> usize {
        self.grant.len()
    }

    pub fn containers(&self) -> Vec<ContainerRef> {
        self.grant.containers()
    }

    pub fn grant_wait(&self) -> Duration {
        self.grant.wait()
    }

    /// Report a job-level failure to the installed telemetry plane
    /// (flight-recorder bundle) and hand the error back unchanged.
    fn report_failure(&self, e: anyhow::Error) -> anyhow::Error {
        crate::obs::job_failed(&self.spec.app, &e);
        e
    }

    fn shard_env(&self) -> ShardEnv {
        ShardEnv {
            rm: self.rm.clone(),
            app: self.spec.app.clone(),
            resources: self.spec.resources,
            grant_timeout: self.spec.grant_timeout,
            held: self.grant.shared(),
            budget: self.spec.max_shard_retries,
            retries: self.retries.clone(),
            preemptions: self.preemptions.clone(),
            metrics: self.metrics.clone(),
            trace: self.span.ctx(),
        }
    }

    /// Shard `items` across the grant via the DCE executor pool: one
    /// partition per container, each shard closure retried within the
    /// job's budget, panics converted into job errors, preempted
    /// containers swapped for replacements. Output order follows input
    /// order.
    pub fn run_sharded<T: Data, U: Data>(
        &self,
        ctx: &DceContext,
        items: Vec<T>,
        f: impl Fn(&ShardCtx, Vec<T>) -> Result<Vec<U>> + Send + Sync + 'static,
    ) -> Result<Vec<U>> {
        let conts: Vec<ContainerRef> = self.grant.containers();
        let shards = conts.len();
        let env = self.shard_env();
        ctx.parallelize(items, shards)
            .map_partitions(move |part, items: Vec<T>| {
                let container = conts[part % conts.len()].clone();
                // The shard's input is cloned per attempt: a preemption
                // can interrupt even the final permitted retry, and the
                // requeued attempt needs the items again.
                env.run_attempts(part, shards, container, |sctx| f(sctx, items.clone()))
            })
            .collect()
            .map_err(|e| self.report_failure(e))
    }

    /// One closure per granted container on dedicated threads — for
    /// workloads that poll or stream rather than consume a fixed list
    /// (e.g. the compactor draining its share of log partitions). Same
    /// retry budget, panic containment, and preemption requeue as
    /// [`Self::run_sharded`].
    pub fn run_per_container<U: Send>(
        &self,
        f: impl Fn(&ShardCtx) -> Result<U> + Send + Sync,
    ) -> Result<Vec<U>> {
        let conts = self.grant.containers();
        let shards = conts.len();
        let env = self.shard_env();
        let results: Vec<std::thread::Result<Result<U>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..shards)
                .map(|w| {
                    let f = &f;
                    let env = &env;
                    let container = conts[w].clone();
                    s.spawn(move || env.run_attempts(w, shards, container, f))
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        let mut out = Vec::with_capacity(shards);
        let mut first_err: Option<anyhow::Error> = None;
        for r in results {
            match r {
                Ok(Ok(v)) => out.push(v),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(payload) => {
                    first_err.get_or_insert(anyhow!(
                        "job worker panicked: {}",
                        panic_msg(payload.as_ref())
                    ));
                }
            }
        }
        match first_err {
            Some(e) => Err(self.report_failure(e)),
            None => Ok(out),
        }
    }

    /// Run one closure inside the first granted container — the shape
    /// of a sequential single-container stage (not preemptible: the
    /// closure is `FnOnce`, so there is nothing to requeue).
    pub fn run_single<T>(&self, f: impl FnOnce(&ContainerCtx) -> Result<T>) -> Result<T> {
        let run = || -> Result<T> {
            let conts = self.grant.containers();
            let c = conts
                .first()
                .ok_or_else(|| anyhow!("job '{}' holds no containers", self.spec.app))?;
            c.run(f)?
        };
        run().map_err(|e| self.report_failure(e))
    }

    /// Finish the job: record container-seconds, return the stats, and
    /// release the grant + app registration (RAII).
    pub fn finish(self) -> JobStats {
        let elapsed = self.started.elapsed();
        let containers = self.grant.len();
        let container_seconds = elapsed.as_secs_f64() * containers as f64;
        self.metrics.container_ms.add((container_seconds * 1000.0) as u64);
        let job_ctx = self.span.ctx();
        let mut stats = JobStats {
            app: self.spec.app.clone(),
            queue: self.spec.queue.clone(),
            containers,
            grant_wait: self.grant.wait(),
            shard_retries: self.retries.load(Ordering::Relaxed),
            preemptions: self.preemptions.load(Ordering::Relaxed),
            container_seconds,
            elapsed,
            critical_path: None,
        };
        // Dropping the handle closes the root span (after releasing
        // the grant and lease), so every span of the trace is recorded
        // before the analyzer reads it back.
        drop(self);
        if !job_ctx.is_none() {
            let spans = trace::tracer().spans_for(job_ctx.trace_id);
            stats.critical_path =
                trace::critical_path::analyze(&spans, job_ctx.span_id);
        }
        stats
    }
}

/// Submit + run one closure in one container + finish: the shape of a
/// pre-unification per-stage job (the staged pipeline baselines submit
/// one of these per stage, paying the grant churn the unified path
/// avoids).
pub fn run_stage<T>(
    rm: &Arc<ResourceManager>,
    spec: JobSpec,
    f: impl FnOnce(&ContainerCtx) -> Result<T>,
) -> Result<T> {
    let job = JobHandle::submit(rm, spec)?;
    let out = job.run_single(f);
    let _ = job.finish();
    out
}

/// Everything a shard attempt needs beyond its closure: the job's
/// resource handles (for preemption requeue) and the grant's shared
/// container set (replacements are adopted into it so the RAII release
/// still covers them).
#[derive(Clone)]
struct ShardEnv {
    rm: Arc<ResourceManager>,
    app: String,
    resources: ResourceVec,
    grant_timeout: Duration,
    held: Arc<Mutex<Vec<ContainerRef>>>,
    budget: usize,
    retries: Arc<AtomicU64>,
    preemptions: Arc<AtomicU64>,
    metrics: JobMetrics,
    /// The job's root span — the parent of every shard attempt.
    trace: SpanCtx,
}

impl ShardEnv {
    /// Retry loop shared by the sharded and per-container runners:
    /// panics are caught and converted to errors so the RAII guards —
    /// not luck — decide when containers go back to the pool. A failure
    /// on a container flagged for preemption is not charged against the
    /// retry budget: the container is yielded to the reclaiming queue,
    /// a replacement is acquired, and the shard is requeued.
    ///
    /// Classification is deliberately conservative: ANY failure on a
    /// flagged container counts as a preemption. A genuine shard bug
    /// that coincides with a flag costs exactly one extra execution —
    /// the replacement container starts unflagged, so the rerun fails
    /// into the normal retry budget (the requeue cap only matters
    /// under sustained re-flagging, i.e. real preemption pressure).
    fn run_attempts<U>(
        &self,
        shard: usize,
        shards: usize,
        mut container: ContainerRef,
        attempt_fn: impl Fn(&ShardCtx) -> Result<U>,
    ) -> Result<U> {
        let mut last: Option<anyhow::Error> = None;
        let mut attempt = 0usize;
        let mut requeues = 0usize;
        while attempt <= self.budget {
            let mut sp =
                trace::span_in("job.shard", trace::Category::Compute, self.trace);
            sp.arg("shard", shard as u64)
                .arg("attempt", attempt as u64)
                .arg("requeues", requeues as u64);
            let sctx = ShardCtx {
                shard,
                shards,
                attempt,
                container: container.clone(),
                trace: sp.ctx(),
            };
            let outcome = catch_unwind(AssertUnwindSafe(|| attempt_fn(&sctx)));
            drop(sp); // the attempt span ends here, unwound or not
            let err = match outcome {
                Ok(Ok(v)) => return Ok(v),
                Ok(Err(e)) => e,
                Err(payload) => {
                    self.metrics.shard_panics.inc();
                    anyhow!("shard {shard} panicked: {}", panic_msg(payload.as_ref()))
                }
            };
            if container.preempt_requested() && requeues < MAX_PREEMPT_REQUEUES {
                requeues += 1;
                self.preemptions.fetch_add(1, Ordering::Relaxed);
                self.metrics.preemptions.inc();
                let requeued = {
                    let mut rsp = trace::span_in(
                        "job.preempt_requeue",
                        trace::Category::PreemptRequeue,
                        self.trace,
                    );
                    rsp.arg("shard", shard as u64);
                    self.requeue(&container)
                };
                match requeued {
                    Ok(replacement) => {
                        container = replacement;
                        continue; // the retry budget is untouched
                    }
                    Err(e) => {
                        let msg = format!("shard {shard} preempted and could not reacquire");
                        last = Some(e.context(msg));
                        break;
                    }
                }
            }
            last = Some(err);
            attempt += 1;
            if attempt <= self.budget {
                self.retries.fetch_add(1, Ordering::Relaxed);
                self.metrics.shard_retries.inc();
            }
        }
        let e = last.expect("at least one attempt ran");
        Err(e.context(format!("shard {shard} failed after {} attempt(s)", self.budget + 1)))
    }

    /// Yield a preempted container back to the pool (waking the
    /// reclaiming queue) and adopt a replacement into the grant's
    /// shared set so the RAII release covers it.
    fn requeue(&self, old: &ContainerRef) -> Result<ContainerRef> {
        self.held.lock().unwrap().retain(|c| c.id != old.id);
        if !old.is_released() {
            self.rm.release(old)?;
        }
        let start = Instant::now();
        let replacement = self
            .rm
            .acquire_container(&self.app, self.resources, self.grant_timeout)?;
        self.metrics.preempt_requeue_wait.record(start.elapsed());
        self.held.lock().unwrap().push(replacement.clone());
        Ok(replacement)
    }
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::metrics::MetricsRegistry;

    fn rm() -> Arc<ResourceManager> {
        ResourceManager::new(&PlatformConfig::test().cluster, MetricsRegistry::new())
    }

    #[test]
    fn spec_builder_clamps_ranges() {
        let s = JobSpec::new("j").containers(0, 0);
        assert_eq!((s.min_containers, s.max_containers), (1, 1));
        let s = JobSpec::new("j").containers(3, 2);
        assert_eq!((s.min_containers, s.max_containers), (3, 3));
    }

    #[test]
    fn sharded_job_runs_and_releases() {
        let rm = rm();
        let ctx = DceContext::local().unwrap();
        let job = JobHandle::submit(&rm, JobSpec::new("j").containers(1, 3)).unwrap();
        assert!(job.shards() >= 1);
        let out = job
            .run_sharded(&ctx, (0..50u64).collect(), |sctx, items: Vec<u64>| {
                assert!(sctx.shard < sctx.shards);
                sctx.run(|_| items.into_iter().map(|x| x + 1).collect())
            })
            .unwrap();
        assert_eq!(out, (1..=50).collect::<Vec<u64>>());
        let stats = job.finish();
        assert_eq!(stats.shard_retries, 0);
        assert!(stats.containers >= 1);
        assert_eq!(rm.live_containers(), 0);
    }

    #[test]
    fn duplicate_submit_fails_until_finished() {
        let rm = rm();
        let job = JobHandle::submit(&rm, JobSpec::new("dup")).unwrap();
        assert!(JobHandle::submit(&rm, JobSpec::new("dup")).is_err());
        let _ = job.finish();
        let again = JobHandle::submit(&rm, JobSpec::new("dup")).unwrap();
        let _ = again.finish();
    }

    #[test]
    fn shard_retry_budget_is_counted() {
        let rm = rm();
        let ctx = DceContext::local().unwrap();
        let job =
            JobHandle::submit(&rm, JobSpec::new("flaky").containers(1, 1).retries(2)).unwrap();
        let calls = Arc::new(AtomicU64::new(0));
        let c2 = calls.clone();
        let out = job
            .run_sharded(&ctx, vec![7u32], move |_sctx, items: Vec<u32>| {
                if c2.fetch_add(1, Ordering::SeqCst) < 2 {
                    anyhow::bail!("transient");
                }
                Ok(items)
            })
            .unwrap();
        assert_eq!(out, vec![7]);
        let stats = job.finish();
        assert_eq!(stats.shard_retries, 2);
        assert_eq!(rm.live_containers(), 0);
    }

    #[test]
    fn preempted_shard_requeues_without_burning_its_retry_budget() {
        let rm = rm();
        let ctx = DceContext::local().unwrap();
        // Zero retries: if the preemption were charged as a retry, the
        // job would fail.
        let job =
            JobHandle::submit(&rm, JobSpec::new("victim").containers(1, 1).retries(0)).unwrap();
        assert_eq!(rm.request_preemption("victim", 1), 1);
        let seen: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        let out = job
            .run_sharded(&ctx, vec![5u32], move |sctx, items: Vec<u32>| {
                seen2.lock().unwrap().push(sctx.container().id);
                sctx.check_preempted()?;
                Ok(items)
            })
            .unwrap();
        assert_eq!(out, vec![5]);
        let stats = job.finish();
        assert_eq!(stats.preemptions, 1);
        assert_eq!(stats.shard_retries, 0, "preemption must not burn the retry budget");
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 2, "one preempted attempt + one requeued attempt");
        assert_ne!(seen[0], seen[1], "the requeue must run on a replacement container");
        assert_eq!(rm.live_containers(), 0, "victim and replacement are both released");
    }

    #[test]
    fn run_single_uses_the_first_container() {
        let rm = rm();
        let job = JobHandle::submit(&rm, JobSpec::new("single")).unwrap();
        let v = job.run_single(|cctx| {
            cctx.alloc_mem(1024)?;
            cctx.free_mem(1024);
            Ok(99)
        });
        assert_eq!(v.unwrap(), 99);
        let _ = job.finish();
        assert_eq!(rm.live_containers(), 0);
    }

    #[test]
    fn run_stage_is_a_self_contained_job() {
        let rm = rm();
        let out = run_stage(&rm, JobSpec::new("stage"), |_c| Ok(5u32)).unwrap();
        assert_eq!(out, 5);
        assert_eq!(rm.live_containers(), 0);
        assert_eq!(rm.metrics().counter("platform.job.jobs").get(), 1);
    }
}
