//! Paper-experiment harness: one function per table/figure of the
//! evaluation (E1–E12 in DESIGN.md §4), each returning a rendered
//! [`Table`]. The CLI (`adcloud repro-tables`) and every `cargo bench`
//! target call into here, so the numbers in EXPERIMENTS.md are
//! regenerated from exactly this code.
//!
//! Each table is labelled with its execution mode:
//! * `real`          — measured wall-clock on this host.
//! * `real+model`    — real execution with the calibrated storage/device
//!                     models enforced (the I/O-bound comparisons).
//! * `virtual-time`  — the discrete-event cluster simulation driven by
//!                     task costs measured on this host (datacenter-scale
//!                     scaling figures; see DESIGN.md §6).

use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::PlatformConfig;
use crate::dce::{DceContext, SimCluster, SimJob, SimTask};
use crate::hetero::Dispatcher;
use crate::ingest;
use crate::mapreduce::MapReduceEngine;
use crate::metrics::MetricsRegistry;
use crate::resource::{DeviceKind, ResourceManager, ResourceVec};
use crate::scenario;
use crate::services::{mapgen, simulation, sql, training};
use crate::storage::{DfsStore, EvictionPolicy, TieredStore, UnderStore};
use crate::trace;
use crate::trace::critical_path::{analyze, CriticalPath};
use crate::util::{fmt_duration, Rng};

use super::job::{JobHandle, JobSpec};

/// A paper-style result table.
#[derive(Debug, Clone)]
pub struct Table {
    pub id: &'static str,
    pub title: String,
    pub mode: &'static str,
    pub header: Vec<&'static str>,
    pub rows: Vec<Vec<String>>,
    pub notes: String,
}

impl Table {
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let mut out = format!("== {} [{}] ({})\n", self.id, self.mode, self.title);
        let fmt_row = |cells: Vec<String>| -> String {
            let mut line = String::from("  ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(self.header.iter().map(|s| s.to_string()).collect()));
        for row in &self.rows {
            out.push_str(&fmt_row(row.clone()));
        }
        if !self.notes.is_empty() {
            out.push_str(&format!("  note: {}\n", self.notes));
        }
        out
    }
}

pub const ALL_IDS: [&str; 22] = [
    "e1", "e2", "e3", "e4", "e5", "e6", "e7", "e8", "e9", "e10", "e11", "e12", "e13", "e14",
    "e15", "e16", "e17", "e18", "e19", "e20", "e21", "e22",
];

/// Run one experiment by id. `quick` shrinks workloads for CI/tests.
pub fn run_experiment(id: &str, quick: bool) -> Result<Table> {
    match id {
        "e1" => e1_sql(quick),
        "e2" => e2_storage(quick),
        "e3" => e3_cnn(quick),
        "e4" => e4_container(quick),
        "e5" => e5_feature_scaling(quick),
        "e6" => e6_replay_scaling(quick),
        "e7" => e7_pipeline(quick),
        "e8" => e8_param_server(quick),
        "e9" => e9_training_scaling(quick),
        "e10" => e10_mapgen(quick),
        "e11" => e11_icp(quick),
        "e12" => e12_reliability(quick),
        "e13" => e13_campaign(quick),
        "e14" => e14_ingest(quick),
        "e15" => e15_multitenant(quick),
        "e16" => e16_preemption(quick),
        "e17" => e17_fastpath(quick),
        "e18" => e18_trace(quick),
        "e19" => e19_observability(quick),
        "e20" => e20_fleet(quick),
        "e21" => e21_serve(quick),
        "e22" => e22_shuffle(quick),
        other => Err(anyhow!("unknown experiment '{other}' (have {ALL_IDS:?})")),
    }
}

fn dispatcher() -> Result<Dispatcher> {
    let reg = crate::hetero::KernelRegistry::new();
    let rt = crate::runtime::shared_runtime()?;
    crate::hetero::register_default_kernels(&reg, &rt);
    Ok(Dispatcher::new(reg, MetricsRegistry::new()))
}

fn speedup(slow: Duration, fast: Duration) -> String {
    format!("{:.1}x", slow.as_secs_f64() / fast.as_secs_f64().max(1e-12))
}

/// The standard 1→8 scaling sweep shared by E6/E13/E14/E15. `f` runs
/// one configuration and returns the row's leading cells plus a rate
/// (higher = better: throughput, or 1/makespan). A final column is
/// appended showing the rate relative to the first (single-node) run.
const SWEEP_NODES: [usize; 4] = [1, 2, 4, 8];

fn sweep_rows(
    mut f: impl FnMut(usize) -> Result<(Vec<String>, f64)>,
) -> Result<Vec<Vec<String>>> {
    let mut rows = Vec::new();
    let mut base: Option<f64> = None;
    for nodes in SWEEP_NODES {
        let (mut cells, rate) = f(nodes)?;
        let b = *base.get_or_insert(rate);
        cells.push(format!("{:.2}x", rate / b.max(1e-12)));
        rows.push(cells);
    }
    Ok(rows)
}

// ===========================================================================
// E1 (§2.1): SQL queries — DCE vs MapReduce, same resources
// ===========================================================================

fn e1_sql(quick: bool) -> Result<Table> {
    let n = if quick { 4_000 } else { 120_000 };
    let vehicles = 100;
    let parts = 8;
    let mut cfg = PlatformConfig::bench();
    cfg.engine.default_parallelism = parts;
    let ctx = DceContext::new(cfg.clone())?;
    let dfs = DfsStore::new(cfg.storage.dfs.clone(), true, MetricsRegistry::new())?;
    let engine = MapReduceEngine::new(cfg.cluster.total_cores(), dfs, MetricsRegistry::new());

    let data = sql::generate_telemetry(n, vehicles, cfg.seed);
    let registry = sql::generate_vehicles(vehicles, cfg.seed);
    let rdd = ctx.parallelize(data.clone(), parts).cache();
    let reg_rdd = ctx.parallelize(registry.clone(), 2);
    let input = engine.write_file(data, parts)?;

    let mut rows = Vec::new();
    let mut total_dce = Duration::ZERO;
    let mut total_mr = Duration::ZERO;
    // Q1
    let t = Instant::now();
    sql::q1_dce(&rdd, parts)?;
    let d_dce = t.elapsed();
    let t = Instant::now();
    sql::q1_mr(&engine, &input, parts)?;
    let d_mr = t.elapsed();
    rows.push(vec![
        "Q1 filter+agg".into(),
        fmt_duration(d_dce),
        fmt_duration(d_mr),
        speedup(d_mr, d_dce),
    ]);
    total_dce += d_dce;
    total_mr += d_mr;
    // Q2
    let t = Instant::now();
    sql::q2_dce(&rdd, &reg_rdd, parts)?;
    let d_dce = t.elapsed();
    let t = Instant::now();
    sql::q2_mr(&engine, &input, &registry, parts)?;
    let d_mr = t.elapsed();
    rows.push(vec![
        "Q2 join+agg".into(),
        fmt_duration(d_dce),
        fmt_duration(d_mr),
        speedup(d_mr, d_dce),
    ]);
    total_dce += d_dce;
    total_mr += d_mr;
    // Q3 — the multi-stage "daily query".
    let t = Instant::now();
    sql::q3_dce(&rdd, parts)?;
    let d_dce = t.elapsed();
    let t = Instant::now();
    sql::q3_mr(&engine, &input, parts)?;
    let d_mr = t.elapsed();
    rows.push(vec![
        "Q3 daily multi-stage".into(),
        fmt_duration(d_dce),
        fmt_duration(d_mr),
        speedup(d_mr, d_dce),
    ]);
    total_dce += d_dce;
    total_mr += d_mr;
    rows.push(vec![
        "TOTAL".into(),
        fmt_duration(total_dce),
        fmt_duration(total_mr),
        speedup(total_mr, total_dce),
    ]);
    Ok(Table {
        id: "e1",
        title: format!("SQL workload, {n} telemetry rows: DCE (Spark-analog) vs MapReduce"),
        mode: "real+model",
        header: vec!["query", "dce", "mapreduce", "speedup"],
        rows,
        notes: "paper: Spark ≥5x avg; daily query 1000s -> 150s (6.7x). Our synthetic queries are compute-lighter than production SQL, so factors run higher; the ordering (multi-stage wins most) matches.".into(),
    })
}

// ===========================================================================
// E2 (§2.2): tiered store vs DFS-only
// ===========================================================================

fn e2_storage(quick: bool) -> Result<Table> {
    let block = 8 << 20; // 8 MiB blocks
    let blocks = if quick { 4 } else { 24 };
    let reads = if quick { 3 } else { 10 };
    let cfg = PlatformConfig::bench().storage;
    let metrics = MetricsRegistry::new();
    let under = UnderStore::temp("e2", cfg.dfs.clone(), true)?;
    let mut big = cfg.clone();
    big.mem.capacity_bytes = 1 << 30;
    let tiered = TieredStore::new(&big, under, EvictionPolicy::Lru, metrics.clone());
    let dfs = DfsStore::new(cfg.dfs.clone(), true, metrics)?;

    let payload = vec![7u8; block];
    // Write + repeatedly read a hot working set through each engine.
    let t = Instant::now();
    for i in 0..blocks {
        tiered.put(&format!("ws/{i}"), payload.clone())?;
    }
    for _ in 0..reads {
        for i in 0..blocks {
            tiered.get(&format!("ws/{i}"))?;
        }
    }
    let tiered_time = t.elapsed();
    tiered.flush();
    let t = Instant::now();
    for i in 0..blocks {
        dfs.write(&format!("ws/{i}"), &payload)?;
    }
    for _ in 0..reads {
        for i in 0..blocks {
            dfs.read(&format!("ws/{i}"))?;
        }
    }
    let dfs_time = t.elapsed();
    let total_bytes = (blocks * (reads + 1) * block) as u64;
    let bw = |t: Duration| {
        format!("{}/s", crate::util::fmt_bytes((total_bytes as f64 / t.as_secs_f64()) as u64))
    };
    Ok(Table {
        id: "e2",
        title: format!(
            "{} x {} blocks, {} hot reads: tiered (Alluxio-analog) vs DFS-only",
            blocks,
            crate::util::fmt_bytes(block as u64),
            reads
        ),
        mode: "real+model",
        header: vec!["engine", "time", "effective bw", "speedup"],
        rows: vec![
            vec![
                "tiered (mem-speed, async persist)".into(),
                fmt_duration(tiered_time),
                bw(tiered_time),
                speedup(dfs_time, tiered_time),
            ],
            vec![
                "dfs only (1GbE remote)".into(),
                fmt_duration(dfs_time),
                bw(dfs_time),
                "1.0x".into(),
            ],
        ],
        notes: "paper: 30x with Alluxio co-located cache vs HDFS-only.".into(),
    })
}

// ===========================================================================
// E3 (§2.3): CNN inference GPU-class vs CPU (+ FPGA energy)
// ===========================================================================

fn e3_cnn(quick: bool) -> Result<Table> {
    let d = dispatcher()?;
    let mut rng = Rng::new(3);
    let params = crate::hetero::cpu_impls::init_params(&mut rng);
    let mut ins: Vec<crate::runtime::Tensor> = params
        .iter()
        .zip(crate::hetero::cpu_impls::PARAM_SHAPES.iter())
        .map(|(p, (_, s))| crate::runtime::Tensor::from_f32(p.clone(), s).unwrap())
        .collect();
    let batch = 32usize;
    let x: Vec<f32> = (0..batch * 32 * 32 * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    ins.push(crate::runtime::Tensor::from_f32(x, &[batch, 32, 32, 3])?);
    let iters = if quick { 3 } else { 15 };
    let mut rows = Vec::new();
    let mut times = std::collections::HashMap::new();
    for kind in [DeviceKind::Gpu, DeviceKind::Fpga, DeviceKind::Cpu] {
        // warmup
        d.run_on(kind, "cnn_infer_b32", &ins)?;
        let best = (0..iters)
            .map(|_| {
                let t = Instant::now();
                d.run_on(kind, "cnn_infer_b32", &ins).map(|_| t.elapsed())
            })
            .collect::<Result<Vec<_>>>()?
            .into_iter()
            .min()
            .unwrap();
        times.insert(kind, best);
    }
    let cpu = times[&DeviceKind::Cpu];
    for kind in [DeviceKind::Cpu, DeviceKind::Fpga, DeviceKind::Gpu] {
        let t = times[&kind];
        let per_img = t / batch as u32;
        let joules = kind.power_watts() * t.as_secs_f64();
        rows.push(vec![
            format!("{} (this host, measured)", kind.name()),
            fmt_duration(t),
            fmt_duration(per_img),
            format!("{:.3} J/batch", joules),
            speedup(cpu, t),
        ]);
    }
    // Paper-hardware rows: roofline models of the 2016-era parts, at the
    // paper's CNN scale (AlexNet-class ~0.7 GFLOP/image — our 32x32 net
    // is launch-bound on any real accelerator). See hetero::roofline.
    use crate::hetero::roofline::{KernelCost, RooflineDevice};
    let paper_cost = KernelCost {
        flops: 0.7e9 * batch as f64,
        bytes: batch as f64 * 5e6, // cached weights, tiled activations
        irregular: false,
    };
    let cpu_m = RooflineDevice::server_cpu();
    let gpu_m = RooflineDevice::m40_gpu();
    let fpga_m = RooflineDevice::fpga_card();
    let t_cpu = cpu_m.time(&paper_cost);
    for dev in [&cpu_m, &fpga_m, &gpu_m] {
        let t = dev.time(&paper_cost);
        let watts = match dev.name {
            n if n.contains("gpu") => 250.0,
            n if n.contains("fpga") => 25.0,
            _ => 2.0 * 120.0,
        };
        rows.push(vec![
            dev.name.into(),
            fmt_duration(t),
            fmt_duration(t / batch as u32),
            format!("{:.3} J/batch", watts * t.as_secs_f64()),
            speedup(t_cpu, t),
        ]);
    }
    Ok(Table {
        id: "e3",
        title: format!(
            "CNN object-recognition inference, batch {batch} (measured best of {iters} + paper-hardware roofline)"
        ),
        mode: "real + roofline model",
        header: vec!["device", "batch latency", "per image", "energy", "speedup vs cpu"],
        rows,
        notes: "paper: GPU 10-20x over CPU on CNN; FPGA slower but most energy-efficient. Host rows are single-core; modelled rows use 2016-era device rooflines at AlexNet scale.".into(),
    })
}

// ===========================================================================
// E4 (§2.3): container overhead < 5%
// ===========================================================================

fn e4_container(quick: bool) -> Result<Table> {
    let cfg = PlatformConfig::bench();
    let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
    let job = JobHandle::submit(
        &rm,
        JobSpec::new("e4").resources(ResourceVec::cores(1, 64 << 20)),
    )?;
    let c = job.containers()[0].clone();
    let imgs = if quick { 32 } else { 64 };
    let mut rng = Rng::new(4);
    let frames: Vec<Vec<f32>> = (0..imgs)
        .map(|_| (0..64 * 64).map(|_| rng.next_f32()).collect())
        .collect();
    let work = |frames: &[Vec<f32>]| {
        let mut acc = 0f32;
        for f in frames {
            let feats = crate::hetero::cpu_impls::feature_extract(f, 1, 64, 64);
            acc += feats.iter().sum::<f32>();
        }
        acc
    };
    let reps = if quick { 10 } else { 20 };
    // Paired measurement: native and containerised runs back-to-back per
    // rep, keeping the best of each — pairing cancels scheduler drift on
    // a shared single-core host.
    let mut native = Duration::MAX;
    let mut contained = Duration::MAX;
    for _ in 0..reps {
        let t = Instant::now();
        std::hint::black_box(work(&frames));
        native = native.min(t.elapsed());
        let t = Instant::now();
        c.run(|ctx| {
            ctx.alloc_mem((imgs * 64 * 64 * 4) as u64).unwrap();
            let out = std::hint::black_box(work(&frames));
            ctx.free_mem((imgs * 64 * 64 * 4) as u64);
            out
        })
        .unwrap();
        contained = contained.min(t.elapsed());
    }
    let _ = job.finish();
    let overhead =
        (contained.as_secs_f64() - native.as_secs_f64()) / native.as_secs_f64() * 100.0;
    Ok(Table {
        id: "e4",
        title: format!("container wrapper overhead, {imgs}-image feature job (best of {reps})"),
        mode: "real",
        header: vec!["execution", "time", "overhead"],
        rows: vec![
            vec!["native".into(), fmt_duration(native), "-".into()],
            vec![
                "inside container".into(),
                fmt_duration(contained),
                format!("{overhead:.2}%"),
            ],
        ],
        notes: "paper: LXC CPU overhead < 5% vs native.".into(),
    })
}

// ===========================================================================
// E5 (Fig 6): feature extraction over 1M images, 2,000 -> 10,000 cores
// ===========================================================================

fn e5_feature_scaling(quick: bool) -> Result<Table> {
    // Calibrate the per-task cost from the REAL artifact execution.
    let d = dispatcher()?;
    let mut rng = Rng::new(5);
    let img: Vec<f32> = (0..8 * 64 * 64).map(|_| rng.next_f32()).collect();
    let t8 = crate::runtime::Tensor::from_f32(img, &[8, 64, 64])?;
    d.run_on(DeviceKind::Gpu, "feature_b8", &[t8.clone()])?; // warm
    let per_batch = crate::dce::measure_per_item_cost(
        || {
            d.run_on(DeviceKind::Gpu, "feature_b8", &[t8.clone()]).unwrap();
        },
        1,
        if quick { 3 } else { 10 },
    );
    // Virtual time is cheap: always simulate the paper's full 1M images
    // (quick mode only trims the real calibration loop above).
    let images = 1_000_000u64;
    let batch = 64u64; // images per task (8 artifact calls)
    let task_compute = per_batch * (batch / 8) as u32;
    let tasks = (images / batch) as usize;
    let image_bytes = 64 * 64 * 4u64;
    let mut rows = Vec::new();
    let mut base: Option<Duration> = None;
    for cores in [2000usize, 4000, 6000, 8000, 10000] {
        let cluster = SimCluster { seed: 5, ..SimCluster::with_cores(cores) };
        let job = SimJob::single_stage(
            "feature-extract",
            (0..tasks)
                .map(|_| SimTask {
                    compute: task_compute,
                    input_bytes: batch * image_bytes,
                    remote_read: true,
                    output_bytes: batch * 8 * 8 * 4 * 4,
                })
                .collect(),
        );
        let report = crate::dce::simclock::simulate(&cluster, &job);
        let b = *base.get_or_insert(report.makespan);
        rows.push(vec![
            format!("{cores}"),
            fmt_duration(report.makespan),
            format!("{:.2}", b.as_secs_f64() / report.makespan.as_secs_f64()),
            format!("{:.0}%", report.utilization * 100.0),
        ]);
    }
    Ok(Table {
        id: "e5",
        title: format!(
            "feature extraction over {images} images (task cost calibrated: {}/64-image task)",
            fmt_duration(task_compute)
        ),
        mode: "virtual-time",
        header: vec!["cores", "exec time", "scaling", "utilization"],
        rows,
        notes: "paper Fig 6: 2,000 cores 130s -> 10,000 cores ~32s (near-linear, ~4x at 5x cores).".into(),
    })
}

// ===========================================================================
// E6 (§3.3): replay simulation, 1 node -> 8 nodes
// ===========================================================================

fn e6_replay_scaling(quick: bool) -> Result<Table> {
    // Calibrate per-frame detection cost from a REAL distributed replay.
    let d = dispatcher()?;
    let dir = std::env::temp_dir().join(format!("ade6-{}", std::process::id()));
    let bags = simulation::record_drive(&dir, 2, if quick { 8 } else { 24 }, 6)?;
    let ctx = DceContext::new(PlatformConfig::test())?;
    let report = simulation::replay(&ctx, &d, &bags, DeviceKind::Gpu)?;
    let per_frame = report.elapsed / report.frames.max(1) as u32;
    let _ = std::fs::remove_dir_all(&dir);
    // The paper's dataset: 3h on one node. Node = 8 cores here.
    let frames_total = 400_000u64; // ~11h of 10Hz driving
    let frames_per_task = 200u64;
    let frame_bytes = (8 + 4 + 64 * 64 * 4) as u64;
    let rows = sweep_rows(|nodes| {
        let cluster = SimCluster {
            nodes,
            cores_per_node: 8,
            seed: 6,
            ..SimCluster::with_cores(nodes * 8)
        };
        let job = SimJob::single_stage(
            "replay",
            (0..(frames_total / frames_per_task) as usize)
                .map(|_| SimTask {
                    compute: per_frame * frames_per_task as u32,
                    input_bytes: frames_per_task * frame_bytes,
                    remote_read: true,
                    output_bytes: 64,
                })
                .collect(),
        );
        let r = crate::dce::simclock::simulate(&cluster, &job);
        Ok((
            vec![format!("{nodes}"), fmt_duration(r.makespan)],
            1.0 / r.makespan.as_secs_f64().max(1e-9),
        ))
    })?;
    Ok(Table {
        id: "e6",
        title: format!(
            "replay qualification, {frames_total} frames (per-frame cost calibrated: {} — accuracy {:.0}% on real subset)",
            fmt_duration(per_frame),
            report.accuracy * 100.0
        ),
        mode: "virtual-time (calibrated by real replay)",
        header: vec!["nodes", "exec time", "speedup"],
        rows,
        notes: "paper: whole replay set 3h on one node -> ~25min on 8 nodes (7.2x).".into(),
    })
}

// ===========================================================================
// E7 (§4.1 / Fig 7): unified vs staged training pipeline
// ===========================================================================

fn e7_pipeline(quick: bool) -> Result<Table> {
    let d = dispatcher()?;
    let mut cfg = PlatformConfig::bench();
    cfg.engine.default_parallelism = 4;
    let ctx = DceContext::new(cfg.clone())?;
    let (examples, rounds) = if quick { (128, 2) } else { (4096, 6) };
    // Warm the train-step executable on every device queue so neither
    // pipeline is charged the one-time PJRT compilation.
    {
        let mut rng = Rng::new(0);
        let params = crate::hetero::cpu_impls::init_params(&mut rng);
        let mut ins: Vec<crate::runtime::Tensor> = params
            .iter()
            .zip(crate::hetero::cpu_impls::PARAM_SHAPES.iter())
            .map(|(p, (_, s))| crate::runtime::Tensor::from_f32(p.clone(), s).unwrap())
            .collect();
        ins.push(crate::runtime::Tensor::zeros(&[16, 32, 32, 3]));
        ins.push(crate::runtime::Tensor::from_i32(vec![0; 16], &[16])?);
        for _ in 0..4 {
            d.run_on(DeviceKind::Gpu, "cnn_train_b16", &ins)?;
        }
    }
    let store = TieredStore::test_store(&cfg.storage);
    let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
    let ps_u = training::ParamServer::tiered(store.clone(), "e7u");
    let uo = crate::platform::JobOpts::new("training-unified").workers(4);
    let u = training::run_unified(&ctx, &rm, &d, DeviceKind::Gpu, &ps_u, examples, rounds, &uo, 7)?;
    let ps_s = training::ParamServer::tiered(store, "e7s");
    let so = crate::platform::JobOpts::new("training-staged").workers(4);
    let s =
        training::run_staged(ctx.dfs(), &rm, &d, DeviceKind::Gpu, &ps_s, examples, rounds, &so, 7)?;
    Ok(Table {
        id: "e7",
        title: format!("ETL->feature->train pipeline, {examples} examples, {rounds} rounds"),
        mode: "real+model",
        header: vec!["pipeline", "time", "throughput", "final loss", "speedup"],
        rows: vec![
            vec![
                "unified (in-memory RDDs)".into(),
                fmt_duration(u.elapsed),
                format!("{:.0} ex/s", u.throughput_eps),
                format!("{:.3}", u.final_loss),
                speedup(s.elapsed, u.elapsed),
            ],
            vec![
                "staged (DFS between stages)".into(),
                fmt_duration(s.elapsed),
                format!("{:.0} ex/s", s.throughput_eps),
                format!("{:.3}", s.final_loss),
                "1.0x".into(),
            ],
        ],
        notes: "paper Fig 7: unified pipeline ~2x throughput.".into(),
    })
}

// ===========================================================================
// E8 (§4.2): parameter server on tiered store vs DFS
// ===========================================================================

fn e8_param_server(quick: bool) -> Result<Table> {
    let cfg = PlatformConfig::bench();
    let rounds = if quick { 3 } else { 20 };
    let mut rng = Rng::new(8);
    let params = crate::hetero::cpu_impls::init_params(&mut rng);
    // (a) the real perception model (latency-dominated: ~60 KiB).
    let store = TieredStore::test_store(&cfg.storage);
    let ps_t = training::ParamServer::tiered(store, "e8");
    let dfs = DfsStore::new(cfg.storage.dfs.clone(), true, MetricsRegistry::new())?;
    let ps_d = training::ParamServer::dfs(dfs.clone(), "e8");
    let time_ps = |ps: &training::ParamServer| -> Result<Duration> {
        let t = Instant::now();
        for v in 0..rounds {
            ps.push(v, &params)?;
            ps.pull(v)?;
        }
        Ok(t.elapsed())
    };
    let small_t = time_ps(&ps_t)?;
    let small_d = time_ps(&ps_d)?;
    // (b) a paper-scale model: 64 MiB of parameters as raw blocks
    // (bandwidth-dominated).
    let big_block = vec![1u8; 16 << 20];
    let store2 = TieredStore::new(
        &{
            let mut s = cfg.storage.clone();
            // Size the cache for the live working set (a real PS keeps a
            // couple of versions hot, not the whole history).
            s.mem.capacity_bytes = 4 << 30;
            s
        },
        UnderStore::temp("e8b", cfg.storage.dfs.clone(), true)?,
        EvictionPolicy::Lru,
        MetricsRegistry::new(),
    );
    let t = Instant::now();
    for v in 0..rounds {
        for b in 0..4 {
            store2.put(&format!("big/v{v}/{b}"), big_block.clone())?;
            store2.get(&format!("big/v{v}/{b}"))?;
            // Version GC: drop v-2, as a production PS would.
            if v >= 2 {
                store2.delete(&format!("big/v{}/{b}", v - 2))?;
            }
        }
    }
    let big_t = t.elapsed();
    let t = Instant::now();
    for v in 0..rounds {
        for b in 0..4 {
            dfs.write(&format!("big/v{v}/{b}"), &big_block)?;
            dfs.read(&format!("big/v{v}/{b}"))?;
        }
    }
    let big_d = t.elapsed();
    Ok(Table {
        id: "e8",
        title: format!("parameter server push+pull, {rounds} rounds"),
        mode: "real+model",
        header: vec!["model", "tiered store", "dfs", "gain"],
        rows: vec![
            vec![
                "perception CNN (60 KiB)".into(),
                fmt_duration(small_t),
                fmt_duration(small_d),
                speedup(small_d, small_t),
            ],
            vec![
                "paper-scale model (64 MiB)".into(),
                fmt_duration(big_t),
                fmt_duration(big_d),
                speedup(big_d, big_t),
            ],
        ],
        notes: "paper: >5x I/O gain using Alluxio as parameter server vs HDFS. The 60 KiB model is latency-dominated (per-block round trips); the 64 MiB row is the bandwidth-comparable one.".into(),
    })
}

// ===========================================================================
// E9 (§4.3 / Fig 9): training — GPU vs CPU, and per-pass GPU scaling
// ===========================================================================

fn e9_training_scaling(quick: bool) -> Result<Table> {
    let d = dispatcher()?;
    // (a) real: one train step, GPU-class vs CPU.
    let mut rng = Rng::new(9);
    let params = crate::hetero::cpu_impls::init_params(&mut rng);
    let mut ins: Vec<crate::runtime::Tensor> = params
        .iter()
        .zip(crate::hetero::cpu_impls::PARAM_SHAPES.iter())
        .map(|(p, (_, s))| crate::runtime::Tensor::from_f32(p.clone(), s).unwrap())
        .collect();
    let x: Vec<f32> = (0..16 * 32 * 32 * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..16).map(|i| (i % 10) as i32).collect();
    ins.push(crate::runtime::Tensor::from_f32(x, &[16, 32, 32, 3])?);
    ins.push(crate::runtime::Tensor::from_i32(y, &[16])?);
    d.run_on(DeviceKind::Gpu, "cnn_train_b16", &ins)?; // warm
    let iters = if quick { 2 } else { 8 };
    let gpu_step = (0..iters)
        .map(|_| {
            let t = Instant::now();
            d.run_on(DeviceKind::Gpu, "cnn_train_b16", &ins).map(|_| t.elapsed())
        })
        .collect::<Result<Vec<_>>>()?
        .into_iter()
        .min()
        .unwrap();
    let cpu_step = {
        let t = Instant::now();
        d.run_on(DeviceKind::Cpu, "cnn_train_b16", &ins)?;
        t.elapsed()
    };
    let mut rows = vec![
        vec![
            "train step cpu (this host, measured)".into(),
            fmt_duration(cpu_step),
            "-".into(),
            speedup(cpu_step, gpu_step) + " xla-host speedup",
        ],
        vec![
            "train step xla-host (measured)".into(),
            fmt_duration(gpu_step),
            "-".into(),
            "-".into(),
        ],
    ];
    // Paper-hardware rows: training step at the paper's model scale
    // (AlexNet-class fwd+bwd ≈ 2.1 GFLOP/image).
    {
        use crate::hetero::roofline::{KernelCost, RooflineDevice};
        let cost = KernelCost { flops: 2.1e9 * 16.0, bytes: 16.0 * 15e6, irregular: false };
        let cpu_m = RooflineDevice::server_cpu().time(&cost);
        let gpu_m = RooflineDevice::m40_gpu().time(&cost);
        rows.push(vec![
            "train step xeon-class (roofline)".into(),
            fmt_duration(cpu_m),
            "-".into(),
            speedup(cpu_m, gpu_m) + " modelled gpu speedup",
        ]);
        rows.push(vec![
            "train step m40-class (roofline)".into(),
            fmt_duration(gpu_m),
            "-".into(),
            "-".into(),
        ]);
    }
    // (b) virtual-time: latency per pass vs #GPUs (Fig 9's curve), with
    // the per-round parameter sync modelled over the network.
    let batches_per_pass = if quick { 2_000u64 } else { 8_000 };
    let param_bytes = 63_000u64 * 4;
    let mut base: Option<Duration> = None;
    for gpus in [1usize, 2, 4, 8] {
        let cluster = SimCluster {
            nodes: gpus,
            cores_per_node: 1, // one accelerator queue per node (Fig 9 setup)
            net_bps: 1.2e9,
            disk_bps: 400e6,
            sched_overhead: Duration::from_millis(2),
            straggler_cv: 0.05,
            seed: 9,
        };
        let tasks: Vec<SimTask> = (0..batches_per_pass)
            .map(|_| SimTask {
                compute: gpu_step,
                input_bytes: 16 * 32 * 32 * 3 * 4,
                remote_read: false,
                output_bytes: 0,
            })
            .collect();
        // One barrier per pass chunk: model parameter sync as an extra
        // stage whose tasks are the gradient pushes.
        let sync = SimStageSync(gpus, param_bytes);
        let job = SimJob {
            stages: vec![
                crate::dce::SimStage { name: "grads".into(), tasks },
                crate::dce::SimStage {
                    name: "sync".into(),
                    tasks: (0..sync.0)
                        .map(|_| SimTask {
                            compute: Duration::from_micros(200),
                            input_bytes: sync.1,
                            remote_read: true,
                            output_bytes: sync.1,
                        })
                        .collect(),
                },
            ],
        };
        let r = crate::dce::simclock::simulate(&cluster, &job);
        let b = *base.get_or_insert(r.makespan);
        rows.push(vec![
            format!("pass on {gpus} gpu(s)"),
            fmt_duration(r.makespan),
            format!("{:.2}x", b.as_secs_f64() / r.makespan.as_secs_f64()),
            format!("util {:.0}%", r.utilization * 100.0),
        ]);
    }
    Ok(Table {
        id: "e9",
        title: format!(
            "distributed training: real step latency + per-pass scaling ({batches_per_pass} batches/pass)"
        ),
        mode: "real (steps) + virtual-time (scaling)",
        header: vec!["row", "time", "scaling", "extra"],
        rows,
        notes: "paper: 15x GPU over CPU (§4.3); Fig 9: per-pass latency drops near-linearly with #GPUs.".into(),
    })
}

struct SimStageSync(usize, u64);

// ===========================================================================
// E10 (§5.2): map pipeline fused vs staged
// ===========================================================================

fn e10_mapgen(quick: bool) -> Result<Table> {
    let d = dispatcher()?;
    // Production-fidelity clouds (dense LiDAR) with subsampled ICP: the
    // stage boundaries move full-density data, compute does not — the
    // exact regime the paper's 5x in-memory win lives in.
    let density = if quick { 2 } else { 20 };
    let world = mapgen::gen_world_with_density(10, density);
    let steps = if quick { 40 } else { 400 };
    let log = mapgen::gen_drive(&world, steps, 10);
    let cfg = mapgen::SlamConfig {
        device: DeviceKind::Gpu,
        icp_every: 60,
        ..Default::default()
    };
    let tier = PlatformConfig::bench().storage.dfs;
    let dfs = DfsStore::new(tier, true, MetricsRegistry::new())?;
    let rm = ResourceManager::new(&PlatformConfig::bench().cluster, MetricsRegistry::new());
    let fused = mapgen::run_fused(
        &d,
        &rm,
        &log,
        &cfg,
        &crate::platform::JobOpts::new("mapgen-fused"),
        0.1,
    )?;
    let staged = mapgen::run_staged(
        &d,
        &rm,
        &dfs,
        &log,
        &cfg,
        &crate::platform::JobOpts::new("mapgen-staged"),
        0.1,
    )?;
    Ok(Table {
        id: "e10",
        title: format!("HD-map pipeline, {steps}-step drive (SLAM err {:.2} m)", fused.slam_err_m),
        mode: "real+model",
        header: vec!["pipeline", "time", "cells", "signs", "speedup"],
        rows: vec![
            vec![
                "fused (one job, in-memory)".into(),
                fmt_duration(fused.elapsed),
                fused.occupied_cells.to_string(),
                fused.signs.to_string(),
                speedup(staged.elapsed, fused.elapsed),
            ],
            vec![
                "staged (DFS per stage)".into(),
                fmt_duration(staged.elapsed),
                staged.occupied_cells.to_string(),
                staged.signs.to_string(),
                "1.0x".into(),
            ],
        ],
        notes: "paper: 5x from linking the stages into one job with in-memory intermediates.".into(),
    })
}

// ===========================================================================
// E11 (§5.2): ICP on GPU-class vs CPU
// ===========================================================================

fn e11_icp(quick: bool) -> Result<Table> {
    let d = dispatcher()?;
    let mut rng = Rng::new(11);
    let n = 4096;
    let src: Vec<f32> = (0..n * 3).map(|_| rng.normal_f32(0.0, 8.0)).collect();
    let tf = crate::pointcloud::Se3::new(crate::pointcloud::rot_z(0.05), [0.4, -0.2, 0.1]);
    let dst = tf.apply_cloud(&src);
    let iters = if quick { 2 } else { 5 };
    let mut rows = Vec::new();
    let mut cpu_time = Duration::ZERO;
    for kind in [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::Fpga] {
        let t = Instant::now();
        let r = mapgen::icp_align(&d, kind, &src, &dst, n, iters)?;
        let elapsed = t.elapsed();
        if kind == DeviceKind::Cpu {
            cpu_time = elapsed;
        }
        rows.push(vec![
            format!("{} (this host, measured)", kind.name()),
            fmt_duration(elapsed),
            format!("{:.4}", r.final_err),
            format!("{}", r.iterations),
            if kind == DeviceKind::Cpu { "1.0x".into() } else { speedup(cpu_time, elapsed) },
        ]);
    }
    // Paper-hardware rows (roofline): map-production clouds are ~100k
    // points per alignment; CPU-side NN search is irregular (KD-tree),
    // accelerator side is dense brute force.
    use crate::hetero::roofline::{icp_iter_cost, RooflineDevice};
    let big_n = 100_000usize;
    let cpu_m = RooflineDevice::server_cpu();
    let gpu_m = RooflineDevice::m40_gpu();
    let t_cpu = cpu_m.time(&icp_iter_cost(big_n, big_n, true)).mul_f64(iters as f64);
    let t_gpu = gpu_m.time(&icp_iter_cost(big_n, big_n, false)).mul_f64(iters as f64);
    rows.push(vec![
        format!("{} @100k pts", cpu_m.name),
        fmt_duration(t_cpu),
        "-".into(),
        format!("{iters}"),
        "1.0x".into(),
    ]);
    rows.push(vec![
        format!("{} @100k pts", gpu_m.name),
        fmt_duration(t_gpu),
        "-".into(),
        format!("{iters}"),
        speedup(t_cpu, t_gpu),
    ]);
    Ok(Table {
        id: "e11",
        title: format!(
            "ICP alignment, {n}-point clouds, {iters} iterations (measured + paper-hardware roofline)"
        ),
        mode: "real + roofline model",
        header: vec!["device", "time", "final err", "iters", "speedup vs cpu"],
        rows,
        notes: "paper: 30x by offloading the ICP core to GPU. Host rows are single-core (no hardware parallelism available); modelled rows use 2016-era rooflines at map-production cloud sizes.".into(),
    })
}

// ===========================================================================
// E12 (§2.1): reliability soak with fault injection
// ===========================================================================

fn e12_reliability(quick: bool) -> Result<Table> {
    let ctx = DceContext::new(PlatformConfig::test())?;
    let jobs = if quick { 20 } else { 200 };
    let injected = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let i2 = injected.clone();
    // 10% of first attempts crash (executor loss), deterministic per task.
    ctx.set_fail_injector(Some(Arc::new(move |tc| {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        use std::hash::{Hash, Hasher};
        (tc.stage.as_str(), tc.partition).hash(&mut h);
        if tc.attempt == 0 && h.finish() % 10 == 0 {
            i2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            anyhow::bail!("injected executor crash");
        }
        Ok(())
    })));
    let mut ok = 0usize;
    let mut correct = 0usize;
    let t = Instant::now();
    for j in 0..jobs {
        let n = 200 + (j as u64 % 100);
        let expected: u64 = (0..n).map(|x| x * 2).filter(|x| x % 3 == 0).sum();
        let got = ctx
            .range(n, 4)
            .map(|x| x * 2)
            .filter(|x| x % 3 == 0)
            .key_by(|x| (x % 8) as u32)
            .reduce_by_key(|a, b| a + b, 4)
            .collect()
            .map(|rows| rows.into_iter().map(|(_, v)| v).sum::<u64>());
        if let Ok(sum) = got {
            ok += 1;
            if sum == expected {
                correct += 1;
            }
        }
    }
    let elapsed = t.elapsed();
    ctx.set_fail_injector(None);
    let inj = injected.load(std::sync::atomic::Ordering::Relaxed);
    Ok(Table {
        id: "e12",
        title: format!("fault-injection soak: {jobs} shuffle jobs, 10% first-attempt crash rate"),
        mode: "real",
        header: vec!["metric", "value"],
        rows: vec![
            vec!["jobs completed".into(), format!("{ok}/{jobs}")],
            vec!["results correct".into(), format!("{correct}/{jobs}")],
            vec!["failures injected".into(), inj.to_string()],
            vec!["soak time".into(), fmt_duration(elapsed)],
        ],
        notes: "paper: 1,000-machine stress test -> 'ran smoothly with very few crashes'. Here: every injected crash is retried/recovered with correct results.".into(),
    })
}

// ===========================================================================
// E13: scenario-campaign throughput, 1 -> 8 simulated nodes
// ===========================================================================

fn e13_campaign(quick: bool) -> Result<Table> {
    // Calibrate the per-scenario cost from a REAL campaign on the local
    // cluster (CPU detection path — no artifacts required).
    let n = if quick { 6 } else { 16 };
    let frames = if quick { 8 } else { 32 };
    let cfg = PlatformConfig::test();
    let ctx = DceContext::new(cfg.clone())?;
    let rm = crate::resource::ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
    let specs = scenario::generate_campaign_sized(13, n, frames);
    let ccfg = scenario::CampaignConfig::new("e13", 2);
    let real = scenario::run_campaign(&ctx, &rm, &specs, &ccfg)?;
    // The calibration campaign ran its shards concurrently, so scale
    // wall-elapsed back up to per-scenario *compute* cost.
    let per_scenario = real.elapsed * real.shards as u32 / n as u32;
    // Virtual time: a fleet-qualification campaign of 256 scenarios at
    // 1/2/4/8 nodes x 8 cores, each task one scenario (materialize +
    // replay), inputs read remotely like sharded bag chunks.
    let campaign_n = 256u64;
    let frame_bytes = (8 + 4 + 64 * 64 * 4) as u64;
    // Match the calibration scenarios' size so the virtual I/O model is
    // consistent with the measured compute cost.
    let scenario_bytes = frames as u64 * frame_bytes;
    let mut rows = vec![vec![
        format!("calib ({n} scen, real)"),
        fmt_duration(real.elapsed),
        format!("{:.1}/s", real.scenarios_per_sec()),
        "-".into(),
    ]];
    rows.extend(sweep_rows(|nodes| {
        let cluster = SimCluster {
            nodes,
            cores_per_node: 8,
            seed: 13,
            ..SimCluster::with_cores(nodes * 8)
        };
        let job = SimJob::single_stage(
            "campaign",
            (0..campaign_n as usize)
                .map(|_| SimTask {
                    compute: per_scenario,
                    input_bytes: scenario_bytes,
                    remote_read: true,
                    output_bytes: 128,
                })
                .collect(),
        );
        let r = crate::dce::simclock::simulate(&cluster, &job);
        Ok((
            vec![
                format!("{nodes} node(s)"),
                fmt_duration(r.makespan),
                format!("{:.1}/s", campaign_n as f64 / r.makespan.as_secs_f64().max(1e-9)),
            ],
            1.0 / r.makespan.as_secs_f64().max(1e-9),
        ))
    })?);
    Ok(Table {
        id: "e13",
        title: format!(
            "scenario-campaign throughput, {campaign_n} scenarios (per-scenario cost calibrated: {} — {}/{} passed on real subset)",
            fmt_duration(per_scenario),
            real.passed,
            real.scenarios
        ),
        mode: "virtual-time (calibrated by real campaign)",
        header: vec!["nodes", "campaign time", "scenarios/sec", "speedup"],
        rows,
        notes: "campaign tasks are embarrassingly parallel: throughput should scale near-linearly with nodes until bag I/O dominates.".into(),
    })
}

// ===========================================================================
// E14: sustained ingest throughput, 1 -> 8 log partitions
// ===========================================================================

/// One timed ingest run: `parts` producer threads (one per partition)
/// append a fixed record stream — one frame at a time, or group-
/// committed in 256-record batches when `batched` — while an optional
/// concurrent compactor drains the partitions into a tiered store.
/// Returns the elapsed wall time, the p99 consumer tail lag (sampled
/// once per 256 appended records), and the records retention truncated
/// before any consumer read them.
fn e14_run(
    parts: usize,
    records_per_part: u64,
    payload: &[u8],
    with_compaction: bool,
    batched: bool,
) -> Result<(Duration, u64, u64)> {
    use crate::ingest::{AppendRecord, LogConfig, PartitionedLog};
    use std::sync::atomic::{AtomicBool, Ordering};

    const CHUNK: u64 = 256;

    let log = PartitionedLog::temp(
        "e14",
        LogConfig {
            partitions: parts,
            segment_bytes: 512 << 10,
            retention_bytes: 1 << 30,
            ..Default::default()
        },
    )?;
    let store = crate::storage::TieredStore::test_store(&PlatformConfig::test().storage);
    let stop = AtomicBool::new(false);
    let mut elapsed = Duration::ZERO;
    let mut lag_samples: Vec<u64> = Vec::new();
    std::thread::scope(|s| -> Result<()> {
        let drainer = with_compaction.then(|| {
            let (log, store, stop) = (log.clone(), store.clone(), &stop);
            s.spawn(move || {
                // A lean consumer loop: read committed..head through the
                // zero-copy path, pack the borrowed frames into a block,
                // land it, commit — the same lock and store traffic the
                // container compactor generates.
                while !stop.load(Ordering::Relaxed) {
                    let mut idle = true;
                    for p in 0..log.partitions() {
                        let from = log.committed(p).max(log.start_offset(p));
                        let drained = log.read_range_with(p, from, 512, |frames| {
                            Ok(frames
                                .last()
                                .map(|f| (f.offset + 1, crate::ingest::encode_block_refs(frames))))
                        });
                        if let Ok(Some((next, block))) = drained {
                            idle = false;
                            let _ = store.put(&format!("e14/p{p}/b{from:010}"), block);
                            let _ = log.commit(p, next);
                        }
                    }
                    if idle {
                        std::thread::yield_now();
                    }
                }
            })
        });
        let t = Instant::now();
        let mut producers = Vec::new();
        for p in 0..parts {
            let log = log.clone();
            producers.push(s.spawn(move || -> Result<Vec<u64>> {
                let mut lags = Vec::new();
                if batched {
                    let mut i = 0u64;
                    while i < records_per_part {
                        let n = CHUNK.min(records_per_part - i);
                        let recs: Vec<AppendRecord> = (i..i + n)
                            .map(|j| AppendRecord {
                                ts_ns: j * 100_000_000,
                                source: p as u32,
                                payload,
                            })
                            .collect();
                        log.append_batch(p, &recs)?;
                        lags.push(log.lag(p));
                        i += n;
                    }
                } else {
                    for i in 0..records_per_part {
                        log.append(p, i * 100_000_000, p as u32, payload)?;
                        if (i + 1) % CHUNK == 0 {
                            lags.push(log.lag(p));
                        }
                    }
                }
                Ok(lags)
            }));
        }
        for h in producers {
            lag_samples.extend(h.join().expect("e14 producer panicked")?);
        }
        elapsed = t.elapsed();
        stop.store(true, Ordering::Relaxed);
        if let Some(d) = drainer {
            let _ = d.join();
        }
        Ok(())
    })?;
    lag_samples.sort_unstable();
    let p99 = match lag_samples.len() {
        0 => 0,
        n => lag_samples[(n - 1) * 99 / 100],
    };
    let lost: u64 = (0..parts).map(|p| log.lost_records(p)).sum();
    Ok((elapsed, p99, lost))
}

/// §3-adjacent ingest benchmark, reworked for the group-commit log:
/// per-frame appends (the `--baseline` path) vs 256-record
/// `append_batch` group commits, plus a contended run with a
/// concurrent zero-copy drain. Emits machine-readable `BENCH_E14.json`
/// so `adcloud bench-diff` can defend the batched append rate.
fn e14_ingest(quick: bool) -> Result<Table> {
    use crate::util::json::Json;

    let records_per_part = if quick { 2_000u64 } else { 20_000 };
    let payload = vec![7u8; 256];
    let mut json_rows = Vec::new();
    let mut speedup_at_8 = 0.0;
    let rows = sweep_rows(|parts| {
        let total = records_per_part * parts as u64;
        let (plain, _, _) = e14_run(parts, records_per_part, &payload, false, false)?;
        let (grouped, _, _) = e14_run(parts, records_per_part, &payload, false, true)?;
        let (contended, lag_p99, lost) =
            e14_run(parts, records_per_part, &payload, true, true)?;
        let rps = total as f64 / plain.as_secs_f64().max(1e-9);
        let rps_b = total as f64 / grouped.as_secs_f64().max(1e-9);
        let rps_c = total as f64 / contended.as_secs_f64().max(1e-9);
        let batched_speedup = rps_b / rps.max(1e-9);
        if parts == 8 {
            speedup_at_8 = batched_speedup;
        }
        json_rows.push(Json::obj(vec![
            ("partitions", Json::num(parts as f64)),
            ("per_frame_records_per_sec", Json::num(rps)),
            ("batched_records_per_sec", Json::num(rps_b)),
            ("batched_speedup", Json::num(batched_speedup)),
            ("with_compaction_records_per_sec", Json::num(rps_c)),
            ("tail_lag_p99", Json::num(lag_p99 as f64)),
            ("lost_records", Json::num(lost as f64)),
        ]));
        Ok((
            vec![
                format!("{parts}"),
                format!("{:.0}/s", rps),
                format!("{:.0}/s", rps_b),
                format!("{batched_speedup:.1}x"),
                format!("{:.0}/s", rps_c),
                format!("{lag_p99}"),
                format!("{lost}"),
            ],
            rps_b,
        ))
    })?;
    let json = Json::obj(vec![
        ("experiment", Json::str("e14")),
        ("quick", Json::Bool(quick)),
        ("batched_speedup_at_8_partitions", Json::num(speedup_at_8)),
        ("rows", Json::arr(json_rows)),
    ]);
    let json_path = "BENCH_E14.json";
    std::fs::write(json_path, json.to_string_pretty())?;
    Ok(Table {
        id: "e14",
        title: format!(
            "sustained fleet ingest, {records_per_part} x 256 B records per partition \
             (one producer thread per partition)"
        ),
        mode: "real",
        header: vec![
            "partitions",
            "per-frame",
            "group-commit",
            "speedup",
            "with compaction",
            "lag p99",
            "lost",
            "scaling",
        ],
        rows,
        notes: format!(
            "per-frame = one segment write per record (the `adcloud --baseline` admission \
             path appends this way); group-commit = 256-record append_batch, one segment \
             write per batch. lag p99 / lost come from the contended run (concurrent \
             zero-copy drain into the tiered store). Rows written to {json_path}."
        ),
    })
}

// ===========================================================================
// E15: multi-tenancy — two concurrent jobs under capacity-share queues
// ===========================================================================

/// One concurrent two-tenant run: a scenario campaign on its configured
/// queue and a fleet-compaction drain on its configured queue, started
/// together (or with the compaction arriving `stagger` later — the
/// late-tenant shape the preemption experiments measure) and joined.
/// Shared by E15, E16, the `jobs` CLI subcommand, and
/// `examples/unified_jobs.rs`. Errors if any container is still live
/// when both jobs have finished (the RAII-grant contract).
pub struct TenantPairRun {
    pub campaign: scenario::CampaignReport,
    pub campaign_elapsed: Duration,
    pub compaction: ingest::CompactionReport,
    pub compaction_elapsed: Duration,
    pub makespan: Duration,
}

pub fn run_tenant_pair(
    ctx: &DceContext,
    rm: &Arc<ResourceManager>,
    specs: &[scenario::ScenarioSpec],
    campaign_cfg: &scenario::CampaignConfig,
    log: &Arc<ingest::PartitionedLog>,
    store: &Arc<TieredStore>,
    compactor_cfg: &ingest::CompactorConfig,
    stagger: Duration,
) -> Result<TenantPairRun> {
    let t = Instant::now();
    let (camp, comp) = std::thread::scope(|s| {
        let camp = s.spawn(|| {
            let t = Instant::now();
            scenario::run_campaign(ctx, rm, specs, campaign_cfg).map(|r| (r, t.elapsed()))
        });
        let comp = s.spawn(|| {
            if !stagger.is_zero() {
                std::thread::sleep(stagger);
            }
            let t = Instant::now();
            ingest::compact(log, store, rm, compactor_cfg).map(|r| (r, t.elapsed()))
        });
        (camp.join().expect("campaign job"), comp.join().expect("compaction job"))
    });
    let makespan = t.elapsed();
    let (campaign, campaign_elapsed) = camp?;
    let (compaction, compaction_elapsed) = comp?;
    anyhow::ensure!(rm.live_containers() == 0, "tenant pair leaked containers");
    Ok(TenantPairRun { campaign, campaign_elapsed, compaction, compaction_elapsed, makespan })
}

/// Two jobs run concurrently against a 50/50 capacity split: a scenario
/// campaign on queue `sim` and a fleet-compaction drain on queue
/// `fleet`, both scheduled through the unified job layer, at 1/2/4/8
/// nodes. The first true multi-tenant benchmark of the platform:
/// per-queue throughput plus the grant-wait latency the job layer
/// records.
fn e15_multitenant(quick: bool) -> Result<Table> {
    use crate::ingest::{LogConfig, PartitionedLog};

    let scen_n = if quick { 4 } else { 16 };
    let frames = if quick { 8u32 } else { 16 };
    let records_per_part = if quick { 200u64 } else { 2_000 };
    let rows = sweep_rows(|nodes| {
        let mut cfg = PlatformConfig::test();
        cfg.cluster.nodes = nodes;
        let metrics = MetricsRegistry::new();
        let rm = ResourceManager::with_queues(
            &cfg.cluster,
            vec![("sim".into(), 0.5), ("fleet".into(), 0.5)],
            metrics.clone(),
        );
        let ctx = DceContext::new(cfg.clone())?;
        // Fleet side: a pre-filled partitioned log to drain.
        let parts = nodes.max(2);
        let log = PartitionedLog::temp(
            &format!("e15-{nodes}"),
            LogConfig {
                partitions: parts,
                segment_bytes: 64 << 10,
                retention_bytes: 1 << 30,
                ..Default::default()
            },
        )?;
        for p in 0..parts {
            for i in 0..records_per_part {
                log.append(p, i * 1_000_000, p as u32, &[7u8; 200])?;
            }
        }
        let store = TieredStore::test_store(&cfg.storage);
        // Sim side: a procedurally generated campaign.
        let specs = scenario::generate_campaign_sized(15, scen_n, frames);
        let mut ccfg = scenario::CampaignConfig::new(format!("e15-camp-{nodes}"), nodes);
        ccfg.opts.queue = "sim".into();
        let mut kcfg = ingest::CompactorConfig::new(format!("e15-comp-{nodes}"), nodes);
        kcfg.opts.queue = "fleet".into();

        let run = run_tenant_pair(&ctx, &rm, &specs, &ccfg, &log, &store, &kcfg, Duration::ZERO)?;
        let wait = metrics.histogram("platform.job.grant_wait");
        Ok((
            vec![
                format!("{nodes}"),
                fmt_duration(run.makespan),
                format!(
                    "{:.1}/s",
                    run.campaign.scenarios as f64 / run.campaign_elapsed.as_secs_f64().max(1e-9)
                ),
                format!(
                    "{:.0}/s",
                    run.compaction.records as f64 / run.compaction_elapsed.as_secs_f64().max(1e-9)
                ),
                fmt_duration(wait.max()),
            ],
            1.0 / run.makespan.as_secs_f64().max(1e-9),
        ))
    })?;
    Ok(Table {
        id: "e15",
        title: format!(
            "two concurrent jobs on capacity-share queues (sim 50% / fleet 50%): \
             {scen_n}-scenario campaign + {records_per_part} records/partition compaction"
        ),
        mode: "real",
        header: vec!["nodes", "makespan", "sim scen/s", "fleet rec/s", "grant wait max", "scaling"],
        rows,
        notes: "both tenants schedule through JobSpec/JobHandle; the capacity scheduler caps \
                each queue at half the cores, so neither job can starve the other, and \
                throughput on both queues should grow with node count."
            .into(),
    })
}

// ===========================================================================
// E16: fair-share preemption — reclaim latency and wasted work
// ===========================================================================

/// One E16 configuration. Queues `sim` and `fleet` are guaranteed 50%
/// each with elastic ceilings of 100%: a scenario campaign balloons
/// over its share to the whole idle cluster, then a compaction job
/// arrives late on `fleet`, below its guarantee. Returns `(reclaim
/// wait, rescored scenarios, campaign wall time, makespan)` — reclaim
/// wait is how long the late tenant's first (gang) grant blocked, and
/// rescored counts scenario scorings beyond one per scenario (the work
/// preemption wasted; zero when checkpointing absorbs the requeue).
fn e16_run(
    nodes: usize,
    preempt: bool,
    scen_per_core: usize,
    frames: u32,
    records_per_part: u64,
) -> Result<(Duration, u64, Duration, Duration)> {
    use crate::ingest::{LogConfig, PartitionedLog};

    let mut cfg = PlatformConfig::test();
    cfg.cluster.nodes = nodes;
    let cores = cfg.cluster.total_cores();
    let metrics = MetricsRegistry::new();
    let rm = ResourceManager::with_elastic_queues(
        &cfg.cluster,
        vec![("sim".into(), 0.5, 1.0), ("fleet".into(), 0.5, 1.0)],
        metrics.clone(),
    );
    rm.set_preemption(preempt);
    let ctx = DceContext::new(cfg.clone())?;
    let parts = nodes.max(2);
    let log = PartitionedLog::temp(
        &format!("e16-{nodes}-{preempt}"),
        LogConfig {
            partitions: parts,
            segment_bytes: 64 << 10,
            retention_bytes: 1 << 30,
            ..Default::default()
        },
    )?;
    for p in 0..parts {
        for i in 0..records_per_part {
            log.append(p, i * 1_000_000, p as u32, &[7u8; 200])?;
        }
    }
    let store = TieredStore::test_store(&cfg.storage);
    let specs = scenario::generate_campaign_sized(16, scen_per_core * cores, frames);
    let mut ccfg = scenario::CampaignConfig::new(format!("e16-camp-{nodes}-{preempt}"), cores);
    ccfg.opts.queue = "sim".into();
    ccfg.opts.checkpoint = true;
    let mut kcfg = ingest::CompactorConfig::new(format!("e16-comp-{nodes}-{preempt}"), parts);
    kcfg.opts.queue = "fleet".into();

    let t0 = Instant::now();
    let (camp, comp) = std::thread::scope(|s| {
        let camp = s.spawn(|| {
            let t = Instant::now();
            scenario::run_campaign(&ctx, &rm, &specs, &ccfg).map(|r| (r, t.elapsed()))
        });
        // The late tenant arrives once the campaign holds the whole
        // cluster (not a guessed sleep — poll the live-container count
        // so the over-share state is guaranteed).
        while rm.live_containers() < cores && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(1));
        }
        let comp = ingest::compact(&log, &store, &rm, &kcfg);
        (camp.join().expect("campaign job"), comp)
    });
    let makespan = t0.elapsed();
    let (campaign, campaign_elapsed) = camp?;
    let compaction = comp?;
    anyhow::ensure!(rm.live_containers() == 0, "e16 leaked containers");
    anyhow::ensure!(campaign.scenarios == specs.len(), "campaign lost scenarios");
    anyhow::ensure!(
        compaction.records == parts as u64 * records_per_part,
        "compaction lost records"
    );
    // The campaign's grant lands on an idle cluster (wait ~0); the
    // histogram max is therefore the late tenant's reclaim wait.
    let reclaim = metrics.histogram("platform.job.grant_wait").max();
    let scored = ctx.metrics().counter("scenario.scored").get();
    Ok((reclaim, scored.saturating_sub(specs.len() as u64), campaign_elapsed, makespan))
}

/// Fair-share preemption on/off at 1/2/4/8 nodes: an over-share
/// campaign vs. a late-arriving compaction job. With preemption off the
/// late tenant's first grant waits for the campaign to finish; with it
/// on, a victim shard checkpoints and yields, so the grant lands at a
/// scenario boundary and checkpoint/resume reruns zero completed work.
fn e16_preemption(quick: bool) -> Result<Table> {
    let scen_per_core = if quick { 3 } else { 4 };
    let frames = if quick { 12 } else { 24 };
    let records = if quick { 300 } else { 2_000 };
    let mut rows = Vec::new();
    for nodes in SWEEP_NODES {
        let mut off_reclaim = Duration::ZERO;
        for preempt in [false, true] {
            let (reclaim, rescored, campaign_elapsed, makespan) =
                e16_run(nodes, preempt, scen_per_core, frames, records)?;
            let speedup = if preempt {
                format!("{:.1}x", off_reclaim.as_secs_f64() / reclaim.as_secs_f64().max(1e-9))
            } else {
                off_reclaim = reclaim;
                "-".into()
            };
            rows.push(vec![
                format!("{nodes}"),
                String::from(if preempt { "on" } else { "off" }),
                fmt_duration(reclaim),
                format!("{rescored}"),
                fmt_duration(campaign_elapsed),
                fmt_duration(makespan),
                speedup,
            ]);
        }
    }
    Ok(Table {
        id: "e16",
        title: format!(
            "fair-share preemption: over-share campaign ({scen_per_core} scen/core) vs. \
             late compaction ({records} records/partition), queues sim/fleet 50% \
             guaranteed with 100% ceilings"
        ),
        mode: "real",
        header: vec![
            "nodes",
            "preempt",
            "reclaim wait",
            "rescored",
            "campaign",
            "makespan",
            "reclaim speedup",
        ],
        rows,
        notes: "reclaim wait is the late below-share tenant's first grant wait; with \
                preemption on it lands at a scenario boundary instead of the campaign's end, \
                and the rescored column shows checkpoint/resume rerunning zero completed \
                scenarios."
            .into(),
    })
}

// ===========================================================================
// E17: data-plane fast path — sharded store vs the old single-lock path
// ===========================================================================

/// The E17 store: MEM sized well below the working set so the steady
/// state is an eviction cascade on every put — victim selection IS the
/// benchmark. `baseline` forces the pre-PR-5 path (one shard, one
/// global lock, O(n) scan per victim); otherwise the lock-striped
/// store with its incremental eviction index runs.
fn e17_store(baseline: bool) -> Arc<TieredStore> {
    use crate::config::TierConfig;
    let mut cfg = PlatformConfig::test().storage;
    cfg.mem = TierConfig { capacity_bytes: 1 << 20, bandwidth_bps: 1e12, latency_us: 0 };
    cfg.ssd = TierConfig { capacity_bytes: 8 << 20, bandwidth_bps: 1e12, latency_us: 0 };
    cfg.hdd = TierConfig { capacity_bytes: 64 << 20, bandwidth_bps: 1e12, latency_us: 0 };
    cfg.model_devices = false;
    cfg.scan_evict = baseline;
    TieredStore::test_store(&cfg)
}

/// One store microbench: `threads` workers each run `ops` operations
/// (2/3 put, 1/3 get-with-promotion) over per-thread key ranges sized
/// so every MEM insert evicts. Returns aggregate ops/sec.
fn e17_store_run(threads: usize, ops: u64, baseline: bool) -> Result<f64> {
    e17_store_run_on(&e17_store(baseline), threads, ops)
}

/// The microbench core, against a caller-owned store — E18 reuses it
/// to measure the same workload with the tracer on vs. off.
fn e17_store_run_on(store: &Arc<TieredStore>, threads: usize, ops: u64) -> Result<f64> {
    const KEYS_PER_THREAD: u64 = 512;
    const BLOCK: usize = 4096;
    let val = vec![7u8; BLOCK];
    // Pre-populate the resident set so the first measured op already
    // pays steady-state eviction cost (persist=false: this measures
    // the tier path, not the host's disk).
    for t in 0..threads {
        for k in 0..KEYS_PER_THREAD {
            store.put_opts(&format!("t{t}/k{k}"), val.clone(), false, false)?;
        }
    }
    let start = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let mut workers = Vec::new();
        for t in 0..threads {
            let store = store.clone();
            let val = val.clone();
            workers.push(s.spawn(move || -> Result<()> {
                let mut rng = Rng::new(17_000 + t as u64);
                for _ in 0..ops {
                    let key = format!("t{t}/k{}", rng.below(KEYS_PER_THREAD));
                    if rng.below(3) == 0 {
                        // Lower-tier hits promote back to MEM, which
                        // cascades exactly like a put.
                        let _ = store.get(&key);
                    } else {
                        store.put_opts(&key, val.clone(), false, false)?;
                    }
                }
                Ok(())
            }));
        }
        for w in workers {
            w.join().expect("e17 store worker panicked")?;
        }
        Ok(())
    })?;
    store.check_invariants()?;
    Ok((threads as u64 * ops) as f64 / start.elapsed().as_secs_f64().max(1e-9))
}

/// One end-to-end configuration: the E15 tenant pair (campaign on
/// `sim`, compaction drain on `fleet`) over a store whose MEM tier is
/// squeezed so blocks + checkpoints churn through eviction, with the
/// storage path picked by `baseline`. Returns the makespan plus the
/// run's full metrics snapshot (counters/gauges/histograms), which
/// the BENCH json embeds per row.
fn e17_e2e_run(
    nodes: usize,
    baseline: bool,
    scen_n: usize,
    frames: u32,
    records_per_part: u64,
) -> Result<(Duration, crate::util::json::Json)> {
    use crate::ingest::{LogConfig, PartitionedLog};

    let mut cfg = PlatformConfig::test();
    cfg.cluster.nodes = nodes;
    cfg.storage.scan_evict = baseline;
    cfg.storage.mem.capacity_bytes = 256 << 10;
    let metrics = MetricsRegistry::new();
    let rm = ResourceManager::with_queues(
        &cfg.cluster,
        vec![("sim".into(), 0.5), ("fleet".into(), 0.5)],
        metrics.clone(),
    );
    let ctx = DceContext::new(cfg.clone())?;
    let parts = nodes.max(2);
    let log = PartitionedLog::temp(
        &format!("e17-{nodes}-{baseline}"),
        LogConfig {
            partitions: parts,
            segment_bytes: 64 << 10,
            retention_bytes: 1 << 30,
            ..Default::default()
        },
    )?;
    for p in 0..parts {
        for i in 0..records_per_part {
            log.append(p, i * 1_000_000, p as u32, &[7u8; 200])?;
        }
    }
    let specs = scenario::generate_campaign_sized(17, scen_n, frames);
    let mut ccfg =
        scenario::CampaignConfig::new(format!("e17-camp-{nodes}-{baseline}"), nodes);
    ccfg.opts.queue = "sim".into();
    let mut kcfg = ingest::CompactorConfig::new(format!("e17-comp-{nodes}-{baseline}"), nodes);
    kcfg.opts.queue = "fleet".into();
    let run = run_tenant_pair(
        &ctx,
        &rm,
        &specs,
        &ccfg,
        &log,
        ctx.store(),
        &kcfg,
        Duration::ZERO,
    )?;
    anyhow::ensure!(
        run.compaction.records == parts as u64 * records_per_part,
        "e17 compaction lost records"
    );
    // Two registries drive the run: the scheduler's (grant waits, live
    // containers) and the compute context's (store tiers, scenarios).
    let snapshot = crate::util::json::Json::obj(vec![
        ("scheduler", metrics.report_json()),
        ("workload", ctx.metrics().report_json()),
    ]);
    Ok((run.makespan, snapshot))
}

/// Data-plane fast path A/B: sharded lock-striped store + O(log n)
/// eviction index + work-stealing executors vs the old single-lock
/// O(n)-scan storage path, at 1/2/4/8 threads. Also emits the rows as
/// machine-readable `BENCH_E17.json` so later PRs have a perf
/// trajectory to defend.
fn e17_fastpath(quick: bool) -> Result<Table> {
    let ops = if quick { 800u64 } else { 3_000 };
    let scen_n = if quick { 4 } else { 8 };
    let frames = if quick { 8u32 } else { 16 };
    let records = if quick { 200u64 } else { 1_000 };
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut speedup_at_8 = 0.0;
    for threads in SWEEP_NODES {
        let base_ops = e17_store_run(threads, ops, true)?;
        let fast_ops = e17_store_run(threads, ops, false)?;
        let store_speedup = fast_ops / base_ops.max(1e-9);
        let (base_e2e, _) = e17_e2e_run(threads, true, scen_n, frames, records)?;
        let (fast_e2e, fast_metrics) = e17_e2e_run(threads, false, scen_n, frames, records)?;
        let e2e_speedup = base_e2e.as_secs_f64() / fast_e2e.as_secs_f64().max(1e-9);
        if threads == 8 {
            speedup_at_8 = store_speedup;
        }
        rows.push(vec![
            format!("{threads}"),
            format!("{:.0}/s", base_ops),
            format!("{:.0}/s", fast_ops),
            format!("{store_speedup:.1}x"),
            fmt_duration(base_e2e),
            fmt_duration(fast_e2e),
            format!("{e2e_speedup:.2}x"),
        ]);
        json_rows.push(crate::util::json::Json::obj(vec![
            ("threads", crate::util::json::Json::num(threads as f64)),
            ("store_baseline_ops_per_sec", crate::util::json::Json::num(base_ops)),
            ("store_sharded_ops_per_sec", crate::util::json::Json::num(fast_ops)),
            ("store_speedup", crate::util::json::Json::num(store_speedup)),
            ("e2e_baseline_sec", crate::util::json::Json::num(base_e2e.as_secs_f64())),
            ("e2e_sharded_sec", crate::util::json::Json::num(fast_e2e.as_secs_f64())),
            ("e2e_speedup", crate::util::json::Json::num(e2e_speedup)),
            ("metrics", fast_metrics),
        ]));
    }
    let json = crate::util::json::Json::obj(vec![
        ("experiment", crate::util::json::Json::str("e17")),
        ("quick", crate::util::json::Json::Bool(quick)),
        ("store_speedup_at_8_threads", crate::util::json::Json::num(speedup_at_8)),
        ("rows", crate::util::json::Json::arr(json_rows)),
    ]);
    let json_path = "BENCH_E17.json";
    std::fs::write(json_path, json.to_string_pretty())?;
    Ok(Table {
        id: "e17",
        title: format!(
            "data-plane fast path: sharded store vs single-lock baseline \
             ({ops} ops/thread over 512 x 4 KiB blocks/thread, MEM squeezed to force \
             eviction on every insert)"
        ),
        mode: "real",
        header: vec![
            "threads",
            "store base",
            "store sharded",
            "speedup",
            "e2e base",
            "e2e sharded",
            "speedup",
        ],
        rows,
        notes: format!(
            "baseline = pre-fast-path store (one global lock, O(n) scan per eviction \
             victim), forced by StorageConfig.scan_evict / `adcloud --baseline`. e2e = \
             concurrent campaign+compaction tenant pair on the same store. Rows written \
             to {json_path}."
        ),
    })
}

// ===========================================================================
// E18: causal tracing — critical-path attribution and tracing overhead
// ===========================================================================

/// Merge the critical paths of every job root in `spans`. Log pre-fill
/// and store microbenches leave stray single-span traces in the
/// archive, so only parentless spans named "job" count as roots.
fn job_critical_paths(spans: &[trace::SpanEvent]) -> (usize, CriticalPath) {
    let mut merged = CriticalPath::default();
    let mut jobs = 0;
    for e in spans {
        if e.parent_id == 0 && e.name == "job" {
            if let Some(cp) = analyze(spans, e.span_id) {
                merged.merge(&cp);
                jobs += 1;
            }
        }
    }
    (jobs, merged)
}

/// Run `f` with the tracer on and return its output plus every span
/// recorded during the run. A harvester thread drains the per-thread
/// rings every few milliseconds so span-heavy runs can't overflow one
/// container thread's ring between collections. Leaves the tracer
/// disabled on return.
fn with_tracing<T>(f: impl FnOnce() -> Result<T>) -> Result<(T, Vec<trace::SpanEvent>)> {
    use std::sync::atomic::{AtomicBool, Ordering};

    trace::tracer().enable();
    trace::tracer().clear();
    let stop = Arc::new(AtomicBool::new(false));
    let harvester = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                trace::tracer().collect();
                std::thread::sleep(Duration::from_millis(5));
            }
        })
    };
    let out = f();
    stop.store(true, Ordering::Relaxed);
    harvester.join().expect("trace harvester panicked");
    let spans = trace::tracer().take_all();
    trace::tracer().disable();
    Ok((out?, spans))
}

/// One traced E15-shaped tenant pair: concurrent campaign (queue
/// `sim`) + compaction drain (queue `fleet`). Returns the makespan,
/// the run's spans, and the metrics snapshot the BENCH json embeds.
fn e18_traced_pair(
    nodes: usize,
    scen_n: usize,
    frames: u32,
    records_per_part: u64,
) -> Result<(Duration, Vec<trace::SpanEvent>, crate::util::json::Json)> {
    use crate::ingest::{LogConfig, PartitionedLog};

    let mut cfg = PlatformConfig::test();
    cfg.cluster.nodes = nodes;
    let metrics = MetricsRegistry::new();
    let rm = ResourceManager::with_queues(
        &cfg.cluster,
        vec![("sim".into(), 0.5), ("fleet".into(), 0.5)],
        metrics.clone(),
    );
    let ctx = DceContext::new(cfg.clone())?;
    let parts = nodes.max(2);
    let log = PartitionedLog::temp(
        &format!("e18-{nodes}"),
        LogConfig {
            partitions: parts,
            segment_bytes: 64 << 10,
            retention_bytes: 1 << 30,
            ..Default::default()
        },
    )?;
    for p in 0..parts {
        for i in 0..records_per_part {
            log.append(p, i * 1_000_000, p as u32, &[7u8; 200])?;
        }
    }
    let store = TieredStore::test_store(&cfg.storage);
    let specs = scenario::generate_campaign_sized(18, scen_n, frames);
    let mut ccfg = scenario::CampaignConfig::new(format!("e18-camp-{nodes}"), nodes);
    ccfg.opts.queue = "sim".into();
    let mut kcfg = ingest::CompactorConfig::new(format!("e18-comp-{nodes}"), nodes);
    kcfg.opts.queue = "fleet".into();
    let (run, spans) = with_tracing(|| {
        run_tenant_pair(&ctx, &rm, &specs, &ccfg, &log, &store, &kcfg, Duration::ZERO)
    })?;
    let snapshot = crate::util::json::Json::obj(vec![
        ("scheduler", metrics.report_json()),
        ("workload", ctx.metrics().report_json()),
    ]);
    Ok((run.makespan, spans, snapshot))
}

/// Tracing-overhead gate: the E17 store microbench (8 threads, fast
/// path) with the tracer off vs. on, best-of-3 each way to shave
/// scheduler noise. Returns `(untraced ops/s, traced ops/s, overhead
/// %)`; the acceptance budget is <5%.
fn e18_overhead(ops: u64) -> Result<(f64, f64, f64)> {
    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    trace::tracer().disable();
    for _ in 0..3 {
        best_off = best_off.max(e17_store_run(8, ops, false)?);
    }
    trace::tracer().enable();
    for _ in 0..3 {
        best_on = best_on.max(e17_store_run(8, ops, false)?);
    }
    trace::tracer().disable();
    // Microbench spans are measurement exhaust, not a trace anyone
    // reads — keep them out of the attribution archive.
    trace::tracer().clear();
    let overhead_pct = (1.0 - best_on / best_off.max(1e-9)) * 100.0;
    Ok((best_off, best_on, overhead_pct))
}

/// Causal tracing end-to-end: per-category critical-path attribution
/// of the two-tenant pair at 1/2/4/8 nodes plus one preemption-heavy
/// E16 configuration, gated on tracing overhead staying under 5% on
/// the E17 store microbench. Emits machine-readable `BENCH_E18.json`.
fn e18_trace(quick: bool) -> Result<Table> {
    use crate::trace::Category as C;
    use crate::util::json::Json;

    let scen_n = if quick { 4 } else { 16 };
    let frames = if quick { 8u32 } else { 16 };
    let records = if quick { 200u64 } else { 2_000 };
    let ops = if quick { 800u64 } else { 3_000 };
    let was_enabled = trace::tracer().enabled();

    // Gate first: the attribution numbers are only worth reading if
    // collecting them stays effectively free.
    let (off_ops, on_ops, overhead_pct) = e18_overhead(ops)?;
    anyhow::ensure!(
        overhead_pct < 5.0,
        "tracing overhead {overhead_pct:.2}% exceeds the 5% budget \
         ({off_ops:.0}/s untraced vs {on_ops:.0}/s traced)"
    );

    let pct = |cp: &CriticalPath, cats: &[C]| -> String {
        let f: f64 = cats.iter().map(|&c| cp.category_frac(c)).sum();
        format!("{:.0}%", f * 100.0)
    };
    let compute = [C::Compute, C::Shuffle];
    let io = [C::StoreIo, C::LogIo];
    let waits = [C::GrantWait, C::PreemptRequeue, C::CheckpointReplay, C::Other];
    let mut json_rows = Vec::new();
    let mut rows = sweep_rows(|nodes| {
        let (makespan, spans, snapshot) = e18_traced_pair(nodes, scen_n, frames, records)?;
        let (jobs, cp) = job_critical_paths(&spans);
        anyhow::ensure!(jobs >= 2, "tenant pair must trace both job roots, got {jobs}");
        anyhow::ensure!(
            cp.sum_us() == cp.total_us,
            "attribution must partition the makespan exactly"
        );
        json_rows.push(Json::obj(vec![
            ("nodes", Json::num(nodes as f64)),
            ("shape", Json::str("pair")),
            ("makespan_sec", Json::num(makespan.as_secs_f64())),
            ("spans", Json::num(spans.len() as f64)),
            ("jobs", Json::num(jobs as f64)),
            ("critical_path", cp.to_json()),
            ("metrics", snapshot),
        ]));
        Ok((
            vec![
                format!("{nodes}"),
                "pair".into(),
                fmt_duration(makespan),
                format!("{}", spans.len()),
                pct(&cp, &compute),
                pct(&cp, &io),
                pct(&cp, &waits),
            ],
            1.0 / makespan.as_secs_f64().max(1e-9),
        ))
    })?;

    // One preemption-heavy configuration: the traced E16 over-share
    // campaign vs. a late compaction with preemption on, so the
    // preempt-requeue and grant-wait categories actually appear.
    let ((_, _, _, mk), spans) =
        with_tracing(|| e16_run(2, true, if quick { 3 } else { 4 }, frames, records))?;
    let (jobs, pcp) = job_critical_paths(&spans);
    anyhow::ensure!(jobs >= 2, "e16 pair must trace both job roots, got {jobs}");
    json_rows.push(Json::obj(vec![
        ("nodes", Json::num(2.0)),
        ("shape", Json::str("pair+preempt")),
        ("makespan_sec", Json::num(mk.as_secs_f64())),
        ("spans", Json::num(spans.len() as f64)),
        ("jobs", Json::num(jobs as f64)),
        ("critical_path", pcp.to_json()),
    ]));
    rows.push(vec![
        "2".into(),
        "pair+preempt".into(),
        fmt_duration(mk),
        format!("{}", spans.len()),
        pct(&pcp, &compute),
        pct(&pcp, &io),
        pct(&pcp, &waits),
        "-".into(),
    ]);

    let json = Json::obj(vec![
        ("experiment", Json::str("e18")),
        ("quick", Json::Bool(quick)),
        ("tracing_overhead_pct", Json::num(overhead_pct)),
        ("store_ops_per_sec_untraced", Json::num(off_ops)),
        ("store_ops_per_sec_traced", Json::num(on_ops)),
        ("rows", Json::arr(json_rows)),
    ]);
    let json_path = "BENCH_E18.json";
    std::fs::write(json_path, json.to_string_pretty())?;
    if was_enabled {
        // `--trace` was on when we started; keep tracing whatever the
        // caller runs next.
        trace::tracer().enable();
    }
    Ok(Table {
        id: "e18",
        title: format!(
            "causal tracing: critical-path attribution of the two-tenant pair \
             ({scen_n} scenarios + {records} records/partition) and tracing \
             overhead on the E17 store microbench"
        ),
        mode: "real",
        header: vec![
            "nodes",
            "shape",
            "makespan",
            "spans",
            "compute",
            "io",
            "wait/other",
            "scaling",
        ],
        rows,
        notes: format!(
            "compute = compute+shuffle, io = store-io+log-io, wait/other = grant-wait+\
             preempt-requeue+checkpoint-replay+other; each job's attribution partitions \
             its root span exactly (sums checked). Tracing overhead {overhead_pct:.1}% \
             on the store microbench (budget 5%, {off_ops:.0}/s untraced vs \
             {on_ops:.0}/s traced). Per-category micros in {json_path}."
        ),
    })
}

// ===========================================================================
// E19: observability — watchdog detection latency and sampler overhead
// ===========================================================================

/// A fast telemetry plane for fault-injection runs: 2 ms sampling,
/// built-in rules with no sustain window, so detection latency is the
/// sampler/watchdog pipeline itself rather than a debounce budget.
fn e19_obs(registry: MetricsRegistry) -> Arc<crate::obs::Observability> {
    crate::obs::Observability::start(
        registry,
        crate::obs::ObsConfig {
            sampler: crate::obs::SamplerConfig {
                period: Duration::from_millis(2),
                ..Default::default()
            },
            rules: crate::obs::builtin_rules(Duration::ZERO),
            ..Default::default()
        },
    )
}

/// Drive `fault` until `rule` reaches critical on `obs`. Returns the
/// detection latency (ms since this call) and the rule's peak value.
fn e19_detect(
    obs: &Arc<crate::obs::Observability>,
    rule: &str,
    timeout: Duration,
    mut fault: impl FnMut() -> Result<()>,
) -> Result<(f64, f64)> {
    let t0 = Instant::now();
    loop {
        fault()?;
        if obs.rule_level(rule) == Some(crate::obs::Level::Critical) {
            let peak = obs.rule_value(rule).unwrap_or(0.0);
            return Ok((t0.elapsed().as_secs_f64() * 1000.0, peak));
        }
        anyhow::ensure!(
            t0.elapsed() < timeout,
            "rule '{rule}' never went critical within {timeout:?}"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Paused compactor: records append but nothing ever commits, so the
/// partition's produced-minus-committed lag climbs past the 10k bound.
fn e19_fault_backlog(timeout: Duration) -> Result<(f64, f64)> {
    use crate::ingest::{GatewayConfig, IngestGateway, LogConfig, PartitionedLog, VehicleUpload};
    let m = MetricsRegistry::new();
    let obs = e19_obs(m.clone());
    let log = PartitionedLog::temp(
        "e19-backlog",
        LogConfig {
            partitions: 1,
            segment_bytes: 1 << 20,
            retention_bytes: 1 << 30,
            ..Default::default()
        },
    )?;
    let gcfg = GatewayConfig { rate_per_tick: u32::MAX, max_lag: u64::MAX };
    let gw = IngestGateway::new(log, gcfg, m);
    let mut i = 0u64;
    let out = e19_detect(&obs, "ingest-backlog", timeout, || {
        gw.begin_tick();
        for _ in 0..512 {
            gw.upload(&VehicleUpload::new(1, i, b"r".to_vec()))?;
            i += 1;
        }
        Ok(())
    })?;
    obs.stop();
    Ok(out)
}

/// Corrupt-CRC uploads: the dead-letter queue fills past 50 entries.
fn e19_fault_dlq(timeout: Duration) -> Result<(f64, f64)> {
    use crate::ingest::{GatewayConfig, IngestGateway, LogConfig, PartitionedLog, VehicleUpload};
    let m = MetricsRegistry::new();
    let obs = e19_obs(m.clone());
    let log = PartitionedLog::temp(
        "e19-dlq",
        LogConfig {
            partitions: 1,
            segment_bytes: 1 << 20,
            retention_bytes: 1 << 30,
            ..Default::default()
        },
    )?;
    let gw = IngestGateway::new(log, GatewayConfig::default(), m);
    let mut i = 0u64;
    let out = e19_detect(&obs, "ingest-dlq", timeout, || {
        gw.begin_tick();
        for _ in 0..4 {
            let mut up = VehicleUpload::new((i % 64) as u32, i, vec![7u8; 16]);
            up.payload[0] ^= 0xFF; // bit-flip after the CRC was declared
            gw.upload(&up)?;
            i += 1;
        }
        Ok(())
    })?;
    obs.stop();
    Ok(out)
}

/// Over-admitted queue: one job holds every core while a late job
/// blocks in admission, so its recorded grant wait blows the p99 rule.
fn e19_fault_grant_wait(timeout: Duration) -> Result<(f64, f64)> {
    let m = MetricsRegistry::new();
    let obs = e19_obs(m.clone());
    let mut cfg = PlatformConfig::test();
    cfg.cluster.nodes = 1;
    let rm = ResourceManager::new(&cfg.cluster, m);
    let cores = cfg.cluster.total_cores();
    let hold = JobHandle::submit(&rm, JobSpec::new("e19-hold").containers(cores, cores))?;
    let rm2 = rm.clone();
    let waiter = std::thread::spawn(move || -> Result<()> {
        let j = JobHandle::submit(&rm2, JobSpec::new("e19-late").containers(1, 1))?;
        j.finish();
        Ok(())
    });
    // Hold admission shut for ~150 ms — past the rule's 100 ms bound.
    std::thread::sleep(Duration::from_millis(150));
    hold.finish();
    waiter.join().expect("e19 grant waiter panicked")?;
    let out = e19_detect(&obs, "grant-wait-p99", timeout, || Ok(()))?;
    obs.stop();
    Ok(out)
}

/// Tiny MEM cap hammered with puts: every insert evicts, pushing the
/// memory-tier eviction rate past 1000/s.
fn e19_fault_evict(timeout: Duration) -> Result<(f64, f64)> {
    let store = e17_store(false);
    let obs = e19_obs(store.metrics().clone());
    let val = vec![7u8; 4096];
    let mut i = 0u64;
    let out = e19_detect(&obs, "evict-thrash", timeout, || {
        for _ in 0..256 {
            store.put_opts(&format!("k{}", i % 1024), val.clone(), false, false)?;
            i += 1;
        }
        Ok(())
    })?;
    obs.stop();
    Ok(out)
}

/// Mass shard replay: a checkpoint registry replayed in a tight loop
/// drives the lookup-hit rate past 500/s.
fn e19_fault_ckpt(timeout: Duration) -> Result<(f64, f64)> {
    let store = TieredStore::test_store(&PlatformConfig::test().storage);
    let obs = e19_obs(store.metrics().clone());
    let ck = super::checkpoint::ShardCheckpoint::new(&store, "e19-replay");
    for i in 0..8 {
        ck.commit(&format!("item{i}"), vec![1, 2, 3])?;
    }
    let out = e19_detect(&obs, "ckpt-replay-storm", timeout, || {
        for i in 0..8 {
            for _ in 0..8 {
                let _ = ck.lookup(&format!("item{i}"));
            }
        }
        Ok(())
    })?;
    obs.stop();
    Ok(out)
}

/// Executor starvation: floods of tiny tasks keep idle workers
/// stealing from loaded ones; a probe surfaces the pool's steal count
/// into the sampler as `dce.executor.steals.rate`.
fn e19_fault_steals(timeout: Duration) -> Result<(f64, f64)> {
    let ctx = DceContext::local()?;
    let obs = e19_obs(ctx.metrics().clone());
    let probe_ctx = ctx.clone();
    obs.add_probe("dce.executor.steals", crate::obs::ProbeKind::Counter, move || {
        probe_ctx.executor_steals() as f64
    });
    let out = e19_detect(&obs, "steal-starvation", timeout, || {
        ctx.range(1_000, 128).count()?;
        Ok(())
    })?;
    obs.stop();
    Ok(out)
}

/// Sampler-overhead gate: the E17 store microbench (8 threads, fast
/// path) plain vs. with a live telemetry plane over the store's
/// registry, best-of-3 each way. The budget is <3%.
fn e19_overhead(ops: u64) -> Result<(f64, f64, f64)> {
    let mut best_plain = 0.0f64;
    let mut best_sampled = 0.0f64;
    for _ in 0..3 {
        best_plain = best_plain.max(e17_store_run(8, ops, false)?);
    }
    for _ in 0..3 {
        let store = e17_store(false);
        let obs = crate::obs::Observability::start(
            store.metrics().clone(),
            crate::obs::ObsConfig {
                sampler: crate::obs::SamplerConfig {
                    period: Duration::from_millis(5),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let r = e17_store_run_on(&store, 8, ops)?;
        obs.stop();
        best_sampled = best_sampled.max(r);
    }
    let overhead_pct = (1.0 - best_sampled / best_plain.max(1e-9)) * 100.0;
    Ok((best_plain, best_sampled, overhead_pct))
}

/// Observability end-to-end: inject one fault per built-in SLO rule,
/// measure how long the sampler→watchdog pipeline takes to flag each,
/// and gate the sampler's overhead on the E17 store microbench. Emits
/// machine-readable `BENCH_E19.json`.
fn e19_observability(quick: bool) -> Result<Table> {
    use crate::util::json::Json;

    let timeout = if quick { Duration::from_secs(5) } else { Duration::from_secs(10) };
    let ops = if quick { 800u64 } else { 3_000 };

    // Gate first: a telemetry plane that taxes the hot path is not
    // worth its dashboards.
    let (plain_ops, sampled_ops, overhead_pct) = e19_overhead(ops)?;
    anyhow::ensure!(
        overhead_pct < 3.0,
        "sampler overhead {overhead_pct:.2}% exceeds the 3% budget \
         ({plain_ops:.0}/s plain vs {sampled_ops:.0}/s sampled)"
    );

    let faults: Vec<(&str, &str, (f64, f64))> = vec![
        ("ingest-backlog", "paused compactor", e19_fault_backlog(timeout)?),
        ("ingest-dlq", "corrupt uploads", e19_fault_dlq(timeout)?),
        ("grant-wait-p99", "over-admitted queue", e19_fault_grant_wait(timeout)?),
        ("evict-thrash", "tiny MEM cap", e19_fault_evict(timeout)?),
        ("ckpt-replay-storm", "mass shard replay", e19_fault_ckpt(timeout)?),
        ("steal-starvation", "tiny-task floods", e19_fault_steals(timeout)?),
    ];

    let rules = crate::obs::builtin_rules(Duration::ZERO);
    let mut rows = Vec::new();
    let mut json_rules = Vec::new();
    for (name, fault, (detection_ms, peak)) in &faults {
        let rule = rules.iter().find(|r| r.name == *name).expect("builtin rule");
        anyhow::ensure!(
            *peak >= rule.critical,
            "rule '{name}' tripped at {peak:.1}, below its critical bound {:.1}",
            rule.critical
        );
        rows.push(vec![
            name.to_string(),
            fault.to_string(),
            format!("{detection_ms:.0} ms"),
            format!("{peak:.0}"),
            format!("{:.0}/{:.0}", rule.warn, rule.critical),
        ]);
        json_rules.push(Json::obj(vec![
            ("rule", Json::str(*name)),
            ("fault", Json::str(*fault)),
            ("detection_ms", Json::num(*detection_ms)),
            ("peak", Json::num(*peak)),
            ("warn", Json::num(rule.warn)),
            ("critical", Json::num(rule.critical)),
        ]));
    }

    let json = Json::obj(vec![
        ("experiment", Json::str("e19")),
        ("quick", Json::Bool(quick)),
        ("sampler_overhead_pct", Json::num(overhead_pct)),
        ("store_ops_per_sec_plain", Json::num(plain_ops)),
        ("store_ops_per_sec_sampled", Json::num(sampled_ops)),
        ("rules", Json::arr(json_rules)),
    ]);
    let json_path = "BENCH_E19.json";
    std::fs::write(json_path, json.to_string_pretty())?;

    Ok(Table {
        id: "e19",
        title: "observability: per-rule fault-injection detection latency and sampler \
                overhead on the E17 store microbench"
            .into(),
        mode: "real",
        header: vec!["rule", "injected fault", "detection", "peak", "warn/crit"],
        rows,
        notes: format!(
            "each row injects the fault its SLO rule watches (2 ms sampling, no sustain \
             debounce) and reports time-to-critical. Sampler overhead {overhead_pct:.1}% \
             on the store microbench (budget 3%, {plain_ops:.0}/s plain vs \
             {sampled_ops:.0}/s sampled). Rows written to {json_path}."
        ),
    })
}

// ===========================================================================
// E20: million-vehicle gateway — fleet-size sweep on the batched path
// ===========================================================================

/// One event-driven fleet run against an 8-partition log with a lean
/// concurrent committer advancing the consumer frontier (so lag is real
/// tail lag, not an ever-growing backlog). Returns the fleet report and
/// the elapsed wall time.
fn e20_run(vehicles: u32, ticks: usize) -> Result<(ingest::FleetReport, Duration)> {
    use crate::ingest::{FleetConfig, GatewayConfig, LogConfig, PartitionedLog};
    use std::sync::atomic::{AtomicBool, Ordering};

    let log = PartitionedLog::temp(
        "e20",
        LogConfig {
            partitions: 8,
            segment_bytes: 4 << 20,
            retention_bytes: 1 << 30,
            ..Default::default()
        },
    )?;
    let gw = ingest::IngestGateway::new(
        log.clone(),
        GatewayConfig { rate_per_tick: 4, max_lag: 200_000 },
        MetricsRegistry::new(),
    );
    let mut cfg = FleetConfig::new(vehicles, ticks, 0xE20);
    cfg.bag_every = 0;
    cfg.cadence_max = 4;
    cfg.corrupt_rate = 0.0005;
    let stop = AtomicBool::new(false);
    let mut out: Option<(ingest::FleetReport, Duration)> = None;
    std::thread::scope(|s| -> Result<()> {
        let committer = {
            let (log, stop) = (log.clone(), &stop);
            s.spawn(move || {
                // Commit-only consumer: walk the head forward through
                // the zero-copy read so retention never overruns an
                // unread record and the lag column measures a tail.
                while !stop.load(Ordering::Relaxed) {
                    let mut idle = true;
                    for p in 0..log.partitions() {
                        let from = log.committed(p).max(log.start_offset(p));
                        let next = log.read_range_with(p, from, 2048, |frames| {
                            Ok(frames.last().map(|f| f.offset + 1))
                        });
                        if let Ok(Some(next)) = next {
                            idle = false;
                            let _ = log.commit(p, next);
                        }
                    }
                    if idle {
                        std::thread::yield_now();
                    }
                }
            })
        };
        let t = Instant::now();
        let report = ingest::simulate_fleet(&gw, &cfg)?;
        let elapsed = t.elapsed();
        stop.store(true, Ordering::Relaxed);
        let _ = committer.join();
        out = Some((report, elapsed));
        Ok(())
    })?;
    Ok(out.expect("e20 scope sets its result"))
}

/// E20 at a caller-chosen fleet ceiling (`adcloud repro-tables e20
/// --vehicles N`): sweeps three fleet sizes up to `max_vehicles` so the
/// quick CI run and the full million-vehicle run share one code path.
pub fn e20_fleet_sized(max_vehicles: u32, quick: bool) -> Result<Table> {
    let ticks = if quick { 6 } else { 10 };
    let mut rows = Vec::new();
    for vehicles in [(max_vehicles / 25).max(100), (max_vehicles / 5).max(100), max_vehicles] {
        let (report, elapsed) = e20_run(vehicles, ticks)?;
        let secs = elapsed.as_secs_f64().max(1e-9);
        rows.push(vec![
            format!("{vehicles}"),
            format!("{:.0}/s", report.uploads as f64 / secs),
            format!("{:.0}/s", report.accepted as f64 / secs),
            format!("{}", report.tail_lag_p99),
            format!("{}", report.lost_records),
            format!("{}", report.dead_lettered),
            format!("{}", report.stranded),
        ]);
    }
    Ok(Table {
        id: "e20",
        title: format!(
            "million-vehicle gateway: event-driven fleet sweep to {max_vehicles} vehicles \
             ({ticks} ticks, cadence 1..=4, 8 partitions, concurrent committer)"
        ),
        mode: "real",
        header: vec![
            "vehicles",
            "uploads",
            "accepted",
            "lag p99",
            "lost",
            "dead-lettered",
            "stranded",
        ],
        rows,
        notes: "the timer wheel only touches vehicles due each tick and admission is one \
                batched decision pass per tick, so upload throughput should hold as the \
                fleet grows; lag p99 is the worst partition's uncommitted tail sampled \
                at every tick end."
            .into(),
    })
}

fn e20_fleet(quick: bool) -> Result<Table> {
    e20_fleet_sized(if quick { 50_000 } else { 1_000_000 }, quick)
}

// ===========================================================================
// E21 (§3): latency-SLO serving — offered-load sweep to saturation
// ===========================================================================

/// Sweep offered load across the latency cliff at 1/2/4/8 nodes,
/// EDF+speculation vs the FIFO/no-speculation `--baseline` arm under
/// identical arrivals (deterministic virtual-time runs), then two real
/// serving-plane runs for wall-clock goodput and the container-leak
/// check. Writes BENCH_E21.json for the bench-diff gate.
pub fn e21_serve_sized(requests: usize, quick: bool) -> Result<Table> {
    use crate::serve::{self, ServeConfig, ServePlane};
    use crate::util::json::Json;

    let loads = [0.5, 0.9, 1.5, 2.5];
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for nodes in SWEEP_NODES {
        for load in loads {
            let cfg = ServeConfig { nodes, requests, ..ServeConfig::default() }.at_load(load);
            let edf = serve::simulate(&cfg);
            let fifo = serve::simulate(&cfg.clone().baseline());
            if load <= 0.5 {
                // Below the knee the SLO must hold outright: p99 within
                // the deadline and (near-)nothing degraded or missed.
                anyhow::ensure!(
                    edf.p99_us <= cfg.deadline_us
                        && edf.miss_pct() < 0.5
                        && edf.fallback_pct() < 0.5,
                    "below-knee SLO violated at {nodes} nodes load {load}: {}",
                    edf.render()
                );
            } else {
                // At and past the knee speculation must hold the miss
                // rate under 1% — overflow shows up as rejections and
                // degraded completions instead.
                anyhow::ensure!(
                    edf.miss_pct() < 1.0,
                    "miss rate escaped speculation at {nodes} nodes load {load}: {}",
                    edf.render()
                );
            }
            if load >= 1.0 {
                anyhow::ensure!(
                    edf.rejected > 0 && edf.missed <= fifo.missed,
                    "past the knee admission must shed load and EDF must not out-miss \
                     the baseline at {nodes} nodes load {load}: {} vs {}",
                    edf.render(),
                    fifo.render()
                );
            }
            for (arm, r) in [("edf", &edf), ("fifo-base", &fifo)] {
                rows.push(vec![
                    format!("{nodes}"),
                    format!("{load:.1}x"),
                    arm.into(),
                    format!("{:.0}/s", cfg.offered_rps),
                    format!("{:.0}/s", r.goodput_per_sec()),
                    format!("{}", r.p50_us),
                    format!("{}", r.p99_us),
                    format!("{}", r.p999_us),
                    format!("{:.2}%", r.miss_pct()),
                    format!("{:.2}%", r.fallback_pct()),
                ]);
                json_rows.push(Json::obj(vec![
                    ("nodes", Json::num(nodes as f64)),
                    ("load", Json::num(load)),
                    ("arm", Json::str(arm)),
                    ("offered_rps", Json::num(cfg.offered_rps)),
                    ("sim_goodput_rps", Json::num(r.goodput_per_sec())),
                    ("p50_us", Json::num(r.p50_us as f64)),
                    ("p99_us", Json::num(r.p99_us as f64)),
                    ("p999_us", Json::num(r.p999_us as f64)),
                    ("miss_pct", Json::num(r.miss_pct())),
                    ("fallback_pct", Json::num(r.fallback_pct())),
                ]));
            }
        }
    }

    // The real plane (job-layer containers on the `interactive` queue,
    // wall-clock pacing), kept to 1–2 nodes so the spin-wait workers
    // don't oversubscribe CI hosts. `ServePlane::run` fails on any
    // leaked container.
    let mut real_goodput = Vec::new();
    for nodes in [1usize, 2] {
        let cfg = ServeConfig {
            nodes,
            workers_per_node: 2,
            requests: if quick { 150 } else { 600 },
            mean_service_us: 400,
            deadline_us: 2400,
            local_service_us: 80,
            ..ServeConfig::default()
        }
        .at_load(0.8);
        let r = ServePlane::run(&cfg)?;
        anyhow::ensure!(
            r.admitted + r.rejected == r.offered
                && r.completed + r.missed + r.fallbacks == r.admitted,
            "real-plane accounting must balance at {nodes} nodes: {}",
            r.render()
        );
        real_goodput.push(r.goodput_per_sec());
        rows.push(vec![
            format!("{nodes}"),
            "0.8x".into(),
            "real-edf".into(),
            format!("{:.0}/s", cfg.offered_rps),
            format!("{:.0}/s", r.goodput_per_sec()),
            format!("{}", r.p50_us),
            format!("{}", r.p99_us),
            format!("{}", r.p999_us),
            format!("{:.2}%", r.miss_pct()),
            format!("{:.2}%", r.fallback_pct()),
        ]);
    }

    let json = Json::obj(vec![
        ("experiment", Json::str("e21")),
        ("quick", Json::Bool(quick)),
        ("serve_goodput_1node_per_sec", Json::num(real_goodput[0])),
        ("serve_goodput_2node_per_sec", Json::num(real_goodput[1])),
        ("rows", Json::arr(json_rows)),
    ]);
    std::fs::write("BENCH_E21.json", json.to_string_pretty())?;

    Ok(Table {
        id: "e21",
        title: format!(
            "latency-SLO serving: offered-load sweep across the cliff ({requests} requests \
             per arm, deadline 12ms, EDF+speculation vs FIFO baseline, real plane at 1-2 \
             nodes)"
        ),
        mode: "virtual-time",
        header: vec![
            "nodes",
            "load",
            "arm",
            "offered",
            "goodput",
            "p50 us",
            "p99 us",
            "p999 us",
            "miss",
            "fallback",
        ],
        rows,
        notes: "below the knee (load < 1.0) the edf arm holds p99 inside the deadline with \
                nothing degraded; past the knee admission sheds overflow on arrival and \
                speculation converts would-be misses into degraded local completions, so \
                goodput flattens instead of collapsing while the fifo baseline's miss rate \
                climbs. The real-edf rows are wall-clock runs through the unified job layer \
                on the interactive priority queue (leak-checked)."
            .into(),
    })
}

fn e21_serve(quick: bool) -> Result<Table> {
    e21_serve_sized(if quick { 4000 } else { 20_000 }, quick)
}

// ===========================================================================
// E22: shuffle plane — sharded, affinity-aware manager vs single lock
// ===========================================================================

/// One shuffle-manager microbench: `threads` workers each drive their
/// own shuffle ids through `rounds` rounds of an 8-map x 8-reduce
/// bucket matrix — every map writes every reduce partition, then every
/// reduce partition is taken in one batch, then the shuffle is GC'd
/// (the manager's entire hot path: insert, transport accounting,
/// batched take, clear). Returns aggregate bucket ops (puts + takes)
/// per second.
fn e22_shuffle_run(threads: usize, rounds: u64, baseline: bool) -> Result<f64> {
    use crate::dce::ShuffleManager;
    const MAPS: usize = 8;
    const REDUCES: usize = 8;
    let mgr = ShuffleManager::with_config(
        MetricsRegistry::new(),
        crate::config::DEFAULT_SHUFFLE_SHARDS,
        baseline,
        0,
    );
    mgr.set_transport(Some(Arc::new(crate::storage::DeviceModel::new(
        PlatformConfig::test().storage.mem.clone(),
        false,
    ))));
    let start = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let mut workers = Vec::new();
        for t in 0..threads {
            let mgr = mgr.clone();
            workers.push(s.spawn(move || -> Result<()> {
                let data: Vec<(u64, u64)> = (0..16u64).map(|i| (i, i * 3)).collect();
                for round in 0..rounds {
                    let shuffle = t * 1_000_000 + round as usize;
                    for m in 0..MAPS {
                        for r in 0..REDUCES {
                            mgr.put_bucket(shuffle, m, r, data.clone(), 256);
                        }
                    }
                    for r in 0..REDUCES {
                        let got = mgr.take_buckets::<(u64, u64)>(shuffle, MAPS, r)?;
                        anyhow::ensure!(got.len() == MAPS, "short bucket read");
                    }
                    mgr.clear_shuffle(shuffle);
                }
                Ok(())
            }));
        }
        for w in workers {
            w.join().expect("e22 shuffle worker panicked")?;
        }
        Ok(())
    })?;
    let ops = threads as u64 * rounds * (MAPS as u64 * REDUCES as u64 + REDUCES as u64);
    Ok(ops as f64 / start.elapsed().as_secs_f64().max(1e-9))
}

/// One end-to-end configuration: the two shuffle-heavy service slices
/// (training label histogram via `reduce_by_key`, mapgen tile binning
/// via `group_by_key`) through a full `DceContext`, with the shuffle
/// arm picked by `baseline`. Returns the makespan, both outputs (the
/// cross-arm bit-identical check), and the run's affinity-hint hits.
#[allow(clippy::type_complexity)]
fn e22_e2e_run(
    threads: usize,
    baseline: bool,
    examples: usize,
    density: usize,
) -> Result<(Duration, Vec<(i32, u64)>, Vec<((i32, i32), u64)>, u64)> {
    let mut cfg = PlatformConfig::test();
    cfg.cluster.nodes = threads;
    cfg.engine.shuffle_single_lock = baseline;
    cfg.engine.default_parallelism = threads.max(2) * 2;
    let ctx = DceContext::new(cfg)?;
    let parts = ctx.default_parallelism();
    let dataset = training::gen_dataset(examples, 22);
    let world = mapgen::gen_world_with_density(22, density);
    let start = Instant::now();
    let hist = training::label_histogram(&ctx, &dataset, parts)?;
    let tiles = mapgen::tile_histogram(&ctx, &world.landmarks, 10.0, parts)?;
    let makespan = start.elapsed();
    let hits = ctx.metrics().counter("dce.shuffle.affinity_hits").get();
    Ok((makespan, hist, tiles, hits))
}

/// Shuffle-plane A/B: lock-striped bucket map + manager-side combine +
/// batched takes + executor affinity vs the old single-lock
/// per-op-metrics path, at 1/2/4/8 threads, over the manager
/// microbench and two shuffle-heavy service slices. Both arms must
/// produce bit-identical outputs. Also emits BENCH_E22.json for the
/// bench-diff gate.
fn e22_shuffle(quick: bool) -> Result<Table> {
    use crate::util::json::Json;
    let rounds = if quick { 60u64 } else { 400 };
    let examples = if quick { 200 } else { 2_000 };
    let density = if quick { 1 } else { 4 };
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut speedup_at_8 = 0.0;
    for threads in SWEEP_NODES {
        let base_ops = e22_shuffle_run(threads, rounds, true)?;
        let fast_ops = e22_shuffle_run(threads, rounds, false)?;
        let bucket_speedup = fast_ops / base_ops.max(1e-9);
        let (base_e2e, base_hist, base_tiles, _) =
            e22_e2e_run(threads, true, examples, density)?;
        let (fast_e2e, fast_hist, fast_tiles, hits) =
            e22_e2e_run(threads, false, examples, density)?;
        anyhow::ensure!(
            base_hist == fast_hist,
            "e22 at {threads} threads: training outputs diverged across shuffle arms"
        );
        anyhow::ensure!(
            base_tiles == fast_tiles,
            "e22 at {threads} threads: mapgen outputs diverged across shuffle arms"
        );
        let e2e_speedup = base_e2e.as_secs_f64() / fast_e2e.as_secs_f64().max(1e-9);
        if threads == 8 {
            speedup_at_8 = bucket_speedup;
        }
        rows.push(vec![
            format!("{threads}"),
            format!("{:.0}/s", base_ops),
            format!("{:.0}/s", fast_ops),
            format!("{bucket_speedup:.1}x"),
            fmt_duration(base_e2e),
            fmt_duration(fast_e2e),
            format!("{e2e_speedup:.2}x"),
            format!("{hits}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("threads", Json::num(threads as f64)),
            ("bucket_baseline_ops_per_sec", Json::num(base_ops)),
            ("bucket_sharded_ops_per_sec", Json::num(fast_ops)),
            ("bucket_speedup", Json::num(bucket_speedup)),
            ("e2e_baseline_sec", Json::num(base_e2e.as_secs_f64())),
            ("e2e_sharded_sec", Json::num(fast_e2e.as_secs_f64())),
            ("e2e_speedup", Json::num(e2e_speedup)),
            ("affinity_hits", Json::num(hits as f64)),
        ]));
    }
    anyhow::ensure!(
        speedup_at_8 >= 2.0,
        "sharded shuffle manager must sustain >= 2x the single-lock baseline's bucket \
         throughput at 8 threads, got {speedup_at_8:.2}x"
    );
    let json = Json::obj(vec![
        ("experiment", Json::str("e22")),
        ("quick", Json::Bool(quick)),
        ("shuffle_speedup_at_8_threads", Json::num(speedup_at_8)),
        ("rows", Json::arr(json_rows)),
    ]);
    let json_path = "BENCH_E22.json";
    std::fs::write(json_path, json.to_string_pretty())?;
    Ok(Table {
        id: "e22",
        title: format!(
            "shuffle plane: sharded affinity-aware manager vs single-lock baseline \
             ({rounds} rounds/thread over an 8x8 bucket matrix; e2e = training label \
             histogram + mapgen tile binning, {examples} examples / density {density})"
        ),
        mode: "real",
        header: vec![
            "threads",
            "bucket base",
            "bucket sharded",
            "speedup",
            "e2e base",
            "e2e sharded",
            "speedup",
            "affinity hits",
        ],
        rows,
        notes: format!(
            "baseline = pre-shuffle-plane manager (one global bucket lock, per-op metric \
             lookups, per-bucket transport clones, no manager-side combine, no placement \
             hints), forced by EngineConfig.shuffle_single_lock / `adcloud --baseline`. \
             Both arms must produce bit-identical service outputs. Rows written to \
             {json_path}."
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        let ok = crate::artifacts_dir().join("manifest.json").is_file();
        if !ok {
            eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
        }
        ok
    }

    #[test]
    fn table_render_aligns() {
        let t = Table {
            id: "t",
            title: "x".into(),
            mode: "real",
            header: vec!["a", "b"],
            rows: vec![vec!["1".into(), "2".into()]],
            notes: "n".into(),
        };
        let r = t.render();
        assert!(r.contains("a"));
        assert!(r.contains("note: n"));
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("e99", true).is_err());
    }

    #[test]
    fn quick_experiments_run() {
        if !have_artifacts() {
            return;
        }
        // The pure-infrastructure experiments, quick mode.
        for id in ["e2", "e4", "e12"] {
            let t = run_experiment(id, true).unwrap();
            assert!(!t.rows.is_empty(), "{id} produced no rows");
        }
    }

    #[test]
    fn e12_soak_all_jobs_survive() {
        let t = run_experiment("e12", true).unwrap();
        assert_eq!(t.rows[0][1], "20/20", "{:?}", t.rows);
        assert_eq!(t.rows[1][1], "20/20", "{:?}", t.rows);
    }

    #[test]
    fn e13_campaign_scales_without_artifacts() {
        // The campaign experiment runs on the CPU detection path — no
        // artifacts gate.
        let t = run_experiment("e13", true).unwrap();
        assert_eq!(t.rows.len(), 5, "{:?}", t.rows);
        // 8 nodes must beat 1 node.
        let speedup: f64 =
            t.rows.last().unwrap()[3].trim_end_matches('x').parse().unwrap();
        assert!(speedup > 2.0, "campaign speedup {speedup} too sub-linear");
    }

    #[test]
    fn e15_multitenant_queues_both_make_progress() {
        // Both tenants run on the CPU detection / pure-infrastructure
        // paths — no artifacts gate.
        let t = run_experiment("e15", true).unwrap();
        assert_eq!(t.rows.len(), 4, "{:?}", t.rows);
        for row in &t.rows {
            let scen: f64 = row[2].trim_end_matches("/s").parse().unwrap();
            let rec: f64 = row[3].trim_end_matches("/s").parse().unwrap();
            assert!(scen > 0.0, "sim queue starved: {row:?}");
            assert!(rec > 0.0, "fleet queue starved: {row:?}");
        }
    }

    #[test]
    fn e16_preemption_reclaims_before_the_over_share_job_ends() {
        // Pure-infrastructure paths — no artifacts gate. One mid-size
        // configuration, asserted directly on e16_run's numbers.
        let mut off = Duration::ZERO;
        let mut off_campaign = Duration::ZERO;
        let mut on = Duration::ZERO;
        for preempt in [false, true] {
            let (reclaim, rescored, campaign, _mk) = e16_run(2, preempt, 4, 16, 200).unwrap();
            if preempt {
                on = reclaim;
                assert_eq!(rescored, 0, "checkpoint/resume must rerun zero scenarios");
            } else {
                off = reclaim;
                off_campaign = campaign;
            }
        }
        assert!(
            on < off,
            "with preemption the below-share grant ({on:?}) must land before the \
             over-share campaign finishes ({off:?}, campaign {off_campaign:?})"
        );
    }

    #[test]
    fn e16_table_has_on_off_rows_per_node_count() {
        let t = run_experiment("e16", true).unwrap();
        assert_eq!(t.rows.len(), 8, "{:?}", t.rows);
        for pair in t.rows.chunks(2) {
            assert_eq!(pair[0][1], "off");
            assert_eq!(pair[1][1], "on");
            assert_eq!(pair[1][3], "0", "preempt+checkpoint rows must rescore nothing");
        }
    }

    #[test]
    fn e17_sharded_store_beats_the_single_lock_baseline() {
        // Pure infrastructure — no artifacts gate. The acceptance bar
        // for the fast path: >= 2x store throughput over the forced
        // single-lock O(n)-scan baseline at 8 threads. The asymmetry
        // is algorithmic (full-map scan vs index min), so it holds on
        // single-core CI hosts too.
        let base = e17_store_run(8, 400, true).unwrap();
        let fast = e17_store_run(8, 400, false).unwrap();
        assert!(
            fast >= 2.0 * base,
            "sharded store must be >= 2x the baseline at 8 threads: {fast:.0}/s vs {base:.0}/s"
        );
    }

    #[test]
    fn e17_writes_the_bench_json() {
        let t = run_experiment("e17", true).unwrap();
        assert_eq!(t.rows.len(), 4, "{:?}", t.rows);
        let text = std::fs::read_to_string("BENCH_E17.json").unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.req("experiment").unwrap().as_str().unwrap(), "e17");
        assert_eq!(j.req("rows").unwrap().as_arr().unwrap().len(), 4);
        let s = j.req("store_speedup_at_8_threads").unwrap().as_f64().unwrap();
        assert!(s >= 2.0, "store speedup at 8 threads {s:.2} below the 2x bar");
    }

    #[test]
    fn e18_attribution_sums_and_tracks_the_measured_makespan() {
        let _g = trace::testing::serial();
        let rm = ResourceManager::new(&PlatformConfig::test().cluster, MetricsRegistry::new());
        let ctx = DceContext::local().unwrap();
        trace::tracer().enable();
        trace::tracer().clear();
        let t = Instant::now();
        let job =
            JobHandle::submit(&rm, JobSpec::new("e18-attr").containers(1, 2)).unwrap();
        let out = job
            .run_sharded(&ctx, (0..4u64).collect(), |sctx, items: Vec<u64>| {
                sctx.run(|_| {
                    std::thread::sleep(Duration::from_millis(200));
                    items
                })
            })
            .unwrap();
        let stats = job.finish();
        let elapsed = t.elapsed();
        trace::tracer().disable();
        assert_eq!(out.len(), 4);
        let cp = stats.critical_path.expect("tracer on => stats carry a critical path");
        assert_eq!(cp.sum_us(), cp.total_us, "attribution must partition the makespan");
        assert!(cp.category_us(trace::Category::Compute) > 0, "sleeping shards are compute");
        let measured = elapsed.as_micros() as f64;
        let diff = (measured - cp.total_us as f64).abs() / measured;
        assert!(
            diff < 0.01,
            "critical-path total {}us vs measured {measured:.0}us ({:.2}% off)",
            cp.total_us,
            diff * 100.0
        );
    }

    #[test]
    fn e18_writes_the_bench_json_and_stays_under_the_overhead_budget() {
        let _g = trace::testing::serial();
        let t = run_experiment("e18", true).unwrap();
        // Four sweep rows plus the preemption-heavy configuration.
        assert_eq!(t.rows.len(), 5, "{:?}", t.rows);
        assert_eq!(t.rows[4][1], "pair+preempt");
        let text = std::fs::read_to_string("BENCH_E18.json").unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.req("experiment").unwrap().as_str().unwrap(), "e18");
        assert_eq!(j.req("rows").unwrap().as_arr().unwrap().len(), 5);
        let o = j.req("tracing_overhead_pct").unwrap().as_f64().unwrap();
        assert!(o < 5.0, "tracing overhead {o:.2}% over the 5% budget");
    }

    #[test]
    fn e19_watchdogs_detect_every_injected_fault() {
        let t = run_experiment("e19", true).unwrap();
        assert_eq!(t.rows.len(), 6, "{:?}", t.rows);
        let text = std::fs::read_to_string("BENCH_E19.json").unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.req("experiment").unwrap().as_str().unwrap(), "e19");
        let rules = j.req("rules").unwrap().as_arr().unwrap();
        assert_eq!(rules.len(), 6, "every built-in rule must be exercised");
        for r in rules {
            let name = r.req("rule").unwrap().as_str().unwrap();
            let ms = r.req("detection_ms").unwrap().as_f64().unwrap();
            assert!(ms.is_finite() && ms >= 0.0, "rule '{name}' detection {ms}");
            let peak = r.req("peak").unwrap().as_f64().unwrap();
            let crit = r.req("critical").unwrap().as_f64().unwrap();
            assert!(peak >= crit, "rule '{name}' peak {peak} below critical {crit}");
        }
        let o = j.req("sampler_overhead_pct").unwrap().as_f64().unwrap();
        assert!(o < 3.0, "sampler overhead {o:.2}% over the 3% budget");
    }

    #[test]
    fn e14_ingest_runs_without_artifacts() {
        // The ingest experiment is pure infrastructure — no artifacts gate.
        let t = run_experiment("e14", true).unwrap();
        assert_eq!(t.rows.len(), 4, "{:?}", t.rows);
        for row in &t.rows {
            let rps: f64 = row[1].trim_end_matches("/s").parse().unwrap();
            let rps_b: f64 = row[2].trim_end_matches("/s").parse().unwrap();
            let rps_c: f64 = row[4].trim_end_matches("/s").parse().unwrap();
            assert!(rps > 0.0 && rps_b > 0.0, "throughput must be positive: {row:?}");
            assert!(rps_c > 0.0, "contended run must still make progress: {row:?}");
            let lost: u64 = row[6].parse().unwrap();
            assert_eq!(lost, 0, "a 1 GiB retention budget must not lose records: {row:?}");
        }
        let text = std::fs::read_to_string("BENCH_E14.json").unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.req("experiment").unwrap().as_str().unwrap(), "e14");
        assert_eq!(j.req("rows").unwrap().as_arr().unwrap().len(), 4);
        assert!(j.req("batched_speedup_at_8_partitions").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn e14_group_commit_beats_per_frame_appends_5x_at_8_partitions() {
        // The acceptance bar for the group-commit log: >= 5x sustained
        // append rate over the per-frame path at 8 partitions. The
        // asymmetry is one write syscall + CRC-staging pass per
        // 256-record batch vs one per record, so it holds on
        // single-core CI hosts too.
        let payload = vec![7u8; 256];
        let (per_frame, _, _) = e14_run(8, 6_000, &payload, false, false).unwrap();
        let (batched, _, _) = e14_run(8, 6_000, &payload, false, true).unwrap();
        let speedup = per_frame.as_secs_f64() / batched.as_secs_f64().max(1e-9);
        assert!(
            speedup >= 5.0,
            "group-commit must sustain >= 5x the per-frame append rate at 8 partitions, \
             got {speedup:.1}x"
        );
    }

    #[test]
    fn e20_sweeps_three_fleet_sizes() {
        let t = e20_fleet_sized(5_000, true).unwrap();
        assert_eq!(t.rows.len(), 3, "{:?}", t.rows);
        assert_eq!(t.rows[2][0], "5000");
        for row in &t.rows {
            let ups: f64 = row[1].trim_end_matches("/s").parse().unwrap();
            assert!(ups > 0.0, "fleet must upload: {row:?}");
            let lost: u64 = row[4].parse().unwrap();
            assert_eq!(lost, 0, "committed tail must never be truncated: {row:?}");
        }
    }

    #[test]
    fn e21_latency_cliff_holds_and_bench_json_round_trips() {
        // Small but past-the-cliff sweep; the in-function gates already
        // assert the below-knee SLO, the past-knee <1% miss rate, and
        // the leak-free real runs — failure surfaces as Err here.
        let t = e21_serve_sized(2_000, true).unwrap();
        // 4 node counts x 4 loads x 2 arms, plus 2 real-plane rows.
        assert_eq!(t.rows.len(), SWEEP_NODES.len() * 4 * 2 + 2, "{:?}", t.rows);
        // The cliff: at 8 nodes the edf arm's p99 stays inside the
        // 12 ms deadline at load 0.5 and blows past it by load 2.5,
        // while goodput holds instead of collapsing.
        let row = |load: &str, arm: &str| {
            t.rows
                .iter()
                .find(|r| r[0] == "8" && r[1] == load && r[2] == arm)
                .unwrap_or_else(|| panic!("missing row {load}/{arm}"))
                .clone()
        };
        let below: f64 = row("0.5x", "edf")[6].parse().unwrap();
        let past: f64 = row("2.5x", "edf")[6].parse().unwrap();
        assert!(below <= 12_000.0, "below-knee p99 {below} escaped the deadline");
        assert!(past > below, "the sweep must cross a latency cliff");
        let good_low: f64 = row("0.5x", "edf")[4].trim_end_matches("/s").parse().unwrap();
        let good_hi: f64 = row("2.5x", "edf")[4].trim_end_matches("/s").parse().unwrap();
        assert!(good_hi > good_low * 0.8, "goodput must hold past the knee, not collapse");
        let text = std::fs::read_to_string("BENCH_E21.json").unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.req("experiment").unwrap().as_str().unwrap(), "e21");
        assert_eq!(j.req("rows").unwrap().as_arr().unwrap().len(), SWEEP_NODES.len() * 4 * 2);
        assert!(j.req("serve_goodput_1node_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.req("serve_goodput_2node_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn e22_sharded_shuffle_beats_the_single_lock_baseline() {
        // Pure infrastructure — no artifacts gate. The acceptance bar
        // for the shuffle plane: >= 2x bucket throughput over the
        // forced single-lock baseline at 8 threads. The asymmetry is
        // per-op work (registry lookups + transport clones + lock
        // reacquisition per bucket vs pre-resolved handles + one
        // striped acquisition per row), so it holds on single-core CI
        // hosts too.
        let base = e22_shuffle_run(8, 40, true).unwrap();
        let fast = e22_shuffle_run(8, 40, false).unwrap();
        assert!(
            fast >= 2.0 * base,
            "sharded manager must be >= 2x the baseline at 8 threads: {fast:.0}/s vs {base:.0}/s"
        );
    }

    #[test]
    fn e22_writes_the_bench_json_and_arms_agree() {
        // The in-function ensure!s already assert the >= 2x bar and the
        // bit-identical cross-arm outputs — failure surfaces as Err.
        let t = run_experiment("e22", true).unwrap();
        assert_eq!(t.rows.len(), SWEEP_NODES.len(), "{:?}", t.rows);
        let text = std::fs::read_to_string("BENCH_E22.json").unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.req("experiment").unwrap().as_str().unwrap(), "e22");
        assert_eq!(j.req("rows").unwrap().as_arr().unwrap().len(), SWEEP_NODES.len());
        let s = j.req("shuffle_speedup_at_8_threads").unwrap().as_f64().unwrap();
        assert!(s >= 2.0, "shuffle speedup at 8 threads {s:.2} below the 2x bar");
        for row in j.req("rows").unwrap().as_arr().unwrap() {
            let b = row.req("bucket_sharded_ops_per_sec").unwrap().as_f64().unwrap();
            assert!(b > 0.0, "sharded throughput must be positive");
        }
    }

    #[test]
    fn e5_scaling_is_near_linear() {
        if !have_artifacts() {
            return;
        }
        let t = run_experiment("e5", true).unwrap();
        // last row = 10,000 cores; scaling column ~5x of the 2,000-core row.
        let scaling: f64 = t.rows.last().unwrap()[2].parse().unwrap();
        assert!(scaling > 3.0, "scaling {scaling} too sub-linear");
        assert!(scaling <= 5.2, "scaling {scaling} super-linear?!");
    }
}
