//! The assembled platform: one call boots the whole stack — resource
//! manager, tiered storage, PJRT runtime, kernel registry, dispatcher,
//! and the compute-engine context — wired exactly as Figure 2 draws it.
//! The [`job`] submodule is the unified job layer every workload
//! schedules through.

pub mod checkpoint;
pub mod experiments;
pub mod job;
pub mod opts;

pub use checkpoint::ShardCheckpoint;
pub use job::{run_stage, JobHandle, JobSpec, JobStats, ShardCtx};
pub use opts::JobOpts;

use anyhow::Result;
use std::sync::Arc;

use crate::config::PlatformConfig;
use crate::dce::DceContext;
use crate::hetero::{register_default_kernels, Dispatcher, KernelRegistry};
use crate::metrics::MetricsRegistry;
use crate::resource::ResourceManager;
use crate::runtime::XlaRuntime;

/// A booted platform instance.
pub struct Platform {
    pub config: PlatformConfig,
    pub metrics: MetricsRegistry,
    pub resources: Arc<ResourceManager>,
    pub ctx: DceContext,
    /// None when `artifacts/` has not been built (CPU-only operation).
    pub runtime: Option<XlaRuntime>,
    pub dispatcher: Dispatcher,
}

impl Platform {
    /// Boot every subsystem from a config.
    pub fn boot(config: PlatformConfig) -> Result<Self> {
        let metrics = MetricsRegistry::new();
        let resources = ResourceManager::new(&config.cluster, metrics.clone());
        let ctx = DceContext::new(config.clone())?;
        let registry = KernelRegistry::new();
        let artifacts = crate::artifacts_dir();
        let runtime = if artifacts.join("manifest.json").is_file() {
            // One PJRT device-server per GPU-class accelerator (capped:
            // each server owns a full XLA client).
            let devices = (config.cluster.nodes * config.cluster.gpus_per_node).clamp(1, 4);
            let rt = XlaRuntime::new(&artifacts, devices, metrics.clone())?;
            register_default_kernels(&registry, &rt);
            Some(rt)
        } else {
            None
        };
        let dispatcher = Dispatcher::new(registry, metrics.clone());
        Ok(Self { config, metrics, resources, ctx, runtime, dispatcher })
    }

    /// Small test platform (no device models).
    pub fn local() -> Result<Self> {
        Self::boot(PlatformConfig::test())
    }

    /// Bench platform (device models enforced).
    pub fn bench() -> Result<Self> {
        Self::boot(PlatformConfig::bench())
    }

    pub fn has_accelerators(&self) -> bool {
        self.runtime.is_some()
    }

    /// One-line platform summary for the CLI.
    pub fn describe(&self) -> String {
        format!(
            "adcloud platform: {} nodes x {} cores, {} gpu-class + {} fpga-class per node; artifacts: {}",
            self.config.cluster.nodes,
            self.config.cluster.cores_per_node,
            self.config.cluster.gpus_per_node,
            self.config.cluster.fpgas_per_node,
            if self.has_accelerators() {
                format!("{} kernels", self.dispatcher.registry().kernel_names().len())
            } else {
                "missing (run `make artifacts`)".to_string()
            }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boot_local_platform() {
        let p = Platform::local().unwrap();
        assert!(p.describe().contains("2 nodes"));
        // RDD job works end to end on the booted context.
        let sum = p.ctx.range(100, 4).reduce(|a, b| a + b).unwrap();
        assert_eq!(sum, Some(4950));
    }

    #[test]
    fn kernels_registered_when_artifacts_present() {
        let p = Platform::local().unwrap();
        if p.has_accelerators() {
            let names = p.dispatcher.registry().kernel_names();
            assert!(names.iter().any(|n| n == "cnn_train_b16"), "{names:?}");
            assert!(names.iter().any(|n| n == "icp_step_4096"));
        }
    }
}
