//! Scenario mining: turn compacted fleet drives into test scenarios.
//!
//! A DCE job scans the compacted blocks in the tiered store for safety
//! events — hard brakes, disengagements, sensor dropouts — and distills
//! each into a [`ScenarioSpec`] inside a named `mined-*` family. The
//! emitted specs satisfy every invariant the scenario engine enforces
//! (quadrant exclusivity, actor bounds, exact-f64 seeds), so
//! [`crate::scenario::run_campaign`] executes them unmodified: the
//! loop from fleet data back into qualification campaigns.
//!
//! Mining is deterministic: the same blocks produce byte-identical
//! spec sets (every spec parameter derives from the event's identity
//! through the in-tree RNG), which the e2e tests assert via
//! [`crate::scenario::campaign_digest`].

use anyhow::Result;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::compact::{decode_block, BlockRef};
use super::gateway::decode_telemetry;
use crate::dce::DceContext;
use crate::platform::checkpoint::ShardCheckpoint;
use crate::platform::job::JobHandle;
use crate::platform::opts::JobOpts;
use crate::resource::{ResourceManager, ResourceVec};
use crate::scenario::{
    base_route, fnv1a64, ActorKind, ActorSpec, FaultSpec, ScenarioSpec, Weather,
};
use crate::storage::TieredStore;
use crate::util::Rng;

/// The event classes the miner detects.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EventKind {
    HardBrake,
    Disengagement,
    SensorDropout,
}

impl EventKind {
    pub const ALL: [EventKind; 3] =
        [EventKind::HardBrake, EventKind::Disengagement, EventKind::SensorDropout];

    pub fn name(&self) -> &'static str {
        match self {
            EventKind::HardBrake => "hard-brake",
            EventKind::Disengagement => "disengagement",
            EventKind::SensorDropout => "sensor-dropout",
        }
    }
}

/// One detected safety event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MinedEvent {
    pub kind: EventKind,
    pub vehicle: u32,
    pub ts_ns: u64,
    pub speed_mps: f32,
}

/// Detection thresholds and spec-emission knobs. The shared submission
/// fields (app name, queue, worker ceiling, checkpointing — where
/// `opts.checkpoint` commits each block's scan result into a
/// [`ShardCheckpoint`] so a preempted or resubmitted mining job skips
/// scanned blocks) live in [`JobOpts`].
#[derive(Debug, Clone)]
pub struct MinerConfig {
    /// Shared job-submission options.
    pub opts: JobOpts,
    /// Deceleration at or below this is a hard brake (m/s^2).
    pub hard_brake_mps2: f32,
    /// Camera gap at or above this is a sensor dropout (ms).
    pub dropout_ms: u32,
    /// Events from one vehicle closer than this collapse into one.
    pub merge_window_ns: u64,
    /// Frames per emitted scenario.
    pub frames: u32,
    /// Cap on specs emitted per family.
    pub max_specs_per_family: usize,
}

impl Default for MinerConfig {
    fn default() -> Self {
        Self {
            opts: JobOpts::new("scenario-miner").workers(4),
            hard_brake_mps2: -6.0,
            dropout_ms: 500,
            merge_window_ns: 500_000_000,
            frames: 16,
            max_specs_per_family: 64,
        }
    }
}

/// Checkpoint key for one block's scan: the block key plus a digest of
/// the detection thresholds, so a resubmission with different knobs
/// can never reuse scans made under the old ones.
fn ckpt_key(block_key: &str, cfg: &MinerConfig) -> String {
    let knobs = format!("{:016x}-{}", cfg.hard_brake_mps2.to_bits(), cfg.dropout_ms);
    format!("{block_key}-{:016x}", fnv1a64(knobs.as_bytes()))
}

/// Checkpoint codec for one block's scan result:
/// `u32 count | { u8 kind | u32 vehicle | u64 ts_ns | f32 speed }*`.
fn encode_events(events: &[MinedEvent]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + events.len() * 17);
    out.extend_from_slice(&(events.len() as u32).to_le_bytes());
    for e in events {
        let kind = EventKind::ALL.iter().position(|k| *k == e.kind).unwrap() as u8;
        out.push(kind);
        out.extend_from_slice(&e.vehicle.to_le_bytes());
        out.extend_from_slice(&e.ts_ns.to_le_bytes());
        out.extend_from_slice(&e.speed_mps.to_le_bytes());
    }
    out
}

fn decode_events(bytes: &[u8]) -> Result<Vec<MinedEvent>> {
    if bytes.len() < 4 {
        anyhow::bail!("event blob too short: {} bytes", bytes.len());
    }
    let count = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    if bytes.len() != 4 + count * 17 {
        anyhow::bail!("event blob claims {count} events in {} bytes", bytes.len());
    }
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let b = &bytes[4 + i * 17..4 + (i + 1) * 17];
        let kind = match EventKind::ALL.get(b[0] as usize) {
            Some(k) => *k,
            None => anyhow::bail!("event blob has invalid kind index {}", b[0]),
        };
        out.push(MinedEvent {
            kind,
            vehicle: u32::from_le_bytes(b[1..5].try_into().unwrap()),
            ts_ns: u64::from_le_bytes(b[5..13].try_into().unwrap()),
            speed_mps: f32::from_le_bytes(b[13..17].try_into().unwrap()),
        });
    }
    Ok(out)
}

/// Scan one decoded block's telemetry for events. Rosbag-chunk payloads
/// are skipped (the miner only reads the telemetry stream).
pub fn scan_block(bytes: &[u8], cfg: &MinerConfig) -> Result<Vec<MinedEvent>> {
    let mut out = Vec::new();
    for rec in decode_block(bytes)? {
        let Some(samples) = decode_telemetry(&rec.payload)? else {
            continue;
        };
        for t in samples {
            if t.accel_mps2 <= cfg.hard_brake_mps2 {
                out.push(MinedEvent {
                    kind: EventKind::HardBrake,
                    vehicle: t.vehicle,
                    ts_ns: t.ts_ns,
                    speed_mps: t.speed_mps,
                });
            }
            if t.disengaged {
                out.push(MinedEvent {
                    kind: EventKind::Disengagement,
                    vehicle: t.vehicle,
                    ts_ns: t.ts_ns,
                    speed_mps: t.speed_mps,
                });
            }
            if t.sensor_gap_ms >= cfg.dropout_ms {
                out.push(MinedEvent {
                    kind: EventKind::SensorDropout,
                    vehicle: t.vehicle,
                    ts_ns: t.ts_ns,
                    speed_mps: t.speed_mps,
                });
            }
        }
    }
    Ok(out)
}

/// Sort events canonically and collapse bursts: consecutive events of
/// one (vehicle, kind) within the merge window are one episode.
pub fn dedupe_events(mut events: Vec<MinedEvent>, cfg: &MinerConfig) -> Vec<MinedEvent> {
    events.sort_by(|a, b| (a.kind, a.vehicle, a.ts_ns).cmp(&(b.kind, b.vehicle, b.ts_ns)));
    let mut out: Vec<MinedEvent> = Vec::with_capacity(events.len());
    for e in events {
        let merge = out.last().is_some_and(|p| {
            p.kind == e.kind
                && p.vehicle == e.vehicle
                && e.ts_ns.saturating_sub(p.ts_ns) <= cfg.merge_window_ns
        });
        if merge {
            // Extend the episode's window instead of emitting again.
            out.last_mut().unwrap().ts_ns = e.ts_ns;
        } else {
            out.push(e);
        }
    }
    out
}

/// Weather regime each event class stresses (the plausible aggravator:
/// braking distance in rain, night handovers, fog-blind sensors).
fn weather_for(kind: EventKind) -> Weather {
    match kind {
        EventKind::HardBrake => Weather::Rain,
        EventKind::Disengagement => Weather::Night,
        EventKind::SensorDropout => Weather::Fog,
    }
}

/// Actor class planted in front of the replayed event.
fn actor_kind_for(kind: EventKind) -> ActorKind {
    match kind {
        EventKind::HardBrake => ActorKind::Vehicle,
        EventKind::Disengagement => ActorKind::Pedestrian,
        EventKind::SensorDropout => ActorKind::Debris,
    }
}

/// One actor with the scenario engine's placement discipline (4 px
/// quadrant margin, 8..=12 px boxes, dx+w <= 24).
fn gen_actor(kind: ActorKind, quadrant: u8, frames: u32, rng: &mut Rng) -> ActorSpec {
    let w = 8 + rng.below(5) as u8;
    let h = 8 + rng.below(5) as u8;
    let dx = rng.below(25 - w as u64) as u8;
    let dy = rng.below(25 - h as u64) as u8;
    let appear = rng.below((frames as u64 / 2).max(1)) as u32;
    let vanish = appear + 1 + rng.below(frames.max(1) as u64 * 2) as u32;
    ActorSpec { kind, quadrant, dx, dy, w, h, appear, vanish }
}

/// Distill one event into a reproducible scenario spec. Every parameter
/// derives from the event's identity, so mining is deterministic.
pub fn event_to_spec(event: &MinedEvent, index: usize, cfg: &MinerConfig) -> ScenarioSpec {
    let identity = format!("{}:{}:{}", event.kind.name(), event.vehicle, event.ts_ns);
    // Keep the seed < 2^32 so the spec's JSON f64 representation is exact.
    let seed = fnv1a64(identity.as_bytes()) & 0xFFFF_FFFF;
    let mut rng = Rng::new(seed);
    let route = base_route(&mut rng);
    let actors_n = if event.kind == EventKind::HardBrake { 2 } else { 1 };
    let mut quadrants = [0u8, 1, 2, 3];
    rng.shuffle(&mut quadrants);
    let actors = quadrants[..actors_n]
        .iter()
        .map(|&q| gen_actor(actor_kind_for(event.kind), q, cfg.frames, &mut rng))
        .collect();
    // Faster drives get noisier sensors; dropouts replay with the
    // recording-path faults that produced them.
    let pixel_noise =
        crate::scenario::spec::round3(0.01 + (event.speed_mps as f64 / 33.0).min(1.0) * 0.05);
    let faults = if event.kind == EventKind::SensorDropout {
        FaultSpec { drop_rate: 0.1, corrupt_rate: 0.05 }
    } else {
        FaultSpec::none()
    };
    ScenarioSpec {
        id: format!("mined-{}-{index:04}", event.kind.name()),
        family: format!("mined-{}", event.kind.name()),
        seed,
        frames: cfg.frames,
        weather: weather_for(event.kind),
        pixel_noise,
        route,
        actors,
        faults,
    }
}

/// Mining outcome: the events found and the spec families emitted.
#[derive(Debug, Clone)]
pub struct MineReport {
    pub events: Vec<MinedEvent>,
    pub specs: Vec<ScenarioSpec>,
    pub records_scanned: u64,
    pub elapsed: Duration,
}

impl MineReport {
    /// Distinct family names, sorted.
    pub fn families(&self) -> Vec<String> {
        let set: std::collections::BTreeSet<String> =
            self.specs.iter().map(|s| s.family.clone()).collect();
        set.into_iter().collect()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "mined {} events from {} records in {}:\n",
            self.events.len(),
            self.records_scanned,
            crate::util::fmt_duration(self.elapsed),
        );
        for family in self.families() {
            let n = self.specs.iter().filter(|s| s.family == family).count();
            out.push_str(&format!("  {family:<24} {n} scenario(s)\n"));
        }
        out
    }
}

/// Run the mining job on the unified job layer: acquire a container
/// grant, shard the block list over the compute engine (one shard per
/// container), scan each block inside its container's accounting, and
/// distill the merged event stream into scenario families. With
/// `checkpoint` enabled (the default), per-block scan results are
/// committed as they land and shards yield between blocks when their
/// container is flagged for preemption, so a requeued or resubmitted
/// mining job rescans nothing.
pub fn mine(
    ctx: &DceContext,
    rm: &Arc<ResourceManager>,
    store: &Arc<TieredStore>,
    blocks: &[BlockRef],
    cfg: &MinerConfig,
) -> Result<MineReport> {
    let start = Instant::now();
    if blocks.is_empty() {
        return Ok(MineReport {
            events: Vec::new(),
            specs: Vec::new(),
            records_scanned: 0,
            elapsed: start.elapsed(),
        });
    }
    let records_scanned = blocks.iter().map(|b| b.records as u64).sum();
    let keys: Vec<String> = blocks.iter().map(|b| b.key.clone()).collect();
    let max_block = blocks.iter().map(|b| b.bytes).max().unwrap_or(0);
    let job = JobHandle::submit(
        rm,
        cfg.opts
            .spec()
            .containers(1, cfg.opts.workers.clamp(1, keys.len()))
            .resources(ResourceVec::cores(1, (4 * max_block).max(8 << 20))),
    )?;
    let ckpt = cfg.opts.checkpoint.then(|| ShardCheckpoint::new(store, &cfg.opts.app));
    let shard_ckpt = ckpt.clone();
    // Resolve the per-block counters once; the scan loop must not take
    // the registry lock per block.
    let ckpt_hits = ctx.metrics().counter("ingest.mine.ckpt_hits");
    let ckpt_corrupt = ctx.metrics().counter("ingest.mine.ckpt_corrupt");
    let (store2, cfg2) = (store.clone(), cfg.clone());
    let scanned = job.run_sharded(ctx, keys.clone(), move |sctx, keys: Vec<String>| {
        let mut out = Vec::new();
        for key in keys {
            let item = ckpt_key(&key, &cfg2);
            // Resume path: blocks scanned before a preemption or by a
            // prior submission are reloaded from the checkpoint. A
            // blob that fails to decode must not poison the job —
            // fall through and rescan instead.
            if let Some(bytes) = shard_ckpt.as_ref().and_then(|c| c.lookup(&item)) {
                if let Ok(events) = decode_events(&bytes) {
                    out.extend(events);
                    ckpt_hits.inc();
                    continue;
                }
                ckpt_corrupt.inc();
            }
            sctx.check_preempted()?;
            let bytes = store2.get(&key)?;
            let block_len = bytes.len() as u64;
            let events = sctx.run(|cctx| -> Result<Vec<MinedEvent>> {
                cctx.alloc_mem(block_len)?;
                let events = scan_block(&bytes, &cfg2);
                cctx.free_mem(block_len);
                events
            })??;
            if let Some(c) = &shard_ckpt {
                c.commit(&item, encode_events(&events))?;
            }
            out.extend(events);
        }
        Ok(out)
    });
    let _ = job.finish();
    let scanned = scanned?;
    if let Some(c) = &ckpt {
        // Success: the next mining pass over these blocks starts fresh.
        c.clear(keys.iter().map(|k| ckpt_key(k, cfg)));
    }
    let events = dedupe_events(scanned, cfg);
    ctx.metrics().counter("ingest.mine.events").add(events.len() as u64);
    let mut specs: Vec<ScenarioSpec> = Vec::new();
    let mut seen: HashSet<u64> = HashSet::new();
    let mut per_family = [0usize; 3];
    for e in &events {
        let fam = EventKind::ALL.iter().position(|k| *k == e.kind).unwrap();
        if per_family[fam] >= cfg.max_specs_per_family {
            continue;
        }
        let spec = event_to_spec(e, per_family[fam], cfg);
        if seen.insert(spec.content_hash()) {
            per_family[fam] += 1;
            specs.push(spec);
        }
    }
    ctx.metrics().counter("ingest.mine.specs").add(specs.len() as u64);
    Ok(MineReport { events, specs, records_scanned, elapsed: start.elapsed() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::ingest::compact::{compact, CompactorConfig};
    use crate::ingest::gateway::{encode_telemetry, gen_drive};
    use crate::ingest::log::{LogConfig, PartitionedLog};
    use crate::metrics::MetricsRegistry;
    use crate::resource::ResourceManager;
    use crate::util::json::Json;

    /// Ingest a deterministic fleet and compact it; returns the blocks.
    fn compacted_fixture(
        store: &Arc<TieredStore>,
        vehicles: u32,
        ticks: usize,
    ) -> Vec<BlockRef> {
        let log = PartitionedLog::temp("mine", LogConfig::default()).unwrap();
        for v in 0..vehicles {
            let drive = gen_drive(v, 11, ticks);
            for chunk in drive.chunks(8) {
                let p = log.partition_for(v);
                log.append(p, chunk[0].ts_ns, v, &encode_telemetry(chunk)).unwrap();
            }
        }
        let cfg = PlatformConfig::test();
        let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
        compact(&log, store, &rm, &CompactorConfig::new("mine-fix", 2)).unwrap().blocks
    }

    fn test_rm() -> Arc<ResourceManager> {
        ResourceManager::new(&PlatformConfig::test().cluster, MetricsRegistry::new())
    }

    #[test]
    fn mining_finds_every_event_family() {
        let ctx = DceContext::new(PlatformConfig::test()).unwrap();
        let rm = test_rm();
        let blocks = compacted_fixture(ctx.store(), 8, 400);
        let report = mine(&ctx, &rm, ctx.store(), &blocks, &MinerConfig::default()).unwrap();
        assert!(!report.events.is_empty());
        assert_eq!(rm.live_containers(), 0, "mining grant must be returned");
        assert_eq!(
            report.families(),
            vec![
                "mined-disengagement".to_string(),
                "mined-hard-brake".to_string(),
                "mined-sensor-dropout".to_string()
            ],
            "all three event classes must surface at this fleet size"
        );
        assert!(report.specs.len() >= 3);
    }

    #[test]
    fn mining_is_deterministic() {
        let ctx = DceContext::new(PlatformConfig::test()).unwrap();
        let rm = test_rm();
        let blocks = compacted_fixture(ctx.store(), 4, 300);
        let a = mine(&ctx, &rm, ctx.store(), &blocks, &MinerConfig::default()).unwrap();
        let b = mine(&ctx, &rm, ctx.store(), &blocks, &MinerConfig::default()).unwrap();
        assert_eq!(a.events, b.events);
        assert_eq!(
            crate::scenario::campaign_digest(&a.specs),
            crate::scenario::campaign_digest(&b.specs)
        );
    }

    #[test]
    fn mining_resumes_from_block_checkpoints() {
        let ctx = DceContext::new(PlatformConfig::test()).unwrap();
        let rm = test_rm();
        let blocks = compacted_fixture(ctx.store(), 4, 300);
        let cfg = MinerConfig::default();
        // Simulate an interrupted job: one block's scan is already
        // committed under the miner's app name, and one blob is
        // corrupt (must be rescanned, not fatal).
        let ckpt = ShardCheckpoint::new(ctx.store(), &cfg.opts.app);
        let pre = scan_block(ctx.store().get(&blocks[0].key).unwrap().as_ref(), &cfg).unwrap();
        ckpt.commit(&ckpt_key(&blocks[0].key, &cfg), encode_events(&pre)).unwrap();
        ckpt.commit(&ckpt_key(&blocks[1].key, &cfg), b"garbage".to_vec()).unwrap();
        let report = mine(&ctx, &rm, ctx.store(), &blocks, &cfg).unwrap();
        assert_eq!(ctx.metrics().counter("ingest.mine.ckpt_hits").get(), 1);
        assert_eq!(ctx.metrics().counter("ingest.mine.ckpt_corrupt").get(), 1);
        // Resumed output is identical to a from-scratch run.
        let fresh = mine(&ctx, &rm, ctx.store(), &blocks, &cfg).unwrap();
        assert_eq!(report.events, fresh.events);
        assert_eq!(
            crate::scenario::campaign_digest(&report.specs),
            crate::scenario::campaign_digest(&fresh.specs)
        );
        // Success cleared the checkpoint.
        assert!(!ckpt.contains(&ckpt_key(&blocks[0].key, &cfg)));
    }

    #[test]
    fn mined_specs_satisfy_scenario_invariants() {
        let ctx = DceContext::new(PlatformConfig::test()).unwrap();
        let rm = test_rm();
        let blocks = compacted_fixture(ctx.store(), 6, 300);
        let report = mine(&ctx, &rm, ctx.store(), &blocks, &MinerConfig::default()).unwrap();
        for s in &report.specs {
            // from_json re-runs every spec validity check; a mined spec
            // must survive it so campaigns can execute it unmodified.
            let back = ScenarioSpec::from_json(&Json::parse(&s.canonical_json()).unwrap())
                .unwrap_or_else(|e| panic!("mined spec {} invalid: {e:#}", s.id));
            assert_eq!(&back, s);
        }
        let hashes: HashSet<u64> = report.specs.iter().map(|s| s.content_hash()).collect();
        assert_eq!(hashes.len(), report.specs.len(), "content hashes must be distinct");
    }

    #[test]
    fn event_codec_roundtrips_and_rejects_corruption() {
        let events = vec![
            MinedEvent { kind: EventKind::HardBrake, vehicle: 3, ts_ns: 99, speed_mps: 21.5 },
            MinedEvent { kind: EventKind::SensorDropout, vehicle: 8, ts_ns: 5, speed_mps: 0.0 },
        ];
        let b = encode_events(&events);
        assert_eq!(decode_events(&b).unwrap(), events);
        assert!(decode_events(&b[..b.len() - 1]).is_err());
        assert!(decode_events(&[9, 9]).is_err());
    }

    #[test]
    fn dedupe_collapses_bursts_per_vehicle() {
        let cfg = MinerConfig::default();
        let e = |v: u32, ts: u64, kind| MinedEvent { kind, vehicle: v, ts_ns: ts, speed_mps: 10.0 };
        let events = vec![
            e(1, 0, EventKind::HardBrake),
            e(1, 100_000_000, EventKind::HardBrake), // same episode
            e(1, 200_000_000, EventKind::HardBrake), // still the same
            e(1, 5_000_000_000, EventKind::HardBrake), // new episode
            e(2, 100_000_000, EventKind::HardBrake), // other vehicle
            e(1, 100_000_000, EventKind::Disengagement), // other kind
        ];
        let deduped = dedupe_events(events, &cfg);
        assert_eq!(deduped.len(), 4);
    }

    #[test]
    fn scan_skips_bag_chunks() {
        let cfg = MinerConfig::default();
        let recs = vec![crate::ingest::log::LogRecord {
            offset: 0,
            ts_ns: 0,
            source: 1,
            payload: crate::services::simulation::encode_bag(&[]),
        }];
        let block = crate::ingest::compact::encode_block(&recs);
        assert!(scan_block(&block, &cfg).unwrap().is_empty());
    }
}
