//! The ingest gateway: the fleet's front door into the platform.
//!
//! Simulated vehicles upload telemetry batches and rosbag chunks. The
//! gateway admits, throttles, or rejects each upload:
//!
//! * **rate limiting** — a per-vehicle token bucket refilled each tick;
//! * **backpressure** — uploads bounce when the target partition's lag
//!   (appended minus compacted offsets) exceeds the configured bound,
//!   so a stalled compactor propagates pressure back to the fleet
//!   instead of filling the log;
//! * **dead-letter handling** — uploads whose payload fails its
//!   declared CRC are captured in a dead-letter queue with a reason,
//!   never appended to the clean log.
//!
//! Everything is seed-deterministic: [`gen_drive`] produces each
//! vehicle's telemetry (with plantable hard-brake / disengagement /
//! sensor-dropout episodes the miner later digs out), and
//! [`simulate_fleet`] replays a whole fleet against the gateway.
//!
//! The fleet loop is event-driven and batched by default: a
//! hierarchical [`TimerWheel`] yields only the vehicles due to emit
//! each tick, and the tick's uploads are admitted in one
//! [`IngestGateway::upload_batch`] pass that folds per-vehicle token
//! accounting into a single lock acquisition and group-commits each
//! partition's accepted records. The original per-vehicle/per-upload
//! path survives behind `FleetConfig::baseline` as the A/B control,
//! regression-tested to produce bit-identical admission outcomes.

use anyhow::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use super::log::{crc32, AppendRecord, PartitionedLog};
use crate::scenario::fnv1a64;
use crate::metrics::{GatewayMetrics, MetricsRegistry};
use crate::services::simulation::{encode_bag, Message};
use crate::trace;
use crate::util::Rng;

/// Magic prefix of an encoded telemetry batch payload (rosbag chunks
/// carry the bag codec's own `ADBG` magic instead).
pub const TELEMETRY_MAGIC: &[u8; 4] = b"ADTL";

/// One telemetry sample from a vehicle's CAN/sensor bus.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Telemetry {
    pub vehicle: u32,
    pub ts_ns: u64,
    pub speed_mps: f32,
    pub accel_mps2: f32,
    /// Safety driver took over at this tick.
    pub disengaged: bool,
    /// Milliseconds since the last camera frame (0 = nominal cadence).
    pub sensor_gap_ms: u32,
}

/// Fixed wire size of one sample.
pub const TELEMETRY_BYTES: usize = 25;

impl Telemetry {
    pub fn to_bytes(&self) -> [u8; TELEMETRY_BYTES] {
        let mut out = [0u8; TELEMETRY_BYTES];
        out[0..4].copy_from_slice(&self.vehicle.to_le_bytes());
        out[4..12].copy_from_slice(&self.ts_ns.to_le_bytes());
        out[12..16].copy_from_slice(&self.speed_mps.to_le_bytes());
        out[16..20].copy_from_slice(&self.accel_mps2.to_le_bytes());
        out[20] = self.disengaged as u8;
        out[21..25].copy_from_slice(&self.sensor_gap_ms.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        anyhow::ensure!(
            bytes.len() == TELEMETRY_BYTES,
            "telemetry sample is {} bytes, want {TELEMETRY_BYTES}",
            bytes.len()
        );
        Ok(Self {
            vehicle: u32::from_le_bytes(bytes[0..4].try_into().unwrap()),
            ts_ns: u64::from_le_bytes(bytes[4..12].try_into().unwrap()),
            speed_mps: f32::from_le_bytes(bytes[12..16].try_into().unwrap()),
            accel_mps2: f32::from_le_bytes(bytes[16..20].try_into().unwrap()),
            disengaged: bytes[20] != 0,
            sensor_gap_ms: u32::from_le_bytes(bytes[21..25].try_into().unwrap()),
        })
    }
}

/// Encode a batch of samples as one upload payload.
pub fn encode_telemetry(samples: &[Telemetry]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + samples.len() * TELEMETRY_BYTES);
    out.extend_from_slice(TELEMETRY_MAGIC);
    out.extend_from_slice(&(samples.len() as u32).to_le_bytes());
    for s in samples {
        out.extend_from_slice(&s.to_bytes());
    }
    out
}

/// Decode a telemetry batch payload. `Ok(None)` when the payload is a
/// different kind (e.g. a rosbag chunk) — not an error, just not ours.
pub fn decode_telemetry(payload: &[u8]) -> Result<Option<Vec<Telemetry>>> {
    if payload.len() < 8 || &payload[..4] != TELEMETRY_MAGIC {
        return Ok(None);
    }
    let count = u32::from_le_bytes(payload[4..8].try_into().unwrap()) as usize;
    anyhow::ensure!(
        payload.len() == 8 + count * TELEMETRY_BYTES,
        "telemetry batch claims {count} samples in {} bytes",
        payload.len()
    );
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let at = 8 + i * TELEMETRY_BYTES;
        out.push(Telemetry::from_bytes(&payload[at..at + TELEMETRY_BYTES])?);
    }
    Ok(Some(out))
}

/// Incremental form of [`gen_drive`]: the identical RNG stream, one
/// sample per call — so a million-vehicle fleet generates telemetry
/// lazily at emit time instead of materializing every drive up front.
pub struct DriveGen {
    vehicle: u32,
    rng: Rng,
    speed: f32,
    brake_left: usize,
    tick: usize,
}

impl DriveGen {
    pub fn new(vehicle: u32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ (vehicle as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let speed = rng.range_f64(8.0, 20.0) as f32;
        Self { vehicle, rng, speed, brake_left: 0, tick: 0 }
    }

    /// The next tick's sample (the tick index advances per call).
    pub fn next_sample(&mut self) -> Telemetry {
        let t = self.tick;
        self.tick += 1;
        let mut accel = self.rng.normal_f32(0.0, 0.6);
        if self.brake_left > 0 {
            self.brake_left -= 1;
            accel = -7.5 + self.rng.normal_f32(0.0, 0.3);
        } else if self.rng.next_f64() < 0.01 {
            self.brake_left = 2;
            accel = -7.5;
        }
        let disengaged = self.rng.next_f64() < 0.004;
        let sensor_gap_ms =
            if self.rng.next_f64() < 0.006 { 400 + self.rng.below(800) as u32 } else { 0 };
        self.speed = (self.speed + accel * 0.1).clamp(0.0, 33.0);
        Telemetry {
            vehicle: self.vehicle,
            ts_ns: t as u64 * 100_000_000,
            speed_mps: self.speed,
            accel_mps2: accel,
            disengaged,
            sensor_gap_ms,
        }
    }
}

/// Deterministic per-vehicle drive: a speed random walk with plantable
/// hard-brake episodes, disengagements, and sensor dropouts — the raw
/// material [`super::mine`] later turns into scenario families.
pub fn gen_drive(vehicle: u32, seed: u64, ticks: usize) -> Vec<Telemetry> {
    let mut gen = DriveGen::new(vehicle, seed);
    (0..ticks).map(|_| gen.next_sample()).collect()
}

/// Slots per level of the hierarchical timer wheel.
const WHEEL_SLOTS: u64 = 64;

/// Hierarchical timer wheel scheduling vehicle emissions: `advance`
/// returns exactly the vehicles due this tick, so a fleet tick costs
/// O(vehicles due) instead of O(fleet). Two 64-slot levels cover a
/// 4096-tick horizon; entries beyond it park in an overflow list that
/// cascades back down as the wheel turns.
pub struct TimerWheel {
    now: u64,
    /// Level 0: one slot per tick within the next 64 ticks.
    l0: Vec<Vec<u32>>,
    /// Level 1: one slot per 64-tick span within the next 4096 ticks.
    l1: Vec<Vec<(u32, u64)>>,
    overflow: Vec<(u32, u64)>,
}

impl Default for TimerWheel {
    fn default() -> Self {
        Self::new()
    }
}

impl TimerWheel {
    pub fn new() -> Self {
        Self {
            now: 0,
            l0: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            l1: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            overflow: Vec::new(),
        }
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    /// Schedule `vehicle` to emit at absolute tick `due` (clamped to
    /// the present — the wheel never schedules into the past).
    pub fn schedule(&mut self, vehicle: u32, due: u64) {
        let due = due.max(self.now);
        if due - self.now < WHEEL_SLOTS {
            self.l0[(due % WHEEL_SLOTS) as usize].push(vehicle);
        } else if due - self.now < WHEEL_SLOTS * WHEEL_SLOTS {
            self.l1[((due / WHEEL_SLOTS) % WHEEL_SLOTS) as usize].push((vehicle, due));
        } else {
            self.overflow.push((vehicle, due));
        }
    }

    /// Drain the vehicles due at the current tick (ascending, matching
    /// the order a per-vehicle loop would visit them) and advance.
    pub fn advance(&mut self) -> Vec<u32> {
        if self.now % WHEEL_SLOTS == 0 {
            if self.now % (WHEEL_SLOTS * WHEEL_SLOTS) == 0 {
                // Crossing a level-1 horizon: re-file the overflow.
                for (v, due) in std::mem::take(&mut self.overflow) {
                    self.schedule(v, due);
                }
            }
            // Cascade the level-1 slot covering [now, now + 64) down.
            let slot = ((self.now / WHEEL_SLOTS) % WHEEL_SLOTS) as usize;
            for (v, due) in std::mem::take(&mut self.l1[slot]) {
                self.l0[(due % WHEEL_SLOTS) as usize].push(v);
            }
        }
        let mut due = std::mem::take(&mut self.l0[(self.now % WHEEL_SLOTS) as usize]);
        due.sort_unstable();
        self.now += 1;
        due
    }
}

/// One upload as it arrives at the gateway. `declared_crc` is what the
/// vehicle computed before transmission; a mismatch against the
/// received payload means in-flight corruption.
#[derive(Debug, Clone)]
pub struct VehicleUpload {
    pub vehicle: u32,
    pub ts_ns: u64,
    pub payload: Vec<u8>,
    pub declared_crc: u32,
}

impl VehicleUpload {
    /// A well-formed upload (CRC computed over the payload as-is).
    pub fn new(vehicle: u32, ts_ns: u64, payload: Vec<u8>) -> Self {
        let declared_crc = crc32(&payload);
        Self { vehicle, ts_ns, payload, declared_crc }
    }
}

/// What the gateway decided about one upload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Admission {
    Accepted { partition: usize, offset: u64 },
    /// Vehicle exceeded its per-tick rate; retry next tick.
    Throttled,
    /// Target partition's lag exceeds the bound; retry after compaction.
    Backpressure,
    /// Payload failed its CRC; captured in the dead-letter queue.
    DeadLettered,
}

/// A rejected-as-corrupt upload plus why.
#[derive(Debug, Clone)]
pub struct DeadLetter {
    pub vehicle: u32,
    pub ts_ns: u64,
    pub reason: String,
    pub bytes: usize,
}

/// Gateway admission knobs.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Uploads each vehicle may land per tick.
    pub rate_per_tick: u32,
    /// Backpressure once a partition's lag reaches this many records.
    pub max_lag: u64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self { rate_per_tick: 4, max_lag: 100_000 }
    }
}

/// The ingest gateway over a [`PartitionedLog`].
pub struct IngestGateway {
    log: Arc<PartitionedLog>,
    cfg: GatewayConfig,
    tokens: Mutex<HashMap<u32, u32>>,
    dead: Mutex<Vec<DeadLetter>>,
    /// Admission counters resolved once — one decision per upload.
    m: GatewayMetrics,
}

impl IngestGateway {
    pub fn new(log: Arc<PartitionedLog>, cfg: GatewayConfig, metrics: MetricsRegistry) -> Self {
        Self {
            log,
            cfg,
            tokens: Mutex::new(HashMap::new()),
            dead: Mutex::new(Vec::new()),
            m: GatewayMetrics::new(&metrics),
        }
    }

    pub fn log(&self) -> &Arc<PartitionedLog> {
        &self.log
    }

    /// Refill every vehicle's token bucket (call once per fleet tick).
    pub fn begin_tick(&self) {
        self.tokens.lock().unwrap().clear();
    }

    /// Admit one upload.
    pub fn upload(&self, up: &VehicleUpload) -> Result<Admission> {
        let mut sp = trace::span("gateway.upload", trace::Category::LogIo);
        sp.arg("vehicle", up.vehicle as u64).arg("bytes", up.payload.len() as u64);
        {
            let mut tokens = self.tokens.lock().unwrap();
            let t = tokens.entry(up.vehicle).or_insert(self.cfg.rate_per_tick);
            if *t == 0 {
                self.m.throttled.inc();
                return Ok(Admission::Throttled);
            }
            *t -= 1;
        }
        if crc32(&up.payload) != up.declared_crc {
            self.m.dead_lettered.inc();
            let mut dead = self.dead.lock().unwrap();
            dead.push(DeadLetter {
                vehicle: up.vehicle,
                ts_ns: up.ts_ns,
                reason: "payload CRC mismatch".into(),
                bytes: up.payload.len(),
            });
            self.m.dlq_depth.set(dead.len() as u64);
            return Ok(Admission::DeadLettered);
        }
        let partition = self.log.partition_for(up.vehicle);
        let lag = self.log.lag(partition);
        // Worst-partition lag feeds the ingest-backlog watchdog; each
        // admission decision refreshes it for the partition it probed.
        if lag >= self.m.partition_lag.get() || partition == 0 {
            self.m.partition_lag.set(lag);
        }
        if lag >= self.cfg.max_lag {
            self.m.backpressured.inc();
            return Ok(Admission::Backpressure);
        }
        let offset = self.log.append(partition, up.ts_ns, up.vehicle, &up.payload)?;
        self.m.accepted.inc();
        Ok(Admission::Accepted { partition, offset })
    }

    /// Admit a whole tick's uploads in one pass: one token-bucket lock
    /// acquisition for the batch, one lag probe per partition touched
    /// (each accepted record then counts against that probe, so every
    /// upload's outcome is bit-identical to calling [`Self::upload`] on
    /// the same sequence), and one group-commit
    /// [`PartitionedLog::append_batch`] per partition instead of one
    /// append per record. A CRC mismatch dead-letters only the affected
    /// upload — one corrupt frame never rejects its batch.
    pub fn upload_batch(&self, ups: &[VehicleUpload]) -> Result<Vec<Admission>> {
        let mut sp = trace::span("gateway.upload_batch", trace::Category::LogIo);
        sp.arg("uploads", ups.len() as u64);
        let mut out = Vec::with_capacity(ups.len());
        // partition -> (lag at batch start, indices accepted into it).
        let mut parts: BTreeMap<usize, (u64, Vec<usize>)> = BTreeMap::new();
        {
            let mut tokens = self.tokens.lock().unwrap();
            for (i, up) in ups.iter().enumerate() {
                let t = tokens.entry(up.vehicle).or_insert(self.cfg.rate_per_tick);
                if *t == 0 {
                    self.m.throttled.inc();
                    out.push(Admission::Throttled);
                    continue;
                }
                *t -= 1;
                if crc32(&up.payload) != up.declared_crc {
                    self.m.dead_lettered.inc();
                    let mut dead = self.dead.lock().unwrap();
                    dead.push(DeadLetter {
                        vehicle: up.vehicle,
                        ts_ns: up.ts_ns,
                        reason: "payload CRC mismatch".into(),
                        bytes: up.payload.len(),
                    });
                    self.m.dlq_depth.set(dead.len() as u64);
                    out.push(Admission::DeadLettered);
                    continue;
                }
                let partition = self.log.partition_for(up.vehicle);
                let entry = parts
                    .entry(partition)
                    .or_insert_with(|| (self.log.lag(partition), Vec::new()));
                // Records this batch already accepted raise the lag the
                // sequential path would have observed here.
                if entry.0 + entry.1.len() as u64 >= self.cfg.max_lag {
                    self.m.backpressured.inc();
                    out.push(Admission::Backpressure);
                    continue;
                }
                entry.1.push(i);
                out.push(Admission::Accepted { partition, offset: 0 });
            }
        }
        for (&partition, (_, idxs)) in &parts {
            if idxs.is_empty() {
                continue;
            }
            let recs: Vec<AppendRecord<'_>> = idxs
                .iter()
                .map(|&i| AppendRecord {
                    ts_ns: ups[i].ts_ns,
                    source: ups[i].vehicle,
                    payload: &ups[i].payload,
                })
                .collect();
            let first = self.log.append_batch(partition, &recs)?;
            for (j, &i) in idxs.iter().enumerate() {
                out[i] = Admission::Accepted { partition, offset: first + j as u64 };
            }
            self.m.accepted.add(idxs.len() as u64);
            let lag = self.log.lag(partition);
            if lag >= self.m.partition_lag.get() || partition == 0 {
                self.m.partition_lag.set(lag);
            }
        }
        self.m.batches.inc();
        Ok(out)
    }

    pub fn dead_letters(&self) -> Vec<DeadLetter> {
        self.dead.lock().unwrap().clone()
    }
}

/// Fleet-simulation knobs.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub vehicles: u32,
    pub ticks: usize,
    pub seed: u64,
    /// Fraction of uploads corrupted in flight (exercises dead-letter).
    pub corrupt_rate: f64,
    /// Every this many ticks a vehicle also uploads a rosbag chunk.
    pub bag_every: usize,
    /// Per-vehicle emit cadence is drawn deterministically from
    /// `1..=cadence_max` ticks; 1 (the default) makes every vehicle
    /// emit every tick, the pre-event-driven behavior. A vehicle
    /// uploads all samples accumulated since its last emission as one
    /// telemetry batch, so higher cadences mean fewer, fatter uploads.
    pub cadence_max: u32,
    /// Use the pre-batching control path: per-vehicle iteration each
    /// tick, one admission decision and one log append per upload
    /// (`--baseline`). The event-driven batched path is regression-
    /// tested to produce identical admission outcomes against it.
    pub baseline: bool,
}

impl FleetConfig {
    pub fn new(vehicles: u32, ticks: usize, seed: u64) -> Self {
        Self {
            vehicles,
            ticks,
            seed,
            corrupt_rate: 0.0,
            bag_every: 16,
            cadence_max: 1,
            baseline: false,
        }
    }
}

/// Aggregate outcome of one simulated fleet run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FleetReport {
    pub uploads: u64,
    pub accepted: u64,
    pub throttled: u64,
    pub backpressured: u64,
    pub dead_lettered: u64,
    pub bytes_accepted: u64,
    /// Uploads still waiting on backpressure when the run ended.
    pub stranded: u64,
    /// p99 of the worst per-partition lag sampled at every tick end.
    pub tail_lag_p99: u64,
    /// Records retention truncated before any consumer read them.
    pub lost_records: u64,
}

impl FleetReport {
    pub fn render(&self) -> String {
        format!(
            "fleet: {} uploads — {} accepted ({}), {} throttled, {} backpressured, \
             {} dead-lettered, {} stranded, lag p99 {}, {} lost",
            self.uploads,
            self.accepted,
            crate::util::fmt_bytes(self.bytes_accepted),
            self.throttled,
            self.backpressured,
            self.dead_lettered,
            self.stranded,
            self.tail_lag_p99,
            self.lost_records,
        )
    }
}

/// One admission attempt: tally the outcome, re-queue throttled and
/// backpressured uploads for a later tick.
fn admit(
    gw: &IngestGateway,
    up: VehicleUpload,
    report: &mut FleetReport,
    pending: &mut Vec<VehicleUpload>,
) -> Result<()> {
    report.uploads += 1;
    match gw.upload(&up)? {
        Admission::Accepted { .. } => {
            report.accepted += 1;
            report.bytes_accepted += up.payload.len() as u64;
        }
        Admission::Backpressure => {
            report.backpressured += 1;
            pending.push(up);
        }
        Admission::Throttled => {
            report.throttled += 1;
            pending.push(up);
        }
        Admission::DeadLettered => report.dead_lettered += 1,
    }
    Ok(())
}

/// The deterministic emit cadence of one vehicle, in ticks.
fn cadence_of(vehicle: u32, seed: u64, cadence_max: u32) -> u64 {
    if cadence_max <= 1 {
        return 1;
    }
    let mut key = [0u8; 12];
    key[..4].copy_from_slice(&vehicle.to_le_bytes());
    key[4..].copy_from_slice(&seed.to_le_bytes());
    1 + fnv1a64(&key) % cadence_max as u64
}

/// Build the uploads one vehicle emits at `tick`: the telemetry batch
/// covering the `cadence` samples since its last emission, plus the
/// periodic rosbag chunk, with in-flight corruption applied in stream
/// order (so the baseline and batched paths draw the identical RNG
/// sequence).
fn emit_uploads(
    v: u32,
    tick: usize,
    cadence: u64,
    gen: &mut DriveGen,
    cfg: &FleetConfig,
    corrupt_rng: &mut Rng,
    out: &mut Vec<VehicleUpload>,
) {
    let samples: Vec<Telemetry> = (0..cadence).map(|_| gen.next_sample()).collect();
    let mut payloads = vec![encode_telemetry(&samples)];
    if cfg.bag_every > 0 && tick % cfg.bag_every == cfg.bag_every - 1 {
        payloads.push(encode_bag(&[Message {
            topic: "/camera/front".into(),
            ts_ns: tick as u64 * 100_000_000,
            payload: vec![(tick % 256) as u8; 128],
        }]));
    }
    for payload in payloads {
        let mut up = VehicleUpload::new(v, tick as u64 * 100_000_000, payload);
        if corrupt_rng.next_f64() < cfg.corrupt_rate {
            // Bit-flip after the CRC was declared: in-flight loss.
            let at = corrupt_rng.below(up.payload.len() as u64) as usize;
            up.payload[at] ^= 0x40;
        }
        out.push(up);
    }
}

/// Worst per-partition lag right now (the tail-lag sample).
fn worst_lag(gw: &IngestGateway) -> u64 {
    (0..gw.log.partitions()).map(|p| gw.log.lag(p)).max().unwrap_or(0)
}

fn p99(mut samples: Vec<u64>) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    samples.sort_unstable();
    samples[(samples.len() - 1) * 99 / 100]
}

/// Finalize a fleet report with the run's tail-lag and loss numbers.
fn finish_report(gw: &IngestGateway, mut report: FleetReport, lag_samples: Vec<u64>) -> FleetReport {
    report.tail_lag_p99 = p99(lag_samples);
    report.lost_records = (0..gw.log.partitions()).map(|p| gw.log.lost_records(p)).sum();
    report
}

/// Drive a whole simulated fleet through the gateway: each vehicle
/// emits a telemetry batch on its cadence (plus periodic rosbag
/// chunks), in-flight corruption at `corrupt_rate`, and throttled or
/// backpressured uploads retried on later ticks.
///
/// The default path is event-driven and batched: a hierarchical
/// [`TimerWheel`] hands each tick exactly the vehicles due to emit,
/// and the whole tick's uploads go through one
/// [`IngestGateway::upload_batch`] admission pass. `cfg.baseline`
/// selects the original per-vehicle/per-upload control path; both
/// produce bit-identical admission outcomes on the same seed.
pub fn simulate_fleet(gw: &IngestGateway, cfg: &FleetConfig) -> Result<FleetReport> {
    if cfg.baseline {
        simulate_fleet_baseline(gw, cfg)
    } else {
        simulate_fleet_batched(gw, cfg)
    }
}

/// The pre-batching control path (`--baseline`): iterate every vehicle
/// every tick, admit uploads one at a time.
fn simulate_fleet_baseline(gw: &IngestGateway, cfg: &FleetConfig) -> Result<FleetReport> {
    let mut gens: Vec<DriveGen> =
        (0..cfg.vehicles).map(|v| DriveGen::new(v, cfg.seed)).collect();
    let cadences: Vec<u64> =
        (0..cfg.vehicles).map(|v| cadence_of(v, cfg.seed, cfg.cadence_max)).collect();
    let mut rng = Rng::new(cfg.seed ^ 0xF1EE_7000);
    let mut report = FleetReport::default();
    let mut pending: Vec<VehicleUpload> = Vec::new();
    let mut lag_samples = Vec::with_capacity(cfg.ticks);
    let mut emitted: Vec<VehicleUpload> = Vec::new();
    for tick in 0..cfg.ticks {
        gw.begin_tick();
        // Retry what earlier ticks bounced first.
        for up in std::mem::take(&mut pending) {
            admit(gw, up, &mut report, &mut pending)?;
        }
        for v in 0..cfg.vehicles {
            let cadence = cadences[v as usize];
            if (tick as u64 + 1) % cadence != 0 {
                continue;
            }
            emit_uploads(v, tick, cadence, &mut gens[v as usize], cfg, &mut rng, &mut emitted);
            for up in emitted.drain(..) {
                admit(gw, up, &mut report, &mut pending)?;
            }
        }
        lag_samples.push(worst_lag(gw));
    }
    report.stranded = pending.len() as u64;
    Ok(finish_report(gw, report, lag_samples))
}

/// The event-driven batched path: the timer wheel yields only the
/// vehicles due this tick, and the tick's uploads are admitted in one
/// batch.
fn simulate_fleet_batched(gw: &IngestGateway, cfg: &FleetConfig) -> Result<FleetReport> {
    let mut gens: Vec<DriveGen> =
        (0..cfg.vehicles).map(|v| DriveGen::new(v, cfg.seed)).collect();
    let cadences: Vec<u64> =
        (0..cfg.vehicles).map(|v| cadence_of(v, cfg.seed, cfg.cadence_max)).collect();
    let mut wheel = TimerWheel::new();
    for v in 0..cfg.vehicles {
        // First emission once a full cadence window has elapsed.
        wheel.schedule(v, cadences[v as usize] - 1);
    }
    let mut rng = Rng::new(cfg.seed ^ 0xF1EE_7000);
    let mut report = FleetReport::default();
    let mut pending: Vec<VehicleUpload> = Vec::new();
    let mut lag_samples = Vec::with_capacity(cfg.ticks);
    for tick in 0..cfg.ticks {
        gw.begin_tick();
        // Retries keep their arrival order ahead of this tick's
        // emissions, exactly like the baseline loop.
        let mut ups = std::mem::take(&mut pending);
        for v in wheel.advance() {
            let cadence = cadences[v as usize];
            emit_uploads(v, tick, cadence, &mut gens[v as usize], cfg, &mut rng, &mut ups);
            wheel.schedule(v, tick as u64 + cadence);
        }
        let outcomes = gw.upload_batch(&ups)?;
        for (up, adm) in ups.into_iter().zip(outcomes) {
            report.uploads += 1;
            match adm {
                Admission::Accepted { .. } => {
                    report.accepted += 1;
                    report.bytes_accepted += up.payload.len() as u64;
                }
                Admission::Backpressure => {
                    report.backpressured += 1;
                    pending.push(up);
                }
                Admission::Throttled => {
                    report.throttled += 1;
                    pending.push(up);
                }
                Admission::DeadLettered => report.dead_lettered += 1,
            }
        }
        lag_samples.push(worst_lag(gw));
    }
    report.stranded = pending.len() as u64;
    Ok(finish_report(gw, report, lag_samples))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::log::LogConfig;

    fn gateway(partitions: usize, rate: u32, max_lag: u64) -> IngestGateway {
        let log = PartitionedLog::temp(
            "gw",
            LogConfig {
                partitions,
                segment_bytes: 64 << 10,
                retention_bytes: 16 << 20,
                ..Default::default()
            },
        )
        .unwrap();
        IngestGateway::new(
            log,
            GatewayConfig { rate_per_tick: rate, max_lag },
            MetricsRegistry::new(),
        )
    }

    #[test]
    fn telemetry_roundtrips() {
        let t = Telemetry {
            vehicle: 42,
            ts_ns: 123_456_789,
            speed_mps: 13.5,
            accel_mps2: -7.25,
            disengaged: true,
            sensor_gap_ms: 612,
        };
        assert_eq!(Telemetry::from_bytes(&t.to_bytes()).unwrap(), t);
        let batch = vec![t; 7];
        let payload = encode_telemetry(&batch);
        assert_eq!(decode_telemetry(&payload).unwrap().unwrap(), batch);
        // A rosbag payload is "not telemetry", not an error.
        let bag = encode_bag(&[]);
        assert_eq!(decode_telemetry(&bag).unwrap(), None);
        // A mangled batch header is an error.
        let mut bad = encode_telemetry(&batch);
        bad.truncate(bad.len() - 3);
        assert!(decode_telemetry(&bad).is_err());
    }

    #[test]
    fn gen_drive_is_deterministic_with_events() {
        let a = gen_drive(3, 77, 1000);
        let b = gen_drive(3, 77, 1000);
        assert_eq!(a, b);
        assert_ne!(a, gen_drive(4, 77, 1000));
        assert!(a.iter().any(|t| t.accel_mps2 <= -6.0), "drive must contain hard brakes");
        assert!(a.iter().any(|t| t.disengaged), "drive must contain disengagements");
        assert!(a.iter().any(|t| t.sensor_gap_ms >= 500), "drive must contain dropouts");
    }

    #[test]
    fn clean_upload_accepted_into_routed_partition() {
        let gw = gateway(4, 8, 1000);
        let up = VehicleUpload::new(9, 0, encode_telemetry(&gen_drive(9, 1, 4)));
        match gw.upload(&up).unwrap() {
            Admission::Accepted { partition, offset } => {
                assert_eq!(partition, gw.log().partition_for(9));
                assert_eq!(offset, 0);
            }
            other => panic!("expected acceptance, got {other:?}"),
        }
        let recs = gw.log().read_from(gw.log().partition_for(9), 0, 10).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].source, 9);
    }

    #[test]
    fn rate_limit_throttles_then_refills() {
        let gw = gateway(1, 2, 1000);
        let up = VehicleUpload::new(1, 0, b"x".to_vec());
        assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
        assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
        assert_eq!(gw.upload(&up).unwrap(), Admission::Throttled);
        // Another vehicle has its own bucket.
        let other = VehicleUpload::new(2, 0, b"y".to_vec());
        assert!(matches!(gw.upload(&other).unwrap(), Admission::Accepted { .. }));
        gw.begin_tick();
        assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
    }

    #[test]
    fn corrupt_upload_goes_to_dead_letter_not_log() {
        let gw = gateway(1, 8, 1000);
        let mut up = VehicleUpload::new(5, 7, encode_telemetry(&gen_drive(5, 1, 2)));
        up.payload[10] ^= 0xFF;
        assert_eq!(gw.upload(&up).unwrap(), Admission::DeadLettered);
        let dead = gw.dead_letters();
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].vehicle, 5);
        assert!(dead[0].reason.contains("CRC"));
        assert_eq!(gw.log().next_offset(0), 0, "corrupt payload must not reach the log");
        assert_eq!(gw.m.dlq_depth.get(), 1, "DLQ depth gauge must track the queue");
    }

    #[test]
    fn backpressure_when_partition_lags_and_clears_on_commit() {
        let gw = gateway(1, 100, 3);
        let up = VehicleUpload::new(1, 0, b"t".to_vec());
        for _ in 0..3 {
            assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
        }
        assert_eq!(gw.upload(&up).unwrap(), Admission::Backpressure);
        assert_eq!(gw.m.partition_lag.get(), 3, "lag gauge must reflect the probed partition");
        // A consumer draining the partition releases the pressure.
        gw.log().commit(0, 3).unwrap();
        assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
        assert!(gw.m.partition_lag.get() <= 1, "lag gauge must fall once the log is drained");
    }

    #[test]
    fn simulated_fleet_is_deterministic() {
        let run = |tag: &str| {
            let log = PartitionedLog::temp(tag, LogConfig::default()).unwrap();
            let gw = IngestGateway::new(log, GatewayConfig::default(), MetricsRegistry::new());
            let mut cfg = FleetConfig::new(6, 40, 99);
            cfg.corrupt_rate = 0.05;
            let report = simulate_fleet(&gw, &cfg).unwrap();
            (report.accepted, report.dead_lettered, gw.log().next_offset(0))
        };
        assert_eq!(run("fa"), run("fb"));
        let (accepted, dead, _) = run("fc");
        assert!(accepted > 0);
        assert!(dead > 0, "5% corruption over 240+ uploads must dead-letter some");
    }

    #[test]
    fn drive_gen_streams_the_same_samples_as_gen_drive() {
        let mut gen = DriveGen::new(11, 1234);
        let all = gen_drive(11, 1234, 200);
        let streamed: Vec<Telemetry> = (0..200).map(|_| gen.next_sample()).collect();
        assert_eq!(streamed, all, "incremental and batch generation must be bit-identical");
    }

    #[test]
    fn timer_wheel_fires_every_vehicle_exactly_on_cadence() {
        // Cadences spanning level 0, level 1, and the overflow list.
        let cadences: [(u32, u64); 6] = [(0, 1), (1, 3), (2, 63), (3, 64), (4, 700), (5, 5000)];
        let mut wheel = TimerWheel::new();
        for &(v, c) in &cadences {
            wheel.schedule(v, c - 1);
        }
        let mut fired: HashMap<u32, Vec<u64>> = HashMap::new();
        for tick in 0..12_000u64 {
            for v in wheel.advance() {
                fired.entry(v).or_default().push(tick);
                let c = cadences[v as usize].1;
                wheel.schedule(v, tick + c);
            }
        }
        for &(v, c) in &cadences {
            let want: Vec<u64> = (0..12_000 / c).map(|k| (k + 1) * c - 1).collect();
            assert_eq!(fired[&v], want, "vehicle {v} with cadence {c} misfired");
        }
    }

    #[test]
    fn timer_wheel_drains_due_vehicles_in_ascending_order() {
        let mut wheel = TimerWheel::new();
        for v in [9u32, 2, 40, 0, 17] {
            wheel.schedule(v, 0);
        }
        assert_eq!(wheel.advance(), vec![0, 2, 9, 17, 40]);
        assert!(wheel.advance().is_empty());
        assert_eq!(wheel.now(), 2);
    }

    #[test]
    fn corrupt_upload_in_batch_dead_letters_only_that_frame() {
        let gw = gateway(1, 8, 1000);
        let mut ups: Vec<VehicleUpload> = (0..5u32)
            .map(|v| VehicleUpload::new(v, 0, encode_telemetry(&gen_drive(v, 1, 3))))
            .collect();
        ups[2].payload[9] ^= 0xFF;
        let out = gw.upload_batch(&ups).unwrap();
        assert_eq!(out[2], Admission::DeadLettered);
        let mut offsets = Vec::new();
        for (i, adm) in out.iter().enumerate() {
            if i == 2 {
                continue;
            }
            match adm {
                Admission::Accepted { offset, .. } => offsets.push(*offset),
                other => panic!("upload {i} should have landed, got {other:?}"),
            }
        }
        assert_eq!(offsets, vec![0, 1, 2, 3], "clean frames must land contiguously");
        let dead = gw.dead_letters();
        assert_eq!(dead.len(), 1, "only the corrupt frame goes to the DLQ");
        assert_eq!(dead[0].vehicle, 2);
        assert_eq!(gw.log().next_offset(0), 4);
    }

    #[test]
    fn upload_batch_matches_sequential_uploads_decision_for_decision() {
        // Throttling, backpressure, CRC failures, and multi-partition
        // routing in one stream — batched admission must reproduce the
        // sequential path's outcome for every single upload.
        let mk_ups = || {
            let mut rng = Rng::new(7);
            let mut ups = Vec::new();
            for i in 0..120u32 {
                let v = i % 9;
                let mut up =
                    VehicleUpload::new(v, i as u64, encode_telemetry(&gen_drive(v, 2, 2)));
                if rng.next_f64() < 0.1 {
                    up.payload[5] ^= 0x08;
                }
                ups.push(up);
            }
            ups
        };
        let (a, b) = (gateway(4, 3, 18), gateway(4, 3, 18));
        let seq: Vec<Admission> = mk_ups().iter().map(|up| a.upload(up).unwrap()).collect();
        let bat = b.upload_batch(&mk_ups()).unwrap();
        assert_eq!(bat, seq);
        let (da, db) = (a.dead_letters(), b.dead_letters());
        assert_eq!(da.len(), db.len());
        for (x, y) in da.iter().zip(&db) {
            assert_eq!((x.vehicle, x.ts_ns, x.bytes), (y.vehicle, y.ts_ns, y.bytes));
        }
        for p in 0..4 {
            assert_eq!(a.log().next_offset(p), b.log().next_offset(p));
        }
    }

    #[test]
    fn batched_fleet_is_bit_identical_to_the_baseline_path() {
        // The tentpole acceptance gate: same seeded fleet, same
        // accept/reject/DLQ outcomes, same log contents — only faster.
        let run = |tag: &str, baseline: bool| {
            let log = PartitionedLog::temp(tag, LogConfig::default()).unwrap();
            let gw = IngestGateway::new(
                log,
                GatewayConfig { rate_per_tick: 2, max_lag: 30 },
                MetricsRegistry::new(),
            );
            let mut cfg = FleetConfig::new(7, 50, 424_242);
            cfg.corrupt_rate = 0.05;
            cfg.cadence_max = 3;
            cfg.baseline = baseline;
            let report = simulate_fleet(&gw, &cfg).unwrap();
            let offsets: Vec<u64> =
                (0..gw.log().partitions()).map(|p| gw.log().next_offset(p)).collect();
            let dead: Vec<(u32, u64, usize)> =
                gw.dead_letters().iter().map(|d| (d.vehicle, d.ts_ns, d.bytes)).collect();
            (report, offsets, dead)
        };
        let base = run("eqb", true);
        let batched = run("eqf", false);
        assert_eq!(batched.0, base.0, "fleet reports diverge");
        assert_eq!(batched.1, base.1, "per-partition heads diverge");
        assert_eq!(batched.2, base.2, "dead-letter queues diverge");
        assert!(base.0.throttled > 0, "fleet must exercise throttling");
        assert!(base.0.dead_lettered > 0, "fleet must exercise the DLQ");
        assert!(base.0.backpressured > 0, "fleet must exercise backpressure");
    }
}
