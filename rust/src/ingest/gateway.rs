//! The ingest gateway: the fleet's front door into the platform.
//!
//! Simulated vehicles upload telemetry batches and rosbag chunks. The
//! gateway admits, throttles, or rejects each upload:
//!
//! * **rate limiting** — a per-vehicle token bucket refilled each tick;
//! * **backpressure** — uploads bounce when the target partition's lag
//!   (appended minus compacted offsets) exceeds the configured bound,
//!   so a stalled compactor propagates pressure back to the fleet
//!   instead of filling the log;
//! * **dead-letter handling** — uploads whose payload fails its
//!   declared CRC are captured in a dead-letter queue with a reason,
//!   never appended to the clean log.
//!
//! Everything is seed-deterministic: [`gen_drive`] produces each
//! vehicle's telemetry (with plantable hard-brake / disengagement /
//! sensor-dropout episodes the miner later digs out), and
//! [`simulate_fleet`] replays a whole fleet against the gateway.

use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::log::{crc32, PartitionedLog};
use crate::metrics::{GatewayMetrics, MetricsRegistry};
use crate::services::simulation::{encode_bag, Message};
use crate::trace;
use crate::util::Rng;

/// Magic prefix of an encoded telemetry batch payload (rosbag chunks
/// carry the bag codec's own `ADBG` magic instead).
pub const TELEMETRY_MAGIC: &[u8; 4] = b"ADTL";

/// One telemetry sample from a vehicle's CAN/sensor bus.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Telemetry {
    pub vehicle: u32,
    pub ts_ns: u64,
    pub speed_mps: f32,
    pub accel_mps2: f32,
    /// Safety driver took over at this tick.
    pub disengaged: bool,
    /// Milliseconds since the last camera frame (0 = nominal cadence).
    pub sensor_gap_ms: u32,
}

/// Fixed wire size of one sample.
pub const TELEMETRY_BYTES: usize = 25;

impl Telemetry {
    pub fn to_bytes(&self) -> [u8; TELEMETRY_BYTES] {
        let mut out = [0u8; TELEMETRY_BYTES];
        out[0..4].copy_from_slice(&self.vehicle.to_le_bytes());
        out[4..12].copy_from_slice(&self.ts_ns.to_le_bytes());
        out[12..16].copy_from_slice(&self.speed_mps.to_le_bytes());
        out[16..20].copy_from_slice(&self.accel_mps2.to_le_bytes());
        out[20] = self.disengaged as u8;
        out[21..25].copy_from_slice(&self.sensor_gap_ms.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        anyhow::ensure!(
            bytes.len() == TELEMETRY_BYTES,
            "telemetry sample is {} bytes, want {TELEMETRY_BYTES}",
            bytes.len()
        );
        Ok(Self {
            vehicle: u32::from_le_bytes(bytes[0..4].try_into().unwrap()),
            ts_ns: u64::from_le_bytes(bytes[4..12].try_into().unwrap()),
            speed_mps: f32::from_le_bytes(bytes[12..16].try_into().unwrap()),
            accel_mps2: f32::from_le_bytes(bytes[16..20].try_into().unwrap()),
            disengaged: bytes[20] != 0,
            sensor_gap_ms: u32::from_le_bytes(bytes[21..25].try_into().unwrap()),
        })
    }
}

/// Encode a batch of samples as one upload payload.
pub fn encode_telemetry(samples: &[Telemetry]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + samples.len() * TELEMETRY_BYTES);
    out.extend_from_slice(TELEMETRY_MAGIC);
    out.extend_from_slice(&(samples.len() as u32).to_le_bytes());
    for s in samples {
        out.extend_from_slice(&s.to_bytes());
    }
    out
}

/// Decode a telemetry batch payload. `Ok(None)` when the payload is a
/// different kind (e.g. a rosbag chunk) — not an error, just not ours.
pub fn decode_telemetry(payload: &[u8]) -> Result<Option<Vec<Telemetry>>> {
    if payload.len() < 8 || &payload[..4] != TELEMETRY_MAGIC {
        return Ok(None);
    }
    let count = u32::from_le_bytes(payload[4..8].try_into().unwrap()) as usize;
    anyhow::ensure!(
        payload.len() == 8 + count * TELEMETRY_BYTES,
        "telemetry batch claims {count} samples in {} bytes",
        payload.len()
    );
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let at = 8 + i * TELEMETRY_BYTES;
        out.push(Telemetry::from_bytes(&payload[at..at + TELEMETRY_BYTES])?);
    }
    Ok(Some(out))
}

/// Deterministic per-vehicle drive: a speed random walk with plantable
/// hard-brake episodes, disengagements, and sensor dropouts — the raw
/// material [`super::mine`] later turns into scenario families.
pub fn gen_drive(vehicle: u32, seed: u64, ticks: usize) -> Vec<Telemetry> {
    let mut rng = Rng::new(seed ^ (vehicle as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut speed = rng.range_f64(8.0, 20.0) as f32;
    let mut brake_left = 0usize;
    let mut out = Vec::with_capacity(ticks);
    for t in 0..ticks {
        let mut accel = rng.normal_f32(0.0, 0.6);
        if brake_left > 0 {
            brake_left -= 1;
            accel = -7.5 + rng.normal_f32(0.0, 0.3);
        } else if rng.next_f64() < 0.01 {
            brake_left = 2;
            accel = -7.5;
        }
        let disengaged = rng.next_f64() < 0.004;
        let sensor_gap_ms = if rng.next_f64() < 0.006 { 400 + rng.below(800) as u32 } else { 0 };
        speed = (speed + accel * 0.1).clamp(0.0, 33.0);
        out.push(Telemetry {
            vehicle,
            ts_ns: t as u64 * 100_000_000,
            speed_mps: speed,
            accel_mps2: accel,
            disengaged,
            sensor_gap_ms,
        });
    }
    out
}

/// One upload as it arrives at the gateway. `declared_crc` is what the
/// vehicle computed before transmission; a mismatch against the
/// received payload means in-flight corruption.
#[derive(Debug, Clone)]
pub struct VehicleUpload {
    pub vehicle: u32,
    pub ts_ns: u64,
    pub payload: Vec<u8>,
    pub declared_crc: u32,
}

impl VehicleUpload {
    /// A well-formed upload (CRC computed over the payload as-is).
    pub fn new(vehicle: u32, ts_ns: u64, payload: Vec<u8>) -> Self {
        let declared_crc = crc32(&payload);
        Self { vehicle, ts_ns, payload, declared_crc }
    }
}

/// What the gateway decided about one upload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Admission {
    Accepted { partition: usize, offset: u64 },
    /// Vehicle exceeded its per-tick rate; retry next tick.
    Throttled,
    /// Target partition's lag exceeds the bound; retry after compaction.
    Backpressure,
    /// Payload failed its CRC; captured in the dead-letter queue.
    DeadLettered,
}

/// A rejected-as-corrupt upload plus why.
#[derive(Debug, Clone)]
pub struct DeadLetter {
    pub vehicle: u32,
    pub ts_ns: u64,
    pub reason: String,
    pub bytes: usize,
}

/// Gateway admission knobs.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Uploads each vehicle may land per tick.
    pub rate_per_tick: u32,
    /// Backpressure once a partition's lag reaches this many records.
    pub max_lag: u64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self { rate_per_tick: 4, max_lag: 100_000 }
    }
}

/// The ingest gateway over a [`PartitionedLog`].
pub struct IngestGateway {
    log: Arc<PartitionedLog>,
    cfg: GatewayConfig,
    tokens: Mutex<HashMap<u32, u32>>,
    dead: Mutex<Vec<DeadLetter>>,
    /// Admission counters resolved once — one decision per upload.
    m: GatewayMetrics,
}

impl IngestGateway {
    pub fn new(log: Arc<PartitionedLog>, cfg: GatewayConfig, metrics: MetricsRegistry) -> Self {
        Self {
            log,
            cfg,
            tokens: Mutex::new(HashMap::new()),
            dead: Mutex::new(Vec::new()),
            m: GatewayMetrics::new(&metrics),
        }
    }

    pub fn log(&self) -> &Arc<PartitionedLog> {
        &self.log
    }

    /// Refill every vehicle's token bucket (call once per fleet tick).
    pub fn begin_tick(&self) {
        self.tokens.lock().unwrap().clear();
    }

    /// Admit one upload.
    pub fn upload(&self, up: &VehicleUpload) -> Result<Admission> {
        let mut sp = trace::span("gateway.upload", trace::Category::LogIo);
        sp.arg("vehicle", up.vehicle as u64).arg("bytes", up.payload.len() as u64);
        {
            let mut tokens = self.tokens.lock().unwrap();
            let t = tokens.entry(up.vehicle).or_insert(self.cfg.rate_per_tick);
            if *t == 0 {
                self.m.throttled.inc();
                return Ok(Admission::Throttled);
            }
            *t -= 1;
        }
        if crc32(&up.payload) != up.declared_crc {
            self.m.dead_lettered.inc();
            let mut dead = self.dead.lock().unwrap();
            dead.push(DeadLetter {
                vehicle: up.vehicle,
                ts_ns: up.ts_ns,
                reason: "payload CRC mismatch".into(),
                bytes: up.payload.len(),
            });
            self.m.dlq_depth.set(dead.len() as u64);
            return Ok(Admission::DeadLettered);
        }
        let partition = self.log.partition_for(up.vehicle);
        let lag = self.log.lag(partition);
        // Worst-partition lag feeds the ingest-backlog watchdog; each
        // admission decision refreshes it for the partition it probed.
        if lag >= self.m.partition_lag.get() || partition == 0 {
            self.m.partition_lag.set(lag);
        }
        if lag >= self.cfg.max_lag {
            self.m.backpressured.inc();
            return Ok(Admission::Backpressure);
        }
        let offset = self.log.append(partition, up.ts_ns, up.vehicle, &up.payload)?;
        self.m.accepted.inc();
        Ok(Admission::Accepted { partition, offset })
    }

    pub fn dead_letters(&self) -> Vec<DeadLetter> {
        self.dead.lock().unwrap().clone()
    }
}

/// Fleet-simulation knobs.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub vehicles: u32,
    pub ticks: usize,
    pub seed: u64,
    /// Fraction of uploads corrupted in flight (exercises dead-letter).
    pub corrupt_rate: f64,
    /// Every this many ticks a vehicle also uploads a rosbag chunk.
    pub bag_every: usize,
}

impl FleetConfig {
    pub fn new(vehicles: u32, ticks: usize, seed: u64) -> Self {
        Self { vehicles, ticks, seed, corrupt_rate: 0.0, bag_every: 16 }
    }
}

/// Aggregate outcome of one simulated fleet run.
#[derive(Debug, Clone, Default)]
pub struct FleetReport {
    pub uploads: u64,
    pub accepted: u64,
    pub throttled: u64,
    pub backpressured: u64,
    pub dead_lettered: u64,
    pub bytes_accepted: u64,
    /// Uploads still waiting on backpressure when the run ended.
    pub stranded: u64,
}

impl FleetReport {
    pub fn render(&self) -> String {
        format!(
            "fleet: {} uploads — {} accepted ({}), {} throttled, {} backpressured, \
             {} dead-lettered, {} stranded",
            self.uploads,
            self.accepted,
            crate::util::fmt_bytes(self.bytes_accepted),
            self.throttled,
            self.backpressured,
            self.dead_lettered,
            self.stranded,
        )
    }
}

/// One admission attempt: tally the outcome, re-queue throttled and
/// backpressured uploads for a later tick.
fn admit(
    gw: &IngestGateway,
    up: VehicleUpload,
    report: &mut FleetReport,
    pending: &mut Vec<VehicleUpload>,
) -> Result<()> {
    report.uploads += 1;
    match gw.upload(&up)? {
        Admission::Accepted { .. } => {
            report.accepted += 1;
            report.bytes_accepted += up.payload.len() as u64;
        }
        Admission::Backpressure => {
            report.backpressured += 1;
            pending.push(up);
        }
        Admission::Throttled => {
            report.throttled += 1;
            pending.push(up);
        }
        Admission::DeadLettered => report.dead_lettered += 1,
    }
    Ok(())
}

/// Drive a whole simulated fleet through the gateway: one telemetry
/// batch per vehicle per tick (plus periodic rosbag chunks), in-flight
/// corruption at `corrupt_rate`, and backpressured uploads retried on
/// later ticks.
pub fn simulate_fleet(gw: &IngestGateway, cfg: &FleetConfig) -> Result<FleetReport> {
    let drives: Vec<Vec<Telemetry>> =
        (0..cfg.vehicles).map(|v| gen_drive(v, cfg.seed, cfg.ticks)).collect();
    let mut rng = Rng::new(cfg.seed ^ 0xF1EE_7000);
    let mut report = FleetReport::default();
    let mut pending: Vec<VehicleUpload> = Vec::new();
    for tick in 0..cfg.ticks {
        gw.begin_tick();
        // Retry what earlier ticks bounced first.
        for up in std::mem::take(&mut pending) {
            admit(gw, up, &mut report, &mut pending)?;
        }
        for v in 0..cfg.vehicles {
            let mut payloads = vec![encode_telemetry(&drives[v as usize][tick..tick + 1])];
            if cfg.bag_every > 0 && tick % cfg.bag_every == cfg.bag_every - 1 {
                payloads.push(encode_bag(&[Message {
                    topic: "/camera/front".into(),
                    ts_ns: tick as u64 * 100_000_000,
                    payload: vec![(tick % 256) as u8; 128],
                }]));
            }
            for payload in payloads {
                let mut up = VehicleUpload::new(v, tick as u64 * 100_000_000, payload);
                if rng.next_f64() < cfg.corrupt_rate {
                    // Bit-flip after the CRC was declared: in-flight loss.
                    let at = rng.below(up.payload.len() as u64) as usize;
                    up.payload[at] ^= 0x40;
                }
                admit(gw, up, &mut report, &mut pending)?;
            }
        }
    }
    report.stranded = pending.len() as u64;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::log::LogConfig;

    fn gateway(partitions: usize, rate: u32, max_lag: u64) -> IngestGateway {
        let log = PartitionedLog::temp(
            "gw",
            LogConfig { partitions, segment_bytes: 64 << 10, retention_bytes: 16 << 20 },
        )
        .unwrap();
        IngestGateway::new(
            log,
            GatewayConfig { rate_per_tick: rate, max_lag },
            MetricsRegistry::new(),
        )
    }

    #[test]
    fn telemetry_roundtrips() {
        let t = Telemetry {
            vehicle: 42,
            ts_ns: 123_456_789,
            speed_mps: 13.5,
            accel_mps2: -7.25,
            disengaged: true,
            sensor_gap_ms: 612,
        };
        assert_eq!(Telemetry::from_bytes(&t.to_bytes()).unwrap(), t);
        let batch = vec![t; 7];
        let payload = encode_telemetry(&batch);
        assert_eq!(decode_telemetry(&payload).unwrap().unwrap(), batch);
        // A rosbag payload is "not telemetry", not an error.
        let bag = encode_bag(&[]);
        assert_eq!(decode_telemetry(&bag).unwrap(), None);
        // A mangled batch header is an error.
        let mut bad = encode_telemetry(&batch);
        bad.truncate(bad.len() - 3);
        assert!(decode_telemetry(&bad).is_err());
    }

    #[test]
    fn gen_drive_is_deterministic_with_events() {
        let a = gen_drive(3, 77, 1000);
        let b = gen_drive(3, 77, 1000);
        assert_eq!(a, b);
        assert_ne!(a, gen_drive(4, 77, 1000));
        assert!(a.iter().any(|t| t.accel_mps2 <= -6.0), "drive must contain hard brakes");
        assert!(a.iter().any(|t| t.disengaged), "drive must contain disengagements");
        assert!(a.iter().any(|t| t.sensor_gap_ms >= 500), "drive must contain dropouts");
    }

    #[test]
    fn clean_upload_accepted_into_routed_partition() {
        let gw = gateway(4, 8, 1000);
        let up = VehicleUpload::new(9, 0, encode_telemetry(&gen_drive(9, 1, 4)));
        match gw.upload(&up).unwrap() {
            Admission::Accepted { partition, offset } => {
                assert_eq!(partition, gw.log().partition_for(9));
                assert_eq!(offset, 0);
            }
            other => panic!("expected acceptance, got {other:?}"),
        }
        let recs = gw.log().read_from(gw.log().partition_for(9), 0, 10).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].source, 9);
    }

    #[test]
    fn rate_limit_throttles_then_refills() {
        let gw = gateway(1, 2, 1000);
        let up = VehicleUpload::new(1, 0, b"x".to_vec());
        assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
        assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
        assert_eq!(gw.upload(&up).unwrap(), Admission::Throttled);
        // Another vehicle has its own bucket.
        let other = VehicleUpload::new(2, 0, b"y".to_vec());
        assert!(matches!(gw.upload(&other).unwrap(), Admission::Accepted { .. }));
        gw.begin_tick();
        assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
    }

    #[test]
    fn corrupt_upload_goes_to_dead_letter_not_log() {
        let gw = gateway(1, 8, 1000);
        let mut up = VehicleUpload::new(5, 7, encode_telemetry(&gen_drive(5, 1, 2)));
        up.payload[10] ^= 0xFF;
        assert_eq!(gw.upload(&up).unwrap(), Admission::DeadLettered);
        let dead = gw.dead_letters();
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].vehicle, 5);
        assert!(dead[0].reason.contains("CRC"));
        assert_eq!(gw.log().next_offset(0), 0, "corrupt payload must not reach the log");
        assert_eq!(gw.m.dlq_depth.get(), 1, "DLQ depth gauge must track the queue");
    }

    #[test]
    fn backpressure_when_partition_lags_and_clears_on_commit() {
        let gw = gateway(1, 100, 3);
        let up = VehicleUpload::new(1, 0, b"t".to_vec());
        for _ in 0..3 {
            assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
        }
        assert_eq!(gw.upload(&up).unwrap(), Admission::Backpressure);
        assert_eq!(gw.m.partition_lag.get(), 3, "lag gauge must reflect the probed partition");
        // A consumer draining the partition releases the pressure.
        gw.log().commit(0, 3).unwrap();
        assert!(matches!(gw.upload(&up).unwrap(), Admission::Accepted { .. }));
        assert!(gw.m.partition_lag.get() <= 1, "lag gauge must fall once the log is drained");
    }

    #[test]
    fn simulated_fleet_is_deterministic() {
        let run = |tag: &str| {
            let log = PartitionedLog::temp(tag, LogConfig::default()).unwrap();
            let gw = IngestGateway::new(log, GatewayConfig::default(), MetricsRegistry::new());
            let mut cfg = FleetConfig::new(6, 40, 99);
            cfg.corrupt_rate = 0.05;
            let report = simulate_fleet(&gw, &cfg).unwrap();
            (report.accepted, report.dead_lettered, gw.log().next_offset(0))
        };
        assert_eq!(run("fa"), run("fb"));
        let (accepted, dead, _) = run("fc");
        assert!(accepted > 0);
        assert!(dead > 0, "5% corruption over 240+ uploads must dead-letter some");
    }
}
