//! The durable partitioned telemetry log (Kafka analog).
//!
//! Each partition is a sequence of append-only segment files on real
//! disk. Records are offset-addressed (dense, per-partition), framed as
//! `u32 body_len | body | u32 crc32(body)` with
//! `body = u64 offset | u64 ts_ns | u32 source | payload`, so a
//! bit-flip anywhere in a frame is detected at read time. Segments roll
//! at a configured size and the oldest sealed segments are truncated
//! once a partition exceeds its retention budget — reads below the
//! retained start offset fail loudly rather than returning a gap.
//!
//! Consumers (the [`super::compact`] workers) track progress through a
//! per-partition committed offset stored on the log; `next - committed`
//! is the lag the gateway's backpressure check watches.

use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::Write;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::metrics::{LogMetrics, MetricsRegistry};
use crate::scenario::fnv1a64;
use crate::trace;

/// IEEE CRC-32 lookup tables for slicing-by-8, built at compile time.
/// `TABLES[0]` is the classic byte-at-a-time table; `TABLES[k][b]` is
/// the CRC of byte `b` followed by `k` zero bytes, which lets one table
/// lookup per input byte absorb eight bytes per iteration.
const fn crc32_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[k - 1][i];
            t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        k += 1;
    }
    t
}

static CRC_TABLES: [[u32; 256]; 8] = crc32_tables();

/// IEEE CRC-32 (the record-integrity check on every log frame),
/// slicing-by-8: eight table lookups fold eight input bytes per
/// iteration instead of one, ~4-6x the byte-at-a-time throughput on
/// the append path. Bit-identical to [`crc32_bytewise`].
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        let lo = crc ^ u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        let hi = u32::from_le_bytes([c[4], c[5], c[6], c[7]]);
        crc = CRC_TABLES[7][(lo & 0xFF) as usize]
            ^ CRC_TABLES[6][((lo >> 8) & 0xFF) as usize]
            ^ CRC_TABLES[5][((lo >> 16) & 0xFF) as usize]
            ^ CRC_TABLES[4][(lo >> 24) as usize]
            ^ CRC_TABLES[3][(hi & 0xFF) as usize]
            ^ CRC_TABLES[2][((hi >> 8) & 0xFF) as usize]
            ^ CRC_TABLES[1][((hi >> 16) & 0xFF) as usize]
            ^ CRC_TABLES[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        crc = (crc >> 8) ^ CRC_TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// The reference byte-at-a-time implementation, kept as the oracle the
/// sliced version is tested against.
pub fn crc32_bytewise(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC_TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// Sizing and retention knobs for one log instance.
#[derive(Debug, Clone)]
pub struct LogConfig {
    /// Number of partitions (the unit of ingest/compaction parallelism).
    pub partitions: usize,
    /// Roll the active segment once it reaches this many bytes.
    pub segment_bytes: u64,
    /// Per-partition retention budget; oldest sealed segments are
    /// dropped while a partition holds more than this.
    pub retention_bytes: u64,
    /// Group-commit staging budget: [`PartitionedLog::append_batch`]
    /// accumulates frames in memory and issues one write per this many
    /// staged bytes (or per segment roll, whichever comes first).
    pub batch_bytes: usize,
    /// Sync the active segment to disk every this many appended
    /// records; 0 leaves flushing to the OS page cache (the default,
    /// and the only behavior before group commit existed).
    pub flush_interval: u64,
}

impl Default for LogConfig {
    fn default() -> Self {
        Self {
            partitions: 4,
            segment_bytes: 256 << 10,
            retention_bytes: 64 << 20,
            batch_bytes: 256 << 10,
            flush_interval: 0,
        }
    }
}

/// One decoded log record.
#[derive(Debug, Clone, PartialEq)]
pub struct LogRecord {
    /// Dense per-partition offset.
    pub offset: u64,
    pub ts_ns: u64,
    /// Producer id (vehicle id for fleet ingest).
    pub source: u32,
    pub payload: Vec<u8>,
}

/// One record of a group-commit batch. The payload is borrowed — the
/// point of [`PartitionedLog::append_batch`] is that nothing is copied
/// per record until it is framed straight into the staging buffer.
#[derive(Debug, Clone, Copy)]
pub struct AppendRecord<'a> {
    pub ts_ns: u64,
    pub source: u32,
    pub payload: &'a [u8],
}

/// A zero-copy view of one log frame: the payload borrows the segment
/// buffer the whole read batch shares instead of being copied into a
/// per-record `Vec` (the compactor's hot path).
#[derive(Debug, Clone, Copy)]
pub struct FrameRef<'a> {
    pub offset: u64,
    pub ts_ns: u64,
    pub source: u32,
    pub payload: &'a [u8],
}

/// Frame header (body length) + trailing CRC.
const FRAME_OVERHEAD: u64 = 8;
/// Fixed body bytes before the payload.
const BODY_HEADER: usize = 20;

struct Segment {
    base_offset: u64,
    path: PathBuf,
    bytes: u64,
    records: u64,
}

struct PartState {
    dir: PathBuf,
    /// Sealed segments plus (last) the active one.
    segments: Vec<Segment>,
    /// Open handle for the active segment, if any.
    writer: Option<File>,
    next_offset: u64,
    /// First offset still retained (advances on truncation).
    start_offset: u64,
    /// Consumer progress (exclusive upper bound of consumed offsets).
    committed: u64,
    bytes_total: u64,
    /// Records truncated by retention before any consumer read them.
    lost_records: u64,
    /// Records appended since the last `flush_interval` sync.
    unsynced: u64,
}

/// The partitioned, segmented, CRC-checked append-only log.
pub struct PartitionedLog {
    cfg: LogConfig,
    root: PathBuf,
    parts: Vec<Mutex<PartState>>,
    metrics: MetricsRegistry,
    /// Handles resolved once: the append path must not pay the
    /// registry lock + name allocation per record.
    m: LogMetrics,
}

impl PartitionedLog {
    pub fn create(
        root: impl Into<PathBuf>,
        cfg: LogConfig,
        metrics: MetricsRegistry,
    ) -> Result<Arc<Self>> {
        anyhow::ensure!(cfg.partitions >= 1, "log needs at least one partition");
        anyhow::ensure!(cfg.segment_bytes > 0, "segment_bytes must be positive");
        let root = root.into();
        let mut parts = Vec::with_capacity(cfg.partitions);
        for p in 0..cfg.partitions {
            let dir = root.join(format!("partition-{p:03}"));
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating log partition dir {dir:?}"))?;
            parts.push(Mutex::new(PartState {
                dir,
                segments: Vec::new(),
                writer: None,
                next_offset: 0,
                start_offset: 0,
                committed: 0,
                bytes_total: 0,
                lost_records: 0,
                unsynced: 0,
            }));
        }
        Ok(Arc::new(Self { cfg, root, parts, m: LogMetrics::new(&metrics), metrics }))
    }

    /// Re-open an existing log root, rebuilding partition state from the
    /// segment files on disk (crash recovery). Every segment but the
    /// last in a partition must decode cleanly; the *last* one is
    /// scanned tolerantly — a tail torn by a crash mid group-commit is
    /// truncated back to the final whole frame, so every fully-committed
    /// frame survives and only the torn bytes are dropped. Recovered
    /// tail segments are sealed (appends continue in a fresh segment at
    /// the recovered head offset). Consumer offsets live in memory only,
    /// so `committed` restarts at the retained start — the compactor
    /// re-reads, never loses.
    pub fn open(
        root: impl Into<PathBuf>,
        cfg: LogConfig,
        metrics: MetricsRegistry,
    ) -> Result<Arc<Self>> {
        anyhow::ensure!(cfg.partitions >= 1, "log needs at least one partition");
        anyhow::ensure!(cfg.segment_bytes > 0, "segment_bytes must be positive");
        let root = root.into();
        let m = LogMetrics::new(&metrics);
        let mut parts = Vec::with_capacity(cfg.partitions);
        for p in 0..cfg.partitions {
            let dir = root.join(format!("partition-{p:03}"));
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating log partition dir {dir:?}"))?;
            let mut found: Vec<(u64, PathBuf)> = Vec::new();
            for entry in
                std::fs::read_dir(&dir).with_context(|| format!("listing {dir:?}"))?
            {
                let path = entry?.path();
                let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
                if let Some(base) =
                    name.strip_prefix("seg-").and_then(|s| s.strip_suffix(".log"))
                {
                    let base: u64 =
                        base.parse().with_context(|| format!("segment name {name}"))?;
                    found.push((base, path));
                }
            }
            found.sort();
            let mut segments = Vec::new();
            for (i, (base, path)) in found.iter().enumerate() {
                let bytes =
                    std::fs::read(path).with_context(|| format!("reading segment {path:?}"))?;
                let tolerant = i + 1 == found.len();
                let (records, good_bytes) = scan_segment(&bytes, *base, tolerant)
                    .with_context(|| format!("recovering segment {path:?}"))?;
                if good_bytes < bytes.len() as u64 {
                    let f = std::fs::OpenOptions::new()
                        .write(true)
                        .open(path)
                        .with_context(|| format!("truncating torn segment {path:?}"))?;
                    f.set_len(good_bytes)?;
                    m.torn_tail_bytes.add(bytes.len() as u64 - good_bytes);
                }
                if records == 0 {
                    // Nothing recovered: remove the husk so the next
                    // append can re-create a segment at this offset.
                    let _ = std::fs::remove_file(path);
                    continue;
                }
                segments.push(Segment {
                    base_offset: *base,
                    path: path.clone(),
                    bytes: good_bytes,
                    records,
                });
            }
            let start_offset = segments.first().map(|s| s.base_offset).unwrap_or(0);
            let next_offset =
                segments.last().map(|s| s.base_offset + s.records).unwrap_or(start_offset);
            let bytes_total = segments.iter().map(|s| s.bytes).sum();
            parts.push(Mutex::new(PartState {
                dir,
                segments,
                writer: None,
                next_offset,
                start_offset,
                committed: start_offset,
                bytes_total,
                lost_records: 0,
                unsynced: 0,
            }));
        }
        Ok(Arc::new(Self { cfg, root, parts, m, metrics }))
    }

    /// A throwaway log in the system temp dir (tests, examples, CLI).
    pub fn temp(tag: &str, cfg: LogConfig) -> Result<Arc<Self>> {
        let unique = format!(
            "adcloud-log-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        );
        Self::create(std::env::temp_dir().join(unique), cfg, MetricsRegistry::new())
    }

    pub fn partitions(&self) -> usize {
        self.parts.len()
    }

    pub fn config(&self) -> &LogConfig {
        &self.cfg
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Stable source -> partition routing (FNV over the source id).
    pub fn partition_for(&self, source: u32) -> usize {
        (fnv1a64(&source.to_le_bytes()) % self.parts.len() as u64) as usize
    }

    /// Append one record; returns its offset.
    pub fn append(&self, part: usize, ts_ns: u64, source: u32, payload: &[u8]) -> Result<u64> {
        let mut sp = trace::span("log.append", trace::Category::LogIo);
        sp.arg("partition", part as u64).arg("bytes", payload.len() as u64);
        let mut st = self.part(part)?.lock().unwrap();
        if st.writer.is_none() {
            self.open_segment(&mut st)?;
        }
        let offset = st.next_offset;
        let mut body = Vec::with_capacity(BODY_HEADER + payload.len());
        body.extend_from_slice(&offset.to_le_bytes());
        body.extend_from_slice(&ts_ns.to_le_bytes());
        body.extend_from_slice(&source.to_le_bytes());
        body.extend_from_slice(payload);
        let mut frame = Vec::with_capacity(body.len() + FRAME_OVERHEAD as usize);
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        let crc = crc32(&body);
        frame.extend_from_slice(&body);
        frame.extend_from_slice(&crc.to_le_bytes());
        st.writer
            .as_mut()
            .expect("active segment writer")
            .write_all(&frame)
            .context("appending log frame")?;
        st.next_offset += 1;
        st.bytes_total += frame.len() as u64;
        let seg = st.segments.last_mut().expect("active segment");
        seg.bytes += frame.len() as u64;
        seg.records += 1;
        self.m.appends.inc();
        self.m.bytes.add(frame.len() as u64);
        if seg.bytes >= self.cfg.segment_bytes {
            // Seal: the next append opens a fresh segment.
            st.writer = None;
            self.enforce_retention(&mut st);
        }
        self.maybe_sync(&mut st, 1);
        Ok(offset)
    }

    /// Group-commit: append a whole batch to one partition under a
    /// single lock acquisition. Frames are staged into one buffer —
    /// each body is CRC'd in the same pass that frames it, so the batch
    /// pays one CRC sweep over the concatenated frames while every
    /// frame keeps its own header CRC for read-side verification — and
    /// written with one `write_all` per `batch_bytes` of staged data
    /// (or per segment roll). The resulting segment layout is
    /// byte-identical to appending the records one at a time; only the
    /// per-record lock, offset-assignment, allocation, and syscall
    /// costs are amortized. Returns the offset of the first record.
    pub fn append_batch(&self, part: usize, recs: &[AppendRecord<'_>]) -> Result<u64> {
        let mut sp = trace::span("log.append_batch", trace::Category::LogIo);
        sp.arg("partition", part as u64).arg("records", recs.len() as u64);
        let mut st = self.part(part)?.lock().unwrap();
        let first = st.next_offset;
        if recs.is_empty() {
            return Ok(first);
        }
        let mut staged: Vec<u8> = Vec::with_capacity(self.cfg.batch_bytes.min(1 << 20));
        let mut batch_bytes = 0u64;
        for r in recs {
            if st.writer.is_none() {
                // The previous record sealed its segment (staged bytes
                // already flushed to it); open the next one.
                self.open_segment(&mut st)?;
            }
            let body_len = BODY_HEADER + r.payload.len();
            let frame_len = body_len as u64 + FRAME_OVERHEAD;
            staged.extend_from_slice(&(body_len as u32).to_le_bytes());
            let body_at = staged.len();
            staged.extend_from_slice(&st.next_offset.to_le_bytes());
            staged.extend_from_slice(&r.ts_ns.to_le_bytes());
            staged.extend_from_slice(&r.source.to_le_bytes());
            staged.extend_from_slice(r.payload);
            let crc = crc32(&staged[body_at..]);
            staged.extend_from_slice(&crc.to_le_bytes());
            st.next_offset += 1;
            st.bytes_total += frame_len;
            batch_bytes += frame_len;
            let seg = st.segments.last_mut().expect("active segment");
            seg.bytes += frame_len;
            seg.records += 1;
            if seg.bytes >= self.cfg.segment_bytes {
                write_staged(&mut st, &mut staged)?;
                st.writer = None;
                self.enforce_retention(&mut st);
            } else if staged.len() >= self.cfg.batch_bytes {
                write_staged(&mut st, &mut staged)?;
            }
        }
        write_staged(&mut st, &mut staged)?;
        self.m.appends.add(recs.len() as u64);
        self.m.bytes.add(batch_bytes);
        self.m.batch_appends.inc();
        self.maybe_sync(&mut st, recs.len() as u64);
        Ok(first)
    }

    /// Honor `flush_interval`: sync the active segment once enough
    /// records have accumulated since the last sync.
    fn maybe_sync(&self, st: &mut PartState, appended: u64) {
        if self.cfg.flush_interval == 0 {
            return;
        }
        st.unsynced += appended;
        if st.unsynced >= self.cfg.flush_interval {
            if let Some(w) = st.writer.as_ref() {
                let _ = w.sync_data();
            }
            st.unsynced = 0;
        }
    }

    fn open_segment(&self, st: &mut PartState) -> Result<()> {
        let path = st.dir.join(format!("seg-{:012}.log", st.next_offset));
        let file = File::create(&path).with_context(|| format!("creating segment {path:?}"))?;
        st.segments.push(Segment { base_offset: st.next_offset, path, bytes: 0, records: 0 });
        st.writer = Some(file);
        Ok(())
    }

    fn enforce_retention(&self, st: &mut PartState) {
        while st.bytes_total > self.cfg.retention_bytes && st.segments.len() > 1 {
            let seg = st.segments.remove(0);
            st.bytes_total -= seg.bytes;
            let _ = std::fs::remove_file(&seg.path);
            st.start_offset = st.segments[0].base_offset;
            if st.committed < st.start_offset {
                // Retention overran the consumer: those records are gone
                // for good. The clamp keeps consumers drainable, but the
                // loss must be observable, not silent.
                let lost = st.start_offset - st.committed;
                st.lost_records += lost;
                st.committed = st.start_offset;
                self.m.lost_unconsumed.add(lost);
            }
            self.m.truncated_segments.inc();
        }
    }

    /// Read up to `max` records starting at `from` (inclusive). Offsets
    /// below the retained start are an error — the data is gone, and a
    /// consumer must decide, not silently skip.
    pub fn read_from(&self, part: usize, from: u64, max: usize) -> Result<Vec<LogRecord>> {
        let st = self.part(part)?.lock().unwrap();
        if from < st.start_offset {
            bail!(
                "partition {part} offset {from} below retained start {} (truncated by retention)",
                st.start_offset
            );
        }
        if from >= st.next_offset || max == 0 {
            return Ok(Vec::new());
        }
        let first = match st.segments.iter().rposition(|s| s.base_offset <= from) {
            Some(i) => i,
            None => bail!("partition {part} has no segment covering offset {from}"),
        };
        let mut out = Vec::new();
        for seg in &st.segments[first..] {
            if out.len() >= max {
                break;
            }
            let bytes = std::fs::read(&seg.path)
                .with_context(|| format!("reading segment {:?}", seg.path))?;
            decode_frames(&bytes, seg.base_offset, |rec| {
                if rec.offset >= from {
                    out.push(rec);
                }
                // Stop decoding (and CRC-checking) once the batch is full.
                out.len() < max
            })?;
        }
        Ok(out)
    }

    /// Zero-copy read: up to `max` records starting at `from` handed to
    /// `f` as [`FrameRef`]s borrowing the raw segment buffers — one
    /// buffer read per segment touched, no per-frame allocation. Same
    /// bounds, CRC, and continuity checks as [`Self::read_from`].
    pub fn read_range_with<R>(
        &self,
        part: usize,
        from: u64,
        max: usize,
        f: impl FnOnce(&[FrameRef<'_>]) -> Result<R>,
    ) -> Result<R> {
        let st = self.part(part)?.lock().unwrap();
        if from < st.start_offset {
            bail!(
                "partition {part} offset {from} below retained start {} (truncated by retention)",
                st.start_offset
            );
        }
        if from >= st.next_offset || max == 0 {
            return f(&[]);
        }
        let first = match st.segments.iter().rposition(|s| s.base_offset <= from) {
            Some(i) => i,
            None => bail!("partition {part} has no segment covering offset {from}"),
        };
        // Phase 1: slurp every segment the range touches. All buffers
        // must be alive before any FrameRef can borrow into them.
        let mut bufs: Vec<(u64, Vec<u8>)> = Vec::new();
        let mut remaining = max as u64;
        for seg in &st.segments[first..] {
            if remaining == 0 {
                break;
            }
            let bytes = std::fs::read(&seg.path)
                .with_context(|| format!("reading segment {:?}", seg.path))?;
            let skipped = from.saturating_sub(seg.base_offset);
            remaining = remaining.saturating_sub(seg.records.saturating_sub(skipped));
            bufs.push((seg.base_offset, bytes));
        }
        // Phase 2: parse frames out of the shared buffers.
        let mut frames: Vec<FrameRef<'_>> = Vec::new();
        for (base, bytes) in &bufs {
            if frames.len() >= max {
                break;
            }
            parse_frames(bytes, *base, |fr| {
                if fr.offset >= from {
                    frames.push(fr);
                }
                frames.len() < max
            })?;
        }
        f(&frames)
    }

    /// Scan a whole partition, counting records whose CRC fails instead
    /// of erroring (diagnostics / dead-letter audits).
    pub fn verify(&self, part: usize) -> Result<(u64, u64)> {
        let st = self.part(part)?.lock().unwrap();
        let (mut ok, mut bad) = (0u64, 0u64);
        for seg in &st.segments {
            let bytes = std::fs::read(&seg.path)
                .with_context(|| format!("reading segment {:?}", seg.path))?;
            let mut off = 0usize;
            while off + 4 <= bytes.len() {
                let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
                if off + 4 + len + 4 > bytes.len() {
                    bad += 1;
                    break;
                }
                let body = &bytes[off + 4..off + 4 + len];
                let stored = u32::from_le_bytes(
                    bytes[off + 4 + len..off + 8 + len].try_into().unwrap(),
                );
                if crc32(body) == stored && len >= BODY_HEADER {
                    ok += 1;
                } else {
                    bad += 1;
                }
                off += 4 + len + 4;
            }
        }
        Ok((ok, bad))
    }

    /// Advance the consumer offset (monotonic; exclusive upper bound).
    pub fn commit(&self, part: usize, upto: u64) -> Result<()> {
        let mut st = self.part(part)?.lock().unwrap();
        st.committed = st.committed.max(upto.min(st.next_offset));
        Ok(())
    }

    pub fn committed(&self, part: usize) -> u64 {
        self.parts[part].lock().unwrap().committed
    }

    pub fn next_offset(&self, part: usize) -> u64 {
        self.parts[part].lock().unwrap().next_offset
    }

    pub fn start_offset(&self, part: usize) -> u64 {
        self.parts[part].lock().unwrap().start_offset
    }

    /// Unconsumed records in a partition (the backpressure signal).
    pub fn lag(&self, part: usize) -> u64 {
        let st = self.parts[part].lock().unwrap();
        st.next_offset - st.committed
    }

    /// Records retention truncated before any consumer read them. A
    /// non-zero value means the retention budget overran the compactor
    /// (raise `retention_bytes` or lower the gateway's `max_lag`).
    pub fn lost_records(&self, part: usize) -> u64 {
        self.parts[part].lock().unwrap().lost_records
    }

    /// Total bytes currently retained across all partitions.
    pub fn retained_bytes(&self) -> u64 {
        self.parts.iter().map(|p| p.lock().unwrap().bytes_total).sum()
    }

    fn part(&self, part: usize) -> Result<&Mutex<PartState>> {
        self.parts
            .get(part)
            .ok_or_else(|| anyhow::anyhow!("partition {part} out of range 0..{}", self.parts.len()))
    }
}

impl Drop for PartitionedLog {
    fn drop(&mut self) {
        // Best-effort cleanup of temp logs (mirrors UnderStore).
        if self.root.starts_with(std::env::temp_dir()) {
            let _ = std::fs::remove_dir_all(&self.root);
        }
    }
}

/// Flush the group-commit staging buffer to the active segment writer.
fn write_staged(st: &mut PartState, staged: &mut Vec<u8>) -> Result<()> {
    if staged.is_empty() {
        return Ok(());
    }
    st.writer
        .as_mut()
        .expect("active segment writer")
        .write_all(staged)
        .context("appending group-commit frames")?;
    staged.clear();
    Ok(())
}

/// Parse frames in a segment's bytes as zero-copy [`FrameRef`]s,
/// calling `sink` per frame until it returns `false` (lets callers
/// stop once a batch is full).
fn parse_frames<'a>(
    bytes: &'a [u8],
    base_offset: u64,
    mut sink: impl FnMut(FrameRef<'a>) -> bool,
) -> Result<()> {
    let mut off = 0usize;
    let mut expect = base_offset;
    while off < bytes.len() {
        if off + 4 > bytes.len() {
            bail!("segment truncated mid frame header at byte {off}");
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        if len < BODY_HEADER || off + 4 + len + 4 > bytes.len() {
            bail!("segment frame at byte {off} claims {len} body bytes");
        }
        let body = &bytes[off + 4..off + 4 + len];
        let stored = u32::from_le_bytes(bytes[off + 4 + len..off + 8 + len].try_into().unwrap());
        if crc32(body) != stored {
            bail!("CRC mismatch on record {expect} (frame at byte {off})");
        }
        let offset = u64::from_le_bytes(body[0..8].try_into().unwrap());
        let ts_ns = u64::from_le_bytes(body[8..16].try_into().unwrap());
        let source = u32::from_le_bytes(body[16..20].try_into().unwrap());
        if offset != expect {
            bail!("offset discontinuity: segment holds {offset}, expected {expect}");
        }
        let more = sink(FrameRef { offset, ts_ns, source, payload: &body[BODY_HEADER..] });
        if !more {
            break;
        }
        expect += 1;
        off += 4 + len + 4;
    }
    Ok(())
}

/// Decode frames in a segment's bytes, calling `sink` per record until
/// it returns `false` — the owning-copy shim over [`parse_frames`].
fn decode_frames(
    bytes: &[u8],
    base_offset: u64,
    mut sink: impl FnMut(LogRecord) -> bool,
) -> Result<()> {
    parse_frames(bytes, base_offset, |fr| {
        sink(LogRecord {
            offset: fr.offset,
            ts_ns: fr.ts_ns,
            source: fr.source,
            payload: fr.payload.to_vec(),
        })
    })
}

/// Validate one segment's frames for [`PartitionedLog::open`]. Returns
/// (whole records, clean byte length). Strict mode errors on any
/// malformed frame; tolerant mode (a partition's final segment) stops
/// at the first bad frame so the caller can truncate a torn
/// group-commit tail back to the last whole frame.
fn scan_segment(bytes: &[u8], base_offset: u64, tolerant: bool) -> Result<(u64, u64)> {
    let mut off = 0usize;
    let mut records = 0u64;
    let mut expect = base_offset;
    while off < bytes.len() {
        match whole_frame_len(bytes, off, expect) {
            Some(frame_len) => {
                records += 1;
                expect += 1;
                off += frame_len;
            }
            None if tolerant => break,
            None => bail!("malformed frame for record {expect} at byte {off}"),
        }
    }
    Ok((records, off as u64))
}

/// Length of the whole, CRC-clean, offset-continuous frame at `off`,
/// or `None` if the bytes there are torn or corrupt.
fn whole_frame_len(bytes: &[u8], off: usize, expect: u64) -> Option<usize> {
    if off + 4 > bytes.len() {
        return None;
    }
    let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
    if len < BODY_HEADER || off + 4 + len + 4 > bytes.len() {
        return None;
    }
    let body = &bytes[off + 4..off + 4 + len];
    let stored = u32::from_le_bytes(bytes[off + 4 + len..off + 8 + len].try_into().unwrap());
    if crc32(body) != stored {
        return None;
    }
    (u64::from_le_bytes(body[0..8].try_into().unwrap()) == expect).then_some(4 + len + 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_log(partitions: usize, segment: u64, retention: u64) -> Arc<PartitionedLog> {
        PartitionedLog::temp(
            "ut",
            LogConfig {
                partitions,
                segment_bytes: segment,
                retention_bytes: retention,
                ..Default::default()
            },
        )
        .unwrap()
    }

    /// Every segment file of one partition, sorted by name.
    fn segment_files(log: &PartitionedLog, part: usize) -> Vec<(String, Vec<u8>)> {
        let dir = log.root.join(format!("partition-{part:03}"));
        let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| {
                let p = e.unwrap().path();
                (p.file_name().unwrap().to_str().unwrap().to_string(), std::fs::read(&p).unwrap())
            })
            .collect();
        out.sort();
        out
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE 802.3 check values — both implementations must hit them.
        for f in [crc32, crc32_bytewise] {
            assert_eq!(f(b""), 0);
            assert_eq!(f(b"123456789"), 0xCBF4_3926);
            assert_eq!(f(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
            assert_eq!(f(&[0u8; 32]), 0x190A_55AD);
            assert_eq!(f(&[0xFFu8; 32]), 0xFF6C_AB0B);
        }
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn crc32_slicing_matches_bytewise_on_random_buffers() {
        // Every length 0..64 (all remainder shapes around the 8-byte
        // slices) plus larger odd sizes, random contents.
        let mut rng = crate::util::Rng::new(0xC3C3);
        for len in (0..64usize).chain([255, 1000, 4093, 1 << 16]) {
            let buf: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            assert_eq!(
                crc32(&buf),
                crc32_bytewise(&buf),
                "sliced and bytewise CRC diverge at len {len}"
            );
        }
    }

    #[test]
    fn append_read_roundtrip() {
        let log = small_log(2, 1 << 20, 1 << 30);
        for i in 0..10u64 {
            let off = log.append(0, i * 100, 7, &[i as u8; 16]).unwrap();
            assert_eq!(off, i);
        }
        let recs = log.read_from(0, 0, 100).unwrap();
        assert_eq!(recs.len(), 10);
        assert_eq!(recs[3].offset, 3);
        assert_eq!(recs[3].ts_ns, 300);
        assert_eq!(recs[3].source, 7);
        assert_eq!(recs[3].payload, vec![3u8; 16]);
        // Offset-addressed read from the middle, bounded by max.
        let mid = log.read_from(0, 6, 2).unwrap();
        assert_eq!(mid.iter().map(|r| r.offset).collect::<Vec<_>>(), vec![6, 7]);
        // Other partition untouched.
        assert!(log.read_from(1, 0, 10).unwrap().is_empty());
    }

    #[test]
    fn segments_roll_and_reads_span_them() {
        // Tiny segments: every record or two rolls a new file.
        let log = small_log(1, 64, 1 << 30);
        for i in 0..50u64 {
            log.append(0, i, 1, &[0u8; 24]).unwrap();
        }
        let recs = log.read_from(0, 0, 1000).unwrap();
        assert_eq!(recs.len(), 50);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.offset, i as u64);
        }
    }

    #[test]
    fn retention_truncates_oldest_and_reads_below_start_fail() {
        let log = small_log(1, 128, 384);
        for i in 0..100u64 {
            log.append(0, i, 1, &[0u8; 32]).unwrap();
        }
        assert!(log.start_offset(0) > 0, "retention must have truncated");
        // Budget is enforced at seal time, so the bound is retention
        // plus one in-flight segment's worth of slack.
        assert!(log.retained_bytes() <= 2 * 384, "budget roughly respected");
        let start = log.start_offset(0);
        assert!(log.read_from(0, 0, 10).is_err(), "reading truncated offsets must fail");
        let recs = log.read_from(0, start, 1000).unwrap();
        assert_eq!(recs.first().unwrap().offset, start);
        assert_eq!(recs.last().unwrap().offset, 99);
        // Nothing was ever committed, so every truncated record counts
        // as lost — the overrun is observable, not silent.
        assert_eq!(log.lost_records(0), start);
    }

    #[test]
    fn corruption_is_detected_on_read() {
        let log = small_log(1, 1 << 20, 1 << 30);
        for i in 0..5u64 {
            log.append(0, i, 1, &[7u8; 64]).unwrap();
        }
        // Flip one payload byte in the active segment file.
        let dir = std::fs::read_dir(log.root.join("partition-000")).unwrap();
        let seg = dir.map(|e| e.unwrap().path()).next().unwrap();
        let mut bytes = std::fs::read(&seg).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&seg, &bytes).unwrap();
        assert!(log.read_from(0, 0, 10).is_err(), "bit flip must fail the CRC");
        let (ok, bad) = log.verify(0).unwrap();
        assert!(bad >= 1, "verify must count the corrupt record");
        assert!(ok < 5);
    }

    #[test]
    fn commit_and_lag_track_consumption() {
        let log = small_log(1, 1 << 20, 1 << 30);
        for i in 0..8u64 {
            log.append(0, i, 1, b"x").unwrap();
        }
        assert_eq!(log.lag(0), 8);
        log.commit(0, 5).unwrap();
        assert_eq!(log.committed(0), 5);
        assert_eq!(log.lag(0), 3);
        // Commits are monotonic and clamped to the head.
        log.commit(0, 2).unwrap();
        assert_eq!(log.committed(0), 5);
        log.commit(0, 99).unwrap();
        assert_eq!(log.committed(0), 8);
        assert_eq!(log.lag(0), 0);
    }

    #[test]
    fn partition_routing_is_stable_and_in_range() {
        let log = small_log(4, 1 << 20, 1 << 30);
        for v in 0..100u32 {
            let p = log.partition_for(v);
            assert!(p < 4);
            assert_eq!(p, log.partition_for(v), "routing must be deterministic");
        }
        // All partitions get some traffic.
        let hit: std::collections::HashSet<usize> =
            (0..100u32).map(|v| log.partition_for(v)).collect();
        assert_eq!(hit.len(), 4);
    }

    #[test]
    fn out_of_range_partition_errors() {
        let log = small_log(2, 1 << 20, 1 << 30);
        assert!(log.append(5, 0, 1, b"x").is_err());
        assert!(log.read_from(5, 0, 1).is_err());
    }

    /// The records every group-commit test appends: varied sizes so the
    /// staging buffer crosses frame boundaries at awkward places.
    fn varied_payloads(n: usize) -> Vec<Vec<u8>> {
        (0..n).map(|i| vec![i as u8; 1 + (i * 13) % 90]).collect()
    }

    #[test]
    fn append_batch_layout_is_byte_identical_to_sequential_appends() {
        // Tiny segments + tiny staging budget: the batch rolls segments
        // mid-stream and flushes the staging buffer repeatedly, and the
        // on-disk bytes must still exactly match one-at-a-time appends.
        let mk = || {
            PartitionedLog::temp(
                "gc",
                LogConfig {
                    partitions: 1,
                    segment_bytes: 300,
                    retention_bytes: 1 << 30,
                    batch_bytes: 128,
                    flush_interval: 7,
                },
            )
            .unwrap()
        };
        let (a, b) = (mk(), mk());
        let payloads = varied_payloads(40);
        for (i, p) in payloads.iter().enumerate() {
            a.append(0, i as u64 * 10, 3, p).unwrap();
        }
        let recs: Vec<AppendRecord<'_>> = payloads
            .iter()
            .enumerate()
            .map(|(i, p)| AppendRecord { ts_ns: i as u64 * 10, source: 3, payload: p })
            .collect();
        assert_eq!(b.append_batch(0, &recs).unwrap(), 0);
        assert_eq!(b.next_offset(0), 40);
        assert_eq!(segment_files(&a, 0), segment_files(&b, 0), "segment layouts diverge");
        assert_eq!(a.read_from(0, 0, 100).unwrap(), b.read_from(0, 0, 100).unwrap());
        // Batches stack: offsets continue densely across calls.
        assert_eq!(b.append_batch(0, &recs[..5]).unwrap(), 40);
        assert_eq!(b.next_offset(0), 45);
        // An empty batch is a no-op that reports the head.
        assert_eq!(b.append_batch(0, &[]).unwrap(), 45);
    }

    #[test]
    fn read_range_with_matches_read_from_without_copies() {
        let log = small_log(1, 200, 1 << 30);
        let payloads = varied_payloads(30);
        for (i, p) in payloads.iter().enumerate() {
            log.append(0, i as u64, 9, p).unwrap();
        }
        for (from, max) in [(0u64, 100usize), (7, 5), (29, 100), (11, 1), (30, 4)] {
            let owned = log.read_from(0, from, max).unwrap();
            log.read_range_with(0, from, max, |frames| {
                assert_eq!(frames.len(), owned.len(), "from={from} max={max}");
                for (f, r) in frames.iter().zip(&owned) {
                    assert_eq!((f.offset, f.ts_ns, f.source), (r.offset, r.ts_ns, r.source));
                    assert_eq!(f.payload, &r.payload[..]);
                }
                Ok(())
            })
            .unwrap();
        }
        // Same loud failure below the retained start as read_from.
        let tight = small_log(1, 128, 384);
        for i in 0..100u64 {
            tight.append(0, i, 1, &[0u8; 32]).unwrap();
        }
        assert!(tight.read_range_with(0, 0, 10, |_| Ok(())).is_err());
    }

    #[test]
    fn open_recovers_whole_frames_and_drops_only_the_torn_tail() {
        // A crash mid group-commit leaves a prefix of the batch on
        // disk: every whole frame must survive, the torn frame must go.
        let root = std::env::temp_dir()
            .join(format!("adcloud-log-recover-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let cfg = LogConfig { partitions: 1, ..Default::default() };
        let log =
            PartitionedLog::create(&root, cfg.clone(), MetricsRegistry::new()).unwrap();
        let payloads = varied_payloads(8);
        let recs: Vec<AppendRecord<'_>> = payloads
            .iter()
            .enumerate()
            .map(|(i, p)| AppendRecord { ts_ns: i as u64, source: 1, payload: p })
            .collect();
        log.append_batch(0, &recs).unwrap();
        // Tear the tail: chop the final frame short mid-write.
        let seg = root.join("partition-000").join("seg-000000000000.log");
        let bytes = std::fs::read(&seg).unwrap();
        std::fs::write(&seg, &bytes[..bytes.len() - 13]).unwrap();
        let re = PartitionedLog::open(&root, cfg.clone(), MetricsRegistry::new()).unwrap();
        assert_eq!(re.next_offset(0), 7, "7 whole frames recovered, torn 8th dropped");
        let recovered = re.read_from(0, 0, 100).unwrap();
        assert_eq!(recovered.len(), 7);
        assert_eq!(recovered[6].payload, payloads[6]);
        // The log stays appendable at the recovered head.
        assert_eq!(re.append(0, 99, 1, b"after").unwrap(), 7);
        assert_eq!(re.read_from(0, 7, 10).unwrap()[0].payload, b"after");
        drop(re);
        drop(log);
    }

    #[test]
    fn open_rejects_corruption_below_the_tail_segment() {
        // Mid-log damage is not a torn tail — recovery must fail loudly
        // instead of silently dropping committed history.
        let root = std::env::temp_dir()
            .join(format!("adcloud-log-midcorrupt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let cfg = LogConfig {
            partitions: 1,
            segment_bytes: 128,
            retention_bytes: 1 << 30,
            ..Default::default()
        };
        let log =
            PartitionedLog::create(&root, cfg.clone(), MetricsRegistry::new()).unwrap();
        for i in 0..20u64 {
            log.append(0, i, 1, &[i as u8; 40]).unwrap();
        }
        // Flip a byte in the FIRST (sealed) segment.
        let seg = root.join("partition-000").join("seg-000000000000.log");
        let mut bytes = std::fs::read(&seg).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&seg, &bytes).unwrap();
        assert!(
            PartitionedLog::open(&root, cfg, MetricsRegistry::new()).is_err(),
            "corruption in a sealed segment must fail recovery"
        );
        drop(log);
    }

    #[test]
    fn open_roundtrips_a_cleanly_closed_multi_segment_log() {
        let root = std::env::temp_dir()
            .join(format!("adcloud-log-reopen-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let cfg = LogConfig {
            partitions: 2,
            segment_bytes: 256,
            retention_bytes: 1 << 30,
            ..Default::default()
        };
        let log =
            PartitionedLog::create(&root, cfg.clone(), MetricsRegistry::new()).unwrap();
        for p in 0..2 {
            for i in 0..30u64 {
                log.append(p, i, p as u32, &[i as u8; 25]).unwrap();
            }
        }
        let re = PartitionedLog::open(&root, cfg, MetricsRegistry::new()).unwrap();
        for p in 0..2 {
            assert_eq!(re.next_offset(p), 30);
            let recs = re.read_from(p, 0, 100).unwrap();
            assert_eq!(recs.len(), 30);
            assert_eq!(recs[29].payload, vec![29u8; 25]);
        }
        drop(re);
        drop(log);
    }
}
