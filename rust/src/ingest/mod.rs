//! Fleet ingestion: the platform's data plane front door.
//!
//! The paper's cloud exists to absorb what the fleet produces — raw
//! sensor and bag data must land in the unified storage layer before
//! simulation, training, and HD-map generation can consume it. This
//! subsystem is that path:
//!
//! * [`log`] — the Kafka-analog durable partitioned log: segmented
//!   append-only partitions, offset-addressed reads, CRC-checked
//!   records, retention truncation.
//! * [`gateway`] — the ingest gateway a simulated fleet uploads
//!   telemetry and rosbag chunks through: per-vehicle rate limiting,
//!   backpressure when partitions lag, dead-lettering of corrupt
//!   uploads.
//! * [`compact`] — container-granted workers that drain partitions
//!   into blocks in the Alluxio-analog tiered store, with lineage
//!   registered so a lost block is recomputable from the log.
//! * [`mine`] — a DCE job over the compacted drives that detects
//!   hard-brake / disengagement / sensor-dropout events and emits
//!   [`crate::scenario::ScenarioSpec`] families the campaign engine
//!   executes unmodified.

pub mod compact;
pub mod gateway;
pub mod log;
pub mod mine;

pub use compact::{
    compact, decode_block, encode_block, encode_block_refs, BlockRef, CompactionReport,
    CompactorConfig,
};
pub use gateway::{
    decode_telemetry, encode_telemetry, gen_drive, simulate_fleet, Admission, DeadLetter,
    DriveGen, FleetConfig, FleetReport, GatewayConfig, IngestGateway, Telemetry, TimerWheel,
    VehicleUpload,
};
pub use log::{
    crc32, crc32_bytewise, AppendRecord, FrameRef, LogConfig, LogRecord, PartitionedLog,
};
pub use mine::{mine, EventKind, MineReport, MinedEvent, MinerConfig};
