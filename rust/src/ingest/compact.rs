//! Compaction: drain log partitions into tiered-storage blocks.
//!
//! Workers run inside containers granted by the YARN-analog resource
//! manager (one per requested worker, degrading gracefully on a small
//! cluster). Each worker owns the partitions `p % workers == w`, reads
//! batches from the partition's committed offset, packs them into
//! `ADIB` blocks, lands the blocks in the Alluxio-analog
//! [`TieredStore`], registers a lineage rule that can rebuild the block
//! from the log range it covers, and only then commits the consumed
//! offset — so a crash between batch and commit re-reads, never loses.

use anyhow::{bail, Context, Result};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::log::{crc32, FrameRef, LogRecord, PartitionedLog};
use crate::platform::job::JobHandle;
use crate::platform::opts::JobOpts;
use crate::resource::{ResourceManager, ResourceVec};
use crate::storage::TieredStore;
use crate::trace;

/// Magic prefix of a compacted ingest block.
pub const BLOCK_MAGIC: &[u8; 4] = b"ADIB";

/// Pack log records into one block:
/// `"ADIB" | u32 count | { u64 offset | u64 ts_ns | u32 source |
///  u32 payload_len | payload }* | u32 crc32(everything before)`.
pub fn encode_block(records: &[LogRecord]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(BLOCK_MAGIC);
    out.extend_from_slice(&(records.len() as u32).to_le_bytes());
    for r in records {
        out.extend_from_slice(&r.offset.to_le_bytes());
        out.extend_from_slice(&r.ts_ns.to_le_bytes());
        out.extend_from_slice(&r.source.to_le_bytes());
        out.extend_from_slice(&(r.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&r.payload);
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// [`encode_block`] over zero-copy [`FrameRef`]s. Byte-identical output
/// for the same records — the lineage rule re-encodes through
/// [`encode_block`], so the two encoders must never diverge (see the
/// `lineage_rebuilds_blocks_from_the_log` test).
pub fn encode_block_refs(frames: &[FrameRef<'_>]) -> Vec<u8> {
    let body: usize = frames.iter().map(|f| 24 + f.payload.len()).sum();
    let mut out = Vec::with_capacity(12 + body);
    out.extend_from_slice(BLOCK_MAGIC);
    out.extend_from_slice(&(frames.len() as u32).to_le_bytes());
    for f in frames {
        out.extend_from_slice(&f.offset.to_le_bytes());
        out.extend_from_slice(&f.ts_ns.to_le_bytes());
        out.extend_from_slice(&f.source.to_le_bytes());
        out.extend_from_slice(&(f.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(f.payload);
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Unpack and CRC-verify a block.
pub fn decode_block(bytes: &[u8]) -> Result<Vec<LogRecord>> {
    if bytes.len() < 12 || &bytes[..4] != BLOCK_MAGIC {
        bail!("not an ingest block: {} bytes", bytes.len());
    }
    let body = &bytes[..bytes.len() - 4];
    let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    if crc32(body) != stored {
        bail!("ingest block CRC mismatch");
    }
    let count = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    // Each record needs at least 24 bytes; reject impossible counts
    // before allocating (same discipline as the bag codec).
    if count > (body.len() - 8) / 24 {
        bail!("block header claims {count} records in {} bytes", bytes.len());
    }
    let mut out = Vec::with_capacity(count);
    let mut off = 8usize;
    let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
        if *off + n > body.len() {
            bail!("ingest block truncated at byte {off}");
        }
        let s = &body[*off..*off + n];
        *off += n;
        Ok(s)
    };
    for _ in 0..count {
        let offset = u64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap());
        let ts_ns = u64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap());
        let source = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap());
        let pl = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let payload = take(&mut off, pl)?.to_vec();
        out.push(LogRecord { offset, ts_ns, source, payload });
    }
    if off != body.len() {
        bail!("ingest block has {} trailing bytes", body.len() - off);
    }
    Ok(out)
}

/// One compacted block landed in the tiered store.
#[derive(Debug, Clone)]
pub struct BlockRef {
    pub key: String,
    pub partition: usize,
    pub base_offset: u64,
    pub records: u32,
    pub bytes: u64,
}

/// Compactor knobs. The shared submission fields (app name, queue,
/// worker ceiling) live in [`JobOpts`]; only the compaction-domain
/// knobs are declared here.
#[derive(Debug, Clone)]
pub struct CompactorConfig {
    /// Shared job-submission options.
    pub opts: JobOpts,
    /// Max records packed into one block.
    pub batch_records: usize,
    /// Store-key prefix for landed blocks.
    pub block_prefix: String,
}

impl CompactorConfig {
    pub fn new(app: impl Into<String>, workers: usize) -> Self {
        Self {
            opts: JobOpts::new(app).workers(workers),
            batch_records: 256,
            block_prefix: "ingest".into(),
        }
    }
}

/// Outcome of one full compaction drain.
#[derive(Debug, Clone)]
pub struct CompactionReport {
    pub blocks: Vec<BlockRef>,
    pub records: u64,
    pub bytes: u64,
    /// Containers actually granted.
    pub workers: usize,
    pub elapsed: Duration,
}

impl CompactionReport {
    pub fn render(&self) -> String {
        format!(
            "compaction: {} blocks ({} records, {}) via {} container(s) in {}",
            self.blocks.len(),
            self.records,
            crate::util::fmt_bytes(self.bytes),
            self.workers,
            crate::util::fmt_duration(self.elapsed),
        )
    }
}

/// Store key for a block (partition + first covered offset).
fn block_key(prefix: &str, partition: usize, base_offset: u64) -> String {
    format!("{prefix}/p{partition:02}/b{base_offset:010}")
}

/// Drain one partition from its committed offset: pack batches into
/// blocks, land them with lineage, commit after each block. Each block
/// is pushed into `landed` the moment its offset commits — NOT returned
/// at the end — so a retried worker (which resumes from the committed
/// offset and re-reads nothing) never loses first-attempt blocks from
/// the report.
///
/// The commit-offset discipline is the original instance of the
/// pattern `platform::ShardCheckpoint` generalizes: it also makes the
/// drain preemption-safe for free. The loop yields at block boundaries
/// when the container is flagged, and the requeued worker resumes from
/// the committed offset — nothing is re-read, nothing is lost.
fn drain_partition(
    log: &Arc<PartitionedLog>,
    store: &Arc<TieredStore>,
    cctx: &crate::resource::ContainerCtx<'_>,
    partition: usize,
    cfg: &CompactorConfig,
    landed: &Mutex<Vec<BlockRef>>,
) -> Result<()> {
    // Per-block counters resolved once per drain, not per block.
    let blocks_landed = store.metrics().counter("ingest.compact.blocks");
    let records_landed = store.metrics().counter("ingest.compact.records");
    loop {
        let from = log.committed(partition).max(log.start_offset(partition));
        if cctx.preempt_requested() {
            bail!("compaction worker preempted at partition {partition} offset {from}");
        }
        // Zero-copy drain: the block is encoded straight out of the
        // segment buffers — no per-frame Vec allocation on this path.
        let drained = log.read_range_with(partition, from, cfg.batch_records, |frames| {
            if frames.is_empty() {
                return Ok(None);
            }
            let base = frames[0].offset;
            let next = frames.last().unwrap().offset + 1;
            Ok(Some((base, frames.len() as u32, next, encode_block_refs(frames))))
        })?;
        let Some((base, count, next, block)) = drained else {
            break;
        };
        // Parented on the shard attempt that entered the container, so
        // a requeued worker's blocks land under its new attempt span.
        let mut sp =
            trace::span_in("compact.block", trace::Category::StoreIo, cctx.trace());
        sp.arg("partition", partition as u64)
            .arg("base", base)
            .arg("records", count as u64);
        let block_len = block.len() as u64;
        let key = block_key(&cfg.block_prefix, partition, base);
        // Charge the block against the container's memory limit while
        // it is in flight (cgroup memcg-style).
        cctx.alloc_mem(block_len)?;
        let put = store.put(&key, block);
        cctx.free_mem(block_len);
        put.with_context(|| format!("landing block {key}"))?;
        // Lineage: the block is recomputable from the log range it
        // covers — until retention truncates that range, at which point
        // recovery must come from the under-store instead.
        let (lg, part, prefix) = (log.clone(), partition, cfg.block_prefix.clone());
        store.lineage().register(&key, move || {
            let recs = lg.read_from(part, base, count as usize)?;
            if recs.len() != count as usize {
                bail!(
                    "lineage for {} covers {} records but log returned {}",
                    block_key(&prefix, part, base),
                    count,
                    recs.len()
                );
            }
            Ok(encode_block(&recs))
        });
        log.commit(partition, next)?;
        blocks_landed.inc();
        records_landed.add(count as u64);
        landed.lock().unwrap().push(BlockRef {
            key,
            partition,
            base_offset: base,
            records: count,
            bytes: block_len,
        });
    }
    Ok(())
}

/// One full drain as a job on the unified job layer: acquire an
/// elastic worker grant, drain every partition to its head (worker `w`
/// owns partitions `p % workers == w`), and let the job's RAII guards
/// release the grant on every exit path. Safe to call repeatedly —
/// each pass resumes from the committed offsets, which also makes the
/// drain preemptible: a flagged worker yields at a block boundary, the
/// job layer requeues it on a replacement container, and the rerun
/// picks up exactly where the committed offsets point.
pub fn compact(
    log: &Arc<PartitionedLog>,
    store: &Arc<TieredStore>,
    rm: &Arc<ResourceManager>,
    cfg: &CompactorConfig,
) -> Result<CompactionReport> {
    let start = Instant::now();
    // Size the grant for a batch of max-size blocks with headroom.
    let mem = (4 * cfg.batch_records as u64 * 1024).max(8 << 20);
    let job = JobHandle::submit(
        rm,
        cfg.opts
            .spec()
            .containers(1, cfg.opts.workers.min(log.partitions()).max(1))
            .resources(ResourceVec::cores(1, mem)),
    )
    .with_context(|| format!("submitting compaction job '{}'", cfg.opts.app))?;
    let workers = job.shards();
    let landed: Mutex<Vec<BlockRef>> = Mutex::new(Vec::new());
    let drained = job.run_per_container(|sctx| -> Result<()> {
        for partition in (0..log.partitions()).filter(|p| p % sctx.shards == sctx.shard) {
            sctx.run(|cctx| drain_partition(log, store, cctx, partition, cfg, &landed))??;
        }
        Ok(())
    });
    let _ = job.finish();
    drained?;
    let mut blocks = landed.into_inner().unwrap();
    blocks.sort_by(|a, b| (a.partition, a.base_offset).cmp(&(b.partition, b.base_offset)));
    let records = blocks.iter().map(|b| b.records as u64).sum();
    let bytes = blocks.iter().map(|b| b.bytes).sum();
    Ok(CompactionReport { blocks, records, bytes, workers, elapsed: start.elapsed() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::ingest::gateway::{encode_telemetry, gen_drive};
    use crate::ingest::log::LogConfig;
    use crate::metrics::MetricsRegistry;

    fn filled_log(partitions: usize, per_part: usize) -> Arc<PartitionedLog> {
        let log = PartitionedLog::temp(
            "cp",
            LogConfig {
                partitions,
                segment_bytes: 8 << 10,
                retention_bytes: 16 << 20,
                ..Default::default()
            },
        )
        .unwrap();
        for p in 0..partitions {
            for i in 0..per_part {
                let t = gen_drive(p as u32, 5, i + 1);
                log.append(p, i as u64, p as u32, &encode_telemetry(&t)).unwrap();
            }
        }
        log
    }

    #[test]
    fn block_codec_roundtrips_and_rejects_corruption() {
        let recs: Vec<LogRecord> = (0..20)
            .map(|i| LogRecord {
                offset: i,
                ts_ns: i * 7,
                source: (i % 3) as u32,
                payload: vec![i as u8; (i as usize * 11) % 40],
            })
            .collect();
        let block = encode_block(&recs);
        assert_eq!(decode_block(&block).unwrap(), recs);
        let mut bad = block.clone();
        bad[10] ^= 1;
        assert!(decode_block(&bad).is_err());
        let mut trunc = block;
        trunc.truncate(trunc.len() - 5);
        assert!(decode_block(&trunc).is_err());
        // Absurd count rejected before allocation.
        let mut fake = BLOCK_MAGIC.to_vec();
        fake.extend_from_slice(&u32::MAX.to_le_bytes());
        fake.extend_from_slice(&crc32(&fake).to_le_bytes());
        assert!(decode_block(&fake).is_err());
    }

    #[test]
    fn block_refs_encode_byte_identically_to_owned_records() {
        // The zero-copy writer and the lineage recompute path (which
        // goes through `encode_block`) must emit the same bytes.
        let log = filled_log(1, 25);
        let owned = log.read_from(0, 0, 100).unwrap();
        let via_refs = log
            .read_range_with(0, 0, 100, |frames| {
                assert_eq!(frames.len(), 25);
                Ok(encode_block_refs(frames))
            })
            .unwrap();
        assert_eq!(via_refs, encode_block(&owned));
        assert_eq!(decode_block(&via_refs).unwrap(), owned);
    }

    #[test]
    fn compact_drains_all_partitions_and_commits() {
        let cfg = PlatformConfig::test();
        let log = filled_log(3, 50);
        let store = TieredStore::test_store(&cfg.storage);
        let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
        let report = compact(&log, &store, &rm, &CompactorConfig::new("cp-ut", 2)).unwrap();
        assert_eq!(report.records, 150);
        assert!(!report.blocks.is_empty());
        for p in 0..3 {
            assert_eq!(log.committed(p), 50, "partition {p} must be fully drained");
            assert_eq!(log.lag(p), 0);
        }
        // Blocks decode back to the original records.
        let b = &report.blocks[0];
        let bytes = store.get(&b.key).unwrap();
        let recs = decode_block(&bytes).unwrap();
        assert_eq!(recs.len(), b.records as usize);
        assert_eq!(recs[0].offset, b.base_offset);
        assert_eq!(rm.live_containers(), 0, "containers must be released");
        // A second pass over a drained log is a no-op.
        let again = compact(&log, &store, &rm, &CompactorConfig::new("cp-ut", 2)).unwrap();
        assert_eq!(again.records, 0);
        assert!(again.blocks.is_empty());
    }

    #[test]
    fn compact_resumes_after_new_appends() {
        let cfg = PlatformConfig::test();
        let log = filled_log(1, 10);
        let store = TieredStore::test_store(&cfg.storage);
        let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
        let ccfg = CompactorConfig::new("cp-resume", 1);
        compact(&log, &store, &rm, &ccfg).unwrap();
        for i in 0..5u64 {
            log.append(0, 100 + i, 9, b"late").unwrap();
        }
        let second = compact(&log, &store, &rm, &ccfg).unwrap();
        assert_eq!(second.records, 5);
        assert_eq!(second.blocks[0].base_offset, 10);
        assert_eq!(log.committed(0), 15);
    }

    #[test]
    fn preempted_drain_resumes_from_committed_offsets() {
        let cfg = PlatformConfig::test();
        let log = filled_log(2, 200);
        let store = TieredStore::test_store(&cfg.storage);
        let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
        let mut ccfg = CompactorConfig::new("cp-preempt", 2);
        ccfg.batch_records = 16; // many block boundaries = many yield points
        let report = std::thread::scope(|s| {
            let rm2 = rm.clone();
            let flagger = s.spawn(move || {
                // Flag a worker as soon as the grant is live; the drain
                // yields at the next block boundary and requeues.
                let deadline = Instant::now() + Duration::from_secs(2);
                while rm2.live_containers() == 0 && Instant::now() < deadline {
                    std::thread::yield_now();
                }
                rm2.request_preemption("cp-preempt", 1)
            });
            let report = compact(&log, &store, &rm, &ccfg);
            let _ = flagger.join();
            report
        })
        .unwrap();
        // The drain still reaches the head, with no block landed twice.
        assert_eq!(report.records, 400);
        for p in 0..2 {
            assert_eq!(log.committed(p), 200, "partition {p} must be fully drained");
        }
        let mut keys: Vec<&str> = report.blocks.iter().map(|b| b.key.as_str()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), report.blocks.len(), "no block may land twice");
        assert_eq!(rm.live_containers(), 0);
    }

    #[test]
    fn lineage_rebuilds_blocks_from_the_log() {
        let cfg = PlatformConfig::test();
        let log = filled_log(1, 30);
        let store = TieredStore::test_store(&cfg.storage);
        let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
        let report = compact(&log, &store, &rm, &CompactorConfig::new("cp-lin", 1)).unwrap();
        let b = &report.blocks[0];
        let stored = store.get(&b.key).unwrap().as_ref().clone();
        let recomputed = store.lineage().recompute(&b.key).unwrap().unwrap();
        assert_eq!(recomputed, stored, "lineage must rebuild the exact block bytes");
    }

    #[test]
    fn lineage_fails_loudly_once_retention_truncates() {
        // Retention so tight the compacted range is truncated away.
        let log = PartitionedLog::temp(
            "cp-trunc",
            LogConfig {
                partitions: 1,
                segment_bytes: 256,
                retention_bytes: 512,
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..20u64 {
            log.append(0, i, 1, &[0u8; 100]).unwrap();
        }
        let cfg = PlatformConfig::test();
        let store = TieredStore::test_store(&cfg.storage);
        let rm = ResourceManager::new(&cfg.cluster, MetricsRegistry::new());
        // Compact what is still retained.
        let start = log.start_offset(0);
        assert!(start > 0);
        let report = compact(&log, &store, &rm, &CompactorConfig::new("cp-tr", 1)).unwrap();
        let b = &report.blocks[0];
        // Push more data so retention advances past the compacted range.
        for i in 0..40u64 {
            log.append(0, 100 + i, 1, &[0u8; 100]).unwrap();
        }
        assert!(log.start_offset(0) > b.base_offset);
        assert!(store.lineage().recompute(&b.key).is_err(), "recompute must not fabricate data");
    }
}
