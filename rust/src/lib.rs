//! # adcloud — a unified cloud platform for autonomous driving
//!
//! A from-scratch reproduction of *"Implementing a Cloud Platform for
//! Autonomous Driving"* (Liu, Tang, Wang, Wang, Gaudiot — 2017): the
//! unified infrastructure (distributed compute engine, memory-centric
//! tiered storage, YARN/LXC-style resource management, heterogeneous
//! kernel dispatch) plus the three services the paper builds on top of
//! it — distributed simulation replay, offline model training with a
//! storage-backed parameter server, and HD-map generation.
//!
//! The numeric hot spots (CNN convolution, ICP correspondence search,
//! image feature extraction) are authored as JAX/Pallas kernels, AOT
//! lowered to HLO text at build time (`make artifacts`), and executed
//! from Rust through PJRT ([`runtime`]). Python never runs on the
//! request path.
//!
//! Layer map:
//! * [`dce`] — the Spark-analog distributed compute engine (RDDs, DAG
//!   scheduler, shuffle, BinPipeRDD, virtual-time cluster simulation).
//!   Task dispatch is work-stealing: per-worker deques with a
//!   condvar-guarded overflow injector, not one mutex-wrapped channel.
//!   The shuffle is its own plane: a lock-striped `ShuffleManager`
//!   keyed by `(shuffle, reduce)` with pre-resolved metric/transport
//!   handles, single-acquisition batched takes, manager-side combine,
//!   placement hints that route reduce tasks to the worker holding
//!   the plurality of their map-output bytes (stealing still
//!   balances), and spill-to-[`storage`] above a resident-byte budget.
//!   The pre-sharding single-lock manager survives behind
//!   `EngineConfig::shuffle_single_lock` (`adcloud --baseline`) as
//!   experiment E22's A/B baseline.
//! * [`mapreduce`] — the disk-staged MapReduce baseline engine.
//! * [`storage`] — the Alluxio-analog tiered block store and the
//!   HDFS-analog baseline. The block map is lock-striped into
//!   `StorageConfig::shards` shards (per-tier `used` in atomics);
//!   each shard keeps one ordered eviction index per tier —
//!   `BTreeSet<(EvictionPolicy::rank, key)>`, maintained on every
//!   access — whose invariant is that min-rank across the shard
//!   minima is exactly the victim the policy's O(n) scan would pick,
//!   so eviction is O(log n) with unchanged eviction order. The old
//!   single-lock scan path survives behind `StorageConfig::scan_evict`
//!   (`adcloud --baseline`) as experiment E17's A/B baseline.
//! * [`resource`] — YARN-analog resource manager and LXC-analog
//!   containers over a heterogeneous device inventory, with RAII
//!   grants and app leases. Queues carry a guaranteed share plus an
//!   elastic ceiling; grant floors are admitted **gang-atomically**
//!   (all-or-nothing, no hold-and-wait deadlocks), and **fair-share
//!   preemption** flags victim containers of over-guarantee tenants
//!   when a below-guarantee queue is starved.
//! * [`platform`] — one-call platform boot, the **unified job layer**
//!   (`JobSpec`/`JobHandle`: an application-master analog every
//!   workload schedules through; preempted shards checkpoint via
//!   `ShardCheckpoint`, yield their container, and requeue without
//!   burning their retry budget; `ShardCheckpoint::sweep` GCs orphaned
//!   checkpoint blobs past a retention window), shared job-submission
//!   options (`JobOpts`: app/queue/workers/checkpoint/grant-timeout,
//!   one builder reused by every subcommand and service config), and
//!   the paper-experiment harness (E1–E22).
//! * [`hetero`] — kernel registry + dispatch across CPU / GPU-class /
//!   FPGA-class devices.
//! * [`runtime`] — the PJRT artifact runtime (device-server threads).
//! * [`ingest`] — the fleet data plane: partitioned telemetry log,
//!   ingest gateway (rate limiting, backpressure, dead-letter),
//!   compaction into tiered storage, and scenario mining.
//! * [`scenario`] — procedural scenario generation + distributed test
//!   campaigns (spec → generate → campaign → qualification report).
//! * [`serve`] — the latency-SLO serving plane: vehicles offload
//!   inference with hard deadlines; reject-on-arrival admission
//!   (queue-delay estimate vs deadline slack), EDF dispatch on an
//!   `interactive` priority queue above the batch queues, and
//!   speculative local-model fallback when remaining slack stops
//!   covering the p99 service estimate (degraded completion, not an
//!   SLO miss). Ships as a deterministic virtual-time simulator plus
//!   a real plane whose workers are job-layer container shards;
//!   exercised by experiment E21.
//! * [`services`] — simulation, training, HD-map generation, SQL.
//! * [`pointcloud`] — SE(3) math, KD-trees, the 3x3 polar solve.
//! * [`trace`] — causal tracing across every plane: spans recorded
//!   into per-thread lock-free rings (near-zero cost while disabled),
//!   Chrome-trace-event export (`--trace <out.json>`, Perfetto
//!   loadable), and critical-path attribution of a finished job's
//!   makespan to grant-wait / preempt-requeue / checkpoint-replay /
//!   compute / shuffle / store-I/O / log-I/O (experiment E18).
//! * [`obs`] — the telemetry plane built on [`metrics`] and [`trace`]:
//!   a time-series sampler (counters → windowed rates, gauges,
//!   histogram p50/p99, into bounded ring buffers), a declarative SLO
//!   watchdog engine (ok→warn→critical state machines with debounce
//!   and hysteresis; built-in rules for ingest lag/DLQ, grant-wait
//!   p99, eviction thrash, checkpoint-replay storms, steal
//!   starvation), and a flight recorder that dumps post-mortem
//!   bundles on job failure or critical breach. Served live via
//!   `/metrics` + `/healthz` (`runtime::ObsServer`), `adcloud top`,
//!   and `adcloud postmortem`; exercised by experiment E19.

pub mod config;
pub mod dce;
pub mod hetero;
pub mod ingest;
pub mod mapreduce;
pub mod metrics;
pub mod obs;
pub mod platform;
pub mod pointcloud;
pub mod resource;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod services;
pub mod storage;
pub mod trace;
pub mod util;

pub use anyhow::{anyhow, bail, Context as AnyhowContext, Error, Result};

/// Default location of the AOT artifacts, overridable via `ADCLOUD_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("ADCLOUD_ARTIFACTS") {
        return dir.into();
    }
    // Walk up from the current dir so examples/tests work from any cwd.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").is_file() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
