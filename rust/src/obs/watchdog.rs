//! SLO watchdog engine: declarative rules evaluated against sampler
//! series, each running an ok→warn→critical state machine with
//! debounce (a threshold must hold for `sustain` before escalating)
//! and hysteresis (the value must sit below the warn line for `clear`
//! before the rule returns to ok, so a flapping metric cannot
//! oscillate the level every tick).
//!
//! The engine is a pure state machine — callers feed it a clock and a
//! series lookup — which keeps every transition unit-testable without
//! threads. The [`crate::obs::Observability`] loop drives it once per
//! sampler tick and turns returned [`Transition`]s into trace spans
//! and flight-recorder triggers.

use std::time::Duration;

use crate::util::json::Json;

/// Severity level of a rule. Ordered: `Ok < Warn < Critical`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Ok,
    Warn,
    Critical,
}

impl Level {
    pub fn label(self) -> &'static str {
        match self {
            Level::Ok => "ok",
            Level::Warn => "warn",
            Level::Critical => "critical",
        }
    }

    /// Static span name for a transition *into* this level.
    pub fn span_name(self) -> &'static str {
        match self {
            Level::Ok => "slo.clear",
            Level::Warn => "slo.warn",
            Level::Critical => "slo.critical",
        }
    }
}

/// One declarative SLO rule: watch `series`, escalate when its latest
/// value holds at or above a threshold for `sustain`.
#[derive(Clone, Debug)]
pub struct Rule {
    pub name: &'static str,
    /// Sampler series name (e.g. `platform.job.grant_wait.p99`).
    pub series: String,
    /// What the rule is guarding against, for post-mortems.
    pub what: &'static str,
    pub warn: f64,
    pub critical: f64,
    /// How long a threshold must hold before escalating (debounce).
    pub sustain: Duration,
    /// How long the value must stay below `warn` before clearing.
    pub clear: Duration,
    /// When set, the rule watches the series' *rate of change*
    /// (units/second between consecutive samples) instead of its
    /// absolute value — a rising-edge alarm that fires while a latency
    /// series is still climbing toward its absolute threshold.
    pub slope_per_sec: bool,
}

/// A level change on one rule, emitted by [`Watchdog::eval`].
#[derive(Clone, Copy, Debug)]
pub struct Transition {
    pub rule_idx: usize,
    pub rule: &'static str,
    pub from: Level,
    pub to: Level,
    pub at_ms: u64,
    pub value: f64,
}

struct RuleState {
    level: Level,
    warn_since: Option<u64>,
    crit_since: Option<u64>,
    below_since: Option<u64>,
    last_value: f64,
    /// Previous `(at_ms, raw sample)` for slope rules.
    prev: Option<(u64, f64)>,
}

const MAX_TRANSITIONS: usize = 1024;

pub struct Watchdog {
    rules: Vec<Rule>,
    states: Vec<RuleState>,
    transitions: Vec<Transition>,
}

impl Watchdog {
    pub fn new(rules: Vec<Rule>) -> Self {
        let states = rules
            .iter()
            .map(|_| RuleState {
                level: Level::Ok,
                warn_since: None,
                crit_since: None,
                below_since: None,
                last_value: 0.0,
                prev: None,
            })
            .collect();
        Self { rules, states, transitions: Vec::new() }
    }

    pub fn rules(&self) -> &[Rule] {
        &self.rules
    }

    /// Evaluate every rule against the latest series values at `now_ms`.
    /// Missing series leave the rule untouched. Returns the transitions
    /// that fired this round (also kept in a bounded internal log).
    pub fn eval(
        &mut self,
        now_ms: u64,
        lookup: impl Fn(&str) -> Option<f64>,
    ) -> Vec<Transition> {
        let mut fired = Vec::new();
        for (i, rule) in self.rules.iter().enumerate() {
            let raw = match lookup(&rule.series) {
                Some(v) => v,
                None => continue,
            };
            let st = &mut self.states[i];
            let v = if rule.slope_per_sec {
                // Rate of change against the previous sample; the
                // first sample establishes the baseline at slope 0.
                let slope = match st.prev {
                    Some((t0, v0)) if now_ms > t0 => {
                        (raw - v0) / ((now_ms - t0) as f64 / 1000.0)
                    }
                    _ => 0.0,
                };
                st.prev = Some((now_ms, raw));
                slope
            } else {
                raw
            };
            st.last_value = v;
            let mut next = st.level;
            if v >= rule.critical {
                st.below_since = None;
                st.warn_since.get_or_insert(now_ms);
                let since = *st.crit_since.get_or_insert(now_ms);
                if now_ms - since >= rule.sustain.as_millis() as u64 {
                    next = Level::Critical;
                }
            } else if v >= rule.warn {
                st.below_since = None;
                st.crit_since = None;
                let since = *st.warn_since.get_or_insert(now_ms);
                if st.level < Level::Warn && now_ms - since >= rule.sustain.as_millis() as u64 {
                    next = Level::Warn;
                }
                // A Critical rule whose value falls back into the warn
                // band stays Critical: hysteresis requires dropping
                // below `warn` for `clear` before any de-escalation.
            } else {
                st.warn_since = None;
                st.crit_since = None;
                let since = *st.below_since.get_or_insert(now_ms);
                if st.level > Level::Ok && now_ms - since >= rule.clear.as_millis() as u64 {
                    next = Level::Ok;
                }
            }
            if next != st.level {
                let t = Transition {
                    rule_idx: i,
                    rule: rule.name,
                    from: st.level,
                    to: next,
                    at_ms: now_ms,
                    value: v,
                };
                st.level = next;
                fired.push(t);
                if self.transitions.len() < MAX_TRANSITIONS {
                    self.transitions.push(t);
                }
            }
        }
        fired
    }

    pub fn level(&self, rule: &str) -> Option<Level> {
        self.rules
            .iter()
            .position(|r| r.name == rule)
            .map(|i| self.states[i].level)
    }

    pub fn last_value(&self, rule: &str) -> Option<f64> {
        self.rules
            .iter()
            .position(|r| r.name == rule)
            .map(|i| self.states[i].last_value)
    }

    /// Worst level across all rules — the `/healthz` rollup.
    pub fn overall(&self) -> Level {
        self.states.iter().map(|s| s.level).max().unwrap_or(Level::Ok)
    }

    pub fn transitions(&self) -> &[Transition] {
        &self.transitions
    }

    /// Per-rule state as JSON, for `/healthz` and post-mortem bundles.
    pub fn states_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rules
            .iter()
            .zip(&self.states)
            .map(|(r, s)| {
                Json::obj(vec![
                    ("rule", Json::str(r.name)),
                    ("series", Json::str(&r.series)),
                    ("what", Json::str(r.what)),
                    ("level", Json::str(s.level.label())),
                    ("value", Json::num(s.last_value)),
                    ("warn", Json::num(r.warn)),
                    ("critical", Json::num(r.critical)),
                ])
            })
            .collect();
        Json::arr(rows)
    }

    pub fn transitions_json(&self) -> Json {
        let rows: Vec<Json> = self
            .transitions
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("rule", Json::str(t.rule)),
                    ("from", Json::str(t.from.label())),
                    ("to", Json::str(t.to.label())),
                    ("at_ms", Json::num(t.at_ms as f64)),
                    ("value", Json::num(t.value)),
                ])
            })
            .collect();
        Json::arr(rows)
    }
}

/// The built-in rule set, one per failure mode the paper's planes can
/// hit while a campaign is live. Thresholds are in the series' native
/// units (records for lag/depth, microseconds for histogram quantiles,
/// events/second for rates).
pub fn builtin_rules(sustain: Duration) -> Vec<Rule> {
    let clear = sustain * 2;
    let rule = |name, series: &str, what, warn, critical| Rule {
        name,
        series: series.to_string(),
        what,
        warn,
        critical,
        sustain,
        clear,
        slope_per_sec: false,
    };
    vec![
        rule(
            "ingest-backlog",
            "ingest.gateway.partition_lag",
            "worst produced-minus-committed partition lag (records); a paused compactor or stalled consumer shows up here",
            1_000.0,
            10_000.0,
        ),
        rule(
            "ingest-dlq",
            "ingest.gateway.dlq_depth",
            "dead letters parked at the gateway (corrupt uploads)",
            10.0,
            50.0,
        ),
        rule(
            "grant-wait-p99",
            "platform.job.grant_wait.p99",
            "p99 time jobs wait for container grants (µs); an over-admitted queue starves admission",
            50_000.0,
            100_000.0,
        ),
        rule(
            "evict-thrash",
            "storage.tiered.evict.mem.rate",
            "memory-tier evictions per second; a too-small cap makes the store churn instead of cache",
            100.0,
            1_000.0,
        ),
        rule(
            "ckpt-replay-storm",
            "platform.ckpt.hits.rate",
            "checkpoint lookup hits per second; mass shard replay after a failure wave",
            50.0,
            500.0,
        ),
        rule(
            "steal-starvation",
            "dce.executor.steals.rate",
            "executor work-steals per second; sustained stealing means the submit path is starving some workers",
            100.0,
            1_000.0,
        ),
    ]
}

/// Serving-plane SLO rules, composed with [`builtin_rules`] by the
/// `serve` subcommand and experiment E21. Kept separate so batch-only
/// deployments keep the historical six-rule set: the interactive queue
/// answers vehicle offloads with ~100 ms deadlines, so its grant-wait
/// budget is 5x tighter than the batch `grant-wait-p99` rule, and the
/// slope rule fires while serve latency is still *climbing* toward the
/// absolute threshold — the earliest observable edge of a saturation
/// cliff.
pub fn serve_rules(sustain: Duration) -> Vec<Rule> {
    let clear = sustain * 2;
    vec![
        Rule {
            name: "interactive-grant-wait",
            series: "resource.grant_wait.interactive.p99".to_string(),
            what: "p99 container grant wait on the interactive queue (µs); offload \
                   deadlines are ~100ms so admission must stay far under the batch budget",
            warn: 10_000.0,
            critical: 25_000.0,
            sustain,
            clear,
            slope_per_sec: false,
        },
        Rule {
            name: "serve-latency-rising",
            series: "serve.latency.p99".to_string(),
            what: "rate of change of serve p99 latency (µs per second); a sustained \
                   climb is the leading edge of the saturation cliff",
            warn: 50_000.0,
            critical: 250_000.0,
            sustain,
            clear,
            slope_per_sec: true,
        },
        Rule {
            name: "serve-latency-p99",
            series: "serve.latency.p99".to_string(),
            what: "absolute p99 offload latency (µs) against the ~100ms deadline class",
            warn: 80_000.0,
            critical: 150_000.0,
            sustain,
            clear,
            slope_per_sec: false,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_rule(sustain_ms: u64, clear_ms: u64) -> Watchdog {
        Watchdog::new(vec![Rule {
            name: "r",
            series: "s".into(),
            what: "test",
            warn: 10.0,
            critical: 100.0,
            sustain: Duration::from_millis(sustain_ms),
            clear: Duration::from_millis(clear_ms),
            slope_per_sec: false,
        }])
    }

    #[test]
    fn escalates_only_after_sustain_window() {
        let mut w = one_rule(50, 50);
        assert!(w.eval(0, |_| Some(500.0)).is_empty(), "not sustained yet");
        assert_eq!(w.level("r"), Some(Level::Ok));
        assert!(w.eval(20, |_| Some(500.0)).is_empty());
        let t = w.eval(60, |_| Some(500.0));
        assert_eq!(t.len(), 1);
        assert_eq!((t[0].from, t[0].to), (Level::Ok, Level::Critical));
        assert_eq!(w.overall(), Level::Critical);
    }

    #[test]
    fn a_blip_below_threshold_resets_the_sustain_clock() {
        let mut w = one_rule(50, 50);
        w.eval(0, |_| Some(500.0));
        w.eval(30, |_| Some(1.0)); // blip: debounce restarts
        w.eval(60, |_| Some(500.0));
        assert_eq!(w.level("r"), Some(Level::Ok), "60ms elapsed but not sustained");
        let t = w.eval(120, |_| Some(500.0));
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].to, Level::Critical);
    }

    #[test]
    fn warn_band_with_hysteresis_keeps_critical_until_fully_below_warn() {
        let mut w = one_rule(0, 50);
        w.eval(0, |_| Some(500.0));
        assert_eq!(w.level("r"), Some(Level::Critical));
        // Fall back into the warn band: still critical (hysteresis).
        w.eval(10, |_| Some(50.0));
        assert_eq!(w.level("r"), Some(Level::Critical));
        // Below warn, but not for long enough to clear.
        w.eval(20, |_| Some(1.0));
        assert_eq!(w.level("r"), Some(Level::Critical));
        let t = w.eval(80, |_| Some(1.0));
        assert_eq!(t.len(), 1);
        assert_eq!((t[0].from, t[0].to), (Level::Critical, Level::Ok));
    }

    #[test]
    fn warn_level_fires_between_thresholds() {
        let mut w = one_rule(0, 0);
        let t = w.eval(0, |_| Some(20.0));
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].to, Level::Warn);
        assert_eq!(w.overall(), Level::Warn);
    }

    #[test]
    fn missing_series_leaves_state_untouched() {
        let mut w = one_rule(0, 0);
        w.eval(0, |_| Some(500.0));
        assert_eq!(w.level("r"), Some(Level::Critical));
        assert!(w.eval(10, |_| None).is_empty());
        assert_eq!(w.level("r"), Some(Level::Critical));
    }

    #[test]
    fn builtin_rules_cover_every_plane() {
        let rules = builtin_rules(Duration::from_millis(500));
        let names: Vec<_> = rules.iter().map(|r| r.name).collect();
        for expect in [
            "ingest-backlog",
            "ingest-dlq",
            "grant-wait-p99",
            "evict-thrash",
            "ckpt-replay-storm",
            "steal-starvation",
        ] {
            assert!(names.contains(&expect), "missing builtin rule {expect}");
        }
        for r in &rules {
            assert!(r.warn < r.critical, "{}: warn must sit below critical", r.name);
        }
    }

    #[test]
    fn slope_rule_fires_while_series_is_rising_not_merely_high() {
        let mut w = Watchdog::new(vec![Rule {
            name: "rising",
            series: "s".into(),
            what: "test",
            warn: 100.0,
            critical: 1000.0,
            sustain: Duration::ZERO,
            clear: Duration::ZERO,
            slope_per_sec: true,
        }]);
        // High but FLAT: slope 0, never fires.
        assert!(w.eval(0, |_| Some(5_000.0)).is_empty());
        assert!(w.eval(1000, |_| Some(5_000.0)).is_empty());
        assert_eq!(w.level("rising"), Some(Level::Ok));
        // Climbing at 500 units/s: warn fires while the absolute value
        // is unremarkable relative to where it is heading.
        let t = w.eval(2000, |_| Some(5_500.0));
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].to, Level::Warn);
        // Climbing at 2000 units/s: critical.
        let t = w.eval(3000, |_| Some(7_500.0));
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].to, Level::Critical);
        // Plateau: slope collapses to 0 and the rule clears.
        let t = w.eval(4000, |_| Some(7_500.0));
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].to, Level::Ok);
    }

    #[test]
    fn serve_rules_are_tighter_than_batch_and_include_a_slope_rule() {
        let sustain = Duration::from_millis(500);
        let batch = builtin_rules(sustain);
        let serve = serve_rules(sustain);
        let batch_wait = batch.iter().find(|r| r.name == "grant-wait-p99").unwrap();
        let serve_wait = serve.iter().find(|r| r.name == "interactive-grant-wait").unwrap();
        assert!(serve_wait.warn < batch_wait.warn);
        assert!(serve_wait.critical < batch_wait.critical);
        assert!(serve_wait.series.contains("interactive"));
        let rising = serve.iter().find(|r| r.name == "serve-latency-rising").unwrap();
        assert!(rising.slope_per_sec, "rising rule must watch the slope");
        for r in &serve {
            assert!(r.warn < r.critical, "{}: warn must sit below critical", r.name);
        }
    }

    #[test]
    fn states_json_reports_levels_and_values() {
        let mut w = one_rule(0, 0);
        w.eval(0, |_| Some(500.0));
        let j = w.states_json();
        let row = &j.as_arr().unwrap()[0];
        assert_eq!(row.req("level").unwrap().as_str().unwrap(), "critical");
        assert_eq!(row.req("value").unwrap().as_f64().unwrap(), 500.0);
    }
}
