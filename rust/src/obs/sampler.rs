//! Time-series sampler: periodic snapshots of a [`MetricsRegistry`]
//! into per-series fixed-capacity ring buffers.
//!
//! Counters become *windowed rates* (`<name>.rate`, per second, from
//! deltas between ticks), gauges are sampled directly (`<name>`), and
//! histograms are sampled at their current p50/p99/p999 (`<name>.p50`,
//! `<name>.p99`, `<name>.p999`, microseconds — the tail quantile is
//! what the serving plane's latency SLOs are written against).
//! External sources that are not in the registry — executor steal
//! counts, trace-ring drops — plug in as probes
//! ([`Sampler::add_probe`]).
//!
//! **Zero new locks on hot paths.** The sampler clones the registry's
//! `(name, Arc)` handle map once per tick ([`MetricsRegistry::handles`])
//! and then reads the same atomics the cached metric handles write;
//! recording paths never see the sampler's own mutex.
//!
//! **Bounded memory.** Each series keeps a fine ring (one slot per
//! tick, default 512) plus a coarse ring downsampled every
//! `coarse_every` ticks into `(mean, max)` points (default capacity
//! 2250). At the default 100 ms period that is ~51 s of fine history
//! and an hour of coarse history in a few tens of KiB per series.

use std::collections::{BTreeMap, VecDeque};
use std::time::Duration;

use crate::metrics::MetricsRegistry;
use crate::util::json::Json;

#[derive(Clone)]
pub struct SamplerConfig {
    /// Tick period; the background thread in [`crate::obs::Observability`]
    /// sleeps this long between ticks.
    pub period: Duration,
    /// Fine ring capacity (one slot per tick).
    pub fine_capacity: usize,
    /// Fold one coarse point out of every N ticks.
    pub coarse_every: usize,
    /// Coarse ring capacity.
    pub coarse_capacity: usize,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self {
            period: Duration::from_millis(100),
            fine_capacity: 512,
            coarse_every: 16,
            coarse_capacity: 2250,
        }
    }
}

/// How a probe's raw value is interpreted.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ProbeKind {
    /// Monotonic count: the series is the windowed rate (`<name>.rate`).
    Counter,
    /// Point-in-time level: sampled directly under the probe's name.
    Gauge,
}

struct Probe {
    name: String,
    kind: ProbeKind,
    read: Box<dyn Fn() -> f64 + Send>,
    last: Option<f64>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CoarsePoint {
    pub at_ms: u64,
    pub mean: f64,
    pub max: f64,
}

struct CoarseAcc {
    start_ms: u64,
    sum: f64,
    max: f64,
    n: usize,
}

#[derive(Default)]
struct Series {
    fine: VecDeque<(u64, f64)>,
    coarse: VecDeque<CoarsePoint>,
    acc: Option<CoarseAcc>,
}

impl Series {
    fn push(&mut self, at_ms: u64, v: f64, cfg: &SamplerConfig) {
        self.fine.push_back((at_ms, v));
        while self.fine.len() > cfg.fine_capacity {
            self.fine.pop_front();
        }
        let acc = self.acc.get_or_insert(CoarseAcc {
            start_ms: at_ms,
            sum: 0.0,
            max: f64::MIN,
            n: 0,
        });
        acc.sum += v;
        acc.max = acc.max.max(v);
        acc.n += 1;
        if acc.n >= cfg.coarse_every.max(1) {
            let point = CoarsePoint {
                at_ms: acc.start_ms,
                mean: acc.sum / acc.n as f64,
                max: acc.max,
            };
            self.acc = None;
            self.coarse.push_back(point);
            while self.coarse.len() > cfg.coarse_capacity {
                self.coarse.pop_front();
            }
        }
    }
}

/// The sampler state machine. Owns no thread: callers (the
/// [`crate::obs::Observability`] loop, or tests) drive [`Sampler::tick`]
/// with an explicit clock, which keeps every transition deterministic.
pub struct Sampler {
    registry: MetricsRegistry,
    cfg: SamplerConfig,
    series: BTreeMap<String, Series>,
    last_counter: BTreeMap<String, u64>,
    probes: Vec<Probe>,
    last_tick_ms: Option<u64>,
    ticks: u64,
}

impl Sampler {
    pub fn new(registry: MetricsRegistry, cfg: SamplerConfig) -> Self {
        Self {
            registry,
            cfg,
            series: BTreeMap::new(),
            last_counter: BTreeMap::new(),
            probes: Vec::new(),
            last_tick_ms: None,
            ticks: 0,
        }
    }

    pub fn config(&self) -> &SamplerConfig {
        &self.cfg
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Register an external value source (executor steals, trace-ring
    /// drops). `Counter` probes are surfaced as `<name>.rate`.
    pub fn add_probe(
        &mut self,
        name: impl Into<String>,
        kind: ProbeKind,
        read: impl Fn() -> f64 + Send + 'static,
    ) {
        self.probes.push(Probe { name: name.into(), kind, read: Box::new(read), last: None });
    }

    /// Take one snapshot at `now_ms` (milliseconds on the caller's
    /// monotonic clock). Counter rates are deltas against the previous
    /// tick, clamped at zero so a registry `clear()` between ticks can
    /// never produce a negative rate.
    pub fn tick(&mut self, now_ms: u64) {
        let dt_s = match self.last_tick_ms {
            Some(prev) => (now_ms.saturating_sub(prev) as f64 / 1000.0).max(1e-6),
            None => f64::INFINITY, // first tick: every rate is 0
        };
        self.last_tick_ms = Some(now_ms);
        self.ticks += 1;

        let handles = self.registry.handles();
        for (name, c) in handles.counters {
            let cur = c.get();
            let prev = *self.last_counter.get(&name).unwrap_or(&cur);
            self.last_counter.insert(name.clone(), cur);
            let rate = cur.saturating_sub(prev) as f64 / dt_s;
            self.push(format!("{name}.rate"), now_ms, rate);
        }
        for (name, g) in handles.gauges {
            self.push(name, now_ms, g.get() as f64);
        }
        for (name, h) in handles.histograms {
            if h.count() == 0 {
                continue;
            }
            let p50 = h.quantile(0.5).as_micros() as f64;
            let p99 = h.quantile(0.99).as_micros() as f64;
            let p999 = h.quantile(0.999).as_micros() as f64;
            self.push(format!("{name}.p50"), now_ms, p50);
            self.push(format!("{name}.p99"), now_ms, p99);
            self.push(format!("{name}.p999"), now_ms, p999);
        }
        for i in 0..self.probes.len() {
            let raw = (self.probes[i].read)();
            match self.probes[i].kind {
                ProbeKind::Gauge => {
                    let name = self.probes[i].name.clone();
                    self.push(name, now_ms, raw);
                }
                ProbeKind::Counter => {
                    let prev = self.probes[i].last.unwrap_or(raw);
                    self.probes[i].last = Some(raw);
                    let rate = (raw - prev).max(0.0) / dt_s;
                    let name = format!("{}.rate", self.probes[i].name);
                    self.push(name, now_ms, rate);
                }
            }
        }
    }

    fn push(&mut self, name: String, at_ms: u64, v: f64) {
        self.series.entry(name).or_default().push(at_ms, v, &self.cfg);
    }

    pub fn names(&self) -> Vec<&str> {
        self.series.keys().map(String::as_str).collect()
    }

    /// Most recent sample of a series.
    pub fn latest(&self, name: &str) -> Option<f64> {
        self.series.get(name).and_then(|s| s.fine.back()).map(|&(_, v)| v)
    }

    /// Fine samples with `at_ms >= since_ms`, oldest first.
    pub fn window(&self, name: &str, since_ms: u64) -> Vec<(u64, f64)> {
        match self.series.get(name) {
            Some(s) => s.fine.iter().copied().filter(|&(t, _)| t >= since_ms).collect(),
            None => Vec::new(),
        }
    }

    /// The downsampled long-horizon ring for one series.
    pub fn coarse(&self, name: &str) -> Vec<CoarsePoint> {
        match self.series.get(name) {
            Some(s) => s.coarse.iter().copied().collect(),
            None => Vec::new(),
        }
    }

    /// Every series' fine tail inside the window, as
    /// `{name: [[at_ms, value], ...]}` — the flight recorder's payload.
    pub fn tail_json(&self, now_ms: u64, window: Duration) -> Json {
        let since = now_ms.saturating_sub(window.as_millis() as u64);
        let pairs: Vec<(String, Json)> = self
            .series
            .iter()
            .map(|(name, s)| {
                let points: Vec<Json> = s
                    .fine
                    .iter()
                    .filter(|&&(t, _)| t >= since)
                    .map(|&(t, v)| Json::arr(vec![Json::num(t as f64), Json::num(v)]))
                    .collect();
                (name.clone(), Json::arr(points))
            })
            .collect();
        Json::Obj(pairs.into_iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    fn cfg() -> SamplerConfig {
        SamplerConfig { fine_capacity: 8, coarse_every: 4, coarse_capacity: 4, ..Default::default() }
    }

    #[test]
    fn counters_become_rates_gauges_sample_directly() {
        let m = MetricsRegistry::new();
        let mut s = Sampler::new(m.clone(), cfg());
        m.counter("c").add(10);
        m.gauge("g").set(7);
        s.tick(0); // first tick: baseline, rate 0
        assert_eq!(s.latest("c.rate"), Some(0.0));
        assert_eq!(s.latest("g"), Some(7.0));
        m.counter("c").add(50);
        m.gauge("g").set(3);
        s.tick(1000);
        assert_eq!(s.latest("c.rate"), Some(50.0), "50 increments over 1s");
        assert_eq!(s.latest("g"), Some(3.0));
    }

    #[test]
    fn histograms_sample_p50_and_p99() {
        let m = MetricsRegistry::new();
        let mut s = Sampler::new(m.clone(), cfg());
        for _ in 0..100 {
            m.histogram("h").record(Duration::from_micros(10));
        }
        s.tick(0);
        assert_eq!(s.latest("h.p50"), Some(10.0));
        assert!(s.latest("h.p99").unwrap() >= 10.0);
        assert!(s.latest("h.p999").unwrap() >= s.latest("h.p99").unwrap());
    }

    #[test]
    fn registry_clear_between_ticks_never_yields_negative_rates() {
        let m = MetricsRegistry::new();
        let mut s = Sampler::new(m.clone(), cfg());
        m.counter("c").add(1000);
        s.tick(0);
        s.tick(100);
        m.clear();
        m.counter("c").add(1); // reborn counter, far below the old value
        s.tick(200);
        for (_, v) in s.window("c.rate", 0) {
            assert!(v >= 0.0, "rate went negative: {v}");
        }
    }

    #[test]
    fn rates_stay_nonnegative_under_concurrent_mutation() {
        // Writers hammer a counter and flip a gauge while the sampler
        // ticks as fast as it can: every rate sample must be finite and
        // >= 0 (no torn reads, no negative deltas).
        let m = MetricsRegistry::new();
        let stop = Arc::new(AtomicBool::new(false));
        let mut writers = Vec::new();
        for w in 0..4 {
            let m = m.clone();
            let stop = stop.clone();
            writers.push(std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    m.counter("hot").add(1 + w);
                    m.gauge("level").set(i % 1000);
                    i += 1;
                }
            }));
        }
        let mut s = Sampler::new(m.clone(), cfg());
        let clock = AtomicU64::new(0);
        for _ in 0..200 {
            s.tick(clock.fetch_add(5, Ordering::Relaxed));
        }
        stop.store(true, Ordering::Relaxed);
        for w in writers {
            w.join().unwrap();
        }
        let samples = s.window("hot.rate", 0);
        assert!(!samples.is_empty());
        for (_, v) in samples {
            assert!(v.is_finite() && v >= 0.0, "bad rate sample: {v}");
        }
    }

    #[test]
    fn rings_stay_bounded_and_coarse_downsamples_mean_and_max() {
        let m = MetricsRegistry::new();
        let mut s = Sampler::new(m.clone(), cfg());
        for i in 0..100u64 {
            m.gauge("g").set(i);
            s.tick(i * 10);
        }
        assert_eq!(s.window("g", 0).len(), 8, "fine ring capped at capacity");
        let coarse = s.coarse("g");
        assert_eq!(coarse.len(), 4, "coarse ring capped at capacity");
        let last = coarse.last().unwrap();
        // Each coarse point folds 4 consecutive gauge values i..i+4.
        assert!(last.max >= last.mean, "{last:?}");
        assert!(last.max <= 99.0);
    }

    #[test]
    fn counter_probes_rate_like_registry_counters() {
        let m = MetricsRegistry::new();
        let mut s = Sampler::new(m, cfg());
        let v = Arc::new(AtomicU64::new(0));
        let v2 = v.clone();
        s.add_probe("ext.steals", ProbeKind::Counter, move || v2.load(Ordering::Relaxed) as f64);
        s.tick(0);
        v.store(500, Ordering::Relaxed);
        s.tick(1000);
        assert_eq!(s.latest("ext.steals.rate"), Some(500.0));
        v.store(400, Ordering::Relaxed); // probe source reset
        s.tick(2000);
        assert_eq!(s.latest("ext.steals.rate"), Some(0.0), "clamped, never negative");
    }

    #[test]
    fn tail_json_windows_each_series() {
        let m = MetricsRegistry::new();
        let mut s = Sampler::new(m.clone(), cfg());
        for i in 0..8u64 {
            m.gauge("g").set(i);
            s.tick(i * 100);
        }
        let j = s.tail_json(700, Duration::from_millis(300));
        let arr = j.req("g").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 4, "samples at 400..=700 only: {j:?}");
    }
}
