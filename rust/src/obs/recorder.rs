//! Flight recorder: when a job fails or a watchdog rule goes
//! critical, capture everything an operator needs for a post-mortem
//! into one JSON bundle — the last N seconds of every sampler series,
//! the recent span archive, a full `report_json` registry snapshot,
//! and the watchdog rule states + transition log.
//!
//! Bundles round-trip: [`capture`] → [`write`] → [`load`] →
//! [`render`], and `adcloud postmortem <bundle>` is a thin CLI over
//! `load` + `render`.

use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::metrics::MetricsRegistry;
use crate::obs::sampler::Sampler;
use crate::obs::watchdog::Watchdog;
use crate::trace::{self, SpanEvent};
use crate::util::json::Json;

pub const BUNDLE_VERSION: u64 = 1;

fn span_json(e: &SpanEvent) -> Json {
    let args: Vec<(&str, Json)> = e
        .args()
        .iter()
        .map(|&(k, v)| (k, Json::num(v as f64)))
        .collect();
    Json::obj(vec![
        ("name", Json::str(e.name)),
        ("cat", Json::str(e.cat.label())),
        ("trace_id", Json::num(e.trace_id as f64)),
        ("span_id", Json::num(e.span_id as f64)),
        ("parent_id", Json::num(e.parent_id as f64)),
        ("start_us", Json::num(e.start_us as f64)),
        ("end_us", Json::num(e.end_us as f64)),
        ("tid", Json::num(e.tid as f64)),
        ("args", Json::obj(args)),
    ])
}

/// Snapshot one post-mortem bundle. `now_ms` is the sampler clock;
/// `window` bounds how much series history the bundle carries and
/// `max_spans` caps the span archive copy.
pub fn capture(
    reason: &str,
    now_ms: u64,
    sampler: &Sampler,
    watchdog: &Watchdog,
    registry: &MetricsRegistry,
    window: Duration,
    max_spans: usize,
) -> Json {
    let spans: Vec<Json> = trace::tracer()
        .recent(max_spans)
        .iter()
        .map(span_json)
        .collect();
    Json::obj(vec![
        ("version", Json::num(BUNDLE_VERSION as f64)),
        ("reason", Json::str(reason)),
        ("at_ms", Json::num(now_ms as f64)),
        ("window_ms", Json::num(window.as_millis() as f64)),
        ("series", sampler.tail_json(now_ms, window)),
        ("spans", Json::arr(spans)),
        ("metrics", registry.report_json()),
        ("rules", watchdog.states_json()),
        ("transitions", watchdog.transitions_json()),
    ])
}

pub fn write(path: impl AsRef<Path>, bundle: &Json) -> Result<()> {
    let path = path.as_ref();
    std::fs::write(path, bundle.to_string_pretty())
        .with_context(|| format!("writing flight-recorder bundle {}", path.display()))
}

pub fn load(path: impl AsRef<Path>) -> Result<Json> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading flight-recorder bundle {}", path.display()))?;
    Json::parse(&text).with_context(|| format!("parsing bundle {}", path.display()))
}

/// Pretty-print a bundle for `adcloud postmortem`: the reason, every
/// non-ok rule, the transition history, the tail value of each series,
/// and the slowest recent spans.
pub fn render(bundle: &Json) -> Result<String> {
    use std::fmt::Write as _;
    let mut out = String::new();
    let reason = bundle.req("reason")?.as_str()?;
    let at_ms = bundle.req("at_ms")?.as_f64()?;
    writeln!(out, "== flight recorder bundle ==").unwrap();
    writeln!(out, "reason:  {reason}").unwrap();
    writeln!(out, "at:      t+{:.1}s (sampler clock)", at_ms / 1000.0).unwrap();

    writeln!(out, "\n-- watchdog rules --").unwrap();
    for row in bundle.req("rules")?.as_arr()? {
        let level = row.req("level")?.as_str()?;
        let marker = match level {
            "critical" => "!!",
            "warn" => " !",
            _ => "  ",
        };
        writeln!(
            out,
            "{marker} {:<18} {:<8} value {:>12.1}  (warn {:.0} / critical {:.0})  {}",
            row.req("rule")?.as_str()?,
            level,
            row.req("value")?.as_f64()?,
            row.req("warn")?.as_f64()?,
            row.req("critical")?.as_f64()?,
            row.req("series")?.as_str()?,
        )
        .unwrap();
    }

    let transitions = bundle.req("transitions")?.as_arr()?;
    if !transitions.is_empty() {
        writeln!(out, "\n-- transitions --").unwrap();
        for t in transitions {
            writeln!(
                out,
                "  t+{:>8.1}s  {:<18} {} -> {}  (value {:.1})",
                t.req("at_ms")?.as_f64()? / 1000.0,
                t.req("rule")?.as_str()?,
                t.req("from")?.as_str()?,
                t.req("to")?.as_str()?,
                t.req("value")?.as_f64()?,
            )
            .unwrap();
        }
    }

    writeln!(out, "\n-- series (tail of recorded window) --").unwrap();
    for (name, points) in bundle.req("series")?.as_obj()? {
        let points = points.as_arr()?;
        let last = match points.last() {
            Some(p) => p.as_arr()?[1].as_f64()?,
            None => continue,
        };
        let max = points
            .iter()
            .filter_map(|p| p.as_arr().ok().and_then(|a| a[1].as_f64().ok()))
            .fold(f64::MIN, f64::max);
        writeln!(out, "  {name:<44} last {last:>14.2}  max {max:>14.2}  n={}", points.len())
            .unwrap();
    }

    let spans = bundle.req("spans")?.as_arr()?;
    writeln!(out, "\n-- spans ({} recorded) --", spans.len()).unwrap();
    let mut slowest: Vec<(&Json, f64)> = spans
        .iter()
        .map(|s| {
            let d = s.req("end_us").and_then(|e| e.as_f64()).unwrap_or(0.0)
                - s.req("start_us").and_then(|e| e.as_f64()).unwrap_or(0.0);
            (s, d)
        })
        .collect();
    slowest.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (s, d) in slowest.iter().take(10) {
        writeln!(
            out,
            "  {:<24} {:<18} {:>10.0}us  trace {}",
            s.req("name")?.as_str()?,
            s.req("cat")?.as_str()?,
            d,
            s.req("trace_id")?.as_f64()?,
        )
        .unwrap();
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::sampler::SamplerConfig;
    use crate::obs::watchdog::{builtin_rules, Watchdog};

    #[test]
    fn bundle_round_trips_through_write_load_render() {
        let _g = trace::testing::serial();
        let m = MetricsRegistry::new();
        m.counter("storage.tiered.evict.mem").add(5000);
        m.gauge("ingest.gateway.dlq_depth").set(75);
        m.histogram("platform.job.grant_wait").record(Duration::from_millis(200));
        let mut s = Sampler::new(m.clone(), SamplerConfig::default());
        s.tick(0);
        s.tick(1000);
        let mut w = Watchdog::new(builtin_rules(Duration::ZERO));
        w.eval(1000, |name| s.latest(name));
        assert!(
            w.level("ingest-dlq") == Some(crate::obs::Level::Critical),
            "dlq_depth 75 must trip the built-in rule"
        );

        let bundle = capture("test breach", 1000, &s, &w, &m, Duration::from_secs(30), 64);
        let dir = std::env::temp_dir().join(format!("adcloud-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bundle-roundtrip.json");
        write(&path, &bundle).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded, bundle, "bundle must survive the disk round-trip");

        let text = render(&loaded).unwrap();
        assert!(text.contains("test breach"));
        assert!(text.contains("ingest-dlq"));
        assert!(text.contains("critical"));
        assert!(text.contains("ingest.gateway.dlq_depth"));
        std::fs::remove_file(&path).ok();
    }
}
