//! Flight recorder: when a job fails or a watchdog rule goes
//! critical, capture everything an operator needs for a post-mortem
//! into one JSON bundle — the last N seconds of every sampler series,
//! the recent span archive, a full `report_json` registry snapshot,
//! and the watchdog rule states + transition log.
//!
//! Bundles round-trip: [`capture`] → [`write`] → [`load`] →
//! [`render`], and `adcloud postmortem <bundle>` is a thin CLI over
//! `load` + `render`.

use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::metrics::MetricsRegistry;
use crate::obs::sampler::Sampler;
use crate::obs::watchdog::Watchdog;
use crate::trace::{self, SpanEvent};
use crate::util::json::Json;

pub const BUNDLE_VERSION: u64 = 1;

fn span_json(e: &SpanEvent) -> Json {
    let args: Vec<(&str, Json)> = e
        .args()
        .iter()
        .map(|&(k, v)| (k, Json::num(v as f64)))
        .collect();
    Json::obj(vec![
        ("name", Json::str(e.name)),
        ("cat", Json::str(e.cat.label())),
        ("trace_id", Json::num(e.trace_id as f64)),
        ("span_id", Json::num(e.span_id as f64)),
        ("parent_id", Json::num(e.parent_id as f64)),
        ("start_us", Json::num(e.start_us as f64)),
        ("end_us", Json::num(e.end_us as f64)),
        ("tid", Json::num(e.tid as f64)),
        ("args", Json::obj(args)),
    ])
}

/// Snapshot one post-mortem bundle. `now_ms` is the sampler clock;
/// `window` bounds how much series history the bundle carries and
/// `max_spans` caps the span archive copy.
pub fn capture(
    reason: &str,
    now_ms: u64,
    sampler: &Sampler,
    watchdog: &Watchdog,
    registry: &MetricsRegistry,
    window: Duration,
    max_spans: usize,
) -> Json {
    let spans: Vec<Json> = trace::tracer()
        .recent(max_spans)
        .iter()
        .map(span_json)
        .collect();
    Json::obj(vec![
        ("version", Json::num(BUNDLE_VERSION as f64)),
        ("reason", Json::str(reason)),
        ("at_ms", Json::num(now_ms as f64)),
        ("window_ms", Json::num(window.as_millis() as f64)),
        ("series", sampler.tail_json(now_ms, window)),
        ("spans", Json::arr(spans)),
        ("metrics", registry.report_json()),
        ("rules", watchdog.states_json()),
        ("transitions", watchdog.transitions_json()),
    ])
}

pub fn write(path: impl AsRef<Path>, bundle: &Json) -> Result<()> {
    let path = path.as_ref();
    std::fs::write(path, bundle.to_string_pretty())
        .with_context(|| format!("writing flight-recorder bundle {}", path.display()))
}

/// Cap the bundle dir at `budget_bytes`: evict the oldest
/// `postmortem-*.json` files (by mtime, filename as tiebreak) until
/// the total fits. The newest bundle is never evicted — an over-sized
/// post-mortem still beats no post-mortem. Other files in the dir are
/// neither counted nor touched. `budget_bytes == 0` means unbounded.
/// Returns the number of bundles evicted.
pub fn enforce_retention(dir: impl AsRef<Path>, budget_bytes: u64) -> Result<u64> {
    let dir = dir.as_ref();
    if budget_bytes == 0 {
        return Ok(0);
    }
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(0), // dir not created yet: nothing to evict
    };
    let mut bundles: Vec<(std::time::SystemTime, String, u64)> = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if !(name.starts_with("postmortem-") && name.ends_with(".json")) {
            continue;
        }
        let Ok(meta) = entry.metadata() else { continue };
        let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
        bundles.push((mtime, name, meta.len()));
    }
    let mut total: u64 = bundles.iter().map(|b| b.2).sum();
    if total <= budget_bytes {
        return Ok(0);
    }
    bundles.sort();
    let mut evicted = 0u64;
    for (_, name, size) in bundles.iter().take(bundles.len() - 1) {
        if total <= budget_bytes {
            break;
        }
        std::fs::remove_file(dir.join(name))
            .with_context(|| format!("evicting flight-recorder bundle {name}"))?;
        total -= size;
        evicted += 1;
    }
    Ok(evicted)
}

pub fn load(path: impl AsRef<Path>) -> Result<Json> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading flight-recorder bundle {}", path.display()))?;
    Json::parse(&text).with_context(|| format!("parsing bundle {}", path.display()))
}

/// Pretty-print a bundle for `adcloud postmortem`: the reason, every
/// non-ok rule, the transition history, the tail value of each series,
/// and the slowest recent spans.
pub fn render(bundle: &Json) -> Result<String> {
    use std::fmt::Write as _;
    let mut out = String::new();
    let reason = bundle.req("reason")?.as_str()?;
    let at_ms = bundle.req("at_ms")?.as_f64()?;
    writeln!(out, "== flight recorder bundle ==").unwrap();
    writeln!(out, "reason:  {reason}").unwrap();
    writeln!(out, "at:      t+{:.1}s (sampler clock)", at_ms / 1000.0).unwrap();

    writeln!(out, "\n-- watchdog rules --").unwrap();
    for row in bundle.req("rules")?.as_arr()? {
        let level = row.req("level")?.as_str()?;
        let marker = match level {
            "critical" => "!!",
            "warn" => " !",
            _ => "  ",
        };
        writeln!(
            out,
            "{marker} {:<18} {:<8} value {:>12.1}  (warn {:.0} / critical {:.0})  {}",
            row.req("rule")?.as_str()?,
            level,
            row.req("value")?.as_f64()?,
            row.req("warn")?.as_f64()?,
            row.req("critical")?.as_f64()?,
            row.req("series")?.as_str()?,
        )
        .unwrap();
    }

    let transitions = bundle.req("transitions")?.as_arr()?;
    if !transitions.is_empty() {
        writeln!(out, "\n-- transitions --").unwrap();
        for t in transitions {
            writeln!(
                out,
                "  t+{:>8.1}s  {:<18} {} -> {}  (value {:.1})",
                t.req("at_ms")?.as_f64()? / 1000.0,
                t.req("rule")?.as_str()?,
                t.req("from")?.as_str()?,
                t.req("to")?.as_str()?,
                t.req("value")?.as_f64()?,
            )
            .unwrap();
        }
    }

    writeln!(out, "\n-- series (tail of recorded window) --").unwrap();
    for (name, points) in bundle.req("series")?.as_obj()? {
        let points = points.as_arr()?;
        let last = match points.last() {
            Some(p) => p.as_arr()?[1].as_f64()?,
            None => continue,
        };
        let max = points
            .iter()
            .filter_map(|p| p.as_arr().ok().and_then(|a| a[1].as_f64().ok()))
            .fold(f64::MIN, f64::max);
        writeln!(out, "  {name:<44} last {last:>14.2}  max {max:>14.2}  n={}", points.len())
            .unwrap();
    }

    let spans = bundle.req("spans")?.as_arr()?;
    writeln!(out, "\n-- spans ({} recorded) --", spans.len()).unwrap();
    let mut slowest: Vec<(&Json, f64)> = spans
        .iter()
        .map(|s| {
            let d = s.req("end_us").and_then(|e| e.as_f64()).unwrap_or(0.0)
                - s.req("start_us").and_then(|e| e.as_f64()).unwrap_or(0.0);
            (s, d)
        })
        .collect();
    slowest.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (s, d) in slowest.iter().take(10) {
        writeln!(
            out,
            "  {:<24} {:<18} {:>10.0}us  trace {}",
            s.req("name")?.as_str()?,
            s.req("cat")?.as_str()?,
            d,
            s.req("trace_id")?.as_f64()?,
        )
        .unwrap();
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::sampler::SamplerConfig;
    use crate::obs::watchdog::{builtin_rules, Watchdog};

    #[test]
    fn bundle_round_trips_through_write_load_render() {
        let _g = trace::testing::serial();
        let m = MetricsRegistry::new();
        m.counter("storage.tiered.evict.mem").add(5000);
        m.gauge("ingest.gateway.dlq_depth").set(75);
        m.histogram("platform.job.grant_wait").record(Duration::from_millis(200));
        let mut s = Sampler::new(m.clone(), SamplerConfig::default());
        s.tick(0);
        s.tick(1000);
        let mut w = Watchdog::new(builtin_rules(Duration::ZERO));
        w.eval(1000, |name| s.latest(name));
        assert!(
            w.level("ingest-dlq") == Some(crate::obs::Level::Critical),
            "dlq_depth 75 must trip the built-in rule"
        );

        let bundle = capture("test breach", 1000, &s, &w, &m, Duration::from_secs(30), 64);
        let dir = std::env::temp_dir().join(format!("adcloud-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bundle-roundtrip.json");
        write(&path, &bundle).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded, bundle, "bundle must survive the disk round-trip");

        let text = render(&loaded).unwrap();
        assert!(text.contains("test breach"));
        assert!(text.contains("ingest-dlq"));
        assert!(text.contains("critical"));
        assert!(text.contains("ingest.gateway.dlq_depth"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn retention_evicts_oldest_bundles_until_the_dir_fits() {
        let dir = std::env::temp_dir()
            .join(format!("adcloud-obs-retention-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        // Five 100-byte bundles, oldest first (zero-padded names break
        // same-millisecond mtime ties deterministically).
        for i in 0..5 {
            std::fs::write(dir.join(format!("postmortem-{i}.json")), vec![b'x'; 100]).unwrap();
        }
        // A non-bundle file: neither counted against the budget nor evicted.
        std::fs::write(dir.join("notes.txt"), vec![b'y'; 1000]).unwrap();

        // 500 bytes resident, 250 allowed: bundles 0, 1, 2 must go.
        assert_eq!(enforce_retention(&dir, 250).unwrap(), 3);
        for i in 0..3 {
            assert!(!dir.join(format!("postmortem-{i}.json")).exists(), "bundle {i} kept");
        }
        for i in 3..5 {
            assert!(dir.join(format!("postmortem-{i}.json")).exists(), "bundle {i} evicted");
        }
        assert!(dir.join("notes.txt").exists(), "non-bundle file must be untouched");

        // Under budget now: a second pass is a no-op.
        assert_eq!(enforce_retention(&dir, 250).unwrap(), 0);
        // A budget smaller than one bundle still keeps the newest.
        assert_eq!(enforce_retention(&dir, 10).unwrap(), 1);
        assert!(dir.join("postmortem-4.json").exists(), "newest bundle must survive");
        // Zero budget means unbounded, not scorched earth.
        assert_eq!(enforce_retention(&dir, 0).unwrap(), 0);
        assert!(dir.join("postmortem-4.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
