//! The telemetry plane: sampler → watchdogs → flight recorder.
//!
//! [`Observability`] owns one background thread that, once per
//! [`SamplerConfig::period`]:
//!
//! 1. ticks the [`sampler`] (registry counters → windowed rates,
//!    gauges direct, histograms at p50/p99, plus external probes such
//!    as executor steal counts and trace-ring drops),
//! 2. evaluates the [`watchdog`] rules against the fresh samples —
//!    each ok→warn→critical transition is emitted as a `trace` span
//!    (`slo.warn` / `slo.critical` / `slo.clear`) so breaches land in
//!    the same causal timeline as the work they disturbed, and
//! 3. on a transition *into* critical, asks the [`recorder`] for a
//!    post-mortem bundle (auto-written when a bundle dir is set).
//!
//! The job layer reports failures through the process-wide hook
//! ([`install`] / [`job_failed`]); `runtime::ObsServer` serves the
//! same state over HTTP as `/metrics` (Prometheus text) and
//! `/healthz` (watchdog rollup JSON).
//!
//! Everything here stays off the hot paths: recording a metric or a
//! span never touches an `obs` lock — the sampler reads the shared
//! atomics from its own thread.

pub mod recorder;
pub mod sampler;
pub mod watchdog;

pub use sampler::{ProbeKind, Sampler, SamplerConfig};
pub use watchdog::{builtin_rules, serve_rules, Level, Rule, Transition, Watchdog};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::MetricsRegistry;
use crate::trace;
use crate::util::json::Json;

#[derive(Clone)]
pub struct ObsConfig {
    pub sampler: SamplerConfig,
    pub rules: Vec<Rule>,
    /// How much series history a post-mortem bundle carries.
    pub bundle_window: Duration,
    /// Span-archive cap per bundle.
    pub bundle_spans: usize,
    /// When set, critical breaches and reported job failures write
    /// `postmortem-*.json` bundles here automatically.
    pub bundle_dir: Option<PathBuf>,
    /// Byte budget for the bundle dir: after each auto-written bundle,
    /// the oldest `postmortem-*.json` files are evicted until the dir
    /// fits (the newest bundle always survives). `0` = unbounded.
    pub bundle_budget_bytes: u64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            sampler: SamplerConfig::default(),
            rules: builtin_rules(Duration::from_millis(500)),
            bundle_window: Duration::from_secs(30),
            bundle_spans: 512,
            bundle_dir: None,
            bundle_budget_bytes: 64 << 20,
        }
    }
}

struct ObsState {
    sampler: Sampler,
    watchdog: Watchdog,
}

/// The live telemetry plane for one registry. Create with
/// [`Observability::start`]; the sampling thread stops on drop.
pub struct Observability {
    cfg: ObsConfig,
    registry: MetricsRegistry,
    start: Instant,
    state: Mutex<ObsState>,
    stop: AtomicBool,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    bundles: AtomicU64,
    last_bundle: Mutex<Option<Json>>,
}

impl Observability {
    /// Spawn the sampling/watchdog thread over `registry`.
    pub fn start(registry: MetricsRegistry, cfg: ObsConfig) -> Arc<Self> {
        let obs = Arc::new(Self {
            state: Mutex::new(ObsState {
                sampler: Sampler::new(registry.clone(), cfg.sampler.clone()),
                watchdog: Watchdog::new(cfg.rules.clone()),
            }),
            cfg,
            registry,
            start: Instant::now(),
            stop: AtomicBool::new(false),
            thread: Mutex::new(None),
            bundles: AtomicU64::new(0),
            last_bundle: Mutex::new(None),
        });
        let weak = Arc::downgrade(&obs);
        let period = obs.cfg.sampler.period;
        let handle = std::thread::Builder::new()
            .name("obs-sampler".into())
            .spawn(move || loop {
                std::thread::sleep(period);
                match weak.upgrade() {
                    Some(obs) if !obs.stop.load(Ordering::Relaxed) => obs.tick_once(),
                    _ => break,
                }
            })
            .expect("spawn obs-sampler thread");
        *obs.thread.lock().unwrap() = Some(handle);
        obs
    }

    /// Milliseconds since this plane started — the sampler clock.
    pub fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// Register an external value source on the sampler.
    pub fn add_probe(
        &self,
        name: impl Into<String>,
        kind: ProbeKind,
        read: impl Fn() -> f64 + Send + 'static,
    ) {
        self.state.lock().unwrap().sampler.add_probe(name, kind, read);
    }

    /// One sampler tick + watchdog evaluation. The background thread
    /// calls this on its period; tests call it directly.
    pub fn tick_once(&self) {
        let now_ms = self.now_ms();
        let mut criticals = Vec::new();
        {
            let mut st = self.state.lock().unwrap();
            let ObsState { sampler, watchdog } = &mut *st;
            sampler.tick(now_ms);
            let fired = watchdog.eval(now_ms, |name| sampler.latest(name));
            for t in &fired {
                let mut sp = trace::span(t.to.span_name(), trace::Category::Other);
                sp.arg("rule", t.rule_idx as u64);
                sp.arg("value", t.value as u64);
                if t.to == Level::Critical {
                    criticals.push(*t);
                }
            }
        }
        for t in criticals {
            let reason =
                format!("slo breach: rule '{}' went critical (value {:.1})", t.rule, t.value);
            self.record_bundle(&reason);
        }
    }

    /// Latest sample of a series (see [`Sampler::latest`]).
    pub fn latest(&self, series: &str) -> Option<f64> {
        self.state.lock().unwrap().sampler.latest(series)
    }

    pub fn rule_level(&self, rule: &str) -> Option<Level> {
        self.state.lock().unwrap().watchdog.level(rule)
    }

    pub fn rule_value(&self, rule: &str) -> Option<f64> {
        self.state.lock().unwrap().watchdog.last_value(rule)
    }

    pub fn overall(&self) -> Level {
        self.state.lock().unwrap().watchdog.overall()
    }

    /// Bundles captured so far (breaches + reported job failures).
    pub fn bundles_captured(&self) -> u64 {
        self.bundles.load(Ordering::Relaxed)
    }

    /// The most recent post-mortem bundle, if any was captured.
    pub fn last_bundle(&self) -> Option<Json> {
        self.last_bundle.lock().unwrap().clone()
    }

    /// Capture a post-mortem bundle right now.
    pub fn capture_bundle(&self, reason: &str) -> Json {
        let now_ms = self.now_ms();
        let st = self.state.lock().unwrap();
        recorder::capture(
            reason,
            now_ms,
            &st.sampler,
            &st.watchdog,
            &self.registry,
            self.cfg.bundle_window,
            self.cfg.bundle_spans,
        )
    }

    fn record_bundle(&self, reason: &str) {
        let bundle = self.capture_bundle(reason);
        let n = self.bundles.fetch_add(1, Ordering::Relaxed);
        if let Some(dir) = &self.cfg.bundle_dir {
            let _ = std::fs::create_dir_all(dir);
            let path = dir.join(format!("postmortem-{}-{n}.json", std::process::id()));
            if let Err(e) = recorder::write(&path, &bundle) {
                eprintln!("obs: failed to write post-mortem bundle: {e:#}");
            }
            self.enforce_bundle_retention();
        }
        *self.last_bundle.lock().unwrap() = Some(bundle);
    }

    /// Apply [`ObsConfig::bundle_budget_bytes`] to the bundle dir
    /// (oldest-first eviction; no-op without a dir or budget). Runs
    /// after every auto-written bundle; returns how many were evicted.
    pub fn enforce_bundle_retention(&self) -> u64 {
        let Some(dir) = &self.cfg.bundle_dir else { return 0 };
        match recorder::enforce_retention(dir, self.cfg.bundle_budget_bytes) {
            Ok(n) => {
                if n > 0 {
                    self.registry.counter("obs.recorder.bundles_evicted").add(n);
                }
                n
            }
            Err(e) => {
                eprintln!("obs: bundle retention enforcement failed: {e:#}");
                0
            }
        }
    }

    /// Capture + write a bundle to an explicit path (CI artifacts,
    /// `jobs --force-postmortem`).
    pub fn write_bundle(&self, reason: &str, path: impl AsRef<std::path::Path>) -> Result<()> {
        let bundle = self.capture_bundle(reason);
        self.bundles.fetch_add(1, Ordering::Relaxed);
        recorder::write(path, &bundle)?;
        *self.last_bundle.lock().unwrap() = Some(bundle);
        Ok(())
    }

    /// `/healthz` payload: worst level across rules + per-rule detail.
    pub fn health_json(&self) -> Json {
        let st = self.state.lock().unwrap();
        Json::obj(vec![
            ("status", Json::str(st.watchdog.overall().label())),
            ("rules", st.watchdog.states_json()),
        ])
    }

    /// `/metrics` payload: the registry in Prometheus text format.
    /// Scraped fresh from the shared atomics, not from the sampler.
    pub fn prometheus_text(&self) -> String {
        use std::fmt::Write as _;
        fn sanitize(name: &str) -> String {
            name.chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect()
        }
        let h = self.registry.handles();
        let mut out = String::new();
        for (name, c) in h.counters {
            let s = sanitize(&name);
            writeln!(out, "# TYPE {s} counter").unwrap();
            writeln!(out, "{s} {}", c.get()).unwrap();
        }
        for (name, g) in h.gauges {
            let s = sanitize(&name);
            writeln!(out, "# TYPE {s} gauge").unwrap();
            writeln!(out, "{s} {}", g.get()).unwrap();
        }
        for (name, hist) in h.histograms {
            let s = sanitize(&name);
            writeln!(out, "# TYPE {s}_count counter").unwrap();
            writeln!(out, "{s}_count {}", hist.count()).unwrap();
            for (suffix, v) in [
                ("p50_us", hist.quantile(0.5).as_micros() as u64),
                ("p99_us", hist.quantile(0.99).as_micros() as u64),
                ("max_us", hist.max().as_micros() as u64),
            ] {
                writeln!(out, "# TYPE {s}_{suffix} gauge").unwrap();
                writeln!(out, "{s}_{suffix} {v}").unwrap();
            }
        }
        out
    }

    /// One text-dashboard frame for `adcloud top`.
    pub fn dashboard(&self) -> String {
        use std::fmt::Write as _;
        const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let st = self.state.lock().unwrap();
        let mut out = String::new();
        writeln!(
            out,
            "adcloud top — t+{:.1}s, {} series, health: {}",
            self.start.elapsed().as_secs_f64(),
            st.sampler.names().len(),
            st.watchdog.overall().label()
        )
        .unwrap();
        writeln!(out, "\n{:<20} {:<9} {:>14}  thresholds", "rule", "level", "value").unwrap();
        for row in st.watchdog.rules().iter() {
            let level = st.watchdog.level(row.name).unwrap_or(Level::Ok);
            let value = st.watchdog.last_value(row.name).unwrap_or(0.0);
            writeln!(
                out,
                "{:<20} {:<9} {:>14.1}  warn {:.0} / crit {:.0}",
                row.name,
                level.label(),
                value,
                row.warn,
                row.critical
            )
            .unwrap();
        }
        writeln!(out, "\n{:<44} {:>14} {:>14}  last 32 ticks", "series", "last", "max").unwrap();
        let names: Vec<String> = st.sampler.names().iter().map(|s| s.to_string()).collect();
        for name in names {
            let tail = st.sampler.window(&name, 0);
            let tail = &tail[tail.len().saturating_sub(32)..];
            let last = tail.last().map(|&(_, v)| v).unwrap_or(0.0);
            let max = tail.iter().map(|&(_, v)| v).fold(f64::MIN, f64::max).max(1e-9);
            let spark: String = tail
                .iter()
                .map(|&(_, v)| {
                    let idx = ((v / max) * 7.0).round().clamp(0.0, 7.0) as usize;
                    SPARK[idx]
                })
                .collect();
            writeln!(out, "{name:<44} {last:>14.1} {max:>14.1}  {spark}").unwrap();
        }
        out
    }

    /// Stop and join the sampling thread (also runs on drop).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Observability {
    fn drop(&mut self) {
        self.stop();
    }
}

// ---------------------------------------------------------------- hook

static HOOK: OnceLock<Mutex<Option<Arc<Observability>>>> = OnceLock::new();

fn hook() -> &'static Mutex<Option<Arc<Observability>>> {
    HOOK.get_or_init(|| Mutex::new(None))
}

/// Make `obs` the process-wide telemetry plane: job failures reported
/// via [`job_failed`] capture flight-recorder bundles on it. Tests
/// that install must serialize (reuse `trace::testing::serial`).
pub fn install(obs: &Arc<Observability>) {
    *hook().lock().unwrap() = Some(obs.clone());
}

pub fn uninstall() {
    *hook().lock().unwrap() = None;
}

pub fn installed() -> Option<Arc<Observability>> {
    hook().lock().unwrap().clone()
}

/// Report a failed job to the installed telemetry plane (no-op when
/// none is installed). Called by the job layer on every error return.
pub fn job_failed(app: &str, err: &anyhow::Error) {
    if let Some(obs) = installed() {
        obs.record_bundle(&format!("job '{app}' failed: {err:#}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> ObsConfig {
        ObsConfig {
            sampler: SamplerConfig { period: Duration::from_millis(2), ..Default::default() },
            rules: builtin_rules(Duration::ZERO),
            ..Default::default()
        }
    }

    #[test]
    fn background_thread_samples_and_trips_rules() {
        let m = MetricsRegistry::new();
        let obs = Observability::start(m.clone(), fast_cfg());
        m.gauge("ingest.gateway.dlq_depth").set(500);
        let t0 = Instant::now();
        while obs.rule_level("ingest-dlq") != Some(Level::Critical) {
            assert!(t0.elapsed() < Duration::from_secs(5), "watchdog never tripped");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(obs.overall(), Level::Critical);
        assert!(obs.bundles_captured() >= 1, "critical breach must capture a bundle");
        let bundle = obs.last_bundle().unwrap();
        assert!(bundle.req("reason").unwrap().as_str().unwrap().contains("ingest-dlq"));
        obs.stop();
    }

    #[test]
    fn job_failed_hook_captures_a_bundle_when_installed() {
        let _g = trace::testing::serial();
        let m = MetricsRegistry::new();
        let obs = Observability::start(m, fast_cfg());
        install(&obs);
        job_failed("unit-app", &anyhow::anyhow!("simulated shard explosion"));
        uninstall();
        let bundle = obs.last_bundle().expect("hook must capture a bundle");
        let reason = bundle.req("reason").unwrap().as_str().unwrap().to_string();
        assert!(reason.contains("unit-app") && reason.contains("shard explosion"), "{reason}");
        assert!(job_failed_is_noop_without_hook());
        obs.stop();
    }

    fn job_failed_is_noop_without_hook() -> bool {
        job_failed("nobody-listening", &anyhow::anyhow!("x"));
        true
    }

    #[test]
    fn bundle_budget_evicts_oldest_and_counts() {
        let m = MetricsRegistry::new();
        let dir = std::env::temp_dir().join(format!("adcloud-obs-budget-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        for i in 0..4 {
            std::fs::write(dir.join(format!("postmortem-{i}.json")), vec![b'x'; 100]).unwrap();
        }
        let obs = Observability::start(
            m.clone(),
            ObsConfig {
                bundle_dir: Some(dir.clone()),
                bundle_budget_bytes: 200,
                ..fast_cfg()
            },
        );
        assert_eq!(obs.enforce_bundle_retention(), 2, "400 resident, 200 allowed");
        assert_eq!(m.counter("obs.recorder.bundles_evicted").get(), 2);
        assert_eq!(obs.enforce_bundle_retention(), 0, "under budget: no-op");
        assert_eq!(m.counter("obs.recorder.bundles_evicted").get(), 2);
        obs.stop();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prometheus_text_and_health_render() {
        let m = MetricsRegistry::new();
        m.counter("a.b").add(3);
        m.gauge("c.d").set(9);
        m.histogram("e.f").record(Duration::from_micros(100));
        let obs = Observability::start(m, fast_cfg());
        let text = obs.prometheus_text();
        assert!(text.contains("# TYPE a_b counter"));
        assert!(text.contains("a_b 3"));
        assert!(text.contains("c_d 9"));
        assert!(text.contains("e_f_count 1"));
        let health = obs.health_json();
        assert_eq!(health.req("status").unwrap().as_str().unwrap(), "ok");
        let dash = obs.dashboard();
        assert!(dash.contains("adcloud top"));
        obs.stop();
    }
}
