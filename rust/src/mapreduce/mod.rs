//! Disk-staged MapReduce baseline engine (the paper's Hadoop side).
//!
//! "MapReduce programs read input data from disk, map a function across
//! the data, reduce the results of the map, and store reduction results
//! on disk." This engine enforces exactly that linear dataflow: inputs
//! are [`MrFile`]s living on the DFS device, every map→reduce boundary
//! materialises through DFS-rate charges, every job ends with a DFS
//! write, and multi-stage pipelines are chains of independent jobs that
//! re-read their input from DFS. The 5X (section 2.1), 2X (section 4.1)
//! and 5X (section 5.2) comparisons pit this against the in-memory DCE.

use anyhow::Result;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

use crate::dce::{partition_of, Data, ExecutorPool};
use crate::metrics::MetricsRegistry;
use crate::storage::DfsStore;

fn est_bytes<T>(n: usize) -> u64 {
    (n * std::mem::size_of::<T>()) as u64 + 16
}

/// A dataset materialised on the DFS device.
pub struct MrFile<T: Data> {
    pub parts: Vec<Arc<Vec<T>>>,
}

impl<T: Data> MrFile<T> {
    pub fn num_records(&self) -> usize {
        self.parts.iter().map(|p| p.len()).sum()
    }

    pub fn collect(&self) -> Vec<T> {
        self.parts.iter().flat_map(|p| p.iter().cloned()).collect()
    }
}

/// The baseline engine.
pub struct MapReduceEngine {
    pool: ExecutorPool,
    dfs: Arc<DfsStore>,
    metrics: MetricsRegistry,
}

impl MapReduceEngine {
    pub fn new(workers: usize, dfs: Arc<DfsStore>, metrics: MetricsRegistry) -> Self {
        Self { pool: ExecutorPool::new(workers), dfs, metrics }
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    pub fn dfs(&self) -> &Arc<DfsStore> {
        &self.dfs
    }

    /// Materialise a local dataset as an input file on DFS (charged).
    pub fn write_file<T: Data>(&self, data: Vec<T>, parts: usize) -> Result<MrFile<T>> {
        let parts = parts.max(1);
        let per = data.len().div_ceil(parts).max(1);
        let mut chunks = Vec::new();
        let mut it = data.into_iter();
        for i in 0..parts {
            let chunk: Vec<T> = it.by_ref().take(per).collect();
            // Real DFS write of the charged size (placeholder payload —
            // the typed data itself stays in memory, the *cost* is real).
            self.dfs
                .write(
                    &format!("mr/input-{i:05}"),
                    &vec![0u8; est_bytes::<T>(chunk.len()) as usize],
                )?;
            chunks.push(Arc::new(chunk));
        }
        Ok(MrFile { parts: chunks })
    }

    /// One MapReduce job: DFS-read input → map → DFS-staged shuffle →
    /// group → reduce → DFS-write output.
    pub fn run<I, K, V, O>(
        &self,
        input: &MrFile<I>,
        mapper: impl Fn(&I) -> Vec<(K, V)> + Send + Sync + 'static,
        reducer: impl Fn(&K, Vec<V>) -> Vec<O> + Send + Sync + 'static,
        num_reducers: usize,
    ) -> Result<MrFile<O>>
    where
        I: Data,
        K: Data + Hash + Eq,
        V: Data,
        O: Data,
    {
        let num_reducers = num_reducers.max(1);
        let mapper = Arc::new(mapper);
        let reducer = Arc::new(reducer);
        self.metrics.counter("mapreduce.jobs").inc();

        // ---- map phase ---------------------------------------------------
        let map_tasks: Vec<Arc<dyn Fn(usize) -> Result<Vec<Vec<(K, V)>>> + Send + Sync>> = input
            .parts
            .iter()
            .enumerate()
            .map(|(mi, part)| {
                let part = part.clone();
                let mapper = mapper.clone();
                let dfs = self.dfs.clone();
                let f: Arc<dyn Fn(usize) -> Result<Vec<Vec<(K, V)>>> + Send + Sync> =
                    Arc::new(move |_| {
                        // Read input split from DFS (charged).
                        dfs.device().charge(est_bytes::<I>(part.len()));
                        let mut buckets: Vec<Vec<(K, V)>> =
                            (0..num_reducers).map(|_| Vec::new()).collect();
                        for rec in part.iter() {
                            for (k, v) in mapper(rec) {
                                buckets[partition_of(&k, num_reducers)].push((k, v));
                            }
                        }
                        // Spill every bucket to DFS (charged, real file).
                        for (r, b) in buckets.iter().enumerate() {
                            dfs.write(
                                &format!("mr/spill-{mi:05}-{r:05}"),
                                &vec![0u8; est_bytes::<(K, V)>(b.len()) as usize],
                            )?;
                        }
                        Ok(buckets)
                    });
                f
            })
            .collect();
        let map_outputs = self.pool.run_tasks(map_tasks, 1)?;

        // ---- shuffle + reduce phase --------------------------------------
        let map_outputs = Arc::new(map_outputs);
        let reduce_tasks: Vec<Arc<dyn Fn(usize) -> Result<Vec<O>> + Send + Sync>> = (0
            ..num_reducers)
            .map(|r| {
                let map_outputs = map_outputs.clone();
                let reducer = reducer.clone();
                let dfs = self.dfs.clone();
                let f: Arc<dyn Fn(usize) -> Result<Vec<O>> + Send + Sync> = Arc::new(move |_| {
                    // Fetch every map's spill for this reducer (charged).
                    let mut groups: HashMap<K, Vec<V>> = HashMap::new();
                    for mo in map_outputs.iter() {
                        let bucket = &mo[r];
                        dfs.device().charge(est_bytes::<(K, V)>(bucket.len()));
                        for (k, v) in bucket.iter().cloned() {
                            groups.entry(k).or_default().push(v);
                        }
                    }
                    let mut out = Vec::new();
                    for (k, vs) in groups {
                        out.extend(reducer(&k, vs));
                    }
                    // Write reducer output to DFS (charged, real file).
                    dfs.write(
                        &format!("mr/out-{r:05}"),
                        &vec![0u8; est_bytes::<O>(out.len()) as usize],
                    )?;
                    Ok(out)
                });
                f
            })
            .collect();
        let outputs = self.pool.run_tasks(reduce_tasks, 1)?;
        Ok(MrFile { parts: outputs.into_iter().map(Arc::new).collect() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TierConfig;

    fn engine() -> MapReduceEngine {
        let cfg = TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e9, latency_us: 0 };
        let dfs = DfsStore::new(cfg, false, MetricsRegistry::new()).unwrap();
        MapReduceEngine::new(4, dfs, MetricsRegistry::new())
    }

    #[test]
    fn wordcount_end_to_end() {
        let e = engine();
        let docs: Vec<String> = vec![
            "the quick brown fox".into(),
            "the lazy dog".into(),
            "the fox".into(),
        ];
        let input = e.write_file(docs, 2).unwrap();
        let out = e
            .run(
                &input,
                |doc: &String| {
                    doc.split_whitespace()
                        .map(|w| (w.to_string(), 1u64))
                        .collect()
                },
                |k: &String, vs: Vec<u64>| vec![(k.clone(), vs.into_iter().sum::<u64>())],
                3,
            )
            .unwrap();
        let mut counts: Vec<(String, u64)> = out.collect();
        counts.sort();
        let the = counts.iter().find(|(w, _)| w == "the").unwrap();
        assert_eq!(the.1, 3);
        let fox = counts.iter().find(|(w, _)| w == "fox").unwrap();
        assert_eq!(fox.1, 2);
    }

    #[test]
    fn every_stage_hits_dfs() {
        let e = engine();
        let input = e.write_file((0..100u64).collect::<Vec<_>>(), 4).unwrap();
        let before_ops = e.dfs.device().ops_total();
        let _ = e
            .run(
                &input,
                |x: &u64| vec![(x % 5, 1u64)],
                |_k: &u64, vs: Vec<u64>| vec![vs.len() as u64],
                2,
            )
            .unwrap();
        let ops = e.dfs.device().ops_total() - before_ops;
        // 4 input reads + 4x2 spill writes + 2x4 fetches + 2 output writes.
        assert!(ops >= 16, "only {ops} DFS ops charged");
    }

    #[test]
    fn chained_jobs_reread_from_dfs() {
        let e = engine();
        let input = e.write_file((0..50u64).collect::<Vec<_>>(), 2).unwrap();
        let stage1 = e
            .run(
                &input,
                |x: &u64| vec![(*x % 10, *x)],
                |k: &u64, vs: Vec<u64>| vec![(*k, vs.into_iter().sum::<u64>())],
                2,
            )
            .unwrap();
        let stage2 = e
            .run(
                &stage1,
                |&(k, s): &(u64, u64)| vec![(k % 2, s)],
                |k: &u64, vs: Vec<u64>| vec![(*k, vs.into_iter().sum::<u64>())],
                2,
            )
            .unwrap();
        let mut out = stage2.collect();
        out.sort();
        let total: u64 = out.iter().map(|(_, s)| s).sum();
        assert_eq!(total, (0..50).sum::<u64>());
        assert_eq!(e.metrics.counter("mapreduce.jobs").get(), 2);
    }

    #[test]
    fn empty_input_works() {
        let e = engine();
        let input = e.write_file(Vec::<u64>::new(), 2).unwrap();
        let out = e
            .run(
                &input,
                |x: &u64| vec![(*x, *x)],
                |_: &u64, v: Vec<u64>| v,
                2,
            )
            .unwrap();
        assert_eq!(out.num_records(), 0);
    }
}
