//! YARN-analog resource manager (paper section 2.3).
//!
//! "When a Spark application is launched, it can request heterogeneous
//! computing resources through YARN. YARN then allocates LXCs to satisfy
//! the request." This module is that allocator: applications register
//! against capacity-share queues, request containers carrying CPU cores,
//! memory, and GPU/FPGA device slots, and either get a grant, an error,
//! or (with [`ResourceManager::acquire_container`]) block until capacity
//! frees up.
//!
//! Three scheduler behaviours layer on top of the basic allocator:
//!
//! * **Elastic queues** — every queue has a *guaranteed* share and an
//!   *elastic ceiling* ([`ResourceManager::with_elastic_queues`]). A
//!   queue may borrow idle capacity up to its ceiling while siblings
//!   are quiet; [`ResourceManager::with_queues`] keeps the older
//!   hard-cap behaviour (ceiling == guarantee).
//! * **Fair-share preemption** — when preemption is enabled and a
//!   request from a queue *below its guarantee* is blocked, the
//!   scheduler flags victim containers of apps on queues *above* their
//!   guarantee, newest first. The signal is cooperative: the job layer
//!   checkpoints the interrupted shard, releases the container, and
//!   requeues — see `platform::job`.
//! * **Gang admission** — [`ResourceManager::acquire_gang`] reserves a
//!   job's container floor all-or-nothing under the scheduler lock, so
//!   two concurrent floors can no longer hold-and-wait each other into
//!   deadlock; timeouts surface as a typed [`GrantTimeout`] naming the
//!   queue and the deficit.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::container::{Container, ContainerRef};
use super::device::{DeviceId, DeviceKind, ResourceVec};
use crate::config::ClusterConfig;
use crate::metrics::{Gauge, Histogram, MetricsRegistry};

/// Typed error for blocking acquisition that hit its deadline: names
/// the queue and the deficit so a starved share is diagnosable from the
/// error alone (and so callers can downcast and requeue whole).
#[derive(Debug, Clone)]
pub struct GrantTimeout {
    pub app: String,
    pub queue: String,
    /// Containers still missing when the deadline passed.
    pub deficit: usize,
    /// Containers that were grantable at the last attempt (gang floors
    /// report how close admission came; nothing is actually held).
    pub grantable: usize,
}

impl std::fmt::Display for GrantTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "grant for app '{}' on queue '{}' timed out {} container(s) short \
             ({} grantable at deadline)",
            self.app, self.queue, self.deficit, self.grantable
        )
    }
}

impl std::error::Error for GrantTimeout {}

struct NodeState {
    /// Full node shape (never mutated) — used for feasibility checks.
    capacity: ResourceVec,
    avail: ResourceVec,
    free_gpus: Vec<usize>,
    free_fpgas: Vec<usize>,
}

struct AppState {
    queue: String,
    containers: usize,
}

struct QueueState {
    /// Guaranteed fraction of total cluster cores (capacity scheduler
    /// semantics: the share preemption defends).
    share: f64,
    /// Elastic ceiling fraction: how far the queue may borrow idle
    /// capacity beyond its guarantee (== `share` for hard caps).
    max_share: f64,
    cores_used: usize,
    /// Scheduling priority (higher = more urgent). While a queue with
    /// strictly higher priority has pending waiters, lower-priority
    /// queues may not borrow beyond their guarantee — freed capacity
    /// flows to the urgent queue first. 0 for plain batch queues.
    priority: u32,
    /// Cached `resource.queue_pending.<queue>` handle (pending-waiter
    /// depth, in containers still missing — a watchdog input and the
    /// priority gate's signal).
    pending: Arc<Gauge>,
    /// Cached `resource.grant_wait.<queue>` handle: how long blocking
    /// acquisitions on this queue waited for their grant.
    grant_wait: Arc<Histogram>,
}

struct RmInner {
    nodes: Vec<NodeState>,
    apps: HashMap<String, AppState>,
    queues: HashMap<String, QueueState>,
    /// Live containers by id; the scheduler keeps the handle so it can
    /// deliver preemption signals to victims.
    live: HashMap<u64, ContainerRef>,
    next_id: u64,
    total_cores: usize,
}

/// The cluster resource manager.
pub struct ResourceManager {
    inner: Mutex<RmInner>,
    freed: Condvar,
    preempt: AtomicBool,
    /// Delay-scheduling gate, microseconds: how long a request must
    /// have waited before it may *borrow* beyond its queue's
    /// guaranteed share. 0 (the default) borrows immediately.
    borrow_delay_us: AtomicU64,
    metrics: MetricsRegistry,
    /// `resource.live_containers` — refreshed on every grant/release.
    live_gauge: Arc<Gauge>,
}

/// RAII pending-count for `resource.queue_pending.<queue>`: carries the
/// number of containers a blocked acquisition is still short (1 for a
/// single-container wait, the floor deficit for a gang wait) and
/// returns it on every exit path, success or timeout.
struct PendingGuard {
    gauge: Arc<Gauge>,
    count: u64,
}

impl PendingGuard {
    fn new(gauge: Arc<Gauge>, count: u64) -> Self {
        gauge.add(count);
        Self { gauge, count }
    }

    /// Adjust the pending count in place: a waiting gang's deficit
    /// shrinks as partial floors come closer to completion (and can
    /// grow back when capacity is lost to other tenants).
    fn set(&mut self, count: u64) {
        if count > self.count {
            self.gauge.add(count - self.count);
        } else {
            self.gauge.sub(self.count - count);
        }
        self.count = count;
    }
}

impl Drop for PendingGuard {
    fn drop(&mut self) {
        self.gauge.sub(self.count);
    }
}

impl ResourceManager {
    /// Build from the cluster config with a single `default` queue.
    pub fn new(cluster: &ClusterConfig, metrics: MetricsRegistry) -> Arc<Self> {
        Self::with_queues(cluster, vec![("default".into(), 1.0)], metrics)
    }

    /// Build with named capacity queues; shares should sum to <= 1.
    /// Each queue's elastic ceiling equals its guarantee (hard caps —
    /// the pre-preemption behaviour).
    pub fn with_queues(
        cluster: &ClusterConfig,
        queues: Vec<(String, f64)>,
        metrics: MetricsRegistry,
    ) -> Arc<Self> {
        let queues = queues.into_iter().map(|(n, s)| (n, s, s)).collect();
        Self::with_elastic_queues(cluster, queues, metrics)
    }

    /// Build with `(name, guaranteed share, elastic ceiling)` queues: a
    /// queue may borrow idle capacity up to its ceiling; with
    /// preemption enabled, a queue blocked below its guarantee claws
    /// borrowed capacity back from over-guarantee tenants. All queues
    /// get equal (batch) priority.
    pub fn with_elastic_queues(
        cluster: &ClusterConfig,
        queues: Vec<(String, f64, f64)>,
        metrics: MetricsRegistry,
    ) -> Arc<Self> {
        let queues = queues.into_iter().map(|(n, s, m)| (n, s, m, 0)).collect();
        Self::with_priority_queues(cluster, queues, metrics)
    }

    /// Build with `(name, guaranteed share, elastic ceiling, priority)`
    /// queues. Priority refines elastic borrowing, not guarantees:
    /// every queue can always reach its guaranteed share, but while a
    /// strictly-higher-priority queue has pending waiters, lower
    /// queues may not borrow *beyond* guarantee — so capacity freed on
    /// a contended cluster flows to the urgent (e.g. `interactive`)
    /// queue first instead of being re-absorbed by batch tenants.
    pub fn with_priority_queues(
        cluster: &ClusterConfig,
        queues: Vec<(String, f64, f64, u32)>,
        metrics: MetricsRegistry,
    ) -> Arc<Self> {
        let shape = ResourceVec {
            cores: cluster.cores_per_node,
            mem_bytes: cluster.mem_per_node,
            gpus: cluster.gpus_per_node,
            fpgas: cluster.fpgas_per_node,
        };
        let nodes = (0..cluster.nodes)
            .map(|_| NodeState {
                capacity: shape,
                avail: shape,
                free_gpus: (0..cluster.gpus_per_node).collect(),
                free_fpgas: (0..cluster.fpgas_per_node).collect(),
            })
            .collect();
        let queues = queues
            .into_iter()
            .map(|(n, share, max_share, priority)| {
                let pending = metrics.gauge(&format!("resource.queue_pending.{n}"));
                let grant_wait = metrics.histogram(&format!("resource.grant_wait.{n}"));
                let q = QueueState {
                    share,
                    max_share: max_share.max(share),
                    cores_used: 0,
                    priority,
                    pending,
                    grant_wait,
                };
                (n, q)
            })
            .collect();
        Arc::new(Self {
            inner: Mutex::new(RmInner {
                nodes,
                apps: HashMap::new(),
                queues,
                live: HashMap::new(),
                next_id: 0,
                total_cores: cluster.total_cores(),
            }),
            freed: Condvar::new(),
            preempt: AtomicBool::new(false),
            borrow_delay_us: AtomicU64::new(0),
            live_gauge: metrics.gauge("resource.live_containers"),
            metrics,
        })
    }

    /// Configure delay scheduling: a request must have waited this
    /// long before it may borrow idle capacity beyond its queue's
    /// guaranteed share. Short jobs that fit their guarantee are
    /// admitted instantly and stop paying the borrow→preempt→requeue
    /// round-trip; only requests that genuinely need elastic capacity
    /// eat the delay. Zero (the default) disables the gate.
    pub fn set_borrow_delay(&self, delay: Duration) {
        self.borrow_delay_us.store(delay.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn borrow_delay(&self) -> Duration {
        Duration::from_micros(self.borrow_delay_us.load(Ordering::Relaxed))
    }

    /// Mark a blocked request pending against the app's queue
    /// (`resource.queue_pending.<queue>` gauge — a watchdog input and
    /// the priority gate's starvation signal). `count` is the number of
    /// containers the request is short: 1 for a single-container wait,
    /// the floor deficit for a gang wait. The returned guard un-marks
    /// when dropped.
    fn pending_guard(&self, inner: &RmInner, app: &str, count: usize) -> PendingGuard {
        let g = inner
            .apps
            .get(app)
            .and_then(|a| inner.queues.get(&a.queue))
            .map(|q| q.pending.clone())
            .unwrap_or_else(|| self.metrics.gauge("resource.queue_pending.unknown"));
        PendingGuard::new(g, count as u64)
    }

    /// Record how long a blocking acquisition waited for its grant in
    /// the per-queue `resource.grant_wait.<queue>` histogram (the
    /// interactive queue's SLO watchdog input).
    fn record_grant_wait(&self, inner: &RmInner, app: &str, waited: Duration) {
        if let Some(q) = inner.apps.get(app).and_then(|a| inner.queues.get(&a.queue)) {
            q.grant_wait.record(waited);
        }
    }

    /// Enable or disable fair-share preemption (off by default: without
    /// it, an over-guarantee tenant keeps borrowed capacity until it
    /// finishes — the pre-PR-4 behaviour).
    pub fn set_preemption(&self, enabled: bool) {
        self.preempt.store(enabled, Ordering::Relaxed);
    }

    pub fn preemption_enabled(&self) -> bool {
        self.preempt.load(Ordering::Relaxed)
    }

    /// Register an application against a queue.
    pub fn submit_app(&self, app: &str, queue: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.queues.contains_key(queue) {
            bail!("unknown queue '{queue}'");
        }
        if inner.apps.contains_key(app) {
            bail!("app '{app}' already submitted");
        }
        inner
            .apps
            .insert(app.to_string(), AppState { queue: queue.to_string(), containers: 0 });
        self.metrics.counter("resource.apps_submitted").inc();
        Ok(())
    }

    /// Non-blocking container request. Errors if nothing fits right now
    /// or the app's queue is at its elastic ceiling. With a borrow
    /// delay configured, an instant request may not borrow beyond its
    /// guarantee at all — waiting out the delay needs
    /// [`Self::acquire_container`].
    pub fn request_container(
        self: &Arc<Self>,
        app: &str,
        req: ResourceVec,
    ) -> Result<ContainerRef> {
        let allow_borrow = self.borrow_delay().is_zero();
        let mut inner = self.inner.lock().unwrap();
        let c = self.try_grant(&mut inner, app, req, allow_borrow)?;
        self.metrics.counter("resource.containers_granted").inc();
        Ok(c)
    }

    /// Blocking request: waits until a grant is possible (with timeout).
    /// When preemption is enabled and the requesting queue is below its
    /// guarantee, victim containers on over-guarantee queues are flagged
    /// so cooperative yields can free the capacity. The deadline is
    /// rechecked after *every* wakeup — a waiter can be woken by a
    /// release it then loses the race for, and that must not extend the
    /// wait past the timeout.
    pub fn acquire_container(
        self: &Arc<Self>,
        app: &str,
        req: ResourceVec,
        timeout: Duration,
    ) -> Result<ContainerRef> {
        let start = Instant::now();
        let deadline = start + timeout;
        let delay = self.borrow_delay();
        let mut pending: Option<PendingGuard> = None;
        let mut inner = self.inner.lock().unwrap();
        loop {
            let allow_borrow = delay.is_zero() || start.elapsed() >= delay;
            match self.try_grant(&mut inner, app, req, allow_borrow) {
                Ok(c) => {
                    self.metrics.counter("resource.containers_granted").inc();
                    self.record_grant_wait(&inner, app, start.elapsed());
                    return Ok(c);
                }
                Err(_) => {
                    if pending.is_none() {
                        pending = Some(self.pending_guard(&inner, app, 1));
                    }
                    if self.preemption_enabled() {
                        self.preempt_for(&mut inner, app, req.cores, req.cores);
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(self.grant_timeout_err(&inner, app, 1, 0));
                    }
                    // Wake no later than the borrow-delay gate lifts —
                    // an idle cluster produces no release to wake us.
                    let mut wake_at = deadline;
                    if !allow_borrow {
                        wake_at = wake_at.min(start + delay);
                    }
                    let wait = wake_at.saturating_duration_since(now);
                    let (guard, _) = self.freed.wait_timeout(inner, wait).unwrap();
                    inner = guard;
                }
            }
        }
    }

    /// Gang-atomic blocking acquisition: reserve at least `min`
    /// containers of `req` all-or-nothing, then extend greedily up to
    /// `max`. The floor is assembled — and on failure rolled back —
    /// entirely under the scheduler lock, so a floor that cannot
    /// complete is never observable by other applications and a waiting
    /// gang holds *nothing*: the hold-and-wait edge two concurrent
    /// floors need to deadlock each other on a full cluster is gone.
    pub fn acquire_gang(
        self: &Arc<Self>,
        app: &str,
        req: ResourceVec,
        min: usize,
        max: usize,
        timeout: Duration,
    ) -> Result<Vec<ContainerRef>> {
        let min = min.max(1);
        let max = max.max(min);
        let start = Instant::now();
        let deadline = start + timeout;
        let delay = self.borrow_delay();
        let mut pending: Option<PendingGuard> = None;
        let mut inner = self.inner.lock().unwrap();
        // Fail fast on floors no empty cluster or queue ceiling can
        // ever admit — blocking would only burn the whole timeout.
        self.check_gang_feasible(&inner, app, req, min)?;
        loop {
            let allow_borrow = delay.is_zero() || start.elapsed() >= delay;
            let mut gang: Vec<ContainerRef> = Vec::with_capacity(max);
            while gang.len() < min {
                match self.try_grant(&mut inner, app, req, allow_borrow) {
                    Ok(c) => gang.push(c),
                    Err(_) => break,
                }
            }
            if gang.len() >= min {
                // Floor secured atomically; take elastic extras.
                while gang.len() < max {
                    match self.try_grant(&mut inner, app, req, allow_borrow) {
                        Ok(c) => gang.push(c),
                        Err(_) => break,
                    }
                }
                self.metrics
                    .counter("resource.containers_granted")
                    .add(gang.len() as u64);
                self.record_grant_wait(&inner, app, start.elapsed());
                return Ok(gang);
            }
            // Below the floor: roll the partial gang back before
            // waiting (holding it would reintroduce hold-and-wait).
            let grantable = gang.len();
            for c in gang.drain(..) {
                let _ = self.release_locked(&mut inner, &c);
            }
            // Pending depth is the *container deficit*, not a flat 1 —
            // so interactive pending depth stays accurate under
            // gang-floor waits and the gauge reads as "containers
            // still missing" whichever acquisition path blocked.
            match &mut pending {
                Some(p) => p.set((min - grantable) as u64),
                None => pending = Some(self.pending_guard(&inner, app, min - grantable)),
            }
            if self.preemption_enabled() {
                self.preempt_for(&mut inner, app, min * req.cores, (min - grantable) * req.cores);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(self.grant_timeout_err(&inner, app, min - grantable, grantable));
            }
            let mut wake_at = deadline;
            if !allow_borrow {
                wake_at = wake_at.min(start + delay);
            }
            let wait = wake_at.saturating_duration_since(now);
            let (guard, _) = self.freed.wait_timeout(inner, wait).unwrap();
            inner = guard;
        }
    }

    fn grant_timeout_err(
        &self,
        inner: &RmInner,
        app: &str,
        deficit: usize,
        grantable: usize,
    ) -> anyhow::Error {
        let queue = inner
            .apps
            .get(app)
            .map(|a| a.queue.clone())
            .unwrap_or_else(|| "<unsubmitted>".into());
        self.metrics.counter("resource.grant_timeouts").inc();
        anyhow::Error::new(GrantTimeout { app: app.to_string(), queue, deficit, grantable })
    }

    /// How many `req`-shaped containers fit an empty node of `cap`.
    fn fit_count(cap: &ResourceVec, req: &ResourceVec) -> usize {
        let mut n = usize::MAX;
        if req.cores > 0 {
            n = n.min(cap.cores / req.cores);
        }
        if req.mem_bytes > 0 {
            n = n.min((cap.mem_bytes / req.mem_bytes).min(usize::MAX as u64) as usize);
        }
        if req.gpus > 0 {
            n = n.min(cap.gpus / req.gpus);
        }
        if req.fpgas > 0 {
            n = n.min(cap.fpgas / req.fpgas);
        }
        n
    }

    fn check_gang_feasible(
        &self,
        inner: &RmInner,
        app: &str,
        req: ResourceVec,
        min: usize,
    ) -> Result<()> {
        let queue_name = match inner.apps.get(app) {
            Some(a) => &a.queue,
            None => bail!("app '{app}' not submitted"),
        };
        let q = inner.queues.get(queue_name).unwrap();
        let cap = (q.max_share * inner.total_cores as f64).ceil() as usize;
        if min * req.cores > cap {
            bail!(
                "gang floor of {min} x {} core(s) exceeds queue '{queue_name}' ceiling of {cap}",
                req.cores
            );
        }
        let placeable: usize = inner
            .nodes
            .iter()
            .map(|n| Self::fit_count(&n.capacity, &req))
            .fold(0usize, |acc, n| acc.saturating_add(n));
        if placeable < min {
            bail!(
                "gang floor of {min} x {req:?} can never be placed \
                 (empty cluster fits only {placeable})"
            );
        }
        Ok(())
    }

    /// Flag preemption victims so a blocked request from a queue below
    /// its guaranteed share can reclaim capacity. `floor_cores` is the
    /// whole request being placed (the guard: preemption only defends
    /// requests that fit inside the requester's guarantee);
    /// `deficit_cores` is how much must actually be freed. Victims are
    /// live containers of apps on queues above their guarantee, newest
    /// first; cores already flagged but not yet yielded count against
    /// the deficit so repeated wakeups do not cascade through the
    /// whole cluster.
    ///
    /// Known limitation of the cooperative protocol: a flagged
    /// container whose shard never reaches another yield point keeps
    /// its cores until its job ends, and its pending flag suppresses
    /// flagging further victims — the waiter then degrades to plain
    /// FIFO blocking (bounded by its timeout). Smarter victim
    /// accounting is the ROADMAP "preemption cost model" rung.
    fn preempt_for(
        &self,
        inner: &mut RmInner,
        app: &str,
        floor_cores: usize,
        deficit_cores: usize,
    ) {
        let Some(a) = inner.apps.get(app) else { return };
        let req_queue = a.queue.clone();
        let total = inner.total_cores as f64;
        let guaranteed = |q: &QueueState| -> usize { (q.share * total).ceil() as usize };
        {
            let q = inner.queues.get(&req_queue).unwrap();
            if q.cores_used + floor_cores > guaranteed(q) {
                return;
            }
        }
        let app_queue: HashMap<&str, &str> = inner
            .apps
            .iter()
            .map(|(k, v)| (k.as_str(), v.queue.as_str()))
            .collect();
        // Per-queue cores above guarantee, net of victims already
        // flagged (their capacity is on its way back).
        let mut reclaimable: HashMap<&str, i64> = inner
            .queues
            .iter()
            .map(|(n, q)| (n.as_str(), q.cores_used as i64 - guaranteed(q) as i64))
            .collect();
        let mut pending = 0usize;
        for c in inner.live.values() {
            if c.preempt_requested() && !c.is_released() {
                pending += c.limits.cores;
                let q = app_queue.get(c.app.as_str());
                if let Some(r) = q.and_then(|q| reclaimable.get_mut(q)) {
                    *r -= c.limits.cores as i64;
                }
            }
        }
        let mut deficit = deficit_cores.saturating_sub(pending);
        if deficit == 0 {
            return;
        }
        // Newest containers first: they carry the least sunk work.
        let mut victims: Vec<&ContainerRef> = inner
            .live
            .values()
            .filter(|c| !c.preempt_requested() && !c.is_released())
            .filter(|c| app_queue.get(c.app.as_str()).is_some_and(|q| *q != req_queue))
            .collect();
        victims.sort_unstable_by(|a, b| b.id.cmp(&a.id));
        for c in victims {
            if deficit == 0 {
                break;
            }
            let q = app_queue.get(c.app.as_str());
            let Some(r) = q.and_then(|q| reclaimable.get_mut(q)) else {
                continue;
            };
            if *r <= 0 {
                continue;
            }
            c.request_preempt();
            self.metrics.counter("resource.preemptions").inc();
            *r -= c.limits.cores as i64;
            deficit = deficit.saturating_sub(c.limits.cores);
        }
    }

    /// Directly flag an app's newest `n` live containers for preemption
    /// (operational tooling and tests; the scheduler's automatic path
    /// delivers the same signal). Returns how many were flagged.
    pub fn request_preemption(&self, app: &str, n: usize) -> usize {
        let inner = self.inner.lock().unwrap();
        let mut ids: Vec<u64> = inner
            .live
            .iter()
            .filter(|(_, c)| c.app == app && !c.preempt_requested())
            .map(|(id, _)| *id)
            .collect();
        ids.sort_unstable_by(|a, b| b.cmp(a));
        let mut flagged = 0;
        for id in ids.into_iter().take(n) {
            inner.live[&id].request_preempt();
            self.metrics.counter("resource.preemptions").inc();
            flagged += 1;
        }
        flagged
    }

    fn try_grant(
        self: &Arc<Self>,
        inner: &mut RmInner,
        app: &str,
        req: ResourceVec,
        allow_borrow: bool,
    ) -> Result<ContainerRef> {
        let queue_name = match inner.apps.get(app) {
            Some(a) => a.queue.clone(),
            None => bail!("app '{app}' not submitted"),
        };
        // Capacity check: elastic ceiling at max_share * total_cores.
        // Delay scheduling caps a young request at its queue's
        // guaranteed share until the configured delay elapses.
        {
            let total = inner.total_cores;
            let q = inner.queues.get(&queue_name).unwrap();
            let elastic = (q.max_share * total as f64).ceil() as usize;
            let cap = if allow_borrow {
                elastic
            } else {
                elastic.min((q.share * total as f64).ceil() as usize)
            };
            if q.cores_used + req.cores > cap {
                self.metrics.counter("resource.queue_rejections").inc();
                if !allow_borrow && q.cores_used + req.cores <= elastic {
                    bail!(
                        "queue '{queue_name}' at guarantee ({}/{} cores); \
                         borrowing deferred by delay scheduling",
                        q.cores_used,
                        cap
                    );
                }
                bail!(
                    "queue '{queue_name}' at capacity ({}/{} cores)",
                    q.cores_used,
                    cap
                );
            }
            // Priority gate: borrowing beyond guarantee yields to any
            // strictly-higher-priority queue with pending waiters, so
            // freed capacity reaches the urgent queue instead of being
            // re-absorbed by batch tenants. Guarantee-level grants are
            // never gated.
            let guaranteed = (q.share * total as f64).ceil() as usize;
            if q.cores_used + req.cores > guaranteed {
                let starved = inner
                    .queues
                    .iter()
                    .find(|(_, o)| o.priority > q.priority && o.pending.get() > 0);
                if let Some((starved_name, _)) = starved {
                    self.metrics.counter("resource.queue_rejections").inc();
                    bail!(
                        "queue '{queue_name}' may not borrow past its guarantee while \
                         higher-priority queue '{starved_name}' has pending requests"
                    );
                }
            }
        }
        // First-fit across nodes.
        let node_idx = match inner.nodes.iter().position(|n| req.fits_in(&n.avail)) {
            Some(i) => i,
            None => {
                self.metrics.counter("resource.unsatisfied_requests").inc();
                bail!("no node can satisfy {req:?}");
            }
        };
        let node = &mut inner.nodes[node_idx];
        node.avail.sub(&req);
        let mut devices = Vec::new();
        for _ in 0..req.gpus {
            let idx = node.free_gpus.pop().expect("gpu accounting");
            devices.push(DeviceId { node: node_idx, kind: DeviceKind::Gpu, index: idx });
        }
        for _ in 0..req.fpgas {
            let idx = node.free_fpgas.pop().expect("fpga accounting");
            devices.push(DeviceId { node: node_idx, kind: DeviceKind::Fpga, index: idx });
        }
        inner.queues.get_mut(&queue_name).unwrap().cores_used += req.cores;
        inner.apps.get_mut(app).unwrap().containers += 1;
        let id = inner.next_id;
        inner.next_id += 1;
        let container = Arc::new(Container::new(
            id,
            app.to_string(),
            node_idx,
            req,
            devices,
            self.metrics.clone(),
        ));
        inner.live.insert(id, container.clone());
        self.live_gauge.set(inner.live.len() as u64);
        Ok(container)
    }

    /// Unregister a finished application (it must hold no containers),
    /// freeing its name for resubmission.
    pub fn remove_app(&self, app: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let live = match inner.apps.get(app) {
            None => bail!("app '{app}' not submitted"),
            Some(a) => a.containers,
        };
        if live > 0 {
            bail!("app '{app}' still holds {live} container(s)");
        }
        inner.apps.remove(app);
        Ok(())
    }

    /// Return a container's resources to the pool.
    pub fn release(&self, container: &ContainerRef) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        self.release_locked(&mut inner, container)?;
        self.metrics.counter("resource.containers_released").inc();
        self.freed.notify_all();
        Ok(())
    }

    /// Release under an already-held scheduler lock (also the gang
    /// rollback path, which must not be observable as a release).
    fn release_locked(&self, inner: &mut RmInner, container: &ContainerRef) -> Result<()> {
        if inner.live.remove(&container.id).is_none() {
            bail!("container {} not live", container.id);
        }
        container.mark_released();
        let req = container.limits;
        let node = &mut inner.nodes[container.node];
        node.avail.add(&req);
        for d in &container.devices {
            match d.kind {
                DeviceKind::Gpu => node.free_gpus.push(d.index),
                DeviceKind::Fpga => node.free_fpgas.push(d.index),
                DeviceKind::Cpu => {}
            }
        }
        let queue = inner.apps.get(&container.app).map(|a| a.queue.clone());
        if let Some(q) = queue.and_then(|q| inner.queues.get_mut(&q)) {
            q.cores_used -= req.cores;
        }
        if let Some(a) = inner.apps.get_mut(&container.app) {
            a.containers -= 1;
        }
        self.live_gauge.set(inner.live.len() as u64);
        Ok(())
    }

    /// The registry this manager reports into (shared with the job
    /// layer so grant-wait and per-job metrics land in one place).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Whether `req` could EVER be granted to `app`: it must fit an
    /// *empty* node's full shape and sit within the app's queue
    /// elastic ceiling. The job layer calls this before blocking so a
    /// permanently infeasible request fails fast instead of burning
    /// the whole grant timeout.
    pub fn check_feasible(&self, app: &str, req: ResourceVec) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        let queue_name = match inner.apps.get(app) {
            Some(a) => &a.queue,
            None => bail!("app '{app}' not submitted"),
        };
        let q = inner.queues.get(queue_name).unwrap();
        let cap = (q.max_share * inner.total_cores as f64).ceil() as usize;
        if req.cores > cap {
            bail!(
                "request of {} core(s) exceeds queue '{queue_name}' ceiling of {cap}",
                req.cores
            );
        }
        if !inner.nodes.iter().any(|n| req.fits_in(&n.capacity)) {
            bail!("no node shape can ever satisfy {req:?}");
        }
        Ok(())
    }

    /// Total available resources across nodes (diagnostics).
    pub fn available(&self) -> ResourceVec {
        let inner = self.inner.lock().unwrap();
        let mut total = ResourceVec::default();
        for n in &inner.nodes {
            total.add(&n.avail);
        }
        total
    }

    pub fn live_containers(&self) -> usize {
        self.inner.lock().unwrap().live.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> ClusterConfig {
        ClusterConfig {
            nodes: 2,
            cores_per_node: 4,
            gpus_per_node: 1,
            fpgas_per_node: 1,
            mem_per_node: 1000,
        }
    }

    fn rm() -> Arc<ResourceManager> {
        ResourceManager::new(&cluster(), MetricsRegistry::new())
    }

    #[test]
    fn grant_and_release_roundtrip() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let c = rm.request_container("a", ResourceVec::cores(2, 100)).unwrap();
        assert_eq!(rm.live_containers(), 1);
        assert_eq!(rm.available().cores, 6);
        rm.release(&c).unwrap();
        assert_eq!(rm.available().cores, 8);
        assert!(c.is_released());
    }

    #[test]
    fn live_containers_gauge_tracks_grants_and_releases() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let g = rm.metrics().gauge("resource.live_containers");
        assert_eq!(g.get(), 0);
        let c1 = rm.request_container("a", ResourceVec::cores(1, 10)).unwrap();
        let c2 = rm.request_container("a", ResourceVec::cores(1, 10)).unwrap();
        assert_eq!(g.get(), 2);
        rm.release(&c1).unwrap();
        assert_eq!(g.get(), 1);
        rm.release(&c2).unwrap();
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn gpu_slots_are_exclusive() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let c1 = rm.request_container("a", ResourceVec::cores(1, 10).with_gpu(1)).unwrap();
        let c2 = rm.request_container("a", ResourceVec::cores(1, 10).with_gpu(1)).unwrap();
        // Both GPUs taken (one per node) — a third must fail.
        assert!(rm.request_container("a", ResourceVec::cores(1, 10).with_gpu(1)).is_err());
        assert_ne!(
            (c1.devices[0].node, c1.devices[0].index),
            (c2.devices[0].node, c2.devices[0].index)
        );
        rm.release(&c1).unwrap();
        rm.request_container("a", ResourceVec::cores(1, 10).with_gpu(1)).unwrap();
    }

    #[test]
    fn queue_capacity_cap_enforced() {
        let rm = ResourceManager::with_queues(
            &cluster(),
            vec![("small".into(), 0.25), ("big".into(), 0.75)],
            MetricsRegistry::new(),
        );
        rm.submit_app("a", "small").unwrap();
        // 25% of 8 cores = 2.
        rm.request_container("a", ResourceVec::cores(2, 10)).unwrap();
        assert!(rm.request_container("a", ResourceVec::cores(1, 10)).is_err());
    }

    #[test]
    fn queue_cap_is_shared_across_apps() {
        let rm = ResourceManager::with_queues(
            &cluster(),
            vec![("small".into(), 0.25), ("big".into(), 0.75)],
            MetricsRegistry::new(),
        );
        rm.submit_app("a1", "small").unwrap();
        rm.submit_app("a2", "small").unwrap();
        // 25% of 8 cores = 2, shared by every app on the queue.
        let c1 = rm.request_container("a1", ResourceVec::cores(1, 10)).unwrap();
        rm.request_container("a2", ResourceVec::cores(1, 10)).unwrap();
        assert!(rm.request_container("a2", ResourceVec::cores(1, 10)).is_err());
        // Releasing one app's grant reopens the shared cap for the other.
        rm.release(&c1).unwrap();
        rm.request_container("a2", ResourceVec::cores(1, 10)).unwrap();
    }

    #[test]
    fn queue_is_work_conserving_below_its_cap() {
        // An idle sibling queue does not throttle allocation: the big
        // queue immediately fills its full 75% share (6 of 8 cores)
        // without waiting, and is denied only at the cap.
        let rm = ResourceManager::with_queues(
            &cluster(),
            vec![("small".into(), 0.25), ("big".into(), 0.75)],
            MetricsRegistry::new(),
        );
        rm.submit_app("b", "big").unwrap();
        for i in 0..6 {
            rm.request_container("b", ResourceVec::cores(1, 10))
                .unwrap_or_else(|e| panic!("core {i} within share denied: {e}"));
        }
        assert!(
            rm.request_container("b", ResourceVec::cores(1, 10)).is_err(),
            "7th core exceeds the 75% cap"
        );
    }

    #[test]
    fn elastic_queue_borrows_idle_capacity_to_its_ceiling() {
        // Guarantee 50%, ceiling 100%: with the sibling idle, the queue
        // may borrow the whole cluster — the over-share state preemption
        // exists to claw back.
        let rm = ResourceManager::with_elastic_queues(
            &cluster(),
            vec![("sim".into(), 0.5, 1.0), ("fleet".into(), 0.5, 0.5)],
            MetricsRegistry::new(),
        );
        rm.submit_app("a", "sim").unwrap();
        for i in 0..8 {
            rm.request_container("a", ResourceVec::cores(1, 10))
                .unwrap_or_else(|e| panic!("core {i} within ceiling denied: {e}"));
        }
        assert!(rm.request_container("a", ResourceVec::cores(1, 10)).is_err());
    }

    #[test]
    fn preemption_flags_newest_over_guarantee_victims() {
        let rm = ResourceManager::with_elastic_queues(
            &cluster(),
            vec![("sim".into(), 0.5, 1.0), ("fleet".into(), 0.5, 0.5)],
            MetricsRegistry::new(),
        );
        rm.set_preemption(true);
        rm.submit_app("hog", "sim").unwrap();
        rm.submit_app("late", "fleet").unwrap();
        let held: Vec<_> = (0..8)
            .map(|_| rm.request_container("hog", ResourceVec::cores(1, 10)).unwrap())
            .collect();
        // The fleet queue is empty (below its 4-core guarantee); its
        // blocked request must flag exactly one victim — the newest
        // container of the over-guarantee tenant — and be admitted
        // once that victim cooperatively yields.
        let rm2 = rm.clone();
        let waiter = std::thread::spawn(move || {
            rm2.acquire_container("late", ResourceVec::cores(1, 10), Duration::from_secs(5))
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        while !held.last().unwrap().preempt_requested() {
            assert!(Instant::now() < deadline, "victim was never flagged");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(
            held[..7].iter().all(|c| !c.preempt_requested()),
            "only the newest container should be flagged for a 1-core deficit"
        );
        rm.release(held.last().unwrap()).unwrap();
        let got = waiter.join().unwrap().unwrap();
        assert_eq!(rm.metrics().counter("resource.preemptions").get(), 1);
        rm.release(&got).unwrap();
        for c in &held[..7] {
            rm.release(c).unwrap();
        }
    }

    #[test]
    fn preemption_does_not_defend_requests_above_the_guarantee() {
        let rm = ResourceManager::with_elastic_queues(
            &cluster(),
            vec![("sim".into(), 0.5, 1.0), ("fleet".into(), 0.5, 1.0)],
            MetricsRegistry::new(),
        );
        rm.set_preemption(true);
        rm.submit_app("hog", "sim").unwrap();
        rm.submit_app("greedy", "fleet").unwrap();
        let held: Vec<_> = (0..4)
            .map(|_| rm.request_container("hog", ResourceVec::cores(1, 10)).unwrap())
            .collect();
        let mine: Vec<_> = (0..4)
            .map(|_| rm.request_container("greedy", ResourceVec::cores(1, 10)).unwrap())
            .collect();
        // "greedy" already sits AT its 4-core guarantee: asking for a
        // 5th core is borrowing, and borrowing never preempts.
        let r =
            rm.acquire_container("greedy", ResourceVec::cores(1, 10), Duration::from_millis(50));
        assert!(r.is_err());
        assert!(held.iter().all(|c| !c.preempt_requested()), "no victim may be flagged");
        for c in held.iter().chain(mine.iter()) {
            rm.release(c).unwrap();
        }
    }

    #[test]
    fn acquire_wakes_when_grant_from_another_queue_is_released() {
        // Node capacity (not queue share) is the contended resource:
        // queue "a" helps fill the node, queue "b" blocks below its own
        // cap until a grant from "a" is released.
        let one_node = ClusterConfig {
            nodes: 1,
            cores_per_node: 4,
            gpus_per_node: 0,
            fpgas_per_node: 0,
            mem_per_node: 1000,
        };
        let rm = ResourceManager::with_queues(
            &one_node,
            vec![("a".into(), 0.5), ("b".into(), 0.75)],
            MetricsRegistry::new(),
        );
        rm.submit_app("apa", "a").unwrap();
        rm.submit_app("apb", "b").unwrap();
        let a1 = rm.request_container("apa", ResourceVec::cores(1, 10)).unwrap();
        let _a2 = rm.request_container("apa", ResourceVec::cores(1, 10)).unwrap();
        let _b1 = rm.request_container("apb", ResourceVec::cores(2, 10)).unwrap();
        // Node full; "b" holds 2 of its 3-core cap so the next request
        // is node-bound, not share-bound.
        let rm2 = rm.clone();
        let waiter = std::thread::spawn(move || {
            rm2.acquire_container("apb", ResourceVec::cores(1, 10), Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(50));
        rm.release(&a1).unwrap();
        let got = waiter.join().unwrap();
        assert!(got.is_ok(), "release in queue 'a' must wake the waiter in queue 'b'");
    }

    #[test]
    fn unknown_app_or_queue_errors() {
        let rm = rm();
        assert!(rm.submit_app("a", "nope").is_err());
        assert!(rm.request_container("ghost", ResourceVec::cores(1, 1)).is_err());
    }

    #[test]
    fn remove_app_frees_name_for_resubmission() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        assert!(rm.submit_app("a", "default").is_err(), "duplicate submit must fail");
        let c = rm.request_container("a", ResourceVec::cores(1, 10)).unwrap();
        assert!(rm.remove_app("a").is_err(), "live containers must block removal");
        rm.release(&c).unwrap();
        rm.remove_app("a").unwrap();
        assert!(rm.remove_app("a").is_err(), "already removed");
        rm.submit_app("a", "default").unwrap();
    }

    #[test]
    fn oversized_request_rejected() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        assert!(rm.request_container("a", ResourceVec::cores(5, 10)).is_err());
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let big = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let big2 = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let rm2 = rm.clone();
        let waiter = std::thread::spawn(move || {
            rm2.acquire_container("a", ResourceVec::cores(4, 100), Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(50));
        rm.release(&big).unwrap();
        let got = waiter.join().unwrap();
        assert!(got.is_ok());
        rm.release(&big2).unwrap();
    }

    #[test]
    fn acquire_timeout_names_queue_and_deficit() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let _c1 = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let _c2 = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let r = rm.acquire_container("a", ResourceVec::cores(1, 1), Duration::from_millis(50));
        let e = r.unwrap_err();
        let t = e.downcast_ref::<GrantTimeout>().expect("typed GrantTimeout");
        assert_eq!(t.queue, "default");
        assert_eq!(t.deficit, 1);
        assert!(e.to_string().contains("queue 'default'"), "{e}");
    }

    #[test]
    fn gang_floor_is_all_or_nothing() {
        let rm = rm();
        rm.submit_app("hog", "default").unwrap();
        rm.submit_app("g", "default").unwrap();
        let _hold = rm.request_container("hog", ResourceVec::cores(4, 100)).unwrap();
        let _hold2 = rm.request_container("hog", ResourceVec::cores(3, 100)).unwrap();
        // One core free, floor of 3: the gang must hold NOTHING while
        // failing, then report the deficit.
        let req = ResourceVec::cores(1, 10);
        let r = rm.acquire_gang("g", req, 3, 3, Duration::from_millis(50));
        let e = r.unwrap_err();
        let t = e.downcast_ref::<GrantTimeout>().expect("typed GrantTimeout");
        assert_eq!((t.deficit, t.grantable), (2, 1));
        assert_eq!(rm.live_containers(), 2, "failed gang must hold nothing");
    }

    #[test]
    fn infeasible_gang_floor_fails_fast() {
        let rm = rm();
        rm.submit_app("g", "default").unwrap();
        let t = Instant::now();
        // 9 one-core containers can never fit 8 cores.
        let req = ResourceVec::cores(1, 10);
        let r = rm.acquire_gang("g", req, 9, 9, Duration::from_secs(5));
        assert!(r.is_err());
        assert!(t.elapsed() < Duration::from_secs(1), "must fail fast, not block");
    }

    #[test]
    fn concurrent_gang_floors_serialize_instead_of_deadlocking() {
        // The PR-3 escalation path could interleave two floor-3 jobs on
        // an 8-core cluster into 4+4 hold-and-wait. Gang admission
        // reserves floors atomically, so both must now complete.
        let rm = rm();
        rm.submit_app("j1", "default").unwrap();
        rm.submit_app("j2", "default").unwrap();
        let req = ResourceVec::cores(1, 10);
        let (r1, r2) = std::thread::scope(|s| {
            let rm1 = rm.clone();
            let rm2 = rm.clone();
            let h1 = s.spawn(move || {
                let g = rm1.acquire_gang("j1", req, 6, 6, Duration::from_secs(5))?;
                std::thread::sleep(Duration::from_millis(20));
                for c in &g {
                    rm1.release(c)?;
                }
                Ok::<usize, anyhow::Error>(g.len())
            });
            let h2 = s.spawn(move || {
                let g = rm2.acquire_gang("j2", req, 6, 6, Duration::from_secs(5))?;
                std::thread::sleep(Duration::from_millis(20));
                for c in &g {
                    rm2.release(c)?;
                }
                Ok::<usize, anyhow::Error>(g.len())
            });
            (h1.join().unwrap(), h2.join().unwrap())
        });
        assert_eq!(r1.unwrap(), 6);
        assert_eq!(r2.unwrap(), 6);
        assert_eq!(rm.live_containers(), 0);
    }

    #[test]
    fn borrow_delay_defers_cross_queue_borrowing() {
        let rm = ResourceManager::with_elastic_queues(
            &cluster(),
            vec![("sim".into(), 0.5, 1.0), ("fleet".into(), 0.5, 0.5)],
            MetricsRegistry::new(),
        );
        rm.submit_app("a", "sim").unwrap();
        rm.set_borrow_delay(Duration::from_millis(100));
        // Within the 4-core guarantee: grants are instant.
        let t = Instant::now();
        for i in 0..4 {
            rm.request_container("a", ResourceVec::cores(1, 10))
                .unwrap_or_else(|e| panic!("core {i} within guarantee denied: {e}"));
        }
        assert!(t.elapsed() < Duration::from_millis(90), "guarantee grants must not wait");
        // A non-blocking 5th request needs borrowed capacity and is
        // refused outright while the delay gate holds.
        let e = rm.request_container("a", ResourceVec::cores(1, 10)).unwrap_err();
        assert!(e.to_string().contains("deferred by delay scheduling"), "{e}");
        // A blocking request waits out the delay, then borrows.
        let t = Instant::now();
        let c = rm.acquire_container("a", ResourceVec::cores(1, 10), Duration::from_secs(5));
        let waited = t.elapsed();
        assert!(c.is_ok(), "borrow must succeed once the delay elapses: {c:?}");
        assert!(waited >= Duration::from_millis(90), "borrowed too early: {waited:?}");
        assert!(waited < Duration::from_secs(2), "waited far past the gate: {waited:?}");
        // Zero restores immediate borrowing.
        rm.set_borrow_delay(Duration::ZERO);
        rm.request_container("a", ResourceVec::cores(1, 10)).unwrap();
    }

    #[test]
    fn gang_wait_pending_gauge_counts_container_deficit() {
        let rm = rm();
        rm.submit_app("hog", "default").unwrap();
        rm.submit_app("g", "default").unwrap();
        let _hold = rm.request_container("hog", ResourceVec::cores(4, 100)).unwrap();
        let _hold2 = rm.request_container("hog", ResourceVec::cores(3, 100)).unwrap();
        // One core free, floor of 3: the pending gauge must read the
        // CONTAINER DEFICIT (2), not a flat 1 per blocked caller.
        let gauge = rm.metrics().gauge("resource.queue_pending.default");
        let rm2 = rm.clone();
        let waiter = std::thread::spawn(move || {
            rm2.acquire_gang("g", ResourceVec::cores(1, 10), 3, 3, Duration::from_millis(200))
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        while gauge.get() != 2 {
            assert!(
                Instant::now() < deadline,
                "gang deficit never registered (gauge {})",
                gauge.get()
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(waiter.join().unwrap().is_err(), "floor can never complete here");
        assert_eq!(gauge.get(), 0, "pending deficit must clear when the gang gives up");
    }

    #[test]
    fn borrowing_deferred_while_higher_priority_queue_waits() {
        let rm = ResourceManager::with_priority_queues(
            &cluster(),
            vec![("batch".into(), 0.5, 1.0, 0), ("interactive".into(), 0.5, 1.0, 1)],
            MetricsRegistry::new(),
        );
        rm.submit_app("b", "batch").unwrap();
        // Guarantee-level grants (4 of 8 cores) are never gated.
        let held: Vec<_> = (0..4)
            .map(|_| rm.request_container("b", ResourceVec::cores(1, 10)).unwrap())
            .collect();
        // With an interactive request pending, batch may not borrow
        // beyond its guarantee...
        let pending = rm.metrics().gauge("resource.queue_pending.interactive");
        pending.add(1);
        let e = rm.request_container("b", ResourceVec::cores(1, 10)).unwrap_err();
        assert!(e.to_string().contains("higher-priority"), "{e}");
        // ...while grants within the guarantee still flow.
        rm.release(&held[3]).unwrap();
        let again = rm.request_container("b", ResourceVec::cores(1, 10)).unwrap();
        // Once the urgent queue is drained, borrowing reopens.
        pending.sub(1);
        rm.request_container("b", ResourceVec::cores(1, 10)).unwrap();
        let _ = again;
    }

    #[test]
    fn freed_capacity_flows_to_higher_priority_queue_first() {
        let rm = ResourceManager::with_priority_queues(
            &cluster(),
            vec![("batch".into(), 0.5, 1.0, 0), ("interactive".into(), 0.5, 1.0, 1)],
            MetricsRegistry::new(),
        );
        rm.submit_app("b", "batch").unwrap();
        rm.submit_app("i", "interactive").unwrap();
        // Batch borrows the whole idle cluster, then an interactive
        // request arrives and blocks.
        let held: Vec<_> = (0..8)
            .map(|_| rm.request_container("b", ResourceVec::cores(1, 10)).unwrap())
            .collect();
        let pending = rm.metrics().gauge("resource.queue_pending.interactive");
        let rm2 = rm.clone();
        let waiter = std::thread::spawn(move || {
            rm2.acquire_container("i", ResourceVec::cores(1, 10), Duration::from_secs(5))
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        while pending.get() != 1 {
            assert!(Instant::now() < deadline, "interactive wait never registered");
            std::thread::sleep(Duration::from_millis(1));
        }
        // A freed batch core must reach the interactive waiter even if
        // batch immediately asks again: its re-borrow is gated.
        rm.release(&held[7]).unwrap();
        let got = waiter.join().unwrap().unwrap();
        assert_eq!(
            rm.metrics().histogram("resource.grant_wait.interactive").count(),
            1,
            "interactive grant wait must be recorded per queue"
        );
        rm.release(&got).unwrap();
        for c in &held[..7] {
            rm.release(c).unwrap();
        }
    }

    #[test]
    fn queue_pending_gauge_tracks_blocked_requests() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let c1 = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let _c2 = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let gauge = rm.metrics().gauge("resource.queue_pending.default");
        assert_eq!(gauge.get(), 0);
        let rm2 = rm.clone();
        let waiter = std::thread::spawn(move || {
            rm2.acquire_container("a", ResourceVec::cores(2, 10), Duration::from_secs(5))
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        while gauge.get() != 1 {
            assert!(Instant::now() < deadline, "pending gauge never rose");
            std::thread::sleep(Duration::from_millis(1));
        }
        rm.release(&c1).unwrap();
        assert!(waiter.join().unwrap().is_ok());
        assert_eq!(gauge.get(), 0, "pending gauge must drop once the waiter is served");
    }
}
