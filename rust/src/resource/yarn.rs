//! YARN-analog resource manager (paper section 2.3).
//!
//! "When a Spark application is launched, it can request heterogeneous
//! computing resources through YARN. YARN then allocates LXCs to satisfy
//! the request." This module is that allocator: applications register
//! against capacity-share queues, request containers carrying CPU cores,
//! memory, and GPU/FPGA device slots, and either get a grant, an error,
//! or (with [`ResourceManager::acquire_container`]) block until capacity
//! frees up.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::container::{Container, ContainerRef};
use super::device::{DeviceId, DeviceKind, ResourceVec};
use crate::config::ClusterConfig;
use crate::metrics::MetricsRegistry;

struct NodeState {
    /// Full node shape (never mutated) — used for feasibility checks.
    capacity: ResourceVec,
    avail: ResourceVec,
    free_gpus: Vec<usize>,
    free_fpgas: Vec<usize>,
}

struct AppState {
    queue: String,
    containers: usize,
}

struct QueueState {
    /// Fraction of total cluster cores this queue may hold (capacity
    /// scheduler semantics: hard cap, work-conserving below it).
    share: f64,
    cores_used: usize,
}

struct RmInner {
    nodes: Vec<NodeState>,
    apps: HashMap<String, AppState>,
    queues: HashMap<String, QueueState>,
    live: HashMap<u64, (String, usize, ResourceVec, Vec<DeviceId>)>,
    next_id: u64,
    total_cores: usize,
}

/// The cluster resource manager.
pub struct ResourceManager {
    inner: Mutex<RmInner>,
    freed: Condvar,
    metrics: MetricsRegistry,
}

impl ResourceManager {
    /// Build from the cluster config with a single `default` queue.
    pub fn new(cluster: &ClusterConfig, metrics: MetricsRegistry) -> Arc<Self> {
        Self::with_queues(cluster, vec![("default".into(), 1.0)], metrics)
    }

    /// Build with named capacity queues; shares should sum to <= 1.
    pub fn with_queues(
        cluster: &ClusterConfig,
        queues: Vec<(String, f64)>,
        metrics: MetricsRegistry,
    ) -> Arc<Self> {
        let shape = ResourceVec {
            cores: cluster.cores_per_node,
            mem_bytes: cluster.mem_per_node,
            gpus: cluster.gpus_per_node,
            fpgas: cluster.fpgas_per_node,
        };
        let nodes = (0..cluster.nodes)
            .map(|_| NodeState {
                capacity: shape,
                avail: shape,
                free_gpus: (0..cluster.gpus_per_node).collect(),
                free_fpgas: (0..cluster.fpgas_per_node).collect(),
            })
            .collect();
        Arc::new(Self {
            inner: Mutex::new(RmInner {
                nodes,
                apps: HashMap::new(),
                queues: queues
                    .into_iter()
                    .map(|(n, share)| (n, QueueState { share, cores_used: 0 }))
                    .collect(),
                live: HashMap::new(),
                next_id: 0,
                total_cores: cluster.total_cores(),
            }),
            freed: Condvar::new(),
            metrics,
        })
    }

    /// Register an application against a queue.
    pub fn submit_app(&self, app: &str, queue: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.queues.contains_key(queue) {
            bail!("unknown queue '{queue}'");
        }
        if inner.apps.contains_key(app) {
            bail!("app '{app}' already submitted");
        }
        inner
            .apps
            .insert(app.to_string(), AppState { queue: queue.to_string(), containers: 0 });
        self.metrics.counter("resource.apps_submitted").inc();
        Ok(())
    }

    /// Non-blocking container request. Errors if nothing fits right now
    /// or the app's queue is at its capacity cap.
    pub fn request_container(
        self: &Arc<Self>,
        app: &str,
        req: ResourceVec,
    ) -> Result<ContainerRef> {
        let mut inner = self.inner.lock().unwrap();
        self.try_grant(&mut inner, app, req)
    }

    /// Blocking request: waits until a grant is possible (with timeout).
    pub fn acquire_container(
        self: &Arc<Self>,
        app: &str,
        req: ResourceVec,
        timeout: Duration,
    ) -> Result<ContainerRef> {
        let deadline = std::time::Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            match self.try_grant(&mut inner, app, req) {
                Ok(c) => return Ok(c),
                Err(_) => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        bail!("timed out waiting for {req:?} for app '{app}'");
                    }
                    let (guard, _) = self
                        .freed
                        .wait_timeout(inner, deadline - now)
                        .unwrap();
                    inner = guard;
                }
            }
        }
    }

    fn try_grant(
        self: &Arc<Self>,
        inner: &mut RmInner,
        app: &str,
        req: ResourceVec,
    ) -> Result<ContainerRef> {
        let queue_name = match inner.apps.get(app) {
            Some(a) => a.queue.clone(),
            None => bail!("app '{app}' not submitted"),
        };
        // Capacity check: hard cap at share * total_cores.
        {
            let total = inner.total_cores;
            let q = inner.queues.get(&queue_name).unwrap();
            let cap = (q.share * total as f64).ceil() as usize;
            if q.cores_used + req.cores > cap {
                self.metrics.counter("resource.queue_rejections").inc();
                bail!(
                    "queue '{queue_name}' at capacity ({}/{} cores)",
                    q.cores_used,
                    cap
                );
            }
        }
        // First-fit across nodes.
        let node_idx = match inner.nodes.iter().position(|n| req.fits_in(&n.avail)) {
            Some(i) => i,
            None => {
                self.metrics.counter("resource.unsatisfied_requests").inc();
                bail!("no node can satisfy {req:?}");
            }
        };
        let node = &mut inner.nodes[node_idx];
        node.avail.sub(&req);
        let mut devices = Vec::new();
        for _ in 0..req.gpus {
            let idx = node.free_gpus.pop().expect("gpu accounting");
            devices.push(DeviceId { node: node_idx, kind: DeviceKind::Gpu, index: idx });
        }
        for _ in 0..req.fpgas {
            let idx = node.free_fpgas.pop().expect("fpga accounting");
            devices.push(DeviceId { node: node_idx, kind: DeviceKind::Fpga, index: idx });
        }
        inner.queues.get_mut(&queue_name).unwrap().cores_used += req.cores;
        inner.apps.get_mut(app).unwrap().containers += 1;
        let id = inner.next_id;
        inner.next_id += 1;
        inner.live.insert(id, (app.to_string(), node_idx, req, devices.clone()));
        self.metrics.counter("resource.containers_granted").inc();
        Ok(Arc::new(Container::new(
            id,
            app.to_string(),
            node_idx,
            req,
            devices,
            self.metrics.clone(),
        )))
    }

    /// Unregister a finished application (it must hold no containers),
    /// freeing its name for resubmission.
    pub fn remove_app(&self, app: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let live = match inner.apps.get(app) {
            None => bail!("app '{app}' not submitted"),
            Some(a) => a.containers,
        };
        if live > 0 {
            bail!("app '{app}' still holds {live} container(s)");
        }
        inner.apps.remove(app);
        Ok(())
    }

    /// Return a container's resources to the pool.
    pub fn release(&self, container: &ContainerRef) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let (app, node_idx, req, devices) = match inner.live.remove(&container.id) {
            Some(v) => v,
            None => bail!("container {} not live", container.id),
        };
        container.mark_released();
        let node = &mut inner.nodes[node_idx];
        node.avail.add(&req);
        for d in devices {
            match d.kind {
                DeviceKind::Gpu => node.free_gpus.push(d.index),
                DeviceKind::Fpga => node.free_fpgas.push(d.index),
                DeviceKind::Cpu => {}
            }
        }
        let queue = inner.apps.get(&app).map(|a| a.queue.clone());
        if let Some(q) = queue.and_then(|q| inner.queues.get_mut(&q)) {
            q.cores_used -= req.cores;
        }
        if let Some(a) = inner.apps.get_mut(&app) {
            a.containers -= 1;
        }
        self.metrics.counter("resource.containers_released").inc();
        self.freed.notify_all();
        Ok(())
    }

    /// The registry this manager reports into (shared with the job
    /// layer so grant-wait and per-job metrics land in one place).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Whether `req` could EVER be granted to `app`: it must fit an
    /// *empty* node's full shape and sit within the app's queue
    /// absolute capacity cap. The job layer calls this before blocking
    /// so a permanently infeasible request fails fast instead of
    /// burning the whole grant timeout.
    pub fn check_feasible(&self, app: &str, req: ResourceVec) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        let queue_name = match inner.apps.get(app) {
            Some(a) => &a.queue,
            None => bail!("app '{app}' not submitted"),
        };
        let q = inner.queues.get(queue_name).unwrap();
        let cap = (q.share * inner.total_cores as f64).ceil() as usize;
        if req.cores > cap {
            bail!(
                "request of {} core(s) exceeds queue '{queue_name}' cap of {cap}",
                req.cores
            );
        }
        if !inner.nodes.iter().any(|n| req.fits_in(&n.capacity)) {
            bail!("no node shape can ever satisfy {req:?}");
        }
        Ok(())
    }

    /// Total available resources across nodes (diagnostics).
    pub fn available(&self) -> ResourceVec {
        let inner = self.inner.lock().unwrap();
        let mut total = ResourceVec::default();
        for n in &inner.nodes {
            total.add(&n.avail);
        }
        total
    }

    pub fn live_containers(&self) -> usize {
        self.inner.lock().unwrap().live.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> ClusterConfig {
        ClusterConfig {
            nodes: 2,
            cores_per_node: 4,
            gpus_per_node: 1,
            fpgas_per_node: 1,
            mem_per_node: 1000,
        }
    }

    fn rm() -> Arc<ResourceManager> {
        ResourceManager::new(&cluster(), MetricsRegistry::new())
    }

    #[test]
    fn grant_and_release_roundtrip() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let c = rm.request_container("a", ResourceVec::cores(2, 100)).unwrap();
        assert_eq!(rm.live_containers(), 1);
        assert_eq!(rm.available().cores, 6);
        rm.release(&c).unwrap();
        assert_eq!(rm.available().cores, 8);
        assert!(c.is_released());
    }

    #[test]
    fn gpu_slots_are_exclusive() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let c1 = rm.request_container("a", ResourceVec::cores(1, 10).with_gpu(1)).unwrap();
        let c2 = rm.request_container("a", ResourceVec::cores(1, 10).with_gpu(1)).unwrap();
        // Both GPUs taken (one per node) — a third must fail.
        assert!(rm.request_container("a", ResourceVec::cores(1, 10).with_gpu(1)).is_err());
        assert_ne!(
            (c1.devices[0].node, c1.devices[0].index),
            (c2.devices[0].node, c2.devices[0].index)
        );
        rm.release(&c1).unwrap();
        rm.request_container("a", ResourceVec::cores(1, 10).with_gpu(1)).unwrap();
    }

    #[test]
    fn queue_capacity_cap_enforced() {
        let rm = ResourceManager::with_queues(
            &cluster(),
            vec![("small".into(), 0.25), ("big".into(), 0.75)],
            MetricsRegistry::new(),
        );
        rm.submit_app("a", "small").unwrap();
        // 25% of 8 cores = 2.
        rm.request_container("a", ResourceVec::cores(2, 10)).unwrap();
        assert!(rm.request_container("a", ResourceVec::cores(1, 10)).is_err());
    }

    #[test]
    fn queue_cap_is_shared_across_apps() {
        let rm = ResourceManager::with_queues(
            &cluster(),
            vec![("small".into(), 0.25), ("big".into(), 0.75)],
            MetricsRegistry::new(),
        );
        rm.submit_app("a1", "small").unwrap();
        rm.submit_app("a2", "small").unwrap();
        // 25% of 8 cores = 2, shared by every app on the queue.
        let c1 = rm.request_container("a1", ResourceVec::cores(1, 10)).unwrap();
        rm.request_container("a2", ResourceVec::cores(1, 10)).unwrap();
        assert!(rm.request_container("a2", ResourceVec::cores(1, 10)).is_err());
        // Releasing one app's grant reopens the shared cap for the other.
        rm.release(&c1).unwrap();
        rm.request_container("a2", ResourceVec::cores(1, 10)).unwrap();
    }

    #[test]
    fn queue_is_work_conserving_below_its_cap() {
        // An idle sibling queue does not throttle allocation: the big
        // queue immediately fills its full 75% share (6 of 8 cores)
        // without waiting, and is denied only at the cap.
        let rm = ResourceManager::with_queues(
            &cluster(),
            vec![("small".into(), 0.25), ("big".into(), 0.75)],
            MetricsRegistry::new(),
        );
        rm.submit_app("b", "big").unwrap();
        for i in 0..6 {
            rm.request_container("b", ResourceVec::cores(1, 10))
                .unwrap_or_else(|e| panic!("core {i} within share denied: {e}"));
        }
        assert!(
            rm.request_container("b", ResourceVec::cores(1, 10)).is_err(),
            "7th core exceeds the 75% cap"
        );
    }

    #[test]
    fn acquire_wakes_when_grant_from_another_queue_is_released() {
        // Node capacity (not queue share) is the contended resource:
        // queue "a" helps fill the node, queue "b" blocks below its own
        // cap until a grant from "a" is released.
        let one_node = ClusterConfig {
            nodes: 1,
            cores_per_node: 4,
            gpus_per_node: 0,
            fpgas_per_node: 0,
            mem_per_node: 1000,
        };
        let rm = ResourceManager::with_queues(
            &one_node,
            vec![("a".into(), 0.5), ("b".into(), 0.75)],
            MetricsRegistry::new(),
        );
        rm.submit_app("apa", "a").unwrap();
        rm.submit_app("apb", "b").unwrap();
        let a1 = rm.request_container("apa", ResourceVec::cores(1, 10)).unwrap();
        let _a2 = rm.request_container("apa", ResourceVec::cores(1, 10)).unwrap();
        let _b1 = rm.request_container("apb", ResourceVec::cores(2, 10)).unwrap();
        // Node full; "b" holds 2 of its 3-core cap so the next request
        // is node-bound, not share-bound.
        let rm2 = rm.clone();
        let waiter = std::thread::spawn(move || {
            rm2.acquire_container("apb", ResourceVec::cores(1, 10), Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(50));
        rm.release(&a1).unwrap();
        let got = waiter.join().unwrap();
        assert!(got.is_ok(), "release in queue 'a' must wake the waiter in queue 'b'");
    }

    #[test]
    fn unknown_app_or_queue_errors() {
        let rm = rm();
        assert!(rm.submit_app("a", "nope").is_err());
        assert!(rm.request_container("ghost", ResourceVec::cores(1, 1)).is_err());
    }

    #[test]
    fn remove_app_frees_name_for_resubmission() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        assert!(rm.submit_app("a", "default").is_err(), "duplicate submit must fail");
        let c = rm.request_container("a", ResourceVec::cores(1, 10)).unwrap();
        assert!(rm.remove_app("a").is_err(), "live containers must block removal");
        rm.release(&c).unwrap();
        rm.remove_app("a").unwrap();
        assert!(rm.remove_app("a").is_err(), "already removed");
        rm.submit_app("a", "default").unwrap();
    }

    #[test]
    fn oversized_request_rejected() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        assert!(rm.request_container("a", ResourceVec::cores(5, 10)).is_err());
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let big = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let big2 = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let rm2 = rm.clone();
        let waiter = std::thread::spawn(move || {
            rm2.acquire_container("a", ResourceVec::cores(4, 100), Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(50));
        rm.release(&big).unwrap();
        let got = waiter.join().unwrap();
        assert!(got.is_ok());
        rm.release(&big2).unwrap();
    }

    #[test]
    fn acquire_times_out() {
        let rm = rm();
        rm.submit_app("a", "default").unwrap();
        let _c1 = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let _c2 = rm.request_container("a", ResourceVec::cores(4, 100)).unwrap();
        let r = rm.acquire_container("a", ResourceVec::cores(1, 1), Duration::from_millis(50));
        assert!(r.is_err());
    }
}
