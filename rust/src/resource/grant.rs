//! RAII ownership of resource-manager state: container grants and app
//! registrations that clean themselves up on every exit path.
//!
//! Before the unified job layer, each workload released its containers
//! in straight-line code — a shard failure or panic between grant and
//! release permanently deducted cluster capacity. [`Grant`] and
//! [`AppLease`] make release structural: dropping them (normally, on
//! `?`, or during unwinding) returns the containers and frees the app
//! name for resubmission.
//!
//! Acquisition is **gang-atomic**: the `min` floor is reserved
//! all-or-nothing by [`ResourceManager::acquire_gang`] under the
//! scheduler lock, so a grant waiting for its floor holds zero
//! containers and two concurrent floors can no longer hold-and-wait
//! each other into deadlock on a full cluster. The container set is
//! shared with the job layer so a preempted container can be swapped
//! for its replacement while the RAII release still covers everything.

use anyhow::Result;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::container::ContainerRef;
use super::device::ResourceVec;
use super::yarn::ResourceManager;
use crate::trace::{self, SpanCtx};

/// An application registration that unregisters itself on drop.
pub struct AppLease {
    rm: Arc<ResourceManager>,
    app: String,
}

impl AppLease {
    /// Register `app` against `queue`; the registration is removed when
    /// the lease drops (after its containers have been released).
    pub fn submit(rm: &Arc<ResourceManager>, app: &str, queue: &str) -> Result<Self> {
        rm.submit_app(app, queue)?;
        Ok(Self { rm: rm.clone(), app: app.to_string() })
    }

    pub fn app(&self) -> &str {
        &self.app
    }
}

impl Drop for AppLease {
    fn drop(&mut self) {
        // Fails only if containers are still live (the Grant must drop
        // first) or the app was already removed; neither is actionable
        // during drop.
        let _ = self.rm.remove_app(&self.app);
    }
}

/// An elastic set of granted containers, released RAII-style.
pub struct Grant {
    rm: Arc<ResourceManager>,
    containers: Arc<Mutex<Vec<ContainerRef>>>,
    wait: Duration,
}

impl Grant {
    /// Gang-atomic elastic acquisition: block (up to `timeout`) until
    /// the `min` floor can be reserved in one scheduler transaction,
    /// then take elastic extras up to `max`. While waiting, nothing is
    /// held; on timeout a typed [`super::GrantTimeout`] names the
    /// queue and the deficit.
    pub fn acquire(
        rm: &Arc<ResourceManager>,
        app: &str,
        req: ResourceVec,
        min: usize,
        max: usize,
        timeout: Duration,
    ) -> Result<Grant> {
        Self::acquire_in(rm, app, req, min, max, timeout, SpanCtx::NONE)
    }

    /// [`Grant::acquire`] with an explicit trace parent: the blocking
    /// gang wait is recorded as a `grant.acquire` span (category
    /// grant-wait) under the caller's job span, so the critical-path
    /// analyzer can attribute admission stalls.
    pub fn acquire_in(
        rm: &Arc<ResourceManager>,
        app: &str,
        req: ResourceVec,
        min: usize,
        max: usize,
        timeout: Duration,
        parent: SpanCtx,
    ) -> Result<Grant> {
        let mut sp = trace::span_in("grant.acquire", trace::Category::GrantWait, parent);
        sp.arg("min", min as u64).arg("max", max as u64);
        let start = Instant::now();
        let containers = rm.acquire_gang(app, req, min, max, timeout)?;
        sp.arg("granted", containers.len() as u64);
        Ok(Grant {
            rm: rm.clone(),
            containers: Arc::new(Mutex::new(containers)),
            wait: start.elapsed(),
        })
    }

    /// Snapshot of the currently held containers.
    pub fn containers(&self) -> Vec<ContainerRef> {
        self.containers.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.containers.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.containers.lock().unwrap().is_empty()
    }

    /// How long acquisition blocked waiting for capacity.
    pub fn wait(&self) -> Duration {
        self.wait
    }

    /// Shared handle to the live container set: the job layer swaps a
    /// preempted container for its replacement through it, so the RAII
    /// release on drop still covers every container the job ever held.
    pub(crate) fn shared(&self) -> Arc<Mutex<Vec<ContainerRef>>> {
        self.containers.clone()
    }

    /// Explicit release (equivalent to drop, but readable at call sites).
    pub fn release(self) {}
}

impl Drop for Grant {
    fn drop(&mut self) {
        for c in self.containers.lock().unwrap().drain(..) {
            if !c.is_released() {
                let _ = self.rm.release(&c);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::metrics::MetricsRegistry;
    use crate::resource::GrantTimeout;

    fn rm() -> Arc<ResourceManager> {
        let cluster = ClusterConfig {
            nodes: 2,
            cores_per_node: 2,
            gpus_per_node: 0,
            fpgas_per_node: 0,
            mem_per_node: 1000,
        };
        ResourceManager::new(&cluster, MetricsRegistry::new())
    }

    #[test]
    fn grant_releases_on_drop() {
        let rm = rm();
        rm.submit_app("g", "default").unwrap();
        {
            let g = Grant::acquire(
                &rm,
                "g",
                ResourceVec::cores(1, 10),
                1,
                3,
                Duration::from_millis(10),
            )
            .unwrap();
            assert_eq!(g.len(), 3);
            assert_eq!(rm.live_containers(), 3);
        }
        assert_eq!(rm.live_containers(), 0, "drop must return every container");
    }

    #[test]
    fn grant_is_elastic_between_min_and_max() {
        let rm = rm();
        rm.submit_app("g", "default").unwrap();
        // Only 4 cores exist; asking for up to 16 degrades gracefully.
        let g = Grant::acquire(
            &rm,
            "g",
            ResourceVec::cores(1, 10),
            1,
            16,
            Duration::from_millis(10),
        )
        .unwrap();
        assert_eq!(g.len(), 4);
    }

    #[test]
    fn grant_below_floor_times_out_holding_nothing() {
        let rm = rm();
        rm.submit_app("hog", "default").unwrap();
        rm.submit_app("g", "default").unwrap();
        let _hold = rm.request_container("hog", ResourceVec::cores(2, 10)).unwrap();
        let _hold2 = rm.request_container("hog", ResourceVec::cores(1, 10)).unwrap();
        // One core free but the floor is 2: gang admission must time
        // out without ever holding the single container it could get.
        let r = Grant::acquire(
            &rm,
            "g",
            ResourceVec::cores(1, 10),
            2,
            2,
            Duration::from_millis(50),
        );
        let e = r.unwrap_err();
        let t = e.downcast_ref::<GrantTimeout>().expect("typed GrantTimeout");
        assert_eq!((t.deficit, t.grantable), (1, 1));
        assert_eq!(rm.live_containers(), 2, "only the hog's containers remain live");
    }

    #[test]
    fn concurrent_floors_exceeding_the_cluster_do_not_deadlock() {
        // Regression for the PR-3 escalation path: two floor-3 grants
        // on a 4-core cluster could hold 2+2 and starve each other to
        // timeout. Gang admission serializes them: each floor is
        // reserved whole, so both jobs complete within the timeout.
        let rm = rm();
        rm.submit_app("j1", "default").unwrap();
        rm.submit_app("j2", "default").unwrap();
        let (r1, r2) = std::thread::scope(|s| {
            let spawn_job = |app: &'static str| {
                let rm = rm.clone();
                move || -> Result<usize> {
                    let g = Grant::acquire(
                        &rm,
                        app,
                        ResourceVec::cores(1, 10),
                        3,
                        3,
                        Duration::from_secs(5),
                    )?;
                    let n = g.len();
                    std::thread::sleep(Duration::from_millis(20));
                    g.release();
                    Ok(n)
                }
            };
            let h1 = s.spawn(spawn_job("j1"));
            let h2 = s.spawn(spawn_job("j2"));
            (h1.join().unwrap(), h2.join().unwrap())
        });
        assert_eq!(r1.unwrap(), 3, "first floor must admit");
        assert_eq!(r2.unwrap(), 3, "second floor must admit after the first releases");
        assert_eq!(rm.live_containers(), 0);
    }

    #[test]
    fn infeasible_request_fails_fast() {
        let rm = rm();
        rm.submit_app("g", "default").unwrap();
        let t = Instant::now();
        // 3 cores can never fit a 2-core node: must not burn the
        // 5-second blocking timeout before erroring.
        let r = Grant::acquire(
            &rm,
            "g",
            ResourceVec::cores(3, 10),
            1,
            1,
            Duration::from_secs(5),
        );
        assert!(r.is_err());
        assert!(t.elapsed() < Duration::from_secs(1), "must fail fast, not block");
    }

    #[test]
    fn app_lease_unregisters_on_drop() {
        let rm = rm();
        {
            let lease = AppLease::submit(&rm, "lease", "default").unwrap();
            assert_eq!(lease.app(), "lease");
            assert!(rm.submit_app("lease", "default").is_err(), "name held while leased");
        }
        rm.submit_app("lease", "default").unwrap();
    }
}
