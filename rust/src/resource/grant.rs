//! RAII ownership of resource-manager state: container grants and app
//! registrations that clean themselves up on every exit path.
//!
//! Before the unified job layer, each workload released its containers
//! in straight-line code — a shard failure or panic between grant and
//! release permanently deducted cluster capacity. [`Grant`] and
//! [`AppLease`] make release structural: dropping them (normally, on
//! `?`, or during unwinding) returns the containers and frees the app
//! name for resubmission.

use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::container::ContainerRef;
use super::device::ResourceVec;
use super::yarn::ResourceManager;

/// An application registration that unregisters itself on drop.
pub struct AppLease {
    rm: Arc<ResourceManager>,
    app: String,
}

impl AppLease {
    /// Register `app` against `queue`; the registration is removed when
    /// the lease drops (after its containers have been released).
    pub fn submit(rm: &Arc<ResourceManager>, app: &str, queue: &str) -> Result<Self> {
        rm.submit_app(app, queue)?;
        Ok(Self { rm: rm.clone(), app: app.to_string() })
    }

    pub fn app(&self) -> &str {
        &self.app
    }
}

impl Drop for AppLease {
    fn drop(&mut self) {
        // Fails only if containers are still live (the Grant must drop
        // first) or the app was already removed; neither is actionable
        // during drop.
        let _ = self.rm.remove_app(&self.app);
    }
}

/// An elastic set of granted containers, released RAII-style.
pub struct Grant {
    rm: Arc<ResourceManager>,
    containers: Vec<ContainerRef>,
    wait: Duration,
}

impl Grant {
    /// Elastic acquisition: greedily take whatever is free right now
    /// (up to `max` containers of `req` each), then block — waiting for
    /// other jobs to release — until at least `min` are held or
    /// `timeout` expires. A partial grant below the floor is returned
    /// to the pool before the error propagates.
    pub fn acquire(
        rm: &Arc<ResourceManager>,
        app: &str,
        req: ResourceVec,
        min: usize,
        max: usize,
        timeout: Duration,
    ) -> Result<Grant> {
        let min = min.max(1);
        let max = max.max(min);
        let start = Instant::now();
        let mut grant = Grant { rm: rm.clone(), containers: Vec::new(), wait: Duration::ZERO };
        for _ in 0..max {
            match rm.request_container(app, req) {
                Ok(c) => grant.containers.push(c),
                Err(_) => break,
            }
        }
        if grant.containers.len() < min {
            // Fail fast on requests that no node shape or queue cap can
            // ever satisfy — blocking would only burn the full timeout.
            rm.check_feasible(app, req)?;
        }
        // Escalation holds the partial grant while waiting, so two jobs
        // with floors > 1 can hold-and-wait each other into timeout
        // (bounded by `timeout`, never a permanent deadlock). Atomic
        // floor acquisition — gang scheduling — is tracked in ROADMAP.
        while grant.containers.len() < min {
            let left = timeout.saturating_sub(start.elapsed());
            if left.is_zero() {
                bail!(
                    "grant for '{app}' timed out below its floor: {}/{} container(s) after {:?}",
                    grant.containers.len(),
                    min,
                    timeout
                );
            }
            grant.containers.push(rm.acquire_container(app, req, left)?);
        }
        grant.wait = start.elapsed();
        Ok(grant)
    }

    pub fn containers(&self) -> &[ContainerRef] {
        &self.containers
    }

    pub fn len(&self) -> usize {
        self.containers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.containers.is_empty()
    }

    /// How long acquisition blocked waiting for capacity.
    pub fn wait(&self) -> Duration {
        self.wait
    }

    /// Explicit release (equivalent to drop, but readable at call sites).
    pub fn release(self) {}
}

impl Drop for Grant {
    fn drop(&mut self) {
        for c in self.containers.drain(..) {
            if !c.is_released() {
                let _ = self.rm.release(&c);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::metrics::MetricsRegistry;

    fn rm() -> Arc<ResourceManager> {
        let cluster = ClusterConfig {
            nodes: 2,
            cores_per_node: 2,
            gpus_per_node: 0,
            fpgas_per_node: 0,
            mem_per_node: 1000,
        };
        ResourceManager::new(&cluster, MetricsRegistry::new())
    }

    #[test]
    fn grant_releases_on_drop() {
        let rm = rm();
        rm.submit_app("g", "default").unwrap();
        {
            let g = Grant::acquire(
                &rm,
                "g",
                ResourceVec::cores(1, 10),
                1,
                3,
                Duration::from_millis(10),
            )
            .unwrap();
            assert_eq!(g.len(), 3);
            assert_eq!(rm.live_containers(), 3);
        }
        assert_eq!(rm.live_containers(), 0, "drop must return every container");
    }

    #[test]
    fn grant_is_elastic_between_min_and_max() {
        let rm = rm();
        rm.submit_app("g", "default").unwrap();
        // Only 4 cores exist; asking for up to 16 degrades gracefully.
        let g = Grant::acquire(
            &rm,
            "g",
            ResourceVec::cores(1, 10),
            1,
            16,
            Duration::from_millis(10),
        )
        .unwrap();
        assert_eq!(g.len(), 4);
    }

    #[test]
    fn grant_below_floor_times_out_and_returns_partials() {
        let rm = rm();
        rm.submit_app("hog", "default").unwrap();
        rm.submit_app("g", "default").unwrap();
        let _hold = rm.request_container("hog", ResourceVec::cores(2, 10)).unwrap();
        let _hold2 = rm.request_container("hog", ResourceVec::cores(1, 10)).unwrap();
        // One core free but the floor is 2: acquisition must time out
        // and give back the single container it did get.
        let r = Grant::acquire(
            &rm,
            "g",
            ResourceVec::cores(1, 10),
            2,
            2,
            Duration::from_millis(50),
        );
        assert!(r.is_err());
        assert_eq!(rm.live_containers(), 2, "partial grant must be returned");
    }

    #[test]
    fn infeasible_request_fails_fast() {
        let rm = rm();
        rm.submit_app("g", "default").unwrap();
        let t = Instant::now();
        // 3 cores can never fit a 2-core node: must not burn the
        // 5-second blocking timeout before erroring.
        let r = Grant::acquire(
            &rm,
            "g",
            ResourceVec::cores(3, 10),
            1,
            1,
            Duration::from_secs(5),
        );
        assert!(r.is_err());
        assert!(t.elapsed() < Duration::from_secs(1), "must fail fast, not block");
    }

    #[test]
    fn app_lease_unregisters_on_drop() {
        let rm = rm();
        {
            let lease = AppLease::submit(&rm, "lease", "default").unwrap();
            assert_eq!(lease.app(), "lease");
            assert!(rm.submit_app("lease", "default").is_err(), "name held while leased");
        }
        rm.submit_app("lease", "default").unwrap();
    }
}
