//! Heterogeneous device inventory (paper section 2.3).
//!
//! Each node exposes CPU cores plus GPU-class and FPGA-class
//! accelerators. GPU-class devices are backed by real PJRT device-server
//! threads executing the AOT-compiled XLA artifacts; FPGA-class devices
//! execute the same artifacts under a calibrated throughput/power model
//! (see `hetero::energy` and DESIGN.md's substitution ledger).

use std::fmt;

/// The three compute substrates of the paper's platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceKind {
    Cpu,
    Gpu,
    Fpga,
}

impl DeviceKind {
    pub const ALL: [DeviceKind; 3] = [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::Fpga];

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::Cpu => "cpu",
            DeviceKind::Gpu => "gpu",
            DeviceKind::Fpga => "fpga",
        }
    }

    /// Modelled board power (W) for the energy accounting of E3/E11.
    /// Values follow the class the paper targets (server CPU socket,
    /// discrete training GPU, mid-size FPGA card).
    pub fn power_watts(&self) -> f64 {
        match self {
            DeviceKind::Cpu => 95.0,
            DeviceKind::Gpu => 250.0,
            DeviceKind::Fpga => 25.0,
        }
    }
}

impl fmt::Display for DeviceKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A concrete device slot on a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeviceId {
    pub node: usize,
    pub kind: DeviceKind,
    /// Index within (node, kind).
    pub index: usize,
}

impl fmt::Display for DeviceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node{}/{}{}", self.node, self.kind, self.index)
    }
}

/// Resources a container asks for (the YARN request vector).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceVec {
    pub cores: usize,
    pub mem_bytes: u64,
    pub gpus: usize,
    pub fpgas: usize,
}

impl ResourceVec {
    pub fn cores(cores: usize, mem_bytes: u64) -> Self {
        Self { cores, mem_bytes, gpus: 0, fpgas: 0 }
    }

    pub fn with_gpu(mut self, gpus: usize) -> Self {
        self.gpus = gpus;
        self
    }

    pub fn with_fpga(mut self, fpgas: usize) -> Self {
        self.fpgas = fpgas;
        self
    }

    pub fn fits_in(&self, avail: &ResourceVec) -> bool {
        self.cores <= avail.cores
            && self.mem_bytes <= avail.mem_bytes
            && self.gpus <= avail.gpus
            && self.fpgas <= avail.fpgas
    }

    pub fn add(&mut self, other: &ResourceVec) {
        self.cores += other.cores;
        self.mem_bytes += other.mem_bytes;
        self.gpus += other.gpus;
        self.fpgas += other.fpgas;
    }

    pub fn sub(&mut self, other: &ResourceVec) {
        self.cores -= other.cores;
        self.mem_bytes -= other.mem_bytes;
        self.gpus -= other.gpus;
        self.fpgas -= other.fpgas;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_respects_every_dimension() {
        let avail = ResourceVec { cores: 4, mem_bytes: 100, gpus: 1, fpgas: 0 };
        assert!(ResourceVec::cores(4, 100).fits_in(&avail));
        assert!(!ResourceVec::cores(5, 1).fits_in(&avail));
        assert!(!ResourceVec::cores(1, 101).fits_in(&avail));
        assert!(!ResourceVec::cores(1, 1).with_gpu(2).fits_in(&avail));
        assert!(!ResourceVec::cores(1, 1).with_fpga(1).fits_in(&avail));
    }

    #[test]
    fn add_sub_inverse() {
        let mut a = ResourceVec { cores: 4, mem_bytes: 100, gpus: 2, fpgas: 1 };
        let b = ResourceVec { cores: 1, mem_bytes: 30, gpus: 1, fpgas: 1 };
        a.add(&b);
        a.sub(&b);
        assert_eq!(a, ResourceVec { cores: 4, mem_bytes: 100, gpus: 2, fpgas: 1 });
    }

    #[test]
    fn device_display() {
        let d = DeviceId { node: 2, kind: DeviceKind::Gpu, index: 0 };
        assert_eq!(d.to_string(), "node2/gpu0");
        assert!(DeviceKind::Fpga.power_watts() < DeviceKind::Gpu.power_watts());
    }
}
