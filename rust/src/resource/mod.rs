//! Resource management: the YARN-analog scheduler handing out
//! LXC-analog containers over a heterogeneous (CPU/GPU/FPGA) device
//! inventory (paper section 2.3, Figure 3).

pub mod container;
pub mod device;
pub mod grant;
pub mod yarn;

pub use container::{Container, ContainerCtx, ContainerRef};
pub use device::{DeviceId, DeviceKind, ResourceVec};
pub use grant::{AppLease, Grant};
pub use yarn::{GrantTimeout, ResourceManager};
