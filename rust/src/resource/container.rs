//! LXC-analog containers (paper section 2.3).
//!
//! "LXC allows isolation, limitation, and prioritization of resources ...
//! the CPU overhead of hosting a LXC is less than 5% comparing to
//! running an application natively." A [`Container`] here is the same
//! contract: a resource-limited execution wrapper. Isolation is enforced
//! by accounting (memory charges against the container's limit fail when
//! exceeded; core slots bound the wrapped closure's parallelism budget),
//! and the wrapper's real measured overhead is what experiment E4
//! reports against the paper's <5% claim.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::device::{DeviceId, ResourceVec};
use crate::metrics::MetricsRegistry;
use crate::trace::{self, SpanCtx};

/// A granted, resource-limited execution context.
pub struct Container {
    pub id: u64,
    pub app: String,
    pub node: usize,
    pub limits: ResourceVec,
    /// Concrete accelerator slots granted to this container.
    pub devices: Vec<DeviceId>,
    mem_used: AtomicU64,
    released: AtomicBool,
    preempt_requested: AtomicBool,
    cpu_time_us: AtomicU64,
    metrics: MetricsRegistry,
}

impl Container {
    pub(super) fn new(
        id: u64,
        app: String,
        node: usize,
        limits: ResourceVec,
        devices: Vec<DeviceId>,
        metrics: MetricsRegistry,
    ) -> Self {
        Self {
            id,
            app,
            node,
            limits,
            devices,
            mem_used: AtomicU64::new(0),
            released: AtomicBool::new(false),
            preempt_requested: AtomicBool::new(false),
            cpu_time_us: AtomicU64::new(0),
            metrics,
        }
    }

    /// Run a task inside the container: usage accounting + cgroup-style
    /// bookkeeping wraps the closure. The wrapper is intentionally thin —
    /// its measured overhead is the E4 experiment.
    pub fn run<T>(&self, f: impl FnOnce(&ContainerCtx) -> T) -> Result<T> {
        if self.released.load(Ordering::Acquire) {
            bail!("container {} already released", self.id);
        }
        // Capture the caller's span so code inside the container (the
        // compactor, campaign scoring) can parent its spans on the
        // shard attempt that scheduled it.
        let ctx = ContainerCtx { container: self, trace: trace::current() };
        let start = Instant::now();
        let out = f(&ctx);
        let elapsed = start.elapsed();
        self.cpu_time_us
            .fetch_add(elapsed.as_micros() as u64, Ordering::Relaxed);
        self.metrics.counter("resource.container.tasks").inc();
        Ok(out)
    }

    /// Charge an allocation against the memory limit (cgroup memcg-style).
    pub fn alloc_mem(&self, bytes: u64) -> Result<()> {
        let prev = self.mem_used.fetch_add(bytes, Ordering::AcqRel);
        if prev + bytes > self.limits.mem_bytes {
            self.mem_used.fetch_sub(bytes, Ordering::AcqRel);
            self.metrics.counter("resource.container.oom_kills").inc();
            bail!(
                "container {}: OOM — {} + {} exceeds limit {}",
                self.id,
                prev,
                bytes,
                self.limits.mem_bytes
            );
        }
        Ok(())
    }

    pub fn free_mem(&self, bytes: u64) {
        self.mem_used.fetch_sub(bytes.min(self.mem_used.load(Ordering::Acquire)), Ordering::AcqRel);
    }

    pub fn mem_used(&self) -> u64 {
        self.mem_used.load(Ordering::Acquire)
    }

    pub fn cpu_time(&self) -> std::time::Duration {
        std::time::Duration::from_micros(self.cpu_time_us.load(Ordering::Relaxed))
    }

    pub fn is_released(&self) -> bool {
        self.released.load(Ordering::Acquire)
    }

    pub(super) fn mark_released(&self) {
        self.released.store(true, Ordering::Release);
    }

    /// Whether the resource manager has asked this container to yield
    /// so a queue below its guaranteed share can reclaim capacity. The
    /// signal is cooperative: workloads poll it between work items,
    /// checkpoint, and return the container.
    pub fn preempt_requested(&self) -> bool {
        self.preempt_requested.load(Ordering::Acquire)
    }

    pub(super) fn request_preempt(&self) {
        self.preempt_requested.store(true, Ordering::Release);
    }

    /// First granted device of the requested kind, if any.
    pub fn device(&self, kind: super::device::DeviceKind) -> Option<DeviceId> {
        self.devices.iter().copied().find(|d| d.kind == kind)
    }
}

/// Handle passed to code running inside a container.
pub struct ContainerCtx<'a> {
    container: &'a Container,
    /// Trace context of the span that entered the container.
    trace: SpanCtx,
}

impl ContainerCtx<'_> {
    /// Trace parent for spans opened by code inside this container.
    pub fn trace(&self) -> SpanCtx {
        self.trace
    }

    pub fn alloc_mem(&self, bytes: u64) -> Result<()> {
        self.container.alloc_mem(bytes)
    }

    pub fn free_mem(&self, bytes: u64) {
        self.container.free_mem(bytes)
    }

    pub fn limits(&self) -> &ResourceVec {
        &self.container.limits
    }

    pub fn devices(&self) -> &[DeviceId] {
        &self.container.devices
    }

    /// Preemption signal, visible to code running inside the container
    /// (e.g. the compactor's drain loop checks it between blocks).
    pub fn preempt_requested(&self) -> bool {
        self.container.preempt_requested()
    }
}

/// Shared ownership wrapper handed out by the resource manager.
pub type ContainerRef = Arc<Container>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resource::device::DeviceKind;

    fn container(mem: u64) -> Container {
        Container::new(
            1,
            "test".into(),
            0,
            ResourceVec::cores(2, mem),
            vec![DeviceId { node: 0, kind: DeviceKind::Gpu, index: 0 }],
            MetricsRegistry::new(),
        )
    }

    #[test]
    fn run_returns_value_and_accounts_time() {
        let c = container(1000);
        let out = c.run(|_| 7 * 6).unwrap();
        assert_eq!(out, 42);
        assert!(c.cpu_time() > std::time::Duration::ZERO || c.cpu_time().is_zero());
    }

    #[test]
    fn memory_limit_enforced() {
        let c = container(100);
        c.alloc_mem(60).unwrap();
        assert!(c.alloc_mem(60).is_err(), "should OOM");
        assert_eq!(c.mem_used(), 60); // failed alloc rolled back
        c.free_mem(60);
        assert_eq!(c.mem_used(), 0);
        c.alloc_mem(100).unwrap();
    }

    #[test]
    fn released_container_rejects_tasks() {
        let c = container(10);
        c.mark_released();
        assert!(c.run(|_| ()).is_err());
    }

    #[test]
    fn device_lookup_by_kind() {
        let c = container(10);
        assert!(c.device(DeviceKind::Gpu).is_some());
        assert!(c.device(DeviceKind::Fpga).is_none());
    }

    #[test]
    fn ctx_delegates_to_container() {
        let c = container(50);
        c.run(|ctx| {
            ctx.alloc_mem(40).unwrap();
            assert!(ctx.alloc_mem(20).is_err());
            ctx.free_mem(40);
            assert_eq!(ctx.limits().mem_bytes, 50);
            assert_eq!(ctx.devices().len(), 1);
        })
        .unwrap();
    }
}
