//! Calibrated task cost models.
//!
//! The paper's scaling figures (Fig 6: 2,000→10,000 cores; Fig 9: 1→N
//! GPUs) ran on a datacenter we don't have. The reproduction anchors the
//! virtual-time simulator ([`super::simclock`]) to *real measured costs*:
//! run the genuine task closure on real data on this host, fit a
//! per-record/per-byte linear model, and let the simulator schedule
//! thousands of such tasks. The scheduler, partitioner and stage
//! structure being simulated are the real ones.

use std::time::{Duration, Instant};

/// Linear task cost model: `fixed + records * per_record + bytes * per_byte`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    pub fixed_ns: f64,
    pub per_record_ns: f64,
    pub per_byte_ns: f64,
}

impl CostModel {
    pub fn task_duration(&self, records: u64, bytes: u64) -> Duration {
        let ns =
            self.fixed_ns + records as f64 * self.per_record_ns + bytes as f64 * self.per_byte_ns;
        Duration::from_nanos(ns.max(0.0) as u64)
    }

    /// Pure per-record model.
    pub fn per_record(ns: f64) -> Self {
        Self { fixed_ns: 0.0, per_record_ns: ns, per_byte_ns: 0.0 }
    }
}

/// Measure the mean wall-clock cost of one call of `f` (runs it
/// `warmup + iters` times; returns the timed mean over `iters`).
pub fn measure_per_item_cost(mut f: impl FnMut(), warmup: usize, iters: usize) -> Duration {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters.max(1) {
        f();
    }
    start.elapsed() / iters.max(1) as u32
}

/// Calibrate a per-record cost model by timing `f` over a real sample.
/// `f` must process exactly one record per call.
pub fn calibrate_per_record(f: impl FnMut(), warmup: usize, iters: usize) -> CostModel {
    let per = measure_per_item_cost(f, warmup, iters);
    CostModel::per_record(per.as_nanos() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_model_arithmetic() {
        let m = CostModel { fixed_ns: 1000.0, per_record_ns: 10.0, per_byte_ns: 1.0 };
        assert_eq!(m.task_duration(100, 500), Duration::from_nanos(1000 + 1000 + 500));
        assert_eq!(m.task_duration(0, 0), Duration::from_nanos(1000));
    }

    #[test]
    fn measure_cost_scales_with_work() {
        let cheap = measure_per_item_cost(|| { std::hint::black_box(1 + 1); }, 10, 200);
        let pricey = measure_per_item_cost(
            || {
                let mut s = 0u64;
                for i in 0..20_000u64 {
                    s = s.wrapping_add(std::hint::black_box(i * i));
                }
                std::hint::black_box(s);
            },
            3,
            30,
        );
        assert!(pricey > cheap * 5, "pricey={pricey:?} cheap={cheap:?}");
    }

    #[test]
    fn calibrate_produces_positive_model() {
        let m = calibrate_per_record(|| { std::hint::black_box(42); }, 5, 50);
        assert!(m.per_record_ns >= 0.0);
        assert!(m.task_duration(1000, 0) >= Duration::ZERO);
    }
}
