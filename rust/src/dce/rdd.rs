//! RDDs: typed, lazily-evaluated, lineage-tracked distributed datasets.
//!
//! Narrow transformations (map/filter/flatMap/mapPartitions/union) are
//! pipelined: a task computes its whole parent chain in one pass, exactly
//! like Spark's narrow-dependency stages. Wide transformations live in
//! [`super::pair`] and cut stages at shuffle boundaries.

use anyhow::Result;
use std::sync::Arc;

use super::context::DceContext;
use super::executor::TaskContext;

/// Marker bound for record types.
pub trait Data: Clone + Send + Sync + 'static {}
impl<T: Clone + Send + Sync + 'static> Data for T {}

/// One node of the lineage graph.
pub trait RddNode<T: Data>: Send + Sync {
    fn num_partitions(&self) -> usize;
    fn compute(&self, part: usize, tc: &TaskContext) -> Result<Vec<T>>;
    /// Direct shuffle dependencies (narrow nodes forward their parent's).
    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleDep>>;
    /// Preferred executor worker for computing `part` (shuffle readers
    /// answer the worker holding the plurality of the partition's map
    /// bytes; narrow nodes forward their parent's answer). Placement
    /// only — stealing still balances.
    fn placement_hint(&self, _part: usize) -> Option<usize> {
        None
    }
}

/// Type-erased wide dependency (a shuffle's map side).
pub trait ShuffleDep: Send + Sync {
    fn shuffle_id(&self) -> usize;
    fn num_maps(&self) -> usize;
    fn run_map_task(&self, map_part: usize, tc: &TaskContext) -> Result<()>;
    /// Shuffles this shuffle's map side itself depends on.
    fn parents(&self) -> Vec<Arc<dyn ShuffleDep>>;
    /// Preferred worker for running map task `map_part` (the map side's
    /// own input may in turn come from an earlier shuffle).
    fn placement_hint(&self, _map_part: usize) -> Option<usize> {
        None
    }
}

/// A typed distributed dataset.
pub struct Rdd<T: Data> {
    pub(crate) ctx: DceContext,
    pub(crate) node: Arc<dyn RddNode<T>>,
    pub(crate) id: usize,
}

impl<T: Data> Clone for Rdd<T> {
    fn clone(&self) -> Self {
        Self { ctx: self.ctx.clone(), node: self.node.clone(), id: self.id }
    }
}

// ---------------------------------------------------------------------------
// Concrete lineage nodes
// ---------------------------------------------------------------------------

struct ParallelizeNode<T: Data> {
    parts: Vec<Arc<Vec<T>>>,
}

impl<T: Data> RddNode<T> for ParallelizeNode<T> {
    fn num_partitions(&self) -> usize {
        self.parts.len()
    }
    fn compute(&self, part: usize, _tc: &TaskContext) -> Result<Vec<T>> {
        Ok(self.parts[part].as_ref().clone())
    }
    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleDep>> {
        Vec::new()
    }
}

struct MapPartitionsNode<T: Data, U: Data> {
    parent: Arc<dyn RddNode<T>>,
    #[allow(clippy::type_complexity)]
    f: Arc<dyn Fn(usize, Vec<T>) -> Result<Vec<U>> + Send + Sync>,
}

impl<T: Data, U: Data> RddNode<U> for MapPartitionsNode<T, U> {
    fn num_partitions(&self) -> usize {
        self.parent.num_partitions()
    }
    fn compute(&self, part: usize, tc: &TaskContext) -> Result<Vec<U>> {
        let input = self.parent.compute(part, tc)?;
        (self.f)(part, input)
    }
    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleDep>> {
        self.parent.shuffle_deps()
    }
    fn placement_hint(&self, part: usize) -> Option<usize> {
        self.parent.placement_hint(part)
    }
}

struct UnionNode<T: Data> {
    parents: Vec<Arc<dyn RddNode<T>>>,
    /// (parent index, partition within parent) per output partition.
    index: Vec<(usize, usize)>,
}

impl<T: Data> RddNode<T> for UnionNode<T> {
    fn num_partitions(&self) -> usize {
        self.index.len()
    }
    fn compute(&self, part: usize, tc: &TaskContext) -> Result<Vec<T>> {
        let (pi, pp) = self.index[part];
        self.parents[pi].compute(pp, tc)
    }
    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleDep>> {
        self.parents.iter().flat_map(|p| p.shuffle_deps()).collect()
    }
    fn placement_hint(&self, part: usize) -> Option<usize> {
        let (pi, pp) = self.index[part];
        self.parents[pi].placement_hint(pp)
    }
}

struct CoalesceNode<T: Data> {
    parent: Arc<dyn RddNode<T>>,
    /// Parent partitions grouped per output partition.
    groups: Vec<Vec<usize>>,
}

impl<T: Data> RddNode<T> for CoalesceNode<T> {
    fn num_partitions(&self) -> usize {
        self.groups.len()
    }
    fn compute(&self, part: usize, tc: &TaskContext) -> Result<Vec<T>> {
        let mut out = Vec::new();
        for &pp in &self.groups[part] {
            out.extend(self.parent.compute(pp, tc)?);
        }
        Ok(out)
    }
    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleDep>> {
        self.parent.shuffle_deps()
    }
}

struct CachedNode<T: Data> {
    parent: Arc<dyn RddNode<T>>,
    ctx: DceContext,
    rdd_id: usize,
}

impl<T: Data> RddNode<T> for CachedNode<T> {
    fn num_partitions(&self) -> usize {
        self.parent.num_partitions()
    }
    fn compute(&self, part: usize, tc: &TaskContext) -> Result<Vec<T>> {
        if let Some(hit) = self.ctx.inner.cache.get::<T>(self.rdd_id, part) {
            tc.metrics.counter("dce.cache.hits").inc();
            return Ok(hit.as_ref().clone());
        }
        tc.metrics.counter("dce.cache.misses").inc();
        let data = Arc::new(self.parent.compute(part, tc)?);
        self.ctx.inner.cache.put(self.rdd_id, part, data.clone());
        Ok(data.as_ref().clone())
    }
    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleDep>> {
        self.parent.shuffle_deps()
    }
    fn placement_hint(&self, part: usize) -> Option<usize> {
        self.parent.placement_hint(part)
    }
}

// ---------------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------------

impl<T: Data> Rdd<T> {
    pub(crate) fn from_node(ctx: DceContext, node: Arc<dyn RddNode<T>>) -> Self {
        let id = ctx.next_id();
        Self { ctx, node, id }
    }

    pub(crate) fn parallelize(ctx: DceContext, data: Vec<T>, parts: usize) -> Self {
        let n = data.len();
        let per = n.div_ceil(parts.max(1)).max(1);
        let mut chunks: Vec<Arc<Vec<T>>> = Vec::new();
        let mut it = data.into_iter();
        for _ in 0..parts {
            let chunk: Vec<T> = it.by_ref().take(per).collect();
            chunks.push(Arc::new(chunk));
        }
        Self::from_node(ctx, Arc::new(ParallelizeNode { parts: chunks }))
    }

    pub fn context(&self) -> &DceContext {
        &self.ctx
    }

    pub fn num_partitions(&self) -> usize {
        self.node.num_partitions()
    }

    /// Element-wise transform (narrow, pipelined).
    pub fn map<U: Data>(&self, f: impl Fn(T) -> U + Send + Sync + 'static) -> Rdd<U> {
        let f = Arc::new(f);
        self.map_partitions(move |_, items| Ok(items.into_iter().map(|t| f(t)).collect()))
    }

    pub fn filter(&self, f: impl Fn(&T) -> bool + Send + Sync + 'static) -> Rdd<T> {
        let f = Arc::new(f);
        self.map_partitions(move |_, items| Ok(items.into_iter().filter(|t| f(t)).collect()))
    }

    pub fn flat_map<U: Data>(
        &self,
        f: impl Fn(T) -> Vec<U> + Send + Sync + 'static,
    ) -> Rdd<U> {
        let f = Arc::new(f);
        self.map_partitions(move |_, items| Ok(items.into_iter().flat_map(|t| f(t)).collect()))
    }

    /// Whole-partition transform (the workhorse for kernels and pipes).
    pub fn map_partitions<U: Data>(
        &self,
        f: impl Fn(usize, Vec<T>) -> Result<Vec<U>> + Send + Sync + 'static,
    ) -> Rdd<U> {
        Rdd::from_node(
            self.ctx.clone(),
            Arc::new(MapPartitionsNode { parent: self.node.clone(), f: Arc::new(f) }),
        )
    }

    /// Key every element.
    pub fn key_by<K: Data>(&self, f: impl Fn(&T) -> K + Send + Sync + 'static) -> Rdd<(K, T)> {
        self.map(move |t| (f(&t), t))
    }

    pub fn union(&self, other: &Rdd<T>) -> Rdd<T> {
        let parents = vec![self.node.clone(), other.node.clone()];
        let mut index = Vec::new();
        for (pi, p) in parents.iter().enumerate() {
            for pp in 0..p.num_partitions() {
                index.push((pi, pp));
            }
        }
        Rdd::from_node(self.ctx.clone(), Arc::new(UnionNode { parents, index }))
    }

    /// Merge partitions down to `n` (narrow repartition).
    pub fn coalesce(&self, n: usize) -> Rdd<T> {
        let parts = self.node.num_partitions();
        let n = n.clamp(1, parts.max(1));
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n];
        for p in 0..parts {
            groups[p % n].push(p);
        }
        Rdd::from_node(
            self.ctx.clone(),
            Arc::new(CoalesceNode { parent: self.node.clone(), groups }),
        )
    }

    /// Deterministic Bernoulli sample.
    pub fn sample(&self, fraction: f64, seed: u64) -> Rdd<T> {
        self.map_partitions(move |part, items| {
            let mut rng = crate::util::Rng::new(seed ^ (part as u64).wrapping_mul(0x9E37));
            Ok(items.into_iter().filter(|_| rng.next_f64() < fraction).collect())
        })
    }

    /// Memoise computed partitions in the driver-side object cache.
    pub fn cache(&self) -> Rdd<T> {
        let node = Arc::new(CachedNode {
            parent: self.node.clone(),
            ctx: self.ctx.clone(),
            rdd_id: self.id,
        });
        Rdd { ctx: self.ctx.clone(), node, id: self.id }
    }

    /// Drop this RDD's cached partitions.
    pub fn uncache(&self) {
        self.ctx.inner.cache.evict_rdd(self.id);
    }

    // ------------------------------------------------------------ actions

    pub fn collect(&self) -> Result<Vec<T>> {
        let parts = self
            .ctx
            .run_job(self.node.clone(), Arc::new(|_, items: Vec<T>| Ok(items)))?;
        Ok(parts.into_iter().flatten().collect())
    }

    pub fn count(&self) -> Result<usize> {
        let counts = self
            .ctx
            .run_job(self.node.clone(), Arc::new(|_, items: Vec<T>| Ok(items.len())))?;
        Ok(counts.into_iter().sum())
    }

    /// Parallel fold-then-merge reduction. Returns None on empty data.
    pub fn reduce(&self, f: impl Fn(T, T) -> T + Send + Sync + 'static) -> Result<Option<T>> {
        let f = Arc::new(f);
        let f2 = f.clone();
        let partials = self.ctx.run_job(
            self.node.clone(),
            Arc::new(move |_, items: Vec<T>| Ok(items.into_iter().reduce(|a, b| f2(a, b)))),
        )?;
        Ok(partials.into_iter().flatten().reduce(|a, b| f(a, b)))
    }

    pub fn take(&self, n: usize) -> Result<Vec<T>> {
        let mut out = self.collect()?;
        out.truncate(n);
        Ok(out)
    }

    pub fn first(&self) -> Result<Option<T>> {
        Ok(self.take(1)?.into_iter().next())
    }

    /// Run a side-effecting closure per partition (e.g. writing output).
    pub fn foreach_partition(
        &self,
        f: impl Fn(usize, Vec<T>) -> Result<()> + Send + Sync + 'static,
    ) -> Result<()> {
        let f = Arc::new(f);
        self.ctx
            .run_job(self.node.clone(), Arc::new(move |p, items: Vec<T>| f(p, items)))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> DceContext {
        DceContext::local().unwrap()
    }

    #[test]
    fn parallelize_collect_roundtrip() {
        let c = ctx();
        let data: Vec<u32> = (0..100).collect();
        let rdd = c.parallelize(data.clone(), 7);
        assert_eq!(rdd.num_partitions(), 7);
        assert_eq!(rdd.collect().unwrap(), data);
    }

    #[test]
    fn map_filter_flatmap_pipeline() {
        let c = ctx();
        let out = c
            .range(20, 4)
            .map(|x| x * 2)
            .filter(|x| x % 3 == 0)
            .flat_map(|x| vec![x, x + 1])
            .collect()
            .unwrap();
        let expect: Vec<u64> = (0..20)
            .map(|x| x * 2)
            .filter(|x| x % 3 == 0)
            .flat_map(|x| vec![x, x + 1])
            .collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn count_and_reduce() {
        let c = ctx();
        let rdd = c.range(1000, 8);
        assert_eq!(rdd.count().unwrap(), 1000);
        assert_eq!(rdd.reduce(|a, b| a + b).unwrap(), Some(499_500));
        let empty = c.parallelize(Vec::<u64>::new(), 3);
        assert_eq!(empty.reduce(|a, b| a + b).unwrap(), None);
    }

    #[test]
    fn union_concatenates() {
        let c = ctx();
        let a = c.parallelize(vec![1u8, 2], 2);
        let b = c.parallelize(vec![3u8, 4], 2);
        let mut got = a.union(&b).collect().unwrap();
        got.sort();
        assert_eq!(got, vec![1, 2, 3, 4]);
        assert_eq!(a.union(&b).num_partitions(), 4);
    }

    #[test]
    fn coalesce_reduces_partitions_keeps_data() {
        let c = ctx();
        let rdd = c.range(50, 10).coalesce(3);
        assert_eq!(rdd.num_partitions(), 3);
        let mut got = rdd.collect().unwrap();
        got.sort();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_is_deterministic_and_plausible() {
        let c = ctx();
        let rdd = c.range(10_000, 4);
        let s1 = rdd.sample(0.1, 7).count().unwrap();
        let s2 = rdd.sample(0.1, 7).count().unwrap();
        assert_eq!(s1, s2);
        assert!(s1 > 700 && s1 < 1300, "sampled {s1}");
    }

    #[test]
    fn cache_avoids_recomputation() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let c = ctx();
        let computed = Arc::new(AtomicU32::new(0));
        let c2 = computed.clone();
        let rdd = c
            .range(10, 2)
            .map(move |x| {
                c2.fetch_add(1, Ordering::SeqCst);
                x
            })
            .cache();
        rdd.collect().unwrap();
        let after_first = computed.load(Ordering::SeqCst);
        rdd.collect().unwrap();
        assert_eq!(computed.load(Ordering::SeqCst), after_first, "second pass must hit cache");
        rdd.uncache();
        rdd.collect().unwrap();
        assert!(computed.load(Ordering::SeqCst) > after_first);
    }

    #[test]
    fn take_and_first() {
        let c = ctx();
        let rdd = c.range(100, 5);
        assert_eq!(rdd.take(3).unwrap(), vec![0, 1, 2]);
        assert_eq!(rdd.first().unwrap(), Some(0));
        assert_eq!(c.parallelize(Vec::<u64>::new(), 1).first().unwrap(), None);
    }

    #[test]
    fn foreach_partition_side_effects() {
        let c = ctx();
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let s2 = seen.clone();
        c.range(10, 3)
            .foreach_partition(move |p, items| {
                s2.lock().unwrap().push((p, items.len()));
                Ok(())
            })
            .unwrap();
        let mut v = seen.lock().unwrap().clone();
        v.sort();
        let total: usize = v.iter().map(|(_, n)| n).sum();
        assert_eq!(total, 10);
        assert_eq!(v.len(), 3);
    }

    #[test]
    fn fault_injection_retries_transparently() {
        let c = ctx();
        use std::sync::atomic::{AtomicU32, Ordering};
        let failures = Arc::new(AtomicU32::new(0));
        let f2 = failures.clone();
        c.set_fail_injector(Some(Arc::new(move |tc| {
            // Fail the first attempt of partition 1 in the result stage.
            if tc.partition == 1 && tc.attempt == 0 && tc.stage == "result" {
                f2.fetch_add(1, Ordering::SeqCst);
                anyhow::bail!("injected executor crash");
            }
            Ok(())
        })));
        let out = c.range(30, 3).map(|x| x + 1).collect().unwrap();
        assert_eq!(out.len(), 30);
        assert_eq!(failures.load(Ordering::SeqCst), 1);
        c.set_fail_injector(None);
    }

    #[test]
    fn permanent_failure_fails_job() {
        let c = ctx();
        let rdd = c.range(10, 2).map_partitions(|p, items: Vec<u64>| {
            if p == 1 {
                anyhow::bail!("partition 1 is cursed")
            }
            Ok(items)
        });
        assert!(rdd.collect().is_err());
    }
}
