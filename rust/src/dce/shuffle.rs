//! Shuffle manager: the wide-dependency data plane.
//!
//! Map tasks hash-partition their output into per-reducer buckets here;
//! reduce tasks pull every map's bucket for their partition. Buckets are
//! held as type-erased in-memory objects (the engine is generic over
//! record types), while byte-volume accounting is charged to the
//! configured transport device — the tiered store's MEM device on the
//! unified infrastructure, or the DFS device for the MapReduce-baseline
//! configuration. That accounting difference *is* the paper's unified-vs-
//! staged comparison (sections 2.1, 4.1, 5.2).

use anyhow::{anyhow, Result};
use std::any::Any;
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use crate::metrics::MetricsRegistry;
use crate::storage::DeviceModel;

type Bucket = (Box<dyn Any + Send + Sync>, u64);

/// Central shuffle state for one context.
pub struct ShuffleManager {
    buckets: Mutex<HashMap<(usize, usize, usize), Bucket>>,
    complete: Mutex<HashSet<usize>>,
    /// Device charged for shuffle traffic (None = free/unmodelled).
    transport: Mutex<Option<Arc<DeviceModel>>>,
    metrics: MetricsRegistry,
}

impl ShuffleManager {
    pub fn new(metrics: MetricsRegistry) -> Arc<Self> {
        Arc::new(Self {
            buckets: Mutex::new(HashMap::new()),
            complete: Mutex::new(HashSet::new()),
            transport: Mutex::new(None),
            metrics,
        })
    }

    /// Route shuffle byte-accounting through a device model.
    pub fn set_transport(&self, device: Option<Arc<DeviceModel>>) {
        *self.transport.lock().unwrap() = device;
    }

    fn charge(&self, bytes: u64) {
        let t = self.transport.lock().unwrap().clone();
        if let Some(d) = t {
            d.charge(bytes);
        }
    }

    /// Write one map task's bucket for one reducer.
    pub fn put_bucket<T: Send + Sync + 'static>(
        &self,
        shuffle: usize,
        map_part: usize,
        reduce_part: usize,
        data: Vec<T>,
        bytes_est: u64,
    ) {
        self.charge(bytes_est);
        self.metrics.counter("dce.shuffle.bytes_written").add(bytes_est);
        self.metrics.counter("dce.shuffle.buckets_written").inc();
        self.buckets
            .lock()
            .unwrap()
            .insert((shuffle, map_part, reduce_part), (Box::new(data), bytes_est));
    }

    /// Read (and consume) all map buckets for a reduce partition.
    pub fn take_buckets<T: Send + Sync + 'static>(
        &self,
        shuffle: usize,
        num_maps: usize,
        reduce_part: usize,
    ) -> Result<Vec<Vec<T>>> {
        let mut out = Vec::with_capacity(num_maps);
        let mut guard = self.buckets.lock().unwrap();
        for m in 0..num_maps {
            match guard.remove(&(shuffle, m, reduce_part)) {
                Some((boxed, bytes)) => {
                    drop(guard); // charge outside the map lock
                    self.charge(bytes);
                    self.metrics.counter("dce.shuffle.bytes_read").add(bytes);
                    let data = boxed
                        .downcast::<Vec<T>>()
                        .map_err(|_| anyhow!("shuffle {shuffle} bucket type mismatch"))?;
                    out.push(*data);
                    guard = self.buckets.lock().unwrap();
                }
                None => {
                    // A missing bucket means the map side was lost (or never
                    // ran) — the scheduler treats this as a fetch failure.
                    return Err(anyhow!(
                        "shuffle {shuffle}: missing bucket map={m} reduce={reduce_part}"
                    ));
                }
            }
        }
        Ok(out)
    }

    /// Peek (clone-free check) whether a bucket exists.
    pub fn has_bucket(&self, shuffle: usize, map_part: usize, reduce_part: usize) -> bool {
        self.buckets
            .lock()
            .unwrap()
            .contains_key(&(shuffle, map_part, reduce_part))
    }

    pub fn mark_complete(&self, shuffle: usize) {
        self.complete.lock().unwrap().insert(shuffle);
    }

    pub fn is_complete(&self, shuffle: usize) -> bool {
        self.complete.lock().unwrap().contains(&shuffle)
    }

    /// Drop all buckets of a shuffle (post-job GC).
    pub fn clear_shuffle(&self, shuffle: usize) {
        self.buckets
            .lock()
            .unwrap()
            .retain(|(s, _, _), _| *s != shuffle);
        self.complete.lock().unwrap().remove(&shuffle);
    }

    pub fn resident_buckets(&self) -> usize {
        self.buckets.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TierConfig;

    #[test]
    fn put_take_roundtrip() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_bucket(0, 0, 0, vec![1u32, 2], 8);
        m.put_bucket(0, 1, 0, vec![3u32], 4);
        let got: Vec<Vec<u32>> = m.take_buckets(0, 2, 0).unwrap();
        assert_eq!(got, vec![vec![1, 2], vec![3]]);
        assert_eq!(m.resident_buckets(), 0);
    }

    #[test]
    fn missing_bucket_is_fetch_failure() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_bucket(0, 0, 0, vec![1u32], 4);
        let r: Result<Vec<Vec<u32>>> = m.take_buckets(0, 2, 0);
        assert!(r.is_err());
    }

    #[test]
    fn type_mismatch_detected() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_bucket(0, 0, 0, vec![1u32], 4);
        let r: Result<Vec<Vec<String>>> = m.take_buckets(0, 1, 0);
        assert!(r.is_err());
    }

    #[test]
    fn transport_device_charged_both_ways() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        let dev = Arc::new(DeviceModel::new(
            TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e9, latency_us: 0 },
            false,
        ));
        m.set_transport(Some(dev.clone()));
        m.put_bucket(1, 0, 0, vec![0u64; 100], 800);
        let _: Vec<Vec<u64>> = m.take_buckets(1, 1, 0).unwrap();
        assert_eq!(dev.bytes_total(), 1600);
    }

    #[test]
    fn completion_tracking_and_gc() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_bucket(5, 0, 0, vec![1u8], 1);
        m.mark_complete(5);
        assert!(m.is_complete(5));
        m.clear_shuffle(5);
        assert!(!m.is_complete(5));
        assert_eq!(m.resident_buckets(), 0);
    }
}
