//! Shuffle manager: the wide-dependency data plane.
//!
//! Map tasks hash-partition their output into per-reducer buckets here;
//! reduce tasks pull every map's bucket for their partition. Buckets are
//! held as type-erased in-memory objects (the engine is generic over
//! record types), while byte-volume accounting is charged to the
//! configured transport device — the tiered store's MEM device on the
//! unified infrastructure, or the DFS device for the MapReduce-baseline
//! configuration. That accounting difference *is* the paper's unified-vs-
//! staged comparison (sections 2.1, 4.1, 5.2).
//!
//! **Concurrency (PR 10).** The bucket map is lock-striped into
//! [`crate::config::DEFAULT_SHUFFLE_SHARDS`] shards keyed by
//! `(shuffle, reduce partition)`: concurrent map writers targeting
//! different reducers never contend, and because one reduce partition's
//! entire bucket row lives in a single shard, [`ShuffleManager::take_buckets`]
//! removes all of its map buckets under ONE lock acquisition and pays
//! transport outside it. The transport handle is pre-resolved at
//! [`ShuffleManager::set_transport`] time (lock-free reads) instead of
//! cloned out of a `Mutex` on every charge. The pre-PR-10 path — one
//! global lock, per-bucket lock reacquisition, per-op registry lookups,
//! per-charge transport locking — is kept verbatim behind the
//! `--baseline` knob ([`crate::config::EngineConfig::shuffle_single_lock`])
//! for the E22 A/B.
//!
//! Three more mechanisms ride the sharded plane (all off on baseline):
//! map-side **combine** ([`ShuffleManager::put_bucket_combined`] merges
//! a bucket's records with the job's associative combiner before
//! insertion, tracked by `dce.shuffle.combine_ratio`), executor
//! **affinity** (each bucket records the worker that wrote it;
//! [`ShuffleManager::preferred_worker`] answers with the worker holding
//! the plurality of a reduce partition's input bytes, used as a
//! placement hint by the DAG scheduler), and **spill-to-store** (buckets
//! past the configured resident budget stage their bytes in the
//! [`TieredStore`] under `shuf/<shuffle>/<map>/<reduce>`, lineage-free
//! and persist-free, so a lost blob surfaces as a fetch failure the
//! scheduler answers with lineage regeneration).

use anyhow::{anyhow, Result};
use std::any::Any;
use std::collections::{HashMap, HashSet};
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::DEFAULT_SHUFFLE_SHARDS;
use crate::metrics::{MetricsRegistry, ShuffleMetrics};
use crate::storage::{DeviceModel, TieredStore};
use crate::trace;

struct Bucket {
    payload: Box<dyn Any + Send + Sync>,
    bytes: u64,
    /// Executor-pool worker index that produced this bucket (None when
    /// written from a non-worker thread) — the affinity signal.
    owner: Option<usize>,
    /// The bucket's bytes are staged in the spill store rather than
    /// counted against the resident budget; taking it must first read
    /// (and pay for) the staged blob, which can have been lost.
    spilled: bool,
}

type BucketMap = HashMap<(usize, usize, usize), Bucket>;

fn spill_key(shuffle: usize, map_part: usize, reduce_part: usize) -> String {
    format!("shuf/{shuffle}/{map_part}/{reduce_part}")
}

/// Central shuffle state for one context.
pub struct ShuffleManager {
    /// Lock stripes over `(shuffle, map, reduce) -> Bucket`, routed by
    /// `(shuffle, reduce)` so a reduce partition's whole row shares one
    /// shard (single-acquisition batched take).
    shards: Vec<Mutex<BucketMap>>,
    complete: Mutex<HashSet<usize>>,
    /// Pre-resolved transport handle: set once, read lock-free on the
    /// hot paths (None = free/unmodelled).
    transport: OnceLock<Arc<DeviceModel>>,
    /// The pre-PR-10 per-call locker, kept op-for-op for `--baseline`.
    transport_legacy: Mutex<Option<Arc<DeviceModel>>>,
    /// Spill target for buckets past the resident budget.
    spill_store: OnceLock<Arc<TieredStore>>,
    /// Resident-byte budget; 0 = unbounded (never spill).
    spill_budget: u64,
    /// Bytes held in memory (spilled buckets excluded). The bound is
    /// enforced per-put without a lock, so concurrent writers can
    /// overshoot by at most one in-flight bucket each.
    resident_bytes: AtomicU64,
    /// `--baseline`: one shard, one global lock, per-bucket lock
    /// reacquisition in take, per-op registry lookups, per-charge
    /// transport locking; combine/affinity/spill disabled.
    single_lock: bool,
    m: ShuffleMetrics,
    metrics: MetricsRegistry,
}

impl ShuffleManager {
    pub fn new(metrics: MetricsRegistry) -> Arc<Self> {
        Self::with_config(metrics, DEFAULT_SHUFFLE_SHARDS, false, 0)
    }

    /// Build with explicit sharding / baseline / spill knobs (the
    /// context wires these from [`crate::config::EngineConfig`]).
    pub fn with_config(
        metrics: MetricsRegistry,
        shards: usize,
        single_lock: bool,
        spill_budget: u64,
    ) -> Arc<Self> {
        let n = if single_lock { 1 } else { shards.max(1) };
        Arc::new(Self {
            shards: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            complete: Mutex::new(HashSet::new()),
            transport: OnceLock::new(),
            transport_legacy: Mutex::new(None),
            spill_store: OnceLock::new(),
            spill_budget,
            resident_bytes: AtomicU64::new(0),
            single_lock,
            m: ShuffleMetrics::new(&metrics),
            metrics,
        })
    }

    /// Route shuffle byte-accounting through a device model. The fast
    /// path resolves the handle once, here — a manager's transport is
    /// fixed for its lifetime (contexts set it right after
    /// construction); only the baseline arm honours later re-sets.
    pub fn set_transport(&self, device: Option<Arc<DeviceModel>>) {
        *self.transport_legacy.lock().unwrap() = device.clone();
        if let Some(d) = device {
            let _ = self.transport.set(d);
        }
    }

    /// Hand the manager its spill target (set once, at context build).
    pub fn set_spill_store(&self, store: Arc<TieredStore>) {
        let _ = self.spill_store.set(store);
    }

    /// Whether map tasks should ship raw records and let the manager
    /// combine per bucket (everything but the baseline arm).
    pub fn combine_in_manager(&self) -> bool {
        !self.single_lock
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// FNV-1a over `(shuffle, reduce)`: every map bucket of one reduce
    /// partition lands in the same shard.
    fn shard_of(&self, shuffle: usize, reduce_part: usize) -> usize {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in (shuffle as u64)
            .to_le_bytes()
            .into_iter()
            .chain((reduce_part as u64).to_le_bytes())
        {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        (h % self.shards.len() as u64) as usize
    }

    fn charge(&self, bytes: u64) {
        if let Some(d) = self.transport.get() {
            d.charge(bytes);
        }
    }

    fn charge_legacy(&self, bytes: u64) {
        let t = self.transport_legacy.lock().unwrap().clone();
        if let Some(d) = t {
            d.charge(bytes);
        }
    }

    fn publish_resident(&self) {
        self.m.resident_bytes.set(self.resident_bytes.load(Ordering::Relaxed));
    }

    /// Write one map task's bucket for one reducer.
    pub fn put_bucket<T: Send + Sync + 'static>(
        &self,
        shuffle: usize,
        map_part: usize,
        reduce_part: usize,
        data: Vec<T>,
        bytes_est: u64,
    ) {
        self.put_erased(
            shuffle,
            map_part,
            reduce_part,
            Box::new(data),
            bytes_est,
            super::executor::current_worker_tag().map(|(_, w)| w),
        );
    }

    /// Map-side combine: merge a bucket's raw records with the job's
    /// associative combiner before insertion, so reduce_by_key-shaped
    /// stages ship one record per key instead of one per input.
    /// `est` converts the post-merge record count into a byte estimate.
    pub fn put_bucket_combined<K, C>(
        &self,
        shuffle: usize,
        map_part: usize,
        reduce_part: usize,
        raw: Vec<(K, C)>,
        merge: &dyn Fn(C, C) -> C,
        est: impl Fn(usize) -> u64,
    ) where
        K: Hash + Eq + Send + Sync + 'static,
        C: Send + Sync + 'static,
    {
        let in_len = raw.len() as u64;
        let mut merged: HashMap<K, C> = HashMap::with_capacity(raw.len());
        for (k, c) in raw {
            match merged.remove(&k) {
                Some(prev) => {
                    merged.insert(k, merge(prev, c));
                }
                None => {
                    merged.insert(k, c);
                }
            }
        }
        let data: Vec<(K, C)> = merged.into_iter().collect();
        let out_len = data.len();
        self.m.combine_in.add(in_len);
        self.m.combine_out.add(out_len as u64);
        // Cumulative input-records-per-100-shipped (100 = no combining).
        self.m
            .combine_ratio
            .set(self.m.combine_in.get() * 100 / self.m.combine_out.get().max(1));
        self.put_erased(
            shuffle,
            map_part,
            reduce_part,
            Box::new(data),
            est(out_len),
            super::executor::current_worker_tag().map(|(_, w)| w),
        );
    }

    fn put_erased(
        &self,
        shuffle: usize,
        map_part: usize,
        reduce_part: usize,
        payload: Box<dyn Any + Send + Sync>,
        bytes_est: u64,
        owner: Option<usize>,
    ) {
        if self.single_lock {
            // The pre-PR-10 path, op for op: per-charge transport lock,
            // per-op registry lookups, one global bucket lock.
            self.charge_legacy(bytes_est);
            self.metrics.counter("dce.shuffle.bytes_written").add(bytes_est);
            self.metrics.counter("dce.shuffle.buckets_written").inc();
            self.shards[0].lock().unwrap().insert(
                (shuffle, map_part, reduce_part),
                Bucket { payload, bytes: bytes_est, owner: None, spilled: false },
            );
            return;
        }
        // Spill decision before insertion: a bucket that would push the
        // resident set past the budget stages its bytes in the store
        // instead (newest-spills — buckets already resident stay hot).
        let mut spilled = false;
        if self.spill_budget > 0 {
            if let Some(store) = self.spill_store.get() {
                if self.resident_bytes.load(Ordering::Relaxed) + bytes_est > self.spill_budget {
                    let mut sp = trace::span("dce.shuffle.spill", trace::Category::Shuffle);
                    sp.arg("bytes", bytes_est);
                    // The blob is the typed payload's byte-accounting
                    // twin (same convention as the staged mapgen
                    // pipeline): lineage-free and persist-free, so
                    // losing it loses the bucket — exactly the fetch-
                    // failure contract `take_buckets` enforces.
                    let key = spill_key(shuffle, map_part, reduce_part);
                    if store.put_opts(&key, vec![0u8; bytes_est as usize], false, false).is_ok() {
                        spilled = true;
                        self.m.spilled_buckets.inc();
                        self.m.spilled_bytes.add(bytes_est);
                    }
                }
            }
        }
        self.charge(bytes_est);
        self.m.bytes_written.add(bytes_est);
        self.m.buckets_written.inc();
        let prev = self.shards[self.shard_of(shuffle, reduce_part)].lock().unwrap().insert(
            (shuffle, map_part, reduce_part),
            Bucket { payload, bytes: bytes_est, owner, spilled },
        );
        if !spilled {
            self.resident_bytes.fetch_add(bytes_est, Ordering::Relaxed);
        }
        if let Some(p) = prev {
            if !p.spilled {
                self.resident_bytes.fetch_sub(p.bytes, Ordering::Relaxed);
            }
        }
        self.publish_resident();
    }

    /// Read (and consume) all map buckets for a reduce partition: the
    /// whole row comes out under one shard-lock acquisition; transport
    /// and spill-restore costs are paid outside it.
    pub fn take_buckets<T: Send + Sync + 'static>(
        &self,
        shuffle: usize,
        num_maps: usize,
        reduce_part: usize,
    ) -> Result<Vec<Vec<T>>> {
        if self.single_lock {
            return self.take_buckets_baseline(shuffle, num_maps, reduce_part);
        }
        let mut taken: Vec<Bucket> = Vec::with_capacity(num_maps);
        {
            let mut sh = self.shards[self.shard_of(shuffle, reduce_part)].lock().unwrap();
            for m in 0..num_maps {
                match sh.remove(&(shuffle, m, reduce_part)) {
                    Some(b) => {
                        if !b.spilled {
                            self.resident_bytes.fetch_sub(b.bytes, Ordering::Relaxed);
                        }
                        taken.push(b);
                    }
                    None => {
                        // A missing bucket means the map side was lost
                        // (or never ran) — the scheduler treats this as
                        // a fetch failure. Buckets already removed stay
                        // consumed; lineage regenerates them on retry.
                        return Err(anyhow!(
                            "shuffle {shuffle}: missing bucket map={m} reduce={reduce_part}"
                        ));
                    }
                }
            }
        }
        self.publish_resident();
        let mut total = 0u64;
        let mut out: Vec<Vec<T>> = Vec::with_capacity(num_maps);
        for (m, b) in taken.into_iter().enumerate() {
            if b.spilled {
                let store =
                    self.spill_store.get().expect("spilled bucket without a spill store");
                let key = spill_key(shuffle, m, reduce_part);
                match store.get(&key) {
                    Ok(_) => {
                        let _ = store.delete(&key);
                        self.m.spill_restored.inc();
                    }
                    Err(_) => {
                        // Written persist-free and lineage-free: once
                        // evicted out of every tier the blob is gone,
                        // and so is the bucket.
                        self.m.spill_lost.inc();
                        return Err(anyhow!(
                            "shuffle {shuffle}: missing bucket map={m} reduce={reduce_part} \
                             (spilled block lost)"
                        ));
                    }
                }
            }
            total += b.bytes;
            let data = b
                .payload
                .downcast::<Vec<T>>()
                .map_err(|_| anyhow!("shuffle {shuffle} bucket type mismatch"))?;
            out.push(*data);
        }
        self.charge(total);
        self.m.bytes_read.add(total);
        Ok(out)
    }

    /// The pre-PR-10 take, kept verbatim for the E22 A/B: the global
    /// lock is dropped and reacquired once per map bucket, and every
    /// bucket pays a registry lookup plus a transport-mutex clone.
    fn take_buckets_baseline<T: Send + Sync + 'static>(
        &self,
        shuffle: usize,
        num_maps: usize,
        reduce_part: usize,
    ) -> Result<Vec<Vec<T>>> {
        let mut out = Vec::with_capacity(num_maps);
        let mut guard = self.shards[0].lock().unwrap();
        for m in 0..num_maps {
            match guard.remove(&(shuffle, m, reduce_part)) {
                Some(b) => {
                    drop(guard); // charge outside the map lock
                    self.charge_legacy(b.bytes);
                    self.metrics.counter("dce.shuffle.bytes_read").add(b.bytes);
                    let data = b
                        .payload
                        .downcast::<Vec<T>>()
                        .map_err(|_| anyhow!("shuffle {shuffle} bucket type mismatch"))?;
                    out.push(*data);
                    guard = self.shards[0].lock().unwrap();
                }
                None => {
                    return Err(anyhow!(
                        "shuffle {shuffle}: missing bucket map={m} reduce={reduce_part}"
                    ));
                }
            }
        }
        Ok(out)
    }

    /// The worker holding the plurality of a reduce partition's input
    /// bytes — the DAG scheduler's placement hint for the reduce task.
    /// One shard lock covers the whole row. Ties break to the smaller
    /// worker index; baseline and ownerless rows answer None.
    pub fn preferred_worker(
        &self,
        shuffle: usize,
        num_maps: usize,
        reduce_part: usize,
    ) -> Option<usize> {
        if self.single_lock {
            return None;
        }
        let sh = self.shards[self.shard_of(shuffle, reduce_part)].lock().unwrap();
        let mut by_worker: HashMap<usize, u64> = HashMap::new();
        for m in 0..num_maps {
            if let Some(b) = sh.get(&(shuffle, m, reduce_part)) {
                if let Some(w) = b.owner {
                    *by_worker.entry(w).or_default() += b.bytes.max(1);
                }
            }
        }
        by_worker
            .into_iter()
            .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
            .map(|(w, _)| w)
    }

    /// Count whether a hinted task actually ran on its preferred worker
    /// (`dce.shuffle.affinity_hits` / `affinity_misses`).
    pub fn record_affinity(&self, hit: bool) {
        if hit {
            self.m.affinity_hits.inc();
        } else {
            self.m.affinity_misses.inc();
        }
    }

    /// Peek (clone-free check) whether a bucket exists.
    pub fn has_bucket(&self, shuffle: usize, map_part: usize, reduce_part: usize) -> bool {
        self.shards[self.shard_of(shuffle, reduce_part)]
            .lock()
            .unwrap()
            .contains_key(&(shuffle, map_part, reduce_part))
    }

    pub fn mark_complete(&self, shuffle: usize) {
        self.complete.lock().unwrap().insert(shuffle);
    }

    pub fn is_complete(&self, shuffle: usize) -> bool {
        self.complete.lock().unwrap().contains(&shuffle)
    }

    /// Drop all buckets of a shuffle (post-job GC), including blobs it
    /// spilled to the store — plus any orphaned by a failed take.
    pub fn clear_shuffle(&self, shuffle: usize) {
        let mut freed = 0u64;
        let mut had_spilled = false;
        for sh in &self.shards {
            sh.lock().unwrap().retain(|(s, _, _), b| {
                if *s != shuffle {
                    return true;
                }
                if b.spilled {
                    had_spilled = true;
                } else {
                    freed += b.bytes;
                }
                false
            });
        }
        if freed > 0 {
            self.resident_bytes.fetch_sub(freed, Ordering::Relaxed);
            self.publish_resident();
        }
        if had_spilled || self.spill_budget > 0 {
            if let Some(store) = self.spill_store.get() {
                for key in store.keys_with_prefix(&format!("shuf/{shuffle}/")) {
                    let _ = store.delete(&key);
                }
            }
        }
        self.complete.lock().unwrap().remove(&shuffle);
    }

    /// Drop every bucket and all completion state (context-level GC).
    pub fn clear_all(&self) {
        let mut ids: HashSet<usize> = self.complete.lock().unwrap().iter().copied().collect();
        for sh in &self.shards {
            ids.extend(sh.lock().unwrap().keys().map(|(s, _, _)| *s));
        }
        for id in ids {
            self.clear_shuffle(id);
        }
    }

    pub fn resident_buckets(&self) -> usize {
        self.shards.iter().map(|sh| sh.lock().unwrap().len()).sum()
    }

    /// Bytes currently held in memory (spilled buckets excluded).
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, TierConfig};

    #[test]
    fn put_take_roundtrip() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_bucket(0, 0, 0, vec![1u32, 2], 8);
        m.put_bucket(0, 1, 0, vec![3u32], 4);
        let got: Vec<Vec<u32>> = m.take_buckets(0, 2, 0).unwrap();
        assert_eq!(got, vec![vec![1, 2], vec![3]]);
        assert_eq!(m.resident_buckets(), 0);
        assert_eq!(m.resident_bytes(), 0);
    }

    #[test]
    fn missing_bucket_is_fetch_failure() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_bucket(0, 0, 0, vec![1u32], 4);
        let r: Result<Vec<Vec<u32>>> = m.take_buckets(0, 2, 0);
        assert!(r.is_err());
    }

    #[test]
    fn type_mismatch_detected() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_bucket(0, 0, 0, vec![1u32], 4);
        let r: Result<Vec<Vec<String>>> = m.take_buckets(0, 1, 0);
        assert!(r.is_err());
    }

    #[test]
    fn transport_device_charged_both_ways() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        let dev = Arc::new(DeviceModel::new(
            TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e9, latency_us: 0 },
            false,
        ));
        m.set_transport(Some(dev.clone()));
        m.put_bucket(1, 0, 0, vec![0u64; 100], 800);
        let _: Vec<Vec<u64>> = m.take_buckets(1, 1, 0).unwrap();
        assert_eq!(dev.bytes_total(), 1600);
    }

    #[test]
    fn completion_tracking_and_gc() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_bucket(5, 0, 0, vec![1u8], 1);
        m.mark_complete(5);
        assert!(m.is_complete(5));
        m.clear_shuffle(5);
        assert!(!m.is_complete(5));
        assert_eq!(m.resident_buckets(), 0);
    }

    #[test]
    fn baseline_single_lock_matches_sharded_outputs() {
        // The op-for-op A/B contract: identical put/take sequences
        // yield identical buckets, byte totals, and device charges on
        // both arms.
        let fast = ShuffleManager::new(MetricsRegistry::new());
        let slow = ShuffleManager::with_config(MetricsRegistry::new(), 16, true, 0);
        assert_eq!(slow.shard_count(), 1);
        let mk_dev = || {
            Arc::new(DeviceModel::new(
                TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e9, latency_us: 0 },
                false,
            ))
        };
        let (df, ds) = (mk_dev(), mk_dev());
        fast.set_transport(Some(df.clone()));
        slow.set_transport(Some(ds.clone()));
        for shuffle in 0..3usize {
            for m in 0..4usize {
                for r in 0..3usize {
                    let data: Vec<u64> = (0..(m + r) as u64).collect();
                    let bytes = 16 + 8 * data.len() as u64;
                    fast.put_bucket(shuffle, m, r, data.clone(), bytes);
                    slow.put_bucket(shuffle, m, r, data, bytes);
                }
            }
        }
        assert_eq!(fast.resident_buckets(), slow.resident_buckets());
        for shuffle in 0..3usize {
            for r in 0..3usize {
                let a: Vec<Vec<u64>> = fast.take_buckets(shuffle, 4, r).unwrap();
                let b: Vec<Vec<u64>> = slow.take_buckets(shuffle, 4, r).unwrap();
                assert_eq!(a, b, "shuffle {shuffle} reduce {r} diverged");
            }
        }
        assert_eq!(df.bytes_total(), ds.bytes_total(), "device byte accounting diverged");
        assert_eq!(fast.resident_buckets(), 0);
        assert_eq!(slow.resident_buckets(), 0);
    }

    #[test]
    fn concurrent_writers_and_readers_across_shards() {
        // 8 threads, each its own shuffle id: puts and batched takes
        // must never lose or cross-contaminate buckets.
        let m = ShuffleManager::new(MetricsRegistry::new());
        std::thread::scope(|scope| {
            for t in 0..8usize {
                let m = &m;
                scope.spawn(move || {
                    for round in 0..50usize {
                        for map in 0..4usize {
                            let v = vec![(t * 1000 + round) as u64; 8];
                            m.put_bucket(t, map, round % 3, v, 64 + 16);
                        }
                        let got: Vec<Vec<u64>> = m.take_buckets(t, 4, round % 3).unwrap();
                        assert_eq!(got.len(), 4);
                        for b in got {
                            assert_eq!(b, vec![(t * 1000 + round) as u64; 8]);
                        }
                    }
                });
            }
        });
        assert_eq!(m.resident_buckets(), 0);
        assert_eq!(m.resident_bytes(), 0);
    }

    #[test]
    fn manager_combine_merges_and_tracks_ratio() {
        let reg = MetricsRegistry::new();
        let m = ShuffleManager::new(reg.clone());
        let raw = vec![(1u32, 1u64), (1, 2), (2, 5), (1, 4)];
        m.put_bucket_combined(0, 0, 0, raw, &|a, b| a + b, |n| (n * 16) as u64 + 16);
        let mut got: Vec<(u32, u64)> =
            m.take_buckets::<(u32, u64)>(0, 1, 0).unwrap().pop().unwrap();
        got.sort();
        assert_eq!(got, vec![(1, 7), (2, 5)]);
        assert_eq!(reg.counter("dce.shuffle.combine_in").get(), 4);
        assert_eq!(reg.counter("dce.shuffle.combine_out").get(), 2);
        // 4 input records per 2 shipped = 200 per 100.
        assert_eq!(reg.gauge("dce.shuffle.combine_ratio").get(), 200);
    }

    #[test]
    fn over_budget_buckets_spill_to_store_and_restore() {
        let reg = MetricsRegistry::new();
        let m = ShuffleManager::with_config(reg.clone(), 16, false, 100);
        let store = TieredStore::test_store(&PlatformConfig::test().storage);
        m.set_spill_store(store.clone());
        m.put_bucket(7, 0, 0, vec![0u8; 32], 60); // resident: 60
        m.put_bucket(7, 1, 0, vec![0u8; 32], 60); // 120 > 100 -> spills
        assert_eq!(m.resident_bytes(), 60, "second bucket must not count resident");
        assert!(m.resident_bytes() <= 100);
        assert_eq!(reg.counter("dce.shuffle.spilled_buckets").get(), 1);
        assert!(store.contains("shuf/7/1/0"), "spilled blob missing from store");
        let got: Vec<Vec<u8>> = m.take_buckets(7, 2, 0).unwrap();
        assert_eq!(got, vec![vec![0u8; 32], vec![0u8; 32]]);
        assert_eq!(reg.counter("dce.shuffle.spill_restored").get(), 1);
        assert!(!store.contains("shuf/7/1/0"), "restored blob must be deleted");
        assert_eq!(m.resident_bytes(), 0);
    }

    #[test]
    fn lost_spill_blob_is_a_fetch_failure() {
        let reg = MetricsRegistry::new();
        let m = ShuffleManager::with_config(reg.clone(), 16, false, 50);
        let store = TieredStore::test_store(&PlatformConfig::test().storage);
        m.set_spill_store(store.clone());
        m.put_bucket(3, 0, 0, vec![1u8; 16], 40);
        m.put_bucket(3, 1, 0, vec![2u8; 16], 40); // spills
        // Lose the staged blob (persist-free, so deletion is final).
        store.delete("shuf/3/1/0").unwrap();
        let r: Result<Vec<Vec<u8>>> = m.take_buckets(3, 2, 0);
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("spilled block lost"), "{msg}");
        assert_eq!(reg.counter("dce.shuffle.spill_lost").get(), 1);
        // The scheduler's answer — regenerate via lineage — works: the
        // buckets read as missing now.
        assert!(!m.has_bucket(3, 0, 0) && !m.has_bucket(3, 1, 0));
    }

    #[test]
    fn clear_shuffle_gcs_spilled_blobs() {
        let m = ShuffleManager::with_config(MetricsRegistry::new(), 16, false, 10);
        let store = TieredStore::test_store(&PlatformConfig::test().storage);
        m.set_spill_store(store.clone());
        for map in 0..3usize {
            m.put_bucket(9, map, 0, vec![0u8; 8], 32); // all spill (budget 10)
        }
        assert_eq!(store.keys_with_prefix("shuf/9/").len(), 3);
        m.clear_shuffle(9);
        assert_eq!(m.resident_buckets(), 0);
        assert!(store.keys_with_prefix("shuf/9/").is_empty(), "spilled blobs must be GC'd");
    }

    #[test]
    fn preferred_worker_is_the_bytes_plurality() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_erased(0, 0, 0, Box::new(vec![0u8; 1]), 100, Some(2));
        m.put_erased(0, 1, 0, Box::new(vec![0u8; 1]), 300, Some(1));
        m.put_erased(0, 2, 0, Box::new(vec![0u8; 1]), 250, Some(2));
        assert_eq!(m.preferred_worker(0, 3, 0), Some(2), "350B on w2 beats 300B on w1");
        // Ties break to the smaller worker index.
        m.put_erased(1, 0, 0, Box::new(vec![0u8; 1]), 100, Some(4));
        m.put_erased(1, 1, 0, Box::new(vec![0u8; 1]), 100, Some(3));
        assert_eq!(m.preferred_worker(1, 2, 0), Some(3));
        // Ownerless rows (driver-thread puts) and baseline: no hint.
        m.put_bucket(2, 0, 0, vec![0u8; 1], 10);
        assert_eq!(m.preferred_worker(2, 1, 0), None);
        let base = ShuffleManager::with_config(MetricsRegistry::new(), 16, true, 0);
        base.put_erased(0, 0, 0, Box::new(vec![0u8; 1]), 10, Some(1));
        assert_eq!(base.preferred_worker(0, 1, 0), None);
    }

    #[test]
    fn affinity_counters_accumulate() {
        let reg = MetricsRegistry::new();
        let m = ShuffleManager::new(reg.clone());
        m.record_affinity(true);
        m.record_affinity(true);
        m.record_affinity(false);
        assert_eq!(reg.counter("dce.shuffle.affinity_hits").get(), 2);
        assert_eq!(reg.counter("dce.shuffle.affinity_misses").get(), 1);
    }

    #[test]
    fn clear_all_drops_every_shuffle() {
        let m = ShuffleManager::new(MetricsRegistry::new());
        m.put_bucket(0, 0, 0, vec![1u8], 4);
        m.put_bucket(4, 1, 2, vec![2u8], 4);
        m.mark_complete(4);
        m.clear_all();
        assert_eq!(m.resident_buckets(), 0);
        assert_eq!(m.resident_bytes(), 0);
        assert!(!m.is_complete(4));
    }
}
