//! Executor pool: the worker side of the compute engine.
//!
//! Real-execution mode runs tasks on a fixed thread pool sized
//! `nodes x cores_per_node` (each thread is one executor slot of the
//! simulated cluster). Tasks are retryable closures; failures are
//! retried up to the configured limit, which is what the fault-injection
//! soak (experiment E12) exercises.
//!
//! **Dispatch is work-stealing.** The old pool handed every job through
//! one `Mutex<mpsc::Receiver>`, so an 8-worker pool serialized all
//! dispatch on a single lock. Now each worker owns a deque: external
//! submitters round-robin across the worker deques (contending on one
//! worker's lock, not the pool's), a worker spawning from inside a task
//! pushes to its *own* deque (no cross-thread contention at all; past a
//! small cap it overflows into the shared condvar-guarded injector so
//! siblings pick the surplus up without stealing), and an idle worker
//! pops its own deque first, then the injector, then steals from its
//! siblings. Idle workers park on the injector's condvar; every push
//! notifies it, and the final not-empty re-check runs under the
//! injector lock so a wakeup can never be lost.
//!
//! Whole-batch submission ([`ExecutorPool::spawn_batch`], which
//! [`ExecutorPool::run_tasks`] uses for its initial wave) bypasses the
//! per-job path: the batch is dealt across the worker deques with each
//! deque locked once for its entire share, then one wake pass rouses
//! the parked workers.

use anyhow::{anyhow, Result};
use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::metrics::MetricsRegistry;
use crate::trace;

/// Context visible to a running task.
#[derive(Clone)]
pub struct TaskContext {
    pub stage: String,
    pub partition: usize,
    pub attempt: usize,
    pub metrics: MetricsRegistry,
    /// Fault injection hook: return Err to simulate an executor failure.
    pub fail_injector: Option<Arc<dyn Fn(&TaskContext) -> Result<()> + Send + Sync>>,
}

impl TaskContext {
    pub fn check_failure(&self) -> Result<()> {
        match &self.fail_injector {
            Some(f) => f(self),
            None => Ok(()),
        }
    }
}

type PoolJob = Box<dyn FnOnce() + Send>;

/// A worker-local spawn keeps at most this many jobs on its own deque
/// before overflowing into the shared injector.
const LOCAL_OVERFLOW_CAP: usize = 64;

thread_local! {
    /// `(pool identity, worker index)` when the current thread is an
    /// executor worker — lets spawn-from-a-task hit the local deque.
    static WORKER: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

/// `(pool identity, worker index)` of the calling thread, if it is an
/// executor worker. The shuffle manager stamps this on every bucket a
/// map task writes — the signal behind reduce-task placement hints.
pub(crate) fn current_worker_tag() -> Option<(usize, usize)> {
    WORKER.with(|c| c.get())
}

struct PoolShared {
    /// Overflow/entry queue; its mutex doubles as the condvar's guard,
    /// so a worker's final empty re-check and a producer's notify are
    /// ordered and a wakeup can never be lost.
    injector: Mutex<VecDeque<PoolJob>>,
    available: Condvar,
    /// One deque per worker.
    locals: Vec<Mutex<VecDeque<PoolJob>>>,
    /// Workers currently inside the sleep protocol. A producer only
    /// touches the injector lock to notify when this is non-zero, so
    /// the busy-pool fast path pays one striped lock per push, total.
    parked: AtomicUsize,
    shutdown: AtomicBool,
    /// External-submission round-robin cursor.
    rr: AtomicUsize,
    /// Jobs that ran on a different worker than they were queued on
    /// (observability only).
    steals: AtomicU64,
}

impl PoolShared {
    /// Stable identity for the thread-local worker tag (the shared
    /// state's address — fixed for the pool's lifetime inside its Arc).
    fn id(&self) -> usize {
        self as *const PoolShared as usize
    }

    fn push_local(&self, w: usize, job: PoolJob) {
        self.locals[w].lock().unwrap().push_back(job);
        self.notify();
    }

    fn push_injector(&self, job: PoolJob) {
        self.injector.lock().unwrap().push_back(job);
        self.notify();
    }

    /// Wake one parked worker, if any. A parked worker increments
    /// `parked` (SeqCst) *before* its final re-scan of every queue, so
    /// if this load sees zero the worker's re-scan is guaranteed to see
    /// the job we just pushed; if it sees non-zero we take the injector
    /// lock — serializing with the sleeper — and notify.
    fn notify(&self) {
        if self.parked.load(Ordering::SeqCst) > 0 {
            drop(self.injector.lock().unwrap());
            self.available.notify_one();
        }
    }

    /// Non-blocking find: own deque, then injector, then steal.
    fn try_pop(&self, w: usize) -> Option<PoolJob> {
        if let Some(job) = self.locals[w].lock().unwrap().pop_front() {
            return Some(job);
        }
        if let Some(job) = self.injector.lock().unwrap().pop_front() {
            return Some(job);
        }
        let n = self.locals.len();
        for off in 1..n {
            let victim = (w + off) % n;
            // Steal the victim's newest job: the victim drains from the
            // front, so the two ends never contend logically.
            if let Some(job) = self.locals[victim].lock().unwrap().pop_back() {
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(job);
            }
        }
        None
    }

    /// Blocking pop; None means shutdown with nothing left to drain.
    fn pop(&self, w: usize) -> Option<PoolJob> {
        loop {
            if let Some(job) = self.try_pop(w) {
                return Some(job);
            }
            let mut inj = self.injector.lock().unwrap();
            self.parked.fetch_add(1, Ordering::SeqCst);
            // Final re-check, ordered after the parked increment (see
            // `notify`): anything pushed after our failed try_pop is
            // either visible to this scan or will wake us.
            let found = inj.pop_front().or_else(|| self.steal_scan(w));
            if let Some(job) = found {
                self.parked.fetch_sub(1, Ordering::SeqCst);
                return Some(job);
            }
            if self.shutdown.load(Ordering::SeqCst) {
                self.parked.fetch_sub(1, Ordering::SeqCst);
                return None;
            }
            inj = self.available.wait(inj).unwrap();
            drop(inj);
            self.parked.fetch_sub(1, Ordering::SeqCst);
        }
    }

    fn steal_scan(&self, w: usize) -> Option<PoolJob> {
        let n = self.locals.len();
        for off in 0..n {
            let q = (w + off) % n;
            if let Some(job) = self.locals[q].lock().unwrap().pop_front() {
                if q != w {
                    self.steals.fetch_add(1, Ordering::Relaxed);
                }
                return Some(job);
            }
        }
        None
    }
}

/// Fixed-size worker pool with per-worker deques + work stealing.
pub struct ExecutorPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    in_flight: Arc<AtomicUsize>,
}

impl ExecutorPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(PoolShared {
            injector: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            locals: (0..size).map(|_| Mutex::new(VecDeque::new())).collect(),
            parked: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            rr: AtomicUsize::new(0),
            steals: AtomicU64::new(0),
        });
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("dce-executor-{i}"))
                    .spawn(move || {
                        WORKER.with(|c| c.set(Some((shared.id(), i))));
                        while let Some(job) = shared.pop(i) {
                            job();
                        }
                    })
                    .expect("spawn executor")
            })
            .collect();
        Self { shared, workers, size, in_flight }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Jobs that ran on a different worker than they were queued on.
    pub fn steals(&self) -> u64 {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Worker index of the calling thread, if it belongs to THIS pool
    /// (the affinity-hit check: did a hinted task run where hinted?).
    pub fn current_worker(&self) -> Option<usize> {
        current_worker_tag().and_then(|(pool, w)| (pool == self.shared.id()).then_some(w))
    }

    /// Submit a fire-and-forget job.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(anyhow!("pool shut down"));
        }
        let inflight = self.in_flight.clone();
        inflight.fetch_add(1, Ordering::Relaxed);
        let job: PoolJob = Box::new(move || {
            job();
            inflight.fetch_sub(1, Ordering::Relaxed);
        });
        let own = WORKER
            .with(|c| c.get())
            .and_then(|(pool, w)| (pool == self.shared.id()).then_some(w));
        match own {
            // A task spawning subtasks: keep them on this worker's
            // deque (zero contention) unless it is already deep, in
            // which case overflow to the injector so parked siblings
            // can pick the surplus up directly.
            Some(w) => {
                let mut q = self.shared.locals[w].lock().unwrap();
                if q.len() < LOCAL_OVERFLOW_CAP {
                    q.push_back(job);
                    drop(q);
                    self.shared.notify();
                } else {
                    drop(q);
                    self.shared.push_injector(job);
                }
            }
            // External submitters spread round-robin over the deques so
            // no single lock serializes dispatch.
            None => {
                let w = self.shared.rr.fetch_add(1, Ordering::Relaxed) % self.size;
                self.shared.push_local(w, job);
            }
        }
        Ok(())
    }

    /// Submit a batch of fire-and-forget jobs in one dispatch pass.
    ///
    /// [`Self::spawn`] in a loop pays one lock acquisition and one
    /// notify per job; here the batch is dealt round-robin across the
    /// worker deques with each deque locked ONCE for its entire share,
    /// followed by a single wake pass. On an idle pool the jobs land
    /// directly where the workers look first — the shared injector is
    /// bypassed entirely — and stealing still rebalances the deques if
    /// one worker's share runs long.
    pub fn spawn_batch(&self, jobs: Vec<Box<dyn FnOnce() + Send>>) -> Result<()> {
        self.spawn_batch_hinted(jobs.into_iter().map(|j| (None, j)).collect())
    }

    /// [`Self::spawn_batch`] with optional per-job placement hints.
    ///
    /// A hinted job is dealt to the hinted worker's deque (mod pool
    /// size) instead of the round-robin cursor — the shuffle plane
    /// hints reduce tasks at the worker holding the plurality of their
    /// map output, so on an idle pool the bytes never move. Hints are
    /// placement only, never correctness: a busy hinted worker's share
    /// is stolen from the back exactly like any other deque, and
    /// unhinted jobs advance the round-robin cursor as before.
    pub fn spawn_batch_hinted(
        &self,
        jobs: Vec<(Option<usize>, Box<dyn FnOnce() + Send>)>,
    ) -> Result<()> {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(anyhow!("pool shut down"));
        }
        if jobs.is_empty() {
            return Ok(());
        }
        let unhinted = jobs.iter().filter(|(h, _)| h.is_none()).count();
        let start = self.shared.rr.fetch_add(unhinted, Ordering::Relaxed);
        let mut queues: Vec<Vec<PoolJob>> = (0..self.size).map(|_| Vec::new()).collect();
        let mut rr = 0usize;
        for (hint, job) in jobs {
            let inflight = self.in_flight.clone();
            inflight.fetch_add(1, Ordering::Relaxed);
            let wrapped: PoolJob = Box::new(move || {
                job();
                inflight.fetch_sub(1, Ordering::Relaxed);
            });
            let w = match hint {
                Some(h) => h % self.size,
                None => {
                    let w = (start.wrapping_add(rr)) % self.size;
                    rr += 1;
                    w
                }
            };
            queues[w].push(wrapped);
        }
        for (w, share) in queues.into_iter().enumerate() {
            if !share.is_empty() {
                self.shared.locals[w].lock().unwrap().extend(share);
            }
        }
        // One wake pass for the whole batch (see `PoolShared::notify`
        // for why the empty injector lock is taken first).
        if self.shared.parked.load(Ordering::SeqCst) > 0 {
            drop(self.shared.injector.lock().unwrap());
            self.shared.available.notify_all();
        }
        Ok(())
    }

    /// Run a set of retryable tasks to completion, preserving order.
    ///
    /// Each task is `Arc<dyn Fn>` so a failed attempt can be re-submitted;
    /// after `max_retries` additional attempts the whole job fails (all
    /// other tasks still drain first).
    pub fn run_tasks<T: Send + 'static>(
        &self,
        tasks: Vec<Arc<dyn Fn(usize) -> Result<T> + Send + Sync>>,
        max_retries: usize,
    ) -> Result<Vec<T>> {
        self.run_tasks_traced(tasks, max_retries, "dce.task", trace::Category::Compute)
    }

    /// [`Self::run_tasks`] with an explicit span name/category: every
    /// attempt runs under a span parented on the *caller's* current
    /// span, so work executed on (possibly stolen-to) worker threads
    /// still lands in the submitting job's trace.
    pub fn run_tasks_traced<T: Send + 'static>(
        &self,
        tasks: Vec<Arc<dyn Fn(usize) -> Result<T> + Send + Sync>>,
        max_retries: usize,
        span_name: &'static str,
        cat: trace::Category,
    ) -> Result<Vec<T>> {
        self.run_tasks_hinted(tasks, &[], max_retries, span_name, cat)
    }

    /// [`Self::run_tasks_traced`] with per-task placement hints
    /// (`hints[i]`, missing/None = round-robin): the first-attempt
    /// batch is dealt hint-aware; retries take the unhinted per-job
    /// path (after a failure, locality is the least of the problems).
    pub fn run_tasks_hinted<T: Send + 'static>(
        &self,
        tasks: Vec<Arc<dyn Fn(usize) -> Result<T> + Send + Sync>>,
        hints: &[Option<usize>],
        max_retries: usize,
        span_name: &'static str,
        cat: trace::Category,
    ) -> Result<Vec<T>> {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let parent = trace::current();
        let (rtx, rrx) = mpsc::channel::<(usize, usize, Result<T>)>();
        let make = |i: usize, attempt: usize| -> PoolJob {
            let task = tasks[i].clone();
            let rtx = rtx.clone();
            Box::new(move || {
                let mut sp = trace::span_in(span_name, cat, parent);
                sp.arg("task", i as u64).arg("attempt", attempt as u64);
                let r = task(attempt);
                drop(sp);
                let _ = rtx.send((i, attempt, r));
            })
        };
        // First attempts go out as one batch (single dispatch pass);
        // the rare retry takes the per-job path.
        self.spawn_batch_hinted(
            (0..n).map(|i| (hints.get(i).copied().flatten(), make(i, 0))).collect(),
        )?;
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut done = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        while done < n {
            let (i, attempt, result) = rrx
                .recv()
                .map_err(|_| anyhow!("executor pool died mid-job"))?;
            match result {
                Ok(v) => {
                    out[i] = Some(v);
                    done += 1;
                }
                Err(_) if attempt < max_retries => {
                    self.spawn(make(i, attempt + 1))?;
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!(
                            "task {i} failed after {} attempts",
                            attempt + 1
                        )));
                    }
                    done += 1;
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => out
                .into_iter()
                .map(|o| o.ok_or_else(|| anyhow!("task produced no result")))
                .collect(),
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Serialize with any worker's final re-check (see pop).
        drop(self.shared.injector.lock().unwrap());
        self.shared.available.notify_all();
        // The pool can be dropped FROM a worker thread (task closures
        // hold context clones; the last one may die inside a worker).
        // Joining yourself is EDEADLK — detach in that case, join the
        // rest.
        let me = std::thread::current().id();
        for w in self.workers.drain(..) {
            if w.thread().id() != me {
                let _ = w.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_tasks_preserves_order() {
        let pool = ExecutorPool::new(4);
        let tasks: Vec<Arc<dyn Fn(usize) -> Result<usize> + Send + Sync>> = (0..32)
            .map(|i| {
                let f: Arc<dyn Fn(usize) -> Result<usize> + Send + Sync> =
                    Arc::new(move |_| Ok(i * 10));
                f
            })
            .collect();
        let out = pool.run_tasks(tasks, 0).unwrap();
        assert_eq!(out, (0..32).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn failing_task_is_retried_then_succeeds() {
        let pool = ExecutorPool::new(2);
        let counter = Arc::new(AtomicU32::new(0));
        let c2 = counter.clone();
        let flaky: Arc<dyn Fn(usize) -> Result<u32> + Send + Sync> = Arc::new(move |attempt| {
            c2.fetch_add(1, Ordering::SeqCst);
            if attempt < 2 {
                anyhow::bail!("injected failure on attempt {attempt}")
            }
            Ok(99)
        });
        let out = pool.run_tasks(vec![flaky], 2).unwrap();
        assert_eq!(out, vec![99]);
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn exhausted_retries_fail_the_job() {
        let pool = ExecutorPool::new(2);
        let bad: Arc<dyn Fn(usize) -> Result<u32> + Send + Sync> =
            Arc::new(|_| anyhow::bail!("always broken"));
        let ok: Arc<dyn Fn(usize) -> Result<u32> + Send + Sync> = Arc::new(|_| Ok(1));
        let r = pool.run_tasks(vec![ok, bad], 1);
        assert!(r.is_err());
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("always broken"), "{msg}");
    }

    #[test]
    fn empty_task_set_is_ok() {
        let pool = ExecutorPool::new(1);
        let out: Vec<u32> = pool
            .run_tasks(Vec::<Arc<dyn Fn(usize) -> Result<u32> + Send + Sync>>::new(), 0)
            .unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn pool_parallelism_uses_all_workers() {
        let pool = ExecutorPool::new(4);
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let tasks: Vec<Arc<dyn Fn(usize) -> Result<()> + Send + Sync>> = (0..4)
            .map(|_| {
                let b = barrier.clone();
                let f: Arc<dyn Fn(usize) -> Result<()> + Send + Sync> = Arc::new(move |_| {
                    // Deadlocks unless all 4 run concurrently.
                    b.wait();
                    Ok(())
                });
                f
            })
            .collect();
        pool.run_tasks(tasks, 0).unwrap();
    }

    #[test]
    fn idle_workers_steal_queued_work() {
        // Pin worker deques full from outside, then have one slow job
        // block its owner: the rest must drain via injector/steals, so
        // the whole batch still finishes promptly.
        let pool = ExecutorPool::new(4);
        let done = Arc::new(AtomicU32::new(0));
        for i in 0..64u32 {
            let done = done.clone();
            pool.spawn(move || {
                if i == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(30));
                }
                done.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while done.load(Ordering::SeqCst) < 64 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(done.load(Ordering::SeqCst), 64, "pool lost jobs");
        // in_flight decrements after the job body; give it a beat.
        while pool.in_flight() > 0 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn worker_local_spawn_is_drained() {
        // A task fanning out subtasks from inside the pool: the
        // children land on the worker's own deque (or overflow to the
        // injector) and must all run.
        let pool = Arc::new(ExecutorPool::new(2));
        let done = Arc::new(AtomicU32::new(0));
        let (p2, d2) = (pool.clone(), done.clone());
        pool.spawn(move || {
            for _ in 0..100 {
                let d = d2.clone();
                p2.spawn(move || {
                    d.fetch_add(1, Ordering::SeqCst);
                })
                .unwrap();
            }
        })
        .unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while done.load(Ordering::SeqCst) < 100 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(done.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn spawn_batch_drains_on_an_idle_pool() {
        let pool = ExecutorPool::new(4);
        let done = Arc::new(AtomicU32::new(0));
        let jobs: Vec<Box<dyn FnOnce() + Send>> = (0..128)
            .map(|_| {
                let done = done.clone();
                let j: Box<dyn FnOnce() + Send> = Box::new(move || {
                    done.fetch_add(1, Ordering::SeqCst);
                });
                j
            })
            .collect();
        pool.spawn_batch(jobs).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while done.load(Ordering::SeqCst) < 128 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(done.load(Ordering::SeqCst), 128, "pool lost batched jobs");
        assert!(pool.spawn_batch(Vec::new()).is_ok(), "empty batch must be a no-op");
    }

    #[test]
    fn batched_jobs_are_still_stolen_from_a_blocked_worker() {
        // The injector bypass must not regress stealing: when one
        // worker's share is stuck behind a long job, its siblings must
        // still drain that deque from the back.
        let pool = ExecutorPool::new(4);
        let done = Arc::new(AtomicU32::new(0));
        let jobs: Vec<Box<dyn FnOnce() + Send>> = (0..64u32)
            .map(|i| {
                let done = done.clone();
                let j: Box<dyn FnOnce() + Send> = Box::new(move || {
                    if i == 0 {
                        // Hog this worker until every other job ran, so
                        // the rest of its share can only finish stolen.
                        let deadline =
                            std::time::Instant::now() + std::time::Duration::from_secs(5);
                        while done.load(Ordering::SeqCst) < 63
                            && std::time::Instant::now() < deadline
                        {
                            std::thread::yield_now();
                        }
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                });
                j
            })
            .collect();
        pool.spawn_batch(jobs).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while done.load(Ordering::SeqCst) < 64 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(done.load(Ordering::SeqCst), 64, "pool lost batched jobs");
        assert!(pool.steals() >= 1, "blocked worker's share was never stolen");
    }

    #[test]
    fn hinted_job_lands_on_the_hinted_idle_worker() {
        // Deterministic affinity check: block 3 of 4 workers, discover
        // the free one, hint a job at it. The free worker pops its own
        // deque first and the blocked ones can't steal (they're inside
        // jobs), so the hinted job MUST run there.
        let pool = ExecutorPool::new(4);
        let release = Arc::new(AtomicBool::new(false));
        let busy: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let started = Arc::new(AtomicU32::new(0));
        for _ in 0..3 {
            let (rel, busy, started) = (release.clone(), busy.clone(), started.clone());
            pool.spawn(move || {
                busy.lock().unwrap().push(current_worker_tag().unwrap().1);
                started.fetch_add(1, Ordering::SeqCst);
                while !rel.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
            })
            .unwrap();
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while started.load(Ordering::SeqCst) < 3 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(started.load(Ordering::SeqCst), 3, "blockers never started");
        let blocked = busy.lock().unwrap().clone();
        let free = (0..4).find(|w| !blocked.contains(w)).unwrap();
        let ran_on = Arc::new(Mutex::new(None));
        let r2 = ran_on.clone();
        let done = Arc::new(AtomicBool::new(false));
        let d2 = done.clone();
        pool.spawn_batch_hinted(vec![(
            Some(free),
            Box::new(move || {
                *r2.lock().unwrap() = current_worker_tag().map(|(_, w)| w);
                d2.store(true, Ordering::SeqCst);
            }),
        )])
        .unwrap();
        while !done.load(Ordering::SeqCst) && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        release.store(true, Ordering::SeqCst);
        assert!(done.load(Ordering::SeqCst), "hinted job never ran");
        assert_eq!(*ran_on.lock().unwrap(), Some(free), "hinted job missed its worker");
    }

    #[test]
    fn hint_to_a_busy_worker_degrades_to_stealing() {
        // A hint is placement, not correctness: with the hinted worker
        // wedged, the idle sibling must steal the job and finish it.
        let pool = ExecutorPool::new(2);
        let release = Arc::new(AtomicBool::new(false));
        let blocker_on = Arc::new(Mutex::new(None));
        let started = Arc::new(AtomicBool::new(false));
        let (rel, b2, s2) = (release.clone(), blocker_on.clone(), started.clone());
        pool.spawn(move || {
            *b2.lock().unwrap() = current_worker_tag().map(|(_, w)| w);
            s2.store(true, Ordering::SeqCst);
            while !rel.load(Ordering::SeqCst) {
                std::thread::yield_now();
            }
        })
        .unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while !started.load(Ordering::SeqCst) && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        let wedged = blocker_on.lock().unwrap().unwrap();
        let ran_on = Arc::new(Mutex::new(None));
        let r2 = ran_on.clone();
        let done = Arc::new(AtomicBool::new(false));
        let d2 = done.clone();
        pool.spawn_batch_hinted(vec![(
            Some(wedged),
            Box::new(move || {
                *r2.lock().unwrap() = current_worker_tag().map(|(_, w)| w);
                d2.store(true, Ordering::SeqCst);
            }),
        )])
        .unwrap();
        while !done.load(Ordering::SeqCst) && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        release.store(true, Ordering::SeqCst);
        assert!(done.load(Ordering::SeqCst), "job stuck behind a busy hinted worker");
        assert_ne!(*ran_on.lock().unwrap(), Some(wedged), "wedged worker can't have run it");
        assert!(pool.steals() >= 1, "completion must have come from a steal");
    }

    #[test]
    fn task_context_fault_injection() {
        let tc = TaskContext {
            stage: "s".into(),
            partition: 3,
            attempt: 0,
            metrics: MetricsRegistry::new(),
            fail_injector: Some(Arc::new(|tc: &TaskContext| {
                if tc.partition == 3 {
                    anyhow::bail!("injected")
                }
                Ok(())
            })),
        };
        assert!(tc.check_failure().is_err());
        let tc_ok = TaskContext { partition: 1, ..tc.clone() };
        assert!(tc_ok.check_failure().is_ok());
    }
}
