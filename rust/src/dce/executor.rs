//! Executor pool: the worker side of the compute engine.
//!
//! Real-execution mode runs tasks on a fixed thread pool sized
//! `nodes x cores_per_node` (each thread is one executor slot of the
//! simulated cluster). Tasks are retryable closures; failures are
//! retried up to the configured limit, which is what the fault-injection
//! soak (experiment E12) exercises.

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::metrics::MetricsRegistry;

/// Context visible to a running task.
#[derive(Clone)]
pub struct TaskContext {
    pub stage: String,
    pub partition: usize,
    pub attempt: usize,
    pub metrics: MetricsRegistry,
    /// Fault injection hook: return Err to simulate an executor failure.
    pub fail_injector: Option<Arc<dyn Fn(&TaskContext) -> Result<()> + Send + Sync>>,
}

impl TaskContext {
    pub fn check_failure(&self) -> Result<()> {
        match &self.fail_injector {
            Some(f) => f(self),
            None => Ok(()),
        }
    }
}

type PoolJob = Box<dyn FnOnce() + Send>;

/// Fixed-size worker pool.
pub struct ExecutorPool {
    tx: Mutex<Option<mpsc::Sender<PoolJob>>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    in_flight: Arc<AtomicUsize>,
}

impl ExecutorPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<PoolJob>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("dce-executor-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn executor")
            })
            .collect();
        Self { tx: Mutex::new(Some(tx)), workers, size, in_flight }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Submit a fire-and-forget job.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        let guard = self.tx.lock().unwrap();
        let tx = guard.as_ref().ok_or_else(|| anyhow!("pool shut down"))?;
        let inflight = self.in_flight.clone();
        inflight.fetch_add(1, Ordering::Relaxed);
        tx.send(Box::new(move || {
            job();
            inflight.fetch_sub(1, Ordering::Relaxed);
        }))
        .map_err(|_| anyhow!("pool workers gone"))
    }

    /// Run a set of retryable tasks to completion, preserving order.
    ///
    /// Each task is `Arc<dyn Fn>` so a failed attempt can be re-submitted;
    /// after `max_retries` additional attempts the whole job fails (all
    /// other tasks still drain first).
    pub fn run_tasks<T: Send + 'static>(
        &self,
        tasks: Vec<Arc<dyn Fn(usize) -> Result<T> + Send + Sync>>,
        max_retries: usize,
    ) -> Result<Vec<T>> {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (rtx, rrx) = mpsc::channel::<(usize, usize, Result<T>)>();
        let submit = |i: usize, attempt: usize| -> Result<()> {
            let task = tasks[i].clone();
            let rtx = rtx.clone();
            self.spawn(move || {
                let r = task(attempt);
                let _ = rtx.send((i, attempt, r));
            })
        };
        for i in 0..n {
            submit(i, 0)?;
        }
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut done = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        while done < n {
            let (i, attempt, result) = rrx
                .recv()
                .map_err(|_| anyhow!("executor pool died mid-job"))?;
            match result {
                Ok(v) => {
                    out[i] = Some(v);
                    done += 1;
                }
                Err(_) if attempt < max_retries => {
                    submit(i, attempt + 1)?;
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!(
                            "task {i} failed after {} attempts",
                            attempt + 1
                        )));
                    }
                    done += 1;
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => out
                .into_iter()
                .map(|o| o.ok_or_else(|| anyhow!("task produced no result")))
                .collect(),
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        *self.tx.lock().unwrap() = None;
        // The pool can be dropped FROM a worker thread (task closures
        // hold context clones; the last one may die inside a worker).
        // Joining yourself is EDEADLK — detach in that case, join the
        // rest.
        let me = std::thread::current().id();
        for w in self.workers.drain(..) {
            if w.thread().id() != me {
                let _ = w.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn run_tasks_preserves_order() {
        let pool = ExecutorPool::new(4);
        let tasks: Vec<Arc<dyn Fn(usize) -> Result<usize> + Send + Sync>> = (0..32)
            .map(|i| {
                let f: Arc<dyn Fn(usize) -> Result<usize> + Send + Sync> =
                    Arc::new(move |_| Ok(i * 10));
                f
            })
            .collect();
        let out = pool.run_tasks(tasks, 0).unwrap();
        assert_eq!(out, (0..32).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn failing_task_is_retried_then_succeeds() {
        let pool = ExecutorPool::new(2);
        let counter = Arc::new(AtomicU32::new(0));
        let c2 = counter.clone();
        let flaky: Arc<dyn Fn(usize) -> Result<u32> + Send + Sync> = Arc::new(move |attempt| {
            c2.fetch_add(1, Ordering::SeqCst);
            if attempt < 2 {
                anyhow::bail!("injected failure on attempt {attempt}")
            }
            Ok(99)
        });
        let out = pool.run_tasks(vec![flaky], 2).unwrap();
        assert_eq!(out, vec![99]);
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn exhausted_retries_fail_the_job() {
        let pool = ExecutorPool::new(2);
        let bad: Arc<dyn Fn(usize) -> Result<u32> + Send + Sync> =
            Arc::new(|_| anyhow::bail!("always broken"));
        let ok: Arc<dyn Fn(usize) -> Result<u32> + Send + Sync> = Arc::new(|_| Ok(1));
        let r = pool.run_tasks(vec![ok, bad], 1);
        assert!(r.is_err());
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("always broken"), "{msg}");
    }

    #[test]
    fn empty_task_set_is_ok() {
        let pool = ExecutorPool::new(1);
        let out: Vec<u32> = pool
            .run_tasks(Vec::<Arc<dyn Fn(usize) -> Result<u32> + Send + Sync>>::new(), 0)
            .unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn pool_parallelism_uses_all_workers() {
        let pool = ExecutorPool::new(4);
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let tasks: Vec<Arc<dyn Fn(usize) -> Result<()> + Send + Sync>> = (0..4)
            .map(|_| {
                let b = barrier.clone();
                let f: Arc<dyn Fn(usize) -> Result<()> + Send + Sync> = Arc::new(move |_| {
                    // Deadlocks unless all 4 run concurrently.
                    b.wait();
                    Ok(())
                });
                f
            })
            .collect();
        pool.run_tasks(tasks, 0).unwrap();
    }

    #[test]
    fn task_context_fault_injection() {
        let tc = TaskContext {
            stage: "s".into(),
            partition: 3,
            attempt: 0,
            metrics: MetricsRegistry::new(),
            fail_injector: Some(Arc::new(|tc: &TaskContext| {
                if tc.partition == 3 {
                    anyhow::bail!("injected")
                }
                Ok(())
            })),
        };
        assert!(tc.check_failure().is_err());
        let tc_ok = TaskContext { partition: 1, ..tc.clone() };
        assert!(tc_ok.check_failure().is_ok());
    }
}
